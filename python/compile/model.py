"""L2: the jitted compute graphs that the AOT pipeline lowers.

Each function composes the L1 Pallas kernels into the exact signature
the rust runtime executes (fixed shapes, f32, tuple outputs — see
``rust/src/runtime/``):

- ``scores_fn(x, w) -> (p,)``       score matvec for one row tile
- ``grad_fn(x, c) -> (a,)``         subgradient assembly for one tile
- ``pair_count_fn(p, y, v) -> (c, d)``  tiled pair-violation counts
- ``hinge_from_counts_fn``          Lemma-1 loss assembly (fused tail)

Python runs only at build time: ``aot.py`` lowers these once to HLO
text under ``artifacts/`` and the rust coordinator loads the artifacts
via PJRT.
"""

import jax.numpy as jnp

from .kernels import grad as grad_kernel
from .kernels import pair_count as pair_count_kernel
from .kernels import scores as scores_kernel


def scores_fn(x, w):
    """One row tile of p = X @ w. Returns a 1-tuple (AOT convention)."""
    return (scores_kernel.scores(x, w),)


def grad_fn(x, coeffs):
    """One row tile of a = X^T @ coeffs. Returns a 1-tuple."""
    return (grad_kernel.grad(x, coeffs),)


def pair_count_fn(p, y, valid):
    """Tiled pair-violation counts (c, d) — 2-tuple output."""
    c, d = pair_count_kernel.pair_count(p, y, valid)
    return (c, d)


def hinge_from_counts_fn(p, c, d, inv_n):
    """Lemma 1: loss = (1/N) Σ ((c_i − d_i)·p_i + c_i), fused reduction.

    ``inv_n`` is a (1,) array so N stays a runtime input (the pair count
    depends on the labels, not the shapes).
    """
    cd = c - d
    loss = jnp.sum(cd * p + c) * inv_n[0]
    return (loss.reshape((1,)),)
