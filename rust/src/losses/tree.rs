//! Algorithm 3 — the paper's main contribution.
//!
//! Computes the pairwise-hinge frequencies
//! `c_i = |{j : y_i < y_j ∧ p_i > p_j − 1}|` (eq. 5) and
//! `d_i = |{j : y_i > y_j ∧ p_i < p_j + 1}|` (eq. 6) with two sweeps over
//! the examples sorted by predicted score, inserting labels into an
//! order-statistics tree so that each `c_i`/`d_i` is one `Count-Larger` /
//! `Count-Smaller` query. Total `O(m log m)` per call (Theorem 2), for
//! *arbitrary real-valued* utility scores — no dependence on the number
//! of distinct levels `r`.

use super::{assemble_from_counts, OracleOutput, RankingOracle};
use crate::linalg::ops::{argsort_into, par_argsort_into, SortScratch};
use crate::rbtree::{OsTree, RankCounter};
use crate::runtime::pool::WorkerPool;
use crate::util::timer::PhaseTimes;
use std::sync::Arc;

/// Tree-based oracle, generic over the counting structure so the
/// ablation bench can swap in [`crate::rbtree::FenwickCounter`] or the
/// dedup tree variant. Production use is [`TreeOracle`].
pub struct GenericTreeOracle<T: RankCounter> {
    counter: T,
    /// Reusable buffers (Algorithm 3 lines 2–4) — no per-call allocation.
    pi: Vec<usize>,
    c: Vec<u64>,
    d: Vec<u64>,
    /// §Perf: `p` and `y` gathered into score order once per call, so the
    /// two sweeps stream contiguous memory instead of chasing `π`
    /// (≈25% oracle speedup at m = 500k — EXPERIMENTS.md §Perf).
    p_sorted: Vec<f64>,
    y_sorted: Vec<f64>,
    /// Optional persistent pool: when present, line 4's argsort runs as
    /// the deterministic parallel merge sort (identical permutation, see
    /// [`par_argsort_into`]); the tree sweeps themselves stay serial —
    /// that is [`super::sharded::ShardedTreeOracle`]'s job.
    pool: Option<Arc<WorkerPool>>,
    sort_scratch: SortScratch,
    /// Per-phase timing (sort / sweep / assemble), for §Perf.
    pub phases: PhaseTimes,
}

/// The paper's TreeRSVM oracle: red-black order-statistics tree.
pub type TreeOracle = GenericTreeOracle<OsTree>;

impl TreeOracle {
    pub fn new() -> Self {
        GenericTreeOracle::with_counter(OsTree::new())
    }

    /// Dedup-tree variant (`nodesize` of §4.2) — `O(log r)` tree ops.
    pub fn new_dedup() -> GenericTreeOracle<OsTree> {
        GenericTreeOracle::with_counter(OsTree::new_dedup())
    }
}

impl Default for TreeOracle {
    fn default() -> Self {
        Self::new()
    }
}

/// Fenwick-counter variant of the oracle (ablation): requires the label
/// universe up front (always available in training — labels are fixed).
pub fn fenwick_oracle(y: &[f64]) -> GenericTreeOracle<crate::rbtree::FenwickCounter> {
    GenericTreeOracle::with_counter(crate::rbtree::FenwickCounter::new(y))
}

impl<T: RankCounter> GenericTreeOracle<T> {
    pub fn with_counter(counter: T) -> Self {
        GenericTreeOracle {
            counter,
            pi: Vec::new(),
            c: Vec::new(),
            d: Vec::new(),
            p_sorted: Vec::new(),
            y_sorted: Vec::new(),
            pool: None,
            sort_scratch: SortScratch::default(),
            phases: PhaseTimes::new(),
        }
    }

    /// Run this oracle's argsort on a persistent pool (builder-style).
    /// The permutation — and hence every count and float — is identical
    /// to the serial sort; only the sort wall-clock changes.
    pub fn with_pool(mut self, pool: Arc<WorkerPool>) -> Self {
        self.pool = Some(pool);
        self
    }

    /// Compute the raw frequency vectors (`c`, `d`) of eqs. (5)–(6) into
    /// the internal buffers; exposed for tests and for the loss-only path.
    pub fn compute_counts(&mut self, p: &[f64], y: &[f64]) -> (&[u64], &[u64]) {
        let m = p.len();
        assert_eq!(m, y.len());
        self.c.clear();
        self.c.resize(m, 0);
        self.d.clear();
        self.d.resize(m, 0);

        // Line 4: π ← indices sorted ascending by p; gather p, y into
        // score order so the sweeps read sequentially (§Perf).
        let pi_buf = &mut self.pi;
        let scratch = &mut self.sort_scratch;
        let pool = self.pool.as_deref();
        self.phases.time("sort", || match pool {
            Some(pool) => par_argsort_into(p, pi_buf, scratch, pool),
            None => argsort_into(p, pi_buf),
        });
        self.p_sorted.clear();
        self.p_sorted.extend(self.pi.iter().map(|&k| p[k]));
        self.y_sorted.clear();
        self.y_sorted.extend(self.pi.iter().map(|&k| y[k]));

        // Lines 5–13: forward sweep. Invariant: before processing i, the
        // tree holds y[π[k]] for all k inside i's margin window; the
        // while loop extends the window to keep it so.
        //
        // The paper writes the window tests as `p_i > p_j − 1` (line 8)
        // and `p_i < p_j + 1` (line 17); we evaluate both as the single
        // canonical hinge predicate `1 + p_low − p_high > 0` so that
        // every oracle in the crate (tree / pair / r-level / squared /
        // the Pallas kernel) agrees bit-for-bit on boundary values —
        // the two paper forms can disagree under floating point when
        // score differences land exactly on the margin.
        // NaN labels are incomparable: never inserted (a NaN key would
        // sit structure-dependently in the counting tree) and counted
        // zero as queries — matching [`super::sharded`] exactly, so a
        // rogue NaN can neither panic nor make serial and sharded runs
        // diverge.
        self.phases.time("sweep_c", || {
            self.counter.clear();
            let (ps, ys) = (&self.p_sorted, &self.y_sorted);
            let mut j = 0usize;
            for i in 0..m {
                let p_i = ps[i];
                // i is the low-label candidate: violation ⇔ 1 + p_i − p_j > 0.
                while j < m && 1.0 + p_i - ps[j] > 0.0 {
                    if !ys[j].is_nan() {
                        self.counter.insert(ys[j]);
                    }
                    j += 1;
                }
                let yi = ys[i];
                self.c[self.pi[i]] = if yi.is_nan() { 0 } else { self.counter.count_larger(yi) };
            }
        });

        // Lines 14–22: backward sweep for d.
        self.phases.time("sweep_d", || {
            self.counter.clear();
            let (ps, ys) = (&self.p_sorted, &self.y_sorted);
            let mut j = m as isize - 1;
            for i in (0..m).rev() {
                let p_i = ps[i];
                // i is the high-label candidate: violation ⇔ 1 + p_j − p_i > 0.
                while j >= 0 && 1.0 + ps[j as usize] - p_i > 0.0 {
                    if !ys[j as usize].is_nan() {
                        self.counter.insert(ys[j as usize]);
                    }
                    j -= 1;
                }
                let yi = ys[i];
                self.d[self.pi[i]] = if yi.is_nan() { 0 } else { self.counter.count_smaller(yi) };
            }
        });

        (&self.c, &self.d)
    }
}

impl<T: RankCounter> RankingOracle for GenericTreeOracle<T> {
    fn eval(&mut self, p: &[f64], y: &[f64], n_pairs: f64) -> OracleOutput {
        self.compute_counts(p, y);
        let (c, d) = (&self.c, &self.d);
        // Lines 23–24 via Lemmas 1–2.
        assemble_from_counts(p, c, d, n_pairs)
    }

    fn name(&self) -> &'static str {
        "tree"
    }

    fn phase_times(&self) -> Option<&PhaseTimes> {
        Some(&self.phases)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::losses::count_comparable_pairs;
    use crate::util::rng::Rng;

    /// Brute-force eqs. (5)–(6).
    fn naive_counts(p: &[f64], y: &[f64]) -> (Vec<u64>, Vec<u64>) {
        let m = p.len();
        let mut c = vec![0u64; m];
        let mut d = vec![0u64; m];
        for i in 0..m {
            for j in 0..m {
                if y[i] < y[j] && 1.0 + p[i] - p[j] > 0.0 {
                    c[i] += 1;
                }
                if y[i] > y[j] && 1.0 + p[j] - p[i] > 0.0 {
                    d[i] += 1;
                }
            }
        }
        (c, d)
    }

    /// Direct eq. (4): average pairwise hinge.
    fn naive_loss(p: &[f64], y: &[f64]) -> f64 {
        let m = p.len();
        let mut loss = 0.0;
        let mut n = 0u64;
        for i in 0..m {
            for j in 0..m {
                if y[i] < y[j] {
                    n += 1;
                    loss += (1.0 + p[i] - p[j]).max(0.0);
                }
            }
        }
        if n == 0 {
            0.0
        } else {
            loss / n as f64
        }
    }

    #[test]
    fn counts_match_bruteforce_randomized() {
        let mut rng = Rng::new(55);
        for trial in 0..40 {
            let m = 1 + rng.below(120);
            // Mix of label regimes: real-valued, few levels, bipartite.
            let y: Vec<f64> = match trial % 3 {
                0 => (0..m).map(|_| rng.normal()).collect(),
                1 => (0..m).map(|_| rng.below(5) as f64).collect(),
                _ => (0..m).map(|_| rng.below(2) as f64).collect(),
            };
            let p: Vec<f64> = (0..m).map(|_| rng.normal() * 2.0).collect();
            let (nc, nd) = naive_counts(&p, &y);
            let mut oracle = TreeOracle::new();
            let (c, d) = oracle.compute_counts(&p, &y);
            assert_eq!(c, &nc[..], "c mismatch (trial {trial})");
            assert_eq!(d, &nd[..], "d mismatch (trial {trial})");
        }
    }

    #[test]
    fn lemma1_loss_equals_direct_hinge() {
        let mut rng = Rng::new(66);
        for _ in 0..30 {
            let m = 2 + rng.below(80);
            let y: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
            let p: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
            let n = count_comparable_pairs(&y) as f64;
            let mut oracle = TreeOracle::new();
            let out = oracle.eval(&p, &y, n);
            let direct = naive_loss(&p, &y);
            let tol = 1e-9 * (1.0 + direct);
            assert!((out.loss - direct).abs() < tol, "{} vs {}", out.loss, direct);
        }
    }

    #[test]
    fn dedup_variant_agrees() {
        let mut rng = Rng::new(77);
        let m = 200;
        let y: Vec<f64> = (0..m).map(|_| rng.below(4) as f64).collect();
        let p: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
        let n = count_comparable_pairs(&y) as f64;
        let mut a = TreeOracle::new();
        let mut b = TreeOracle::new_dedup();
        let oa = a.eval(&p, &y, n);
        let ob = b.eval(&p, &y, n);
        assert_eq!(oa.coeffs, ob.coeffs);
        assert!((oa.loss - ob.loss).abs() < 1e-12);
    }

    #[test]
    fn fenwick_counter_agrees() {
        use crate::rbtree::FenwickCounter;
        let mut rng = Rng::new(88);
        let m = 150;
        let y: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
        let p: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
        let n = count_comparable_pairs(&y) as f64;
        let mut a = TreeOracle::new();
        let mut b = GenericTreeOracle::with_counter(FenwickCounter::new(&y));
        let oa = a.eval(&p, &y, n);
        let ob = b.eval(&p, &y, n);
        assert_eq!(oa.coeffs, ob.coeffs);
        assert!((oa.loss - ob.loss).abs() < 1e-12);
    }

    #[test]
    fn degenerate_inputs() {
        let mut oracle = TreeOracle::new();
        // all labels equal → N = 0 → zero loss/grad
        let out = oracle.eval(&[1.0, 2.0, 3.0], &[5.0, 5.0, 5.0], 0.0);
        assert_eq!(out.loss, 0.0);
        assert!(out.coeffs.iter().all(|&c| c == 0.0));
        // single example
        let out = oracle.eval(&[1.0], &[1.0], 0.0);
        assert_eq!(out.loss, 0.0);
        // empty
        let out = oracle.eval(&[], &[], 0.0);
        assert_eq!(out.loss, 0.0);
        assert!(out.coeffs.is_empty());
    }

    #[test]
    fn tied_predictions_inside_margin() {
        // p all equal: every comparable pair violates the margin
        // (1 + p_i − p_j = 1 > 0) → loss = 1.
        let y = [1.0, 2.0, 3.0];
        let p = [0.0, 0.0, 0.0];
        let n = count_comparable_pairs(&y) as f64;
        let mut oracle = TreeOracle::new();
        let out = oracle.eval(&p, &y, n);
        assert!((out.loss - 1.0).abs() < 1e-12);
    }

    #[test]
    fn perfect_separation_zero_loss() {
        // Scores ordered like labels with margin > 1 → zero loss and grad.
        let y = [1.0, 2.0, 3.0, 4.0];
        let p = [0.0, 2.0, 4.0, 6.0];
        let n = count_comparable_pairs(&y) as f64;
        let mut oracle = TreeOracle::new();
        let out = oracle.eval(&p, &y, n);
        assert_eq!(out.loss, 0.0);
        assert!(out.coeffs.iter().all(|&c| c == 0.0));
    }

    #[test]
    fn pooled_argsort_variant_is_bit_identical() {
        use std::sync::Arc;
        let mut rng = Rng::new(99);
        let m = 2000; // above the parallel-sort threshold
        let y: Vec<f64> = (0..m).map(|_| rng.below(6) as f64).collect();
        let p: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
        let n = count_comparable_pairs(&y) as f64;
        let mut serial = TreeOracle::new();
        let pool = Arc::new(crate::runtime::pool::WorkerPool::new(4));
        let mut pooled = TreeOracle::new().with_pool(pool);
        let a = serial.eval(&p, &y, n);
        let b = pooled.eval(&p, &y, n);
        assert_eq!(a.coeffs, b.coeffs);
        assert_eq!(a.loss.to_bits(), b.loss.to_bits());
    }

    #[test]
    fn buffers_reused_across_calls() {
        let mut oracle = TreeOracle::new();
        let y = [1.0, 2.0];
        let n = 1.0;
        let a = oracle.eval(&[0.5, 0.0], &y, n);
        let b = oracle.eval(&[0.0, 5.0], &y, n);
        assert!(a.loss > 0.0);
        assert_eq!(b.loss, 0.0);
        // different sizes across calls must also work
        let c = oracle.eval(&[1.0, 2.0, 3.0], &[3.0, 2.0, 1.0], 3.0);
        assert!(c.loss > 0.0);
    }
}
