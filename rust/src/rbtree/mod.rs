//! Order-statistics search structures — the paper's §4.2.
//!
//! [`OsTree`] is the faithful reproduction: a red-black tree augmented
//! with subtree sizes supporting `Tree-Insert`, `Count-Smaller`
//! (Algorithm 2) and `Count-Larger` in `O(log m)` (Lemmas 3–5), plus the
//! duplicate-merging `nodesize` variant with `O(log r)` operations.
//! [`FenwickCounter`] is an ablation alternative exploiting the fixed key
//! universe of Algorithm 3 (see `benches/ablation_tree.rs`).

pub mod fenwick;
pub mod ostree;
pub mod sumtree;

pub use fenwick::FenwickCounter;
pub use ostree::OsTree;
pub use sumtree::{Agg, SumTree};

/// Common interface over the counting structures so Algorithm 3 can be
/// instantiated with either (used by the ablation bench and tests).
pub trait RankCounter {
    /// Insert one occurrence of `key`.
    fn insert(&mut self, key: f64);
    /// Stored keys strictly smaller than `key`.
    fn count_smaller(&self, key: f64) -> u64;
    /// Stored keys strictly larger than `key`.
    fn count_larger(&self, key: f64) -> u64;
    /// Remove everything, keeping capacity.
    fn clear(&mut self);
}

impl RankCounter for OsTree {
    fn insert(&mut self, key: f64) {
        OsTree::insert(self, key)
    }
    fn count_smaller(&self, key: f64) -> u64 {
        OsTree::count_smaller(self, key)
    }
    fn count_larger(&self, key: f64) -> u64 {
        OsTree::count_larger(self, key)
    }
    fn clear(&mut self) {
        OsTree::clear(self)
    }
}

impl RankCounter for FenwickCounter {
    fn insert(&mut self, key: f64) {
        FenwickCounter::insert(self, key)
    }
    fn count_smaller(&self, key: f64) -> u64 {
        FenwickCounter::count_smaller(self, key)
    }
    fn count_larger(&self, key: f64) -> u64 {
        FenwickCounter::count_larger(self, key)
    }
    fn clear(&mut self) {
        FenwickCounter::clear(self)
    }
}
