//! Truncated-Newton optimizer — the PRSVM baseline (Chapelle & Keerthi,
//! 2010).
//!
//! PRSVM minimizes the *squared* pairwise hinge plus the quadratic
//! regularizer,
//!
//! `J(w) = λ‖w‖² + (1/N) Σ_{y_i<y_j} max(0, 1 + w·x_i − w·x_j)²`,
//!
//! which is differentiable with a piecewise-linear gradient, so Newton
//! steps with a conjugate-gradient inner solve (products with the
//! generalized Hessian only — never materializing it) converge in a
//! handful of outer iterations. Termination follows the paper's setup:
//! Newton decrement `< 1e-6`, stated there to be roughly equivalent to
//! the BMRM methods' `ε < 1e-3`.
//!
//! The generalized Hessian at `w` is `2λI + (2/N) Xᵀ A_w X` with `A_w`
//! the signed incidence structure of the *active* pairs; products are
//! provided by [`crate::losses::SquaredPairOracle::hessian_apply`]
//! through the [`HessianOracle`] trait.

use crate::bmrm::ScoreOracle;
use crate::linalg::ops;

/// Score-space generalized-Hessian product, to be combined with
/// [`ScoreOracle`]'s matvecs: `H v = 2λ v + Xᵀ · hess_apply(X·v)`.
/// The active set is the one fixed by the most recent `risk_at`.
pub trait HessianOracle: ScoreOracle {
    fn hess_apply(&mut self, u: &[f64]) -> Vec<f64>;
}

/// Truncated-Newton hyper-parameters.
#[derive(Clone, Debug)]
pub struct NewtonConfig {
    pub lambda: f64,
    /// Stop when the Newton decrement √(−gᵀd) falls below this.
    pub decrement_tol: f64,
    pub max_iter: usize,
    /// CG: relative residual target and iteration cap (truncation).
    pub cg_tol: f64,
    pub cg_max_iter: usize,
    /// Armijo backtracking parameters.
    pub armijo_c: f64,
    pub backtrack: f64,
    pub max_backtracks: usize,
}

impl Default for NewtonConfig {
    fn default() -> Self {
        NewtonConfig {
            lambda: 1e-2,
            decrement_tol: 1e-6,
            max_iter: 100,
            cg_tol: 1e-4,
            cg_max_iter: 250,
            armijo_c: 1e-4,
            backtrack: 0.5,
            max_backtracks: 40,
        }
    }
}

/// Outcome of a truncated-Newton run.
#[derive(Clone, Debug)]
pub struct NewtonResult {
    pub w: Vec<f64>,
    pub objective: f64,
    pub iterations: usize,
    pub converged: bool,
    /// (iteration, objective, decrement) trace.
    pub trace: Vec<(usize, f64, f64)>,
    /// Total seconds inside loss/grad/Hessian evaluations.
    pub oracle_secs_total: f64,
}

/// Minimize the PRSVM objective with truncated Newton from `w0`.
pub fn optimize<O: HessianOracle>(
    oracle: &mut O,
    cfg: &NewtonConfig,
    w0: Vec<f64>,
) -> NewtonResult {
    let n = oracle.dim();
    assert_eq!(w0.len(), n);
    let lambda = cfg.lambda;
    let mut w = w0;
    let mut trace = Vec::new();
    let mut oracle_secs_total = 0.0;
    let mut converged = false;
    let mut iterations = 0;

    // Objective and gradient at w; risk_at also fixes the active set used
    // by subsequent Hessian products.
    let eval = |oracle: &mut O, w: &[f64]| -> (f64, Vec<f64>, Vec<f64>) {
        let p = oracle.scores(w);
        let (risk, coeffs) = oracle.risk_at(&p);
        let mut g = oracle.grad(&coeffs);
        ops::axpy(2.0 * lambda, w, &mut g);
        let obj = risk + lambda * ops::norm_sq(w);
        (obj, g, p)
    };

    let t0 = std::time::Instant::now();
    let (mut obj, mut g, _p) = eval(oracle, &w);
    oracle_secs_total += t0.elapsed().as_secs_f64();

    for it in 1..=cfg.max_iter {
        iterations = it;
        let t_iter = std::time::Instant::now();

        // --- CG solve of H d = −g (truncated).
        let mut d = vec![0.0; n];
        let mut r: Vec<f64> = g.iter().map(|x| -x).collect(); // r = −g − H·0
        let mut q = r.clone(); // search direction
        let r0_norm = ops::norm(&r);
        if r0_norm > 0.0 {
            let mut rs_old = ops::norm_sq(&r);
            for _ in 0..cfg.cg_max_iter {
                // Hq = 2λq + Xᵀ A (X q)
                let u = oracle.scores(&q);
                let hq_scores = oracle.hess_apply(&u);
                let mut hq = oracle.grad(&hq_scores);
                ops::axpy(2.0 * lambda, &q, &mut hq);

                let qhq = ops::dot(&q, &hq);
                if qhq <= 1e-300 {
                    break; // flat direction; H is PSD so stop
                }
                let alpha = rs_old / qhq;
                ops::axpy(alpha, &q, &mut d);
                ops::axpy(-alpha, &hq, &mut r);
                let rs_new = ops::norm_sq(&r);
                if rs_new.sqrt() <= cfg.cg_tol * r0_norm {
                    break;
                }
                let beta = rs_new / rs_old;
                for (qi, ri) in q.iter_mut().zip(&r) {
                    *qi = ri + beta * *qi;
                }
                rs_old = rs_new;
            }
        }

        // Newton decrement: √(−gᵀd) (≥ 0 since H ≻ 0 and d ≈ −H⁻¹g).
        let gd = ops::dot(&g, &d);
        let decrement = (-gd).max(0.0).sqrt();
        trace.push((it, obj, decrement));
        if decrement < cfg.decrement_tol {
            converged = true;
            oracle_secs_total += t_iter.elapsed().as_secs_f64();
            break;
        }

        // --- Armijo backtracking on J along d.
        let mut step = 1.0;
        let mut accepted = false;
        for _ in 0..cfg.max_backtracks {
            let w_try: Vec<f64> = w.iter().zip(&d).map(|(wi, di)| wi + step * di).collect();
            let (obj_try, g_try, _) = eval(oracle, &w_try);
            if obj_try <= obj + cfg.armijo_c * step * gd {
                w = w_try;
                obj = obj_try;
                g = g_try;
                accepted = true;
                break;
            }
            step *= cfg.backtrack;
        }
        oracle_secs_total += t_iter.elapsed().as_secs_f64();
        if !accepted {
            // Numerical floor reached; treat as converged at the floor.
            converged = decrement < cfg.decrement_tol * 1e3;
            break;
        }
    }

    NewtonResult { w, objective: obj, iterations, converged, trace, oracle_secs_total }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bmrm::ScoreOracle;

    /// Smooth quadratic test problem: risk = ‖p − target‖², identity X.
    struct Quad {
        target: Vec<f64>,
    }
    impl ScoreOracle for Quad {
        fn dim(&self) -> usize {
            self.target.len()
        }
        fn scores(&mut self, w: &[f64]) -> Vec<f64> {
            w.to_vec()
        }
        fn risk_at(&mut self, p: &[f64]) -> (f64, Vec<f64>) {
            let mut risk = 0.0;
            let mut g = Vec::with_capacity(p.len());
            for (pi, ti) in p.iter().zip(&self.target) {
                risk += (pi - ti) * (pi - ti);
                g.push(2.0 * (pi - ti));
            }
            (risk, g)
        }
        fn grad(&mut self, c: &[f64]) -> Vec<f64> {
            c.to_vec()
        }
    }
    impl HessianOracle for Quad {
        fn hess_apply(&mut self, u: &[f64]) -> Vec<f64> {
            u.iter().map(|x| 2.0 * x).collect() // ∇²risk = 2I in score space
        }
    }

    #[test]
    fn newton_one_step_on_quadratic() {
        // J = λ‖w‖² + ‖w − c‖² → w* = c/(1+λ); Newton should land in 1–2
        // iterations.
        let lambda = 0.5;
        let mut o = Quad { target: vec![2.0, -4.0, 1.0] };
        let cfg = NewtonConfig { lambda, decrement_tol: 1e-10, ..Default::default() };
        let res = optimize(&mut o, &cfg, vec![0.0; 3]);
        assert!(res.converged);
        assert!(res.iterations <= 3, "took {}", res.iterations);
        for (wi, ti) in res.w.iter().zip(&o.target) {
            assert!((wi - ti / 1.5).abs() < 1e-8);
        }
    }

    #[test]
    fn objective_monotone_decreasing() {
        let mut o = Quad { target: vec![1.0; 6] };
        let cfg = NewtonConfig { lambda: 0.1, decrement_tol: 1e-12, ..Default::default() };
        let res = optimize(&mut o, &cfg, vec![5.0; 6]);
        for w in res.trace.windows(2) {
            assert!(w[1].1 <= w[0].1 + 1e-12, "objective increased");
        }
        assert!(res.converged);
    }
}
