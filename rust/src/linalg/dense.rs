//! Dense row-major matrix.
//!
//! Examples are stored as rows (`m × n`); the paper writes `X ∈ R^{n×m}`
//! with examples as columns, so our `p = X·w` is the paper's `Xᵀw` and our
//! `Xᵀ·v` is the paper's `X·v`. The row-major layout serves both the score
//! matvec (row-wise dot products) and the subgradient accumulation
//! (row-wise axpy) with sequential memory access.

/// Dense `rows × cols` matrix, row-major.
#[derive(Clone, Debug, PartialEq)]
pub struct DenseMatrix {
    rows: usize,
    cols: usize,
    data: Vec<f64>,
}

impl DenseMatrix {
    /// Zero-filled matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        DenseMatrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// From a row-major data vector. Panics if the length mismatches.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Self {
        assert_eq!(data.len(), rows * cols, "data length must be rows*cols");
        DenseMatrix { rows, cols, data }
    }

    /// From a slice of rows. Panics on ragged input.
    pub fn from_rows(rows: &[Vec<f64>]) -> Self {
        let r = rows.len();
        let c = rows.first().map(|r| r.len()).unwrap_or(0);
        let mut data = Vec::with_capacity(r * c);
        for row in rows {
            assert_eq!(row.len(), c, "ragged rows");
            data.extend_from_slice(row);
        }
        DenseMatrix { rows: r, cols: c, data }
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f64 {
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f64) {
        self.data[i * self.cols + j] = v;
    }

    /// Flat row-major data.
    pub fn data(&self) -> &[f64] {
        &self.data
    }

    /// `p = X·w` (length `rows`). Panics if `w.len() != cols`.
    pub fn matvec(&self, w: &[f64], out: &mut [f64]) {
        assert_eq!(w.len(), self.cols);
        assert_eq!(out.len(), self.rows);
        for (i, o) in out.iter_mut().enumerate() {
            *o = super::ops::dot(self.row(i), w);
        }
    }

    /// `a = Xᵀ·v` (length `cols`), accumulated row-wise. Panics on shape
    /// mismatch. `out` is overwritten.
    pub fn matvec_t(&self, v: &[f64], out: &mut [f64]) {
        assert_eq!(v.len(), self.rows);
        assert_eq!(out.len(), self.cols);
        out.iter_mut().for_each(|x| *x = 0.0);
        for i in 0..self.rows {
            let vi = v[i];
            if vi != 0.0 {
                super::ops::axpy(vi, self.row(i), out);
            }
        }
    }

    /// View of a contiguous row range `[lo, hi)` as a borrowed sub-matrix.
    pub fn row_slice(&self, lo: usize, hi: usize) -> DenseView<'_> {
        assert!(lo <= hi && hi <= self.rows);
        DenseView {
            rows: hi - lo,
            cols: self.cols,
            data: &self.data[lo * self.cols..hi * self.cols],
        }
    }
}

/// Borrowed row-major view (used by the XLA backend to feed row tiles).
#[derive(Clone, Copy, Debug)]
pub struct DenseView<'a> {
    pub rows: usize,
    pub cols: usize,
    pub data: &'a [f64],
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matvec_matches_manual() {
        let x = DenseMatrix::from_rows(&[vec![1.0, 2.0], vec![3.0, 4.0], vec![5.0, 6.0]]);
        let w = [10.0, 1.0];
        let mut p = vec![0.0; 3];
        x.matvec(&w, &mut p);
        assert_eq!(p, vec![12.0, 34.0, 56.0]);
    }

    #[test]
    fn matvec_t_matches_manual() {
        let x = DenseMatrix::from_rows(&[vec![1.0, 2.0], vec![3.0, 4.0]]);
        let v = [1.0, -1.0];
        let mut a = vec![0.0; 2];
        x.matvec_t(&v, &mut a);
        assert_eq!(a, vec![-2.0, -2.0]);
    }

    #[test]
    fn transpose_consistency_property() {
        // <Xw, v> == <w, Xᵀv> for random data.
        let mut rng = crate::util::rng::Rng::new(3);
        for _ in 0..20 {
            let m = 1 + rng.below(30);
            let n = 1 + rng.below(20);
            let mut x = DenseMatrix::zeros(m, n);
            for i in 0..m {
                for j in 0..n {
                    x.set(i, j, rng.normal());
                }
            }
            let w: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let v: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
            let mut p = vec![0.0; m];
            x.matvec(&w, &mut p);
            let mut a = vec![0.0; n];
            x.matvec_t(&v, &mut a);
            let lhs = crate::linalg::ops::dot(&p, &v);
            let rhs = crate::linalg::ops::dot(&w, &a);
            assert!((lhs - rhs).abs() < 1e-9 * (1.0 + lhs.abs()), "{lhs} vs {rhs}");
        }
    }

    #[test]
    fn row_slice_views() {
        let x = DenseMatrix::from_rows(&[vec![1.0], vec![2.0], vec![3.0]]);
        let v = x.row_slice(1, 3);
        assert_eq!(v.rows, 2);
        assert_eq!(v.data, &[2.0, 3.0]);
    }

    #[test]
    #[should_panic]
    fn shape_mismatch_panics() {
        let x = DenseMatrix::zeros(2, 3);
        let mut p = vec![0.0; 2];
        x.matvec(&[1.0, 2.0], &mut p); // w too short
    }
}
