//! Deterministic work plans for the stealing scheduler.
//!
//! The work-stealing [`super::pool::WorkerPool`] balances *tasks*, so
//! the quality of balance is set by how finely a parallel region is cut
//! into tasks. For the grouped oracle the natural unit is the query
//! group — but real grouped data is Zipf-skewed: a handful of giant
//! groups next to thousands of singletons. One task per group would
//! drown the scheduler in thousands of near-empty tasks; one task per
//! *shard* (the PR 1–3 plan) serializes the batch behind the giant
//! group's owner. [`WorkPlan`] is the middle ground: pack consecutive
//! items into **bounded-weight runs** — tiny items coalesce until a run
//! reaches the weight budget, oversized items become singleton runs,
//! and **nothing is ever split**, so a run boundary is always an item
//! boundary (a query group never straddles two tasks, which the grouped
//! reduction's bit-identity argument relies on).
//!
//! The plan is a pure function of the item weights and the target run
//! count — never of thread scheduling — so the task decomposition is
//! reproducible, and because each run's results are reduced serially in
//! run (= item) order, the run count itself cannot influence a result
//! bit either. `tests/scheduler.rs` pins both properties.

/// A partition of `n_items` consecutive items into contiguous runs of
/// bounded total weight. Built once per trainer (group sizes are fixed
/// by the dataset); consumed as one pool task per run.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct WorkPlan {
    /// Half-open `[lo, hi)` item ranges, ascending and exactly covering
    /// `0..n_items`.
    runs: Vec<(usize, usize)>,
}

impl WorkPlan {
    /// Pack `n_items` items into at most ~`target_runs` runs (more only
    /// when oversized items force extra singleton runs): the weight
    /// budget per run is `ceil(total_weight / target_runs)`, a greedy
    /// scan closes a run when adding the next item would exceed it, and
    /// every run keeps at least one item. Zero-weight items coalesce
    /// into their neighbours.
    pub fn pack(n_items: usize, target_runs: usize, weight: impl Fn(usize) -> usize) -> WorkPlan {
        if n_items == 0 {
            return WorkPlan { runs: Vec::new() };
        }
        let target = target_runs.max(1);
        let total: usize = (0..n_items).map(&weight).sum();
        let budget = total.div_ceil(target).max(1);
        let mut runs = Vec::with_capacity(target.min(n_items) + 1);
        let mut lo = 0usize;
        let mut acc = 0usize;
        for i in 0..n_items {
            let w = weight(i);
            if i > lo && acc + w > budget {
                runs.push((lo, i));
                lo = i;
                acc = 0;
            }
            acc += w;
        }
        runs.push((lo, n_items));
        WorkPlan { runs }
    }

    /// The `[lo, hi)` item ranges, in item order.
    pub fn runs(&self) -> &[(usize, usize)] {
        &self.runs
    }

    /// Number of runs (= pool tasks this plan submits).
    pub fn n_runs(&self) -> usize {
        self.runs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.runs.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn check_cover(plan: &WorkPlan, n_items: usize) {
        let mut expect_lo = 0;
        for &(lo, hi) in plan.runs() {
            assert_eq!(lo, expect_lo, "runs must be contiguous");
            assert!(hi > lo, "runs must be non-empty");
            expect_lo = hi;
        }
        assert_eq!(expect_lo, n_items, "runs must cover all items");
    }

    #[test]
    fn empty_and_singleton() {
        assert!(WorkPlan::pack(0, 8, |_| 1).is_empty());
        let p = WorkPlan::pack(1, 8, |_| 100);
        assert_eq!(p.runs(), &[(0, 1)]);
    }

    #[test]
    fn uniform_items_land_near_the_target() {
        let p = WorkPlan::pack(1000, 8, |_| 1);
        check_cover(&p, 1000);
        assert_eq!(p.n_runs(), 8);
        for &(lo, hi) in p.runs() {
            assert!(hi - lo <= 125, "run [{lo},{hi}) exceeds the budget");
        }
    }

    #[test]
    fn giant_item_is_isolated_not_split() {
        // 200 singletons, one weight-1000 giant, 200 more singletons,
        // target 8: budget = ceil(1400/8) = 175 — the giant exceeds it
        // alone, so it must sit in a run of exactly one item.
        let weight = |i: usize| if i == 200 { 1000 } else { 1 };
        let p = WorkPlan::pack(401, 8, weight);
        check_cover(&p, 401);
        let giant = p.runs().iter().find(|&&(lo, hi)| (lo..hi).contains(&200)).unwrap();
        assert_eq!(*giant, (200, 201), "giant item must be a singleton run");
        // The singletons around it still coalesce (no one-task-per-item
        // explosion).
        assert!(p.n_runs() <= 10, "{} runs for 401 items", p.n_runs());
    }

    #[test]
    fn zero_weight_items_coalesce() {
        let p = WorkPlan::pack(500, 4, |_| 0);
        check_cover(&p, 500);
        assert_eq!(p.n_runs(), 1, "all-zero weights must form one run");
    }

    #[test]
    fn target_one_is_one_run() {
        let p = WorkPlan::pack(57, 1, |i| i);
        check_cover(&p, 57);
        assert_eq!(p.n_runs(), 1);
    }

    #[test]
    fn plan_is_deterministic_in_inputs_only() {
        let w = |i: usize| (i * 7919) % 23;
        let a = WorkPlan::pack(777, 16, w);
        let b = WorkPlan::pack(777, 16, w);
        assert_eq!(a, b);
        check_cover(&a, 777);
    }

    #[test]
    fn run_count_stays_bounded_under_adversarial_weights() {
        // Alternating giant/tiny weights: every giant forces a cut, but
        // the run count stays O(target + giants), never O(items).
        let w = |i: usize| if i % 50 == 0 { 10_000 } else { 1 };
        let p = WorkPlan::pack(1000, 8, w);
        check_cover(&p, 1000);
        assert!(p.n_runs() <= 42, "{} runs", p.n_runs());
    }
}
