//! Bundle Methods for Regularized risk Minimization — Algorithm 1.
//!
//! Minimizes `J(w) = R_emp(w) + λ‖w‖²` by iteratively tightening a
//! piecewise-linear lower bound `R_t` on the convex empirical risk from
//! cutting planes `⟨·, a_t⟩ + b_t` (first-order Taylor minorants), solving
//! the small regularized master problem exactly at each step
//! (see [`qp::BundleQp`]), and — following Franc & Sonnenburg (2009), as
//! the paper does — tracking the best-so-far iterate `w_b`, terminating
//! when the gap `ε_t = J(w_b) − J_t(w_t)` drops below `ε`.
//!
//! Convergence is `O(1/(ελ))` iterations *independent of m and s*
//! (Smola et al., 2007; Theorem 3 of the paper), so end-to-end training
//! cost is dominated by the per-iteration oracle: `O(ms + m log m)` with
//! the tree oracle, `O(ms + m²)` with the pair oracle.
//!
//! The oracle interface is split score-side/feature-side
//! ([`ScoreOracle`]) so the optional line search (§6 future work of the
//! paper, implemented in [`linesearch`]) can probe `J` along a segment
//! using only `O(m log m)` score-space evaluations — scores are affine
//! along the segment, no extra `O(ms)` matvecs.

pub mod linesearch;
pub mod qp;

use crate::linalg::ops;

/// Decoupled risk oracle: the `O(ms)` linear algebra (score matvec,
/// gradient assembly) is separated from the `O(m log m)` (or `O(m²)`)
/// score-space loss so BMRM and the line search can mix them freely.
pub trait ScoreOracle {
    /// Feature dimension `n`.
    fn dim(&self) -> usize;
    /// `p = X·w` — `O(ms)`.
    fn scores(&mut self, w: &[f64]) -> Vec<f64>;
    /// `(R_emp, ∂R/∂p)` at the given scores — `O(m log m)` for the tree.
    fn risk_at(&mut self, p: &[f64]) -> (f64, Vec<f64>);
    /// Risk value only (line-search probes; default falls back to full).
    fn risk_value_at(&mut self, p: &[f64]) -> f64 {
        self.risk_at(p).0
    }
    /// `a = Xᵀ·coeffs` — `O(ms)`.
    fn grad(&mut self, coeffs: &[f64]) -> Vec<f64>;
}

/// BMRM hyper-parameters.
#[derive(Clone, Debug)]
pub struct BmrmConfig {
    /// Regularization λ (paper's objective: `R_emp + λ‖w‖²`).
    pub lambda: f64,
    /// Termination gap ε (paper uses 1e-3, SVM^rank's default).
    pub epsilon: f64,
    /// Hard iteration cap (safety; convergence theory is `O(1/ελ)`).
    pub max_iter: usize,
    /// Inner QP tolerance and sweep cap.
    pub qp_tol: f64,
    pub qp_max_sweeps: usize,
    /// Enable the OCAS-style score-space line search.
    pub line_search: bool,
}

impl Default for BmrmConfig {
    fn default() -> Self {
        BmrmConfig {
            lambda: 1e-2,
            epsilon: 1e-3,
            max_iter: 2000,
            qp_tol: 1e-9,
            qp_max_sweeps: 2000,
            line_search: false,
        }
    }
}

/// Per-iteration trace record (drives Fig. 1/2 style reporting).
#[derive(Clone, Debug)]
pub struct IterStats {
    pub iter: usize,
    /// J(w_b) so far.
    pub best_objective: f64,
    /// Lower bound J_t(w_t) from the master problem.
    pub lower_bound: f64,
    /// Gap ε_t.
    pub gap: f64,
    /// Empirical risk at the evaluated point.
    pub risk: f64,
    /// Line-search probe evaluations this iteration (0 when the line
    /// search is disabled or not yet engaged).
    pub ls_steps: usize,
    /// Oracle wall-clock seconds for this iteration.
    pub oracle_secs: f64,
}

/// A reusable cutting-plane model: the planes and offsets accumulated
/// by a finished [`optimize_warm`] run, plus its best iterate.
///
/// Each plane `⟨·, aᵢ⟩ + bᵢ` is a first-order minorant of the
/// *empirical risk* `R_emp` alone — λ never enters a cut, only the
/// master problem's regularizer — so a bundle collected at one λ is a
/// valid lower model of `R_emp` at **every** λ. That is what makes
/// warm-starting a regularization path sound: see the convergence
/// contract on [`optimize_warm`].
#[derive(Clone, Debug, Default)]
pub struct Bundle {
    /// Cutting-plane gradients `aᵢ` (dense, `dim()`-length each).
    pub planes: Vec<Vec<f64>>,
    /// Matching offsets `bᵢ = R(wᵢ) − ⟨wᵢ, aᵢ⟩`.
    pub offsets: Vec<f64>,
    /// Best iterate `w_b` of the run that produced the bundle (a
    /// convenient Newton-style seed for solvers that cannot consume
    /// planes).
    pub w: Vec<f64>,
}

/// Optimization result.
#[derive(Clone, Debug)]
pub struct BmrmResult {
    /// Best weight vector `w_b`.
    pub w: Vec<f64>,
    /// `J(w_b)`.
    pub objective: f64,
    /// Final gap `ε_t`.
    pub gap: f64,
    pub iterations: usize,
    pub converged: bool,
    pub trace: Vec<IterStats>,
    /// Total seconds inside the loss/subgradient oracle (Fig. 1 metric).
    pub oracle_secs_total: f64,
}

/// Run Algorithm 1 from `w0` (usually zeros).
pub fn optimize<O: ScoreOracle>(oracle: &mut O, cfg: &BmrmConfig, w0: Vec<f64>) -> BmrmResult {
    optimize_observed(oracle, cfg, w0, &mut |_, _| {})
}

/// [`optimize`] with a per-iteration observer, called after each
/// [`IterStats`] is recorded with read access to the stats and the
/// oracle (for e.g. phase-clock snapshots).
///
/// The observer is the trace hook for `train --trace`
/// (docs/OBSERVABILITY.md): it runs *between* iterations, after all of
/// the iteration's numerics, and nothing it does can feed back into the
/// solver state — so a run with an observer is byte-identical to a run
/// without one (pinned by `tests/obs.rs`).
pub fn optimize_observed<O: ScoreOracle>(
    oracle: &mut O,
    cfg: &BmrmConfig,
    w0: Vec<f64>,
    observer: &mut dyn FnMut(&IterStats, &mut O),
) -> BmrmResult {
    optimize_warm_observed(oracle, cfg, w0, None, observer).0
}

/// [`optimize`] seeded from a previous run's cutting-plane model — the
/// warm-start entry point for regularization-path sweeps
/// (`coordinator::modelsel`).
///
/// With `warm = None` this is *exactly* [`optimize`]: the cold path is
/// bit-identical, plus the returned [`Bundle`] for chaining. With
/// `warm = Some(bundle)` the bundle's planes are preloaded into a fresh
/// master problem at the new λ (Gram columns recomputed; the QP dual is
/// λ-dependent, so α is re-solved from scratch) and the first iterate
/// `w_1` is the preloaded master's minimizer instead of `w0`.
///
/// # Convergence contract
///
/// Warm and cold starts reach the **same ε-optimum**. Every preloaded
/// plane minorizes `R_emp` (planes never depend on λ), so the master's
/// lower bound satisfies `J_t(w_t) ≤ J* = min J` throughout, exactly as
/// in a cold run, and the termination test `J(w_b) − J_t(w_t) < ε`
/// therefore guarantees `J(w_b) ≤ J* + ε` on both paths. The two final
/// objectives differ by at most ε (each is within `[J*, J* + ε]`);
/// the *iterates* may differ, the *guarantee* does not. Warm starts
/// change only how many oracle calls the guarantee costs —
/// `BmrmResult::iterations` counts oracle calls made by *this* run
/// (preloaded planes are free), which is what the model-selection
/// differential tests compare.
///
/// The returned bundle contains the preloaded planes *plus* this run's
/// new cuts, so chaining along a sorted λ path accumulates one growing
/// model of `R_emp`.
pub fn optimize_warm<O: ScoreOracle>(
    oracle: &mut O,
    cfg: &BmrmConfig,
    w0: Vec<f64>,
    warm: Option<&Bundle>,
) -> (BmrmResult, Bundle) {
    optimize_warm_observed(oracle, cfg, w0, warm, &mut |_, _| {})
}

/// [`optimize_warm`] with the per-iteration observer of
/// [`optimize_observed`].
pub fn optimize_warm_observed<O: ScoreOracle>(
    oracle: &mut O,
    cfg: &BmrmConfig,
    w0: Vec<f64>,
    warm: Option<&Bundle>,
    observer: &mut dyn FnMut(&IterStats, &mut O),
) -> (BmrmResult, Bundle) {
    let n = oracle.dim();
    assert_eq!(w0.len(), n);
    let lambda = cfg.lambda;

    let mut qp = qp::BundleQp::new(lambda);
    // Stored plane vectors a_i (needed for Gram columns and w(α)) and
    // their offsets b_i (kept so the bundle can be handed on).
    let mut planes: Vec<Vec<f64>> = Vec::new();
    let mut offsets: Vec<f64> = Vec::new();

    let mut w_b = w0.clone();
    let mut w_cur = w0;

    // Warm start: preload the previous run's planes into the new master
    // problem and move the first iterate to its minimizer. j_best stays
    // +∞ — the best-iterate track only ever holds points this run has
    // actually evaluated, so the gap test below keeps its cold-start
    // meaning.
    if let Some(bundle) = warm {
        debug_assert_eq!(bundle.planes.len(), bundle.offsets.len());
        for (a_i, &b_i) in bundle.planes.iter().zip(&bundle.offsets) {
            assert_eq!(a_i.len(), n, "warm-start plane dimension mismatch");
            let mut col: Vec<f64> = planes.iter().map(|aj| ops::dot(a_i, aj)).collect();
            col.push(ops::dot(a_i, a_i));
            planes.push(a_i.clone());
            offsets.push(b_i);
            qp.add_plane(b_i, col);
        }
        if !planes.is_empty() {
            qp.solve(cfg.qp_tol, cfg.qp_max_sweeps);
            let alpha = qp.alpha();
            let mut w_next = vec![0.0; n];
            for (k, ai) in planes.iter().enumerate() {
                if alpha[k] != 0.0 {
                    ops::axpy(-alpha[k] / (2.0 * lambda), ai, &mut w_next);
                }
            }
            w_cur = w_next;
        }
    }
    // Scores at w_b, kept for the line search.
    let mut p_b: Option<Vec<f64>> = None;

    let mut trace = Vec::new();
    let mut oracle_secs_total = 0.0;
    let mut j_best = f64::INFINITY;
    let mut gap = f64::INFINITY;
    let mut converged = false;
    let mut iterations = 0;

    for t in 1..=cfg.max_iter {
        iterations = t;
        let timer = std::time::Instant::now();

        // --- Oracle at w_{t-1}: risk, subgradient (lines 5–6).
        let p_cur = oracle.scores(&w_cur);

        // Optional line search: evaluate the cut at the best point on the
        // segment [w_b, w_cur] instead of at w_cur (Franc–Sonnenburg
        // style). Scores are affine along the segment, so the probes cost
        // no extra matvecs.
        let mut ls_steps = 0usize;
        let (w_eval, p_eval) = if cfg.line_search && p_b.is_some() {
            let pb = p_b.as_ref().unwrap();
            let beta = linesearch::golden_section(
                |beta| {
                    ls_steps += 1;
                    let p_mix: Vec<f64> =
                        pb.iter().zip(&p_cur).map(|(a, b)| a + beta * (b - a)).collect();
                    let risk = oracle.risk_value_at(&p_mix);
                    let mut reg = 0.0;
                    for (wb_i, wc_i) in w_b.iter().zip(&w_cur) {
                        let wm = wb_i + beta * (wc_i - wb_i);
                        reg += wm * wm;
                    }
                    risk + lambda * reg
                },
                0.0,
                1.0,
                12,
            );
            let w_mix: Vec<f64> =
                w_b.iter().zip(&w_cur).map(|(a, b)| a + beta * (b - a)).collect();
            let p_mix: Vec<f64> = pb.iter().zip(&p_cur).map(|(a, b)| a + beta * (b - a)).collect();
            (w_mix, p_mix)
        } else {
            (w_cur.clone(), p_cur.clone())
        };

        let (risk, coeffs) = oracle.risk_at(&p_eval);
        let a_t = oracle.grad(&coeffs);
        let oracle_secs = timer.elapsed().as_secs_f64();
        oracle_secs_total += oracle_secs;

        // b_t = R(w') − ⟨w', a_t⟩.
        let b_t = risk - ops::dot(&w_eval, &a_t);

        // Track best iterate (lines 9–11).
        let j_eval = risk + lambda * ops::norm_sq(&w_eval);
        if j_eval < j_best {
            j_best = j_eval;
            w_b.copy_from_slice(&w_eval);
            p_b = Some(p_eval);
        }

        // Add the plane (line 7): Gram column against stored planes.
        let mut col: Vec<f64> = planes.iter().map(|ai| ops::dot(&a_t, ai)).collect();
        col.push(ops::dot(&a_t, &a_t));
        planes.push(a_t);
        offsets.push(b_t);
        qp.add_plane(b_t, col);

        // Master problem (line 8): w_t = argmin J_t via the dual.
        let lower = qp.solve(cfg.qp_tol, cfg.qp_max_sweeps);
        let alpha = qp.alpha();
        let mut w_next = vec![0.0; n];
        for (k, ai) in planes.iter().enumerate() {
            if alpha[k] != 0.0 {
                ops::axpy(-alpha[k] / (2.0 * lambda), ai, &mut w_next);
            }
        }
        w_cur = w_next;

        // Gap (line 12): ε_t = J(w_b) − J_t(w_t).
        gap = j_best - lower;
        let stats = IterStats {
            iter: t,
            best_objective: j_best,
            lower_bound: lower,
            gap,
            risk,
            ls_steps,
            oracle_secs,
        };
        observer(&stats, oracle);
        trace.push(stats);

        if gap < cfg.epsilon {
            converged = true;
            break;
        }
    }

    let bundle = Bundle { planes, offsets, w: w_b.clone() };
    (
        BmrmResult {
            w: w_b,
            objective: j_best,
            gap,
            iterations,
            converged,
            trace,
            oracle_secs_total,
        },
        bundle,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Quadratic test oracle: R(w) = ‖w − target‖² (convex, smooth) —
    /// lets us check BMRM against the analytic optimum of
    /// `min ‖w − c‖² + λ‖w‖²`, i.e. `w* = c/(1+λ)`.
    struct QuadOracle {
        target: Vec<f64>,
    }

    impl ScoreOracle for QuadOracle {
        fn dim(&self) -> usize {
            self.target.len()
        }
        fn scores(&mut self, w: &[f64]) -> Vec<f64> {
            w.to_vec() // identity "matvec"
        }
        fn risk_at(&mut self, p: &[f64]) -> (f64, Vec<f64>) {
            let mut risk = 0.0;
            let mut g = Vec::with_capacity(p.len());
            for (pi, ti) in p.iter().zip(&self.target) {
                risk += (pi - ti) * (pi - ti);
                g.push(2.0 * (pi - ti));
            }
            (risk, g)
        }
        fn grad(&mut self, coeffs: &[f64]) -> Vec<f64> {
            coeffs.to_vec()
        }
    }

    #[test]
    fn converges_to_analytic_optimum() {
        let target = vec![3.0, -1.0, 2.0];
        let lambda = 0.5;
        let mut oracle = QuadOracle { target: target.clone() };
        let cfg = BmrmConfig { lambda, epsilon: 1e-8, max_iter: 500, ..Default::default() };
        let res = optimize(&mut oracle, &cfg, vec![0.0; 3]);
        assert!(res.converged, "gap {}", res.gap);
        for (wi, ti) in res.w.iter().zip(&target) {
            let expect = ti / (1.0 + lambda);
            assert!((wi - expect).abs() < 1e-3, "{wi} vs {expect}");
        }
    }

    #[test]
    fn bounds_are_valid_and_monotone() {
        let mut oracle = QuadOracle { target: vec![1.0, 2.0, 3.0, 4.0] };
        let cfg = BmrmConfig { lambda: 0.1, epsilon: 1e-9, max_iter: 300, ..Default::default() };
        let res = optimize(&mut oracle, &cfg, vec![0.0; 4]);
        for w in res.trace.windows(2) {
            assert!(w[1].best_objective <= w[0].best_objective + 1e-12);
            assert!(w[1].lower_bound >= w[0].lower_bound - 1e-9);
        }
        for s in &res.trace {
            assert!(s.lower_bound <= s.best_objective + 1e-9);
        }
        assert!(res.converged);
    }

    #[test]
    fn line_search_variant_also_converges() {
        let target = vec![2.0, -3.0];
        let lambda = 0.25;
        let mut oracle = QuadOracle { target: target.clone() };
        let cfg = BmrmConfig {
            lambda,
            epsilon: 1e-8,
            max_iter: 500,
            line_search: true,
            ..Default::default()
        };
        let res = optimize(&mut oracle, &cfg, vec![0.0; 2]);
        assert!(res.converged);
        for (wi, ti) in res.w.iter().zip(&target) {
            let expect = ti / (1.0 + lambda);
            assert!((wi - expect).abs() < 1e-3);
        }
    }

    #[test]
    fn observer_sees_every_iteration_and_probe_counts() {
        let cfg = BmrmConfig {
            lambda: 0.25,
            epsilon: 1e-8,
            max_iter: 500,
            line_search: true,
            ..Default::default()
        };
        let mut oracle = QuadOracle { target: vec![2.0, -3.0] };
        let mut seen = 0usize;
        let mut probed = 0usize;
        let res = optimize_observed(&mut oracle, &cfg, vec![0.0; 2], &mut |s, _| {
            seen += 1;
            probed += s.ls_steps;
        });
        assert_eq!(seen, res.iterations);
        assert!(probed > 0, "line search never probed");
        // Iteration 1 has no best-point scores yet → no probes.
        assert_eq!(res.trace[0].ls_steps, 0);
        // An observed run is bitwise identical to an unobserved one.
        let mut oracle2 = QuadOracle { target: vec![2.0, -3.0] };
        let res2 = optimize(&mut oracle2, &cfg, vec![0.0; 2]);
        let bits = |w: &[f64]| w.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&res.w), bits(&res2.w));
        assert_eq!(res.objective.to_bits(), res2.objective.to_bits());
    }

    #[test]
    fn warm_none_is_bit_identical_to_cold() {
        let target = vec![3.0, -1.0, 2.0, 0.5];
        let cfg = BmrmConfig { lambda: 0.5, epsilon: 1e-8, max_iter: 500, ..Default::default() };
        let mut o1 = QuadOracle { target: target.clone() };
        let cold = optimize(&mut o1, &cfg, vec![0.0; 4]);
        let mut o2 = QuadOracle { target: target.clone() };
        let (warm_none, bundle) = optimize_warm(&mut o2, &cfg, vec![0.0; 4], None);
        let bits = |w: &[f64]| w.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&cold.w), bits(&warm_none.w));
        assert_eq!(cold.objective.to_bits(), warm_none.objective.to_bits());
        assert_eq!(cold.iterations, warm_none.iterations);
        // The bundle records one (plane, offset) pair per oracle call.
        assert_eq!(bundle.planes.len(), warm_none.iterations);
        assert_eq!(bundle.offsets.len(), warm_none.iterations);
        assert_eq!(bits(&bundle.w), bits(&warm_none.w));
    }

    #[test]
    fn warm_start_reaches_same_optimum_no_more_expensively() {
        // λ path: solve at λ₁ cold, then λ₂ both cold and warm-started
        // from the λ₁ bundle. The convergence contract: both ends land
        // within ε of J*(λ₂), so the two objectives differ by ≤ ε; the
        // warm run may not need more oracle calls than the cold one.
        let target = vec![4.0, -2.0, 1.0, 3.0, -1.5];
        let eps = 1e-9;
        let cfg1 = BmrmConfig { lambda: 0.5, epsilon: eps, max_iter: 1000, ..Default::default() };
        let mut o = QuadOracle { target: target.clone() };
        let (_r1, bundle) = optimize_warm(&mut o, &cfg1, vec![0.0; 5], None);

        let cfg2 = BmrmConfig { lambda: 0.1, ..cfg1.clone() };
        let mut oc = QuadOracle { target: target.clone() };
        let cold = optimize(&mut oc, &cfg2, vec![0.0; 5]);
        let mut ow = QuadOracle { target: target.clone() };
        let (warm, grown) = optimize_warm(&mut ow, &cfg2, vec![0.0; 5], Some(&bundle));

        assert!(cold.converged && warm.converged);
        assert!(
            (warm.objective - cold.objective).abs() <= eps,
            "warm {} vs cold {}",
            warm.objective,
            cold.objective
        );
        for (wi, ti) in warm.w.iter().zip(&target) {
            let expect = ti / (1.0 + cfg2.lambda);
            assert!((wi - expect).abs() < 1e-3, "{wi} vs {expect}");
        }
        assert!(
            warm.iterations <= cold.iterations,
            "warm start cost more oracle calls ({} > {})",
            warm.iterations,
            cold.iterations
        );
        // Chaining: the returned bundle holds preloaded + new planes.
        assert_eq!(grown.planes.len(), bundle.planes.len() + warm.iterations);
    }

    #[test]
    #[should_panic(expected = "warm-start plane dimension mismatch")]
    fn warm_start_rejects_wrong_dimension() {
        let bundle = Bundle { planes: vec![vec![1.0; 3]], offsets: vec![0.0], w: vec![0.0; 3] };
        let mut oracle = QuadOracle { target: vec![1.0, 2.0] };
        let cfg = BmrmConfig::default();
        let _ = optimize_warm(&mut oracle, &cfg, vec![0.0; 2], Some(&bundle));
    }

    #[test]
    fn respects_max_iter() {
        let mut oracle = QuadOracle { target: vec![5.0; 10] };
        let cfg = BmrmConfig { lambda: 1e-4, epsilon: 1e-14, max_iter: 3, ..Default::default() };
        let res = optimize(&mut oracle, &cfg, vec![0.0; 10]);
        assert!(!res.converged);
        assert_eq!(res.iterations, 3);
        assert_eq!(res.trace.len(), 3);
    }
}
