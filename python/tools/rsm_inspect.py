#!/usr/bin/env python3
"""Inspect and validate a `.rsm` scoring model (docs/MODEL_FORMAT.md).

A dependency-free second implementation of the format reader: magic,
version, flag registry, section geometry, and the FNV-1a-64 full-file
checksum in the documented stream order (payload, header[0:24],
header[32:96]). Useful for poking at model files from ops tooling
without the Rust toolchain, and as a cross-language check that the
normative spec is implementable from its text alone.

Usage:
    python3 rsm_inspect.py MODEL.rsm [--dump-weights]

Exit status: 0 valid, 1 structurally invalid / checksum mismatch,
2 usage error.  `--selftest` builds a model in memory per the spec,
round-trips it, and exercises every refusal path.
"""

import struct
import sys

MAGIC = b"RSMODL\0"
VERSION = 1
HEADER_LEN = 96
N_SECTIONS = 2
FLAG_HAS_NORMS = 0x1
KNOWN_FLAGS = FLAG_HAS_NORMS

FNV_OFFSET = 0xCBF29CE484222325
FNV_PRIME = 0x100000001B3
U64_MASK = (1 << 64) - 1


def fnv1a64(chunks):
    h = FNV_OFFSET
    for chunk in chunks:
        for b in chunk:
            h = ((h ^ b) * FNV_PRIME) & U64_MASK
    return h


def fail(msg):
    raise ValueError(msg)


def parse(data):
    """Validate `data` as a .rsm file; return a dict of its contents."""
    if len(data) < HEADER_LEN:
        fail(f"file is {len(data)} bytes, smaller than the {HEADER_LEN}-byte header")
    if data[:7] != MAGIC:
        fail("bad magic (not a scoring model)")
    version = data[7]
    if version != VERSION:
        fail(f"unsupported scoring-model version {version} (this reader knows {VERSION})")
    dim, flags, checksum = struct.unpack_from("<QQQ", data, 8)
    offsets = struct.unpack_from(f"<{N_SECTIONS}Q", data, 32)
    if any(b != 0 for b in data[48:HEADER_LEN]):
        fail("reserved header bytes are not zero")
    if flags & ~KNOWN_FLAGS:
        fail(f"unknown scoring-model flag bits {flags & ~KNOWN_FLAGS:#x}")

    lengths = [dim * 8, dim * 8 if flags & FLAG_HAS_NORMS else 0]
    cursor = HEADER_LEN
    for sec, (off, length) in enumerate(zip(offsets, lengths)):
        if off % 8 != 0:
            fail(f"section {sec} offset {off} is not 8-byte aligned")
        if off < cursor:
            fail(f"section {sec} offset {off} overlaps its predecessor")
        cursor = off + length
    if cursor != len(data):
        fail(f"sections end at {cursor} but the file is {len(data)} bytes")

    expected = fnv1a64([data[HEADER_LEN:], data[:24], data[32:HEADER_LEN]])
    if expected != checksum:
        fail(
            "checksum mismatch — the model file is corrupt "
            f"(expected {expected:#018x}, found {checksum:#018x})"
        )

    w = struct.unpack_from(f"<{dim}d", data, offsets[0])
    norms = (
        struct.unpack_from(f"<{dim}d", data, offsets[1])
        if flags & FLAG_HAS_NORMS
        else None
    )
    return {"dim": dim, "flags": flags, "w": w, "norms": norms}


def build(w, norms=None):
    """Writer mirror (the spec's byte-deterministic layout), for tests."""
    dim = len(w)
    flags = FLAG_HAS_NORMS if norms is not None else 0
    if norms is not None and len(norms) != dim:
        fail("norms length must equal dim")
    payload = struct.pack(f"<{dim}d", *w)
    if norms is not None:
        payload += struct.pack(f"<{dim}d", *norms)
    offsets = (HEADER_LEN, HEADER_LEN + dim * 8)
    head = MAGIC + bytes([VERSION]) + struct.pack("<QQ", dim, flags)
    tail = struct.pack(f"<{N_SECTIONS}Q", *offsets) + bytes(HEADER_LEN - 48)
    checksum = fnv1a64([payload, head, tail])
    return head + struct.pack("<Q", checksum) + tail + payload


def selftest():
    w = [0.5, -1.25e-7, 3.0, 0.0]
    norms = [1.0, 2.5, 0.0, 7.125]
    for ns in (None, norms):
        good = build(w, ns)
        got = parse(good)
        assert got["dim"] == 4 and list(got["w"]) == w
        assert (got["norms"] is None) == (ns is None)
        if ns is not None:
            assert list(got["norms"]) == norms
        # Determinism: same parameters, same bytes.
        assert build(w, ns) == good
        # Every single-byte flip must be caught (full-file coverage).
        for pos in range(0, len(good), 7):
            bad = bytearray(good)
            bad[pos] ^= 0x10
            try:
                parse(bytes(bad))
            except ValueError:
                continue
            raise AssertionError(f"flip at byte {pos} went undetected")
    # Refusals: version, flags, truncation, trailing bytes.
    for doctor, needle in [
        (lambda b: b[:7] + bytes([9]) + b[8:], "version"),
        (lambda b: b[:16] + struct.pack("<Q", 0x80) + b[24:], "flag"),
        (lambda b: b[:-8], "file is" if len(w) == 0 else "sections end"),
        (lambda b: b + bytes(8), "sections end"),
    ]:
        try:
            parse(doctor(build(w)))
        except ValueError as e:
            assert needle in str(e), (needle, e)
        else:
            raise AssertionError(f"doctored file ({needle}) was accepted")
    print("rsm_inspect selftest: ok")


def main(argv):
    if "--selftest" in argv:
        selftest()
        return 0
    args = [a for a in argv if not a.startswith("--")]
    if len(args) != 1:
        print(__doc__.strip(), file=sys.stderr)
        return 2
    try:
        with open(args[0], "rb") as f:
            model = parse(f.read())
    except (OSError, ValueError) as e:
        print(f"{args[0]}: {e}", file=sys.stderr)
        return 1
    normalize = "l2-col" if model["norms"] is not None else "none"
    print(f"{args[0]}: valid scoring model, version {VERSION}")
    print(f"  dim       {model['dim']}")
    print(f"  normalize {normalize}")
    w = model["w"]
    if w:
        print(f"  |w|_inf   {max(abs(x) for x in w):.6g}")
    if "--dump-weights" in argv:
        for j, x in enumerate(w):
            print(f"  w[{j}] = {x!r}")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
