//! Kernelized RankSVM via reduced-set (Nyström) approximation — the
//! paper's §6: *"the approach could also be used to speed up its
//! kernelized version using a reduced set approximation, such as the one
//! proposed by Joachims and Yu (2009)"*.
//!
//! A reduced set of `k` basis examples induces the explicit feature map
//! `φ(x) = K_bb^{-1/2} · k_b(x)` where `k_b(x) = [K(x, b_1)…K(x, b_k)]ᵀ`
//! and `K_bb` is the basis Gram matrix; linear RankSVM on `φ(x)` then
//! approximates the kernel machine while keeping the `O(ms + m log m)`
//! per-iteration training cost (now with s = k). With `k = m` (basis =
//! all training points) the approximation is exact.
//!
//! `K_bb^{-1/2}` comes from a cyclic Jacobi eigendecomposition
//! ([`eigen_sym`]) — adequate for reduced sets of a few hundred basis
//! vectors, which is the regime Joachims & Yu target.

pub mod jacobi;

pub use jacobi::eigen_sym;

use crate::data::Dataset;
use crate::linalg::{CsrMatrix, DenseMatrix};
use crate::util::rng::Rng;

/// Kernel functions over sparse rows.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Kernel {
    /// ⟨a, b⟩ (sanity: reduces the map to a linear re-basis).
    Linear,
    /// exp(−γ‖a − b‖²).
    Rbf { gamma: f64 },
    /// (γ⟨a,b⟩ + coef0)^degree.
    Poly { gamma: f64, coef0: f64, degree: u32 },
}

impl Kernel {
    /// Evaluate on two sparse rows given as (indices, values).
    pub fn eval(&self, a: (&[u32], &[f64]), b: (&[u32], &[f64])) -> f64 {
        let dot = sparse_dot(a, b);
        match *self {
            Kernel::Linear => dot,
            Kernel::Rbf { gamma } => {
                let na = a.1.iter().map(|v| v * v).sum::<f64>();
                let nb = b.1.iter().map(|v| v * v).sum::<f64>();
                (-gamma * (na - 2.0 * dot + nb)).exp()
            }
            Kernel::Poly { gamma, coef0, degree } => (gamma * dot + coef0).powi(degree as i32),
        }
    }
}

/// Sparse-sparse dot product (indices ascending).
fn sparse_dot(a: (&[u32], &[f64]), b: (&[u32], &[f64])) -> f64 {
    let (ai, av) = a;
    let (bi, bv) = b;
    let (mut x, mut y) = (0usize, 0usize);
    let mut s = 0.0;
    while x < ai.len() && y < bi.len() {
        match ai[x].cmp(&bi[y]) {
            std::cmp::Ordering::Less => x += 1,
            std::cmp::Ordering::Greater => y += 1,
            std::cmp::Ordering::Equal => {
                s += av[x] * bv[y];
                x += 1;
                y += 1;
            }
        }
    }
    s
}

/// Fitted Nyström feature map.
#[derive(Clone, Debug)]
pub struct NystromMap {
    kernel: Kernel,
    /// The `k` basis rows (reduced set).
    basis: CsrMatrix,
    /// `K_bb^{-1/2}` (k × k), eigenvalue-floored for stability.
    whitener: DenseMatrix,
}

impl NystromMap {
    /// Fit on `k` basis examples sampled uniformly from `ds`
    /// (deterministic in `seed`). `k` is clamped to `ds.len()`.
    pub fn fit(ds: &Dataset, kernel: Kernel, k: usize, seed: u64) -> Self {
        let k = k.min(ds.len()).max(1);
        let mut rng = Rng::new(seed);
        let rows = rng.sample_indices(ds.len(), k);
        let basis = ds.x.select_rows(&rows);
        // Basis Gram matrix.
        let mut gram = DenseMatrix::zeros(k, k);
        for i in 0..k {
            for j in i..k {
                let v = kernel.eval(basis.row(i), basis.row(j));
                gram.set(i, j, v);
                gram.set(j, i, v);
            }
        }
        // K_bb^{-1/2} = V diag(1/√λ) Vᵀ with small-λ floor.
        let (eigvals, eigvecs) = eigen_sym(&gram);
        let floor = 1e-10 * eigvals.iter().cloned().fold(1.0_f64, f64::max).max(1e-30);
        let mut whitener = DenseMatrix::zeros(k, k);
        for a in 0..k {
            for b in 0..k {
                let mut s = 0.0;
                for t in 0..k {
                    let lam = eigvals[t];
                    if lam > floor {
                        s += eigvecs.get(a, t) * eigvecs.get(b, t) / lam.sqrt();
                    }
                }
                whitener.set(a, b, s);
            }
        }
        NystromMap { kernel, basis, whitener }
    }

    /// Number of basis vectors (= output feature dimension).
    pub fn dim(&self) -> usize {
        self.basis.rows()
    }

    /// Map one sparse row to its `k`-dimensional Nyström features.
    pub fn features(&self, row: (&[u32], &[f64])) -> Vec<f64> {
        let k = self.dim();
        let mut kb = vec![0.0; k];
        for (j, kb_j) in kb.iter_mut().enumerate() {
            *kb_j = self.kernel.eval(row, self.basis.row(j));
        }
        // φ = W · k_b (W symmetric).
        let mut out = vec![0.0; k];
        for (a, o) in out.iter_mut().enumerate() {
            *o = crate::linalg::ops::dot(self.whitener.row(a), &kb);
        }
        out
    }

    /// Transform a whole dataset into Nyström feature space (dense rows).
    pub fn transform(&self, ds: &Dataset) -> Dataset {
        let k = self.dim();
        let mut triplets = Vec::with_capacity(ds.len() * k);
        for i in 0..ds.len() {
            let phi = self.features(ds.x.row(i));
            for (j, v) in phi.into_iter().enumerate() {
                if v != 0.0 {
                    triplets.push((i, j, v));
                }
            }
        }
        Dataset::new(
            CsrMatrix::from_triplets(ds.len(), k, triplets),
            ds.y.clone(),
            ds.qid.clone(),
            format!("{}@nystrom{k}", ds.name),
        )
    }
}

/// Kernel ranking model: the Nyström map plus the linear model trained on
/// top of it.
#[derive(Clone, Debug)]
pub struct KernelRankModel {
    pub map: NystromMap,
    pub model: crate::coordinator::RankModel,
}

impl KernelRankModel {
    /// Predict utility scores for a raw (untransformed) dataset.
    pub fn predict(&self, ds: &Dataset) -> Vec<f64> {
        (0..ds.len())
            .map(|i| {
                let phi = self.map.features(ds.x.row(i));
                crate::linalg::ops::dot(&phi, &self.model.w)
            })
            .collect()
    }
}

/// Train a kernelized ranking SVM: fit the reduced-set map, transform,
/// train linear RankSVM in feature space (TreeRSVM inside — the paper's
/// §6 suggestion realized).
pub fn train_kernel(
    ds: &Dataset,
    cfg: &crate::coordinator::TrainConfig,
    kernel: Kernel,
    k: usize,
    seed: u64,
) -> anyhow::Result<(KernelRankModel, crate::coordinator::TrainOutcome)> {
    let map = NystromMap::fit(ds, kernel, k, seed);
    let mapped = map.transform(ds);
    let outcome = crate::coordinator::train(&mapped, cfg)?;
    let model = outcome.model.clone();
    Ok((KernelRankModel { map, model }, outcome))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{Method, TrainConfig};
    use crate::data::synthetic;
    use crate::metrics;
    use crate::util::rng::Rng;

    fn nonlinear_dataset(m: usize, seed: u64) -> Dataset {
        // Utility depends on the distance from the origin — no linear
        // ranker can order it; an RBF machine can.
        let mut rng = Rng::new(seed);
        let n = 4;
        let mut triplets = Vec::new();
        let mut y = Vec::with_capacity(m);
        for i in 0..m {
            let mut norm_sq = 0.0;
            for j in 0..n {
                let v = rng.normal();
                triplets.push((i, j, v));
                norm_sq += v * v;
            }
            y.push(-norm_sq + 0.05 * rng.normal());
        }
        Dataset::new(CsrMatrix::from_triplets(m, n, triplets), y, None, "radial")
    }

    #[test]
    fn sparse_dot_matches_dense() {
        let a = CsrMatrix::from_triplets(1, 6, vec![(0, 1, 2.0), (0, 4, -1.0)]);
        let b = CsrMatrix::from_triplets(1, 6, vec![(0, 1, 3.0), (0, 2, 9.0), (0, 4, 4.0)]);
        assert_eq!(sparse_dot(a.row(0), b.row(0)), 2.0 * 3.0 - 4.0);
    }

    #[test]
    fn kernels_basic_identities() {
        let a = CsrMatrix::from_triplets(1, 3, vec![(0, 0, 1.0), (0, 1, 2.0)]);
        let b = CsrMatrix::from_triplets(1, 3, vec![(0, 0, 3.0)]);
        assert_eq!(Kernel::Linear.eval(a.row(0), b.row(0)), 3.0);
        // RBF self-similarity = 1
        let rbf = Kernel::Rbf { gamma: 0.7 };
        assert!((rbf.eval(a.row(0), a.row(0)) - 1.0).abs() < 1e-12);
        assert!(rbf.eval(a.row(0), b.row(0)) < 1.0);
        let poly = Kernel::Poly { gamma: 1.0, coef0: 1.0, degree: 2 };
        assert_eq!(poly.eval(a.row(0), b.row(0)), 16.0); // (3+1)^2
    }

    #[test]
    fn full_basis_whitening_gives_orthonormal_features() {
        // With k = m, the Nyström features satisfy φ(x_i)·φ(x_j) = K_ij.
        let ds = nonlinear_dataset(30, 5);
        let kernel = Kernel::Rbf { gamma: 0.5 };
        let map = NystromMap::fit(&ds, kernel, ds.len(), 1);
        let mapped = map.transform(&ds);
        for i in (0..30).step_by(7) {
            for j in (0..30).step_by(5) {
                let want = kernel.eval(ds.x.row(i), ds.x.row(j));
                let got = sparse_dot(mapped.x.row(i), mapped.x.row(j));
                assert!(
                    (got - want).abs() < 1e-6,
                    "K[{i}][{j}]: {got} vs {want}"
                );
            }
        }
    }

    #[test]
    fn rbf_ranking_beats_linear_on_radial_labels() {
        let ds = nonlinear_dataset(500, 9);
        let (tr, te) = ds.split(150, 2);
        let cfg = TrainConfig { method: Method::Tree, lambda: 1e-3, ..Default::default() };

        let linear_out = crate::coordinator::train(&tr, &cfg).unwrap();
        let linear_err = {
            let p = linear_out.model.predict(&te);
            metrics::pairwise_error(&p, &te.y)
        };

        let (kmodel, outcome) =
            train_kernel(&tr, &cfg, Kernel::Rbf { gamma: 0.25 }, 100, 3).unwrap();
        assert!(outcome.converged);
        let kernel_err = metrics::pairwise_error(&kmodel.predict(&te), &te.y);

        assert!(
            linear_err > 0.4,
            "radial labels should defeat a linear ranker (err {linear_err})"
        );
        assert!(
            kernel_err < 0.2,
            "RBF reduced-set ranker should learn it (err {kernel_err} vs linear {linear_err})"
        );
    }

    #[test]
    fn reduced_set_size_trades_accuracy() {
        let ds = nonlinear_dataset(400, 11);
        let (tr, te) = ds.split(100, 4);
        let cfg = TrainConfig { method: Method::Tree, lambda: 1e-3, ..Default::default() };
        let mut errs = Vec::new();
        for k in [5usize, 50, 200] {
            let (km, _) = train_kernel(&tr, &cfg, Kernel::Rbf { gamma: 0.25 }, k, 7).unwrap();
            errs.push(metrics::pairwise_error(&km.predict(&te), &te.y));
        }
        // Larger reduced set should not be (much) worse.
        assert!(errs[2] <= errs[0] + 0.02, "errors along k: {errs:?}");
    }
}
