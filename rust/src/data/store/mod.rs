//! The pallas store: a versioned, checksummed, memory-mapped binary
//! dataset format for out-of-core training.
//!
//! The paper's oracle is `O(m·s + m·log m)` per iteration — cheap. What
//! actually limits training at scale is the data pipeline: re-parsing
//! libsvm text on every run and holding the full CSR matrix resident
//! caps `m` at RAM (WMRB, Liu 2017, makes the same observation for
//! batch ranking at web scale). The store fixes both ends:
//!
//! - **Convert once** ([`convert_libsvm`]): a streaming two-phase
//!   converter ingests libsvm text in bounded memory — a parallel parse
//!   phase shards the text into disjoint byte ranges on the same
//!   work-stealing pool that runs training, the matrix payload goes
//!   through fixed-budget spill buffers and is never materialized, and
//!   a serial stitch phase writes the CSR arrays, labels, query ids, a
//!   precomputed query-group index, and cached per-column statistics
//!   ([`ColStat`]: nnz/sum/sumsq/min/max per feature) as aligned
//!   little-endian sections behind a checksummed header (`format`; the
//!   normative spec is `docs/STORE_FORMAT.md`). The output is
//!   byte-identical for any `--threads` value (`docs/DETERMINISM.md`).
//! - **Map forever** ([`PallasStore`]): opening memory-maps the file
//!   read-only and hands out zero-copy [`crate::linalg::CsrView`] /
//!   label / qid slices through the [`crate::data::DatasetView`] trait,
//!   so the trainer, the oracles, the benches, and the CLI run straight
//!   off the kernel page cache with no parse step. Growing-prefix
//!   scalability experiments become O(1) slices of one mapping, and
//!   datasets larger than RAM page in lazily.
//!
//! Training from a store is **bit-identical** to training from the
//! equivalent libsvm text: both paths share one line parser, one group
//! indexer, and one pair counter, and everything the store caches
//! (counts, offsets) is integer-exact. `tests/store.rs` pins this
//! differentially, along with the corruption-rejection suite.

mod format;
mod mmap;
mod reader;
mod writer;

pub use format::{
    cast_slice, Checksum, ColStat, Header, Pod, CHECKSUM_FIELD, COLSTAT_BYTES,
    FLAG_HAS_COLSTATS, FLAG_HAS_QID, HEADER_LEN, KNOWN_FLAGS, MAGIC, N_SECTIONS, OFFSETS_START,
    VERSION,
};
pub use mmap::{fadvise_sequential, Advice, Mmap};
pub use reader::{compute_col_stats, is_store_file, PallasStore};
pub use writer::{convert_libsvm, ConvertOptions, ConvertStats};
