"""Pallas kernel: blocked dense score matvec  p = X @ w  (L1).

The `O(ms)` hot spot of every TreeRSVM iteration (Algorithm 3 line 1).

TPU mapping (DESIGN.md §Hardware-Adaptation): the feature tile is
streamed HBM→VMEM in `(BM, n)` blocks via the BlockSpec grid while the
weight vector stays VMEM-resident (`n ≤ 64` floats here — negligible);
each block is one VPU-friendly contraction. VMEM footprint per grid step
is `BM·n·4 + n·4 + BM·4` bytes — 128 KiB at the default `(512, 64)`,
far under the ~16 MiB VMEM budget, leaving room for double buffering.

Lowered with ``interpret=True``: the CPU PJRT plugin cannot execute
Mosaic custom-calls; interpret mode lowers to plain HLO with identical
numerics (see /opt/xla-example/README.md).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default row-block height. 8 sublanes × 64 ≈ a few VREGs per step on
# real TPU; on CPU-interpret it only shapes the HLO loop structure.
DEFAULT_BLOCK_M = 256


def _scores_kernel(x_ref, w_ref, o_ref):
    """One row block: o = x_block @ w."""
    o_ref[...] = x_ref[...] @ w_ref[...]


@functools.partial(jax.jit, static_argnames=("block_m",))
def scores(x, w, *, block_m=DEFAULT_BLOCK_M):
    """p = X @ w with X (m, n) f32, w (n,) f32; m must divide by block_m
    (the AOT wrapper pads rows to the tile height).
    """
    m, n = x.shape
    bm = min(block_m, m)
    if m % bm != 0:
        raise ValueError(f"m={m} not divisible by block_m={bm}")
    grid = (m // bm,)
    return pl.pallas_call(
        _scores_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, n), lambda i: (i, 0)),
            pl.BlockSpec((n,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bm,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((m,), jnp.float32),
        interpret=True,
    )(x, w)
