//! `ranksvm` CLI — the leader entry point of the coordinator.
//!
//! Subcommands:
//!
//! - `train`      — train a model on a libsvm/pstore file or a synthetic set
//! - `eval`       — ranking quality of a saved model on a dataset
//!   (pairwise error, AUC, precision@k — grouped means when qids exist)
//! - `cv`         — parallel warm-started k-fold sweep over a λ grid;
//!   one JSON path report line (byte-identical for every `--threads`)
//! - `losses`     — list the registered losses (one JSON line each)
//! - `predict`    — one score per line for a dataset (raw features; a
//!   model's recorded `--normalize` norms are applied automatically)
//! - `serve`      — long-running scoring daemon (stdio or `--listen` TCP)
//!   with batched scoring, top-k, and atomic model hot swap
//! - `gen-data`   — write a synthetic dataset in libsvm format
//! - `convert`    — libsvm text → memory-mappable pallas store (`.pstore`),
//!   optionally with a parallel parse phase (`--threads`)
//! - `stats`      — pretty-print a store's cached per-column statistics
//! - `mem-probe`  — child process used by the Fig.-3 memory benchmark
//! - `info`       — dataset statistics (m, n, s, r, N)
//! - `report`     — render a `train --trace` JSONL run trace as a table
//!
//! `--data` accepts either format everywhere: pallas stores are
//! autodetected by magic bytes and memory-mapped (no parse), anything
//! else is parsed as libsvm text. Run with no args for usage.
//!
//! Every subcommand accepts `--verbose` / `--quiet`, resolved once here
//! into the process-wide [`ranksvm::obs::log`] level (verbose wins when
//! both are given); protocol output (scores, JSON records, serve
//! responses) is unaffected by either flag.
//!
//! Errors (including malformed flag values) print one `error:` line and
//! exit with code 2 — no panics, no backtraces.

use anyhow::{bail, Context, Result};
use ranksvm::coordinator::{
    evaluate_scoring, memprobe, train, BackendKind, Method, Normalize, ScoringModel, TrainConfig,
};
use ranksvm::data::{libsvm, materialize, store, synthetic, Dataset, DatasetView, LoadedDataset};
use ranksvm::serve;
use ranksvm::util::cli::Args;
use ranksvm::util::json::Json;

fn usage() -> ! {
    eprintln!(
        "ranksvm — linearithmic linear RankSVM training (TreeRSVM reproduction)

USAGE:
  ranksvm train     (--data F | --synthetic K --m M) [--loss NAME]
                    (--method is an accepted alias; `ranksvm losses` lists
                      the registered names — tree, pair, rlevel, prsvm,
                      toppush, ... — plus solver family and substrate)
                    [--lambda L] [--epsilon E] [--max-iter I] [--backend native|native-csc|xla]
                    [--threads T]  (0 = all cores; results are identical for any T)
                    [--chunk-target-kib K]  (per-chunk working-set target for the
                      cache-aware parallel plans; 0 = auto-probe half of L2.
                      Purely a speed knob — results are identical for any K)
                    [--normalize none|l2-col]  (l2-col divides each column by its
                      l2 norm, consuming store-cached stats when available)
                    [--artifacts DIR] [--line-search] [--test-size T] [--seed S] [--out MODEL] [--verbose]
                    [--trace OUT.jsonl]  (structured per-iteration run trace,
                      one JSON line per BMRM iteration — inert: the trained
                      model is byte-identical with or without it;
                      docs/OBSERVABILITY.md)
  ranksvm eval      --model MODEL --data F [--k K]
                    (pairwise_error + auc + precision_at_k JSON; metrics
                      are per-query means when the data carries qids;
                      --k sets the precision cutoff, default 10)
  ranksvm cv        (--data F | --synthetic K --m M) [--loss NAME]
                    [--lambdas L1,L2,..] [--folds K] [--seed S]
                    [--metric error|auc|precision] [--k K] [--threads T]
                    [--epsilon E] [--max-iter I] [--cold] [--trace OUT.jsonl]
                    (k-fold CV over the λ grid as one pool-scheduled
                      warm-started path sweep; prints one JSON path report
                      with error/auc/precision@k per λ. The report carries
                      no thread or timing fields — bytes are identical for
                      every --threads value. --cold disables warm starts;
                      --trace writes one cv_point JSONL line per λ)
  ranksvm losses    (one JSON line per registered loss: name, aliases,
                      solver family, parallel substrate, normalization)
  ranksvm predict   --model MODEL (--data F | --synthetic K --m M)
                    (one score per line, raw features in — an l2-col
                      model applies its recorded norms itself)
  ranksvm serve     --model MODEL [--data F] [--threads T] [--listen ADDR]
                    [--no-verify]
                    (newline protocol on stdio, or TCP with --listen;
                      requests: score/rows/topk/batch/metrics/info/ping/
                      reload/swap/quit — see docs/MODEL_FORMAT.md and README)
  ranksvm gen-data  --synthetic K --m M --out F [--seed S]
  ranksvm convert   --data F.libsvm --out F.pstore [--chunk-kib N] [--threads T]
                    (parallel parse; output bytes identical for every T)
  ranksvm stats     F.pstore [--limit K] [--no-verify]
                    (cached per-column stats; --limit 0 prints all columns)
  ranksvm info      (--data F | --synthetic K --m M)
  ranksvm mem-probe (--dataset K | --data F) --m M --method NAME [--lambda L] [--max-iter I]
                    [--cv [--lambdas L1,L2,..] [--folds K]]  (probe a CV sweep
                      instead of one training — the zero-copy-folds memory check)
  ranksvm perf      [--sizes N,N,..] [--reps R] [--synthetic K]
                    [--method tree|tree-fenwick|sharded|par-sort] [--threads T]
  ranksvm report    --trace RUN.jsonl
                    (human summary table of a `train --trace` run)

  Every subcommand accepts --verbose / --quiet (log level of diagnostic
  stderr output; verbose wins when both are given). Protocol output —
  scores, JSON records, serve responses — is never affected.

  --data F: libsvm text or a pallas store (.pstore, autodetected by magic
  bytes and memory-mapped zero-copy). --no-verify skips the store
  checksum/structure scan — no full-file read at open; for out-of-core
  data you trust.

  synthetic kinds K: cadata | reuters | reuters-small | ordinal | queries
                     | zipf-queries (Zipf(--zipf-a, default 1.1) group sizes
                       over --groups groups — the skewed-shard fixture)"
    );
    std::process::exit(2);
}

fn load_dataset(args: &Args) -> Result<LoadedDataset> {
    let seed = args.u64_or("seed", 42)?;
    if let Some(path) = args.get("data") {
        return ranksvm::data::load_auto_with(path, !args.flag("no-verify"));
    }
    let m = args.usize_or("m", 1000)?;
    let ds = match args.get("synthetic") {
        Some("cadata") => synthetic::cadata_like(m, seed),
        Some("reuters") => synthetic::reuters_like(m, seed),
        Some("reuters-small") => synthetic::reuters_like_with(m, 5000, 30, seed),
        Some("ordinal") => synthetic::ordinal(m, args.usize_or("levels", 5)?, seed),
        Some("queries") => {
            let per = args.usize_or("per-query", 20)?;
            synthetic::queries(m.div_ceil(per), per, args.usize_or("features", 10)?, seed)
        }
        Some("zipf-queries") => {
            // Zipf-skewed group sizes (the work-stealing scheduler's
            // adversarial fixture): one giant group, a long singleton
            // tail.
            let groups = args.usize_or("groups", m.div_ceil(8).max(1))?;
            let a = args.f64_or("zipf-a", 1.1)?;
            if a.is_nan() || a <= 0.0 {
                bail!("bad --zipf-a {a}: the Zipf exponent must be > 0");
            }
            synthetic::zipf_queries(m, groups, args.usize_or("features", 10)?, a, seed)
        }
        Some(k) => bail!("unknown synthetic kind {k:?}"),
        None => bail!("need --data or --synthetic"),
    };
    Ok(LoadedDataset::Owned(ds))
}

/// Resolve `--loss` (registry-era spelling) or `--method` (historical
/// alias) through the loss registry. The unknown-name error lists every
/// registered loss *from the registry* — no hardcoded spellings to
/// drift — and `tests/cli.rs` pins that.
fn parse_loss(args: &Args) -> Result<Method> {
    let (flag, name) = match args.get("loss") {
        Some(v) => ("--loss", v),
        None => ("--method", args.get("method").unwrap_or("tree")),
    };
    Method::parse(name).ok_or_else(|| {
        let names: Vec<&str> = ranksvm::losses::registry::names().collect();
        anyhow::anyhow!(
            "unknown {flag} {name:?} — registered losses: {} (see `ranksvm losses`)",
            names.join(", ")
        )
    })
}

fn cmd_train(args: &Args) -> Result<()> {
    let loaded = load_dataset(args)?;
    let method = parse_loss(args)?;
    let backend = BackendKind::parse(&args.str_or("backend", "native")).context("bad --backend")?;
    let cfg = TrainConfig {
        method,
        backend,
        lambda: args.f64_or("lambda", 1e-2)?,
        epsilon: args.f64_or("epsilon", 1e-3)?,
        max_iter: args.usize_or("max-iter", 2000)?,
        line_search: args.flag("line-search"),
        artifacts_dir: args.str_or("artifacts", "artifacts"),
        verbose: args.flag("verbose"),
        trace_path: args.get("trace").map(str::to_string),
        n_threads: args.usize_or("threads", 0)?,
        normalize: Normalize::parse(&args.str_or("normalize", "none"))
            .context("bad --normalize (none|l2-col)")?,
        chunk_target_kib: args.usize_or("chunk-target-kib", 0)?,
    };
    let test_size = args.usize_or("test-size", 0)?;
    // A shuffled split needs owned storage; materialize a store first.
    // Without a split the store trains in place, zero-copy.
    // "mmap" reports whether training actually runs off a kernel
    // mapping (false for the read fallback or a materialized split).
    let mapped = match &loaded {
        LoadedDataset::Store(st) => st.is_mapped(),
        LoadedDataset::Owned(_) => false,
    };
    let (train_holder, test_ds): (LoadedDataset, Option<Dataset>) = if test_size > 0 {
        let owned = match loaded {
            LoadedDataset::Owned(ds) => ds,
            LoadedDataset::Store(st) => materialize(&st),
        };
        let (tr, te) = owned.split(test_size, args.u64_or("seed", 42)?);
        (LoadedDataset::Owned(tr), Some(te))
    } else {
        (loaded, None)
    };
    let train_view = train_holder.view();
    let out = train(train_view, &cfg)?;
    // The outcome's scoring model carries the training-set norms when
    // --normalize is on, so the held-out split (and any later predict /
    // serve traffic) is scored on raw features and normalized inside
    // the shared kernel — same fold, same bits as scaling by hand.
    let scoring = out.scoring_model();
    let mut record = vec![
        ("dataset".to_string(), Json::Str(train_view.name().to_string())),
        ("m".to_string(), train_view.len().into()),
        ("n".to_string(), train_view.dim().into()),
        ("s".to_string(), train_view.sparsity().into()),
        ("levels".to_string(), train_view.n_levels().into()),
        ("threads".to_string(), cfg.resolved_threads().into()),
        ("normalize".to_string(), Json::Str(cfg.normalize.name().to_string())),
        ("mmap".to_string(), (mapped && test_size == 0).into()),
    ];
    if let Json::Obj(base) = out.to_json() {
        record.extend(base);
    }
    if let Some(te) = &test_ds {
        record.push(("test_error".to_string(), evaluate_scoring(&scoring, te).into()));
        record.push(("test_m".to_string(), te.len().into()));
    }
    println!("{}", Json::Obj(record).to_string());
    if let Some(path) = args.get("out") {
        // Versioned binary format (docs/MODEL_FORMAT.md): weights plus
        // the recorded normalization, checksummed, published atomically.
        scoring.save(path)?;
        ranksvm::obs::log::info(&format!("model saved to {path}"));
    }
    Ok(())
}

fn cmd_eval(args: &Args) -> Result<()> {
    use ranksvm::metrics;
    // Either model format, autodetected: binary .rsm or legacy text.
    let model = ScoringModel::load_auto(args.get("model").context("need --model")?)?;
    let loaded = load_dataset(args)?;
    let ds = loaded.view();
    let k = args.usize_or("k", 10)?;
    // One scoring pass feeds every metric. With qids each metric is the
    // per-query mean over its effective groups (matching the grouped
    // training risk); without, it is computed over the one global
    // ranking. AUC and precision@k treat y > 0 as relevant — the same
    // label partition TopPush trains on — so a `--loss toppush` model
    // is measurable here with no external tooling.
    let p = model.scores(ds);
    let (err, auc, prec) = match ds.qid() {
        Some(q) => (
            metrics::grouped_pairwise_error(&p, ds.y(), q),
            metrics::grouped_auc(&p, ds.y(), q),
            metrics::grouped_precision_at_k(&p, ds.y(), q, k, 0.0),
        ),
        None => (
            metrics::pairwise_error(&p, ds.y()),
            metrics::auc(&p, ds.y()),
            metrics::precision_at_k(&p, ds.y(), k, 0.0),
        ),
    };
    println!(
        "{}",
        Json::obj(vec![
            ("dataset", Json::Str(ds.name().to_string())),
            ("m", ds.len().into()),
            ("grouped", ds.qid().is_some().into()),
            ("normalize", Json::Str(model.normalize_name().to_string())),
            ("pairwise_error", err.into()),
            ("auc", auc.into()),
            ("k", k.into()),
            ("precision_at_k", prec.into()),
        ])
        .to_string()
    );
    Ok(())
}

/// `ranksvm cv` — the parallel warm-started λ-path sweep
/// (`coordinator::modelsel`). Prints exactly one JSON path report line.
/// The report deliberately carries **no** thread counts and **no**
/// wall-clock fields: the CI cv-matrix leg runs the same sweep at
/// `--threads 1/2/8` and byte-compares the three reports
/// (docs/DETERMINISM.md).
fn cmd_cv(args: &Args) -> Result<()> {
    use ranksvm::coordinator::{cv_sweep, CvConfig, CvMetric};
    let loaded = load_dataset(args)?;
    let ds = loaded.view();
    let method = parse_loss(args)?;
    let base = TrainConfig {
        method,
        epsilon: args.f64_or("epsilon", 1e-3)?,
        max_iter: args.usize_or("max-iter", 2000)?,
        n_threads: args.usize_or("threads", 0)?,
        chunk_target_kib: args.usize_or("chunk-target-kib", 0)?,
        verbose: args.flag("verbose"),
        ..Default::default()
    };
    let lambdas = args.f64_list_or("lambdas", &[1e-4, 1e-3, 1e-2, 1e-1, 1.0])?;
    let folds = args.usize_or("folds", 5)?;
    let seed = args.u64_or("seed", 42)?;
    let cfg = CvConfig {
        warm_start: !args.flag("cold"),
        metric: CvMetric::parse(&args.str_or("metric", "error"))?,
        k: args.usize_or("k", 10)?,
        ..CvConfig::new(base, lambdas, folds, seed)
    };
    let report = cv_sweep(ds, &cfg)?;
    // Optional per-point trace, written *after* the sweep so the engine
    // itself stays observation-free (these files are cv_point JSONL,
    // not training traces — `ranksvm report` does not render them).
    if let Some(path) = args.get("trace") {
        use ranksvm::obs::trace::{cv_point_event, CvPointInfo, TraceSink};
        let mut sink = TraceSink::create(path)?;
        for p in &report.points {
            sink.event(&cv_point_event(&CvPointInfo {
                lambda: p.lambda,
                mean_error: p.mean_error,
                mean_auc: p.mean_auc,
                mean_precision_at_k: p.mean_precision_at_k,
                iterations: p.iterations,
                selected: p.lambda == report.selected_lambda,
            }))?;
        }
        sink.finish()?;
    }
    let points: Vec<Json> = report
        .points
        .iter()
        .map(|p| {
            Json::obj(vec![
                ("lambda", p.lambda.into()),
                ("mean_error", p.mean_error.into()),
                ("mean_auc", p.mean_auc.into()),
                ("mean_precision_at_k", p.mean_precision_at_k.into()),
                ("iterations", p.iterations.into()),
                ("fold_errors", Json::Arr(p.fold_errors.iter().map(|&e| e.into()).collect())),
            ])
        })
        .collect();
    println!(
        "{}",
        Json::obj(vec![
            ("schema", Json::Str("ranksvm-cv-path".into())),
            ("schema_version", Json::Int(1)),
            ("dataset", Json::Str(ds.name().to_string())),
            ("m", ds.len().into()),
            ("loss", Json::Str(method.name().to_string())),
            ("folds", cfg.folds.into()),
            ("seed", Json::Int(cfg.seed as i64)),
            ("warm_start", cfg.warm_start.into()),
            ("metric", Json::Str(cfg.metric.name().to_string())),
            ("k", cfg.k.into()),
            ("points", Json::Arr(points)),
            ("selected_lambda", report.selected_lambda.into()),
            ("total_iterations", report.total_iterations.into()),
        ])
        .to_string()
    );
    Ok(())
}

/// `ranksvm losses` — the registry, one JSON line per loss (stable
/// field order; CI and scripts iterate this instead of hardcoding
/// method lists).
fn cmd_losses() -> Result<()> {
    for spec in ranksvm::losses::registry::SPECS {
        let aliases: Vec<Json> = spec.aliases.iter().map(|a| Json::Str(a.to_string())).collect();
        println!(
            "{}",
            Json::obj(vec![
                ("name", Json::Str(spec.name.to_string())),
                ("aliases", Json::Arr(aliases)),
                ("solver", Json::Str(spec.solver.name().to_string())),
                ("substrate", Json::Str(spec.substrate.name().to_string())),
                ("normalization", Json::Str(spec.normalization.name().to_string())),
                ("about", Json::Str(spec.about.to_string())),
            ])
            .to_string()
        );
    }
    Ok(())
}

/// `ranksvm predict` — one score per line, in dataset row order, with
/// `{}` float formatting. `ranksvm serve` responses are byte-identical
/// to this output for the same model and rows (CI pins it).
fn cmd_predict(args: &Args) -> Result<()> {
    use std::io::Write as _;
    let model = ScoringModel::load_auto(args.get("model").context("need --model")?)?;
    let loaded = load_dataset(args)?;
    let scores = model.scores(loaded.view());
    let stdout = std::io::stdout();
    let mut out = std::io::BufWriter::new(stdout.lock());
    for s in &scores {
        writeln!(out, "{s}")?;
    }
    out.flush()?;
    Ok(())
}

/// `ranksvm serve` — the long-running scoring daemon. Stdio by default
/// (one response line per request line), thread-per-connection TCP with
/// `--listen ADDR`. `--data` attaches a feature store for `rows`/`topk`
/// requests; the model hot-swaps atomically on `swap`/`reload` or when
/// the model file is republished.
fn cmd_serve(args: &Args) -> Result<()> {
    let model_path = args.get("model").context("need --model")?;
    let verify = !args.flag("no-verify");
    let data = if args.get("data").is_some() || args.get("synthetic").is_some() {
        Some(load_dataset(args)?)
    } else {
        None
    };
    let n_threads = ranksvm::util::resolve_threads(args.usize_or("threads", 0)?);
    let engine = serve::Engine::new(model_path, data, n_threads, verify)?;
    match args.get("listen") {
        Some(addr) => serve::serve_tcp(std::sync::Arc::new(engine), addr),
        None => serve::serve_stdio(&engine),
    }
}

fn cmd_gen_data(args: &Args) -> Result<()> {
    let loaded = load_dataset(args)?;
    let out = args.get("out").context("need --out")?;
    let ds = loaded.view();
    libsvm::write(ds, out)?;
    ranksvm::obs::log::info(&format!(
        "wrote {} examples ({} features) to {out}",
        ds.len(),
        ds.dim()
    ));
    Ok(())
}

fn cmd_convert(args: &Args) -> Result<()> {
    let input = args.get("data").context("need --data INPUT (libsvm text)")?;
    let output = args.get("out").context("need --out OUTPUT.pstore")?;
    if store::is_store_file(input) {
        bail!("{input} is already a pallas store");
    }
    let chunk_kib = args.usize_or("chunk-kib", 8192)?;
    let opts = store::ConvertOptions {
        chunk_bytes: chunk_kib.max(1) * 1024,
        // Parallel parse is opt-in (`0` = all cores): output bytes are
        // identical for every value, so this is purely a speed knob.
        n_threads: args.usize_or("threads", 1)?,
    };
    let stats = store::convert_libsvm(input, output, &opts)?;
    let mut record = vec![
        ("input".to_string(), Json::Str(input.to_string())),
        ("output".to_string(), Json::Str(output.to_string())),
        ("m".to_string(), stats.rows.into()),
        ("n".to_string(), stats.cols.into()),
        ("nnz".to_string(), stats.nnz.into()),
        ("groups".to_string(), stats.n_groups.into()),
        ("n_pairs".to_string(), (stats.n_pairs as usize).into()),
        ("out_bytes".to_string(), (stats.out_bytes as usize).into()),
        ("chunk_bytes".to_string(), opts.chunk_bytes.into()),
        ("max_buffered_bytes".to_string(), stats.max_buffered_bytes.into()),
        ("threads".to_string(), stats.threads.into()),
        ("shards".to_string(), stats.shards.into()),
    ];
    if let Some(peak) = ranksvm::util::peak_rss_kib() {
        record.push(("peak_rss_kib".to_string(), (peak as usize).into()));
    }
    println!("{}", Json::Obj(record).to_string());
    Ok(())
}

/// `ranksvm stats F.pstore` — one summary JSON line plus a per-column
/// table of the cached statistics (libsvm 1-based column numbering).
fn cmd_stats(args: &Args) -> Result<()> {
    let path = args
        .get("data")
        .map(str::to_string)
        .or_else(|| args.positional.get(1).cloned())
        .context("need a store: ranksvm stats FILE.pstore")?;
    if !store::is_store_file(&path) {
        bail!("{path} is not a pallas store (convert libsvm text with `ranksvm convert` first)");
    }
    let st = if args.flag("no-verify") {
        store::PallasStore::open_unchecked(&path)?
    } else {
        store::PallasStore::open(&path)?
    };
    let stats = st.col_stats();
    println!(
        "{}",
        Json::obj(vec![
            ("store", Json::Str(path.clone())),
            ("m", st.len().into()),
            ("n", st.dim().into()),
            ("nnz", st.nnz().into()),
            ("groups", st.n_groups().into()),
            ("n_pairs", (st.n_pairs() as usize).into()),
            ("file_bytes", st.file_bytes().into()),
            ("colstats", stats.is_some().into()),
        ])
        .to_string()
    );
    let Some(stats) = stats else {
        ranksvm::obs::log::info(&format!("{path}: no cached column statistics in this store"));
        return Ok(());
    };
    let limit = args.usize_or("limit", 20)?;
    let shown = if limit == 0 { stats.len() } else { stats.len().min(limit) };
    println!(
        "{:>8} {:>10} {:>13} {:>13} {:>13} {:>13} {:>13}",
        "col", "nnz", "l2_norm", "mean", "min", "max", "sum"
    );
    for (c, s) in stats.iter().take(shown).enumerate() {
        let mean = if s.nnz > 0 { s.sum / s.nnz as f64 } else { 0.0 };
        println!(
            "{:>8} {:>10} {:>13.6e} {:>13.6e} {:>13.6e} {:>13.6e} {:>13.6e}",
            c + 1, // libsvm feature indices are 1-based
            s.nnz,
            s.sumsq.sqrt(),
            mean,
            s.min,
            s.max,
            s.sum,
        );
    }
    if shown < stats.len() {
        ranksvm::obs::log::info(&format!(
            "... {} more columns (--limit 0 prints all)",
            stats.len() - shown
        ));
    }
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    let loaded = load_dataset(args)?;
    let ds = loaded.view();
    // `n_pairs` here is the whole-vector comparable-pair count for both
    // formats (the seed's info semantics). The store's precomputed
    // n_pairs is the *training objective's* count, which for grouped
    // data is the per-group sum — only reuse it when they coincide.
    let n_pairs = match (ds.qid(), ds.n_pairs_hint()) {
        (None, Some(n)) => n as usize,
        _ => ranksvm::losses::count_comparable_pairs(ds.y()) as usize,
    };
    let mut record = vec![
        ("dataset".to_string(), Json::Str(ds.name().to_string())),
        ("format".to_string(), Json::Str(if loaded.is_store() { "pstore" } else { "libsvm" }.into())),
        ("m".to_string(), ds.len().into()),
        ("n".to_string(), ds.dim().into()),
        ("nnz".to_string(), ds.x().nnz().into()),
        ("s".to_string(), ds.sparsity().into()),
        ("levels".to_string(), ds.n_levels().into()),
        ("n_pairs".to_string(), n_pairs.into()),
        ("grouped".to_string(), ds.qid().is_some().into()),
    ];
    if let LoadedDataset::Store(st) = &loaded {
        record.push(("groups".to_string(), st.n_groups().into()));
        record.push(("file_bytes".to_string(), st.file_bytes().into()));
        record.push(("mmap".to_string(), st.is_mapped().into()));
    }
    println!("{}", Json::Obj(record).to_string());
    Ok(())
}

/// §Perf probe: break one TreeRSVM oracle call into its phases
/// (score matvec / argsort / c-sweep / d-sweep / gradient) at growing m.
fn cmd_perf(args: &Args) -> Result<()> {
    use ranksvm::losses::{count_comparable_pairs, RankingOracle, TreeOracle};
    let sizes = args.usize_list_or("sizes", &[10_000, 50_000, 200_000])?;
    let reps = args.usize_or("reps", 5)?;
    let kind = args.str_or("synthetic", "reuters");
    println!(
        "{:>9} {:>11} {:>11} {:>11} {:>11} {:>11} {:>11}",
        "m", "matvec", "sort", "sweep_c", "sweep_d", "grad", "total"
    );
    for &m in &sizes {
        let ds = match kind.as_str() {
            "cadata" => synthetic::cadata_like(m, 7),
            _ => synthetic::reuters_like(m, 7),
        };
        let n_pairs = count_comparable_pairs(&ds.y) as f64;
        let mut w = vec![0.0; ds.dim()];
        ds.x.matvec_t(&ds.y, &mut w);
        let nrm = ranksvm::linalg::ops::norm(&w).max(1e-12);
        ranksvm::linalg::ops::scal(1.0 / nrm, &mut w);
        let method = args.str_or("method", "tree");
        if method == "tree-fenwick" {
            // Fenwick comparison path: report eval total only.
            let mut oracle = ranksvm::losses::tree::fenwick_oracle(&ds.y);
            let mut p = vec![0.0; ds.len()];
            ds.x.matvec(&w, &mut p);
            std::hint::black_box(oracle.eval(&p, &ds.y, n_pairs));
            let t = std::time::Instant::now();
            for _ in 0..reps {
                std::hint::black_box(oracle.eval(&p, &ds.y, n_pairs));
            }
            let avg_ms = 1e3 * t.elapsed().as_secs_f64() / reps as f64;
            println!("{m:>9} fenwick eval total: {avg_ms:.2}ms");
            continue;
        }
        if method == "sharded" {
            // Sharded-oracle path: eval total at the requested thread
            // count, on one persistent pool reused across the reps (the
            // trainer's arrangement — no per-call thread spawns).
            let threads = ranksvm::util::resolve_threads(args.usize_or("threads", 0)?);
            let pool = std::sync::Arc::new(ranksvm::runtime::WorkerPool::new(threads));
            let mut oracle = ranksvm::losses::ShardedTreeOracle::with_pool(pool, None, &ds.y);
            let mut p = vec![0.0; ds.len()];
            ds.x.matvec(&w, &mut p);
            std::hint::black_box(oracle.eval(&p, &ds.y, n_pairs));
            let t = std::time::Instant::now();
            for _ in 0..reps {
                std::hint::black_box(oracle.eval(&p, &ds.y, n_pairs));
            }
            println!(
                "{:>9} sharded({threads}) eval total: {:.2}ms",
                m,
                1e3 * t.elapsed().as_secs_f64() / reps as f64
            );
            continue;
        }
        if method == "par-sort" {
            // Argsort probe: serial vs pooled parallel merge sort on the
            // score vector (the Amdahl term the sharded oracle removes).
            let threads = ranksvm::util::resolve_threads(args.usize_or("threads", 0)?);
            let pool = ranksvm::runtime::WorkerPool::new(threads);
            let mut p = vec![0.0; ds.len()];
            ds.x.matvec(&w, &mut p);
            let mut idx = Vec::new();
            let mut scratch = ranksvm::linalg::ops::SortScratch::default();
            ranksvm::linalg::ops::argsort_into(&p, &mut idx);
            let t = std::time::Instant::now();
            for _ in 0..reps {
                ranksvm::linalg::ops::argsort_into(&p, &mut idx);
                std::hint::black_box(&idx);
            }
            let serial = 1e3 * t.elapsed().as_secs_f64() / reps as f64;
            ranksvm::linalg::ops::par_argsort_into(&p, &mut idx, &mut scratch, &pool);
            let t = std::time::Instant::now();
            for _ in 0..reps {
                ranksvm::linalg::ops::par_argsort_into(&p, &mut idx, &mut scratch, &pool);
                std::hint::black_box(&idx);
            }
            let par = 1e3 * t.elapsed().as_secs_f64() / reps as f64;
            println!(
                "{:>9} argsort serial: {serial:.2}ms  parallel({threads}): {par:.2}ms  ({:.2}×)",
                m,
                serial / par.max(1e-9)
            );
            continue;
        }
        let mut oracle = TreeOracle::new();
        let mut p = vec![0.0; ds.len()];
        let mut a = vec![0.0; ds.dim()];
        // warmup
        ds.x.matvec(&w, &mut p);
        let out = oracle.eval(&p, &ds.y, n_pairs);
        ds.x.matvec_t(&out.coeffs, &mut a);
        oracle.phases = ranksvm::util::timer::PhaseTimes::new();
        let mut t_matvec = 0.0;
        let mut t_grad = 0.0;
        let total_timer = std::time::Instant::now();
        for _ in 0..reps {
            let t = std::time::Instant::now();
            ds.x.matvec(&w, &mut p);
            t_matvec += t.elapsed().as_secs_f64();
            let out = oracle.eval(&p, &ds.y, n_pairs);
            let t = std::time::Instant::now();
            ds.x.matvec_t(&out.coeffs, &mut a);
            t_grad += t.elapsed().as_secs_f64();
        }
        let total = total_timer.elapsed().as_secs_f64() / reps as f64;
        let ph = &oracle.phases;
        let r = reps as f64;
        println!(
            "{:>9} {:>10.2}ms {:>10.2}ms {:>10.2}ms {:>10.2}ms {:>10.2}ms {:>10.2}ms",
            m,
            1e3 * t_matvec / r,
            1e3 * ph.get("sort").as_secs_f64() / r,
            1e3 * ph.get("sweep_c").as_secs_f64() / r,
            1e3 * ph.get("sweep_d").as_secs_f64() / r,
            1e3 * t_grad / r,
            1e3 * total,
        );
    }
    Ok(())
}

/// `ranksvm report` — render a `train --trace` JSONL run trace as a
/// fixed-width human summary (header, one row per iteration, footer).
fn cmd_report(args: &Args) -> Result<()> {
    let path = args
        .get("trace")
        .map(str::to_string)
        .or_else(|| args.positional.get(1).cloned())
        .context("need a trace: ranksvm report --trace RUN.jsonl")?;
    let text = std::fs::read_to_string(&path).with_context(|| format!("reading {path}"))?;
    print!("{}", ranksvm::obs::trace::render_report(&text)?);
    Ok(())
}

fn cmd_mem_probe(args: &Args) -> Result<()> {
    let method = parse_loss(args)?;
    let lambda = args.f64_or("lambda", 1e-4)?;
    let max_iter = args.usize_or("max-iter", 10)?;
    if args.flag("cv") {
        // CV-sweep probe: the zero-copy-folds memory regression test
        // compares this child's peak against a plain training probe.
        let path = args.get("data").context("mem-probe --cv needs --data")?;
        let lambdas = args.f64_list_or("lambdas", &[1e-2, 1e-1])?;
        return memprobe::run_probe_cv(
            path,
            method,
            &lambdas,
            args.usize_or("folds", 3)?,
            max_iter,
            args.flag("no-verify"),
        );
    }
    if let Some(path) = args.get("data") {
        // Probe a real file (text or store) — the out-of-core story's
        // memory accounting.
        return memprobe::run_probe_path(path, method, lambda, max_iter, args.flag("no-verify"));
    }
    let dataset = args.str_or("dataset", "reuters-small");
    let m = args.usize_or("m", 1000)?;
    memprobe::run_probe(&dataset, m, method, lambda, max_iter, args.u64_or("seed", 42)?)
}

fn run() -> Result<()> {
    let args = Args::from_env();
    // One --verbose/--quiet story for every subcommand: resolve the
    // flags into the process-wide log level before dispatch.
    ranksvm::obs::log::set_level(ranksvm::obs::log::level_from_flags(
        args.flag("quiet"),
        args.flag("verbose"),
    ));
    match args.positional.first().map(|s| s.as_str()) {
        Some("train") => cmd_train(&args),
        Some("eval") => cmd_eval(&args),
        Some("cv") => cmd_cv(&args),
        Some("predict") => cmd_predict(&args),
        Some("serve") => cmd_serve(&args),
        Some("gen-data") => cmd_gen_data(&args),
        Some("convert") => cmd_convert(&args),
        Some("stats") => cmd_stats(&args),
        Some("info") => cmd_info(&args),
        Some("mem-probe") => cmd_mem_probe(&args),
        Some("losses") => cmd_losses(),
        Some("perf") => cmd_perf(&args),
        Some("report") => cmd_report(&args),
        _ => usage(),
    }
}

fn main() {
    if let Err(e) = run() {
        // One readable line (the full context chain), no backtrace.
        eprintln!("error: {e}");
        std::process::exit(2);
    }
}
