//! Cross-module integration tests: full training runs over every method,
//! dataset regime, and the claims of the paper's Fig. 4 (all
//! implementations reach equivalent solutions) as executable assertions.

use ranksvm::coordinator::{evaluate, train, Method, RankModel, TrainConfig};
use ranksvm::data::{libsvm, synthetic};
use ranksvm::losses::{count_comparable_pairs, RankingOracle, TreeOracle};
use ranksvm::metrics;

fn cfg(method: Method, lambda: f64) -> TrainConfig {
    TrainConfig { method, lambda, epsilon: 1e-3, ..Default::default() }
}

#[test]
fn fig4_sanity_all_methods_similar_test_error() {
    // The paper's Fig. 4: despite implementation differences, every
    // *pairwise-comparable* method lands at a similar test pairwise
    // error. Registry losses with a different normalizer (TopPush
    // optimizes top-of-ranking accuracy, not the pairwise risk) are out
    // of scope for this equivalence by construction.
    use ranksvm::losses::registry::Normalization;
    let ds = synthetic::cadata_like(1200, 4);
    let (tr, te) = ds.split(300, 9);
    let mut errors = Vec::new();
    for &m in Method::all() {
        if m.spec().normalization != Normalization::ComparablePairs {
            continue;
        }
        let out = train(&tr, &cfg(m, 0.1)).unwrap();
        let err = evaluate(&out.model, &te);
        errors.push((m.name(), err));
    }
    assert!(errors.len() >= 7, "expected the full pairwise family, got {errors:?}");
    let base = errors[0].1;
    for (name, err) in &errors {
        assert!(
            (err - base).abs() < 0.03,
            "method {name} deviates: {err} vs tree {base} ({errors:?})"
        );
        assert!(*err < 0.30, "method {name} failed to learn: {err}");
    }
}

#[test]
fn bipartite_training_maximizes_auc() {
    // Two utility levels → RankSVM == AUC maximization (§1).
    let base = synthetic::ordinal(800, 2, 13);
    let (tr, te) = base.split(200, 5);
    let out = train(&tr, &cfg(Method::Tree, 0.05)).unwrap();
    let p = out.model.predict(&te);
    let auc = metrics::auc(&p, &te.y);
    assert!(auc > 0.8, "AUC {auc}");
}

#[test]
fn ordinal_ratings_r_level_matches_tree() {
    let ds = synthetic::ordinal(600, 5, 14);
    let t = train(&ds, &cfg(Method::Tree, 0.05)).unwrap();
    let r = train(&ds, &cfg(Method::RLevel, 0.05)).unwrap();
    assert!((t.objective - r.objective).abs() < 2e-3 * (1.0 + t.objective));
}

#[test]
fn grouped_and_global_differ_when_expected() {
    // With per-query offsets, grouped training must beat treating the
    // data as one global ranking.
    let ds = synthetic::queries(30, 20, 8, 15);
    let grouped_out = train(&ds, &cfg(Method::Tree, 0.01)).unwrap();
    let grouped_err = evaluate(&grouped_out.model, &ds);

    let mut global = ds.clone();
    global.qid = None;
    let global_out = train(&global, &cfg(Method::Tree, 0.01)).unwrap();
    // Evaluate BOTH on the grouped criterion (the true task).
    let global_err = {
        let p = global_out.model.predict(&ds);
        metrics::grouped_pairwise_error(&p, &ds.y, ds.qid.as_ref().unwrap())
    };
    assert!(
        grouped_err <= global_err + 0.02,
        "grouped {grouped_err} should not lose to global {global_err}"
    );
}

#[test]
fn model_persistence_round_trip_through_cli_format() {
    let ds = synthetic::cadata_like(300, 16);
    let out = train(&ds, &cfg(Method::Tree, 0.1)).unwrap();
    let tmp = std::env::temp_dir().join("ranksvm_integration_model.txt");
    out.model.save(&tmp).unwrap();
    let loaded = RankModel::load(&tmp).unwrap();
    assert_eq!(loaded, out.model);
    std::fs::remove_file(tmp).ok();
}

#[test]
fn libsvm_export_import_preserves_training_behaviour() {
    let ds = synthetic::cadata_like(250, 17);
    let tmp = std::env::temp_dir().join("ranksvm_integration_data.libsvm");
    libsvm::write(&ds, &tmp).unwrap();
    let back = libsvm::read(&tmp).unwrap();
    let a = train(&ds, &cfg(Method::Tree, 0.1)).unwrap();
    let b = train(&back, &cfg(Method::Tree, 0.1)).unwrap();
    assert!((a.objective - b.objective).abs() < 1e-9 * (1.0 + a.objective));
    std::fs::remove_file(tmp).ok();
}

#[test]
fn regularization_path_is_monotone_in_norm() {
    // Larger λ → smaller ‖w‖ (textbook sanity on the full pipeline).
    let ds = synthetic::cadata_like(400, 18);
    let mut prev_norm = f64::INFINITY;
    for &lambda in &[0.01, 0.1, 1.0, 10.0] {
        let out = train(&ds, &cfg(Method::Tree, lambda)).unwrap();
        let norm = ranksvm::linalg::ops::norm(&out.model.w);
        assert!(
            norm <= prev_norm + 1e-6,
            "‖w‖ not decreasing along λ path: {norm} after {prev_norm}"
        );
        prev_norm = norm;
    }
}

#[test]
fn oracle_scaling_shape_tree_vs_pair() {
    // Micro-version of Fig. 1's asymptotic contrast, as a test: growing m
    // by 4× grows the pair oracle's cost ~16× but the tree oracle's by
    // only ~4–6×. Timing-based but with a generous margin.
    let ds = synthetic::cadata_like(8000, 19);
    let p: Vec<f64> = ds.y.iter().map(|v| v * 0.5).collect(); // any scores
    let time_oracle = |oracle: &mut dyn RankingOracle, m: usize| {
        let n = count_comparable_pairs(&ds.y[..m]) as f64;
        // warmup + best of 3
        let mut best = f64::INFINITY;
        for _ in 0..3 {
            let t = std::time::Instant::now();
            std::hint::black_box(oracle.eval(&p[..m], &ds.y[..m], n));
            best = best.min(t.elapsed().as_secs_f64());
        }
        best
    };
    let mut tree = TreeOracle::new();
    let mut pair = ranksvm::losses::PairOracle::new();
    let t_small = time_oracle(&mut tree, 2000);
    let t_big = time_oracle(&mut tree, 8000);
    let p_small = time_oracle(&mut pair, 2000);
    let p_big = time_oracle(&mut pair, 8000);
    let tree_ratio = t_big / t_small.max(1e-9);
    let pair_ratio = p_big / p_small.max(1e-9);
    assert!(
        pair_ratio > tree_ratio * 1.5,
        "expected quadratic pair scaling ≫ tree scaling: pair {pair_ratio:.1}× vs tree {tree_ratio:.1}×"
    );
}
