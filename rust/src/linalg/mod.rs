//! Dense and sparse linear algebra substrate.
//!
//! The paper's per-iteration linear algebra is two matrix–vector products
//! (`p = Xᵀw`, `a = X(c−d)/N` in the paper's column-example convention;
//! row-example here) plus O(m) vector work. This module provides those in
//! `O(ms)` for sparse and `O(mn)` for dense data.

pub mod dense;
pub mod ops;
pub mod simd;
pub mod sparse;

pub use dense::DenseMatrix;
pub use sparse::{CscMatrix, CsrMatrix, CsrView};
