//! Randomized property tests over the crate's core invariants —
//! the proptest substitute (DESIGN.md §6): seeded xoshiro generation,
//! many iterations, failing inputs printed for replay.

use ranksvm::losses::{
    count_comparable_pairs, PairOracle, RLevelOracle, RankingOracle, SquaredPairOracle, TreeOracle,
};
use ranksvm::metrics;
use ranksvm::rbtree::{FenwickCounter, OsTree, RankCounter, SumTree};
use ranksvm::util::rng::Rng;

/// Run `f` over `iters` seeded cases; on panic, report the failing seed.
fn for_cases(iters: u64, f: impl Fn(&mut Rng) + std::panic::RefUnwindSafe) {
    for seed in 0..iters {
        let result = std::panic::catch_unwind(|| {
            let mut rng = Rng::new(0xABCD_0000 + seed);
            f(&mut rng);
        });
        if let Err(e) = result {
            eprintln!("property failed at seed {seed}");
            std::panic::resume_unwind(e);
        }
    }
}

/// Property: the tree oracle equals the brute-force pair oracle on
/// arbitrary (p, y) — the heart of Theorem 1.
#[test]
fn prop_tree_equals_pair_oracle() {
    for_cases(60, |rng| {
        let m = 1 + rng.below(200);
        let levels = 1 + rng.below(m); // any tie structure
        let y: Vec<f64> = (0..m).map(|_| rng.below(levels) as f64).collect();
        // Include exact ties and near-margin values in p.
        let p: Vec<f64> = (0..m).map(|_| (rng.below(40) as f64) / 7.0 - 3.0).collect();
        let n = count_comparable_pairs(&y) as f64;
        let mut tree = TreeOracle::new();
        let mut pair = PairOracle::new();
        let a = tree.eval(&p, &y, n);
        let b = pair.eval(&p, &y, n);
        assert_eq!(a.coeffs, b.coeffs);
        assert!((a.loss - b.loss).abs() <= 1e-12 * (1.0 + b.loss));
    });
}

/// Property: all three counting structures agree after arbitrary insert
/// sequences (tree plain/dedup, Fenwick over the same universe).
#[test]
fn prop_counters_agree() {
    for_cases(60, |rng| {
        let n_keys = 1 + rng.below(30);
        let universe: Vec<f64> = (0..n_keys).map(|_| rng.normal()).collect();
        let mut plain = OsTree::new();
        let mut dedup = OsTree::new_dedup();
        let mut fen = FenwickCounter::new(&universe);
        let ops = rng.below(300);
        for _ in 0..ops {
            let k = universe[rng.below(n_keys)];
            plain.insert(k);
            dedup.insert(k);
            fen.insert(k);
        }
        plain.check_invariants();
        dedup.check_invariants();
        for &q in &universe {
            let s = RankCounter::count_smaller(&plain, q);
            assert_eq!(s, RankCounter::count_smaller(&dedup, q));
            assert_eq!(s, RankCounter::count_smaller(&fen, q));
            let l = RankCounter::count_larger(&plain, q);
            assert_eq!(l, RankCounter::count_larger(&dedup, q));
            assert_eq!(l, RankCounter::count_larger(&fen, q));
        }
    });
}

/// Property: `Count-Smaller` / `Count-Larger` match naive O(m²)-style
/// counting over the raw insert sequence, for both OsTree variants and
/// the Fenwick counter, under duplicate-heavy and all-distinct regimes,
/// querying both stored keys and keys absent from the tree.
#[test]
fn prop_rank_counts_match_naive_counting() {
    for_cases(60, |rng| {
        let duplicate_heavy = rng.bool(0.5);
        let n_keys = 1 + rng.below(40);
        let universe: Vec<f64> = if duplicate_heavy {
            (0..n_keys).map(|i| (i as f64) * 0.25 - 2.0).collect()
        } else {
            (0..n_keys).map(|_| rng.normal() * 10.0).collect()
        };
        let mut plain = OsTree::new();
        let mut dedup = OsTree::new_dedup();
        let mut fen = FenwickCounter::new(&universe);
        let mut inserted: Vec<f64> = Vec::new();
        let ops = 1 + rng.below(400);
        for _ in 0..ops {
            let k = universe[rng.below(n_keys)];
            plain.insert(k);
            dedup.insert(k);
            fen.insert(k);
            inserted.push(k);
        }
        plain.check_invariants();
        dedup.check_invariants();
        // Queries: every universe key (tie behaviour) plus off-universe
        // probes for the trees (Fenwick requires universe keys).
        for &q in &universe {
            let naive_s = inserted.iter().filter(|&&x| x < q).count() as u64;
            let naive_l = inserted.iter().filter(|&&x| x > q).count() as u64;
            assert_eq!(plain.count_smaller(q), naive_s, "plain smaller({q})");
            assert_eq!(plain.count_larger(q), naive_l, "plain larger({q})");
            assert_eq!(dedup.count_smaller(q), naive_s, "dedup smaller({q})");
            assert_eq!(dedup.count_larger(q), naive_l, "dedup larger({q})");
            assert_eq!(fen.count_smaller(q), naive_s, "fenwick smaller({q})");
            assert_eq!(fen.count_larger(q), naive_l, "fenwick larger({q})");
        }
        for _ in 0..20 {
            let q = rng.range(-15.0, 15.0);
            let naive_s = inserted.iter().filter(|&&x| x < q).count() as u64;
            let naive_l = inserted.iter().filter(|&&x| x > q).count() as u64;
            assert_eq!(plain.count_smaller(q), naive_s);
            assert_eq!(plain.count_larger(q), naive_l);
            assert_eq!(dedup.count_smaller(q), naive_s);
            assert_eq!(dedup.count_larger(q), naive_l);
        }
    });
}

/// Property: the Fenwick counter's internal prefix sums are consistent —
/// for any universe key, smaller + equal + larger partitions the
/// multiset, and counts are monotone along the sorted universe.
#[test]
fn prop_fenwick_prefix_sums_partition() {
    for_cases(40, |rng| {
        let n_keys = 1 + rng.below(30);
        let universe: Vec<f64> = (0..n_keys).map(|i| i as f64).collect();
        let mut fen = FenwickCounter::new(&universe);
        let mut inserted: Vec<f64> = Vec::new();
        for _ in 0..rng.below(300) {
            let k = universe[rng.below(n_keys)];
            fen.insert(k);
            inserted.push(k);
        }
        let mut prev_prefix = 0u64;
        for &q in &universe {
            let eq = inserted.iter().filter(|&&x| x == q).count() as u64;
            assert_eq!(fen.count_smaller(q) + eq + fen.count_larger(q), fen.len());
            // count_smaller along the sorted universe is a nondecreasing
            // prefix-sum sequence.
            assert!(fen.count_smaller(q) >= prev_prefix, "prefix sums not monotone");
            prev_prefix = fen.count_smaller(q) + eq;
        }
    });
}

/// Property: SumTree aggregates (count, Σv, Σv²) over strict key ranges
/// match the naive sweep over the insert sequence, including duplicate
/// keys carrying different auxiliary values.
#[test]
fn prop_sumtree_aggregates_match_naive() {
    for_cases(40, |rng| {
        let n_keys = 1 + rng.below(20); // small universe → many duplicates
        let mut tree = SumTree::new();
        let mut inserted: Vec<(f64, f64)> = Vec::new();
        for _ in 0..1 + rng.below(250) {
            let k = rng.below(n_keys) as f64 * 0.5;
            let v = rng.normal();
            tree.insert(k, v);
            inserted.push((k, v));
        }
        tree.check_invariants();
        for q in 0..n_keys {
            let q = q as f64 * 0.5;
            for larger in [false, true] {
                let agg = if larger { tree.agg_larger(q) } else { tree.agg_smaller(q) };
                let matching: Vec<f64> = inserted
                    .iter()
                    .filter(|(k, _)| if larger { *k > q } else { *k < q })
                    .map(|(_, v)| *v)
                    .collect();
                assert_eq!(agg.count, matching.len() as u64, "count({q}, larger={larger})");
                let sum: f64 = matching.iter().sum();
                let sum_sq: f64 = matching.iter().map(|v| v * v).sum();
                assert!(
                    (agg.sum - sum).abs() < 1e-9 * (1.0 + sum.abs()),
                    "sum({q}): {} vs {sum}",
                    agg.sum
                );
                assert!(
                    (agg.sum_sq - sum_sq).abs() < 1e-9 * (1.0 + sum_sq.abs()),
                    "sum_sq({q}): {} vs {sum_sq}",
                    agg.sum_sq
                );
            }
        }
    });
}

/// Property: the sharded oracle equals the serial tree oracle bit-for-bit
/// on arbitrary (p, y) for any shard count — the engine's core contract,
/// hammered here with the same adversarial generators as the rest of the
/// property suite.
#[test]
fn prop_sharded_equals_tree_bitwise() {
    for_cases(50, |rng| {
        let m = 1 + rng.below(160);
        let levels = 1 + rng.below(m);
        let y: Vec<f64> = (0..m).map(|_| rng.below(levels) as f64).collect();
        let p: Vec<f64> = (0..m).map(|_| (rng.below(40) as f64) / 7.0 - 3.0).collect();
        let n = count_comparable_pairs(&y) as f64;
        let mut tree = TreeOracle::new();
        let expect = tree.eval(&p, &y, n);
        let threads = 1 + rng.below(9);
        let mut sharded = ranksvm::losses::ShardedTreeOracle::new(threads, None, &y);
        let got = sharded.eval(&p, &y, n);
        assert_eq!(got.coeffs, expect.coeffs, "{threads} shards");
        assert_eq!(got.loss.to_bits(), expect.loss.to_bits());
    });
}

/// Property: skewed query-group distributions — Zipf-sampled sizes,
/// occasional giant-group head, interleaved qids — evaluate
/// bit-identically across thread counts *and* task-granularity plans,
/// in grouped and global modes alike. This is the scheduler-facing
/// generalization of `prop_sharded_equals_tree_bitwise`: the work plan
/// (how groups pack into runs, how the sorted order chunks) is part of
/// the randomized input.
#[test]
fn prop_skewed_groups_thread_and_plan_invariant() {
    use ranksvm::losses::{QueryGrouped, ShardedTreeOracle};
    use ranksvm::runtime::WorkerPool;
    use std::sync::Arc;
    for_cases(25, |rng| {
        // Skew in both group count and group sizes.
        let n_groups = 1 + rng.below(50);
        let mut qid: Vec<u64> = Vec::new();
        for g in 0..n_groups {
            let mut sz = 1 + rng.zipf(40, 1.2);
            if g == 0 && rng.bool(0.5) {
                sz += 40 + rng.below(120); // giant head
            }
            qid.extend(std::iter::repeat(g as u64).take(sz));
        }
        rng.shuffle(&mut qid);
        let m = qid.len();
        let y: Vec<f64> = (0..m).map(|_| rng.below(5) as f64).collect();
        let p: Vec<f64> = (0..m).map(|_| (rng.below(40) as f64) / 7.0 - 3.0).collect();
        let n = count_comparable_pairs(&y) as f64;

        let mut serial_grouped = QueryGrouped::new(TreeOracle::new(), &qid, &y);
        let expect_grouped = serial_grouped.eval(&p, &y, serial_grouped.total_pairs());
        let mut serial_global = TreeOracle::new();
        let expect_global = serial_global.eval(&p, &y, n);

        let threads = 1 + rng.below(9);
        let pool = Arc::new(WorkerPool::new(threads));
        let target = 1 + rng.below(100);
        for use_target in [false, true] {
            let (mut grouped, mut global) = if use_target {
                (
                    ShardedTreeOracle::with_run_target(Arc::clone(&pool), Some(&qid), &y, target),
                    ShardedTreeOracle::with_run_target(Arc::clone(&pool), None, &y, target),
                )
            } else {
                (
                    ShardedTreeOracle::with_pool(Arc::clone(&pool), Some(&qid), &y),
                    ShardedTreeOracle::with_pool(Arc::clone(&pool), None, &y),
                )
            };
            let got = grouped.eval(&p, &y, 0.0);
            assert_eq!(
                got.coeffs, expect_grouped.coeffs,
                "grouped: {threads} threads, target {target} ({use_target})"
            );
            assert_eq!(got.loss.to_bits(), expect_grouped.loss.to_bits());
            let got = global.eval(&p, &y, n);
            assert_eq!(
                got.coeffs, expect_global.coeffs,
                "global: {threads} threads, target {target} ({use_target})"
            );
            assert_eq!(got.loss.to_bits(), expect_global.loss.to_bits());
        }
    });
}

/// Property: whole trained models — weights, objective, iteration count
/// — are thread-count-invariant on randomized skewed fixtures (the
/// task plan follows the thread count, so this also randomizes the
/// plan). Few cases: each runs two full BMRM trainings.
#[test]
fn prop_training_thread_invariant_on_skewed_fixtures() {
    use ranksvm::coordinator::{train, Method, TrainConfig};
    use ranksvm::data::synthetic;
    for_cases(5, |rng| {
        let seed = rng.next_u64();
        let grouped = rng.bool(0.5);
        let ds = if grouped {
            let n_groups = 20 + rng.below(60);
            synthetic::zipf_queries(n_groups * 5 + rng.below(100), n_groups, 6, 1.1, seed)
        } else {
            synthetic::cadata_like(150 + rng.below(250), seed)
        };
        let threads_b = 2 + rng.below(7);
        let mut reference: Option<(Vec<f64>, u64, usize)> = None;
        for threads in [1usize, threads_b] {
            let cfg = TrainConfig {
                method: Method::Tree,
                lambda: 0.1,
                epsilon: 1e-3,
                n_threads: threads,
                ..Default::default()
            };
            let out = train(&ds, &cfg).unwrap();
            match &reference {
                None => reference = Some((out.model.w, out.objective.to_bits(), out.iterations)),
                Some((w, obj, iters)) => {
                    assert_eq!(&out.model.w, w, "{} threads vs 1", threads);
                    assert_eq!(out.objective.to_bits(), *obj);
                    assert_eq!(out.iterations, *iters);
                }
            }
        }
    });
}

/// Property: every registered loss is thread-invariant — full training
/// through its registry dispatch produces bit-identical weights and
/// objective at 1, 2, and 8 threads. This is the registry-wide form of
/// the engine contract in docs/DETERMINISM.md: a loss cannot land in
/// [`ranksvm::losses::registry::SPECS`] without inheriting it, because
/// this test iterates the registry rather than a hardcoded list.
#[test]
fn prop_registry_losses_thread_invariant() {
    use ranksvm::coordinator::{train, Method, TrainConfig};
    use ranksvm::data::synthetic;
    for_cases(2, |rng| {
        // Grouped fixture with real-valued labels: both signs appear in
        // every query with overwhelming probability, so the bipartite
        // losses see positives and negatives and the pairwise losses
        // see comparable pairs.
        let ds = synthetic::queries(8, 12, 5, rng.next_u64());
        for &m in Method::all() {
            let mut reference: Option<(Vec<f64>, u64)> = None;
            for threads in [1usize, 2, 8] {
                let cfg = TrainConfig {
                    method: m,
                    lambda: 0.1,
                    epsilon: 1e-2,
                    max_iter: 30,
                    n_threads: threads,
                    ..Default::default()
                };
                let out = train(&ds, &cfg).unwrap();
                match &reference {
                    None => reference = Some((out.model.w, out.objective.to_bits())),
                    Some((w, obj)) => {
                        assert_eq!(&out.model.w, w, "{}: {threads} threads vs 1", m.name());
                        assert_eq!(out.objective.to_bits(), *obj, "{}: objective", m.name());
                    }
                }
            }
        }
    });
}

/// Property: every registered loss is zero-safe — on labels that make
/// the risk vacuous (all tied: no comparable pairs for the pairwise
/// family, a single class for TopPush), the oracle returns exactly
/// zero loss and all-zero coefficients, grouped or not, at any thread
/// count. Dispatched through the registry so new entries are held to
/// the contract automatically.
#[test]
fn prop_registry_losses_zero_safe() {
    use ranksvm::coordinator::Method;
    use ranksvm::data::Dataset;
    use ranksvm::linalg::CsrMatrix;
    use ranksvm::losses::registry::{NewtonKind, OracleCtx};
    use ranksvm::losses::{GroupIndex, SquaredTreeOracle};
    use ranksvm::runtime::WorkerPool;
    use std::sync::Arc;
    for_cases(12, |rng| {
        let m = 1 + rng.below(60);
        let tied = if rng.bool(0.5) { 1.0 } else { -2.0 }; // all-pos or all-neg
        let y = vec![tied; m];
        let qid: Option<Vec<u64>> =
            rng.bool(0.5).then(|| (0..m).map(|i| (i as u64) % 5).collect());
        let triplets: Vec<(usize, usize, f64)> = (0..m).map(|i| (i, i % 4, rng.normal())).collect();
        let ds = Dataset::new(CsrMatrix::from_triplets(m, 4, triplets), y, qid, "tied");
        let p: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
        let pool = Arc::new(WorkerPool::new(1 + rng.below(8)));
        let index = ds.qid.as_ref().map(|q| Arc::new(GroupIndex::build(q, &ds.y)));
        for &meth in Method::all() {
            let spec = meth.spec();
            let out = if let Some(kind) = spec.newton {
                match kind {
                    NewtonKind::MaterializedPairs => {
                        SquaredPairOracle::new(&ds.y).eval_full(&p, 0.0)
                    }
                    NewtonKind::SumTree => SquaredTreeOracle::new().eval_full(&p, &ds.y, 0.0),
                }
            } else {
                let ctor = spec.bmrm.expect("BMRM loss must carry a constructor");
                let mut oracle = ctor(OracleCtx { ds: &ds, index: index.clone(), pool: &pool });
                oracle.eval(&p, &ds.y, 0.0)
            };
            assert!(out.loss == 0.0, "{}: loss {} on vacuous labels", spec.name, out.loss);
            assert_eq!(out.coeffs.len(), m, "{}", spec.name);
            assert!(
                out.coeffs.iter().all(|c| *c == 0.0),
                "{}: nonzero coefficients on vacuous labels",
                spec.name
            );
        }
    });
}

/// Property: subgradient validity — for random w, w', the first-order
/// lower bound R(w') ≥ R(w) + ⟨w' − w, ∇R(w)⟩ holds (convexity + correct
/// subgradient), exercised through score space with X = I.
#[test]
fn prop_subgradient_lower_bounds_risk() {
    for_cases(40, |rng| {
        let m = 2 + rng.below(60);
        let y: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
        let n = count_comparable_pairs(&y) as f64;
        if n == 0.0 {
            return;
        }
        let p1: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
        let p2: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
        let mut tree = TreeOracle::new();
        let at1 = tree.eval(&p1, &y, n);
        let at2 = tree.eval(&p2, &y, n);
        let inner: f64 = at1
            .coeffs
            .iter()
            .zip(p2.iter().zip(&p1))
            .map(|(g, (b, a))| g * (b - a))
            .sum();
        assert!(
            at2.loss + 1e-9 >= at1.loss + inner,
            "subgradient inequality violated: {} < {} + {}",
            at2.loss,
            at1.loss,
            inner
        );
    });
}

/// Property: the same convexity bound for the squared hinge.
#[test]
fn prop_squared_subgradient_lower_bounds() {
    for_cases(30, |rng| {
        let m = 2 + rng.below(40);
        let y: Vec<f64> = (0..m).map(|_| rng.below(5) as f64).collect();
        let n = count_comparable_pairs(&y) as f64;
        if n == 0.0 {
            return;
        }
        let mut o = SquaredPairOracle::new(&y);
        let p1: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
        let p2: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
        let a1 = o.eval_full(&p1, n);
        let a2 = o.eval_full(&p2, n);
        let inner: f64 = a1
            .coeffs
            .iter()
            .zip(p2.iter().zip(&p1))
            .map(|(g, (b, a))| g * (b - a))
            .sum();
        assert!(a2.loss + 1e-9 >= a1.loss + inner);
    });
}

/// Property: pairwise error is invariant under strictly monotone
/// transformations of the predictions (ranking-only criterion).
#[test]
fn prop_metric_monotone_invariance() {
    for_cases(40, |rng| {
        let m = 2 + rng.below(80);
        let y: Vec<f64> = (0..m).map(|_| rng.below(6) as f64).collect();
        let p: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
        let e1 = metrics::pairwise_error(&p, &y);
        let p2: Vec<f64> = p.iter().map(|v| 3.0 * v + 7.0).collect(); // affine
        let p3: Vec<f64> = p.iter().map(|v| v.exp()).collect(); // nonlinear monotone
        assert!((metrics::pairwise_error(&p2, &y) - e1).abs() < 1e-12);
        assert!((metrics::pairwise_error(&p3, &y) - e1).abs() < 1e-12);
    });
}

/// Property: r-level oracle equals the tree oracle across tie regimes
/// including the degenerate single-level case.
#[test]
fn prop_rlevel_equals_tree() {
    for_cases(40, |rng| {
        let m = 1 + rng.below(120);
        let r = 1 + rng.below(12);
        let y: Vec<f64> = (0..m).map(|_| rng.below(r) as f64).collect();
        let p: Vec<f64> = (0..m).map(|_| rng.normal() * 2.0).collect();
        let n = count_comparable_pairs(&y) as f64;
        let mut a = RLevelOracle::new();
        let mut b = TreeOracle::new();
        let oa = a.eval(&p, &y, n);
        let ob = b.eval(&p, &y, n);
        assert_eq!(oa.coeffs, ob.coeffs);
    });
}

/// Property: registry-wide CV — `cross_validate` under every registered
/// loss survives degenerate folds: a fold holding a single query, more
/// folds than distinct queries (an empty test fold, and train splits
/// missing whole queries), all-tied labels (zero comparable pairs for
/// the pairwise family, one class for TopPush), and per-query-constant
/// labels (zero *effective* pairs in every group). No loss may panic,
/// and every reported metric must come back finite — degenerate groups
/// contribute zero, never NaN, so the JSON path report stays
/// well-formed. Iterates the registry, not a hardcoded list: a new
/// loss inherits the obligation by existing.
#[test]
fn prop_registry_cv_survives_degenerate_folds() {
    use ranksvm::coordinator::{cross_validate, Method, TrainConfig};
    use ranksvm::data::Dataset;
    use ranksvm::linalg::CsrMatrix;
    for_cases(2, |rng| {
        let m = 10 + rng.below(14);
        let mut fixtures: Vec<(Dataset, &str)> = Vec::new();
        let x = {
            let mut cols: Vec<f64> = Vec::new();
            for _ in 0..m {
                cols.push(rng.normal());
            }
            move || -> CsrMatrix {
                let triplets: Vec<(usize, usize, f64)> =
                    (0..m).map(|i| (i, i % 3, cols[i])).collect();
                CsrMatrix::from_triplets(m, 3, triplets)
            }
        };
        // 2 queries (one a singleton) under 3 folds: a single-query
        // fold, an empty test fold, and train splits losing a query.
        let qid: Vec<u64> = (0..m).map(|i| if i == 0 { 7 } else { 3 }).collect();
        let y: Vec<f64> = (0..m).map(|_| rng.below(3) as f64).collect();
        fixtures.push((Dataset::new(x(), y, Some(qid), "deg"), "single-query-fold"));
        // All-tied labels: zero comparable pairs / one TopPush class.
        let tied = vec![1.0; m];
        fixtures.push((Dataset::new(x(), tied.clone(), None, "deg"), "all-tied-global"));
        let qid: Vec<u64> = (0..m).map(|i| (i as u64) % 4).collect();
        fixtures.push((Dataset::new(x(), tied, Some(qid.clone()), "deg"), "all-tied-grouped"));
        // Labels constant within each query: pairs exist globally but
        // every group is vacuous (zero effective pairs).
        let y: Vec<f64> = qid.iter().map(|&q| q as f64).collect();
        fixtures.push((Dataset::new(x(), y, Some(qid), "deg"), "zero-effective-pairs"));
        for (ds, tag) in &fixtures {
            for &meth in Method::all() {
                let base = TrainConfig {
                    method: meth,
                    epsilon: 1e-2,
                    max_iter: 15,
                    ..Default::default()
                };
                let points = cross_validate(ds, &base, &[1e-2, 1e-1], 3, rng.next_u64())
                    .unwrap_or_else(|e| panic!("{} on {tag}: {e}", meth.name()));
                assert_eq!(points.len(), 2, "{} on {tag}", meth.name());
                for p in &points {
                    for v in [p.mean_error, p.mean_auc, p.mean_precision_at_k] {
                        assert!(
                            v.is_finite(),
                            "{} on {tag}: non-finite metric {v}",
                            meth.name()
                        );
                    }
                }
            }
        }
    });
}

/// Property: loss is translation-invariant in scores (only differences
/// p_i − p_j enter eq. 4), and scales the subgradient coherently.
#[test]
fn prop_loss_translation_invariant() {
    for_cases(40, |rng| {
        let m = 2 + rng.below(60);
        let y: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
        let p: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
        let n = count_comparable_pairs(&y) as f64;
        let shift = rng.range(-5.0, 5.0);
        let p_shifted: Vec<f64> = p.iter().map(|v| v + shift).collect();
        let mut tree = TreeOracle::new();
        let a = tree.eval(&p, &y, n);
        let b = tree.eval(&p_shifted, &y, n);
        assert!((a.loss - b.loss).abs() < 1e-9 * (1.0 + a.loss));
        assert_eq!(a.coeffs, b.coeffs);
    });
}
