//! Process-wide metrics registry (docs/OBSERVABILITY.md "Metric
//! registry").
//!
//! All primitives are lock-free and const-constructible so they can live
//! in statics and be bumped from worker threads with `Relaxed` atomics.
//! Observing a metric never branches on its value — the registry is
//! write-mostly bookkeeping whose only reader is the exposition path
//! ([`render_prometheus`]) and the `info` counter snapshot.
//!
//! Histogram buckets are **fixed at compile time** and documented
//! normatively in docs/OBSERVABILITY.md (pinned by `tests/docs_spec.rs`);
//! bucket assignment is a binary search over the upper-bound table,
//! cross-checked against a brute-force linear scan in `tests/obs.rs`.

use std::sync::atomic::{AtomicU64, Ordering};

/// Monotonic counter. `Relaxed` everywhere: per-metric totals are exact
/// (atomic RMW) but cross-metric snapshots are only loosely consistent,
/// which is all exposition needs.
pub struct Counter(AtomicU64);

impl Counter {
    pub const fn new() -> Self {
        Counter(AtomicU64::new(0))
    }
    #[inline]
    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }
    #[inline]
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

impl Default for Counter {
    fn default() -> Self {
        Self::new()
    }
}

/// Last-write-wins gauge (e.g. the currently served model version).
pub struct Gauge(AtomicU64);

impl Gauge {
    pub const fn new() -> Self {
        Gauge(AtomicU64::new(0))
    }
    pub fn set(&self, v: u64) {
        self.0.store(v, Ordering::Relaxed);
    }
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

impl Default for Gauge {
    fn default() -> Self {
        Self::new()
    }
}

/// Upper bound on `bounds.len()` for any [`Histogram`] (one slot per
/// finite bound plus the `+Inf` overflow slot).
pub const MAX_HISTOGRAM_BOUNDS: usize = 23;

/// Fixed-bucket histogram over `u64` observations.
///
/// `bounds` are *inclusive* upper bounds in ascending order; an
/// observation `v` lands in the first bucket with `v <= bound`, or the
/// overflow (`+Inf`) bucket past the last bound. Bucket counts and the
/// running sum are independent relaxed atomics, so a concurrent render
/// sees a loosely consistent snapshot (counts never decrease).
pub struct Histogram {
    bounds: &'static [u64],
    buckets: [AtomicU64; MAX_HISTOGRAM_BOUNDS + 1],
    sum: AtomicU64,
}

impl Histogram {
    /// `bounds` must be non-empty, strictly ascending, and at most
    /// [`MAX_HISTOGRAM_BOUNDS`] long (checked at compile time for the
    /// registry statics — `new` is const and panics in const eval).
    pub const fn new(bounds: &'static [u64]) -> Self {
        assert!(!bounds.is_empty() && bounds.len() <= MAX_HISTOGRAM_BOUNDS);
        let mut i = 1;
        while i < bounds.len() {
            assert!(bounds[i - 1] < bounds[i], "histogram bounds must ascend");
            i += 1;
        }
        Histogram {
            bounds,
            buckets: [const { AtomicU64::new(0) }; MAX_HISTOGRAM_BOUNDS + 1],
            sum: AtomicU64::new(0),
        }
    }

    /// Index of the bucket receiving `v`: binary search for the first
    /// bound `>= v` (`partition_point` on `bound < v`), overflow slot if
    /// none. `tests/obs.rs` checks this against a linear scan.
    #[inline]
    pub fn bucket_index(&self, v: u64) -> usize {
        self.bounds.partition_point(|&b| b < v)
    }

    #[inline]
    pub fn observe(&self, v: u64) {
        self.buckets[self.bucket_index(v)].fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
    }

    pub fn bounds(&self) -> &'static [u64] {
        self.bounds
    }

    /// Per-bucket counts (`bounds.len() + 1` entries; last is overflow).
    pub fn bucket_counts(&self) -> Vec<u64> {
        (0..=self.bounds.len()).map(|i| self.buckets[i].load(Ordering::Relaxed)).collect()
    }

    /// Total observations (sum of bucket counts — one consistent read
    /// set, so cumulative `le` lines in the render never regress).
    pub fn count(&self) -> u64 {
        self.bucket_counts().iter().sum()
    }

    pub fn sum(&self) -> u64 {
        self.sum.load(Ordering::Relaxed)
    }
}

/// Request-latency bucket upper bounds, **microseconds**
/// (docs/OBSERVABILITY.md "Histogram buckets").
pub static LATENCY_BUCKETS_US: &[u64] =
    &[50, 100, 250, 500, 1_000, 2_500, 5_000, 10_000, 25_000, 50_000, 100_000, 250_000, 1_000_000];

/// Batch-size bucket upper bounds, **requests per batch** (powers of
/// four up to the protocol cap `MAX_BATCH = 65536`).
pub static BATCH_SIZE_BUCKETS: &[u64] = &[1, 4, 16, 64, 256, 1_024, 4_096, 16_384, 65_536];

// ----------------------------------------------------------------- pool
/// Tasks executed by pool workers (always-on successor of the old
/// `pool-stats` feature counters; mirrors `WorkerPool::stats`).
pub static POOL_TASKS: Counter = Counter::new();
/// Tasks that ran on a worker other than the one they were seeded to.
pub static POOL_STOLEN: Counter = Counter::new();
/// Batches submitted to any pool (`run_batch` calls).
pub static POOL_BATCHES: Counter = Counter::new();
/// Tasks run inline on the caller (pool bypassed: 1 thread or tiny batch).
pub static POOL_INLINE_TASKS: Counter = Counter::new();

// --------------------------------------------------------------- kernels
/// Kernel passes (whole matvec / gradient-scatter sweeps) executed on
/// the scalar reference path (`linalg::simd` dispatch).
pub static KERNEL_SCALAR_PASSES: Counter = Counter::new();
/// Kernel passes executed on the vectorized (AVX2) path.
pub static KERNEL_SIMD_PASSES: Counter = Counter::new();

// ------------------------------------------------------------ converter
/// Rows written by the store converter.
pub static CONVERT_ROWS: Counter = Counter::new();
/// Bytes of pstore output written by the converter.
pub static CONVERT_BYTES: Counter = Counter::new();
/// Shards encoded by the converter.
pub static CONVERT_SHARDS: Counter = Counter::new();

// ---------------------------------------------------------------- serve
/// Requests answered by the serve engine (one per protocol line).
pub static SERVE_REQUESTS: Counter = Counter::new();
/// Batches executed by the serve engine.
pub static SERVE_BATCHES: Counter = Counter::new();
/// Completed hot swaps / reloads.
pub static SERVE_SWAPS: Counter = Counter::new();
/// Requests answered with a structured error line.
pub static SERVE_ERRORS: Counter = Counter::new();
/// Version stamp of the currently served model epoch.
pub static SERVE_MODEL_VERSION: Gauge = Gauge::new();
/// Wall-clock latency of each served request, microseconds.
pub static SERVE_REQUEST_LATENCY_US: Histogram = Histogram::new(LATENCY_BUCKETS_US);
/// Requests per executed batch.
pub static SERVE_BATCH_SIZE: Histogram = Histogram::new(BATCH_SIZE_BUCKETS);

// ------------------------------------------------------------ modelsel
/// CV sweeps started (`cv_serial` / `cv_sweep` engine runs).
pub static CV_SWEEPS: Counter = Counter::new();
/// (fold, λ) cells processed by CV engines.
pub static CV_FOLD_TRAININGS: Counter = Counter::new();
/// BMRM iterations spent inside CV fold trainings — warm-started paths
/// grow this slower than cold ones (tests/modelsel.rs differential).
pub static CV_BMRM_ITERS: Counter = Counter::new();

/// What a registry entry points at.
pub enum Kind {
    Counter(&'static Counter),
    Gauge(&'static Gauge),
    Histogram(&'static Histogram),
}

impl Kind {
    /// Prometheus `# TYPE` word for this metric.
    pub fn type_name(&self) -> &'static str {
        match self {
            Kind::Counter(_) => "counter",
            Kind::Gauge(_) => "gauge",
            Kind::Histogram(_) => "histogram",
        }
    }
}

/// One exported metric: wire name, unit, help text, storage.
pub struct MetricDef {
    pub name: &'static str,
    pub unit: &'static str,
    pub help: &'static str,
    pub kind: Kind,
}

/// Every exported metric, in exposition order. The table in
/// docs/OBSERVABILITY.md mirrors this slice row-by-row (pinned by
/// `tests/docs_spec.rs`).
pub static REGISTRY: &[MetricDef] = &[
    MetricDef {
        name: "ranksvm_pool_tasks_total",
        unit: "tasks",
        help: "tasks executed by worker-pool threads",
        kind: Kind::Counter(&POOL_TASKS),
    },
    MetricDef {
        name: "ranksvm_pool_stolen_total",
        unit: "tasks",
        help: "pool tasks that ran on a non-owner worker (work stealing)",
        kind: Kind::Counter(&POOL_STOLEN),
    },
    MetricDef {
        name: "ranksvm_pool_batches_total",
        unit: "batches",
        help: "task batches submitted to any worker pool",
        kind: Kind::Counter(&POOL_BATCHES),
    },
    MetricDef {
        name: "ranksvm_pool_inline_tasks_total",
        unit: "tasks",
        help: "tasks run inline on the caller (pool bypassed)",
        kind: Kind::Counter(&POOL_INLINE_TASKS),
    },
    MetricDef {
        name: "ranksvm_kernel_scalar_passes_total",
        unit: "passes",
        help: "kernel passes executed on the scalar reference path",
        kind: Kind::Counter(&KERNEL_SCALAR_PASSES),
    },
    MetricDef {
        name: "ranksvm_kernel_simd_passes_total",
        unit: "passes",
        help: "kernel passes executed on the vectorized (AVX2) path",
        kind: Kind::Counter(&KERNEL_SIMD_PASSES),
    },
    MetricDef {
        name: "ranksvm_convert_rows_total",
        unit: "rows",
        help: "rows written by the pstore converter",
        kind: Kind::Counter(&CONVERT_ROWS),
    },
    MetricDef {
        name: "ranksvm_convert_bytes_total",
        unit: "bytes",
        help: "pstore output bytes written by the converter",
        kind: Kind::Counter(&CONVERT_BYTES),
    },
    MetricDef {
        name: "ranksvm_convert_shards_total",
        unit: "shards",
        help: "shards encoded by the converter",
        kind: Kind::Counter(&CONVERT_SHARDS),
    },
    MetricDef {
        name: "ranksvm_serve_requests_total",
        unit: "requests",
        help: "requests answered by the serve engine",
        kind: Kind::Counter(&SERVE_REQUESTS),
    },
    MetricDef {
        name: "ranksvm_serve_batches_total",
        unit: "batches",
        help: "batches executed by the serve engine",
        kind: Kind::Counter(&SERVE_BATCHES),
    },
    MetricDef {
        name: "ranksvm_serve_swaps_total",
        unit: "swaps",
        help: "completed model hot swaps / reloads",
        kind: Kind::Counter(&SERVE_SWAPS),
    },
    MetricDef {
        name: "ranksvm_serve_errors_total",
        unit: "errors",
        help: "requests answered with a structured error",
        kind: Kind::Counter(&SERVE_ERRORS),
    },
    MetricDef {
        name: "ranksvm_serve_model_version",
        unit: "version",
        help: "version stamp of the served model epoch",
        kind: Kind::Gauge(&SERVE_MODEL_VERSION),
    },
    MetricDef {
        name: "ranksvm_serve_request_latency_us",
        unit: "us",
        help: "wall-clock latency per served request",
        kind: Kind::Histogram(&SERVE_REQUEST_LATENCY_US),
    },
    MetricDef {
        name: "ranksvm_serve_batch_size",
        unit: "requests",
        help: "requests per executed serve batch",
        kind: Kind::Histogram(&SERVE_BATCH_SIZE),
    },
    MetricDef {
        name: "ranksvm_cv_sweeps_total",
        unit: "sweeps",
        help: "cross-validation sweeps started",
        kind: Kind::Counter(&CV_SWEEPS),
    },
    MetricDef {
        name: "ranksvm_cv_fold_trainings_total",
        unit: "trainings",
        help: "(fold, lambda) cells processed by CV engines",
        kind: Kind::Counter(&CV_FOLD_TRAININGS),
    },
    MetricDef {
        name: "ranksvm_cv_bmrm_iters_total",
        unit: "iterations",
        help: "BMRM iterations spent inside CV fold trainings",
        kind: Kind::Counter(&CV_BMRM_ITERS),
    },
];

/// Render the whole registry as Prometheus-style text. Deterministic in
/// structure (registry order, fixed `le` labels); terminated by a
/// `# EOF` line so the serve newline protocol can frame the one
/// multi-line response it ever sends.
pub fn render_prometheus() -> String {
    use std::fmt::Write;
    let mut out = String::new();
    for m in REGISTRY {
        let _ = writeln!(out, "# HELP {} {}", m.name, m.help);
        let _ = writeln!(out, "# TYPE {} {}", m.name, m.kind.type_name());
        match &m.kind {
            Kind::Counter(c) => {
                let _ = writeln!(out, "{} {}", m.name, c.get());
            }
            Kind::Gauge(g) => {
                let _ = writeln!(out, "{} {}", m.name, g.get());
            }
            Kind::Histogram(h) => {
                let counts = h.bucket_counts();
                let mut cum = 0u64;
                for (i, b) in h.bounds().iter().enumerate() {
                    cum += counts[i];
                    let _ = writeln!(out, "{}_bucket{{le=\"{}\"}} {}", m.name, b, cum);
                }
                cum += counts[h.bounds().len()];
                let _ = writeln!(out, "{}_bucket{{le=\"+Inf\"}} {}", m.name, cum);
                let _ = writeln!(out, "{}_sum {}", m.name, h.sum());
                let _ = writeln!(out, "{}_count {}", m.name, cum);
            }
        }
    }
    out.push_str("# EOF\n");
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_and_gauge_roundtrip() {
        let c = Counter::new();
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
        let g = Gauge::new();
        g.set(7);
        g.set(3);
        assert_eq!(g.get(), 3);
    }

    #[test]
    fn histogram_buckets_edges() {
        let h = Histogram::new(&[10, 20, 40]);
        for v in [0, 10, 11, 20, 40, 41, u64::MAX] {
            h.observe(v);
        }
        // 0,10 → le=10; 11,20 → le=20; 40 → le=40; 41,MAX → +Inf.
        assert_eq!(h.bucket_counts(), vec![2, 2, 1, 2]);
        assert_eq!(h.count(), 7);
    }

    #[test]
    fn render_is_framed_and_names_every_metric() {
        let text = render_prometheus();
        assert!(text.ends_with("# EOF\n"));
        for m in REGISTRY {
            let ty = format!("# TYPE {} {}", m.name, m.kind.type_name());
            assert!(text.contains(&ty), "{}", m.name);
        }
        // Histogram renders cumulative buckets with a +Inf terminator
        // and _sum/_count lines.
        assert!(text.contains("ranksvm_serve_request_latency_us_bucket{le=\"50\"}"));
        assert!(text.contains("ranksvm_serve_request_latency_us_bucket{le=\"+Inf\"}"));
        assert!(text.contains("ranksvm_serve_request_latency_us_sum"));
        assert!(text.contains("ranksvm_serve_request_latency_us_count"));
    }

    #[test]
    fn registry_names_are_unique_and_prefixed() {
        let mut names: Vec<_> = REGISTRY.iter().map(|m| m.name).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), REGISTRY.len());
        for m in REGISTRY {
            assert!(m.name.starts_with("ranksvm_"), "{}", m.name);
        }
    }
}
