//! Ablation B — optimizer-side design choices:
//!
//! 1. OCAS-style line search (paper §6 future work) on/off: iterations
//!    to convergence and wall clock;
//! 2. inner-QP tolerance: oracle calls dominate, so looser QP solves
//!    should not change iteration counts much (the paper's observation
//!    that the QP cost is "insignificant" at scale);
//! 3. ε sweep: convergence is O(1/ελ) — iterations should scale ~1/ε.

mod common;

use common::{fmt_secs, header, record};
use ranksvm::bmrm::{optimize, BmrmConfig};
use ranksvm::compute::NativeBackend;
use ranksvm::coordinator::trainer::DatasetOracle;
use ranksvm::data::synthetic;
use ranksvm::losses::{count_comparable_pairs, TreeOracle};
use ranksvm::util::json::Json;

fn main() {
    let ds = synthetic::cadata_like(8000, 400);
    let n_pairs = count_comparable_pairs(&ds.y) as f64;
    let lambda = 0.1;

    header("Ablation B1: line search on/off (cadata-like m=8000, λ=0.1)");
    println!("{:>12} {:>8} {:>12} {:>14}", "line-search", "iters", "objective", "time");
    for ls in [false, true] {
        let mut oracle = DatasetOracle::new(
            &ds,
            Box::new(NativeBackend::new()),
            Box::new(TreeOracle::new()),
            n_pairs,
        );
        let cfg = BmrmConfig { lambda, epsilon: 1e-3, line_search: ls, ..Default::default() };
        let t = std::time::Instant::now();
        let res = optimize(&mut oracle, &cfg, vec![0.0; ds.dim()]);
        let secs = t.elapsed().as_secs_f64();
        println!(
            "{:>12} {:>8} {:>12.6} {:>14}",
            ls,
            res.iterations,
            res.objective,
            fmt_secs(secs)
        );
        record(
            "ablation_bmrm",
            Json::obj(vec![
                ("experiment", "line_search".into()),
                ("line_search", ls.into()),
                ("iterations", res.iterations.into()),
                ("objective", res.objective.into()),
                ("secs", secs.into()),
            ]),
        );
    }

    header("Ablation B2: inner QP tolerance");
    println!("{:>10} {:>8} {:>12} {:>14}", "qp_tol", "iters", "objective", "time");
    for qp_tol in [1e-3, 1e-6, 1e-9, 1e-12] {
        let mut oracle = DatasetOracle::new(
            &ds,
            Box::new(NativeBackend::new()),
            Box::new(TreeOracle::new()),
            n_pairs,
        );
        let cfg = BmrmConfig { lambda, epsilon: 1e-3, qp_tol, ..Default::default() };
        let t = std::time::Instant::now();
        let res = optimize(&mut oracle, &cfg, vec![0.0; ds.dim()]);
        let secs = t.elapsed().as_secs_f64();
        println!(
            "{qp_tol:>10.0e} {:>8} {:>12.6} {:>14}",
            res.iterations,
            res.objective,
            fmt_secs(secs)
        );
        record(
            "ablation_bmrm",
            Json::obj(vec![
                ("experiment", "qp_tol".into()),
                ("qp_tol", qp_tol.into()),
                ("iterations", res.iterations.into()),
                ("secs", secs.into()),
            ]),
        );
    }

    header("Ablation B3: ε sweep (iterations ≈ O(1/ελ), Smola et al. 2007)");
    println!("{:>10} {:>8} {:>12}", "epsilon", "iters", "gap");
    for epsilon in [1e-1, 1e-2, 1e-3, 1e-4] {
        let mut oracle = DatasetOracle::new(
            &ds,
            Box::new(NativeBackend::new()),
            Box::new(TreeOracle::new()),
            n_pairs,
        );
        let cfg = BmrmConfig { lambda, epsilon, ..Default::default() };
        let res = optimize(&mut oracle, &cfg, vec![0.0; ds.dim()]);
        println!("{epsilon:>10.0e} {:>8} {:>12.2e}", res.iterations, res.gap);
        record(
            "ablation_bmrm",
            Json::obj(vec![
                ("experiment", "epsilon".into()),
                ("epsilon", epsilon.into()),
                ("iterations", res.iterations.into()),
                ("gap", res.gap.into()),
            ]),
        );
    }
    println!("\nExpected: B1 line search reduces iterations at equal objective;");
    println!("B2 flat (QP cost negligible); B3 iterations grow as ε shrinks.");
}
