//! PJRT-backed [`ComputeBackend`]: compiles the AOT HLO artifacts on the
//! PJRT CPU client and executes them from the training hot path.
//!
//! Artifacts come in fixed shapes (AOT requires static shapes), so the
//! [`XlaBackend`] pads each dataset to row tiles of `TM` and features to
//! the nearest available `N`, then accumulates per-tile results.
//! Arithmetic is f32 on the XLA side (MXU-native on real TPUs); the
//! trainer's f64 vectors are converted at the boundary.

use super::manifest::{Manifest, ManifestEntry};
use crate::compute::ComputeBackend;
use crate::linalg::CsrView;
use anyhow::{anyhow, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// Shared PJRT client + compiled-executable cache over an artifact
/// directory.
pub struct XlaRuntime {
    client: xla::PjRtClient,
    dir: PathBuf,
    manifest: Manifest,
    cache: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl XlaRuntime {
    /// Open the artifact directory (must contain `manifest.txt`).
    pub fn open(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = Manifest::load(dir.join("manifest.txt")).with_context(|| {
            format!("loading manifest from {} — run `make artifacts`", dir.display())
        })?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT CPU client: {e:?}"))?;
        Ok(XlaRuntime { client, dir, manifest, cache: HashMap::new() })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Compile (or fetch from cache) the executable for a manifest entry.
    pub fn executable(&mut self, entry: &ManifestEntry) -> Result<&xla::PjRtLoadedExecutable> {
        if !self.cache.contains_key(&entry.file) {
            let path = self.dir.join(&entry.file);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("non-utf8 artifact path")?,
            )
            .map_err(|e| anyhow!("parsing {}: {e:?}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| anyhow!("compiling {}: {e:?}", path.display()))?;
            self.cache.insert(entry.file.clone(), exe);
        }
        Ok(self.cache.get(&entry.file).unwrap())
    }

    /// Execute a single-output artifact on f32 input literals; returns the
    /// flat f32 output (tuple-unwrapped).
    pub fn run1<L: std::borrow::Borrow<xla::Literal>>(
        &mut self,
        entry: &ManifestEntry,
        inputs: &[L],
    ) -> Result<Vec<f32>> {
        let exe = self.executable(entry)?;
        let result = exe
            .execute(inputs)
            .map_err(|e| anyhow!("executing {}: {e:?}", entry.file))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch result: {e:?}"))?;
        let out = result.to_tuple1().map_err(|e| anyhow!("untuple: {e:?}"))?;
        out.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e:?}"))
    }

    /// Execute a two-output artifact; returns both flat f32 outputs.
    pub fn run2<L: std::borrow::Borrow<xla::Literal>>(
        &mut self,
        entry: &ManifestEntry,
        inputs: &[L],
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        let exe = self.executable(entry)?;
        let result = exe
            .execute(inputs)
            .map_err(|e| anyhow!("executing {}: {e:?}", entry.file))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch result: {e:?}"))?;
        let (a, b) = result.to_tuple2().map_err(|e| anyhow!("untuple2: {e:?}"))?;
        Ok((
            a.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e:?}"))?,
            b.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e:?}"))?,
        ))
    }
}

/// f32 literal of the given shape from a slice.
pub fn literal_2d(data: &[f32], rows: usize, cols: usize) -> Result<xla::Literal> {
    assert_eq!(data.len(), rows * cols);
    xla::Literal::vec1(data)
        .reshape(&[rows as i64, cols as i64])
        .map_err(|e| anyhow!("reshape: {e:?}"))
}

pub fn literal_1d(data: &[f32]) -> xla::Literal {
    xla::Literal::vec1(data)
}

/// Dense, tile-padded copy of a dataset's feature matrix, resident as
/// per-tile literals so the per-iteration hot path uploads only the
/// small vectors.
struct TiledData {
    tiles: Vec<xla::Literal>, // each (tm × n_pad) f32
    m: usize,
    tm: usize,
    n_pad: usize,
}

/// [`ComputeBackend`] that runs the score matvec and gradient assembly
/// through the AOT XLA executables. Dense-data oriented: each row tile is
/// materialized densely (sparse corpora should use the native backend —
/// DESIGN.md §2).
pub struct XlaBackend {
    rt: XlaRuntime,
    scores_entry: Option<ManifestEntry>,
    grad_entry: Option<ManifestEntry>,
    data: Option<TiledData>,
}

impl XlaBackend {
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let rt = XlaRuntime::open(dir)?;
        Ok(XlaBackend { rt, scores_entry: None, grad_entry: None, data: None })
    }

    /// Runtime handle (for tests / the pair-count kernel round trip).
    pub fn runtime(&mut self) -> &mut XlaRuntime {
        &mut self.rt
    }

    fn tile_data(&mut self, x: CsrView<'_>) -> Result<()> {
        let n = x.cols();
        // Smallest artifact feature width that fits this dataset; rows
        // pad to the artifact's tile height.
        let entry = self
            .rt
            .manifest()
            .best_for("scores", n)
            .ok_or_else(|| anyhow!("no scores artifact with n ≥ {n}; regenerate artifacts"))?
            .clone();
        let grad_entry = self
            .rt
            .manifest()
            .best_for("grad", n)
            .ok_or_else(|| anyhow!("no grad artifact with n ≥ {n}"))?
            .clone();
        anyhow::ensure!(
            grad_entry.m == entry.m && grad_entry.n == entry.n,
            "scores/grad artifact shapes diverge"
        );
        let (tm, n_pad) = (entry.m, entry.n);
        let m = x.rows();
        let n_tiles = m.div_ceil(tm).max(1);
        let mut tiles = Vec::with_capacity(n_tiles);
        let mut buf = vec![0.0f32; tm * n_pad];
        for t in 0..n_tiles {
            buf.iter_mut().for_each(|v| *v = 0.0);
            let lo = t * tm;
            let hi = ((t + 1) * tm).min(m);
            for i in lo..hi {
                let (idx, val) = x.row(i);
                let row_off = (i - lo) * n_pad;
                for (&j, &v) in idx.iter().zip(val) {
                    buf[row_off + j as usize] = v as f32;
                }
            }
            tiles.push(literal_2d(&buf, tm, n_pad)?);
        }
        self.data = Some(TiledData { tiles, m, tm, n_pad });
        self.scores_entry = Some(entry);
        self.grad_entry = Some(grad_entry);
        Ok(())
    }
}

impl ComputeBackend for XlaBackend {
    fn name(&self) -> &'static str {
        "xla"
    }

    fn prepare(&mut self, x: CsrView<'_>) {
        self.tile_data(x).expect("XLA backend prepare failed");
    }

    fn scores(&mut self, x: CsrView<'_>, w: &[f64]) -> Vec<f64> {
        if self.data.is_none() {
            self.prepare(x);
        }
        let data = self.data.as_ref().unwrap();
        assert_eq!(data.m, x.rows(), "backend prepared for a different dataset");
        let entry = self.scores_entry.as_ref().unwrap();
        let mut w32 = vec![0.0f32; data.n_pad];
        for (dst, &src) in w32.iter_mut().zip(w) {
            *dst = src as f32;
        }
        let w_lit = literal_1d(&w32);
        let mut out = Vec::with_capacity(data.m);
        for (t, tile) in data.tiles.iter().enumerate() {
            // Borrow-based execute: the resident tile literal is not cloned.
            let args: Vec<&xla::Literal> = vec![tile, &w_lit];
            let p = self.rt.run1(entry, &args).expect("scores artifact execution failed");
            let lo = t * data.tm;
            let hi = ((t + 1) * data.tm).min(data.m);
            out.extend(p[..hi - lo].iter().map(|&v| v as f64));
        }
        out
    }

    fn grad(&mut self, x: CsrView<'_>, coeffs: &[f64]) -> Vec<f64> {
        if self.data.is_none() {
            self.prepare(x);
        }
        let data = self.data.as_ref().unwrap();
        assert_eq!(data.m, x.rows());
        let entry = self.grad_entry.as_ref().unwrap();
        let (tm, n_pad, m) = (data.tm, data.n_pad, data.m);
        let mut acc = vec![0.0f64; n_pad];
        let mut c32 = vec![0.0f32; tm];
        for (t, tile) in data.tiles.iter().enumerate() {
            c32.iter_mut().for_each(|v| *v = 0.0);
            let lo = t * tm;
            let hi = ((t + 1) * tm).min(m);
            for (k, &c) in coeffs[lo..hi].iter().enumerate() {
                c32[k] = c as f32;
            }
            let c_lit = literal_1d(&c32);
            let args: Vec<&xla::Literal> = vec![tile, &c_lit];
            let a = self.rt.run1(entry, &args).expect("grad artifact execution failed");
            for (dst, &src) in acc.iter_mut().zip(&a) {
                *dst += src as f64;
            }
        }
        acc.truncate(x.cols());
        acc
    }
}
