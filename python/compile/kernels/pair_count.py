"""Pallas kernel: tiled O(m^2) pair-violation counting (L1).

The compute hot spot of the PairRSVM baseline — eqs. (5)-(6) —
expressed as a 2-D grid of (BI × BJ) tiles of masked outer comparisons:

    c[i] = Σ_j [y_j > y_i] · [p_i > p_j − 1] · valid_i · valid_j
    d[i] = Σ_j [y_j < y_i] · [p_i < p_j + 1] · valid_i · valid_j

TPU mapping (DESIGN.md §Hardware-Adaptation): where a CUDA formulation
would assign a threadblock per (i, j) tile with shared-memory staging,
here each grid step holds one `(BI,)` slice of p/y and one `(BJ,)` slice
in VMEM and materializes the `(BI, BJ)` comparison tile as a broadcast
compare on the VPU — no HBM traffic beyond the two input slices. The
`j` grid dimension is innermost, so the `(BI,)` output blocks stay
resident and accumulate across the j sweep.

The `valid` mask makes padding exact: the rust runtime pads m up to the
artifact tile and passes 0.0 for padding rows.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = 256


def _pair_count_kernel(pi_ref, yi_ref, vi_ref, pj_ref, yj_ref, vj_ref, c_ref, d_ref):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        c_ref[...] = jnp.zeros_like(c_ref)
        d_ref[...] = jnp.zeros_like(d_ref)

    pi = pi_ref[...][:, None]  # (BI, 1)
    yi = yi_ref[...][:, None]
    vi = vi_ref[...][:, None]
    pj = pj_ref[...][None, :]  # (1, BJ)
    yj = yj_ref[...][None, :]
    vj = vj_ref[...][None, :]

    vv = vi * vj
    # Canonical hinge predicate (matches the rust oracles bit-for-bit).
    c_tile = jnp.where((yj > yi) & (1.0 + pi - pj > 0.0), vv, 0.0)
    d_tile = jnp.where((yj < yi) & (1.0 + pj - pi > 0.0), vv, 0.0)
    c_ref[...] += jnp.sum(c_tile, axis=1)
    d_ref[...] += jnp.sum(d_tile, axis=1)


@functools.partial(jax.jit, static_argnames=("block",))
def pair_count(p, y, valid, *, block=DEFAULT_BLOCK):
    """(c, d) margin-violation counts; p/y/valid are (m,) f32."""
    (m,) = p.shape
    b = min(block, m)
    if m % b != 0:
        raise ValueError(f"m={m} not divisible by block={b}")
    grid = (m // b, m // b)
    vec = lambda index: pl.BlockSpec((b,), index)  # noqa: E731
    return pl.pallas_call(
        _pair_count_kernel,
        grid=grid,
        in_specs=[
            vec(lambda i, j: (i,)),  # p rows
            vec(lambda i, j: (i,)),  # y rows
            vec(lambda i, j: (i,)),  # valid rows
            vec(lambda i, j: (j,)),  # p cols
            vec(lambda i, j: (j,)),  # y cols
            vec(lambda i, j: (j,)),  # valid cols
        ],
        out_specs=[
            pl.BlockSpec((b,), lambda i, j: (i,)),
            pl.BlockSpec((b,), lambda i, j: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m,), jnp.float32),
            jax.ShapeDtypeStruct((m,), jnp.float32),
        ],
        interpret=True,
    )(p, y, valid, p, y, valid)
