//! Minimal, dependency-free shim of the `anyhow` API surface this
//! workspace uses. The offline registry has no crates.io access, so the
//! real crate cannot be pulled; this path dependency provides the same
//! names with the same semantics for the subset we need:
//!
//! - [`Error`]: an opaque error carrying a context chain (outermost
//!   first). Unlike the real crate it stores rendered strings rather
//!   than live trait objects — nothing here ever downcasts.
//! - [`Result<T>`]: alias defaulting the error type.
//! - [`Context`]: `.context(..)` / `.with_context(..)` on `Result` and
//!   `Option`.
//! - [`anyhow!`], [`bail!`], [`ensure!`]: ad-hoc error construction.
//!
//! `Error` deliberately does **not** implement `std::error::Error`, so
//! the blanket `From<E: std::error::Error>` conversion (what makes `?`
//! work on `io::Result` etc. inside `anyhow::Result` functions) does not
//! collide with `impl From<T> for T` — the same trick the real crate
//! uses.

use std::fmt;

/// Opaque error value: a chain of rendered messages, outermost context
/// first.
pub struct Error {
    chain: Vec<String>,
}

/// `Result` with [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Build from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error { chain: vec![message.to_string()] }
    }

    /// Prepend a context message (becomes the new outermost entry).
    pub fn context<C: fmt::Display>(mut self, context: C) -> Self {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The messages, outermost context first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// The root (innermost) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    /// Renders the whole chain joined with `": "` (outermost first).
    /// Real anyhow prints only the outermost message here; the shim joins
    /// so that re-contexting an `Error` through the string-flattening
    /// [`Context`] impl cannot silently drop root causes.
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.join(": "))
    }
}

impl fmt::Debug for Error {
    /// Mirrors the real crate's report format so `fn main() -> Result<()>`
    /// prints the full context chain on failure.
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self.chain.split_first() {
            None => Ok(()),
            Some((head, rest)) => {
                write!(f, "{head}")?;
                if !rest.is_empty() {
                    write!(f, "\n\nCaused by:")?;
                    for cause in rest {
                        write!(f, "\n    {cause}")?;
                    }
                }
                Ok(())
            }
        }
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(err: E) -> Self {
        let mut chain = vec![err.to_string()];
        let mut source = err.source();
        while let Some(s) = source {
            chain.push(s.to_string());
            source = s.source();
        }
        Error { chain }
    }
}

/// Context-attachment extension for `Result` and `Option`.
pub trait Context<T> {
    /// Wrap the error (or `None`) with a context message.
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    /// Like [`Context::context`], evaluating the message lazily.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error { chain: vec![context.to_string(), e.to_string()] })
    }
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error { chain: vec![f().to_string(), e.to_string()] })
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with a formatted [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<String> {
        let s = std::fs::read_to_string("/definitely/not/a/file")
            .context("reading config")?;
        Ok(s)
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<i32> {
            let n: i32 = "not a number".parse()?;
            Ok(n)
        }
        let err = inner().unwrap_err();
        assert!(err.to_string().contains("invalid digit"));
    }

    #[test]
    fn context_chains_outermost_first() {
        let err = io_fail().unwrap_err();
        let chain: Vec<&str> = err.chain().collect();
        assert_eq!(chain[0], "reading config");
        assert!(chain.len() >= 2);
        assert!(format!("{err:?}").contains("Caused by:"));
        assert!(err.to_string().starts_with("reading config: "));
    }

    #[test]
    fn recontexting_an_error_keeps_root_causes() {
        let err: Error = io_fail().context("loading model").unwrap_err();
        let rendered = err.to_string();
        assert!(rendered.starts_with("loading model: reading config"), "{rendered}");
        // The io root cause survives the string flattening.
        let prefix = "loading model: reading config";
        assert!(rendered.len() > prefix.len(), "{rendered}");
    }

    #[test]
    fn option_context() {
        let v: Option<u8> = None;
        let err = v.context("missing value").unwrap_err();
        assert_eq!(err.to_string(), "missing value");
        assert_eq!(Some(3u8).context("unused").unwrap(), 3);
    }

    #[test]
    fn macros() {
        fn f(flag: bool) -> Result<u32> {
            ensure!(flag, "flag was {}", flag);
            if !flag {
                bail!("unreachable");
            }
            Err(anyhow!("fell through with {}", 42))
        }
        assert_eq!(f(false).unwrap_err().to_string(), "flag was false");
        assert_eq!(f(true).unwrap_err().to_string(), "fell through with 42");
    }

    #[test]
    fn double_question_mark_pattern() {
        // Option<io::Result<T>>.context(..)?? — the model-file read idiom.
        fn g() -> Result<String> {
            let lines: Option<std::io::Result<String>> =
                Some(Ok("header".to_string()));
            let header = lines.context("empty file")??;
            Ok(header)
        }
        assert_eq!(g().unwrap(), "header");
    }
}
