//! Data substrate: dataset container, libsvm I/O, the synthetic
//! generators standing in for Cadata and Reuters RCV1 (DESIGN.md §6),
//! and the memory-mapped pallas store for out-of-core training.
//!
//! Everything downstream of loading — the trainer, the oracles, the
//! benches, the CLI — consumes data through the [`DatasetView`] trait,
//! so an owned in-memory [`Dataset`] and a zero-copy memory-mapped
//! [`store::PallasStore`] are interchangeable.

pub mod dataset;
pub mod libsvm;
pub mod store;
pub mod synthetic;

pub use dataset::Dataset;
pub use store::{ColStat, PallasStore};

use crate::linalg::{CsrMatrix, CsrView};
use crate::losses::GroupIndex;
use anyhow::Result;
use std::path::Path;
use std::sync::Arc;

/// Read-only view of a ranking dataset: the sparse feature matrix, the
/// utility labels, and optional query ids — in borrowed, zero-copy form.
///
/// Implemented by the owned [`Dataset`], the memory-mapped
/// [`PallasStore`], and the borrowed [`DatasetRef`] slices the prefix
/// benches use. Object-safe: the trainer takes `&dyn DatasetView`.
pub trait DatasetView {
    /// The feature matrix (rows = examples), borrowed.
    fn x(&self) -> CsrView<'_>;

    /// Per-example utility labels.
    fn y(&self) -> &[f64];

    /// Per-example query id; `None` means one global ranking.
    fn qid(&self) -> Option<&[u64]>;

    /// Human-readable provenance for logs.
    fn name(&self) -> &str;

    /// Precomputed query-group index, if the source carries one (the
    /// pallas store serializes it so training skips the per-run group
    /// scan; `Arc`-shared so consumers reference rather than copy it).
    /// `None` means "derive from [`Self::qid`] if needed".
    fn group_index(&self) -> Option<Arc<GroupIndex>> {
        None
    }

    /// Precomputed comparable-pair count of the training objective, if
    /// the source carries one. Exact integers as f64, so using the hint
    /// is bit-identical to recounting.
    fn n_pairs_hint(&self) -> Option<f64> {
        None
    }

    /// Cached per-column statistics (nnz/sum/sumsq/min/max per feature
    /// column), if the source carries them — the pallas store serializes
    /// a [`ColStat`] record per column so normalization and
    /// model-selection passes skip their `O(m·s)` scan. The cached
    /// values are bit-identical to a from-scratch recomputation
    /// ([`store::compute_col_stats`]), so consumers may use either
    /// interchangeably. `None` means "recompute if needed".
    fn col_stats(&self) -> Option<&[ColStat]> {
        None
    }

    /// Hint that a full sweep over the dataset is imminent. The mapped
    /// pallas store forwards this as `madvise(WILLNEED)` so page-ins
    /// overlap setup; owned datasets are already resident and do
    /// nothing. Never required for correctness.
    fn prefetch(&self) {}

    /// Number of examples `m`.
    fn len(&self) -> usize {
        self.y().len()
    }

    fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Feature dimension `n`.
    fn dim(&self) -> usize {
        self.x().cols()
    }

    /// Average non-zero features per example — the paper's `s`.
    fn sparsity(&self) -> f64 {
        self.x().avg_nnz_per_row()
    }

    /// Number of distinct utility levels — the paper's `r`.
    fn n_levels(&self) -> usize {
        let mut l = self.y().to_vec();
        l.sort_unstable_by(|a, b| a.total_cmp(b));
        l.dedup();
        l.len()
    }

    /// Zero-copy view of the first `m` examples (the scalability
    /// benches' growing prefixes, mirroring the paper's exponentially
    /// growing train sizes). Any precomputed group index or pair count
    /// is dropped — a prefix changes both.
    fn prefix_view(&self, m: usize) -> DatasetRef<'_> {
        assert!(m <= self.len());
        DatasetRef {
            x: self.x().row_range(0, m),
            y: &self.y()[..m],
            qid: self.qid().map(|q| &q[..m]),
            name: format!("{}[:{m}]", self.name()),
        }
    }
}

/// A borrowed dataset: slices into someone else's storage (an owned
/// [`Dataset`], a [`PallasStore`] mapping). What
/// [`DatasetView::prefix_view`] returns.
#[derive(Clone, Debug)]
pub struct DatasetRef<'a> {
    pub x: CsrView<'a>,
    pub y: &'a [f64],
    pub qid: Option<&'a [u64]>,
    pub name: String,
}

impl DatasetView for DatasetRef<'_> {
    fn x(&self) -> CsrView<'_> {
        self.x
    }

    fn y(&self) -> &[f64] {
        self.y
    }

    fn qid(&self) -> Option<&[u64]> {
        self.qid
    }

    fn name(&self) -> &str {
        &self.name
    }
}

/// Copy any view into an owned [`Dataset`] (needed for owned operations
/// like shuffled train/test splits).
pub fn materialize(ds: &dyn DatasetView) -> Dataset {
    let x: CsrMatrix = ds.x().to_owned_matrix();
    Dataset::new(x, ds.y().to_vec(), ds.qid().map(|q| q.to_vec()), ds.name().to_string())
}

/// A dataset loaded from disk: either parsed text (owned) or an opened
/// store (mapped). [`Self::view`] erases the difference.
pub enum LoadedDataset {
    Owned(Dataset),
    Store(PallasStore),
}

impl LoadedDataset {
    pub fn view(&self) -> &dyn DatasetView {
        match self {
            LoadedDataset::Owned(ds) => ds,
            LoadedDataset::Store(st) => st,
        }
    }

    /// True when backed by a pallas store.
    pub fn is_store(&self) -> bool {
        matches!(self, LoadedDataset::Store(_))
    }
}

/// Load a dataset file of either format, autodetected by magic bytes:
/// a pallas store opens as a checked memory mapping, anything else
/// parses as libsvm text.
pub fn load_auto(path: impl AsRef<Path>) -> Result<LoadedDataset> {
    load_auto_with(path, true)
}

/// [`load_auto`] with the store-verification knob: `verify = false`
/// opens a store via [`PallasStore::open_unchecked`] (no full-file
/// checksum/structure scan — the CLI's `--no-verify`). The single home
/// of the format-dispatch rule, so the CLI, the memory probe, and
/// library users cannot drift apart.
pub fn load_auto_with(path: impl AsRef<Path>, verify: bool) -> Result<LoadedDataset> {
    let path = path.as_ref();
    if store::is_store_file(path) {
        let st =
            if verify { PallasStore::open(path)? } else { PallasStore::open_unchecked(path)? };
        Ok(LoadedDataset::Store(st))
    } else {
        Ok(LoadedDataset::Owned(libsvm::read(path)?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prefix_view_matches_owned_prefix() {
        let ds = synthetic::queries(6, 10, 4, 11);
        for m in [0, 1, 17, 60] {
            let pv = DatasetView::prefix_view(&ds, m);
            let owned = ds.prefix(m);
            assert_eq!(pv.y(), &owned.y[..]);
            assert_eq!(pv.qid(), owned.qid.as_deref());
            assert_eq!(DatasetView::len(&pv), m);
            for i in 0..m {
                assert_eq!(pv.x().row(i), owned.x.row(i));
            }
        }
    }

    #[test]
    fn materialize_roundtrips() {
        let ds = synthetic::cadata_like(40, 3);
        let back = materialize(&ds);
        assert_eq!(back.y, ds.y);
        assert_eq!(back.qid, ds.qid);
        assert_eq!(back.x, ds.x);
    }

    #[test]
    fn load_auto_detects_libsvm() {
        let p = std::env::temp_dir().join(format!("ranksvm_auto_{}.libsvm", std::process::id()));
        std::fs::write(&p, "1 1:2.0\n2 1:3.0\n").unwrap();
        let loaded = load_auto(&p).unwrap();
        assert!(!loaded.is_store());
        assert_eq!(loaded.view().len(), 2);
        std::fs::remove_file(p).ok();
    }
}
