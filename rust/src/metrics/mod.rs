//! Ranking performance metrics.
//!
//! The paper's eq. (1) — the pairwise ranking error, i.e. the fraction of
//! comparable pairs ordered incorrectly by the predictions — evaluated in
//! `O(m log m)` by counting inversions with a Fenwick tree over
//! rank-compressed predictions (the naive definition is `O(m²)`; a
//! property test pins them equal). Special cases: AUC (bipartite labels)
//! and a query-grouped average.

use crate::rbtree::FenwickCounter;

/// Pairwise ranking error (eq. 1): fraction of pairs with `y_i < y_j`
/// where the prediction orders them wrongly. Ties in predictions count
/// as half an error (the standard convention, consistent with the
/// Wilcoxon-Mann-Whitney statistic / AUC in the bipartite case).
/// Returns 0 when no comparable pairs exist.
pub fn pairwise_error(pred: &[f64], y: &[f64]) -> f64 {
    assert_eq!(pred.len(), y.len());
    let m = pred.len();
    if m < 2 {
        return 0.0;
    }
    // Sort by label ascending; ties in label grouped. For each label
    // group, all previously inserted examples have strictly smaller y.
    // A pair (prev, cur) is wrong if pred_prev > pred_cur, half-wrong if
    // equal. Count via two Fenwick queries per example over compressed
    // prediction values.
    let mut order: Vec<usize> = (0..m).collect();
    order.sort_unstable_by(|&a, &b| y[a].total_cmp(&y[b]).then(a.cmp(&b)));
    let f_larger = |f: &FenwickCounter, v: f64| f.count_larger(v);
    let f_smaller = |f: &FenwickCounter, v: f64| f.count_smaller(v);

    let mut fen = FenwickCounter::new(pred);
    let mut wrong = 0.0f64;
    let mut total = 0u64;
    let mut i = 0;
    while i < m {
        // label-tie group [i, j)
        let mut j = i;
        while j < m && y[order[j]] == y[order[i]] {
            j += 1;
        }
        let inserted = fen.len(); // examples with strictly smaller label
        for k in i..j {
            let p = pred[order[k]];
            let larger = f_larger(&fen, p); // prev pred > cur pred → wrong
            let smaller = f_smaller(&fen, p);
            let ties = inserted - larger - smaller;
            wrong += larger as f64 + 0.5 * ties as f64;
            total += inserted;
        }
        for k in i..j {
            fen.insert(pred[order[k]]);
        }
        i = j;
    }
    if total == 0 {
        0.0
    } else {
        wrong / total as f64
    }
}

/// AUC for bipartite labels (y ∈ {neg, pos} with neg < pos):
/// `AUC = 1 − pairwise_error` by the Wilcoxon–Mann–Whitney identity.
pub fn auc(pred: &[f64], y: &[f64]) -> f64 {
    1.0 - pairwise_error(pred, y)
}

/// Query-grouped pairwise error: eq. (1) per group, averaged over groups
/// that contain at least one comparable pair (paper §2). Groups
/// accumulate in first-seen qid order — *not* hash order — so the float
/// sum is reproducible across processes (the `ranksvm cv` reports are
/// byte-compared across runs; docs/DETERMINISM.md).
pub fn grouped_pairwise_error(pred: &[f64], y: &[f64], qid: &[u64]) -> f64 {
    grouped_mean(
        pred,
        y,
        qid,
        |yg| crate::losses::count_comparable_pairs(yg) > 0,
        |pg, yg| pairwise_error(pg, yg),
    )
}

/// Partition example indices by qid, groups in first-seen order (the
/// same convention as [`crate::losses::GroupIndex`]), so grouped metric
/// averages accumulate in a deterministic order.
fn groups_first_seen(qid: &[u64]) -> Vec<Vec<usize>> {
    let mut map: std::collections::HashMap<u64, usize> = std::collections::HashMap::new();
    let mut groups: Vec<Vec<usize>> = Vec::new();
    for (i, &q) in qid.iter().enumerate() {
        let g = *map.entry(q).or_insert_with(|| {
            groups.push(Vec::new());
            groups.len() - 1
        });
        groups[g].push(i);
    }
    groups
}

/// Mean of `metric` over the query groups where `effective(y_group)`
/// holds, in first-seen qid order. Returns 0 when no group qualifies.
fn grouped_mean(
    pred: &[f64],
    y: &[f64],
    qid: &[u64],
    effective: impl Fn(&[f64]) -> bool,
    metric: impl Fn(&[f64], &[f64]) -> f64,
) -> f64 {
    assert_eq!(pred.len(), y.len());
    assert_eq!(pred.len(), qid.len());
    let mut sum = 0.0;
    let mut count = 0usize;
    for idx in groups_first_seen(qid) {
        let yg: Vec<f64> = idx.iter().map(|&i| y[i]).collect();
        if !effective(&yg) {
            continue;
        }
        let pg: Vec<f64> = idx.iter().map(|&i| pred[i]).collect();
        sum += metric(&pg, &yg);
        count += 1;
    }
    if count == 0 {
        0.0
    } else {
        sum / count as f64
    }
}

/// Query-grouped AUC: [`auc`] per group, averaged over groups with at
/// least one comparable pair (groups whose labels are all tied carry no
/// ranking information). For bipartite labels this is the mean per-query
/// Wilcoxon–Mann–Whitney statistic.
pub fn grouped_auc(pred: &[f64], y: &[f64], qid: &[u64]) -> f64 {
    grouped_mean(
        pred,
        y,
        qid,
        |yg| crate::losses::count_comparable_pairs(yg) > 0,
        |pg, yg| auc(pg, yg),
    )
}

/// Query-grouped precision@k: [`precision_at_k`] per group, averaged
/// over groups with at least one relevant example (`y > threshold`) —
/// the standard IR convention; a query with nothing relevant says
/// nothing about the ranker.
pub fn grouped_precision_at_k(
    pred: &[f64],
    y: &[f64],
    qid: &[u64],
    k: usize,
    threshold: f64,
) -> f64 {
    grouped_mean(
        pred,
        y,
        qid,
        |yg| yg.iter().any(|&v| v > threshold),
        |pg, yg| precision_at_k(pg, yg, k, threshold),
    )
}

/// Kendall's τ-a over comparable pairs: `1 − 2·error` (in [−1, 1]).
pub fn kendall_tau(pred: &[f64], y: &[f64]) -> f64 {
    1.0 - 2.0 * pairwise_error(pred, y)
}

/// NDCG@k with exponential gains `(2^y − 1)` and log2 discounts — the
/// standard listwise retrieval metric (complements the paper's pairwise
/// criterion in the document-retrieval examples). Ties in `pred` are
/// broken by original index (deterministic). Returns 1.0 for an ideal
/// ordering, 0.0 when there is no gain at all.
pub fn ndcg_at_k(pred: &[f64], y: &[f64], k: usize) -> f64 {
    assert_eq!(pred.len(), y.len());
    let m = pred.len();
    if m == 0 || k == 0 {
        return 0.0;
    }
    let k = k.min(m);
    let gain = |v: f64| (2f64.powf(v) - 1.0).max(0.0);
    let dcg = |order: &[usize]| -> f64 {
        order
            .iter()
            .take(k)
            .enumerate()
            .map(|(rank, &i)| gain(y[i]) / ((rank + 2) as f64).log2())
            .sum()
    };
    let mut by_pred: Vec<usize> = (0..m).collect();
    by_pred.sort_unstable_by(|&a, &b| pred[b].total_cmp(&pred[a]).then(a.cmp(&b)));
    let mut ideal: Vec<usize> = (0..m).collect();
    ideal.sort_unstable_by(|&a, &b| y[b].total_cmp(&y[a]).then(a.cmp(&b)));
    let idcg = dcg(&ideal);
    if idcg <= 0.0 {
        0.0
    } else {
        dcg(&by_pred) / idcg
    }
}

/// Precision@k for bipartite labels (`y > threshold` is relevant):
/// fraction of the top-k predictions that are relevant.
pub fn precision_at_k(pred: &[f64], y: &[f64], k: usize, threshold: f64) -> f64 {
    assert_eq!(pred.len(), y.len());
    let m = pred.len();
    if m == 0 || k == 0 {
        return 0.0;
    }
    let k = k.min(m);
    let mut order: Vec<usize> = (0..m).collect();
    order.sort_unstable_by(|&a, &b| pred[b].total_cmp(&pred[a]).then(a.cmp(&b)));
    order.iter().take(k).filter(|&&i| y[i] > threshold).count() as f64 / k as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn naive_error(pred: &[f64], y: &[f64]) -> f64 {
        let m = pred.len();
        let mut wrong = 0.0;
        let mut total = 0u64;
        for i in 0..m {
            for j in 0..m {
                if y[i] < y[j] {
                    total += 1;
                    if pred[i] > pred[j] {
                        wrong += 1.0;
                    } else if pred[i] == pred[j] {
                        wrong += 0.5;
                    }
                }
            }
        }
        if total == 0 {
            0.0
        } else {
            wrong / total as f64
        }
    }

    #[test]
    fn perfect_and_reversed() {
        let y = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(pairwise_error(&[1.0, 2.0, 3.0, 4.0], &y), 0.0);
        assert_eq!(pairwise_error(&[4.0, 3.0, 2.0, 1.0], &y), 1.0);
        assert_eq!(kendall_tau(&[1.0, 2.0, 3.0, 4.0], &y), 1.0);
        assert_eq!(kendall_tau(&[4.0, 3.0, 2.0, 1.0], &y), -1.0);
    }

    #[test]
    fn all_tied_predictions_give_half() {
        let y = [1.0, 2.0, 3.0];
        assert!((pairwise_error(&[0.0, 0.0, 0.0], &y) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn matches_naive_randomized() {
        let mut rng = Rng::new(601);
        for trial in 0..40 {
            let m = 1 + rng.below(100);
            let y: Vec<f64> = match trial % 3 {
                0 => (0..m).map(|_| rng.normal()).collect(),
                1 => (0..m).map(|_| rng.below(4) as f64).collect(),
                _ => (0..m).map(|_| rng.below(2) as f64).collect(),
            };
            // predictions with deliberate ties
            let p: Vec<f64> = (0..m).map(|_| (rng.below(20) as f64) / 4.0).collect();
            let fast = pairwise_error(&p, &y);
            let naive = naive_error(&p, &y);
            assert!((fast - naive).abs() < 1e-12, "trial {trial}: {fast} vs {naive}");
        }
    }

    #[test]
    fn auc_identity() {
        let y = [0.0, 0.0, 1.0, 1.0];
        let p = [0.1, 0.4, 0.35, 0.8];
        // pairs: (0,2):ok (0,3):ok (1,2):wrong (1,3):ok → auc = 3/4
        assert!((auc(&p, &y) - 0.75).abs() < 1e-12);
    }

    #[test]
    fn grouped_error_averages_groups() {
        let y = [1.0, 2.0, 1.0, 2.0];
        let qid = [0u64, 0, 1, 1];
        let p = [0.0, 1.0, 1.0, 0.0]; // group 0 perfect, group 1 reversed
        assert!((grouped_pairwise_error(&p, &y, &qid) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn degenerate_inputs() {
        assert_eq!(pairwise_error(&[], &[]), 0.0);
        assert_eq!(pairwise_error(&[1.0], &[1.0]), 0.0);
        assert_eq!(pairwise_error(&[1.0, 2.0], &[3.0, 3.0]), 0.0); // no comparable pairs
    }

    #[test]
    fn ndcg_perfect_and_reversed() {
        let y = [3.0, 2.0, 1.0, 0.0];
        assert!((ndcg_at_k(&[4.0, 3.0, 2.0, 1.0], &y, 4) - 1.0).abs() < 1e-12);
        let rev = ndcg_at_k(&[1.0, 2.0, 3.0, 4.0], &y, 4);
        assert!(rev < 1.0 && rev > 0.0);
        // k=1 with the best item on top
        assert!((ndcg_at_k(&[9.0, 0.0, 0.0, 0.0], &y, 1) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn ndcg_matches_manual_small_case() {
        // y = [1, 0], pred puts the irrelevant one first:
        // DCG = 0/log2(2) + 1/log2(3); IDCG = 1/log2(2) = 1.
        let got = ndcg_at_k(&[2.0, 1.0], &[0.0, 1.0], 2);
        let want = 1.0 / 3f64.log2();
        assert!((got - want).abs() < 1e-12, "{got} vs {want}");
    }

    #[test]
    fn ndcg_degenerate() {
        assert_eq!(ndcg_at_k(&[], &[], 5), 0.0);
        assert_eq!(ndcg_at_k(&[1.0, 2.0], &[0.0, 0.0], 2), 0.0); // no gain anywhere
        assert_eq!(ndcg_at_k(&[1.0], &[1.0], 0), 0.0);
    }

    #[test]
    fn grouped_auc_averages_effective_groups() {
        // Group 0 perfect (AUC 1), group 1 reversed (AUC 0), group 2
        // single-class (excluded) → mean 0.5.
        let y = [0.0, 1.0, 0.0, 1.0, 1.0, 1.0];
        let p = [0.0, 1.0, 1.0, 0.0, 9.0, 8.0];
        let qid = [0u64, 0, 1, 1, 2, 2];
        assert!((grouped_auc(&p, &y, &qid) - 0.5).abs() < 1e-12);
        // Identity with the grouped pairwise error on the same data.
        let err = grouped_pairwise_error(&p, &y, &qid);
        assert!((grouped_auc(&p, &y, &qid) - (1.0 - err)).abs() < 1e-12);
        // No effective group at all.
        assert_eq!(grouped_auc(&[1.0, 2.0], &[1.0, 1.0], &[0, 0]), 0.0);
    }

    #[test]
    fn grouped_precision_at_k_skips_groups_without_relevant() {
        // Group 0: top-1 is relevant (P@1 = 1). Group 1: top-1 is not
        // (P@1 = 0). Group 2: nothing relevant — excluded, not zero.
        let y = [1.0, 0.0, 0.0, 1.0, 0.0, 0.0];
        let p = [5.0, 1.0, 7.0, 2.0, 3.0, 4.0];
        let qid = [0u64, 0, 1, 1, 2, 2];
        assert!((grouped_precision_at_k(&p, &y, &qid, 1, 0.0) - 0.5).abs() < 1e-12);
        // k larger than any group truncates per group: group 0 → 1/2,
        // group 1 → 1/2, mean 1/2.
        assert!((grouped_precision_at_k(&p, &y, &qid, 10, 0.0) - 0.5).abs() < 1e-12);
        assert_eq!(grouped_precision_at_k(&p, &y, &[9u64; 6], 2, 5.0), 0.0);
    }

    #[test]
    fn precision_at_k_basics() {
        let y = [1.0, 0.0, 1.0, 0.0];
        let p = [4.0, 3.0, 2.0, 1.0]; // top-2 = items 0,1 → one relevant
        assert!((precision_at_k(&p, &y, 2, 0.5) - 0.5).abs() < 1e-12);
        assert!((precision_at_k(&p, &y, 1, 0.5) - 1.0).abs() < 1e-12);
        assert!((precision_at_k(&p, &y, 4, 0.5) - 0.5).abs() < 1e-12);
        assert_eq!(precision_at_k(&[], &[], 3, 0.5), 0.0);
    }
}
