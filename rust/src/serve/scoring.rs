//! The standalone scoring model and its versioned on-disk format
//! (`.rsm`).
//!
//! [`ScoringModel`] is what the serving path loads: the trained weight
//! vector *plus* everything needed to score **raw** feature vectors —
//! in particular the `--normalize` mode and the training-set column
//! norms. A model trained with `--normalize l2-col` lives in the
//! normalized feature space; before this format existed the plain-text
//! `RankModel` file silently expected callers to pre-scale their inputs
//! with norms they did not have. A `ScoringModel` carries the norms, so
//! `predict`/`serve` score raw inputs bit-identically to scoring
//! explicitly pre-normalized data (pinned in `tests/serve.rs`).
//!
//! The binary format reuses the pallas-store machinery from
//! `data/store/format.rs` — the same FNV-1a-64 [`Checksum`] stream
//! discipline (payload first, then the header minus the checksum
//! field), the same [`cast_slice`] zero-copy boundary, the same
//! refusal policy (unknown version or flag bits are structured errors
//! on the checked *and* unchecked open paths). The normative byte-level
//! spec lives in `docs/MODEL_FORMAT.md`; `tests/model_spec.rs` pins
//! this module to it.
//!
//! ```text
//! offset  size  field
//! ------  ----  -----------------------------------------------
//!      0     7  magic "RSMODL\0"
//!      7     1  format version (1)
//!      8     8  dim (n)                 u64 LE
//!     16     8  flags (bit 0: norms)    u64 LE
//!     24     8  checksum (FNV-1a 64)    u64 LE
//!     32   2×8  section offsets         u64 LE each
//!     48    48  reserved (must be zero)
//!     96     …  sections (8-aligned):
//!               weights  n·f64   trained weight vector
//!               norms    n·f64   training-set column ℓ2 norms
//!                                (flag bit 0 only)
//! ```
//!
//! [`ScoringModel::save`] publishes atomically (write a temp file in
//! the same directory, then `rename`), so a serving daemon watching the
//! path never observes a torn file — that rename *is* the hot-swap
//! protocol (`serve::Engine` picks the new version up at the next batch
//! boundary).
//!
//! Legacy plain-text `ranksvm-model v1` files (un-normalized by
//! construction) still load through [`ScoringModel::load_auto`].

use crate::coordinator::model::RankModel;
use crate::data::store::{cast_slice, Checksum, Mmap};
use crate::data::DatasetView;
use anyhow::{bail, ensure, Context, Result};
use std::path::Path;

/// File magic: the first 7 bytes of every binary scoring model.
pub const MODEL_MAGIC: [u8; 7] = *b"RSMODL\0";

/// Current scoring-model format version (byte 7).
pub const MODEL_VERSION: u8 = 1;

/// Total header size; the first section starts here (8-aligned).
pub const MODEL_HEADER_LEN: usize = 96;

/// Byte range of the checksum field inside the header — the only bytes
/// the checksum stream skips.
pub const MODEL_CHECKSUM_FIELD: std::ops::Range<usize> = 24..32;

/// First byte of the section-offset array inside the header.
pub const MODEL_OFFSETS_START: usize = 32;

/// Section count/order. Indexes into [`ModelHeader::offsets`].
pub const MSEC_WEIGHTS: usize = 0;
pub const MSEC_NORMS: usize = 1;
pub const MODEL_N_SECTIONS: usize = 2;

/// Header flag bit: the model carries training-set column ℓ2 norms
/// (i.e. it was trained with `--normalize l2-col` and scores raw
/// inputs by applying that normalization itself).
pub const MODEL_FLAG_HAS_NORMS: u64 = 1;

/// Every flag bit this build understands; any other bit is refused.
pub const MODEL_KNOWN_FLAGS: u64 = MODEL_FLAG_HAS_NORMS;

/// Decoded scoring-model header. Field meanings per the module layout
/// table.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ModelHeader {
    pub dim: u64,
    pub flags: u64,
    pub checksum: u64,
    pub offsets: [u64; MODEL_N_SECTIONS],
}

impl ModelHeader {
    pub fn has_norms(&self) -> bool {
        self.flags & MODEL_FLAG_HAS_NORMS != 0
    }

    /// Byte length of each section, derived from `dim` — `None` when
    /// the count is large enough to overflow (only reachable from a
    /// corrupt header; [`Self::decode`] rejects such files).
    pub fn checked_section_len(&self, sec: usize) -> Option<u64> {
        match sec {
            MSEC_WEIGHTS => self.dim.checked_mul(8),
            MSEC_NORMS => {
                if self.has_norms() {
                    self.dim.checked_mul(8)
                } else {
                    Some(0)
                }
            }
            _ => unreachable!("unknown model section {sec}"),
        }
    }

    /// Byte length of each section for a header that already passed
    /// [`Self::decode`].
    pub fn section_len(&self, sec: usize) -> u64 {
        self.checked_section_len(sec).expect("header counts validated by decode")
    }

    pub fn encode(&self) -> [u8; MODEL_HEADER_LEN] {
        let mut out = [0u8; MODEL_HEADER_LEN];
        out[..7].copy_from_slice(&MODEL_MAGIC);
        out[7] = MODEL_VERSION;
        for (k, v) in [self.dim, self.flags, self.checksum].iter().enumerate() {
            out[8 + k * 8..16 + k * 8].copy_from_slice(&v.to_le_bytes());
        }
        for (k, v) in self.offsets.iter().enumerate() {
            let at = MODEL_OFFSETS_START + k * 8;
            out[at..at + 8].copy_from_slice(&v.to_le_bytes());
        }
        // Bytes MODEL_OFFSETS_START + 8·MODEL_N_SECTIONS .. HEADER_LEN
        // stay zero (the reserved tail).
        out
    }

    /// Decode and *structurally* validate a header against the file
    /// length: magic, version, reserved bytes, flag registry, section
    /// alignment/order/bounds. Content integrity (the checksum) is
    /// verified separately by the checked open path.
    pub fn decode(bytes: &[u8], file_len: u64) -> Result<ModelHeader> {
        ensure!(bytes.len() >= MODEL_HEADER_LEN, "file too short for a scoring-model header");
        ensure!(bytes[..7] == MODEL_MAGIC, "not a ranksvm scoring model (bad magic)");
        let version = bytes[7];
        if version != MODEL_VERSION {
            bail!(
                "unsupported scoring-model version {version} (this build reads \
                 {MODEL_VERSION}; re-save the model with a matching build)"
            );
        }
        let u64_at = |off: usize| {
            let mut b = [0u8; 8];
            b.copy_from_slice(&bytes[off..off + 8]);
            u64::from_le_bytes(b)
        };
        let mut offsets = [0u64; MODEL_N_SECTIONS];
        for (k, o) in offsets.iter_mut().enumerate() {
            *o = u64_at(MODEL_OFFSETS_START + k * 8);
        }
        let h = ModelHeader { dim: u64_at(8), flags: u64_at(16), checksum: u64_at(24), offsets };
        ensure!(
            bytes[MODEL_OFFSETS_START + 8 * MODEL_N_SECTIONS..MODEL_HEADER_LEN]
                .iter()
                .all(|&b| b == 0),
            "reserved header bytes are not zero"
        );
        // Unknown flag bits mean a feature this build cannot honor —
        // reject them even on the unchecked path (the store's policy).
        ensure!(
            h.flags & !MODEL_KNOWN_FLAGS == 0,
            "unknown scoring-model flag bits {:#x}",
            h.flags & !MODEL_KNOWN_FLAGS
        );
        // Geometry: sections in declaration order, 8-aligned, inside
        // the file, and the last one ends exactly at EOF.
        let mut cursor = MODEL_HEADER_LEN as u64;
        for sec in 0..MODEL_N_SECTIONS {
            let off = h.offsets[sec];
            let len = h
                .checked_section_len(sec)
                .ok_or_else(|| anyhow::anyhow!("section {sec} length overflows (corrupt dim)"))?;
            ensure!(off % 8 == 0, "section {sec} offset {off} is not 8-byte aligned");
            ensure!(off >= cursor, "section {sec} offset {off} overlaps its predecessor");
            let end = off
                .checked_add(len)
                .ok_or_else(|| anyhow::anyhow!("section {sec} length overflows"))?;
            ensure!(
                end <= file_len,
                "section {sec} ends at {end} but the file is {file_len} bytes (short file?)"
            );
            cursor = end;
        }
        ensure!(
            cursor == file_len,
            "file has {} trailing bytes past the last section",
            file_len - cursor
        );
        Ok(h)
    }
}

/// Fold a model header into the checksum stream: every header byte
/// except the checksum field itself (the store's `update_header`
/// discipline, at this format's field offsets).
fn update_model_header(sum: &mut Checksum, header: &[u8]) {
    debug_assert!(header.len() >= MODEL_HEADER_LEN);
    sum.update(&header[..MODEL_CHECKSUM_FIELD.start]);
    sum.update(&header[MODEL_CHECKSUM_FIELD.end..MODEL_HEADER_LEN]);
}

/// The serial per-row scoring kernel — the *only* dot-product loop in
/// the crate, shared by [`RankModel::predict`] (`norms: None`), the
/// [`ScoringModel`], and the serving engine, so every scoring path is
/// bit-identical by construction.
///
/// Feature dimensions may differ (train/test splits of sparse data):
/// entries at `j >= w.len()` contribute zero, matching the historical
/// `RankModel::predict` contract. With `norms`, each value is divided
/// by its column norm *before* the multiply — exactly the
/// `map_values(v / norm)` fold `--normalize l2-col` applies at training
/// time, so scoring raw inputs here equals scoring pre-normalized
/// inputs without norms, to the last bit.
#[inline]
pub fn score_row(w: &[f64], norms: Option<&[f64]>, idx: &[u32], val: &[f64]) -> f64 {
    let mut s = 0.0;
    match norms {
        None => {
            for (&j, &v) in idx.iter().zip(val) {
                if (j as usize) < w.len() {
                    s += v * w[j as usize];
                }
            }
        }
        Some(nr) => {
            for (&j, &v) in idx.iter().zip(val) {
                let j = j as usize;
                if j < w.len() {
                    let vv = if nr[j] > 0.0 { v / nr[j] } else { v };
                    s += vv * w[j];
                }
            }
        }
    }
    s
}

/// Score every row of a CSR view with [`score_row`], in row order.
pub fn score_csr(w: &[f64], norms: Option<&[f64]>, x: &crate::linalg::CsrView<'_>) -> Vec<f64> {
    let mut out = Vec::with_capacity(x.rows());
    for i in 0..x.rows() {
        let (idx, val) = x.row(i);
        out.push(score_row(w, norms, idx, val));
    }
    out
}

/// How the model bytes are held: built in memory, or zero-copy off a
/// memory-mapped `.rsm` file (the serving daemon's arrangement — the
/// mapping lives exactly as long as the model, so an old version's
/// pages are dropped when its last in-flight batch finishes).
enum Backing {
    Owned { w: Vec<f64>, norms: Option<Vec<f64>> },
    Mapped { map: Mmap, w_span: (usize, usize), norms_span: Option<(usize, usize)> },
}

/// A trained linear ranking function plus its scoring-time feature
/// normalization — everything `predict`/`serve` need to score raw
/// inputs. See the module docs for the on-disk format.
pub struct ScoringModel {
    backing: Backing,
    dim: usize,
}

impl ScoringModel {
    /// Build from parts. `norms`, when present, must have one entry per
    /// weight (the training-set column ℓ2 norms).
    pub fn new(w: Vec<f64>, norms: Option<Vec<f64>>) -> Result<ScoringModel> {
        if let Some(n) = &norms {
            ensure!(
                n.len() == w.len(),
                "norms/weights length mismatch: {} norms for {} weights",
                n.len(),
                w.len()
            );
        }
        let dim = w.len();
        Ok(ScoringModel { backing: Backing::Owned { w, norms }, dim })
    }

    /// Wrap a bare [`RankModel`] (no normalization recorded — the
    /// legacy text-format semantics).
    pub fn from_rank_model(model: &RankModel) -> ScoringModel {
        ScoringModel::new(model.w.clone(), None).expect("no norms to mismatch")
    }

    /// Number of weights (the feature-space width).
    pub fn dim(&self) -> usize {
        self.dim
    }

    /// The weight vector (zero-copy for a mapped model).
    pub fn w(&self) -> &[f64] {
        match &self.backing {
            Backing::Owned { w, .. } => w,
            Backing::Mapped { map, w_span, .. } => {
                cast_slice(&map.bytes()[w_span.0..w_span.1]).expect("validated at open")
            }
        }
    }

    /// Training-set column ℓ2 norms, when the model was trained with
    /// `--normalize l2-col`.
    pub fn norms(&self) -> Option<&[f64]> {
        match &self.backing {
            Backing::Owned { norms, .. } => norms.as_deref(),
            Backing::Mapped { map, norms_span, .. } => norms_span
                .map(|(lo, hi)| cast_slice(&map.bytes()[lo..hi]).expect("validated at open")),
        }
    }

    /// The `--normalize` mode this model records.
    pub fn normalize_name(&self) -> &'static str {
        if self.norms().is_some() {
            "l2-col"
        } else {
            "none"
        }
    }

    /// True when backed by a live kernel mapping (false for in-memory
    /// models and the mmap read fallback).
    pub fn is_mapped(&self) -> bool {
        match &self.backing {
            Backing::Owned { .. } => false,
            Backing::Mapped { map, .. } => map.is_mapped(),
        }
    }

    /// Scores for every example of a dataset, raw features in — the
    /// recorded normalization is applied per entry by the shared
    /// kernel.
    pub fn scores(&self, ds: &dyn DatasetView) -> Vec<f64> {
        score_csr(self.w(), self.norms(), &ds.x())
    }

    /// Score one sparse example given `(0-based index, value)` pairs.
    /// Unlike the dataset path (which keeps the historical
    /// out-of-dim-contributes-zero contract), an explicit request with
    /// an out-of-range feature is a structured error — the serving
    /// daemon's dimension check.
    pub fn score_indexed(&self, feats: &[(usize, f64)]) -> Result<f64> {
        let w = self.w();
        let norms = self.norms();
        let mut s = 0.0;
        for &(j, v) in feats {
            ensure!(
                j < self.dim,
                "feature index {} out of range (model dim {})",
                j + 1,
                self.dim
            );
            let vv = match norms {
                Some(nr) if nr[j] > 0.0 => v / nr[j],
                _ => v,
            };
            s += vv * w[j];
        }
        Ok(s)
    }

    /// Save in the versioned binary format, atomically: the bytes are
    /// written to a temp file in the target directory and `rename`d
    /// over `path`, so a concurrent reader (a serving daemon watching
    /// the path) sees either the old complete file or the new one,
    /// never a torn write. This rename is the hot-swap publish step.
    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let path = path.as_ref();
        let w = self.w();
        let norms = self.norms();
        let flags = if norms.is_some() { MODEL_FLAG_HAS_NORMS } else { 0 };
        let w_off = MODEL_HEADER_LEN as u64;
        let mut header = ModelHeader {
            dim: self.dim as u64,
            flags,
            checksum: 0,
            offsets: [w_off, w_off + 8 * self.dim as u64],
        };
        let mut payload = Vec::with_capacity(8 * (self.dim + norms.map_or(0, |n| n.len())));
        for x in w {
            payload.extend_from_slice(&x.to_le_bytes());
        }
        if let Some(nr) = norms {
            for x in nr {
                payload.extend_from_slice(&x.to_le_bytes());
            }
        }
        // Payload-first stream, then the header minus the checksum
        // field — the store's coverage discipline.
        let mut sum = Checksum::new();
        sum.update(&payload);
        update_model_header(&mut sum, &header.encode());
        header.checksum = sum.finish();
        let mut bytes = Vec::with_capacity(MODEL_HEADER_LEN + payload.len());
        bytes.extend_from_slice(&header.encode());
        bytes.extend_from_slice(&payload);
        let dir = path.parent().filter(|d| !d.as_os_str().is_empty());
        let tmp = dir
            .unwrap_or_else(|| Path::new("."))
            .join(format!(".rsm-tmp-{}", std::process::id()));
        std::fs::write(&tmp, &bytes).with_context(|| format!("write {}", tmp.display()))?;
        std::fs::rename(&tmp, path).with_context(|| {
            std::fs::remove_file(&tmp).ok();
            format!("publish {}", path.display())
        })?;
        Ok(())
    }

    /// Open a binary scoring model with full integrity checking
    /// (header geometry + whole-file checksum).
    pub fn open(path: impl AsRef<Path>) -> Result<ScoringModel> {
        Self::open_impl(path.as_ref(), true)
    }

    /// Open without the checksum pass. The header is still fully
    /// validated — bad magic, unknown versions, unknown flag bits, and
    /// broken geometry are refused here exactly as on the checked path;
    /// only payload corruption can slip through.
    pub fn open_unchecked(path: impl AsRef<Path>) -> Result<ScoringModel> {
        Self::open_impl(path.as_ref(), false)
    }

    fn open_impl(path: &Path, verify: bool) -> Result<ScoringModel> {
        let name = path.display().to_string();
        let map = Mmap::open(path)?;
        let bytes = map.bytes();
        let header = ModelHeader::decode(bytes, bytes.len() as u64)
            .with_context(|| format!("{name}: invalid scoring model"))?;
        if verify {
            let mut sum = Checksum::new();
            sum.update(&bytes[MODEL_HEADER_LEN..]);
            update_model_header(&mut sum, bytes);
            ensure!(
                sum.finish() == header.checksum,
                "{name}: checksum mismatch — the model file is corrupt (expected {:#018x}, \
                 found {:#018x})",
                header.checksum,
                sum.finish()
            );
        }
        let dim = usize::try_from(header.dim).context("model dim overflows usize")?;
        let span = |sec: usize| {
            let off = header.offsets[sec] as usize;
            (off, off + header.section_len(sec) as usize)
        };
        let w_span = span(MSEC_WEIGHTS);
        let norms_span = header.has_norms().then(|| span(MSEC_NORMS));
        // Validate the casts once so the accessors can't fail later.
        cast_slice::<f64>(&bytes[w_span.0..w_span.1])
            .with_context(|| format!("{name}: weights section"))?;
        if let Some((lo, hi)) = norms_span {
            cast_slice::<f64>(&bytes[lo..hi]).with_context(|| format!("{name}: norms section"))?;
        }
        Ok(ScoringModel { backing: Backing::Mapped { map, w_span, norms_span }, dim })
    }

    /// Load a model of either format: binary `.rsm` (sniffed by magic
    /// bytes) or the legacy plain-text `ranksvm-model v1` (which never
    /// records normalization — such models score raw features, the
    /// pre-ScoringModel behavior). Rejects pallas stores by name so a
    /// swapped `--model`/`--data` pair fails legibly.
    pub fn load_auto(path: impl AsRef<Path>) -> Result<ScoringModel> {
        Self::load_auto_with(path, true)
    }

    /// [`Self::load_auto`] with an explicit verification toggle for the
    /// binary path (`false` maps to [`Self::open_unchecked`]).
    pub fn load_auto_with(path: impl AsRef<Path>, verify: bool) -> Result<ScoringModel> {
        let path = path.as_ref();
        let mut magic = [0u8; 7];
        let sniffed = std::fs::File::open(path)
            .and_then(|mut f| std::io::Read::read_exact(&mut f, &mut magic))
            .is_ok();
        if sniffed && magic == MODEL_MAGIC {
            return if verify { Self::open(path) } else { Self::open_unchecked(path) };
        }
        if sniffed && magic == crate::data::store::MAGIC {
            bail!(
                "{} is a pallas data store, not a model (pass it as --data)",
                path.display()
            );
        }
        Ok(Self::from_rank_model(&RankModel::load(path)?))
    }
}

impl std::fmt::Debug for ScoringModel {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ScoringModel")
            .field("dim", &self.dim)
            .field("normalize", &self.normalize_name())
            .field("mapped", &self.is_mapped())
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!("ranksvm_scoring_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    fn sample_model(with_norms: bool) -> ScoringModel {
        let w = vec![1.5, -2.25e-10, 0.0, 3.7e8, -1.0];
        let norms = with_norms.then(|| vec![2.0, 1.0, 0.0, 4.0, 0.5]);
        ScoringModel::new(w, norms).unwrap()
    }

    #[test]
    fn save_open_round_trips_bits() {
        for with_norms in [false, true] {
            let m = sample_model(with_norms);
            let path = tmp(&format!("rt_{with_norms}.rsm"));
            m.save(&path).unwrap();
            let back = ScoringModel::open(&path).unwrap();
            assert_eq!(back.w(), m.w());
            assert_eq!(back.norms(), m.norms());
            assert_eq!(back.dim(), m.dim());
            let unchecked = ScoringModel::open_unchecked(&path).unwrap();
            assert_eq!(unchecked.w(), m.w());
        }
    }

    #[test]
    fn save_is_byte_deterministic() {
        let m = sample_model(true);
        let (a, b) = (tmp("det_a.rsm"), tmp("det_b.rsm"));
        m.save(&a).unwrap();
        m.save(&b).unwrap();
        assert_eq!(std::fs::read(a).unwrap(), std::fs::read(b).unwrap());
    }

    #[test]
    fn kernel_matches_rank_model_predict() {
        let ds = synthetic::cadata_like(40, 9);
        let w: Vec<f64> = (0..ds.dim()).map(|j| (j as f64 - 3.0) * 0.25).collect();
        let model = RankModel::new(w.clone());
        let scoring = ScoringModel::from_rank_model(&model);
        let a = model.predict(&ds);
        let b = scoring.scores(&ds);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    #[test]
    fn norms_equal_scoring_pre_normalized_data() {
        let ds = synthetic::cadata_like(60, 17);
        let norms: Vec<f64> = crate::data::store::compute_col_stats(ds.x.view())
            .iter()
            .map(|s| s.sumsq.sqrt())
            .collect();
        let w: Vec<f64> = (0..ds.dim()).map(|j| 0.1 * (j as f64 + 1.0)).collect();
        let with_norms = ScoringModel::new(w.clone(), Some(norms.clone())).unwrap();
        let mut scaled = crate::data::materialize(&ds);
        scaled.x.map_values(|c, v| if norms[c] > 0.0 { v / norms[c] } else { v });
        let plain = ScoringModel::new(w, None).unwrap();
        let a = with_norms.scores(&ds);
        let b = plain.scores(&scaled);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    #[test]
    fn score_indexed_rejects_out_of_dim() {
        let m = sample_model(true);
        assert!(m.score_indexed(&[(0, 1.0), (4, 2.0)]).is_ok());
        let err = m.score_indexed(&[(5, 1.0)]).unwrap_err().to_string();
        assert!(err.contains("out of range"), "{err}");
    }

    #[test]
    fn legacy_text_models_still_load() {
        let rank = RankModel::new(vec![0.5, -1.5, 2.0]);
        let path = tmp("legacy.txt");
        rank.save(&path).unwrap();
        let m = ScoringModel::load_auto(&path).unwrap();
        assert_eq!(m.w(), &rank.w[..]);
        assert!(m.norms().is_none());
        assert_eq!(m.normalize_name(), "none");
    }

    #[test]
    fn load_auto_names_a_store_legibly() {
        let ds = synthetic::cadata_like(20, 3);
        let text = tmp("store_src.libsvm");
        crate::data::libsvm::write(&ds, &text).unwrap();
        let store = tmp("store_src.pstore");
        let opts = crate::data::store::ConvertOptions::default();
        crate::data::store::convert_libsvm(&text, &store, &opts).unwrap();
        let err = ScoringModel::load_auto(&store).unwrap_err().to_string();
        assert!(err.contains("pallas data store"), "{err}");
    }

    #[test]
    fn checksum_skips_only_its_own_field() {
        let m = sample_model(true);
        let path = tmp("sum.rsm");
        m.save(&path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        let header = ModelHeader::decode(&bytes, bytes.len() as u64).unwrap();
        let mut sum = Checksum::new();
        sum.update(&bytes[MODEL_HEADER_LEN..]);
        update_model_header(&mut sum, &bytes);
        assert_eq!(sum.finish(), header.checksum);
    }

    #[test]
    fn header_roundtrip_and_refusals() {
        let m = sample_model(true);
        let path = tmp("hdr.rsm");
        m.save(&path).unwrap();
        let good = std::fs::read(&path).unwrap();
        let h = ModelHeader::decode(&good, good.len() as u64).unwrap();
        assert_eq!(ModelHeader::decode(&h.encode(), good.len() as u64).unwrap(), h);

        let mut bad = good.clone();
        bad[0] = b'X';
        let err = ModelHeader::decode(&bad, good.len() as u64).unwrap_err().to_string();
        assert!(err.contains("magic"), "{err}");

        for bad_version in [0u8, 2, 99] {
            let mut bad = good.clone();
            bad[7] = bad_version;
            let err = ModelHeader::decode(&bad, good.len() as u64).unwrap_err().to_string();
            assert!(err.contains("version"), "{bad_version}: {err}");
        }

        let mut bad = good.clone();
        bad[MODEL_HEADER_LEN - 1] = 1;
        let err = ModelHeader::decode(&bad, good.len() as u64).unwrap_err().to_string();
        assert!(err.contains("reserved"), "{err}");

        // Unknown flag bit: refused structurally (both open paths).
        let mut hdr = h;
        hdr.flags |= 1 << 13;
        let err = ModelHeader::decode(&hdr.encode(), good.len() as u64).unwrap_err().to_string();
        assert!(err.contains("flag"), "{err}");

        // Truncation and trailing bytes.
        assert!(ModelHeader::decode(&good, good.len() as u64 - 8).is_err());
        assert!(ModelHeader::decode(&good, good.len() as u64 + 8).is_err());
        let mut hdr = h;
        hdr.dim = u64::MAX;
        assert!(ModelHeader::decode(&hdr.encode(), good.len() as u64).is_err());
    }

    #[test]
    fn atomic_save_leaves_no_temp_files() {
        let m = sample_model(false);
        let path = tmp("atomic.rsm");
        m.save(&path).unwrap();
        let dir = path.parent().unwrap();
        let leftovers: Vec<_> = std::fs::read_dir(dir)
            .unwrap()
            .filter_map(|e| e.ok())
            .filter(|e| e.file_name().to_string_lossy().starts_with(".rsm-tmp-"))
            .collect();
        assert!(leftovers.is_empty(), "{leftovers:?}");
    }
}
