//! Memory probing for the Fig.-3 benchmark.
//!
//! Peak RSS of an in-process run is contaminated by earlier allocations,
//! so the benchmark measures each (method, m) point in a *fresh child
//! process*: the bench spawns `ranksvm mem-probe ...`, the child trains
//! for a bounded number of iterations, reads its own `VmHWM`, and prints
//! one JSON line the parent parses. std::process only — no extra deps.

use crate::coordinator::{train, Method, TrainConfig};
use crate::data::{synthetic, DatasetView};
use crate::util::json::Json;
use anyhow::{Context, Result};

/// Child-side entry: build the dataset, train, print `{peak_rss_kib, ...}`.
pub fn run_probe(
    dataset: &str,
    m: usize,
    method: Method,
    lambda: f64,
    max_iter: usize,
    seed: u64,
) -> Result<()> {
    let ds = match dataset {
        "cadata" => synthetic::cadata_like(m, seed),
        "reuters" => synthetic::reuters_like(m, seed),
        // smaller vocabulary for quick tests
        "reuters-small" => synthetic::reuters_like_with(m, 5000, 30, seed),
        other => anyhow::bail!("unknown synthetic dataset {other:?}"),
    };
    let cfg = TrainConfig { method, lambda, max_iter, ..Default::default() };
    let out = train(&ds, &cfg)?;
    let peak = crate::util::peak_rss_kib().context("VmHWM unavailable")?;
    crate::obs::log::data(
        &Json::obj(vec![
            ("dataset", dataset.into()),
            ("m", m.into()),
            ("method", method.name().into()),
            ("iterations", out.iterations.into()),
            ("peak_rss_kib", (peak as usize).into()),
        ])
        .to_string(),
    );
    Ok(())
}

/// Child-side entry for real files: train from a libsvm text file or a
/// pallas store (autodetected; a store trains zero-copy off the mapping,
/// which is exactly the difference this probe exists to measure).
/// `no_verify` skips the store's open-time checksum/structure scan — a
/// full-file read that would page everything in and contaminate the
/// peak-RSS figure this probe reports.
pub fn run_probe_path(
    path: &str,
    method: Method,
    lambda: f64,
    max_iter: usize,
    no_verify: bool,
) -> Result<()> {
    let loaded = crate::data::load_auto_with(path, !no_verify)?;
    let ds = loaded.view();
    let cfg = TrainConfig { method, lambda, max_iter, ..Default::default() };
    let out = train(ds, &cfg)?;
    let peak = crate::util::peak_rss_kib().context("VmHWM unavailable")?;
    crate::obs::log::data(
        &Json::obj(vec![
            ("dataset", ds.name().into()),
            ("format", if loaded.is_store() { "pstore" } else { "libsvm" }.into()),
            ("m", ds.len().into()),
            ("method", method.name().into()),
            ("iterations", out.iterations.into()),
            ("peak_rss_kib", (peak as usize).into()),
        ])
        .to_string(),
    );
    Ok(())
}

/// Child-side entry for a CV sweep over a real file: run the parallel
/// λ-path engine and report peak RSS. Folds are zero-copy index views
/// into the one mapping (`coordinator::modelsel`), so a store's CV peak
/// must stay close to a plain training's — the bounded-memory
/// regression test in `tests/modelsel.rs` pins the ratio.
pub fn run_probe_cv(
    path: &str,
    method: Method,
    lambdas: &[f64],
    folds: usize,
    max_iter: usize,
    no_verify: bool,
) -> Result<()> {
    let loaded = crate::data::load_auto_with(path, !no_verify)?;
    let ds = loaded.view();
    let base = TrainConfig { method, max_iter, ..Default::default() };
    let cfg = crate::coordinator::CvConfig::new(base, lambdas.to_vec(), folds, 42);
    let report = crate::coordinator::cv_sweep(ds, &cfg)?;
    let peak = crate::util::peak_rss_kib().context("VmHWM unavailable")?;
    crate::obs::log::data(
        &Json::obj(vec![
            ("dataset", ds.name().into()),
            ("format", if loaded.is_store() { "pstore" } else { "libsvm" }.into()),
            ("m", ds.len().into()),
            ("method", method.name().into()),
            ("folds", folds.into()),
            ("points", report.points.len().into()),
            ("iterations", report.total_iterations.into()),
            ("peak_rss_kib", (peak as usize).into()),
        ])
        .to_string(),
    );
    Ok(())
}

/// Locate the `ranksvm` CLI binary for probe spawning: `$RANKSVM_BIN`,
/// else a `ranksvm` sibling of the current executable (bench binaries
/// live in `target/release/deps/`, the CLI one level up), else
/// `target/release/ranksvm` relative to the working directory.
pub fn find_cli_bin() -> Result<std::path::PathBuf> {
    if let Ok(p) = std::env::var("RANKSVM_BIN") {
        return Ok(p.into());
    }
    if let Ok(exe) = std::env::current_exe() {
        if exe.file_name().map(|f| f.to_string_lossy().starts_with("ranksvm")).unwrap_or(false)
            && !exe.parent().map(|p| p.ends_with("deps")).unwrap_or(false)
        {
            return Ok(exe);
        }
        for anc in exe.ancestors().skip(1) {
            let cand = anc.join("ranksvm");
            if cand.is_file() {
                return Ok(cand);
            }
        }
    }
    let fallback = std::path::Path::new("target/release/ranksvm");
    anyhow::ensure!(
        fallback.is_file(),
        "ranksvm binary not found; build with `cargo build --release` or set RANKSVM_BIN"
    );
    Ok(fallback.to_path_buf())
}

/// Parent-side helper: spawn the CLI binary as a probe child and
/// return its peak RSS in KiB.
pub fn spawn_probe(
    dataset: &str,
    m: usize,
    method: Method,
    lambda: f64,
    max_iter: usize,
) -> Result<u64> {
    let exe = find_cli_bin()?;
    let out = std::process::Command::new(exe)
        .args([
            "mem-probe",
            "--dataset",
            dataset,
            "--m",
            &m.to_string(),
            "--method",
            method.name(),
            "--lambda",
            &lambda.to_string(),
            "--max-iter",
            &max_iter.to_string(),
        ])
        .output()
        .context("spawning mem-probe child")?;
    anyhow::ensure!(
        out.status.success(),
        "probe failed: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let stdout = String::from_utf8_lossy(&out.stdout);
    parse_peak(&stdout).context("parsing probe output")
}

/// Extract `peak_rss_kib` from the probe's JSON line (tiny ad-hoc parse —
/// the format is ours).
pub fn parse_peak(stdout: &str) -> Option<u64> {
    let key = "\"peak_rss_kib\":";
    let pos = stdout.find(key)? + key.len();
    let rest = &stdout[pos..];
    let end = rest.find(['}', ','])?;
    rest[..end].trim().parse().ok()
}

#[cfg(test)]
mod tests {
    #[test]
    fn parse_peak_extracts_value() {
        let s = r#"{"dataset":"cadata","m":100,"method":"tree","iterations":5,"peak_rss_kib":12345}"#;
        assert_eq!(super::parse_peak(s), Some(12345));
        assert_eq!(super::parse_peak("{}"), None);
    }
}
