//! On-disk layout of the pallas store (`.pstore`).
//!
//! A store is one flat file: a fixed-size header followed by 8-byte
//! aligned little-endian sections holding the CSR arrays, labels, query
//! ids, the precomputed query-group index, and cached per-column
//! statistics. Section *offsets* live in the header; section *lengths*
//! are derived from the header counts, so a header that passes
//! validation pins the entire file geometry. The normative byte-level
//! spec (with a flag-bit registry and the refusal policy) lives in
//! `docs/STORE_FORMAT.md`; a test pins this module to it.
//!
//! ```text
//! offset  size  field
//! ------  ----  -----------------------------------------------
//!      0     7  magic "PSTORE\0"
//!      7     1  format version (3)
//!      8     8  rows (m)                u64 LE
//!     16     8  cols (n)                u64 LE
//!     24     8  nnz                     u64 LE
//!     32     8  flags (bit 0: has qid;  u64 LE
//!                      bit 1: has colstats)
//!     40     8  n_groups                u64 LE
//!     48     8  n_pairs                 u64 LE
//!     56     8  checksum (FNV-1a 64; see below)
//!     64  9×8  section offsets         u64 LE each
//!    136    56  reserved (must be zero)
//!    192     …  sections (8-aligned, zero-padded between):
//!               indptr   (m+1)·u64   CSR row offsets
//!               indices  nnz·u32     CSR column indices
//!               values   nnz·f64     CSR values
//!               y        m·f64       utility labels
//!               qid      m·u64       query ids        (grouped only)
//!               goff     (g+1)·u64   group offsets    (grouped only)
//!               gex      m·u64       group example idx (grouped only)
//!               gpairs   g·u64       per-group pairs  (grouped only)
//!               colstats n·40 bytes  per-column stats (flag bit 1)
//! ```
//!
//! `n_pairs` is the comparable-pair count of the training objective:
//! the whole-vector count for a global ranking, the sum of per-group
//! counts for grouped data — both exact integers, so the loaded value
//! is bit-identical to what the text path recomputes.
//!
//! **Column statistics (version 3).** The `colstats` section caches one
//! [`ColStat`] record per feature column — stored-entry count, value
//! sum, sum of squares, min, and max — so normalization and
//! model-selection passes skip their `O(m·s)` scan. The floating-point
//! fields are defined as the *serial row-major fold* over the stored
//! CSR entries (see `docs/DETERMINISM.md`), which is what makes them
//! identical no matter how many threads converted the file.
//!
//! **Checksum coverage (since version 2).** The FNV-1a 64 stream covers
//! every byte of the file except the checksum field itself, in this
//! order: the payload (`bytes[HEADER_LEN..]`, as it is streamed to
//! disk), then the header bytes before the checksum field, then the
//! rest of the header. With full coverage *any* byte flip in a store is
//! a structured `open()` error (fuzzed in `tests/store.rs`). The
//! payload-first order lets the streaming writer fold the header in at
//! the end, when the section offsets are finally known.
//!
//! **Version policy.** Exactly one version is readable per build;
//! version-1 files (payload-only checksum) and version-2 files (128-byte
//! header, no colstats) are refused with a structured version error —
//! re-run `ranksvm convert` to regenerate them.

use anyhow::{bail, ensure, Result};

/// File magic: the first 7 bytes of every pallas store.
pub const MAGIC: [u8; 7] = *b"PSTORE\0";

/// Current format version (byte 7). Version 3 grew the header to 192
/// bytes (nine section-offset slots plus a reserved tail) and added the
/// checksummed `colstats` section; earlier versions are refused with a
/// version error rather than misread under the new geometry.
pub const VERSION: u8 = 3;

/// Total header size; the first section starts here (8-aligned).
pub const HEADER_LEN: usize = 192;

/// Byte range of the checksum field inside the header — the only bytes
/// the checksum stream skips.
pub const CHECKSUM_FIELD: std::ops::Range<usize> = 56..64;

/// First byte of the section-offset array inside the header.
pub const OFFSETS_START: usize = 64;

/// Section count/order. Indexes into [`Header::offsets`].
pub const SEC_INDPTR: usize = 0;
pub const SEC_INDICES: usize = 1;
pub const SEC_VALUES: usize = 2;
pub const SEC_Y: usize = 3;
pub const SEC_QID: usize = 4;
pub const SEC_GOFF: usize = 5;
pub const SEC_GEX: usize = 6;
pub const SEC_GPAIRS: usize = 7;
pub const SEC_COLSTATS: usize = 8;
pub const N_SECTIONS: usize = 9;

/// Header flag bit: the store carries query ids + a group index.
pub const FLAG_HAS_QID: u64 = 1;

/// Header flag bit: the store carries the per-column statistics
/// section (always set by the version-3 writer).
pub const FLAG_HAS_COLSTATS: u64 = 1 << 1;

/// Every flag bit this build understands; any other bit is refused.
pub const KNOWN_FLAGS: u64 = FLAG_HAS_QID | FLAG_HAS_COLSTATS;

/// Cached statistics of one feature column, over the column's *stored*
/// CSR entries (explicit zeros are never stored, so these describe the
/// non-zero structure). One record per column in the `colstats`
/// section, in column order.
///
/// - `nnz` is an exact integer;
/// - `min`/`max` are order-independent folds (both 0.0 for an empty
///   column);
/// - `sum`/`sumsq` are defined as the serial left-to-right fold over
///   the entries in row-major order — the converter computes them in
///   exactly that order regardless of its thread count, so the cached
///   values equal a from-scratch recomputation bit for bit (pinned at
///   `open()` and in `tests/store.rs`).
///
/// The column's ℓ2 norm is `sumsq.sqrt()` — what `--normalize l2-col`
/// consumes.
#[derive(Clone, Copy, Debug, PartialEq)]
#[repr(C)]
pub struct ColStat {
    /// Stored (non-zero) entries in this column.
    pub nnz: u64,
    /// Sum of the stored values (serial row-major fold).
    pub sum: f64,
    /// Sum of squared stored values (serial row-major fold).
    pub sumsq: f64,
    /// Smallest stored value (0.0 for an empty column).
    pub min: f64,
    /// Largest stored value (0.0 for an empty column).
    pub max: f64,
}

/// On-disk size of one [`ColStat`] record.
pub const COLSTAT_BYTES: usize = 40;
const _: () = assert!(std::mem::size_of::<ColStat>() == COLSTAT_BYTES);
const _: () = assert!(std::mem::align_of::<ColStat>() == 8);

/// Decoded header. Field meanings per the module layout table.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Header {
    pub rows: u64,
    pub cols: u64,
    pub nnz: u64,
    pub flags: u64,
    pub n_groups: u64,
    pub n_pairs: u64,
    pub checksum: u64,
    pub offsets: [u64; N_SECTIONS],
}

impl Header {
    pub fn has_qid(&self) -> bool {
        self.flags & FLAG_HAS_QID != 0
    }

    pub fn has_colstats(&self) -> bool {
        self.flags & FLAG_HAS_COLSTATS != 0
    }

    /// Byte length of each section, derived from the counts — `None`
    /// when a count is large enough to overflow (only reachable from a
    /// crafted/corrupt header; [`Self::decode`] rejects such files).
    pub fn checked_section_len(&self, sec: usize) -> Option<u64> {
        let grouped = |n: Option<u64>| if self.has_qid() { n } else { Some(0) };
        match sec {
            SEC_INDPTR => self.rows.checked_add(1)?.checked_mul(8),
            SEC_INDICES => self.nnz.checked_mul(4),
            SEC_VALUES => self.nnz.checked_mul(8),
            SEC_Y => self.rows.checked_mul(8),
            SEC_QID => grouped(self.rows.checked_mul(8)),
            SEC_GOFF => grouped(self.n_groups.checked_add(1).and_then(|g| g.checked_mul(8))),
            SEC_GEX => grouped(self.rows.checked_mul(8)),
            SEC_GPAIRS => grouped(self.n_groups.checked_mul(8)),
            SEC_COLSTATS => {
                if self.has_colstats() {
                    self.cols.checked_mul(COLSTAT_BYTES as u64)
                } else {
                    Some(0)
                }
            }
            _ => unreachable!("unknown section {sec}"),
        }
    }

    /// Byte length of each section for a header that already passed
    /// [`Self::decode`] (which rejected any overflowing counts).
    pub fn section_len(&self, sec: usize) -> u64 {
        self.checked_section_len(sec).expect("header counts validated by decode")
    }

    pub fn encode(&self) -> [u8; HEADER_LEN] {
        let mut out = [0u8; HEADER_LEN];
        out[..7].copy_from_slice(&MAGIC);
        out[7] = VERSION;
        let fields = [
            self.rows,
            self.cols,
            self.nnz,
            self.flags,
            self.n_groups,
            self.n_pairs,
            self.checksum,
        ];
        for (k, v) in fields.iter().enumerate() {
            out[8 + k * 8..16 + k * 8].copy_from_slice(&v.to_le_bytes());
        }
        for (k, v) in self.offsets.iter().enumerate() {
            let at = OFFSETS_START + k * 8;
            out[at..at + 8].copy_from_slice(&v.to_le_bytes());
        }
        // Bytes OFFSETS_START + 8·N_SECTIONS .. HEADER_LEN stay zero
        // (the reserved tail).
        out
    }

    /// Decode and *structurally* validate a header against the file
    /// length: magic, version, reserved bytes, section
    /// alignment/order/bounds. Content integrity (the checksum) is
    /// verified separately by the reader.
    pub fn decode(bytes: &[u8], file_len: u64) -> Result<Header> {
        ensure!(bytes.len() >= HEADER_LEN, "file too short for a pallas store header");
        ensure!(bytes[..7] == MAGIC, "not a pallas store (bad magic)");
        let version = bytes[7];
        if version != VERSION {
            bail!(
                "unsupported pallas store version {version} (this build reads {VERSION}; \
                 re-run `ranksvm convert` to regenerate older stores)"
            );
        }
        let u64_at = |off: usize| {
            let mut b = [0u8; 8];
            b.copy_from_slice(&bytes[off..off + 8]);
            u64::from_le_bytes(b)
        };
        let mut offsets = [0u64; N_SECTIONS];
        for (k, o) in offsets.iter_mut().enumerate() {
            *o = u64_at(OFFSETS_START + k * 8);
        }
        let h = Header {
            rows: u64_at(8),
            cols: u64_at(16),
            nnz: u64_at(24),
            flags: u64_at(32),
            n_groups: u64_at(40),
            n_pairs: u64_at(48),
            checksum: u64_at(56),
            offsets,
        };
        ensure!(
            bytes[OFFSETS_START + 8 * N_SECTIONS..HEADER_LEN].iter().all(|&b| b == 0),
            "reserved header bytes are not zero"
        );
        // Geometry: sections are in declaration order, 8-aligned, inside
        // the file, and the last one ends exactly at EOF.
        let mut cursor = HEADER_LEN as u64;
        for sec in 0..N_SECTIONS {
            let off = h.offsets[sec];
            let len = h
                .checked_section_len(sec)
                .ok_or_else(|| anyhow::anyhow!("section {sec} length overflows (corrupt counts)"))?;
            ensure!(off % 8 == 0, "section {sec} offset {off} is not 8-byte aligned");
            ensure!(off >= cursor, "section {sec} offset {off} overlaps its predecessor");
            let end = off
                .checked_add(len)
                .ok_or_else(|| anyhow::anyhow!("section {sec} length overflows"))?;
            ensure!(
                end <= file_len,
                "section {sec} ends at {end} but the file is {file_len} bytes (short file?)"
            );
            cursor = end;
        }
        ensure!(
            cursor == file_len,
            "file has {} trailing bytes past the last section",
            file_len - cursor
        );
        if !h.has_qid() {
            ensure!(h.n_groups == 0, "global store declares {} query groups", h.n_groups);
        }
        // Unknown flag bits mean a feature this build cannot honor (and
        // would otherwise be silently ignored) — reject them even on
        // the unchecked path.
        ensure!(
            h.flags & !KNOWN_FLAGS == 0,
            "unknown store flag bits {:#x}",
            h.flags & !KNOWN_FLAGS
        );
        Ok(h)
    }
}

/// Streaming FNV-1a (64-bit) — the store's corruption check. Not
/// cryptographic; it guards against torn writes, truncation, and bit
/// rot, which is what an on-disk training cache needs.
#[derive(Clone, Copy, Debug)]
pub struct Checksum(u64);

impl Checksum {
    pub fn new() -> Self {
        Checksum(0xcbf2_9ce4_8422_2325)
    }

    pub fn update(&mut self, bytes: &[u8]) {
        let mut h = self.0;
        for &b in bytes {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
        self.0 = h;
    }

    /// Fold the header into the stream (after the payload): every
    /// header byte except the checksum field itself. Writer and reader
    /// must call this with identical bytes, so the caller passes the
    /// encoded header with the checksum slot in any state — the slot is
    /// skipped.
    pub fn update_header(&mut self, header: &[u8]) {
        debug_assert!(header.len() >= HEADER_LEN);
        self.update(&header[..CHECKSUM_FIELD.start]);
        self.update(&header[CHECKSUM_FIELD.end..HEADER_LEN]);
    }

    pub fn finish(&self) -> u64 {
        self.0
    }
}

impl Default for Checksum {
    fn default() -> Self {
        Self::new()
    }
}

/// Marker for the plain-old-data section element types.
///
/// # Safety
/// Implementors must be valid for every bit pattern and free of padding.
pub unsafe trait Pod: Copy {}
unsafe impl Pod for u32 {}
unsafe impl Pod for u64 {}
unsafe impl Pod for f64 {}
// SAFETY: repr(C), five 8-byte fields, no padding (the const asserts
// above pin size and alignment); u64/f64 accept every bit pattern.
unsafe impl Pod for ColStat {}

/// Reinterpret a byte section as a typed slice — the zero-copy boundary.
/// Rejects misaligned or odd-length sections instead of copying; the
/// store keeps every section 8-aligned and the mmap base is page
/// aligned, so a rejection here means a corrupt or truncated file. The
/// sections are little-endian, hence the compile-time gate (big-endian
/// hosts would need a decode-copy path nothing currently targets).
#[cfg(target_endian = "little")]
pub fn cast_slice<T: Pod>(bytes: &[u8]) -> Result<&[T]> {
    let size = std::mem::size_of::<T>();
    ensure!(
        bytes.len() % size == 0,
        "section length {} is not a multiple of the element size {size}",
        bytes.len()
    );
    // SAFETY: T is Pod (valid for all bit patterns, no padding); the
    // prefix/suffix emptiness check below enforces alignment.
    let (prefix, mid, suffix) = unsafe { bytes.align_to::<T>() };
    ensure!(
        prefix.is_empty() && suffix.is_empty(),
        "section is misaligned for {}-byte elements",
        size
    );
    Ok(mid)
}

#[cfg(not(target_endian = "little"))]
pub fn cast_slice<T: Pod>(_bytes: &[u8]) -> Result<&[T]> {
    bail!("pallas stores are little-endian; this host is big-endian")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn header(rows: u64, nnz: u64, grouped: bool) -> Header {
        let mut h = Header {
            rows,
            cols: 3,
            nnz,
            flags: if grouped { FLAG_HAS_QID | FLAG_HAS_COLSTATS } else { FLAG_HAS_COLSTATS },
            n_groups: if grouped { 2 } else { 0 },
            n_pairs: 5,
            checksum: 0xdead_beef,
            offsets: [0; N_SECTIONS],
        };
        let mut cursor = HEADER_LEN as u64;
        for sec in 0..N_SECTIONS {
            h.offsets[sec] = cursor;
            cursor += h.section_len(sec).next_multiple_of(8);
        }
        h
    }

    fn file_len(h: &Header) -> u64 {
        h.offsets[N_SECTIONS - 1] + h.section_len(N_SECTIONS - 1)
    }

    #[test]
    fn header_roundtrip() {
        for grouped in [false, true] {
            let h = header(10, 37, grouped);
            let bytes = h.encode();
            let back = Header::decode(&bytes, file_len(&h)).unwrap();
            assert_eq!(back, h);
        }
    }

    #[test]
    fn colstats_section_length_follows_flag() {
        let mut h = header(4, 6, false);
        assert!(h.has_colstats());
        assert_eq!(h.section_len(SEC_COLSTATS), h.cols * COLSTAT_BYTES as u64);
        h.flags &= !FLAG_HAS_COLSTATS;
        assert_eq!(h.section_len(SEC_COLSTATS), 0);
    }

    #[test]
    fn decode_rejects_bad_magic_and_version() {
        let h = header(4, 6, false);
        let mut bytes = h.encode();
        bytes[0] = b'X';
        assert!(Header::decode(&bytes, file_len(&h)).unwrap_err().to_string().contains("magic"));
        // Older versions are refused with a structured version error
        // (the v1/v2 refusal policy), as are future versions.
        for bad_version in [1u8, 2, 99] {
            let mut bytes = h.encode();
            bytes[7] = bad_version;
            let err = Header::decode(&bytes, file_len(&h)).unwrap_err().to_string();
            assert!(err.contains("version"), "{bad_version}: {err}");
            assert!(err.contains("convert"), "{bad_version}: {err}");
        }
    }

    #[test]
    fn decode_rejects_nonzero_reserved_bytes() {
        let h = header(4, 6, false);
        let mut bytes = h.encode();
        bytes[HEADER_LEN - 1] = 1;
        let err = Header::decode(&bytes, file_len(&h)).unwrap_err();
        assert!(err.to_string().contains("reserved"), "{err}");
    }

    #[test]
    fn decode_rejects_bad_geometry() {
        let h = header(4, 6, true);
        let len = file_len(&h);
        // Short file.
        let err = Header::decode(&h.encode(), len - 8).unwrap_err();
        assert!(err.to_string().contains("short"), "{err}");
        // Trailing garbage.
        assert!(Header::decode(&h.encode(), len + 8).is_err());
        // Misaligned section.
        let mut bad = h;
        bad.offsets[SEC_VALUES] += 4;
        let err = Header::decode(&bad.encode(), len + 4).unwrap_err();
        assert!(err.to_string().contains("aligned"), "{err}");
        // Overlapping sections.
        let mut bad = h;
        bad.offsets[SEC_Y] = bad.offsets[SEC_VALUES];
        assert!(Header::decode(&bad.encode(), len).is_err());
        // Header shorter than HEADER_LEN.
        assert!(Header::decode(&h.encode()[..64], len).is_err());
        // Overflowing counts must be a clean rejection, not a wrap/panic.
        let mut bad = h;
        bad.rows = u64::MAX;
        let err = Header::decode(&bad.encode(), len).unwrap_err();
        assert!(err.to_string().contains("overflow"), "{err}");
        let mut bad = h;
        bad.nnz = u64::MAX / 2;
        assert!(Header::decode(&bad.encode(), len).is_err());
        let mut bad = h;
        bad.cols = u64::MAX / 2;
        assert!(Header::decode(&bad.encode(), len).is_err());
    }

    #[test]
    fn decode_rejects_unknown_flag_bits() {
        let mut h = header(4, 6, true);
        h.flags |= 1 << 17;
        let err = Header::decode(&h.encode(), file_len(&h)).unwrap_err();
        assert!(err.to_string().contains("flag"), "{err}");
    }

    #[test]
    fn header_checksum_skips_only_the_checksum_field() {
        let h = header(4, 6, false);
        let mut with_zero = h;
        with_zero.checksum = 0;
        let mut with_junk = h;
        with_junk.checksum = 0xDEAD_BEEF_DEAD_BEEF;
        let mut a = Checksum::new();
        a.update_header(&with_zero.encode());
        let mut b = Checksum::new();
        b.update_header(&with_junk.encode());
        assert_eq!(a.finish(), b.finish(), "checksum field must not feed the stream");
        // ...but any other header byte must.
        let mut tweaked = h;
        tweaked.cols += 1;
        let mut c = Checksum::new();
        c.update_header(&tweaked.encode());
        assert_ne!(a.finish(), c.finish());
    }

    #[test]
    fn checksum_is_order_sensitive_and_streaming() {
        let mut a = Checksum::new();
        a.update(b"hello ");
        a.update(b"world");
        let mut b = Checksum::new();
        b.update(b"hello world");
        assert_eq!(a.finish(), b.finish());
        let mut c = Checksum::new();
        c.update(b"world hello");
        assert_ne!(a.finish(), c.finish());
        // Known FNV-1a vector: empty input is the offset basis.
        assert_eq!(Checksum::new().finish(), 0xcbf2_9ce4_8422_2325);
    }

    #[test]
    fn cast_slice_checks_length_and_type() {
        let bytes: Vec<u8> = 1u64.to_le_bytes().into_iter().chain(2u64.to_le_bytes()).collect();
        // The Vec allocation is 8-aligned in practice for this test's
        // purposes only if the allocator says so; go through a u64 copy
        // to guarantee it.
        let words = [1u64, 2u64];
        let raw = unsafe {
            std::slice::from_raw_parts(words.as_ptr() as *const u8, 16)
        };
        assert_eq!(cast_slice::<u64>(raw).unwrap(), &[1, 2]);
        assert_eq!(cast_slice::<u32>(raw).unwrap(), &[1, 0, 2, 0]);
        assert!(cast_slice::<u64>(&raw[..12]).is_err()); // odd length
        assert_eq!(bytes.len(), 16);
    }

    #[test]
    fn colstat_cast_roundtrip() {
        let stats = [
            ColStat { nnz: 3, sum: 1.5, sumsq: 2.25, min: -1.0, max: 2.0 },
            ColStat { nnz: 0, sum: 0.0, sumsq: 0.0, min: 0.0, max: 0.0 },
        ];
        let mut bytes = Vec::new();
        for s in &stats {
            for v in [s.nnz, s.sum.to_bits(), s.sumsq.to_bits(), s.min.to_bits(), s.max.to_bits()]
            {
                bytes.extend_from_slice(&v.to_le_bytes());
            }
        }
        // Copy into an 8-aligned buffer before casting.
        let mut aligned = vec![0u64; bytes.len() / 8];
        let dst = unsafe {
            std::slice::from_raw_parts_mut(aligned.as_mut_ptr() as *mut u8, bytes.len())
        };
        dst.copy_from_slice(&bytes);
        let back: &[ColStat] = cast_slice(dst).unwrap();
        assert_eq!(back, &stats);
    }
}
