//! Compressed sparse row (CSR) and column (CSC) matrices.
//!
//! The Reuters-like workload is high-dimensional tf-idf-style data with
//! ~50 non-zeros per row; both score computation (`p = X·w`) and
//! subgradient accumulation (`a = Xᵀ·v`) run in `O(nnz)` over CSR. A CSC
//! copy is optional: the paper notes its implementation kept both a
//! row-optimized and a column-optimized copy of the data matrix, trading
//! 2× memory for speed (Fig. 3 discussion); `ablation_tree`/§Perf revisit
//! that trade-off here.

/// CSR sparse matrix (`rows × cols`), f64 values, usize column indices.
#[derive(Clone, Debug, PartialEq)]
pub struct CsrMatrix {
    rows: usize,
    cols: usize,
    /// Row start offsets, length `rows + 1`.
    indptr: Vec<usize>,
    /// Column indices, length nnz, ascending within each row.
    indices: Vec<u32>,
    /// Values, length nnz.
    values: Vec<f64>,
}

impl CsrMatrix {
    /// Build from triplets `(row, col, value)`. Duplicate entries are
    /// summed; zero values are kept (callers may prune beforehand).
    pub fn from_triplets(rows: usize, cols: usize, mut triplets: Vec<(usize, usize, f64)>) -> Self {
        triplets.sort_unstable_by_key(|&(r, c, _)| (r, c));
        let mut indptr = vec![0usize; rows + 1];
        let mut indices = Vec::with_capacity(triplets.len());
        let mut values: Vec<f64> = Vec::with_capacity(triplets.len());
        let mut last: Option<(usize, usize)> = None;
        for (r, c, v) in triplets {
            assert!(r < rows && c < cols, "triplet ({r},{c}) out of bounds");
            if last == Some((r, c)) {
                *values.last_mut().unwrap() += v;
            } else {
                indptr[r + 1] += 1;
                indices.push(c as u32);
                values.push(v);
                last = Some((r, c));
            }
        }
        for i in 0..rows {
            indptr[i + 1] += indptr[i];
        }
        CsrMatrix { rows, cols, indptr, indices, values }
    }

    /// Build directly from CSR arrays (validated).
    pub fn from_raw(
        rows: usize,
        cols: usize,
        indptr: Vec<usize>,
        indices: Vec<u32>,
        values: Vec<f64>,
    ) -> Self {
        assert_eq!(indptr.len(), rows + 1);
        assert_eq!(indices.len(), values.len());
        assert_eq!(*indptr.last().unwrap_or(&0), indices.len());
        for w in indptr.windows(2) {
            assert!(w[0] <= w[1], "indptr must be non-decreasing");
        }
        assert!(indices.iter().all(|&c| (c as usize) < cols), "column index out of bounds");
        CsrMatrix { rows, cols, indptr, indices, values }
    }

    /// Dense → CSR (drops exact zeros).
    pub fn from_dense(x: &super::dense::DenseMatrix) -> Self {
        let mut triplets = Vec::new();
        for i in 0..x.rows() {
            for (j, &v) in x.row(i).iter().enumerate() {
                if v != 0.0 {
                    triplets.push((i, j, v));
                }
            }
        }
        CsrMatrix::from_triplets(x.rows(), x.cols(), triplets)
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    #[inline]
    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    /// Average non-zeros per row — the paper's sparsity parameter `s`.
    pub fn avg_nnz_per_row(&self) -> f64 {
        if self.rows == 0 {
            0.0
        } else {
            self.nnz() as f64 / self.rows as f64
        }
    }

    /// Non-zeros of row `i` as `(indices, values)`.
    #[inline]
    pub fn row(&self, i: usize) -> (&[u32], &[f64]) {
        let (lo, hi) = (self.indptr[i], self.indptr[i + 1]);
        (&self.indices[lo..hi], &self.values[lo..hi])
    }

    /// `p = X·w` (length `rows`), `O(nnz)`.
    pub fn matvec(&self, w: &[f64], out: &mut [f64]) {
        assert_eq!(w.len(), self.cols);
        assert_eq!(out.len(), self.rows);
        for i in 0..self.rows {
            let (idx, val) = self.row(i);
            let mut s = 0.0;
            for (&j, &v) in idx.iter().zip(val) {
                s += v * w[j as usize];
            }
            out[i] = s;
        }
    }

    /// `a = Xᵀ·v` (length `cols`), `O(nnz)` scatter. `out` overwritten.
    pub fn matvec_t(&self, v: &[f64], out: &mut [f64]) {
        assert_eq!(v.len(), self.rows);
        assert_eq!(out.len(), self.cols);
        out.iter_mut().for_each(|x| *x = 0.0);
        for i in 0..self.rows {
            let vi = v[i];
            if vi != 0.0 {
                let (idx, val) = self.row(i);
                for (&j, &x) in idx.iter().zip(val) {
                    out[j as usize] += vi * x;
                }
            }
        }
    }

    /// Dot product of row `i` with a dense vector (prediction path).
    pub fn row_dot(&self, i: usize, w: &[f64]) -> f64 {
        let (idx, val) = self.row(i);
        let mut s = 0.0;
        for (&j, &v) in idx.iter().zip(val) {
            s += v * w[j as usize];
        }
        s
    }

    /// Extract a row-range submatrix `[lo, hi)` (used by train/test splits
    /// and the query-grouped loss).
    pub fn row_range(&self, lo: usize, hi: usize) -> CsrMatrix {
        assert!(lo <= hi && hi <= self.rows);
        let (a, b) = (self.indptr[lo], self.indptr[hi]);
        let indptr: Vec<usize> = self.indptr[lo..=hi].iter().map(|&p| p - a).collect();
        CsrMatrix {
            rows: hi - lo,
            cols: self.cols,
            indptr,
            indices: self.indices[a..b].to_vec(),
            values: self.values[a..b].to_vec(),
        }
    }

    /// Gather an arbitrary subset of rows into a new matrix.
    pub fn select_rows(&self, rows: &[usize]) -> CsrMatrix {
        let mut triplets = Vec::new();
        for (new_i, &i) in rows.iter().enumerate() {
            let (idx, val) = self.row(i);
            for (&j, &v) in idx.iter().zip(val) {
                triplets.push((new_i, j as usize, v));
            }
        }
        CsrMatrix::from_triplets(rows.len(), self.cols, triplets)
    }

    /// Convert to CSC (column-optimized copy).
    pub fn to_csc(&self) -> CscMatrix {
        let mut colptr = vec![0usize; self.cols + 1];
        for &c in &self.indices {
            colptr[c as usize + 1] += 1;
        }
        for j in 0..self.cols {
            colptr[j + 1] += colptr[j];
        }
        let mut next = colptr.clone();
        let mut row_indices = vec![0u32; self.nnz()];
        let mut values = vec![0.0f64; self.nnz()];
        for i in 0..self.rows {
            let (idx, val) = self.row(i);
            for (&j, &v) in idx.iter().zip(val) {
                let slot = next[j as usize];
                row_indices[slot] = i as u32;
                values[slot] = v;
                next[j as usize] += 1;
            }
        }
        CscMatrix { rows: self.rows, cols: self.cols, colptr, row_indices, values }
    }

    /// Materialize as dense (tests / XLA tile feeding on small data).
    pub fn to_dense(&self) -> super::dense::DenseMatrix {
        let mut d = super::dense::DenseMatrix::zeros(self.rows, self.cols);
        for i in 0..self.rows {
            let (idx, val) = self.row(i);
            for (&j, &v) in idx.iter().zip(val) {
                d.set(i, j as usize, v);
            }
        }
        d
    }

    /// Approximate heap footprint in bytes (Fig-3 memory accounting).
    pub fn mem_bytes(&self) -> usize {
        self.indptr.len() * std::mem::size_of::<usize>()
            + self.indices.len() * std::mem::size_of::<u32>()
            + self.values.len() * std::mem::size_of::<f64>()
    }
}

/// CSC sparse matrix — column-major twin of [`CsrMatrix`]. Provides the
/// column-oriented `matvec_t` used by the two-copies ablation.
#[derive(Clone, Debug, PartialEq)]
pub struct CscMatrix {
    rows: usize,
    cols: usize,
    colptr: Vec<usize>,
    row_indices: Vec<u32>,
    values: Vec<f64>,
}

impl CscMatrix {
    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    #[inline]
    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    /// Non-zeros of column `j` as `(row indices, values)`.
    #[inline]
    pub fn col(&self, j: usize) -> (&[u32], &[f64]) {
        let (lo, hi) = (self.colptr[j], self.colptr[j + 1]);
        (&self.row_indices[lo..hi], &self.values[lo..hi])
    }

    /// `a = Xᵀ·v` computed column-wise: each `a[j]` is a gather over the
    /// column — no scatter, better locality when `v` is hot in cache.
    pub fn matvec_t(&self, v: &[f64], out: &mut [f64]) {
        assert_eq!(v.len(), self.rows);
        assert_eq!(out.len(), self.cols);
        for j in 0..self.cols {
            let (idx, val) = self.col(j);
            let mut s = 0.0;
            for (&i, &x) in idx.iter().zip(val) {
                s += x * v[i as usize];
            }
            out[j] = s;
        }
    }

    pub fn mem_bytes(&self) -> usize {
        self.colptr.len() * std::mem::size_of::<usize>()
            + self.row_indices.len() * std::mem::size_of::<u32>()
            + self.values.len() * std::mem::size_of::<f64>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::dense::DenseMatrix;
    use crate::util::rng::Rng;

    fn random_csr(rng: &mut Rng, rows: usize, cols: usize, density: f64) -> CsrMatrix {
        let mut t = Vec::new();
        for i in 0..rows {
            for j in 0..cols {
                if rng.bool(density) {
                    t.push((i, j, rng.normal()));
                }
            }
        }
        CsrMatrix::from_triplets(rows, cols, t)
    }

    #[test]
    fn triplets_sum_duplicates() {
        let m = CsrMatrix::from_triplets(2, 2, vec![(0, 1, 2.0), (0, 1, 3.0), (1, 0, 1.0)]);
        assert_eq!(m.nnz(), 2);
        let (idx, val) = m.row(0);
        assert_eq!(idx, &[1]);
        assert_eq!(val, &[5.0]);
    }

    #[test]
    fn matvec_matches_dense() {
        let mut rng = Rng::new(21);
        for _ in 0..20 {
            let rows = 1 + rng.below(40);
            let cols = 1 + rng.below(30);
            let m = random_csr(&mut rng, rows, cols, 0.3);
            let d = m.to_dense();
            let w: Vec<f64> = (0..cols).map(|_| rng.normal()).collect();
            let mut p1 = vec![0.0; rows];
            let mut p2 = vec![0.0; rows];
            m.matvec(&w, &mut p1);
            d.matvec(&w, &mut p2);
            for (a, b) in p1.iter().zip(&p2) {
                assert!((a - b).abs() < 1e-10);
            }
        }
    }

    #[test]
    fn matvec_t_matches_dense_and_csc() {
        let mut rng = Rng::new(23);
        for _ in 0..20 {
            let rows = 1 + rng.below(40);
            let cols = 1 + rng.below(30);
            let m = random_csr(&mut rng, rows, cols, 0.25);
            let d = m.to_dense();
            let csc = m.to_csc();
            let v: Vec<f64> = (0..rows).map(|_| rng.normal()).collect();
            let mut a1 = vec![0.0; cols];
            let mut a2 = vec![0.0; cols];
            let mut a3 = vec![0.0; cols];
            m.matvec_t(&v, &mut a1);
            d.matvec_t(&v, &mut a2);
            csc.matvec_t(&v, &mut a3);
            for i in 0..cols {
                assert!((a1[i] - a2[i]).abs() < 1e-10);
                assert!((a1[i] - a3[i]).abs() < 1e-10);
            }
        }
    }

    #[test]
    fn row_range_and_select() {
        let triplets = vec![(0, 0, 1.0), (1, 1, 2.0), (2, 2, 3.0), (3, 0, 4.0)];
        let m = CsrMatrix::from_triplets(4, 3, triplets);
        let r = m.row_range(1, 3);
        assert_eq!(r.rows(), 2);
        assert_eq!(r.row(0), (&[1u32][..], &[2.0][..]));
        assert_eq!(r.row(1), (&[2u32][..], &[3.0][..]));
        let s = m.select_rows(&[3, 0]);
        assert_eq!(s.row(0), (&[0u32][..], &[4.0][..]));
        assert_eq!(s.row(1), (&[0u32][..], &[1.0][..]));
    }

    #[test]
    fn round_trip_dense() {
        let d = DenseMatrix::from_rows(&[vec![0.0, 1.5], vec![2.5, 0.0]]);
        let m = CsrMatrix::from_dense(&d);
        assert_eq!(m.nnz(), 2);
        assert_eq!(m.to_dense(), d);
    }

    #[test]
    fn empty_matrix() {
        let m = CsrMatrix::from_triplets(0, 5, vec![]);
        assert_eq!(m.nnz(), 0);
        assert_eq!(m.avg_nnz_per_row(), 0.0);
        let mut out = vec![];
        m.matvec(&[0.0; 5], &mut out);
    }

    #[test]
    fn row_dot_matches_matvec() {
        let mut rng = Rng::new(29);
        let m = random_csr(&mut rng, 10, 8, 0.4);
        let w: Vec<f64> = (0..8).map(|_| rng.normal()).collect();
        let mut p = vec![0.0; 10];
        m.matvec(&w, &mut p);
        for i in 0..10 {
            assert!((m.row_dot(i, &w) - p[i]).abs() < 1e-12);
        }
    }

    #[test]
    #[should_panic]
    fn out_of_bounds_triplet_panics() {
        CsrMatrix::from_triplets(2, 2, vec![(2, 0, 1.0)]);
    }
}
