//! Training configuration: the registry-backed method handle,
//! hyper-parameters, and the λ ↔ C conversion the paper describes
//! (§5.1).

use crate::losses::registry::{self, LossSpec};

/// Which loss/subgradient oracle drives training — a handle to one
/// [`LossSpec`] in the loss registry
/// ([`crate::losses::registry::SPECS`]). The historical enum-style
/// spellings (`Method::Tree`, `Method::Pair`, …) are associated
/// constants, so existing call sites keep compiling; parsing accepts
/// every registered name and alias, so *new* registry losses need no
/// change here at all.
#[derive(Clone, Copy)]
pub struct Method(&'static LossSpec);

#[allow(non_upper_case_globals)]
impl Method {
    /// TreeRSVM — Algorithm 3 with the order-statistics red-black tree.
    pub const Tree: Method = Method(&registry::TREE);
    /// TreeRSVM with the duplicate-merging (`nodesize`) tree variant.
    pub const TreeDedup: Method = Method(&registry::TREE_DEDUP);
    /// TreeRSVM with the Fenwick counter (ablation).
    pub const TreeFenwick: Method = Method(&registry::TREE_FENWICK);
    /// PairRSVM — explicit O(m²) pair iteration under the same BMRM.
    pub const Pair: Method = Method(&registry::PAIR);
    /// SVM^rank stand-in — the r-level algorithm of Joachims (2006).
    pub const RLevel: Method = Method(&registry::RLEVEL);
    /// PRSVM — truncated Newton on the squared pairwise hinge, with the
    /// faithful O(m²)-memory pair materialization.
    pub const Prsvm: Method = Method(&registry::PRSVM);
    /// PRSVM objective with our O(m log m) sum-augmented-tree oracle
    /// (the Chapelle & Keerthi "improved version" — extension feature).
    pub const PrsvmTree: Method = Method(&registry::PRSVM_TREE);
    /// TopPush (arXiv:1410.1462) — bipartite top-of-ranking loss, the
    /// first non-pairwise registry entry.
    pub const TopPush: Method = Method(&registry::TOPPUSH);
}

/// Every registered method, registry order (includes every loss family;
/// filter on [`LossSpec::normalization`] to select the paper's
/// pairwise-comparable set for Fig.-4-style sweeps).
static ALL: [Method; 8] = [
    Method::Tree,
    Method::TreeDedup,
    Method::TreeFenwick,
    Method::Pair,
    Method::RLevel,
    Method::Prsvm,
    Method::PrsvmTree,
    Method::TopPush,
];

impl Method {
    /// Resolve a CLI spelling via the registry (canonical names and
    /// aliases).
    pub fn parse(s: &str) -> Option<Method> {
        registry::find(s).map(Method)
    }

    pub fn name(&self) -> &'static str {
        self.0.name
    }

    /// The registry record behind this handle (solver family, parallel
    /// substrate, normalization, oracle constructor).
    pub fn spec(&self) -> &'static LossSpec {
        self.0
    }

    /// All registered methods, for sweeps.
    pub fn all() -> &'static [Method] {
        &ALL
    }
}

impl PartialEq for Method {
    fn eq(&self, other: &Self) -> bool {
        std::ptr::eq(self.0, other.0)
    }
}

impl Eq for Method {}

impl std::fmt::Debug for Method {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_tuple("Method").field(&self.0.name).finish()
    }
}

/// Which backend executes the O(ms) linear algebra.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackendKind {
    /// Native CSR kernels.
    Native,
    /// Native with an extra CSC copy for the gradient (paper's
    /// two-copies trade-off).
    NativeCsc,
    /// AOT-compiled XLA executables via PJRT (dense tiles); requires
    /// `make artifacts`.
    Xla,
}

impl BackendKind {
    pub fn parse(s: &str) -> Option<BackendKind> {
        Some(match s {
            "native" => BackendKind::Native,
            "native-csc" | "csc" => BackendKind::NativeCsc,
            "xla" | "pjrt" => BackendKind::Xla,
            _ => return None,
        })
    }
}

/// Feature normalization applied before optimization.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Normalize {
    /// Train on the features exactly as loaded (the default).
    None,
    /// Divide every feature column by its ℓ2 norm over the training
    /// set. The norms come from the pallas store's cached column stats
    /// when the source carries them (skipping the `O(m·s)` scan) and
    /// from an identical row-major recomputation otherwise — training
    /// is bit-identical either way, and matches training on explicitly
    /// pre-normalized input (pinned in `tests/store.rs`). The trained
    /// weights live in the *normalized* feature space: score raw data
    /// with the same normalization applied.
    L2Col,
}

impl Normalize {
    pub fn parse(s: &str) -> Option<Normalize> {
        Some(match s {
            "none" => Normalize::None,
            "l2-col" | "l2col" => Normalize::L2Col,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Normalize::None => "none",
            Normalize::L2Col => "l2-col",
        }
    }
}

/// Full training configuration.
#[derive(Clone, Debug)]
pub struct TrainConfig {
    pub method: Method,
    pub backend: BackendKind,
    /// Regularizer weight λ in `R_emp + λ‖w‖²` (paper: 1e-1 for Cadata,
    /// 1e-5 for Reuters). When the right value is unknown, sweep a grid
    /// with k-fold CV instead of guessing: [`super::modelsel::cv_sweep`]
    /// / `ranksvm cv` run the whole λ path warm-started and in parallel,
    /// and report the winner per ranking metric.
    pub lambda: f64,
    /// BMRM gap tolerance ε (paper: 1e-3; for PRSVM the Newton decrement
    /// tolerance 1e-6 is derived as `epsilon * 1e-3`).
    pub epsilon: f64,
    pub max_iter: usize,
    /// Enable the OCAS-style line search extension.
    pub line_search: bool,
    /// Directory with `manifest.txt` + `*.hlo.txt` for the XLA backend.
    pub artifacts_dir: String,
    /// Emit per-iteration JSON lines to stderr.
    pub verbose: bool,
    /// When set, write a structured JSONL run trace here — one event per
    /// BMRM iteration (docs/OBSERVABILITY.md; CLI `train --trace`).
    /// Tracing is inert: the trained model is byte-identical with or
    /// without it (pinned by `tests/obs.rs`).
    pub trace_path: Option<String>,
    /// Worker threads for the sharded oracle and the parallel native
    /// backend; `0` (the default) resolves to the host's available
    /// parallelism. Any value produces bit-identical training results —
    /// the shard/chunk reductions are order-fixed (see
    /// [`crate::losses::ShardedTreeOracle`] and
    /// [`crate::compute::ParallelBackend`]; the contract is written
    /// down in `docs/DETERMINISM.md`).
    pub n_threads: usize,
    /// Feature normalization applied before optimization (CLI
    /// `--normalize`).
    pub normalize: Normalize,
    /// Per-chunk working-set target for the cache-aware parallel plans,
    /// in KiB (CLI `--chunk-target-kib`); `0` (the default) probes half
    /// of L2 from sysfs, and the `RANKSVM_CHUNK_KIB` environment
    /// variable slots between the two. Chunk counts shape only
    /// integer-exact decompositions — never a float reduction — so any
    /// value produces bit-identical training results
    /// ([`crate::runtime::cache`]).
    pub chunk_target_kib: usize,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            method: Method::Tree,
            backend: BackendKind::Native,
            lambda: 1e-2,
            epsilon: 1e-3,
            max_iter: 2000,
            line_search: false,
            artifacts_dir: "artifacts".to_string(),
            verbose: false,
            trace_path: None,
            n_threads: 0,
            normalize: Normalize::None,
            chunk_target_kib: 0,
        }
    }
}

impl TrainConfig {
    /// SVM^rank / PRSVM use `C` multiplied into an *unnormalized* risk;
    /// the paper gives the conversion `C = 1/(λN)`.
    pub fn c_equivalent(&self, n_pairs: f64) -> f64 {
        1.0 / (self.lambda * n_pairs)
    }

    /// The concrete worker count: `n_threads`, with `0` resolved to the
    /// host's available parallelism (1 if that probe fails).
    pub fn resolved_threads(&self) -> usize {
        crate::util::resolve_threads(self.n_threads)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn method_parse_round_trip() {
        for &m in Method::all() {
            assert_eq!(Method::parse(m.name()), Some(m));
        }
        assert_eq!(Method::parse("svmrank"), Some(Method::RLevel));
        assert_eq!(Method::parse("toppush"), Some(Method::TopPush));
        assert_eq!(Method::parse("top-push"), Some(Method::TopPush));
        assert_eq!(Method::parse("nope"), None);
    }

    #[test]
    fn method_handles_expose_their_registry_spec() {
        use crate::losses::registry::{NewtonKind, SolverFamily, Substrate};
        assert_eq!(Method::Tree.spec().substrate, Substrate::ShardedTree);
        assert_eq!(Method::TopPush.spec().substrate, Substrate::ShardedGroups);
        assert_eq!(Method::Prsvm.spec().solver, SolverFamily::Newton);
        assert_eq!(Method::Prsvm.spec().newton, Some(NewtonKind::MaterializedPairs));
        assert_eq!(Method::PrsvmTree.spec().newton, Some(NewtonKind::SumTree));
        assert_eq!(format!("{:?}", Method::TopPush), "Method(\"toppush\")");
        // Every registered loss is reachable as a Method.
        assert_eq!(Method::all().len(), crate::losses::registry::SPECS.len());
    }

    #[test]
    fn normalize_parse_round_trip() {
        for n in [Normalize::None, Normalize::L2Col] {
            assert_eq!(Normalize::parse(n.name()), Some(n));
        }
        assert_eq!(Normalize::parse("l2col"), Some(Normalize::L2Col));
        assert_eq!(Normalize::parse("zscore"), None);
        assert_eq!(TrainConfig::default().normalize, Normalize::None);
    }

    #[test]
    fn backend_parse() {
        assert_eq!(BackendKind::parse("xla"), Some(BackendKind::Xla));
        assert_eq!(BackendKind::parse("native"), Some(BackendKind::Native));
        assert_eq!(BackendKind::parse("zzz"), None);
    }

    #[test]
    fn c_conversion() {
        let cfg = TrainConfig { lambda: 0.1, ..Default::default() };
        assert!((cfg.c_equivalent(100.0) - 0.1).abs() < 1e-12);
    }

    #[test]
    fn thread_resolution() {
        let auto = TrainConfig::default();
        assert_eq!(auto.n_threads, 0);
        assert!(auto.resolved_threads() >= 1);
        let fixed = TrainConfig { n_threads: 3, ..Default::default() };
        assert_eq!(fixed.resolved_threads(), 3);
    }
}
