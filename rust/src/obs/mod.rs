//! Unified telemetry layer: metrics registry, leveled logging, and
//! structured run traces (docs/OBSERVABILITY.md).
//!
//! Everything in this module is **provably inert**: instrumentation may
//! read clocks and bump relaxed atomics, but it must never feed a value
//! back into numeric control flow. Training with tracing on produces a
//! byte-identical model to training with tracing off (pinned by
//! `tests/obs.rs`), and the metrics registry is append-only bookkeeping
//! that no solver or scheduler decision ever reads. The contract is
//! spelled out normatively in docs/OBSERVABILITY.md and referenced from
//! the docs/DETERMINISM.md new-code checklist.
//!
//! Four sub-facilities:
//!
//! * [`metrics`] — process-wide registry of monotonic counters, gauges,
//!   and fixed-bucket histograms, rendered as Prometheus-style text by
//!   the serve daemon's `metrics` verb.
//! * [`log`] — a leveled stderr facade (`--quiet` / default / `--verbose`)
//!   shared by every subcommand, plus the one sanctioned stdout door for
//!   data-plane protocol lines outside `main.rs`.
//! * [`trace`] — the `train --trace out.jsonl` run-trace sink (one JSONL
//!   event per BMRM iteration) and the `ranksvm report` renderer.
//! * [`snapshot`] — the shared `BENCH_*.json` metrics-snapshot schema
//!   emitted by every bench binary and gated in CI.

pub mod log;
pub mod metrics;
pub mod snapshot;
pub mod trace;
