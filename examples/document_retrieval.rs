//! Query-grouped document retrieval (§2): preferences only within a
//! query's document set, loss averaged per query — the SVM^rank use case
//! from Joachims (2002).
//!
//!     cargo run --release --example document_retrieval

use ranksvm::coordinator::{evaluate, train, Method, TrainConfig};
use ranksvm::data::synthetic;
use ranksvm::metrics;

fn main() -> anyhow::Result<()> {
    // 80 queries × 25 candidate documents, 20 features; relevance has a
    // shared learnable component plus per-query nuisance offsets.
    let ds = synthetic::queries(80, 25, 20, 77);
    println!(
        "retrieval data: {} queries × 25 docs, n={}, grouped pairs = {}",
        80,
        ds.dim(),
        {
            let g = ranksvm::losses::QueryGrouped::new(
                ranksvm::losses::TreeOracle::new(),
                ds.qid.as_ref().unwrap(),
                &ds.y,
            );
            g.total_pairs() as u64
        }
    );

    // Hold out 20 whole queries for testing (split by index blocks: the
    // generator lays queries out contiguously).
    let train_rows: Vec<usize> = (0..60 * 25).collect();
    let test_rows: Vec<usize> = (60 * 25..80 * 25).collect();
    let tr = ds.subset(&train_rows, "train");
    let te = ds.subset(&test_rows, "test");

    let cfg = TrainConfig { method: Method::Tree, lambda: 0.01, ..Default::default() };
    let out = train(&tr, &cfg)?;
    println!(
        "trained: {} iters, objective {:.6}, {:.2}s",
        out.iterations, out.objective, out.train_secs
    );

    let err = evaluate(&out.model, &te);
    println!("held-out per-query pairwise error: {err:.4}");

    // Contrast with ignoring the query structure at training time.
    let mut flat = tr.clone();
    flat.qid = None;
    let flat_out = train(&flat, &cfg)?;
    let flat_pred = flat_out.model.predict(&te);
    let flat_err = metrics::grouped_pairwise_error(&flat_pred, &te.y, te.qid.as_ref().unwrap());
    println!("same model trained WITHOUT query grouping: {flat_err:.4}");
    println!("(grouping should help: per-query offsets are not learnable)");

    // Show a ranked list for one query.
    let q0 = ds.subset(&(0..25).collect::<Vec<_>>(), "q0");
    let order = out.model.rank(&q0);
    println!("\nquery 0 — top 5 docs by predicted relevance (true utility in parens):");
    for &i in order.iter().take(5) {
        println!("  doc {:2}  true utility {:+.3}", i, q0.y[i]);
    }
    Ok(())
}
