//! Sum-augmented order-statistics tree.
//!
//! Extends the paper's Definition-1 structure: besides the subtree
//! *count*, every node maintains subtree sums of an auxiliary per-key
//! value and of its square. `count_smaller` / `count_larger` then return
//! the aggregate `(count, Σv, Σv²)` over the matching keys in the same
//! `O(log m)` descent.
//!
//! This is what upgrades Algorithm 3 from hinge to *squared* hinge: the
//! per-example squared-hinge statistics
//! `Σ_j (1 + p_i − p_j)² = n(1+p_i)² − 2(1+p_i)·Σp_j + Σp_j²`
//! need exactly these three aggregates over the margin window — giving
//! an `O(ms + m log m)` PRSVM-objective oracle (the "improved version"
//! of Chapelle & Keerthi (2010) that the paper notes has no public
//! implementation; see `losses/squared_tree.rs`).

const NIL: u32 = 0;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Color {
    Red,
    Black,
}

#[derive(Clone, Debug)]
struct Node {
    key: f64,
    /// Auxiliary value attached to this key occurrence (e.g. the
    /// predicted score p_j while the key is the label y_j).
    val: f64,
    val_sq: f64,
    left: u32,
    right: u32,
    parent: u32,
    color: Color,
    size: u32,
    /// Subtree aggregates (including this node).
    sum: f64,
    sum_sq: f64,
}

/// Aggregate returned by the range queries.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Agg {
    pub count: u64,
    pub sum: f64,
    pub sum_sq: f64,
}

/// Order-statistics red-black tree with per-subtree value sums.
#[derive(Clone, Debug)]
pub struct SumTree {
    nodes: Vec<Node>,
    root: u32,
    len: u64,
}

impl SumTree {
    pub fn new() -> Self {
        let sentinel = Node {
            key: f64::NAN,
            val: 0.0,
            val_sq: 0.0,
            left: NIL,
            right: NIL,
            parent: NIL,
            color: Color::Black,
            size: 0,
            sum: 0.0,
            sum_sq: 0.0,
        };
        SumTree { nodes: vec![sentinel], root: NIL, len: 0 }
    }

    pub fn len(&self) -> u64 {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn clear(&mut self) {
        self.nodes.truncate(1);
        self.root = NIL;
        self.len = 0;
    }

    #[inline]
    fn n(&self, i: u32) -> &Node {
        &self.nodes[i as usize]
    }

    #[inline]
    fn nm(&mut self, i: u32) -> &mut Node {
        &mut self.nodes[i as usize]
    }

    #[inline]
    fn fix_aggregates(&mut self, x: u32) {
        let (l, r) = (self.n(x).left, self.n(x).right);
        let (ls, lsum, lsq) = (self.n(l).size, self.n(l).sum, self.n(l).sum_sq);
        let (rs, rsum, rsq) = (self.n(r).size, self.n(r).sum, self.n(r).sum_sq);
        let node = self.nm(x);
        node.size = ls + rs + 1;
        node.sum = lsum + rsum + node.val;
        node.sum_sq = lsq + rsq + node.val_sq;
    }

    /// Insert `(key, val)` — `O(log m)`. NaN keys rejected.
    pub fn insert(&mut self, key: f64, val: f64) {
        assert!(!key.is_nan(), "NaN keys are not orderable");
        self.len += 1;
        let id = self.nodes.len() as u32;
        self.nodes.push(Node {
            key,
            val,
            val_sq: val * val,
            left: NIL,
            right: NIL,
            parent: NIL,
            color: Color::Red,
            size: 1,
            sum: val,
            sum_sq: val * val,
        });
        if self.root == NIL {
            self.nm(id).color = Color::Black;
            self.root = id;
            return;
        }
        // Descend, updating aggregates on the path.
        let mut x = self.root;
        loop {
            {
                let node = self.nm(x);
                node.size += 1;
                node.sum += val;
                node.sum_sq += val * val;
            }
            let k = self.n(x).key;
            let next = if key < k { self.n(x).left } else { self.n(x).right };
            if next == NIL {
                if key < k {
                    self.nm(x).left = id;
                } else {
                    self.nm(x).right = id;
                }
                self.nm(id).parent = x;
                self.insert_fixup(id);
                return;
            }
            x = next;
        }
    }

    fn rotate_left(&mut self, x: u32) {
        let y = self.n(x).right;
        let yl = self.n(y).left;
        self.nm(x).right = yl;
        if yl != NIL {
            self.nm(yl).parent = x;
        }
        let xp = self.n(x).parent;
        self.nm(y).parent = xp;
        if xp == NIL {
            self.root = y;
        } else if self.n(xp).left == x {
            self.nm(xp).left = y;
        } else {
            self.nm(xp).right = y;
        }
        self.nm(y).left = x;
        self.nm(x).parent = y;
        self.fix_aggregates(x);
        self.fix_aggregates(y);
    }

    fn rotate_right(&mut self, x: u32) {
        let y = self.n(x).left;
        let yr = self.n(y).right;
        self.nm(x).left = yr;
        if yr != NIL {
            self.nm(yr).parent = x;
        }
        let xp = self.n(x).parent;
        self.nm(y).parent = xp;
        if xp == NIL {
            self.root = y;
        } else if self.n(xp).left == x {
            self.nm(xp).left = y;
        } else {
            self.nm(xp).right = y;
        }
        self.nm(y).right = x;
        self.nm(x).parent = y;
        self.fix_aggregates(x);
        self.fix_aggregates(y);
    }

    fn insert_fixup(&mut self, mut z: u32) {
        while self.n(self.n(z).parent).color == Color::Red {
            let p = self.n(z).parent;
            let g = self.n(p).parent;
            if p == self.n(g).left {
                let u = self.n(g).right;
                if self.n(u).color == Color::Red {
                    self.nm(p).color = Color::Black;
                    self.nm(u).color = Color::Black;
                    self.nm(g).color = Color::Red;
                    z = g;
                } else {
                    if z == self.n(p).right {
                        z = p;
                        self.rotate_left(z);
                    }
                    let p = self.n(z).parent;
                    let g = self.n(p).parent;
                    self.nm(p).color = Color::Black;
                    self.nm(g).color = Color::Red;
                    self.rotate_right(g);
                }
            } else {
                let u = self.n(g).left;
                if self.n(u).color == Color::Red {
                    self.nm(p).color = Color::Black;
                    self.nm(u).color = Color::Black;
                    self.nm(g).color = Color::Red;
                    z = g;
                } else {
                    if z == self.n(p).left {
                        z = p;
                        self.rotate_right(z);
                    }
                    let p = self.n(z).parent;
                    let g = self.n(p).parent;
                    self.nm(p).color = Color::Black;
                    self.nm(g).color = Color::Red;
                    self.rotate_left(g);
                }
            }
        }
        let r = self.root;
        self.nm(r).color = Color::Black;
    }

    /// Aggregate over keys strictly smaller than `k` — `O(log m)`.
    pub fn agg_smaller(&self, k: f64) -> Agg {
        let mut out = Agg::default();
        let mut x = self.root;
        while x != NIL {
            let node = self.n(x);
            if node.key < k {
                let l = self.n(node.left);
                out.count += (l.size + 1) as u64;
                out.sum += l.sum + node.val;
                out.sum_sq += l.sum_sq + node.val_sq;
                x = node.right;
            } else {
                x = node.left;
            }
        }
        out
    }

    /// Aggregate over keys strictly larger than `k` — `O(log m)`.
    pub fn agg_larger(&self, k: f64) -> Agg {
        let mut out = Agg::default();
        let mut x = self.root;
        while x != NIL {
            let node = self.n(x);
            if node.key > k {
                let r = self.n(node.right);
                out.count += (r.size + 1) as u64;
                out.sum += r.sum + node.val;
                out.sum_sq += r.sum_sq + node.val_sq;
                x = node.left;
            } else {
                x = node.right;
            }
        }
        out
    }

    /// Invariant checker (tests): RB rules, BST order, aggregates.
    pub fn check_invariants(&self) {
        if self.root == NIL {
            assert_eq!(self.len, 0);
            return;
        }
        assert_eq!(self.n(self.root).color, Color::Black);
        let (size, _, sum, _) = self.check_node(self.root, f64::NEG_INFINITY, f64::INFINITY);
        assert_eq!(size as u64, self.len);
        let direct: f64 = (1..self.nodes.len()).map(|i| self.nodes[i].val).sum();
        assert!((sum - direct).abs() < 1e-9 * (1.0 + direct.abs()), "sum aggregate drift");
    }

    fn check_node(&self, x: u32, lo: f64, hi: f64) -> (u32, u32, f64, f64) {
        if x == NIL {
            return (0, 1, 0.0, 0.0);
        }
        let node = self.n(x);
        assert!(node.key >= lo && node.key <= hi, "BST violated");
        if node.color == Color::Red {
            assert_eq!(self.n(node.left).color, Color::Black);
            assert_eq!(self.n(node.right).color, Color::Black);
        }
        let (ls, lb, lsum, lsq) = self.check_node(node.left, lo, node.key);
        let (rs, rb, rsum, rsq) = self.check_node(node.right, node.key, hi);
        assert_eq!(lb, rb, "black height");
        assert_eq!(node.size, ls + rs + 1, "size augmentation");
        let sum = lsum + rsum + node.val;
        let sq = lsq + rsq + node.val_sq;
        assert!((node.sum - sum).abs() < 1e-9 * (1.0 + sum.abs()), "sum augmentation");
        assert!((node.sum_sq - sq).abs() < 1e-9 * (1.0 + sq.abs()), "sum_sq augmentation");
        let bh = lb + if node.color == Color::Black { 1 } else { 0 };
        (node.size, bh, sum, sq)
    }
}

impl Default for SumTree {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn aggregates_match_bruteforce() {
        let mut rng = Rng::new(71);
        for _ in 0..25 {
            let mut t = SumTree::new();
            let n = 1 + rng.below(300);
            let mut items: Vec<(f64, f64)> = Vec::new();
            let universe = 1 + rng.below(40);
            for _ in 0..n {
                let k = rng.below(universe) as f64;
                let v = rng.normal();
                t.insert(k, v);
                items.push((k, v));
            }
            t.check_invariants();
            for _ in 0..30 {
                let q = rng.range(-1.0, universe as f64 + 1.0);
                let smaller = t.agg_smaller(q);
                let want_c = items.iter().filter(|(k, _)| *k < q).count() as u64;
                let want_s: f64 = items.iter().filter(|(k, _)| *k < q).map(|(_, v)| v).sum();
                let want_q: f64 = items.iter().filter(|(k, _)| *k < q).map(|(_, v)| v * v).sum();
                assert_eq!(smaller.count, want_c);
                assert!((smaller.sum - want_s).abs() < 1e-9 * (1.0 + want_s.abs()));
                assert!((smaller.sum_sq - want_q).abs() < 1e-9 * (1.0 + want_q.abs()));
                let larger = t.agg_larger(q);
                let want_c = items.iter().filter(|(k, _)| *k > q).count() as u64;
                assert_eq!(larger.count, want_c);
            }
        }
    }

    #[test]
    fn invariants_after_adversarial_order() {
        let mut t = SumTree::new();
        for i in 0..2000 {
            t.insert(i as f64, i as f64 * 0.5);
        }
        t.check_invariants();
        let a = t.agg_smaller(1000.0);
        assert_eq!(a.count, 1000);
        assert!((a.sum - (0..1000).map(|i| i as f64 * 0.5).sum::<f64>()).abs() < 1e-6);
    }

    #[test]
    fn clear_and_reuse() {
        let mut t = SumTree::new();
        t.insert(1.0, 2.0);
        t.clear();
        assert!(t.is_empty());
        assert_eq!(t.agg_smaller(10.0), Agg::default());
        t.insert(3.0, 4.0);
        assert_eq!(t.agg_smaller(10.0).count, 1);
    }

    #[test]
    fn counts_match_plain_ostree() {
        use crate::rbtree::OsTree;
        let mut rng = Rng::new(73);
        let mut sum_tree = SumTree::new();
        let mut os_tree = OsTree::new();
        for _ in 0..500 {
            let k = rng.below(20) as f64;
            sum_tree.insert(k, rng.normal());
            os_tree.insert(k);
        }
        for q in 0..21 {
            let q = q as f64 - 0.5;
            assert_eq!(sum_tree.agg_smaller(q).count, os_tree.count_smaller(q));
            assert_eq!(sum_tree.agg_larger(q).count, os_tree.count_larger(q));
        }
    }
}
