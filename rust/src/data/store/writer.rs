//! Streaming libsvm → pallas-store converter, with a sharded parallel
//! parse phase.
//!
//! Conversion is a two-phase pipeline:
//!
//! 1. **Parallel parse** — the input is split into disjoint byte ranges
//!    (shards), one stealable task per shard on a
//!    [`crate::runtime::WorkerPool`] (the same work-stealing scheduler
//!    that runs the training oracles). Each worker scans forward to the
//!    first line boundary of its range, then parses every line that
//!    *starts* inside the range, accumulating local CSR spill segments
//!    (fixed-budget buffers spilling to per-shard temp files), labels,
//!    qids, per-row counts, and per-column count/min/max partials.
//! 2. **Serial deterministic stitch** — shard results are concatenated
//!    in byte order (which *is* row order), the group index and pair
//!    counts are computed on the stitched vectors, integer and min/max
//!    column partials merge in fixed shard order, and the
//!    floating-point column `sum`/`sumsq` stats are computed in one
//!    serial pass over the spill segments in row-major entry order.
//!
//! Integer counts decompose exactly across shards and min/max folds are
//! order-independent over finite values, while every floating-point
//! reduction runs serially in an order fixed by the data — the three
//! invariants of `docs/DETERMINISM.md`. The emitted `.pstore` is
//! therefore **byte-identical for any thread count** (including the
//! single-shard serial path), which `tests/store.rs` and CI pin by
//! whole-file comparison.
//!
//! Memory stays bounded as in the serial converter: per-example state is
//! `O(m)`, and the matrix payload streams through spill buffers whose
//! combined budget is `chunk_bytes` (split across shards).
//! `ConvertStats::max_buffered_bytes` reports the summed high-water mark
//! of all spill buffers, so tests can assert the bound instead of hoping
//! RSS behaves.

use super::format::{
    Checksum, Header, FLAG_HAS_COLSTATS, FLAG_HAS_QID, HEADER_LEN, N_SECTIONS, SEC_COLSTATS,
    SEC_GEX, SEC_GOFF, SEC_GPAIRS, SEC_INDICES, SEC_INDPTR, SEC_QID, SEC_VALUES, SEC_Y,
};
use super::mmap::fadvise_sequential;
use crate::data::libsvm::{parse_line, Example, RowAccumulator};
use crate::losses::{count_comparable_pairs, GroupIndex};
use crate::runtime::pool::{Task, WorkerPool};
use anyhow::{bail, Context, Result};
use std::io::{BufRead, BufReader, Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};

/// Converter knobs.
#[derive(Clone, Copy, Debug)]
pub struct ConvertOptions {
    /// Combined budget (bytes) for the feature spill buffers — the
    /// chunk size of the chunked ingest, split across shards. The
    /// converter's transient matrix memory never exceeds this (plus a
    /// few bytes of per-buffer slack and one buffer's worth of copy
    /// scratch during assembly).
    pub chunk_bytes: usize,
    /// Worker threads for the parse phase: `0` = all cores, `1` (the
    /// default) = serial. The output bytes are identical for every
    /// value — parallelism only changes wall-clock.
    pub n_threads: usize,
}

impl Default for ConvertOptions {
    fn default() -> Self {
        // 8 MiB moves ~350k sparse rows per flush; small enough that a
        // laptop never notices, big enough that syscalls don't dominate.
        ConvertOptions { chunk_bytes: 8 << 20, n_threads: 1 }
    }
}

/// What the converter did — printed as JSON by `ranksvm convert`.
#[derive(Clone, Copy, Debug)]
pub struct ConvertStats {
    pub rows: usize,
    pub cols: usize,
    pub nnz: usize,
    pub n_groups: usize,
    /// Comparable pairs of the training objective (global count, or the
    /// per-group sum for qid data).
    pub n_pairs: u64,
    /// Final store size in bytes.
    pub out_bytes: u64,
    /// Summed high-water mark of the feature spill buffers (≤
    /// `chunk_bytes` plus one entry of slack per buffer) — the "bounded
    /// memory" guarantee, made measurable.
    pub max_buffered_bytes: usize,
    /// Resolved worker-thread count of the parse phase.
    pub threads: usize,
    /// Byte-range shards the input was parsed as (1 = serial path).
    pub shards: usize,
}

/// A byte sink that spills to a temp file whenever the in-memory buffer
/// reaches its budget.
struct SpillBuf {
    file: std::fs::File,
    path: PathBuf,
    buf: Vec<u8>,
    cap: usize,
    spilled: u64,
}

impl SpillBuf {
    fn create(path: PathBuf, cap: usize) -> Result<Self> {
        // Read + write: the same handle is rewound and read back during
        // assembly (a write-only fd would EBADF on that read).
        let file = std::fs::OpenOptions::new()
            .read(true)
            .write(true)
            .create(true)
            .truncate(true)
            .open(&path)
            .with_context(|| format!("create spill file {}", path.display()))?;
        Ok(SpillBuf { file, path, buf: Vec::new(), cap: cap.max(64), spilled: 0 })
    }

    fn push(&mut self, bytes: &[u8]) -> Result<()> {
        self.buf.extend_from_slice(bytes);
        if self.buf.len() >= self.cap {
            self.flush()?;
        }
        Ok(())
    }

    fn flush(&mut self) -> Result<()> {
        if !self.buf.is_empty() {
            self.file.write_all(&self.buf).context("writing spill file")?;
            self.spilled += self.buf.len() as u64;
            self.buf.clear();
        }
        Ok(())
    }

    /// Total bytes pushed so far (spilled + still buffered).
    fn len(&self) -> u64 {
        self.spilled + self.buf.len() as u64
    }

    /// Reopen for reading from the start (after a final flush).
    fn into_reader(mut self) -> Result<(std::fs::File, PathBuf)> {
        self.flush()?;
        self.file.seek(SeekFrom::Start(0)).context("rewinding spill file")?;
        Ok((self.file, self.path))
    }
}

/// Checksummed, position-tracking section writer for the output file.
struct SectionWriter {
    out: std::io::BufWriter<std::fs::File>,
    pos: u64,
    sum: Checksum,
}

impl SectionWriter {
    fn write(&mut self, bytes: &[u8]) -> Result<()> {
        self.out.write_all(bytes).context("writing store")?;
        self.sum.update(bytes);
        self.pos += bytes.len() as u64;
        Ok(())
    }

    /// Zero-pad to the next 8-byte boundary (padding is checksummed like
    /// any other payload byte).
    fn pad8(&mut self) -> Result<()> {
        let rem = (self.pos % 8) as usize;
        if rem != 0 {
            self.write(&[0u8; 8][..8 - rem])?;
        }
        Ok(())
    }

    /// Buffered u64 stream write (little-endian).
    fn write_u64s<I: IntoIterator<Item = u64>>(&mut self, items: I) -> Result<()> {
        let mut chunk = [0u8; 8 * 512];
        let mut fill = 0usize;
        for v in items {
            chunk[fill..fill + 8].copy_from_slice(&v.to_le_bytes());
            fill += 8;
            if fill == chunk.len() {
                self.write(&chunk)?;
                fill = 0;
            }
        }
        if fill > 0 {
            self.write(&chunk[..fill])?;
        }
        Ok(())
    }
}

/// Everything one parse shard produced. The stitch phase consumes these
/// strictly in shard (= byte) order, which is what keeps the output
/// independent of how many shards there were.
struct ShardData {
    y: Vec<f64>,
    qids: Vec<u64>,
    any_qid: bool,
    max_col: usize,
    /// Per-row stored-entry counts, in row order.
    row_nnz: Vec<u64>,
    nnz: u64,
    /// Text lines this shard consumed (blank/comment lines included) —
    /// what lets the stitch phase reconstruct global line numbers.
    lines: usize,
    /// Per-column stored-entry counts (exact integers).
    col_nnz: Vec<u64>,
    /// Per-column min over stored values (+inf where the shard saw none).
    col_min: Vec<f64>,
    /// Per-column max over stored values (−inf where the shard saw none).
    col_max: Vec<f64>,
    ind: SpillBuf,
    val: SpillBuf,
    max_buffered: usize,
}

/// Why a parse shard stopped early.
enum ShardFail {
    /// `parse_line` rejected a line. Only the *local* line index is
    /// known inside a shard; the stitch phase adds the preceding shards'
    /// line counts and re-parses the saved text to produce the exact
    /// `name:line` error the serial path would have printed.
    Line { local: usize, text: String },
    /// Any other failure (I/O, index overflow) — already fully formed.
    Other(anyhow::Error),
}

type ShardSlot = Option<Result<ShardData, ShardFail>>;

/// Parse the lines of `input` whose first byte lies in `[lo, hi)`.
fn parse_shard(
    input: &Path,
    name: &str,
    lo: u64,
    hi: u64,
    spill_cap: usize,
    ind_path: PathBuf,
    val_path: PathBuf,
) -> Result<ShardData, ShardFail> {
    fn other<T>(r: Result<T>) -> Result<T, ShardFail> {
        r.map_err(ShardFail::Other)
    }
    let file = other(
        std::fs::File::open(input).with_context(|| format!("open {}", input.display())),
    )?;
    fadvise_sequential(&file);
    let mut reader = BufReader::new(file);
    let mut pos = lo;
    if lo > 0 {
        // A line belongs to the shard holding its first byte. Starting
        // one byte early and skipping to the first newline finds the
        // first line start ≥ lo (and classifies a line starting exactly
        // at lo correctly, since byte lo−1 is then the previous '\n').
        other(reader.seek(SeekFrom::Start(lo - 1)).context("seeking input shard"))?;
        let mut skip = Vec::new();
        let n =
            other(reader.read_until(b'\n', &mut skip).context("scanning shard boundary"))?;
        if skip.last() == Some(&b'\n') {
            pos = lo - 1 + n as u64;
        } else {
            // EOF inside the partial line: no line starts in this range.
            pos = hi;
        }
    }
    let mut ind = other(SpillBuf::create(ind_path, spill_cap))?;
    let mut val = other(SpillBuf::create(val_path, spill_cap))?;
    let mut acc = RowAccumulator::default();
    let mut row_nnz: Vec<u64> = Vec::new();
    let mut col_nnz: Vec<u64> = Vec::new();
    let mut col_min: Vec<f64> = Vec::new();
    let mut col_max: Vec<f64> = Vec::new();
    let mut nnz = 0u64;
    let mut lines = 0usize;
    let mut max_buffered = 0usize;
    let mut ex = Example::default();
    let mut line = String::new();
    while pos < hi {
        line.clear();
        let n = other(reader.read_line(&mut line).with_context(|| format!("reading {name}")))?;
        if n == 0 {
            break;
        }
        pos += n as u64;
        lines += 1;
        // The line number passed here is shard-local; if the line is
        // bad, the stitch phase recomputes the global number and
        // re-parses for the user-facing message.
        match parse_line(&line, name, lines, &mut ex) {
            Err(_) => return Err(ShardFail::Line { local: lines, text: line.clone() }),
            Ok(false) => continue,
            Ok(true) => {}
        }
        let row_start = nnz;
        other(acc.push(&ex, |idx, v| {
            let col = u32::try_from(idx - 1)
                .map_err(|_| anyhow::anyhow!("{name}: feature index {idx} exceeds u32"))?;
            ind.push(&col.to_le_bytes())?;
            val.push(&v.to_le_bytes())?;
            nnz += 1;
            let c = col as usize;
            if c >= col_nnz.len() {
                col_nnz.resize(c + 1, 0);
                col_min.resize(c + 1, f64::INFINITY);
                col_max.resize(c + 1, f64::NEG_INFINITY);
            }
            col_nnz[c] += 1;
            if v < col_min[c] {
                col_min[c] = v;
            }
            if v > col_max[c] {
                col_max[c] = v;
            }
            Ok(())
        }))?;
        row_nnz.push(nnz - row_start);
        max_buffered = max_buffered.max(ind.buf.len() + val.buf.len());
    }
    // Complete the spill files so the stitch phase can reopen them by
    // path for the stats pass.
    other(ind.flush())?;
    other(val.flush())?;
    Ok(ShardData {
        y: acc.y,
        qids: acc.qids,
        any_qid: acc.any_qid,
        max_col: acc.max_col,
        row_nnz,
        nnz,
        lines,
        col_nnz,
        col_min,
        col_max,
        ind,
        val,
        max_buffered,
    })
}

/// Convert a libsvm text file to a pallas store. Two-phase pipeline
/// (parallel parse, serial stitch), bounded memory; the output is
/// byte-for-byte deterministic in the input — independent of
/// `chunk_bytes` (flush cadence only) *and* of `n_threads` (shard
/// decomposition only). Tests pin both invariances.
pub fn convert_libsvm(
    input: impl AsRef<Path>,
    output: impl AsRef<Path>,
    opts: &ConvertOptions,
) -> Result<ConvertStats> {
    let input = input.as_ref();
    let output = output.as_ref();
    if input == output
        || (output.exists()
            && input
                .canonicalize()
                .ok()
                .zip(output.canonicalize().ok())
                .is_some_and(|(a, b)| a == b))
    {
        bail!("refusing to overwrite the input: output {} is the input file", output.display());
    }
    let meta = std::fs::metadata(input).with_context(|| format!("stat {}", input.display()))?;
    // Byte-range sharding needs a seekable regular file with a
    // trustworthy length. Anything else (FIFO, /dev/stdin, process
    // substitution — where metadata reports length 0 regardless of
    // content) streams serially to EOF instead: one shard spanning
    // [0, u64::MAX), which never seeks and reads until the pipe closes.
    let regular = meta.is_file();
    let file_len = if regular { meta.len() } else { u64::MAX };
    let threads = crate::util::resolve_threads(opts.n_threads);
    // Shard count: a few tasks per worker (the work-stealing scheduler
    // balances the rest), but never shards smaller than ~4 KiB — tiny
    // inputs take the single-shard serial path. The choice only affects
    // wall-clock, never a single output byte.
    let n_shards = if !regular || threads <= 1 || file_len < 8192 {
        1
    } else {
        ((4 * threads) as u64).min(file_len / 4096).clamp(1, 256) as usize
    };
    let tmp_paths: Vec<(PathBuf, PathBuf)> = (0..n_shards)
        .map(|k| {
            (
                output.with_extension(format!("pstore.s{k}.ind.tmp")),
                output.with_extension(format!("pstore.s{k}.val.tmp")),
            )
        })
        .collect();
    let mut output_created = false;
    let result = convert_impl(
        input,
        output,
        opts,
        file_len,
        threads,
        n_shards,
        &tmp_paths,
        &mut output_created,
    );
    if result.is_err() {
        // A failed conversion must leave neither a corrupt half-written
        // store (a zeroed header would autodetect as libsvm text and
        // fail confusingly downstream) nor spill litter behind — but
        // never delete an output this run didn't create (a parse
        // failure must not destroy a pre-existing good store).
        if output_created {
            std::fs::remove_file(output).ok();
        }
        for (ind, val) in &tmp_paths {
            std::fs::remove_file(ind).ok();
            std::fs::remove_file(val).ok();
        }
    }
    if let Ok(stats) = &result {
        // Global telemetry mirror (docs/OBSERVABILITY.md): cumulative
        // across every conversion this process performed.
        crate::obs::metrics::CONVERT_ROWS.add(stats.rows as u64);
        crate::obs::metrics::CONVERT_BYTES.add(stats.out_bytes);
        crate::obs::metrics::CONVERT_SHARDS.add(stats.shards as u64);
    }
    result
}

#[allow(clippy::too_many_arguments)]
fn convert_impl(
    input: &Path,
    output: &Path,
    opts: &ConvertOptions,
    file_len: u64,
    threads: usize,
    n_shards: usize,
    tmp_paths: &[(PathBuf, PathBuf)],
    output_created: &mut bool,
) -> Result<ConvertStats> {
    let name = input.display().to_string();

    // --- Phase 1: parse disjoint byte ranges. The per-row policy (zero
    // skip, feature-space widening, qid defaults) lives in the shared
    // RowAccumulator, so this path cannot drift from libsvm::parse. ---
    let spill_cap = (opts.chunk_bytes / (2 * n_shards)).max(64);
    let mut results: Vec<ShardSlot> = (0..n_shards).map(|_| None).collect();
    if n_shards == 1 {
        let (ind_path, val_path) = tmp_paths[0].clone();
        results[0] = Some(parse_shard(input, &name, 0, file_len, spill_cap, ind_path, val_path));
    } else {
        let pool = WorkerPool::new(threads.min(n_shards));
        let name_ref: &str = &name;
        let mut tasks: Vec<Task> = Vec::with_capacity(n_shards);
        for (k, slot) in results.iter_mut().enumerate() {
            let lo = k as u64 * file_len / n_shards as u64;
            let hi = (k as u64 + 1) * file_len / n_shards as u64;
            let (ind_path, val_path) = tmp_paths[k].clone();
            tasks.push(Box::new(move || {
                *slot = Some(parse_shard(
                    input, name_ref, lo, hi, spill_cap, ind_path, val_path,
                ));
            }));
        }
        pool.run(tasks);
    }

    // --- Earliest failure wins; every shard before it succeeded, so
    // the global line number of the offending line is exact. ---
    let mut shards: Vec<ShardData> = Vec::with_capacity(n_shards);
    let mut lines_before = 0usize;
    for slot in results {
        match slot.expect("every shard task ran") {
            Ok(s) => {
                lines_before += s.lines;
                shards.push(s);
            }
            Err(ShardFail::Other(e)) => return Err(e),
            Err(ShardFail::Line { local, text }) => {
                let global = lines_before + local;
                let mut ex = Example::default();
                return Err(match parse_line(&text, &name, global, &mut ex) {
                    Err(e) => e,
                    Ok(_) => anyhow::anyhow!("{name}:{global}: unparseable line"),
                });
            }
        }
    }

    // --- Phase 2: serial deterministic stitch, in shard (byte) order. ---
    let rows: usize = shards.iter().map(|s| s.y.len()).sum();
    let nnz: u64 = shards.iter().map(|s| s.nnz).sum();
    let any_qid = shards.iter().any(|s| s.any_qid);
    let max_col = shards.iter().map(|s| s.max_col).max().unwrap_or(0);
    let max_buffered: usize = shards.iter().map(|s| s.max_buffered).sum();

    let mut indptr: Vec<u64> = Vec::with_capacity(rows + 1);
    indptr.push(0);
    let mut running = 0u64;
    for s in &shards {
        for &c in &s.row_nnz {
            running += c;
            indptr.push(running);
        }
    }
    debug_assert_eq!(running, nnz);

    let mut y: Vec<f64> = Vec::with_capacity(rows);
    let mut qids: Vec<u64> = Vec::with_capacity(rows);
    for s in &mut shards {
        y.append(&mut s.y);
        qids.append(&mut s.qids);
    }
    let qid = if any_qid { Some(qids) } else { None };

    // Group index + pair counts (O(m) state, same code as the text
    // path so the loaded values are bit-identical).
    let gindex = qid.as_ref().map(|q| GroupIndex::build(q, &y));
    let n_pairs = match &gindex {
        Some(gi) => {
            let mut total = 0u64;
            for g in 0..gi.n_groups() {
                total += gi.group_pairs(g);
            }
            total
        }
        None => count_comparable_pairs(&y),
    };
    let n_groups = gindex.as_ref().map(|g| g.n_groups()).unwrap_or(0);

    // Column stats. Counts are exact integers and min/max folds are
    // order-independent over finite values, so the per-shard partials
    // merge in shard order without touching a bit; the float sums are
    // NOT order-independent, so they are computed below in one serial
    // pass in row-major entry order — the same fold a from-scratch
    // recomputation performs (docs/DETERMINISM.md, invariant 3).
    let mut col_nnz = vec![0u64; max_col];
    let mut col_min = vec![f64::INFINITY; max_col];
    let mut col_max = vec![f64::NEG_INFINITY; max_col];
    for s in &shards {
        for (c, &n) in s.col_nnz.iter().enumerate() {
            if n == 0 {
                continue;
            }
            col_nnz[c] += n;
            if s.col_min[c] < col_min[c] {
                col_min[c] = s.col_min[c];
            }
            if s.col_max[c] > col_max[c] {
                col_max[c] = s.col_max[c];
            }
        }
    }
    let (ind_spills, val_spills): (Vec<SpillBuf>, Vec<SpillBuf>) =
        shards.into_iter().map(|s| (s.ind, s.val)).unzip();
    let mut col_sum = vec![0.0f64; max_col];
    let mut col_sumsq = vec![0.0f64; max_col];
    for (ind, val) in ind_spills.iter().zip(&val_spills) {
        sum_spill_pair(ind, val, &mut col_sum, &mut col_sumsq)?;
    }

    // --- Assemble the output file. ---
    let mut flags = FLAG_HAS_COLSTATS;
    if qid.is_some() {
        flags |= FLAG_HAS_QID;
    }
    let mut header = Header {
        rows: rows as u64,
        cols: max_col as u64,
        nnz,
        flags,
        n_groups: n_groups as u64,
        n_pairs,
        checksum: 0,
        offsets: [0; N_SECTIONS],
    };
    let out_file = std::fs::File::create(output)
        .with_context(|| format!("create {}", output.display()))?;
    *output_created = true;
    let mut w = SectionWriter {
        out: std::io::BufWriter::new(out_file),
        pos: HEADER_LEN as u64,
        sum: Checksum::new(),
    };
    // Header placeholder; rewritten with the checksum at the end.
    w.out.write_all(&[0u8; HEADER_LEN]).context("writing store header")?;

    header.offsets[SEC_INDPTR] = w.pos;
    w.write_u64s(indptr.iter().copied())?;
    drop(indptr);

    w.pad8()?;
    header.offsets[SEC_INDICES] = w.pos;
    for spill in ind_spills {
        copy_spill(&mut w, spill, opts.chunk_bytes)?;
    }
    w.pad8()?;
    header.offsets[SEC_VALUES] = w.pos;
    for spill in val_spills {
        copy_spill(&mut w, spill, opts.chunk_bytes)?;
    }

    w.pad8()?;
    header.offsets[SEC_Y] = w.pos;
    w.write_u64s(y.iter().map(|v| v.to_bits()))?;

    header.offsets[SEC_QID] = w.pos;
    if let Some(q) = &qid {
        w.write_u64s(q.iter().copied())?;
    }
    header.offsets[SEC_GOFF] = w.pos;
    if let Some(gi) = &gindex {
        let (offsets, _, _) = gi.as_parts();
        w.write_u64s(offsets.iter().map(|&v| v as u64))?;
    }
    header.offsets[SEC_GEX] = w.pos;
    if let Some(gi) = &gindex {
        let (_, examples, _) = gi.as_parts();
        w.write_u64s(examples.iter().map(|&v| v as u64))?;
    }
    header.offsets[SEC_GPAIRS] = w.pos;
    if let Some(gi) = &gindex {
        let (_, _, pairs) = gi.as_parts();
        w.write_u64s(pairs.iter().copied())?;
    }

    header.offsets[SEC_COLSTATS] = w.pos;
    w.write_u64s((0..max_col).flat_map(|c| {
        let (mn, mx) = if col_nnz[c] == 0 { (0.0, 0.0) } else { (col_min[c], col_max[c]) };
        [col_nnz[c], col_sum[c].to_bits(), col_sumsq[c].to_bits(), mn.to_bits(), mx.to_bits()]
    }))?;

    let out_bytes = w.pos;
    // Fold the final header (checksum slot excluded) into the payload
    // stream — full-file coverage, so any later byte flip is caught.
    let mut sum = w.sum;
    sum.update_header(&header.encode());
    header.checksum = sum.finish();
    let mut out = w.out.into_inner().context("flushing store")?;
    out.seek(SeekFrom::Start(0)).context("rewinding store")?;
    out.write_all(&header.encode()).context("writing store header")?;
    out.sync_all().ok();
    drop(out);

    Ok(ConvertStats {
        rows,
        cols: max_col,
        nnz: nnz as usize,
        n_groups,
        n_pairs,
        out_bytes,
        max_buffered_bytes: max_buffered,
        threads,
        shards: n_shards,
    })
}

/// Accumulate per-column `sum`/`sumsq` from one shard's (index, value)
/// spill pair, in entry order. Called across shards in shard order,
/// this is exactly the serial row-major fold over the final CSR — the
/// converter's one deliberately serial float reduction.
fn sum_spill_pair(
    ind: &SpillBuf,
    val: &SpillBuf,
    sum: &mut [f64],
    sumsq: &mut [f64],
) -> Result<()> {
    let n = ind.len() / 4;
    debug_assert_eq!(ind.len() % 4, 0);
    debug_assert_eq!(val.len(), n * 8);
    let mut fi = BufReader::with_capacity(
        1 << 16,
        std::fs::File::open(&ind.path).context("reopening index spill")?,
    );
    let mut fv = BufReader::with_capacity(
        1 << 17,
        std::fs::File::open(&val.path).context("reopening value spill")?,
    );
    let mut cb = [0u8; 4];
    let mut vb = [0u8; 8];
    for _ in 0..n {
        fi.read_exact(&mut cb).context("reading index spill")?;
        fv.read_exact(&mut vb).context("reading value spill")?;
        let c = u32::from_le_bytes(cb) as usize;
        let v = f64::from_le_bytes(vb);
        sum[c] += v;
        sumsq[c] += v * v;
    }
    Ok(())
}

/// Read-buffer size for copying a spill of `expect` bytes with a
/// requested chunk size of `chunk_bytes`: the full chunk the caller
/// asked for (the old `clamp(4096, 8 << 20)` silently shrank requests
/// above 8 MiB, turning one configured read into many), shrunk to the
/// spill's actual length when that is smaller, floored at 4 KiB.
fn read_buf_len(chunk_bytes: usize, expect: u64) -> usize {
    let want = chunk_bytes.max(4096);
    (expect.min(want as u64) as usize).max(4096)
}

/// Copy a finalized spill file into the output in `chunk_bytes`-bounded
/// reads, then delete it. Verifies the byte count written during the
/// parse pass survived the round trip.
fn copy_spill(w: &mut SectionWriter, spill: SpillBuf, chunk_bytes: usize) -> Result<()> {
    let expect = spill.len();
    let (mut file, path) = spill.into_reader()?;
    let mut buf = vec![0u8; read_buf_len(chunk_bytes, expect)];
    let mut copied = 0u64;
    loop {
        let n = file.read(&mut buf).context("reading spill file")?;
        if n == 0 {
            break;
        }
        w.write(&buf[..n])?;
        copied += n as u64;
    }
    drop(file);
    std::fs::remove_file(&path).ok();
    if copied != expect {
        bail!("spill file {} changed size during conversion ({copied} vs {expect})", path.display());
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn read_buffer_covers_the_requested_chunk_size() {
        // Floors: tiny requests and tiny spills still get a sane buffer.
        assert_eq!(read_buf_len(0, 10), 4096);
        assert_eq!(read_buf_len(1024, 1 << 20), 4096);
        // A small spill never allocates the whole chunk.
        assert_eq!(read_buf_len(8 << 20, 10_000), 10_000);
        // The regression: chunk requests above 8 MiB are honored instead
        // of being silently clamped down to 8 MiB reads.
        assert_eq!(read_buf_len(32 << 20, u64::MAX), 32 << 20);
        assert_eq!(read_buf_len(8 << 20, u64::MAX), 8 << 20);
    }
}
