//! Vectorized sparse/dense kernels behind one runtime dispatch point,
//! under the bit-identity contract of `docs/DETERMINISM.md`.
//!
//! Every kernel here exists in two implementations — a **scalar
//! reference fold** and an **AVX2 lane-parallel** form — that are
//! *bit-identical by construction*, so the dispatch choice is invisible
//! in any result byte:
//!
//! * **Fixed 4-accumulator fold.** Both paths accumulate element `k`
//!   into accumulator `k % 4` and fold the four partials in the fixed
//!   serial order `((a₀ + a₁) + a₂) + a₃`, with the `len % 4` remainder
//!   added last, scalar, in element order. The AVX2 form keeps one
//!   partial per 64-bit lane, so its per-lane sums round exactly like
//!   the reference fold's accumulators.
//! * **No FMA.** The vector paths use separate `mul`/`add` instructions
//!   (`_mm256_mul_pd` + `_mm256_add_pd`), never fused multiply-add: an
//!   FMA rounds once where mul-then-add rounds twice, which would break
//!   scalar/SIMD bit parity. The speedup here comes from width and from
//!   shortening the sequential FP dependency chain, not from fusion.
//! * **Scatter stays ordered.** AVX2 has gathers but no scatter, so
//!   [`scatter_axpy`] vectorizes only the products (one 4-wide multiply)
//!   and applies the adds scalar, in entry order — the exact reference
//!   sequence, entry for entry.
//!
//! Dispatch is resolved once per process (`RANKSVM_KERNEL` env override
//! `auto`/`scalar`/`simd`, then CPU feature detection — AVX2 on x86_64,
//! scalar everywhere else) and cached in one atomic; [`force`] lets
//! tests and benches pin a path. Neither resolution nor [`force`] ever
//! hands out [`Kernel::Simd`] on a host that cannot run it, and because
//! the kernel entry points are safe pub fns taking a caller-supplied
//! [`Kernel`], each `Simd` arm re-checks the cached cpuid word before
//! entering its `target_feature` body anyway — a stray `Kernel::Simd`
//! value degrades to the bit-identical scalar fold, never to illegal
//! instructions. Each kernel *pass* (a whole matvec /
//! gradient scatter, not each row) bumps a registry counter
//! (`ranksvm_kernel_*_passes_total`, docs/OBSERVABILITY.md "Kernel
//! dispatch") so the chosen path is visible in `--trace` runs and serve
//! `metrics` output. `tests/kernels.rs` pins the scalar/SIMD bitwise
//! differential on adversarial CSR shapes and whole-training byte
//! identity with the dispatch forced both ways.

use std::sync::atomic::{AtomicU8, Ordering};

/// Which kernel implementation a pass runs. Resolved once per process
/// by [`active`]; both variants produce bit-identical results.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Kernel {
    /// Reference fold: plain Rust, fixed 4-accumulator unroll.
    Scalar,
    /// AVX2 lane-parallel form of the same fold (x86_64 only).
    Simd,
}

impl Kernel {
    /// Stable wire name (`--trace` start event, bench snapshot params).
    pub fn name(self) -> &'static str {
        match self {
            Kernel::Scalar => "scalar",
            Kernel::Simd => "simd",
        }
    }
}

const UNRESOLVED: u8 = 0;
const FORCED_SCALAR: u8 = 1;
const FORCED_SIMD: u8 = 2;

/// Cached dispatch decision. 0 = not yet resolved; the first [`active`]
/// call resolves from the environment + CPU features and every later
/// call is one relaxed load.
static STATE: AtomicU8 = AtomicU8::new(UNRESOLVED);

/// The kernel path this process runs. First call resolves
/// `RANKSVM_KERNEL` (`scalar` / `simd` / anything else = auto) against
/// CPU feature detection; a `simd` request on unsupported hardware
/// falls back to scalar (the two are bit-identical, so this is a speed
/// decision only).
#[inline]
pub fn active() -> Kernel {
    match STATE.load(Ordering::Relaxed) {
        FORCED_SCALAR => Kernel::Scalar,
        FORCED_SIMD => Kernel::Simd,
        _ => resolve(),
    }
}

#[cold]
fn resolve() -> Kernel {
    let choice = match std::env::var("RANKSVM_KERNEL").as_deref() {
        Ok("scalar") => Kernel::Scalar,
        Ok("simd") if simd_supported() => Kernel::Simd,
        Ok("simd") => Kernel::Scalar,
        _ if simd_supported() => Kernel::Simd,
        _ => Kernel::Scalar,
    };
    STATE.store(encode(choice), Ordering::Relaxed);
    choice
}

fn encode(k: Kernel) -> u8 {
    match k {
        Kernel::Scalar => FORCED_SCALAR,
        Kernel::Simd => FORCED_SIMD,
    }
}

/// Pin the dispatch decision (tests / benches), or `None` to drop back
/// to lazy env + feature resolution. Forcing [`Kernel::Simd`] on a host
/// without AVX2 support downgrades to [`Kernel::Scalar`], exactly like
/// `RANKSVM_KERNEL=simd` — [`active`] never hands out a kernel this
/// host cannot execute, and the two are bit-identical anyway.
pub fn force(k: Option<Kernel>) {
    let k = match k {
        Some(Kernel::Simd) if !simd_supported() => Some(Kernel::Scalar),
        other => other,
    };
    STATE.store(k.map(encode).unwrap_or(UNRESOLVED), Ordering::Relaxed);
}

/// True when the vector path can actually run on this host.
pub fn simd_supported() -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        std::arch::is_x86_feature_detected!("avx2")
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        false
    }
}

/// Count one kernel *pass* (a whole matvec / scatter sweep, not a row)
/// against the dispatch-visibility counters. Called by the pass-level
/// wrappers, never from per-row inner loops, so the relaxed RMW cannot
/// contend on the hot path.
#[inline]
pub fn note_pass(k: Kernel) {
    match k {
        Kernel::Scalar => crate::obs::metrics::KERNEL_SCALAR_PASSES.inc(),
        Kernel::Simd => crate::obs::metrics::KERNEL_SIMD_PASSES.inc(),
    }
}

/// Largest gatherable vector length: AVX2 gathers take 32-bit signed
/// element offsets, so the vector path only engages when every index
/// fits in `i32` (always true for u32 CSR columns into slices below
/// 2³¹ elements; checked per call anyway).
const GATHER_MAX: usize = i32::MAX as usize;

// ------------------------------------------------------------- kernels

/// Sparse·dense gather dot: `Σₖ val[k] · w[idx[k]]`. Backs
/// `CsrView::row_dot`, the CSR `matvec` rows, and the CSC column
/// gather.
#[inline]
pub fn sparse_dot(k: Kernel, idx: &[u32], val: &[f64], w: &[f64]) -> f64 {
    assert_eq!(idx.len(), val.len(), "sparse_dot: idx/val length mismatch");
    match k {
        Kernel::Simd => {
            #[cfg(target_arch = "x86_64")]
            {
                // `k` is caller-supplied on a safe pub fn, so the
                // dispatch invariant (resolve/force never hand out an
                // unrunnable `Simd`) cannot carry the safety proof by
                // itself: re-check the cached cpuid word, and bounds-
                // check the gather indices — the scalar fold bounds-
                // checks `w[idx[k]]` per element, and an out-of-bounds
                // gather must panic the same way, never read wild.
                if simd_supported()
                    && w.len() <= GATHER_MAX
                    && idx.iter().all(|&j| (j as usize) < w.len())
                {
                    // SAFETY: AVX2 verified just above; lengths are
                    // asserted equal and every gather index is in
                    // bounds for `w`, which fits i32 offsets.
                    return unsafe { x86::sparse_dot_avx2(idx, val, w) };
                }
            }
            sparse_dot_scalar(idx, val, w)
        }
        Kernel::Scalar => sparse_dot_scalar(idx, val, w),
    }
}

/// Dense dot product under the same fixed fold. Backs
/// [`crate::linalg::ops::dot`].
#[inline]
pub fn dense_dot(k: Kernel, a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len(), "dense_dot: length mismatch");
    match k {
        Kernel::Simd => {
            #[cfg(target_arch = "x86_64")]
            {
                // Caller-supplied `k`: re-check the cached cpuid word
                // before the `target_feature` body (see sparse_dot).
                if simd_supported() {
                    // SAFETY: AVX2 verified just above; lengths
                    // asserted equal.
                    return unsafe { x86::dense_dot_avx2(a, b) };
                }
            }
            dense_dot_scalar(a, b)
        }
        Kernel::Scalar => dense_dot_scalar(a, b),
    }
}

/// Sparse scatter-axpy: `out[idx[k]] += val[k] · alpha`, in entry
/// order. Backs the CSR `matvec_t` rows and the parallel backend's
/// gradient scatter. Both paths round each product once and apply the
/// adds in the identical order, so this kernel's bits match the
/// historical scalar loop exactly.
#[inline]
pub fn scatter_axpy(k: Kernel, idx: &[u32], val: &[f64], alpha: f64, out: &mut [f64]) {
    assert_eq!(idx.len(), val.len(), "scatter_axpy: idx/val length mismatch");
    match k {
        Kernel::Simd => {
            #[cfg(target_arch = "x86_64")]
            {
                // Caller-supplied `k`: re-check the cached cpuid word
                // before the `target_feature` body (see sparse_dot).
                // Out-of-bounds `idx` needs no pre-scan here — the
                // AVX2 body indexes `out` through safe bounds-checked
                // subscripts, panicking on the same entry, after the
                // same prior side effects, as the scalar loop.
                if simd_supported() {
                    // SAFETY: AVX2 verified just above; lengths
                    // asserted equal.
                    return unsafe { x86::scatter_axpy_avx2(idx, val, alpha, out) };
                }
            }
            scatter_axpy_scalar(idx, val, alpha, out)
        }
        Kernel::Scalar => scatter_axpy_scalar(idx, val, alpha, out),
    }
}

// -------------------------------------------------- scalar reference

/// The reference fold both paths must match bit for bit: element `k`
/// accumulates into `acc[k % 4]`, partials fold as `((a₀+a₁)+a₂)+a₃`,
/// remainder added last in element order.
fn sparse_dot_scalar(idx: &[u32], val: &[f64], w: &[f64]) -> f64 {
    debug_assert_eq!(idx.len(), val.len());
    let n = idx.len();
    let mut acc = [0.0f64; 4];
    let quads = n / 4;
    for q in 0..quads {
        let k = q * 4;
        acc[0] += val[k] * w[idx[k] as usize];
        acc[1] += val[k + 1] * w[idx[k + 1] as usize];
        acc[2] += val[k + 2] * w[idx[k + 2] as usize];
        acc[3] += val[k + 3] * w[idx[k + 3] as usize];
    }
    let mut s = ((acc[0] + acc[1]) + acc[2]) + acc[3];
    for k in quads * 4..n {
        s += val[k] * w[idx[k] as usize];
    }
    s
}

/// Dense form of the reference fold — the historical `ops::dot` body,
/// verbatim, so routing `dot` through dispatch changed no result bit.
fn dense_dot_scalar(a: &[f64], b: &[f64]) -> f64 {
    let mut acc = [0.0f64; 4];
    let quads = a.len() / 4;
    for q in 0..quads {
        let i = q * 4;
        acc[0] += a[i] * b[i];
        acc[1] += a[i + 1] * b[i + 1];
        acc[2] += a[i + 2] * b[i + 2];
        acc[3] += a[i + 3] * b[i + 3];
    }
    let mut s = ((acc[0] + acc[1]) + acc[2]) + acc[3];
    for i in quads * 4..a.len() {
        s += a[i] * b[i];
    }
    s
}

/// Reference scatter: one rounded product and one in-order add per
/// entry — the historical `matvec_t` inner loop.
fn scatter_axpy_scalar(idx: &[u32], val: &[f64], alpha: f64, out: &mut [f64]) {
    debug_assert_eq!(idx.len(), val.len());
    for (&j, &v) in idx.iter().zip(val) {
        out[j as usize] += v * alpha;
    }
}

// ------------------------------------------------------- AVX2 (x86_64)

#[cfg(target_arch = "x86_64")]
mod x86 {
    use std::arch::x86_64::*;

    /// # Safety
    /// Caller must have verified AVX2 support, `idx.len() == val.len()`,
    /// `w.len() <= i32::MAX`, and that every `idx` entry is in bounds
    /// for `w` (the gather takes no bounds checks).
    #[target_feature(enable = "avx2")]
    pub unsafe fn sparse_dot_avx2(idx: &[u32], val: &[f64], w: &[f64]) -> f64 {
        debug_assert_eq!(idx.len(), val.len());
        let n = idx.len();
        let quads = n / 4;
        // One f64 accumulator per lane = the reference fold's acc[0..4].
        let mut acc = _mm256_setzero_pd();
        for q in 0..quads {
            let k = q * 4;
            let v = _mm256_loadu_pd(val.as_ptr().add(k));
            let i = _mm_loadu_si128(idx.as_ptr().add(k) as *const __m128i);
            let g = _mm256_i32gather_pd::<8>(w.as_ptr(), i);
            // mul then add, deliberately unfused (module docs).
            acc = _mm256_add_pd(acc, _mm256_mul_pd(v, g));
        }
        let mut lanes = [0.0f64; 4];
        _mm256_storeu_pd(lanes.as_mut_ptr(), acc);
        let mut s = ((lanes[0] + lanes[1]) + lanes[2]) + lanes[3];
        for k in quads * 4..n {
            s += val[k] * w[idx[k] as usize];
        }
        s
    }

    /// # Safety
    /// Caller must have verified AVX2 support and `a.len() == b.len()`.
    #[target_feature(enable = "avx2")]
    pub unsafe fn dense_dot_avx2(a: &[f64], b: &[f64]) -> f64 {
        debug_assert_eq!(a.len(), b.len());
        let quads = a.len() / 4;
        let mut acc = _mm256_setzero_pd();
        for q in 0..quads {
            let i = q * 4;
            let x = _mm256_loadu_pd(a.as_ptr().add(i));
            let y = _mm256_loadu_pd(b.as_ptr().add(i));
            acc = _mm256_add_pd(acc, _mm256_mul_pd(x, y));
        }
        let mut lanes = [0.0f64; 4];
        _mm256_storeu_pd(lanes.as_mut_ptr(), acc);
        let mut s = ((lanes[0] + lanes[1]) + lanes[2]) + lanes[3];
        for i in quads * 4..a.len() {
            s += a[i] * b[i];
        }
        s
    }

    /// # Safety
    /// Caller must have verified AVX2 support and
    /// `idx.len() == val.len()` (out-of-range `idx` entries panic via
    /// the bounds-checked `out` subscript, same as the scalar loop).
    #[target_feature(enable = "avx2")]
    pub unsafe fn scatter_axpy_avx2(idx: &[u32], val: &[f64], alpha: f64, out: &mut [f64]) {
        debug_assert_eq!(idx.len(), val.len());
        let n = idx.len();
        let quads = n / 4;
        let va = _mm256_set1_pd(alpha);
        let mut prod = [0.0f64; 4];
        for q in 0..quads {
            let k = q * 4;
            let v = _mm256_loadu_pd(val.as_ptr().add(k));
            _mm256_storeu_pd(prod.as_mut_ptr(), _mm256_mul_pd(v, va));
            // No AVX2 scatter exists; the adds run scalar, in entry
            // order — the exact reference sequence.
            out[*idx.get_unchecked(k) as usize] += prod[0];
            out[*idx.get_unchecked(k + 1) as usize] += prod[1];
            out[*idx.get_unchecked(k + 2) as usize] += prod[2];
            out[*idx.get_unchecked(k + 3) as usize] += prod[3];
        }
        for k in quads * 4..n {
            out[idx[k] as usize] += val[k] * alpha;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// Adversarial value pool: denormals, ±0.0, huge/tiny magnitudes —
    /// anything that could expose a rounding-order difference (NaN is
    /// excluded by the crate's NaN-free data contract).
    fn adversarial_value(rng: &mut Rng) -> f64 {
        match rng.below(8) {
            0 => 0.0,
            1 => -0.0,
            2 => f64::MIN_POSITIVE / 2.0,  // subnormal
            3 => -f64::MIN_POSITIVE / 4.0, // subnormal
            4 => 1e300,
            5 => -1e-300,
            _ => rng.normal(),
        }
    }

    fn random_case(rng: &mut Rng, n: usize, cols: usize) -> (Vec<u32>, Vec<f64>, Vec<f64>) {
        let idx: Vec<u32> = (0..n).map(|_| rng.below(cols) as u32).collect();
        let val: Vec<f64> = (0..n).map(|_| adversarial_value(rng)).collect();
        let w: Vec<f64> = (0..cols).map(|_| adversarial_value(rng)).collect();
        (idx, val, w)
    }

    #[test]
    fn scalar_reference_folds_match_by_construction() {
        // dense_dot over contiguous indices equals sparse_dot bit for
        // bit — same fold, gather degenerating to a load.
        let mut rng = Rng::new(11);
        for n in [0usize, 1, 3, 4, 5, 8, 17, 64, 255] {
            let idx: Vec<u32> = (0..n as u32).collect();
            let a: Vec<f64> = (0..n).map(|_| adversarial_value(&mut rng)).collect();
            let b: Vec<f64> = (0..n).map(|_| adversarial_value(&mut rng)).collect();
            let s = sparse_dot(Kernel::Scalar, &idx, &a, &b);
            let d = dense_dot(Kernel::Scalar, &a, &b);
            assert_eq!(s.to_bits(), d.to_bits(), "n={n}");
        }
    }

    #[test]
    fn simd_sparse_dot_is_bit_identical_to_scalar() {
        if !simd_supported() {
            return; // nothing to differentiate on this host
        }
        let mut rng = Rng::new(12);
        for n in [0usize, 1, 2, 3, 4, 5, 7, 8, 9, 31, 64, 100, 1023] {
            let cols = 1 + rng.below(200);
            let (idx, val, w) = random_case(&mut rng, n, cols);
            let a = sparse_dot(Kernel::Scalar, &idx, &val, &w);
            let b = sparse_dot(Kernel::Simd, &idx, &val, &w);
            assert_eq!(a.to_bits(), b.to_bits(), "n={n}");
        }
    }

    #[test]
    fn simd_dense_dot_is_bit_identical_to_scalar() {
        if !simd_supported() {
            return;
        }
        let mut rng = Rng::new(13);
        for n in [0usize, 1, 3, 4, 6, 8, 13, 64, 257, 1000] {
            let a: Vec<f64> = (0..n).map(|_| adversarial_value(&mut rng)).collect();
            let b: Vec<f64> = (0..n).map(|_| adversarial_value(&mut rng)).collect();
            let x = dense_dot(Kernel::Scalar, &a, &b);
            let y = dense_dot(Kernel::Simd, &a, &b);
            assert_eq!(x.to_bits(), y.to_bits(), "n={n}");
        }
    }

    #[test]
    fn simd_scatter_axpy_is_bit_identical_to_scalar() {
        if !simd_supported() {
            return;
        }
        let mut rng = Rng::new(14);
        for n in [0usize, 1, 3, 4, 5, 8, 11, 63, 200] {
            let cols = 1 + rng.below(50);
            // Repeated indices on purpose: accumulation order matters.
            let (idx, val, _) = random_case(&mut rng, n, cols);
            let alpha = adversarial_value(&mut rng);
            let mut a: Vec<f64> = (0..cols).map(|_| adversarial_value(&mut rng)).collect();
            let mut b = a.clone();
            scatter_axpy(Kernel::Scalar, &idx, &val, alpha, &mut a);
            scatter_axpy(Kernel::Simd, &idx, &val, alpha, &mut b);
            for (x, y) in a.iter().zip(&b) {
                assert_eq!(x.to_bits(), y.to_bits(), "n={n}");
            }
        }
    }

    #[test]
    fn kernel_names_are_stable() {
        assert_eq!(Kernel::Scalar.name(), "scalar");
        assert_eq!(Kernel::Simd.name(), "simd");
    }

    #[test]
    fn active_resolves_to_a_runnable_kernel() {
        // Whatever env/CPU this test runs under, the decision must be
        // executable here (Simd implies hardware support).
        if active() == Kernel::Simd {
            assert!(simd_supported());
        }
    }

    #[test]
    fn force_never_pins_an_unrunnable_kernel() {
        // force(Simd) on a non-AVX2 host must downgrade to Scalar, so
        // active() can always be executed as-is. (Runs concurrently
        // with other tests in this binary, but the invariant holds
        // under any interleaving: no store ever encodes an unrunnable
        // Simd.)
        force(Some(Kernel::Simd));
        let pinned = active();
        force(None);
        if simd_supported() {
            assert_eq!(pinned, Kernel::Simd);
        } else {
            assert_eq!(pinned, Kernel::Scalar);
        }
    }

    #[test]
    #[should_panic(expected = "length mismatch")]
    fn sparse_dot_rejects_mismatched_lengths_in_release() {
        // Release-mode assert, not debug_assert: a mismatch must never
        // reach the 4-wide loads.
        sparse_dot(active(), &[0, 1, 2, 3], &[1.0; 3], &[1.0; 4]);
    }

    #[test]
    #[should_panic(expected = "length mismatch")]
    fn dense_dot_rejects_mismatched_lengths_in_release() {
        dense_dot(active(), &[1.0; 5], &[1.0; 4]);
    }

    #[test]
    #[should_panic(expected = "length mismatch")]
    fn scatter_axpy_rejects_mismatched_lengths_in_release() {
        let mut out = vec![0.0; 4];
        scatter_axpy(active(), &[0, 1, 2, 3], &[1.0; 3], 2.0, &mut out);
    }

    #[test]
    #[should_panic(expected = "index out of bounds")]
    fn sparse_dot_panics_on_out_of_bounds_index_even_when_forced_simd() {
        // An index past w.len() must panic exactly like the scalar
        // fold's bounds-checked subscript — never feed the AVX2 gather.
        let idx = [0u32, 9, 1, 2];
        let val = [1.0f64; 4];
        let w = [1.0f64; 3];
        sparse_dot(Kernel::Simd, &idx, &val, &w);
    }

    #[test]
    #[should_panic(expected = "index out of bounds")]
    fn scatter_axpy_panics_on_out_of_bounds_index_even_when_forced_simd() {
        let idx = [0u32, 9, 1, 2];
        let val = [1.0f64; 4];
        let mut out = vec![0.0f64; 3];
        scatter_axpy(Kernel::Simd, &idx, &val, 1.0, &mut out);
    }
}
