//! Leveled logging facade shared by every subcommand
//! (docs/OBSERVABILITY.md "Log levels").
//!
//! Library code must not call `eprintln!`/`println!` directly (a CI grep
//! enforces this outside `obs/` and `main.rs`); diagnostics go through
//! [`info`]/[`debug`] so `--quiet` and `--verbose` mean the same thing
//! for `train`, `convert`, `serve`, and `predict`. Machine-readable
//! protocol output (the mem-probe JSON lines, the serve TCP readiness
//! line) goes through [`data`], the one sanctioned stdout door.
//!
//! The level is process-global, set once in `main` before dispatch;
//! everything here is a relaxed atomic read, so logging can never
//! perturb scheduling or numerics (the inertness contract).

use std::sync::atomic::{AtomicU8, Ordering};

/// Verbosity: `Quiet` (`--quiet`) < `Info` (default) < `Debug`
/// (`--verbose`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Quiet = 0,
    Info = 1,
    Debug = 2,
}

static LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);

/// Set the process-global level (called once by `main` from
/// `--quiet`/`--verbose` before dispatching the subcommand).
pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

pub fn level() -> Level {
    match LEVEL.load(Ordering::Relaxed) {
        0 => Level::Quiet,
        1 => Level::Info,
        _ => Level::Debug,
    }
}

/// Resolve the level implied by the shared CLI flags (`--verbose` wins
/// over `--quiet` when both are given, matching the usage text).
pub fn level_from_flags(quiet: bool, verbose: bool) -> Level {
    if verbose {
        Level::Debug
    } else if quiet {
        Level::Quiet
    } else {
        Level::Info
    }
}

pub fn info_enabled() -> bool {
    level() >= Level::Info
}

pub fn debug_enabled() -> bool {
    level() >= Level::Debug
}

/// Progress note → stderr, suppressed by `--quiet`.
pub fn info(msg: &str) {
    if info_enabled() {
        eprintln!("{msg}");
    }
}

/// Diagnostic detail → stderr, shown only under `--verbose`.
pub fn debug(msg: &str) {
    if debug_enabled() {
        eprintln!("{msg}");
    }
}

/// Data-plane line → stdout, unconditionally (protocol output a caller
/// or pipeline consumes; never subject to the log level).
pub fn data(line: &str) {
    println!("{line}");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flag_resolution_orders_levels() {
        assert_eq!(level_from_flags(false, false), Level::Info);
        assert_eq!(level_from_flags(true, false), Level::Quiet);
        assert_eq!(level_from_flags(false, true), Level::Debug);
        assert_eq!(level_from_flags(true, true), Level::Debug);
        assert!(Level::Quiet < Level::Info && Level::Info < Level::Debug);
    }
}
