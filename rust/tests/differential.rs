//! Differential test suite: every fast oracle in the crate is checked
//! against the exact `O(m²)` explicit-pair reference on seeded random
//! datasets spanning the tie regimes of the paper (arbitrary real-valued
//! utilities, few-level ordinal, bipartite, fully tied) and score
//! distributions that land exactly on the hinge margin. This is the
//! lock-down the sharded engine is developed under: any decomposition
//! bug shows up as a count mismatch here before it can reach training.

use ranksvm::compute::{ComputeBackend, NativeBackend, ParallelBackend};
use ranksvm::losses::{
    count_comparable_pairs, OracleOutput, PairOracle, QueryGrouped, RLevelOracle, RankingOracle,
    ShardedTreeOracle, SquaredPairOracle, SquaredTreeOracle, TopPushOracle, TreeOracle,
};
use ranksvm::util::rng::Rng;

/// Labels across the paper's tie regimes.
fn labels(rng: &mut Rng, m: usize, regime: usize) -> Vec<f64> {
    match regime % 4 {
        0 => (0..m).map(|_| rng.normal() * 3.0).collect(), // r ≈ m real-valued
        1 => (0..m).map(|_| rng.below(5) as f64).collect(), // 5-level ordinal
        2 => (0..m).map(|_| rng.below(2) as f64).collect(), // bipartite
        _ => vec![7.5; m],                                 // all tied (N = 0)
    }
}

/// Scores including exact-margin and exact-tie collisions.
fn scores(rng: &mut Rng, m: usize, regime: usize) -> Vec<f64> {
    match regime % 3 {
        0 => (0..m).map(|_| rng.normal() * 2.0).collect(),
        // Integer-valued: pairs land exactly on the p_i = p_j − 1 margin.
        1 => (0..m).map(|_| rng.below(6) as f64 - 2.0).collect(),
        _ => (0..m).map(|_| (rng.below(40) as f64) / 8.0).collect(),
    }
}

#[test]
fn tree_oracle_matches_pair_oracle() {
    let mut rng = Rng::new(0xD1FF_0001);
    for trial in 0..80 {
        let m = 1 + rng.below(220);
        let y = labels(&mut rng, m, trial);
        let p = scores(&mut rng, m, trial / 4);
        let n = count_comparable_pairs(&y) as f64;
        let mut tree = TreeOracle::new();
        let mut pair = PairOracle::new();
        let a = tree.eval(&p, &y, n);
        let b = pair.eval(&p, &y, n);
        // Integer counts under a shared hinge predicate: the coefficients
        // are exactly equal, the loss to well under the 1e-10 contract.
        assert_eq!(a.coeffs, b.coeffs, "trial {trial}");
        assert!(
            (a.loss - b.loss).abs() <= 1e-10 * (1.0 + b.loss.abs()),
            "trial {trial}: {} vs {}",
            a.loss,
            b.loss
        );
    }
}

#[test]
fn rlevel_oracle_matches_pair_oracle() {
    let mut rng = Rng::new(0xD1FF_0002);
    for trial in 0..80 {
        let m = 1 + rng.below(180);
        let y = labels(&mut rng, m, trial);
        let p = scores(&mut rng, m, trial / 4);
        let n = count_comparable_pairs(&y) as f64;
        let mut rl = RLevelOracle::new();
        let mut pair = PairOracle::new();
        let a = rl.eval(&p, &y, n);
        let b = pair.eval(&p, &y, n);
        assert_eq!(a.coeffs, b.coeffs, "trial {trial}");
        assert!((a.loss - b.loss).abs() <= 1e-10 * (1.0 + b.loss.abs()), "trial {trial}");
    }
}

#[test]
fn squared_tree_oracle_matches_squared_pair_oracle() {
    let mut rng = Rng::new(0xD1FF_0003);
    for trial in 0..60 {
        let m = 1 + rng.below(150);
        let y = labels(&mut rng, m, trial);
        let p = scores(&mut rng, m, trial / 4);
        let n = count_comparable_pairs(&y) as f64;
        let mut tree = SquaredTreeOracle::new();
        let mut pair = SquaredPairOracle::new(&y);
        let a = tree.eval_full(&p, &y, n);
        let b = pair.eval_full(&p, n);
        // The two oracles sum O(m)-term aggregates in different orders;
        // 1e-10 per accumulated unit is the agreement contract.
        let tol = 1e-10 * (1.0 + m as f64 + b.loss.abs());
        assert!(
            (a.loss - b.loss).abs() <= tol,
            "trial {trial}: loss {} vs {}",
            a.loss,
            b.loss
        );
        for (i, (x, z)) in a.coeffs.iter().zip(&b.coeffs).enumerate() {
            assert!((x - z).abs() <= tol, "trial {trial}, coeff {i}: {x} vs {z}");
        }
    }
}

#[test]
fn sharded_oracle_is_bit_identical_across_thread_counts() {
    let mut rng = Rng::new(0xD1FF_0004);
    for trial in 0..50 {
        let m = 1 + rng.below(300);
        let y = labels(&mut rng, m, trial);
        let p = scores(&mut rng, m, trial / 4);
        let n = count_comparable_pairs(&y) as f64;
        let mut reference = TreeOracle::new();
        let expect = reference.eval(&p, &y, n);
        for threads in [1usize, 2, 8] {
            let mut sharded = ShardedTreeOracle::new(threads, None, &y);
            let got = sharded.eval(&p, &y, n);
            assert_eq!(got.coeffs, expect.coeffs, "trial {trial}, {threads} threads");
            assert_eq!(
                got.loss.to_bits(),
                expect.loss.to_bits(),
                "trial {trial}, {threads} threads"
            );
            // Repeated evaluation on reused worker state stays identical.
            let again = sharded.eval(&p, &y, n);
            assert_eq!(again.coeffs, expect.coeffs);
            assert_eq!(again.loss.to_bits(), expect.loss.to_bits());
        }
    }
}

#[test]
fn sharded_grouped_respects_query_boundaries_and_matches_serial() {
    let mut rng = Rng::new(0xD1FF_0005);
    for trial in 0..40 {
        let m = 2 + rng.below(240);
        let n_queries = 1 + rng.below(15);
        // Interleaved, non-contiguous qids.
        let qid: Vec<u64> = (0..m).map(|_| rng.below(n_queries) as u64 * 13 + 5).collect();
        let y = labels(&mut rng, m, trial);
        let p = scores(&mut rng, m, trial / 4);
        let mut serial = QueryGrouped::new(TreeOracle::new(), &qid, &y);
        let expect = serial.eval(&p, &y, serial.total_pairs());
        for threads in [1usize, 2, 8] {
            let mut sharded = ShardedTreeOracle::new(threads, Some(&qid), &y);
            // Whole groups per shard: contiguous, disjoint, covering.
            let ranges = sharded.group_ranges().unwrap();
            let mut lo = 0;
            for &(a, b) in ranges {
                assert_eq!(a, lo, "trial {trial}");
                lo = b;
            }
            assert_eq!(lo, sharded.n_groups().unwrap(), "trial {trial}");
            let got = sharded.eval(&p, &y, 0.0);
            assert_eq!(got.coeffs, expect.coeffs, "trial {trial}, {threads} threads");
            assert_eq!(
                got.loss.to_bits(),
                expect.loss.to_bits(),
                "trial {trial}, {threads} threads"
            );
        }
    }
}

/// Brute-force TopPush reference: for every positive, independently
/// re-scan *all* negatives for the maximum score (quadratic work, no
/// shared top-negative state), then assemble exactly the subgradient
/// the contract in `docs/LOSSES.md` specifies. Strict `>` on the scan
/// keeps the smallest index among tied top negatives.
fn toppush_reference(p: &[f64], y: &[f64]) -> OracleOutput {
    let m = p.len();
    let mut coeffs = vec![0.0; m];
    let n_pos = y.iter().filter(|v| **v > 0.0).count();
    if n_pos == 0 || !y.iter().any(|v| *v <= 0.0 && !v.is_nan()) {
        return OracleOutput { loss: 0.0, coeffs };
    }
    let inv = 1.0 / n_pos as f64;
    let mut sum = 0.0;
    let mut active = 0usize;
    let mut j_star = usize::MAX;
    for i in 0..m {
        if !(y[i] > 0.0) || y[i].is_nan() {
            continue;
        }
        // Quadratic: each positive pays a full pass over the negatives.
        let mut top = usize::MAX;
        for (j, (&pj, &yj)) in p.iter().zip(y).enumerate() {
            if yj.is_nan() || yj > 0.0 {
                continue;
            }
            if top == usize::MAX || pj > p[top] {
                top = j;
            }
        }
        let h = 1.0 + p[top] - p[i];
        if h > 0.0 {
            sum += h;
            active += 1;
            coeffs[i] = -inv;
            j_star = top;
        }
    }
    if j_star != usize::MAX {
        coeffs[j_star] = active as f64 * inv;
    }
    OracleOutput { loss: sum * inv, coeffs }
}

#[test]
fn toppush_oracle_matches_quadratic_reference() {
    // Exact bit equality: the fast oracle and the reference accumulate
    // the same hinges in the same ascending-index order and assemble
    // coefficients through the identical `active * inv` product.
    let mut rng = Rng::new(0xD1FF_0008);
    for trial in 0..120 {
        let m = 1 + rng.below(250);
        // All tie regimes, including single-class and all-NaN-adjacent
        // corners: regime 3 (all tied at 7.5) is all-positive → zero.
        let mut y = labels(&mut rng, m, trial);
        if trial % 5 == 0 {
            for v in y.iter_mut() {
                if rng.bool(0.1) {
                    *v = f64::NAN;
                }
            }
        }
        let p = scores(&mut rng, m, trial / 4);
        let mut fast = TopPushOracle::new();
        let got = fast.eval(&p, &y, 0.0);
        let expect = toppush_reference(&p, &y);
        assert_eq!(got.coeffs, expect.coeffs, "trial {trial}");
        assert_eq!(got.loss.to_bits(), expect.loss.to_bits(), "trial {trial}");
    }
}

#[test]
fn toppush_sharded_engine_matches_serial_grouping() {
    // The generic per-group engine vs a serial loop over the same
    // groups using the quadratic reference, normalized by the number
    // of effective (both-classes-present) groups. Bitwise on coeffs.
    let mut rng = Rng::new(0xD1FF_0009);
    for trial in 0..30 {
        let m = 2 + rng.below(240);
        let n_queries = 1 + rng.below(12);
        let qid: Vec<u64> = (0..m).map(|_| rng.below(n_queries) as u64 * 7 + 3).collect();
        let y: Vec<f64> = (0..m).map(|_| rng.below(2) as f64).collect();
        let p = scores(&mut rng, m, trial / 3);

        let mut serial = QueryGrouped::new(TopPushOracle::new(), &qid, &y);
        let expect = serial.eval(&p, &y, 0.0);
        for threads in [1usize, 2, 8] {
            let pool = std::sync::Arc::new(ranksvm::runtime::WorkerPool::new(threads));
            let index = std::sync::Arc::new(ranksvm::losses::GroupIndex::build(&qid, &y));
            let factory: fn() -> Box<dyn ranksvm::losses::GroupOracle> =
                || Box::new(TopPushOracle::new());
            let mut engine =
                ranksvm::losses::ShardedGroupOracle::new(pool, Some(index), factory, "toppush");
            let got = engine.eval(&p, &y, 0.0);
            assert_eq!(got.coeffs, expect.coeffs, "trial {trial}, {threads} threads");
            assert_eq!(got.loss.to_bits(), expect.loss.to_bits(), "trial {trial}");
        }
    }
}

#[test]
fn parallel_backend_grad_matches_native_and_thread_invariant() {
    let mut rng = Rng::new(0xD1FF_0006);
    for trial in 0..15 {
        let rows = 1 + rng.below(400);
        let cols = 1 + rng.below(60);
        let mut triplets = Vec::new();
        for i in 0..rows {
            for j in 0..cols {
                if rng.bool(0.1) {
                    triplets.push((i, j, rng.normal()));
                }
            }
        }
        let x = ranksvm::linalg::CsrMatrix::from_triplets(rows, cols, triplets);
        let w: Vec<f64> = (0..cols).map(|_| rng.normal()).collect();
        let coeffs: Vec<f64> = (0..rows).map(|_| rng.normal()).collect();

        let mut serial = NativeBackend::new();
        serial.prepare(x.view());
        let p_ref = serial.scores(x.view(), &w);
        let g_ref = serial.grad(x.view(), &coeffs);

        let mut first: Option<Vec<f64>> = None;
        for threads in [1usize, 2, 8] {
            let mut par = ParallelBackend::new(threads);
            par.prepare(x.view());
            assert_eq!(par.scores(x.view(), &w), p_ref, "trial {trial}, {threads} threads");
            let g = par.grad(x.view(), &coeffs);
            for (a, b) in g.iter().zip(&g_ref) {
                assert!((a - b).abs() < 1e-10 * (1.0 + b.abs()), "trial {trial}");
            }
            match &first {
                None => first = Some(g),
                Some(f) => assert_eq!(&g, f, "trial {trial}, {threads} threads"),
            }
        }
    }
}

#[test]
fn sharded_oracle_handles_adversarial_score_distributions() {
    // Distributions that stress the window/ownership logic: constant
    // scores (every window = everything), one outlier far away (empty
    // cross-chunk windows), and a monotone staircase exactly 1.0 apart
    // (boundary-exact margins).
    let cases: Vec<Vec<f64>> = vec![
        vec![0.0; 64],
        {
            let mut v = vec![0.0; 64];
            v[0] = 1e9;
            v
        },
        (0..64).map(|i| i as f64).collect(),
        (0..64).map(|i| (i as f64) * 0.5).collect(),
    ];
    let mut rng = Rng::new(0xD1FF_0007);
    for (ci, p) in cases.iter().enumerate() {
        let y: Vec<f64> = (0..p.len()).map(|_| rng.below(4) as f64).collect();
        let n = count_comparable_pairs(&y) as f64;
        let mut reference = TreeOracle::new();
        let expect = reference.eval(p, &y, n);
        for threads in [2usize, 7] {
            let mut sharded = ShardedTreeOracle::new(threads, None, &y);
            let got = sharded.eval(p, &y, n);
            assert_eq!(got.coeffs, expect.coeffs, "case {ci}, {threads} threads");
            assert_eq!(got.loss.to_bits(), expect.loss.to_bits(), "case {ci}");
        }
    }
}
