//! The serving wire protocol: newline-delimited text, one request per
//! line, one response line per request, in order.
//!
//! Score lines reuse the libsvm feature grammar (and its single
//! validation gate, `data::libsvm::parse_line`), so a feature vector
//! pasted out of a dataset file is a valid request body. Responses
//! carry the model version that scored them (`ok v=<version> …`) —
//! the hot-swap tests assert on it — and print scores with Rust's
//! shortest-round-trip `{}` float formatting, the same formatter
//! `ranksvm predict` uses, which is what makes daemon output
//! byte-comparable to the one-shot CLI.
//!
//! Request grammar (`<…>` required, `[…]` repeated):
//!
//! ```text
//! score <idx>:<val> [<idx>:<val> …]   score one raw feature vector
//!                                     (1-based indices, libsvm style)
//! rows <i> [<i> …]                    score store rows (0-based)
//! topk <k> all                        best k rows of the whole store
//! topk <k> group <g>                  best k within query group g
//! topk <k> rows <i> [<i> …]           best k among the listed rows
//! batch <n>                           the next n lines are one batch
//! metrics                             Prometheus-style registry dump
//! info | ping | reload | swap <path> | quit
//! ```
//!
//! Responses:
//!
//! ```text
//! ok v=<version> <score> [<score> …]        score / rows
//! ok v=<version> <row>:<score> [[…]]        topk (best first)
//! err <message>                             structured failure (one line)
//! ```
//!
//! `metrics` is the one deliberate exception to one-line responses: it
//! answers with the multi-line Prometheus-style text of the whole
//! metrics registry, terminated by a `# EOF` line so clients can frame
//! it (docs/OBSERVABILITY.md).
//!
//! Parsing never fails and never panics: a malformed line becomes
//! [`Request::Invalid`], which the engine answers with an `err` line in
//! the request's slot, keeping batch responses aligned with batch
//! inputs.

use crate::data::libsvm;
use anyhow::{ensure, Result};
use std::fmt::Write as _;
use std::path::PathBuf;

/// Largest `batch <n>` the daemon will frame — bounds the memory one
/// connection can pin before any scoring happens.
pub const MAX_BATCH: usize = 65_536;

/// Which rows a `topk` request ranks.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Selector {
    /// Every row of the store.
    All,
    /// One query group of the store's group index.
    Group(usize),
    /// An explicit row list.
    Rows(Vec<usize>),
}

/// One scoring request (the engine's unit of work).
#[derive(Clone, Debug, PartialEq)]
pub enum Request {
    /// Score one raw feature vector, `(0-based index, value)` pairs.
    Score(Vec<(usize, f64)>),
    /// Score the listed store rows (0-based).
    Rows(Vec<usize>),
    /// Top-k rows by score, best first.
    TopK { k: usize, sel: Selector },
    /// A malformed line; the engine answers `err` in this slot.
    Invalid(String),
}

/// What a successful request produced.
#[derive(Clone, Debug, PartialEq)]
pub enum Payload {
    /// One score per requested item, request order.
    Scores(Vec<f64>),
    /// `(row, score)` ranked best-first.
    Ranked(Vec<(usize, f64)>),
}

/// One response line: the model version that served it plus the
/// payload or a structured error message.
#[derive(Clone, Debug, PartialEq)]
pub struct Response {
    pub version: u64,
    pub body: std::result::Result<Payload, String>,
}

/// A classified input line: a connection-level command or a scoring
/// request.
#[derive(Clone, Debug, PartialEq)]
pub enum Line {
    Quit,
    Ping,
    Info,
    /// Dump the metrics registry (multi-line, `# EOF`-terminated).
    Metrics,
    Reload,
    Swap(PathBuf),
    /// The next `n` lines form one batch (scored against a single
    /// model version, answered in order).
    Batch(usize),
    Req(Request),
}

/// Classify one input line. Never fails: anything malformed becomes
/// `Line::Req(Request::Invalid(…))` so the caller answers `err` without
/// breaking the line/response pairing.
pub fn parse(line: &str) -> Line {
    let line = line.trim();
    let mut parts = line.split_ascii_whitespace();
    let verb = parts.next().unwrap_or("");
    let rest = line[verb.len()..].trim_start();
    let invalid = |msg: String| Line::Req(Request::Invalid(msg));
    match verb {
        "quit" => Line::Quit,
        "ping" => Line::Ping,
        "info" => Line::Info,
        "metrics" => Line::Metrics,
        "reload" => Line::Reload,
        "swap" => {
            if rest.is_empty() {
                invalid("swap needs a path".into())
            } else {
                Line::Swap(PathBuf::from(rest))
            }
        }
        "batch" => match rest.parse::<usize>() {
            Ok(n) if (1..=MAX_BATCH).contains(&n) => Line::Batch(n),
            Ok(n) => invalid(format!("batch size {n} outside 1..={MAX_BATCH}")),
            Err(_) => invalid(format!("batch needs a count, got {rest:?}")),
        },
        "score" => match parse_score(rest) {
            Ok(feats) => Line::Req(Request::Score(feats)),
            Err(e) => invalid(e.to_string()),
        },
        "rows" => match parse_rows(rest) {
            Ok(rows) => Line::Req(Request::Rows(rows)),
            Err(e) => invalid(e.to_string()),
        },
        "topk" => match parse_topk(rest) {
            Ok((k, sel)) => Line::Req(Request::TopK { k, sel }),
            Err(e) => invalid(e.to_string()),
        },
        "" => invalid("empty request".into()),
        other => invalid(format!(
            "unknown verb {other:?} (expected \
             score/rows/topk/batch/metrics/info/ping/reload/swap/quit)"
        )),
    }
}

/// Parse the feature tail of a `score` line through the libsvm gate
/// (strictly increasing 1-based indices, finite values), returning
/// 0-based pairs.
fn parse_score(rest: &str) -> Result<Vec<(usize, f64)>> {
    ensure!(!rest.is_empty(), "score needs at least one idx:val pair");
    let mut ex = libsvm::Example::default();
    // Prefix a dummy label so the request body is exactly the feature
    // grammar of a dataset line.
    let parsed = libsvm::parse_line(&format!("0 {rest}"), "request", 1, &mut ex)?;
    ensure!(parsed, "score needs at least one idx:val pair");
    ensure!(ex.qid.is_none(), "qid: is not allowed in a score request");
    Ok(ex.feats.into_iter().map(|(j, v)| (j - 1, v)).collect())
}

fn parse_rows(rest: &str) -> Result<Vec<usize>> {
    ensure!(!rest.is_empty(), "rows needs at least one row index");
    rest.split_ascii_whitespace()
        .map(|t| {
            t.parse::<usize>()
                .map_err(|_| anyhow::anyhow!("bad row index {t:?} (expected an unsigned integer)"))
        })
        .collect()
}

fn parse_topk(rest: &str) -> Result<(usize, Selector)> {
    let mut parts = rest.split_ascii_whitespace();
    let k = parts
        .next()
        .ok_or_else(|| anyhow::anyhow!("topk needs a count"))?
        .parse::<usize>()
        .map_err(|_| anyhow::anyhow!("topk needs a numeric count"))?;
    ensure!(k > 0, "topk count must be positive");
    let sel = match parts.next() {
        Some("all") => {
            ensure!(parts.next().is_none(), "topk all takes no further arguments");
            Selector::All
        }
        Some("group") => {
            let g = parts
                .next()
                .ok_or_else(|| anyhow::anyhow!("topk … group needs a group index"))?
                .parse::<usize>()
                .map_err(|_| anyhow::anyhow!("bad group index"))?;
            ensure!(parts.next().is_none(), "topk group takes exactly one index");
            Selector::Group(g)
        }
        Some("rows") => {
            let tail = parts.map(str::to_owned).collect::<Vec<_>>().join(" ");
            Selector::Rows(parse_rows(&tail)?)
        }
        other => anyhow::bail!("topk selector must be all/group/rows, got {other:?}"),
    };
    Ok((k, sel))
}

/// Render one response line (no trailing newline). Scores use `{}` —
/// the shortest representation that round-trips, identical to
/// `ranksvm predict` output. Error messages are flattened to one line.
pub fn render(resp: &Response) -> String {
    match &resp.body {
        Ok(Payload::Scores(s)) => {
            let mut out = format!("ok v={}", resp.version);
            for x in s {
                let _ = write!(out, " {x}");
            }
            out
        }
        Ok(Payload::Ranked(items)) => {
            let mut out = format!("ok v={}", resp.version);
            for (row, score) in items {
                let _ = write!(out, " {row}:{score}");
            }
            out
        }
        Err(msg) => format!("err {}", msg.replace(['\n', '\r'], " ")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn score_lines_use_the_libsvm_gate() {
        let Line::Req(Request::Score(feats)) = parse("score 1:0.5 3:-2 7:1e3") else {
            panic!("expected a score request");
        };
        assert_eq!(feats, vec![(0, 0.5), (2, -2.0), (6, 1e3)]);

        // The gate's rules apply verbatim: order, duplicates, 0-index,
        // non-finite values, qid.
        for bad in [
            "score 3:1 1:2",
            "score 2:1 2:2",
            "score 0:1",
            "score 1:nan",
            "score qid:3 1:2",
            "score",
            "score notafeat",
        ] {
            assert!(
                matches!(parse(bad), Line::Req(Request::Invalid(_))),
                "{bad:?} should be invalid"
            );
        }
    }

    #[test]
    fn rows_and_topk_parse() {
        assert_eq!(parse("rows 0 5 2"), Line::Req(Request::Rows(vec![0, 5, 2])));
        assert_eq!(
            parse("topk 3 all"),
            Line::Req(Request::TopK { k: 3, sel: Selector::All })
        );
        assert_eq!(
            parse("topk 10 group 4"),
            Line::Req(Request::TopK { k: 10, sel: Selector::Group(4) })
        );
        assert_eq!(
            parse("topk 2 rows 7 1"),
            Line::Req(Request::TopK { k: 2, sel: Selector::Rows(vec![7, 1]) })
        );
        for bad in [
            "rows",
            "rows -1",
            "rows 1.5",
            "topk",
            "topk 0 all",
            "topk 3",
            "topk 3 bogus",
            "topk 3 group",
            "topk 3 all extra",
            "topk 3 rows",
        ] {
            assert!(
                matches!(parse(bad), Line::Req(Request::Invalid(_))),
                "{bad:?} should be invalid"
            );
        }
    }

    #[test]
    fn control_lines_parse() {
        assert_eq!(parse("quit"), Line::Quit);
        assert_eq!(parse("ping"), Line::Ping);
        assert_eq!(parse("info"), Line::Info);
        assert_eq!(parse("metrics"), Line::Metrics);
        assert_eq!(parse("reload"), Line::Reload);
        assert_eq!(parse("swap /tmp/next.rsm"), Line::Swap(PathBuf::from("/tmp/next.rsm")));
        assert_eq!(parse("batch 16"), Line::Batch(16));
        for bad in ["batch", "batch 0", "batch nope", "swap", "", "  ", "frobnicate 3"] {
            assert!(
                matches!(parse(bad), Line::Req(Request::Invalid(_))),
                "{bad:?} should be invalid"
            );
        }
        assert!(matches!(
            parse(&format!("batch {}", MAX_BATCH + 1)),
            Line::Req(Request::Invalid(_))
        ));
    }

    #[test]
    fn render_matches_predict_formatting() {
        let resp = Response { version: 3, body: Ok(Payload::Scores(vec![0.5, -1.25e-7, 3.0])) };
        // `{}` Display — identical to a predict output line per score.
        assert_eq!(render(&resp), "ok v=3 0.5 -0.000000125 3");
        let ranked =
            Response { version: 1, body: Ok(Payload::Ranked(vec![(4, 2.5), (0, -1.0)])) };
        assert_eq!(render(&ranked), "ok v=1 4:2.5 0:-1");
        let err = Response { version: 9, body: Err("multi\nline\rmessage".into()) };
        assert_eq!(render(&err), "err multi line message");
    }
}
