//! CLI smoke tests: drive the `ranksvm` binary end-to-end through
//! subprocesses (gen-data → info → train → eval → mem-probe), checking
//! exit codes and output contracts. Skipped when the release binary has
//! not been built yet.

use ranksvm::coordinator::memprobe;
use std::process::Command;

fn bin() -> Option<std::path::PathBuf> {
    memprobe::find_cli_bin().ok()
}

fn run(args: &[&str]) -> (bool, String, String) {
    let out = Command::new(bin().unwrap()).args(args).output().expect("spawn ranksvm");
    (
        out.status.success(),
        String::from_utf8_lossy(&out.stdout).to_string(),
        String::from_utf8_lossy(&out.stderr).to_string(),
    )
}

#[test]
fn full_cli_workflow() {
    if bin().is_none() {
        eprintln!("skipping: ranksvm binary not built (cargo build --release)");
        return;
    }
    let dir = std::env::temp_dir().join(format!("ranksvm_cli_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let data = dir.join("data.libsvm");
    let model = dir.join("model.txt");

    // gen-data
    let (ok, _, err) = run(&[
        "gen-data",
        "--synthetic",
        "cadata",
        "--m",
        "400",
        "--out",
        data.to_str().unwrap(),
    ]);
    assert!(ok, "gen-data failed: {err}");
    assert!(data.is_file());

    // info
    let (ok, stdout, _) = run(&["info", "--data", data.to_str().unwrap()]);
    assert!(ok);
    assert!(stdout.contains("\"m\":400"), "info output: {stdout}");
    assert!(stdout.contains("\"n_pairs\""));

    // train with held-out split + model output
    let (ok, stdout, err) = run(&[
        "train",
        "--data",
        data.to_str().unwrap(),
        "--method",
        "tree",
        "--lambda",
        "0.1",
        "--test-size",
        "100",
        "--out",
        model.to_str().unwrap(),
    ]);
    assert!(ok, "train failed: {err}");
    assert!(stdout.contains("\"converged\":true"), "train output: {stdout}");
    assert!(stdout.contains("\"test_error\":"));
    assert!(model.is_file());

    // --normalize trains in the scaled space and must score the
    // held-out split there too (training-set norms): the run succeeds,
    // reports the mode, and the test error stays a sane probability.
    let (ok, stdout, err) = run(&[
        "train",
        "--data",
        data.to_str().unwrap(),
        "--method",
        "tree",
        "--lambda",
        "0.1",
        "--normalize",
        "l2-col",
        "--test-size",
        "100",
    ]);
    assert!(ok, "normalized train failed: {err}");
    assert!(stdout.contains("\"normalize\":\"l2-col\""), "train output: {stdout}");
    let te: f64 = stdout
        .split("\"test_error\":")
        .nth(1)
        .and_then(|s| s.split([',', '}']).next())
        .and_then(|s| s.trim().parse().ok())
        .expect("test_error in normalized train output");
    assert!(
        (0.0..=0.45).contains(&te),
        "normalized test_error {te} — held-out split scored in the wrong feature space?"
    );

    // eval the saved model
    let (ok, stdout, _) = run(&[
        "eval",
        "--model",
        model.to_str().unwrap(),
        "--data",
        data.to_str().unwrap(),
    ]);
    assert!(ok);
    assert!(stdout.contains("\"pairwise_error\":"), "eval output: {stdout}");
    assert!(stdout.contains("\"auc\":"), "eval output: {stdout}");
    assert!(stdout.contains("\"precision_at_k\":"), "eval output: {stdout}");

    // mem-probe protocol
    let (ok, stdout, err) = run(&[
        "mem-probe",
        "--dataset",
        "reuters-small",
        "--m",
        "500",
        "--method",
        "tree",
        "--max-iter",
        "3",
    ]);
    assert!(ok, "mem-probe failed: {err}");
    assert!(memprobe::parse_peak(&stdout).is_some(), "probe output: {stdout}");

    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn cli_bad_flag_values_exit_2_naming_flag() {
    if bin().is_none() {
        return;
    }
    for args in [
        &["train", "--synthetic", "cadata", "--m", "abc"][..],
        &["train", "--synthetic", "cadata", "--m", "100", "--lambda", "zap"][..],
        &["perf", "--sizes", "10,oops"][..],
        &["mem-probe", "--m", "x.y"][..],
        &["cv", "--synthetic", "cadata", "--m", "60", "--lambdas", "1,zap"][..],
        &["cv", "--synthetic", "cadata", "--m", "60", "--folds", "two"][..],
    ] {
        let out = Command::new(bin().unwrap()).args(args).output().expect("spawn ranksvm");
        assert_eq!(out.status.code(), Some(2), "{args:?}");
        let stderr = String::from_utf8_lossy(&out.stderr);
        // One readable error line naming the flag; no panic/backtrace.
        assert!(stderr.contains("error:"), "{args:?}: {stderr}");
        assert!(stderr.contains("--"), "{args:?}: {stderr}");
        assert!(!stderr.contains("panicked"), "{args:?}: {stderr}");
        assert!(!stderr.contains("RUST_BACKTRACE"), "{args:?}: {stderr}");
    }
}

#[test]
fn cli_rejects_bad_inputs() {
    if bin().is_none() {
        return;
    }
    // unknown subcommand → usage, nonzero exit
    let (ok, _, _) = run(&["frobnicate"]);
    assert!(!ok);
    // bad method: the error names the flag and lists every registered
    // loss, straight from the registry
    let (ok, _, err) = run(&["train", "--synthetic", "cadata", "--m", "50", "--method", "magic"]);
    assert!(!ok);
    assert!(err.contains("--method") && err.contains("magic"), "stderr: {err}");
    for name in ["tree", "tree-dedup", "tree-fenwick", "pair", "rlevel", "prsvm", "toppush"] {
        assert!(err.contains(name), "registry name {name} missing from: {err}");
    }
    // same contract under the --loss spelling
    let (ok, _, err) = run(&["train", "--synthetic", "cadata", "--m", "50", "--loss", "nope"]);
    assert!(!ok);
    assert!(err.contains("--loss") && err.contains("toppush"), "stderr: {err}");
    // missing data source
    let (ok, _, _) = run(&["train", "--m", "50"]);
    assert!(!ok);
    // nonexistent file
    let (ok, _, _) = run(&["info", "--data", "/nonexistent/file.libsvm"]);
    assert!(!ok);
}

#[test]
fn cli_cv_reports_the_lambda_path() {
    if bin().is_none() {
        return;
    }
    let sweep = |threads: &str| {
        run(&[
            "cv",
            "--synthetic",
            "cadata",
            "--m",
            "200",
            "--loss",
            "tree",
            "--lambdas",
            "1e-3,1e-1",
            "--folds",
            "3",
            "--seed",
            "7",
            "--metric",
            "auc",
            "--threads",
            threads,
        ])
    };
    let (ok, stdout, err) = sweep("2");
    assert!(ok, "cv failed: {err}");
    // One JSON path report line with the pinned schema and fields.
    assert!(stdout.contains("\"schema\":\"ranksvm-cv-path\""), "{stdout}");
    assert!(stdout.contains("\"schema_version\":1"), "{stdout}");
    assert!(stdout.contains("\"loss\":\"tree\""), "{stdout}");
    assert!(stdout.contains("\"metric\":\"auc\""), "{stdout}");
    assert!(stdout.contains("\"points\":["), "{stdout}");
    assert!(stdout.contains("\"lambda\":"), "{stdout}");
    assert!(stdout.contains("\"mean_error\":"), "{stdout}");
    assert!(stdout.contains("\"mean_auc\":"), "{stdout}");
    assert!(stdout.contains("\"mean_precision_at_k\":"), "{stdout}");
    assert!(stdout.contains("\"fold_errors\":["), "{stdout}");
    assert!(stdout.contains("\"selected_lambda\":"), "{stdout}");
    assert!(stdout.contains("\"total_iterations\":"), "{stdout}");
    // The report must carry no thread counts and no wall-clock fields:
    // CI byte-diffs the reports across --threads 1/2/8.
    assert!(!stdout.contains("thread"), "{stdout}");
    assert!(!stdout.contains("secs"), "{stdout}");
    // And the determinism contract end to end: another thread count,
    // byte-identical report.
    let (ok, stdout8, err) = sweep("8");
    assert!(ok, "cv --threads 8 failed: {err}");
    assert_eq!(stdout, stdout8, "cv report must be thread-count-invariant");

    // Unknown metric: exit 2, one readable line naming the value.
    let (ok, _, err) =
        run(&["cv", "--synthetic", "cadata", "--m", "60", "--metric", "bogus"]);
    assert!(!ok);
    assert!(err.contains("bogus") && err.contains("metric"), "stderr: {err}");
    assert!(!err.contains("panicked"), "stderr: {err}");
}

#[test]
fn cli_train_all_methods_smoke() {
    if bin().is_none() {
        return;
    }
    // Every registered loss, alternating the legacy --method and the
    // canonical --loss spellings (both must keep working). cadata's
    // real-valued labels put both signs in the data, so the bipartite
    // losses train too.
    for (i, method) in ranksvm::losses::registry::names().enumerate() {
        let flag = if i % 2 == 0 { "--method" } else { "--loss" };
        let (ok, stdout, err) = run(&[
            "train",
            "--synthetic",
            "cadata",
            "--m",
            "200",
            flag,
            method,
            "--lambda",
            "0.1",
        ]);
        assert!(ok, "loss {method} via {flag} failed: {err}");
        assert!(stdout.contains(&format!("\"method\":\"{method}\"")), "{method}: {stdout}");
        assert!(stdout.contains("\"solver\":\""), "{method}: missing solver field: {stdout}");
    }
}

#[test]
fn cli_losses_lists_the_registry() {
    if bin().is_none() {
        return;
    }
    let (ok, stdout, err) = run(&["losses"]);
    assert!(ok, "losses failed: {err}");
    for spec in ranksvm::losses::registry::SPECS {
        assert!(
            stdout.contains(&format!("\"name\":\"{}\"", spec.name)),
            "{} missing: {stdout}",
            spec.name
        );
        assert!(stdout.contains(&format!("\"solver\":\"{}\"", spec.solver.name())), "{stdout}");
    }
}
