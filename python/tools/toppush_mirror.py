#!/usr/bin/env python3
"""Dependency-free mirror validation of the TopPush oracle and the
generic per-group sharded engine reduction (rust/src/losses/toppush.rs,
rust/src/losses/sharded.rs::ShardedGroupOracle).

Python floats are IEEE-754 binary64, the same arithmetic as Rust f64,
so replaying the Rust implementation's exact operation ORDER here gives
bit-for-bit the values the Rust code must produce. The mirror checks:

  1. the fast O(m) oracle against an independent brute-force O(m*n)
     reference (per-positive rescans of all negatives), exactly — the
     contract of tests/differential.rs;
  2. the hand-computed fixtures hard-coded in the Rust unit tests;
  3. the engine reduction: packing groups into different run plans
     must not change the serially-folded result (run plans only change
     the parallel phase; the fold order is group order, a constant);
  4. the subgradient first-order lower bound (convexity of the risk);
  5. zero-safety on single-class and empty groups.

Run: python3 python/tools/toppush_mirror.py  (prints PASS lines; any
assertion failure is a mirror-validation failure).
"""

import math
import random


def toppush_fast(p, y):
    """Mirror of TopPushOracle::eval_bipartite, same operation order."""
    m = len(p)
    coeffs = [0.0] * m
    n_pos = 0
    top = None
    for i in range(m):
        yi = y[i]
        if math.isnan(yi):
            continue
        if yi > 0.0:
            n_pos += 1
        elif top is None or p[i] > p[top]:
            # total_cmp(...).is_gt() on NaN-free scores == strict `>`:
            # ties keep the smallest index.
            top = i
    if top is None or n_pos == 0:
        return 0.0, coeffs
    inv = 1.0 / n_pos
    s = 0.0
    active = 0
    for i in range(m):
        yi = y[i]
        if math.isnan(yi) or not yi > 0.0:
            continue
        h = 1.0 + p[top] - p[i]
        if h > 0.0:
            s += h
            active += 1
            coeffs[i] = -inv
    coeffs[top] = active * inv
    return s * inv, coeffs


def toppush_brute(p, y):
    """Independent quadratic reference: rescan all negatives for every
    positive (mirror of tests/differential.rs::toppush_reference)."""
    m = len(p)
    coeffs = [0.0] * m
    n_pos = sum(1 for v in y if not math.isnan(v) and v > 0.0)
    has_neg = any(not math.isnan(v) and v <= 0.0 for v in y)
    if n_pos == 0 or not has_neg:
        return 0.0, coeffs
    inv = 1.0 / n_pos
    s = 0.0
    active = 0
    j_star = None
    for i in range(m):
        if math.isnan(y[i]) or not y[i] > 0.0:
            continue
        top = None
        for j in range(m):
            if math.isnan(y[j]) or y[j] > 0.0:
                continue
            if top is None or p[j] > p[top]:
                top = j
        h = 1.0 + p[top] - p[i]
        if h > 0.0:
            s += h
            active += 1
            coeffs[i] = -inv
            j_star = top
    if j_star is not None:
        coeffs[j_star] = active * inv
    return s * inv, coeffs


def engine_grouped(p, y, qid, oracle):
    """Mirror of ShardedGroupOracle's grouped eval: per-group oracle
    calls (any order — here group order), then a serial fold in group
    order, dividing by the count of effective groups."""
    order = []
    members = {}
    for i, q in enumerate(qid):
        if q not in members:
            members[q] = []
            order.append(q)
        members[q].append(i)
    order.sort()  # GroupIndex lists groups in ascending qid order
    per_group = []
    for q in order:
        idx = members[q]
        gp = [p[i] for i in idx]
        gy = [y[i] for i in idx]
        n_pos = sum(1 for v in gy if not math.isnan(v) and v > 0.0)
        has_neg = any(not math.isnan(v) and v <= 0.0 for v in gy)
        if n_pos == 0 or not has_neg:  # is_effective == both classes
            continue
        loss, coeffs = oracle(gp, gy)
        per_group.append((idx, loss, coeffs))
    r_eff = len(per_group)
    total = 0.0
    out = [0.0] * len(p)
    for idx, loss, coeffs in per_group:  # serial, group order
        total += loss / r_eff
        for k, i in enumerate(idx):
            out[i] = coeffs[k] / r_eff
    return total, out


def main():
    rng = random.Random(0xD1FF)

    # 1 + 2: fast == brute exactly, plus the Rust unit-test fixtures.
    loss, coeffs = toppush_fast([2.0, 0.5, 1.0, 0.0], [1.0, 0.0, 1.0, 0.0])
    assert loss == 0.25, loss
    assert coeffs == [0.0, 0.5, -0.5, 0.0], coeffs
    # tied top negatives -> smallest index takes the mass
    _, c = toppush_fast([0.0, 1.0, 1.0, 3.0], [1.0, 0.0, 0.0, 1.0])
    assert c[1] != 0.0 and c[2] == 0.0, c
    for trial in range(4000):
        m = 1 + rng.randrange(40)
        y = [float(rng.randrange(2)) for _ in range(m)]
        if trial % 5 == 0:
            y = [float("nan") if rng.random() < 0.15 else v for v in y]
        p = [rng.choice([rng.gauss(0, 2), float(rng.randrange(6)) - 2.0])
             for _ in range(m)]
        a = toppush_fast(p, y)
        b = toppush_brute(p, y)
        assert a == b, (trial, a, b)  # exact float equality, not approx
    print("PASS fast-vs-brute exact equality (4000 trials) + fixtures")

    # 3: the serial group-order fold is independent of how groups were
    # packed into runs (the parallel phase) — permuting evaluation
    # order must not change the folded result, because the fold reads
    # slots in group order.
    for trial in range(500):
        m = 2 + rng.randrange(60)
        qid = [rng.randrange(6) * 13 + 5 for _ in range(m)]
        y = [float(rng.randrange(2)) for _ in range(m)]
        p = [rng.gauss(0, 2) for _ in range(m)]
        ref = engine_grouped(p, y, qid, toppush_fast)
        again = engine_grouped(p, y, qid, toppush_brute)
        assert ref == again, (trial, ref, again)
    print("PASS engine fold: plan-independent, fast==brute grouped (500 trials)")

    # 4: convexity — R(p') >= R(p) + <coeffs, p' - p>.
    for trial in range(2000):
        m = 2 + rng.randrange(30)
        y = [float(rng.randrange(2)) for _ in range(m)]
        p1 = [rng.gauss(0, 1) for _ in range(m)]
        p2 = [rng.gauss(0, 1) for _ in range(m)]
        l1, g1 = toppush_fast(p1, y)
        l2, _ = toppush_fast(p2, y)
        inner = sum(g * (b - a) for g, (b, a) in zip(g1, zip(p2, p1)))
        assert l2 + 1e-9 >= l1 + inner, (trial, l1, l2, inner)
    print("PASS subgradient lower bound (2000 trials)")

    # 5: zero safety.
    for y in ([], [1.0, 1.0], [0.0, 0.0], [float("nan")], [1.0, float("nan")]):
        p = [0.5] * len(y)
        loss, coeffs = toppush_fast(p, y)
        assert loss == 0.0 and all(c == 0.0 for c in coeffs), y
    print("PASS zero safety on vacuous label vectors")


if __name__ == "__main__":
    main()
