//! Figure 1 — average per-iteration subgradient cost: TreeRSVM vs
//! PairRSVM, on Cadata-like (left panel) and Reuters-like (right panel)
//! data over exponentially growing training sizes.
//!
//! The paper's claim: the tree oracle scales ~m log m, the pair oracle
//! ~m²; at 512k Reuters examples the gap is 7 s vs 2760 s. We reproduce
//! the *shape* (who wins, roughly what factor, crossover behaviour) on
//! this testbed. `FULL=1 cargo bench --bench fig1_iteration_cost` runs
//! the paper's grids.
//!
//! The tracked snapshot `BENCH_fig1_iteration_cost.json` is written
//! through the shared envelope (`ranksvm::obs::snapshot`,
//! docs/OBSERVABILITY.md): one metric row per (panel, m);
//! `RANKSVM_SNAPSHOT_SCHEMA_ONLY=1` emits the placeholder schema and
//! exits.

mod common;

use common::{data_from_env, fmt_secs, full_scale, header, prefix_grid, record};
use ranksvm::bmrm::ScoreOracle;
use ranksvm::coordinator::trainer::DatasetOracle;
use ranksvm::compute::NativeBackend;
use ranksvm::data::{synthetic, Dataset, DatasetView};
use ranksvm::linalg::simd::{self, Kernel};
use ranksvm::losses::{
    count_comparable_pairs, PairOracle, RankingOracle, ShardedTreeOracle, TreeOracle,
};
use ranksvm::runtime::WorkerPool;
use ranksvm::util::json::Json;
use std::sync::Arc;

fn host_threads() -> usize {
    ranksvm::util::resolve_threads(0)
}

/// Average full oracle cost (matvec + loss/subgradient + grad assembly)
/// over `reps` evaluations at a nontrivial w. Takes any [`DatasetView`]
/// — an owned synthetic set or a zero-copy slice of a mapped store.
fn oracle_cost(ds: &dyn DatasetView, oracle: Box<dyn RankingOracle>, reps: usize) -> f64 {
    let n_pairs = count_comparable_pairs(ds.y()) as f64;
    let mut dso = DatasetOracle::new(ds, Box::new(NativeBackend::new()), oracle, n_pairs);
    // Nontrivial weight vector: one least-squares-flavoured step.
    let mut w = vec![0.0; ds.dim()];
    ds.x().matvec_t(ds.y(), &mut w);
    let norm = ranksvm::linalg::ops::norm(&w).max(1e-12);
    ranksvm::linalg::ops::scal(1.0 / norm, &mut w);

    // warmup
    let p = dso.scores(&w);
    let (_, coeffs) = dso.risk_at(&p);
    std::hint::black_box(dso.grad(&coeffs));

    let t = std::time::Instant::now();
    for _ in 0..reps {
        let p = dso.scores(&w);
        let (_, coeffs) = dso.risk_at(&p);
        std::hint::black_box(dso.grad(&coeffs));
    }
    t.elapsed().as_secs_f64() / reps as f64
}

/// Snapshot fixture parameters (key set is part of the schema gate).
/// `kernel` records the resolved dispatch the timed columns ran on
/// (docs/OBSERVABILITY.md "Kernel dispatch").
fn params(full: bool, pair_cap: usize, threads: usize) -> Json {
    Json::obj(vec![
        ("full", full.into()),
        ("pair_cap", pair_cap.into()),
        ("threads", threads.into()),
        ("kernel", simd::active().name().into()),
    ])
}

/// One snapshot metric row (null values in schema-only mode).
/// `tree_scalar_secs` is the same tree-oracle measurement with the
/// dispatch forced scalar — the per-size SIMD speedup differential.
fn metric_row(
    panel: Json,
    m: Json,
    tree_secs: Json,
    tree_scalar_secs: Json,
    sharded_secs: Json,
    pair_secs: Json,
) -> Json {
    Json::obj(vec![
        ("panel", panel),
        ("m", m),
        ("tree_secs", tree_secs),
        ("tree_scalar_secs", tree_scalar_secs),
        ("sharded_secs", sharded_secs),
        ("pair_secs", pair_secs),
    ])
}

fn panel(
    name: &str,
    make: &dyn Fn(usize) -> Dataset,
    sizes: &[usize],
    pair_cap: usize,
    rows: &mut Vec<Json>,
) {
    let threads = host_threads();
    // One persistent pool for the whole panel — the trainer's
    // arrangement: workers are spawned once and reused by the sharded
    // oracle (and its parallel argsort) at every size and rep.
    let pool = Arc::new(WorkerPool::new(threads));
    header(&format!(
        "Fig 1 ({name}): avg subgradient-computation cost per iteration"
    ));
    println!(
        "{:>9} {:>14} {:>14} {:>14} {:>14} {:>9} {:>9} {:>9}",
        "m",
        "TreeRSVM",
        "Tree(scalar)",
        format!("Sharded({threads})"),
        "PairRSVM",
        "simd ×",
        "par ×",
        "pair ×"
    );
    for &m in sizes {
        let ds = make(m);
        size_row(name, &ds, m, &pool, threads, pair_cap, rows);
    }
}

/// One measured size within a panel.
#[allow(clippy::too_many_arguments)]
fn size_row(
    name: &str,
    ds: &dyn DatasetView,
    m: usize,
    pool: &Arc<WorkerPool>,
    threads: usize,
    pair_cap: usize,
    rows: &mut Vec<Json>,
) {
    let reps = if m <= 4000 { 5 } else { 2 };
    let tree = oracle_cost(ds, Box::new(TreeOracle::new()), reps);
    // The same measurement with the dispatch pinned to the scalar
    // reference: the "simd ×" column. The paths are bit-identical
    // (docs/DETERMINISM.md "Kernel dispatch"), so this differs in
    // wall-clock only.
    simd::force(Some(Kernel::Scalar));
    let tree_scalar = oracle_cost(ds, Box::new(TreeOracle::new()), reps);
    simd::force(None);
    let sharded_oracle = ShardedTreeOracle::with_pool(Arc::clone(pool), None, ds.y());
    let sharded = oracle_cost(ds, Box::new(sharded_oracle), reps);
    let (pair, speedup) = if m <= pair_cap {
        let p = oracle_cost(ds, Box::new(PairOracle::new()), reps.min(3));
        (Some(p), p / tree)
    } else {
        (None, f64::NAN)
    };
    println!(
        "{:>9} {:>14} {:>14} {:>14} {:>14} {:>9} {:>9} {:>9}",
        m,
        fmt_secs(tree),
        fmt_secs(tree_scalar),
        fmt_secs(sharded),
        pair.map(fmt_secs).unwrap_or_else(|| "(skipped)".into()),
        format!("{:.2}×", tree_scalar / tree.max(1e-12)),
        format!("{:.2}×", tree / sharded.max(1e-12)),
        if speedup.is_nan() { "-".into() } else { format!("{speedup:.1}×") },
    );
    record(
        "fig1_iteration_cost",
        Json::obj(vec![
            ("panel", name.into()),
            ("m", m.into()),
            ("tree_secs", tree.into()),
            ("tree_scalar_secs", tree_scalar.into()),
            ("sharded_secs", sharded.into()),
            ("threads", threads.into()),
            ("kernel", simd::active().name().into()),
            ("pair_secs", pair.map(Json::Num).unwrap_or(Json::Null)),
        ]),
    );
    rows.push(metric_row(
        name.into(),
        m.into(),
        tree.into(),
        tree_scalar.into(),
        sharded.into(),
        pair.map(Json::Num).unwrap_or(Json::Null),
    ));
}

fn main() {
    let full = full_scale();
    // Paper grids: cadata to 16k; reuters to 512k (tree) / pair included
    // throughout (it took 46 min/iter at 512k on 2006 hardware — the
    // default grid caps the pair oracle earlier).
    let cadata_sizes: Vec<usize> = vec![1000, 2000, 4000, 8000, 16000];
    let reuters_sizes: Vec<usize> = if full {
        vec![1000, 2000, 4000, 8000, 16000, 32000, 64000, 128000, 256000, 512000]
    } else {
        vec![1000, 2000, 4000, 8000, 16000, 32000, 64000]
    };
    let pair_cap = if full { 512000 } else { 16000 };
    if common::schema_only() {
        let n = || Json::Null;
        common::write_snapshot(
            "fig1_iteration_cost",
            true,
            params(full, pair_cap, host_threads()),
            vec![metric_row(n(), n(), n(), n(), n(), n())],
        );
        return;
    }
    let mut rows = Vec::new();

    panel("cadata", &|m| synthetic::cadata_like(m, 100), &cadata_sizes, pair_cap, &mut rows);
    panel("reuters", &|m| synthetic::reuters_like(m, 200), &reuters_sizes, pair_cap, &mut rows);

    // Real-data panel: growing zero-copy prefixes of a mapped store
    // (RANKSVM_DATA=foo.pstore — convert once, mmap forever).
    if let Some(loaded) = data_from_env() {
        let view = loaded.view();
        let threads = host_threads();
        let pool = Arc::new(WorkerPool::new(threads));
        header(&format!(
            "Fig 1 ({}): avg subgradient cost per iteration, growing prefixes",
            view.name()
        ));
        for m in prefix_grid(view.len()) {
            let prefix = view.prefix_view(m);
            size_row(view.name(), &prefix, m, &pool, threads, pair_cap, &mut rows);
        }
    }

    common::write_snapshot(
        "fig1_iteration_cost",
        false,
        params(full, pair_cap, host_threads()),
        rows,
    );

    println!("\nExpected shape (paper): tree ≈ m·log m (near-linear rows), pair ≈ m²");
    println!("(4× more data → pair column grows ~16×, tree column ~4–5×).");
    println!(
        "Sharded column: same exact counts on a persistent {}-worker pool",
        host_threads()
    );
    println!("(threads spawned once per panel, argsort parallelized) — \"par ×\" should");
    println!("exceed 1 on multi-core hosts at the larger sizes (tiny m is sync-bound).");
}
