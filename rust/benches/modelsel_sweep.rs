//! Model-selection sweep — the parallel warm-started λ-path engine
//! (`coordinator::modelsel`, docs/DETERMINISM.md "model selection").
//!
//! Fixture: cadata-like global ranking data. Three runs over the same
//! k-fold × λ grid: the serial cold reference (`cv_serial`, warm start
//! off — every (fold, λ) cell trained from scratch), the serial warm
//! path (each λ seeded by the previous point's cutting-plane bundle),
//! and the parallel warm sweep (`cv_sweep`) on every available worker.
//! Before timing anything the bench asserts the determinism contract —
//! the parallel warm report must be bit-identical to the serial warm
//! report, fold models included — and that warm and cold paths select
//! the same λ with the warm path spending no more solver iterations.
//! The tracked snapshot `BENCH_modelsel_sweep.json` is written through
//! the shared envelope; `RANKSVM_SNAPSHOT_SCHEMA_ONLY=1` emits the
//! placeholder schema and exits.

mod common;

use common::{fmt_secs, full_scale, header, record};
use ranksvm::coordinator::{cv_serial, cv_sweep, CvConfig, CvReport, Method, TrainConfig};
use ranksvm::data::synthetic;
use ranksvm::util::json::Json;

/// Snapshot fixture parameters (key set is part of the schema gate).
/// `kernel` records the resolved compute-kernel dispatch the timings
/// ran on (docs/OBSERVABILITY.md "Kernel dispatch").
fn params(m: usize, folds: usize, lambdas: usize, threads: usize) -> Json {
    Json::obj(vec![
        ("m", m.into()),
        ("folds", folds.into()),
        ("lambdas", lambdas.into()),
        ("threads", threads.into()),
        ("kernel", ranksvm::linalg::simd::active().name().into()),
    ])
}

/// One snapshot metric row (null values in schema-only mode).
fn metric_row(
    cold_secs: Json,
    warm_secs: Json,
    sweep_secs: Json,
    cold_iters: Json,
    warm_iters: Json,
) -> Json {
    Json::obj(vec![
        ("cold_secs", cold_secs),
        ("warm_secs", warm_secs),
        ("sweep_secs", sweep_secs),
        ("cold_iters", cold_iters),
        ("warm_iters", warm_iters),
    ])
}

/// The parallel engine is *defined* to reproduce the serial one — check
/// every field the report carries, fold models byte-for-byte.
fn assert_identical(a: &CvReport, b: &CvReport) {
    assert_eq!(a.selected_lambda, b.selected_lambda, "selected λ diverged");
    assert_eq!(a.total_iterations, b.total_iterations, "iteration totals diverged");
    assert_eq!(a.points.len(), b.points.len());
    for (pa, pb) in a.points.iter().zip(&b.points) {
        assert_eq!(pa.lambda, pb.lambda);
        assert_eq!(pa.fold_errors, pb.fold_errors, "λ={} fold errors diverged", pa.lambda);
        assert_eq!(pa.fold_aucs, pb.fold_aucs, "λ={} fold AUCs diverged", pa.lambda);
        assert_eq!(pa.fold_iterations, pb.fold_iterations);
        assert_eq!(pa.fold_weights, pb.fold_weights, "λ={} fold models diverged", pa.lambda);
    }
}

fn main() {
    let threads = ranksvm::util::resolve_threads(0);
    let (m, folds) = if full_scale() { (20_000, 5) } else { (3_000, 3) };
    let grid = [1e-4, 1e-3, 1e-2, 1e-1, 1.0];
    if common::schema_only() {
        let n = || Json::Null;
        common::write_snapshot(
            "modelsel_sweep",
            true,
            params(m, folds, grid.len(), threads),
            vec![metric_row(n(), n(), n(), n(), n())],
        );
        return;
    }
    let ds = synthetic::cadata_like(m, 42);
    let base = TrainConfig { method: Method::Tree, n_threads: threads, ..Default::default() };
    let warm_cfg = CvConfig::new(base.clone(), grid.to_vec(), folds, 7);
    let cold_cfg = CvConfig { warm_start: false, ..warm_cfg.clone() };

    header(&format!(
        "Model selection: {folds}-fold × {} λ path, m = {m}, {threads} threads",
        grid.len()
    ));

    let t = std::time::Instant::now();
    let cold = cv_serial(&ds, &cold_cfg).unwrap();
    let t_cold = t.elapsed().as_secs_f64();

    let t = std::time::Instant::now();
    let warm = cv_serial(&ds, &warm_cfg).unwrap();
    let t_warm = t.elapsed().as_secs_f64();

    let t = std::time::Instant::now();
    let sweep = cv_sweep(&ds, &warm_cfg).unwrap();
    let t_sweep = t.elapsed().as_secs_f64();

    // Contracts before the table: parallel ≡ serial, warm ≤ cold work,
    // both paths agree on the winner.
    assert_identical(&warm, &sweep);
    assert_eq!(
        cold.selected_lambda, warm.selected_lambda,
        "warm and cold paths disagree on λ"
    );
    assert!(
        warm.total_iterations <= cold.total_iterations,
        "warm start spent more iterations ({}) than cold ({})",
        warm.total_iterations,
        cold.total_iterations
    );

    println!(
        "{:>24} {:>12} {:>12} {:>12}",
        "engine", "wall", "iters", "vs cold"
    );
    println!(
        "{:>24} {:>12} {:>12} {:>12}",
        "serial cold", fmt_secs(t_cold), cold.total_iterations, "1.00×"
    );
    println!(
        "{:>24} {:>12} {:>12} {:>11.2}×",
        "serial warm",
        fmt_secs(t_warm),
        warm.total_iterations,
        t_cold / t_warm.max(1e-12)
    );
    println!(
        "{:>24} {:>12} {:>12} {:>11.2}×",
        format!("parallel warm ({threads}t)"),
        fmt_secs(t_sweep),
        sweep.total_iterations,
        t_cold / t_sweep.max(1e-12)
    );
    println!(
        "selected λ = {} (all engines agree); warm saved {} iterations",
        warm.selected_lambda,
        cold.total_iterations - warm.total_iterations
    );

    let rec = vec![
        ("bench", Json::Str("modelsel_sweep".into())),
        ("m", m.into()),
        ("folds", folds.into()),
        ("lambdas", grid.len().into()),
        ("threads", threads.into()),
        ("cold_secs", t_cold.into()),
        ("warm_secs", t_warm.into()),
        ("sweep_secs", t_sweep.into()),
        ("cold_iters", cold.total_iterations.into()),
        ("warm_iters", warm.total_iterations.into()),
        ("selected_lambda", warm.selected_lambda.into()),
    ];
    record("modelsel_sweep", Json::obj(rec));

    common::write_snapshot(
        "modelsel_sweep",
        false,
        params(m, folds, grid.len(), threads),
        vec![metric_row(
            t_cold.into(),
            t_warm.into(),
            t_sweep.into(),
            cold.total_iterations.into(),
            warm.total_iterations.into(),
        )],
    );
}
