//! Dense vector kernels used throughout the optimizers and losses.
//!
//! Written as straightforward slice loops; rustc auto-vectorizes the
//! chunked forms. `dot` is the innermost hot operation of the native
//! compute backend (score matvec) and of the BMRM inner QP.

/// Dot product. Panics if lengths differ (debug) / truncates never.
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    // 4-way unrolled accumulation helps the auto-vectorizer and reduces
    // the sequential FP dependency chain.
    let mut acc = [0.0f64; 4];
    let chunks = a.len() / 4;
    for k in 0..chunks {
        let i = k * 4;
        acc[0] += a[i] * b[i];
        acc[1] += a[i + 1] * b[i + 1];
        acc[2] += a[i + 2] * b[i + 2];
        acc[3] += a[i + 3] * b[i + 3];
    }
    let mut s = acc[0] + acc[1] + acc[2] + acc[3];
    for i in chunks * 4..a.len() {
        s += a[i] * b[i];
    }
    s
}

/// `y += alpha * x`.
#[inline]
pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += alpha * xi;
    }
}

/// `y = x` (copy).
#[inline]
pub fn copy(x: &[f64], y: &mut [f64]) {
    y.copy_from_slice(x);
}

/// `x *= alpha`.
#[inline]
pub fn scal(alpha: f64, x: &mut [f64]) {
    for xi in x.iter_mut() {
        *xi *= alpha;
    }
}

/// Squared L2 norm.
#[inline]
pub fn norm_sq(x: &[f64]) -> f64 {
    dot(x, x)
}

/// L2 norm.
#[inline]
pub fn norm(x: &[f64]) -> f64 {
    norm_sq(x).sqrt()
}

/// Argsort: indices that sort `v` ascending (stable). This is the
/// `π` construction on line 4 of Algorithm 3.
pub fn argsort(v: &[f64]) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..v.len()).collect();
    idx.sort_by(|&a, &b| v[a].partial_cmp(&v[b]).expect("NaN in sort key"));
    idx
}

/// Argsort reusing a caller-owned index buffer (avoids the per-iteration
/// allocation in the BMRM loop — §Perf optimization).
pub fn argsort_into(v: &[f64], idx: &mut Vec<usize>) {
    idx.clear();
    idx.extend(0..v.len());
    idx.sort_by(|&a, &b| v[a].partial_cmp(&v[b]).expect("NaN in sort key"));
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_small_and_remainder() {
        assert_eq!(dot(&[1.0, 2.0, 3.0, 4.0, 5.0], &[1.0; 5]), 15.0);
        assert_eq!(dot(&[], &[]), 0.0);
        assert_eq!(dot(&[2.0], &[3.0]), 6.0);
    }

    #[test]
    fn axpy_and_scal() {
        let mut y = vec![1.0, 1.0];
        axpy(2.0, &[3.0, 4.0], &mut y);
        assert_eq!(y, vec![7.0, 9.0]);
        scal(0.5, &mut y);
        assert_eq!(y, vec![3.5, 4.5]);
    }

    #[test]
    fn norms() {
        assert_eq!(norm_sq(&[3.0, 4.0]), 25.0);
        assert_eq!(norm(&[3.0, 4.0]), 5.0);
    }

    #[test]
    fn argsort_orders_and_is_stable() {
        let v = [3.0, 1.0, 2.0, 1.0];
        let idx = argsort(&v);
        assert_eq!(idx, vec![1, 3, 2, 0]); // stable: 1 before 3
        let mut buf = Vec::new();
        argsort_into(&v, &mut buf);
        assert_eq!(buf, idx);
    }

    #[test]
    fn dot_matches_naive_randomized() {
        let mut rng = crate::util::rng::Rng::new(5);
        for _ in 0..50 {
            let n = rng.below(200);
            let a: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let naive: f64 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
            assert!((dot(&a, &b) - naive).abs() < 1e-9 * (1.0 + naive.abs()));
        }
    }
}
