//! Kernel-dispatch lockdown (docs/DETERMINISM.md "Kernel dispatch"):
//! the scalar reference fold and the AVX2 path must be **bit-identical**
//! — per kernel call on adversarial CSR shapes, and end to end on
//! trained weights at 1/2/8 threads with the dispatch forced both ways.
//! Plus the cache-aware chunk-target knob, which may never move a bit.
//!
//! `simd::force` and `cache::set_chunk_target_kib` are process-global,
//! so every test that touches them serializes on [`dispatch_lock`] and
//! restores the default state before releasing it.

use ranksvm::coordinator::{train, Method, TrainConfig};
use ranksvm::data::synthetic;
use ranksvm::linalg::simd::{self, Kernel};
use ranksvm::linalg::CsrMatrix;
use ranksvm::runtime::cache;
use ranksvm::util::rng::Rng;
use std::sync::Mutex;

/// One lock for all process-global dispatch state in this binary.
fn dispatch_lock() -> std::sync::MutexGuard<'static, ()> {
    static LOCK: Mutex<()> = Mutex::new(());
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

/// Run `f` with the kernel dispatch pinned to `k`, restoring lazy
/// resolution afterwards — including when `f` panics. Without the drop
/// guard, one failing assertion would leave the kernel globally forced
/// for every later test in this binary ([`dispatch_lock`] deliberately
/// ignores poisoning), silently pinning "auto" tests to one path.
fn with_kernel<T>(k: Kernel, f: impl FnOnce() -> T) -> T {
    struct Unforce;
    impl Drop for Unforce {
        fn drop(&mut self) {
            simd::force(None);
        }
    }
    let _restore = Unforce;
    simd::force(Some(k));
    f()
}

/// Adversarial value pool: denormals, ±0.0, huge and tiny magnitudes —
/// everything that could expose a rounding-order difference between the
/// two paths (NaN excluded by the crate's NaN-free data contract).
fn adversarial_value(rng: &mut Rng) -> f64 {
    match rng.below(8) {
        0 => 0.0,
        1 => -0.0,
        2 => f64::MIN_POSITIVE / 2.0,  // subnormal
        3 => -f64::MIN_POSITIVE / 4.0, // subnormal
        4 => 1e300,
        5 => -1e-300,
        _ => rng.normal(),
    }
}

/// A CSR fixture with deliberately nasty row shapes: empty rows, a fully
/// dense row, rows of every `len % 4` remainder class, adversarial
/// values throughout.
fn adversarial_matrix(rng: &mut Rng, rows: usize, cols: usize) -> CsrMatrix {
    let mut triplets = Vec::new();
    for r in 0..rows {
        let nnz = match r % 7 {
            0 => 0,    // empty row
            1 => cols, // dense row
            k => k,    // remainder classes 1..=6 around the 4-wide unroll
        };
        let mut seen = std::collections::BTreeSet::new();
        while seen.len() < nnz {
            seen.insert(rng.below(cols));
        }
        for c in seen {
            triplets.push((r, c, adversarial_value(rng)));
        }
    }
    CsrMatrix::from_triplets(rows, cols, triplets)
}

#[test]
fn forced_kernels_agree_bitwise_on_adversarial_matrices() {
    let _guard = dispatch_lock();
    let mut rng = Rng::new(0xD1FF);
    for (rows, cols) in [(1usize, 1usize), (23, 5), (64, 64), (301, 17)] {
        let x = adversarial_matrix(&mut rng, rows, cols);
        let w: Vec<f64> = (0..cols).map(|_| adversarial_value(&mut rng)).collect();
        let v: Vec<f64> = (0..rows).map(|_| adversarial_value(&mut rng)).collect();

        let (mut p_s, mut p_v) = (vec![0.0; rows], vec![0.0; rows]);
        with_kernel(Kernel::Scalar, || x.matvec(&w, &mut p_s));
        with_kernel(Kernel::Simd, || x.matvec(&w, &mut p_v));
        for (r, (a, b)) in p_s.iter().zip(&p_v).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "{rows}x{cols} matvec row {r}");
        }

        let (mut g_s, mut g_v) = (vec![0.0; cols], vec![0.0; cols]);
        with_kernel(Kernel::Scalar, || x.matvec_t(&v, &mut g_s));
        with_kernel(Kernel::Simd, || x.matvec_t(&v, &mut g_v));
        for (c, (a, b)) in g_s.iter().zip(&g_v).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "{rows}x{cols} matvec_t col {c}");
        }

        for r in 0..rows {
            let a = with_kernel(Kernel::Scalar, || x.row_dot(r, &w));
            let b = with_kernel(Kernel::Simd, || x.row_dot(r, &w));
            assert_eq!(a.to_bits(), b.to_bits(), "{rows}x{cols} row_dot {r}");
        }
    }
}

/// The acceptance differential: whole training runs, dispatch forced
/// scalar and SIMD, at 1/2/8 threads, on a global and a grouped fixture
/// — every weight vector byte-identical. (On hosts without AVX2,
/// `force(Simd)` downgrades to scalar, so the assertion is trivially
/// true there; CI runs the leg on AVX2 hardware.)
#[test]
fn trained_weights_are_byte_identical_across_kernels_and_threads() {
    let _guard = dispatch_lock();
    for (ds, tag) in [
        (synthetic::cadata_like(400, 2101), "global"),
        (synthetic::queries(15, 16, 6, 2102), "grouped"),
    ] {
        let mut reference: Option<Vec<f64>> = None;
        for threads in [1usize, 2, 8] {
            let cfg = TrainConfig {
                method: Method::Tree,
                lambda: 0.1,
                epsilon: 1e-3,
                n_threads: threads,
                ..Default::default()
            };
            for kernel in [Kernel::Scalar, Kernel::Simd] {
                let out = with_kernel(kernel, || train(&ds, &cfg).unwrap());
                assert!(out.converged, "{tag}: {threads} threads, {}", kernel.name());
                match &reference {
                    None => reference = Some(out.model.w),
                    Some(w) => assert_eq!(
                        &out.model.w,
                        w,
                        "{tag}: {threads} threads, {} kernel diverged",
                        kernel.name()
                    ),
                }
            }
        }
    }
}

/// Forcing a kernel pins dispatch; releasing it re-resolves to something
/// runnable; forcing SIMD on a scalar-only host safely downgrades to
/// scalar rather than pinning a kernel the host cannot execute.
#[test]
fn force_pins_and_releases_the_dispatch() {
    let _guard = dispatch_lock();
    with_kernel(Kernel::Scalar, || assert_eq!(simd::active(), Kernel::Scalar));
    let runnable = if simd::simd_supported() {
        Kernel::Simd
    } else {
        Kernel::Scalar
    };
    with_kernel(Kernel::Simd, || assert_eq!(simd::active(), runnable));
    // After release, lazy resolution must yield a runnable kernel again.
    if simd::active() == Kernel::Simd {
        assert!(simd::simd_supported());
    }
}

/// Forced-kernel passes land on the matching registry counter — the
/// observability story for "which path did my run take".
#[test]
fn kernel_passes_hit_the_dispatch_counters() {
    let _guard = dispatch_lock();
    let x = CsrMatrix::from_triplets(3, 4, vec![(0, 1, 2.0), (2, 3, -1.0)]);
    let w = vec![1.0; 4];
    let mut p = vec![0.0; 3];
    let before = ranksvm::obs::metrics::KERNEL_SCALAR_PASSES.get();
    with_kernel(Kernel::Scalar, || x.matvec(&w, &mut p));
    let after = ranksvm::obs::metrics::KERNEL_SCALAR_PASSES.get();
    assert!(after > before, "scalar pass not counted: {before} → {after}");
    if simd::simd_supported() {
        let before = ranksvm::obs::metrics::KERNEL_SIMD_PASSES.get();
        with_kernel(Kernel::Simd, || x.matvec(&w, &mut p));
        let after = ranksvm::obs::metrics::KERNEL_SIMD_PASSES.get();
        assert!(after > before, "simd pass not counted: {before} → {after}");
    }
}

/// The cache-aware chunk target is a pure speed knob: absurdly small and
/// absurdly large targets must train byte-identical models (chunk counts
/// shape integer-exact decompositions only — docs/DETERMINISM.md).
#[test]
fn chunk_target_cannot_change_any_trained_bit() {
    let _guard = dispatch_lock();
    let ds = synthetic::cadata_like(500, 2203);
    let mut reference: Option<Vec<f64>> = None;
    for kib in [0usize, 4, 64, 1 << 20] {
        // Through the config, the way the CLI wires --chunk-target-kib
        // (train() installs it process-globally at startup).
        let cfg = TrainConfig {
            method: Method::Tree,
            lambda: 0.1,
            epsilon: 1e-3,
            n_threads: 4,
            chunk_target_kib: kib,
            ..Default::default()
        };
        let out = train(&ds, &cfg).unwrap();
        match &reference {
            None => reference = Some(out.model.w),
            Some(w) => assert_eq!(&out.model.w, w, "chunk target {kib} KiB moved a bit"),
        }
    }
    cache::set_chunk_target_kib(0);
    // And the sizing rule itself engages: a big working set at a small
    // target yields more chunks than the adaptive floor.
    let floor = ranksvm::linalg::ops::adaptive_chunks(4);
    assert!(cache::chunks_for(64 << 20, 256 * 1024, floor) > floor);
}
