//! Work-stealing scheduler lockdown: adversarial group-size skew.
//!
//! The stealing pool and the fine-grained task plans exist to fix the
//! wall-clock of skewed batches — but the repo's core contract is that
//! no scheduling decision may touch a result bit. This battery throws
//! the worst skew shapes at the sharded oracle (one giant query group
//! next to thousands of singletons, Zipf-sampled group sizes, tied-score
//! clusters in global mode) and requires bitwise identity with the
//! serial oracles: across thread counts, across task-granularity plans,
//! and across repeated evaluations on one long-lived pool — i.e. under
//! maximally different stealing histories.

use ranksvm::coordinator::{train, Method, TrainConfig};
use ranksvm::data::synthetic;
use ranksvm::losses::{
    count_comparable_pairs, QueryGrouped, RankingOracle, ShardedTreeOracle, TreeOracle,
};
use ranksvm::runtime::WorkerPool;
use ranksvm::util::rng::Rng;
use std::sync::Arc;

/// One giant group (~40% of the mass) plus thousands of singletons —
/// the shape that serialized the coarse one-task-per-worker plan.
fn giant_plus_singletons(rng: &mut Rng, giant: usize, singletons: usize) -> (Vec<u64>, Vec<f64>) {
    let m = giant + singletons;
    let mut qid = Vec::with_capacity(m);
    qid.extend(std::iter::repeat(0u64).take(giant));
    qid.extend((1..=singletons).map(|g| g as u64));
    let y: Vec<f64> = (0..m).map(|_| rng.below(5) as f64).collect();
    (qid, y)
}

#[test]
fn giant_group_plus_singletons_bitwise_across_threads_and_rounds() {
    let mut rng = Rng::new(0x5CED_0001);
    let (qid, y) = giant_plus_singletons(&mut rng, 1200, 2000);
    let m = y.len();
    let mut serial = QueryGrouped::new(TreeOracle::new(), &qid, &y);
    for threads in [1usize, 2, 3, 8] {
        let pool = Arc::new(WorkerPool::new(threads));
        let mut sharded = ShardedTreeOracle::with_pool(Arc::clone(&pool), Some(&qid), &y);
        // Repeated evaluations on one pool with evolving scores: every
        // round reuses worker state under a fresh stealing history.
        let mut round_rng = Rng::new(0x5CED_0002);
        for round in 0..3 {
            let p: Vec<f64> = (0..m).map(|_| round_rng.normal() * (round + 1) as f64).collect();
            let expect = serial.eval(&p, &y, serial.total_pairs());
            let got = sharded.eval(&p, &y, 0.0);
            assert_eq!(got.coeffs, expect.coeffs, "{threads} threads, round {round}");
            assert_eq!(
                got.loss.to_bits(),
                expect.loss.to_bits(),
                "{threads} threads, round {round}"
            );
        }
    }
}

#[test]
fn zipf_sampled_group_sizes_bitwise_on_one_shared_pool() {
    // Zipf-sampled sizes, interleaved (non-contiguous) qids, grouped and
    // global oracles sharing one pool — the trainer's arrangement under
    // the data shape the issue targets.
    let mut rng = Rng::new(0x5CED_0003);
    let n_groups = 400;
    let mut qid: Vec<u64> = Vec::new();
    for g in 0..n_groups {
        let sz = 1 + rng.zipf(60, 1.1);
        qid.extend(std::iter::repeat(g as u64).take(sz));
    }
    rng.shuffle(&mut qid);
    let m = qid.len();
    let y: Vec<f64> = (0..m).map(|_| rng.below(4) as f64).collect();
    let n = count_comparable_pairs(&y) as f64;
    let mut serial_grouped = QueryGrouped::new(TreeOracle::new(), &qid, &y);
    let mut serial_global = TreeOracle::new();
    for threads in [1usize, 2, 3, 8] {
        let pool = Arc::new(WorkerPool::new(threads));
        let mut grouped = ShardedTreeOracle::with_pool(Arc::clone(&pool), Some(&qid), &y);
        let mut global = ShardedTreeOracle::with_pool(Arc::clone(&pool), None, &y);
        let mut round_rng = Rng::new(0x5CED_0004);
        for round in 0..3 {
            let p: Vec<f64> = (0..m).map(|_| round_rng.normal()).collect();
            let expect_g = serial_grouped.eval(&p, &y, serial_grouped.total_pairs());
            let got_g = grouped.eval(&p, &y, 0.0);
            assert_eq!(got_g.coeffs, expect_g.coeffs, "grouped, {threads} threads, {round}");
            assert_eq!(got_g.loss.to_bits(), expect_g.loss.to_bits());
            let expect = serial_global.eval(&p, &y, n);
            let got = global.eval(&p, &y, n);
            assert_eq!(got.coeffs, expect.coeffs, "global, {threads} threads, {round}");
            assert_eq!(got.loss.to_bits(), expect.loss.to_bits());
        }
    }
}

#[test]
fn global_mode_score_clusters_bitwise_across_threads() {
    // Skew in *window* sizes: half the scores collapse onto one value
    // (their margin windows span the whole cluster), the rest spread
    // out. Chunk tasks over the sorted order see wildly uneven tree
    // sweeps; counts must stay exact at every thread count.
    let mut rng = Rng::new(0x5CED_0005);
    let m = 4000;
    let y: Vec<f64> = (0..m).map(|_| rng.below(6) as f64).collect();
    let p: Vec<f64> = (0..m)
        .map(|i| if i % 2 == 0 { 0.25 } else { rng.normal() * 3.0 })
        .collect();
    let n = count_comparable_pairs(&y) as f64;
    let mut reference = TreeOracle::new();
    let expect = reference.eval(&p, &y, n);
    for threads in [1usize, 2, 3, 8] {
        let mut sharded = ShardedTreeOracle::new(threads, None, &y);
        let got = sharded.eval(&p, &y, n);
        assert_eq!(got.coeffs, expect.coeffs, "{threads} threads");
        assert_eq!(got.loss.to_bits(), expect.loss.to_bits(), "{threads} threads");
    }
}

#[test]
fn task_granularity_is_invisible_in_results_on_skewed_input() {
    // The same skewed fixture through coarse (one task per worker — the
    // PR 1–3 plan), default, and absurdly fine plans: the granularity
    // knob may only move wall-clock, never a bit.
    let mut rng = Rng::new(0x5CED_0006);
    let (qid, y) = giant_plus_singletons(&mut rng, 600, 1000);
    let m = y.len();
    let p: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
    let n = count_comparable_pairs(&y) as f64;
    let mut serial_grouped = QueryGrouped::new(TreeOracle::new(), &qid, &y);
    let expect_grouped = serial_grouped.eval(&p, &y, serial_grouped.total_pairs());
    let mut serial_global = TreeOracle::new();
    let expect_global = serial_global.eval(&p, &y, n);
    let pool = Arc::new(WorkerPool::new(8));
    for target in [8usize, 32, 97] {
        let mut grouped =
            ShardedTreeOracle::with_run_target(Arc::clone(&pool), Some(&qid), &y, target);
        let got = grouped.eval(&p, &y, 0.0);
        assert_eq!(got.coeffs, expect_grouped.coeffs, "grouped, target {target}");
        assert_eq!(got.loss.to_bits(), expect_grouped.loss.to_bits());
        let mut global = ShardedTreeOracle::with_run_target(Arc::clone(&pool), None, &y, target);
        let got = global.eval(&p, &y, n);
        assert_eq!(got.coeffs, expect_global.coeffs, "global, target {target}");
        assert_eq!(got.loss.to_bits(), expect_global.loss.to_bits());
    }
}

#[test]
fn training_on_zipf_fixture_is_bitwise_thread_invariant() {
    // End-to-end: full BMRM runs on a Zipf(1.1) grouped fixture and a
    // global fixture must produce byte-identical models at 1/2/8
    // threads — the CI thread-matrix assertion, in-process.
    for (ds, tag) in [
        (synthetic::zipf_queries(1200, 240, 6, 1.1, 901), "zipf-grouped"),
        (synthetic::cadata_like(500, 902), "global"),
    ] {
        let mut reference: Option<(Vec<f64>, u64, usize)> = None;
        for threads in [1usize, 2, 8] {
            let cfg = TrainConfig {
                method: Method::Tree,
                lambda: 0.1,
                epsilon: 1e-3,
                n_threads: threads,
                ..Default::default()
            };
            let out = train(&ds, &cfg).unwrap();
            assert!(out.converged, "{tag}: {threads} threads failed to converge");
            match &reference {
                None => reference = Some((out.model.w, out.objective.to_bits(), out.iterations)),
                Some((w, obj, iters)) => {
                    assert_eq!(&out.model.w, w, "{tag}: weights differ at {threads} threads");
                    assert_eq!(out.objective.to_bits(), *obj, "{tag}: {threads} threads");
                    assert_eq!(out.iterations, *iters, "{tag}: {threads} threads");
                }
            }
        }
    }
}

#[test]
fn empty_groups_and_tiny_inputs_survive_every_plan() {
    // All-tied groups (zero comparable pairs) interleaved with real
    // ones, fewer examples than workers, single-group data: the packer
    // and the scheduler must agree on every edge.
    let qid = [7u64, 7, 3, 3, 3, 9];
    let y = [1.0, 1.0, 2.0, 1.0, 3.0, 5.0]; // group 7 is all-tied
    let p = [0.4, -0.1, 0.9, 0.2, -0.3, 0.0];
    let mut serial = QueryGrouped::new(TreeOracle::new(), &qid, &y);
    let expect = serial.eval(&p, &y, serial.total_pairs());
    for threads in [1usize, 2, 8] {
        let mut sharded = ShardedTreeOracle::new(threads, Some(&qid), &y);
        let got = sharded.eval(&p, &y, 0.0);
        assert_eq!(got.coeffs, expect.coeffs, "{threads} threads");
        assert_eq!(got.loss.to_bits(), expect.loss.to_bits(), "{threads} threads");
    }
    // Single group, many workers.
    let qid1 = vec![4u64; 5];
    let y1 = [1.0, 2.0, 3.0, 1.0, 2.0];
    let p1 = [0.1, 0.5, 0.2, 0.9, 0.0];
    let mut serial1 = QueryGrouped::new(TreeOracle::new(), &qid1, &y1);
    let expect1 = serial1.eval(&p1, &y1, serial1.total_pairs());
    let mut sharded1 = ShardedTreeOracle::new(8, Some(&qid1), &y1);
    let got1 = sharded1.eval(&p1, &y1, 0.0);
    assert_eq!(got1.coeffs, expect1.coeffs);
}
