//! PairRSVM baseline: the "most obvious approach" of §4.1 — iterate
//! explicitly over all comparable pairs to accumulate the frequencies
//! (5)–(6). `O(m²)` time, `O(m)` extra memory. Identical output to
//! [`super::tree::TreeOracle`] (the paper trains both under the same
//! BMRM and notes they reach exactly the same solution), so Fig. 1/2
//! measure pure oracle-cost differences.

use super::{assemble_from_counts, OracleOutput, RankingOracle};

/// Explicit-pairs oracle.
pub struct PairOracle {
    c: Vec<u64>,
    d: Vec<u64>,
}

impl PairOracle {
    pub fn new() -> Self {
        PairOracle { c: Vec::new(), d: Vec::new() }
    }

    /// Raw frequency computation by the double loop.
    pub fn compute_counts(&mut self, p: &[f64], y: &[f64]) -> (&[u64], &[u64]) {
        let m = p.len();
        assert_eq!(m, y.len());
        self.c.clear();
        self.c.resize(m, 0);
        self.d.clear();
        self.d.resize(m, 0);
        // One triangular pass: for each unordered pair, orient by y and
        // apply the margin test of eqs. (5)/(6). A pair with y_i < y_j and
        // p_i > p_j − 1 contributes to c_i and to d_j (the two sets are
        // mirror images).
        for i in 0..m {
            for j in (i + 1)..m {
                let (lo, hi) = if y[i] < y[j] {
                    (i, j)
                } else if y[j] < y[i] {
                    (j, i)
                } else {
                    continue;
                };
                // lo has the smaller label; canonical margin violation
                // test (same float expression in every oracle):
                if 1.0 + p[lo] - p[hi] > 0.0 {
                    self.c[lo] += 1;
                    self.d[hi] += 1;
                }
            }
        }
        (&self.c, &self.d)
    }
}

impl Default for PairOracle {
    fn default() -> Self {
        Self::new()
    }
}

impl RankingOracle for PairOracle {
    fn eval(&mut self, p: &[f64], y: &[f64], n_pairs: f64) -> OracleOutput {
        self.compute_counts(p, y);
        assemble_from_counts(p, &self.c, &self.d, n_pairs)
    }

    fn name(&self) -> &'static str {
        "pair"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::losses::{count_comparable_pairs, tree::TreeOracle};
    use crate::util::rng::Rng;

    #[test]
    fn agrees_exactly_with_tree_oracle() {
        let mut rng = Rng::new(101);
        for trial in 0..40 {
            let m = 1 + rng.below(150);
            let y: Vec<f64> = match trial % 4 {
                0 => (0..m).map(|_| rng.normal()).collect(),
                1 => (0..m).map(|_| rng.below(3) as f64).collect(),
                2 => (0..m).map(|_| rng.below(2) as f64).collect(),
                _ => vec![1.0; m], // fully tied
            };
            let p: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
            let n = count_comparable_pairs(&y) as f64;
            let mut pair = PairOracle::new();
            let mut tree = TreeOracle::new();
            let op = pair.eval(&p, &y, n);
            let ot = tree.eval(&p, &y, n);
            assert_eq!(op.coeffs, ot.coeffs, "trial {trial}");
            assert!((op.loss - ot.loss).abs() < 1e-12, "trial {trial}");
        }
    }

    #[test]
    fn counts_are_symmetric_totals() {
        // Σc_i == Σd_i (every violating pair is counted once on each side).
        let mut rng = Rng::new(103);
        let m = 80;
        let y: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
        let p: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
        let mut pair = PairOracle::new();
        let (c, d) = pair.compute_counts(&p, &y);
        assert_eq!(c.iter().sum::<u64>(), d.iter().sum::<u64>());
    }

    #[test]
    fn boundary_margin_is_open_interval() {
        // p_i == p_j − 1 exactly → NOT a violation (strict inequality
        // in eq. (5)): hinge is max(0, 1 + p_i − p_j) = 0.
        let y = [0.0, 1.0];
        let p = [-1.0, 0.0];
        let mut pair = PairOracle::new();
        let (c, d) = pair.compute_counts(&p, &y);
        assert_eq!(c, &[0, 0]);
        assert_eq!(d, &[0, 0]);
    }
}
