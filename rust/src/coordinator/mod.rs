//! The L3 coordinator: configuration, the training entry point, model
//! persistence, and the memory-probe subprocess protocol.
//!
//! The paper's contribution (the tree-based oracle) lives in
//! [`crate::losses::tree`]; this module is the framework face that a
//! downstream user touches: [`TrainConfig`] → [`train`] → [`TrainOutcome`]
//! (+ [`evaluate`], [`TrainOutcome::scoring_model`] →
//! [`crate::serve::ScoringModel::save`] for the binary model the
//! serving path loads; the legacy text [`RankModel::save`] remains for
//! interchange).

pub mod config;
pub mod memprobe;
pub mod model;
pub mod modelsel;
pub mod trainer;

pub use config::{BackendKind, Method, Normalize, TrainConfig};
pub use model::RankModel;
pub use modelsel::{
    cross_validate, cv_serial, cv_sweep, kfold_indices, select_by_metric, select_lambda,
    CvConfig, CvMetric, CvPoint, CvReport,
};
pub use trainer::{evaluate, evaluate_scoring, train, TrainOutcome};

/// Re-exported so coordinator users see one model-persistence surface.
pub use crate::serve::ScoringModel;
