//! TopPush — bipartite ranking loss that optimizes accuracy *at the top*
//! of the list (Li, Jin & Zhou, "Top Rank Optimization in Linear Time",
//! arXiv:1410.1462).
//!
//! Instead of penalizing every misordered pair, TopPush penalizes each
//! positive only against the **highest-scoring negative**:
//!
//! ```text
//! R(p) = (1/n₊) Σ_{i : y_i > 0} [ 1 + max_{j : y_j ≤ 0} p_j − p_i ]₊
//! ```
//!
//! Pushing every positive above the top negative is exactly what
//! optimizes precision at the very top of the ranking, and — the reason
//! the loss fits this engine — the inner maximum makes one oracle call
//! `O(m)`: one pass finds the top negative, one pass accumulates the
//! hinges. `R` stays convex in `p` (a sum of maxima of affine
//! functions), so it drops straight into the BMRM cutting-plane solver
//! behind the same [`OracleOutput`] contract as the pairwise family.
//!
//! Normalization is owned by this loss (the [`GroupOracle`] contract):
//! the per-group risk divides by the positive count `n₊`, *not* by the
//! comparable-pair count the pairwise hinges use — `pairs` is ignored.
//! Labels partition at zero: `y > 0` is positive, any other non-NaN
//! label is negative, NaN labels belong to neither class (consistent
//! with the NaN-incomparability convention of the tree sweeps).
//!
//! Determinism: the top negative is selected by `total_cmp` with a
//! strictly-greater predicate, so ties keep the *smallest index* — the
//! subgradient never depends on iteration order, and the hinge
//! accumulation runs in ascending example order. One evaluation is
//! bit-reproducible, which is all the sharded engine's serial
//! group-order reduction needs (docs/DETERMINISM.md).

use super::{GroupOracle, OracleOutput, RankingOracle};

/// The TopPush subgradient oracle. Stateless — kept as a unit struct so
/// it plugs into the per-task `Box<dyn GroupOracle>` slots of the
/// sharded engine like the buffered tree oracles do.
#[derive(Default)]
pub struct TopPushOracle;

impl TopPushOracle {
    pub fn new() -> Self {
        TopPushOracle
    }
}

/// One bipartite TopPush evaluation over a single (query-group) slice.
///
/// Subgradient: every *active* positive (`1 + p_{j*} − p_i > 0`, the
/// same strict-hinge predicate as the pairwise sweeps) contributes
/// `−1/n₊` to its own coefficient and `+1/n₊` to the top negative `j*`;
/// the `j*` coefficient is assembled as one `active·(1/n₊)` product so
/// the result cannot depend on accumulation order.
fn eval_bipartite(p: &[f64], y: &[f64]) -> OracleOutput {
    let m = p.len();
    debug_assert_eq!(m, y.len());
    let mut coeffs = vec![0.0; m];
    let mut n_pos = 0u64;
    let mut top_neg: Option<usize> = None;
    for i in 0..m {
        let yi = y[i];
        if yi.is_nan() {
            continue;
        }
        if yi > 0.0 {
            n_pos += 1;
        } else {
            let better = match top_neg {
                None => true,
                Some(j) => p[i].total_cmp(&p[j]).is_gt(),
            };
            if better {
                top_neg = Some(i);
            }
        }
    }
    let (Some(j_star), true) = (top_neg, n_pos > 0) else {
        // Single-class (or empty) slice: zero loss, zero subgradient.
        return OracleOutput { loss: 0.0, coeffs };
    };
    let inv = 1.0 / n_pos as f64;
    let margin = p[j_star];
    let mut sum = 0.0;
    let mut active = 0u64;
    for i in 0..m {
        let yi = y[i];
        if yi.is_nan() || yi <= 0.0 {
            continue;
        }
        let h = 1.0 + margin - p[i];
        if h > 0.0 {
            sum += h;
            active += 1;
            coeffs[i] = -inv;
        }
    }
    coeffs[j_star] = active as f64 * inv;
    OracleOutput { loss: sum * inv, coeffs }
}

impl GroupOracle for TopPushOracle {
    /// `pairs` is ignored: TopPush normalizes by its positive count.
    fn eval_group(&mut self, p: &[f64], y: &[f64], _pairs: u64) -> OracleOutput {
        eval_bipartite(p, y)
    }

    /// A group contributes iff both classes are present (the loss and
    /// subgradient are identically zero otherwise).
    fn is_effective(&self, y: &[f64], _pairs: u64) -> bool {
        let mut pos = false;
        let mut neg = false;
        for &v in y {
            if v.is_nan() {
                continue;
            }
            if v > 0.0 {
                pos = true;
            } else {
                neg = true;
            }
            if pos && neg {
                return true;
            }
        }
        false
    }

    fn name(&self) -> &'static str {
        "toppush"
    }
}

impl RankingOracle for TopPushOracle {
    /// Serial whole-dataset evaluation (one implicit group). `n_pairs`
    /// is ignored — normalization is the oracle's own (see module docs);
    /// the `n_pairs == 0` ⇒ zero contract still holds because zero
    /// comparable pairs means a single label value, hence one class.
    fn eval(&mut self, p: &[f64], y: &[f64], _n_pairs: f64) -> OracleOutput {
        eval_bipartite(p, y)
    }

    fn name(&self) -> &'static str {
        "toppush"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hand_computed_case() {
        // Negatives at idx 1 (0.5) and 3 (0.0) → j* = 1, margin 0.5.
        // Positive idx 0 clears the margin (2.0 ≥ 1.5), idx 2 does not.
        let p = [2.0, 0.5, 1.0, 0.0];
        let y = [1.0, 0.0, 1.0, 0.0];
        let out = eval_bipartite(&p, &y);
        assert!((out.loss - 0.25).abs() < 1e-15);
        assert_eq!(out.coeffs, vec![0.0, 0.5, -0.5, 0.0]);
    }

    #[test]
    fn single_class_is_zero_safe() {
        let mut o = TopPushOracle::new();
        for y in [vec![1.0, 2.0, 3.0], vec![0.0, -1.0, 0.0], vec![]] {
            let p = vec![0.5; y.len()];
            let out = o.eval(&p, &y, 0.0);
            assert_eq!(out.loss, 0.0);
            assert!(out.coeffs.iter().all(|&c| c == 0.0));
            assert!(!o.is_effective(&y, 0));
        }
    }

    #[test]
    fn tied_top_negatives_pick_smallest_index() {
        // Two negatives tied at the top score: the subgradient mass must
        // land on index 1 (first seen), deterministically.
        let p = [0.0, 3.0, 3.0];
        let y = [1.0, 0.0, 0.0];
        let out = eval_bipartite(&p, &y);
        assert_eq!(out.coeffs, vec![-1.0, 1.0, 0.0]);
        assert!((out.loss - 4.0).abs() < 1e-15);
    }

    #[test]
    fn inactive_positives_contribute_nothing() {
        // All positives clear the margin: zero loss, zero coefficients
        // (including the top negative's, since no hinge is active).
        let p = [5.0, 4.0, 0.0];
        let y = [2.0, 1.0, 0.0];
        let out = eval_bipartite(&p, &y);
        assert_eq!(out.loss, 0.0);
        assert_eq!(out.coeffs, vec![0.0, 0.0, 0.0]);
    }

    #[test]
    fn nan_labels_belong_to_neither_class() {
        // The NaN row would be the top "negative" by score if counted.
        let p = [0.0, 9.0, 1.0];
        let y = [1.0, f64::NAN, 0.0];
        let out = eval_bipartite(&p, &y);
        assert_eq!(out.coeffs[1], 0.0);
        assert_eq!(out.coeffs, vec![-1.0, 0.0, 1.0]);
        assert!((out.loss - 2.0).abs() < 1e-15);
    }

    #[test]
    fn subgradient_is_a_lower_bound() {
        // Convexity check: R(q) ≥ R(p) + ⟨g, q − p⟩ for random pairs.
        let mut rng = crate::util::rng::Rng::new(77);
        let mut o = TopPushOracle::new();
        for _ in 0..50 {
            let m = 2 + rng.below(40);
            let y: Vec<f64> = (0..m).map(|_| rng.below(2) as f64).collect();
            let p: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
            let q: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
            let at_p = o.eval(&p, &y, 0.0);
            let at_q = o.eval(&q, &y, 0.0);
            let lin: f64 =
                at_p.coeffs.iter().zip(p.iter().zip(&q)).map(|(g, (a, b))| g * (b - a)).sum();
            assert!(at_q.loss >= at_p.loss + lin - 1e-9, "subgradient overestimates");
        }
    }
}
