//! Online scoring: the [`ScoringModel`] (versioned binary model
//! format) and the `ranksvm serve` daemon built on it.
//!
//! Training produces a ranking function; this module is what runs it
//! in production. The pieces, bottom up:
//!
//! - [`scoring`] — the standalone [`ScoringModel`]: weights **plus**
//!   the recorded `--normalize` mode and training-set column norms, in
//!   a checksummed mmap-able format (`.rsm`) that shares the pallas
//!   store's header/checksum machinery. One scoring kernel
//!   ([`scoring::score_row`]) is used by `predict`, `evaluate`, and
//!   the daemon, so every path scores bit-identically.
//! - [`engine`] — the [`Engine`]: an immutable model epoch behind one
//!   pointer swap, score batches fanned onto the shared work-stealing
//!   [`crate::runtime::WorkerPool`], per-query top-k via a bounded
//!   heap, and atomic zero-downtime hot swap with a version counter
//!   in every response.
//! - [`protocol`] — the newline-delimited wire grammar and response
//!   rendering (scores print with the same `{}` formatting as
//!   `ranksvm predict`, making serving output byte-comparable).
//! - [`daemon`] — transport front-ends: stdio (the default, and what
//!   CI drives) and thread-per-connection TCP via `--listen`.
//!
//! `tests/serve.rs` pins serving parity, top-k correctness, hot-swap
//! consistency, and the format fuzz battery; `docs/MODEL_FORMAT.md`
//! is the normative format spec (pinned by `tests/model_spec.rs`).

pub mod daemon;
pub mod engine;
pub mod protocol;
pub mod scoring;

pub use daemon::{handle_connection, serve_stdio, serve_tcp};
pub use engine::{top_k, Engine, ModelEpoch};
pub use protocol::{Payload, Request, Response, Selector};
pub use scoring::{score_csr, score_row, ScoringModel};
