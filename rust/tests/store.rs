//! Pallas-store differential suite.
//!
//! The store's contract: `convert → mmap → train` is **bit-identical**
//! to `parse text → train`, for grouped and global datasets, at any
//! thread count — and a damaged store is *rejected at open*, never
//! silently mistrained. Both halves are pinned here, along with the
//! converter's bounded-memory guarantee (exact spill-buffer accounting
//! in-process; child-process peak-RSS in `convert_cli_bounded_memory`).

use ranksvm::coordinator::{evaluate, memprobe, train, Method, Normalize, TrainConfig};
use ranksvm::data::store::{
    compute_col_stats, convert_libsvm, is_store_file, ConvertOptions, PallasStore, VERSION,
};
use ranksvm::data::{libsvm, materialize, synthetic, Dataset, DatasetView};
use ranksvm::losses::GroupIndex;

fn tmp(name: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("ranksvm_store_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(name)
}

/// Write `ds` as libsvm text and return (text path, parsed-text dataset,
/// opened store). Both loaded forms originate from the same bytes on
/// disk, which is exactly the differential the CLI exercises.
fn text_and_store(ds: &Dataset, tag: &str) -> (std::path::PathBuf, Dataset, PallasStore) {
    let text = tmp(&format!("{tag}.libsvm"));
    let pst = tmp(&format!("{tag}.pstore"));
    libsvm::write(ds, &text).unwrap();
    let reference = libsvm::read(&text).unwrap();
    convert_libsvm(&text, &pst, &ConvertOptions::default()).unwrap();
    assert!(is_store_file(&pst));
    assert!(!is_store_file(&text));
    let store = PallasStore::open(&pst).unwrap();
    (text, reference, store)
}

fn assert_same_data(a: &dyn DatasetView, b: &dyn DatasetView) {
    assert_eq!(a.len(), b.len());
    assert_eq!(a.dim(), b.dim());
    assert_eq!(a.y(), b.y());
    assert_eq!(a.qid(), b.qid());
    assert_eq!(a.x().nnz(), b.x().nnz());
    for i in 0..a.len() {
        assert_eq!(a.x().row(i), b.x().row(i), "row {i}");
    }
}

fn cfg(threads: usize) -> TrainConfig {
    TrainConfig {
        method: Method::Tree,
        lambda: 0.1,
        epsilon: 1e-3,
        n_threads: threads,
        ..Default::default()
    }
}

#[test]
fn global_roundtrip_is_bit_identical() {
    let ds = synthetic::cadata_like(400, 9);
    let (_, reference, store) = text_and_store(&ds, "global");
    assert_same_data(&reference, &store);
    assert_eq!(
        store.n_pairs(),
        ranksvm::losses::count_comparable_pairs(&reference.y),
        "precomputed pair count must match the text-path recount"
    );
    for threads in [1usize, 8] {
        let a = train(&reference, &cfg(threads)).unwrap();
        let b = train(&store, &cfg(threads)).unwrap();
        assert_eq!(a.model.w, b.model.w, "{threads} threads");
        assert_eq!(a.objective.to_bits(), b.objective.to_bits(), "{threads} threads");
        assert_eq!(a.iterations, b.iterations, "{threads} threads");
        // And the model evaluates identically against either source.
        assert_eq!(evaluate(&a.model, &reference), evaluate(&a.model, &store));
    }
}

#[test]
fn grouped_roundtrip_is_bit_identical() {
    let ds = synthetic::queries(15, 12, 6, 10);
    assert!(ds.qid.is_some());
    let (_, reference, store) = text_and_store(&ds, "grouped");
    assert_same_data(&reference, &store);
    // The serialized group index is exactly what a scan would build.
    let built = GroupIndex::build(reference.qid.as_deref().unwrap(), &reference.y);
    assert_eq!(store.group_index().as_deref(), Some(&built));
    assert_eq!(store.n_groups(), built.n_groups());
    for threads in [1usize, 8] {
        let a = train(&reference, &cfg(threads)).unwrap();
        let b = train(&store, &cfg(threads)).unwrap();
        assert_eq!(a.model.w, b.model.w, "{threads} threads");
        assert_eq!(a.objective.to_bits(), b.objective.to_bits(), "{threads} threads");
    }
}

#[test]
fn degenerate_queries_roundtrip() {
    // One singleton query, one all-tied query (zero comparable pairs),
    // one normal query — the empty-query fixture of the issue.
    let text = tmp("degenerate.libsvm");
    std::fs::write(
        &text,
        "2 qid:7 1:1.0\n\
         1 qid:3 1:0.5 2:1.0\n\
         1 qid:3 2:2.0\n\
         1 qid:3 1:0.25\n\
         3 qid:9 1:2.0\n\
         1 qid:9 2:0.5\n",
    )
    .unwrap();
    let pst = tmp("degenerate.pstore");
    let stats = convert_libsvm(&text, &pst, &ConvertOptions::default()).unwrap();
    assert_eq!(stats.n_groups, 3);
    let reference = libsvm::read(&text).unwrap();
    let store = PallasStore::open(&pst).unwrap();
    assert_same_data(&reference, &store);
    for threads in [1usize, 8] {
        let a = train(&reference, &cfg(threads)).unwrap();
        let b = train(&store, &cfg(threads)).unwrap();
        assert_eq!(a.model.w, b.model.w);
        assert_eq!(a.objective.to_bits(), b.objective.to_bits());
    }
}

#[test]
fn empty_dataset_roundtrips() {
    let text = tmp("empty.libsvm");
    std::fs::write(&text, "# nothing but comments\n").unwrap();
    let pst = tmp("empty.pstore");
    let stats = convert_libsvm(&text, &pst, &ConvertOptions::default()).unwrap();
    assert_eq!((stats.rows, stats.nnz, stats.n_pairs), (0, 0, 0));
    let store = PallasStore::open(&pst).unwrap();
    assert!(store.is_empty());
    assert_eq!(store.dim(), 0);
}

#[test]
fn converter_output_is_chunk_size_invariant_and_bounded() {
    // Own subdirectory: the spill-litter check below must not race with
    // other tests' in-flight conversions.
    let dir = std::env::temp_dir().join(format!("ranksvm_store_chunks_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let ds = synthetic::reuters_like_with(3000, 800, 20, 4);
    let text = dir.join("chunks.libsvm");
    libsvm::write(&ds, &text).unwrap();
    let out_small = dir.join("chunks_small.pstore");
    let out_big = dir.join("chunks_big.pstore");
    let small = ConvertOptions { chunk_bytes: 4096, ..Default::default() };
    let stats_small = convert_libsvm(&text, &out_small, &small).unwrap();
    let stats_big = convert_libsvm(&text, &out_big, &ConvertOptions::default()).unwrap();
    // The chunk size controls flush cadence only — identical bytes out.
    let a = std::fs::read(&out_small).unwrap();
    let b = std::fs::read(&out_big).unwrap();
    assert_eq!(a, b, "store bytes must not depend on chunk size");
    // Bounded ingest: the spill buffers never exceeded the budget (plus
    // one 12-byte entry of slack per buffer).
    assert!(
        stats_small.max_buffered_bytes <= small.chunk_bytes + 32,
        "max buffered {} vs chunk {}",
        stats_small.max_buffered_bytes,
        small.chunk_bytes
    );
    // The fixture really was larger than the chunk budget.
    assert!(stats_small.nnz * 12 > 8 * small.chunk_bytes);
    assert_eq!(stats_small.nnz, stats_big.nnz);
    // Spill temp files were cleaned up.
    for leftover in std::fs::read_dir(out_small.parent().unwrap()).unwrap() {
        let name = leftover.unwrap().file_name().to_string_lossy().to_string();
        assert!(!name.ends_with(".tmp"), "spill litter: {name}");
    }
}

/// Regression for the spill-copy read buffer: it used to be clamped to
/// 8 MiB no matter what chunk size was requested, silently splitting one
/// configured read into many. A conversion whose value spill exceeds
/// 8 MiB, copied with a >8 MiB chunk request, must produce bytes
/// identical to the default conversion (and the buffer sizing itself is
/// unit-pinned in `data/store/writer.rs`).
#[test]
fn chunk_requests_above_8mib_copy_big_spills_byte_identically() {
    let ds = synthetic::reuters_like_with(40_000, 2000, 30, 92);
    let text = tmp("bigspill.libsvm");
    libsvm::write(&ds, &text).unwrap();
    let out_default = tmp("bigspill_default.pstore");
    let out_big = tmp("bigspill_big.pstore");
    let stats = convert_libsvm(&text, &out_default, &ConvertOptions::default()).unwrap();
    // The value spill really is bigger than the old 8 MiB buffer cap.
    assert!(stats.nnz * 8 > 8 << 20, "fixture too small: nnz={}", stats.nnz);
    let big = ConvertOptions { chunk_bytes: 32 << 20, n_threads: 1 };
    convert_libsvm(&text, &out_big, &big).unwrap();
    assert_eq!(
        std::fs::read(&out_default).unwrap(),
        std::fs::read(&out_big).unwrap(),
        "a >8 MiB chunk request changed the output bytes"
    );
}

#[test]
fn corrupted_stores_are_rejected() {
    let ds = synthetic::queries(6, 10, 4, 77);
    text_and_store(&ds, "victim");
    let good = std::fs::read(tmp("victim.pstore")).unwrap();

    // Flip one payload byte → checksum mismatch. (192 = v3 HEADER_LEN;
    // halfway into the payload is well clear of the header.)
    let mut bad = good.clone();
    let k = 192 + bad.len() / 2;
    bad[k] ^= 0x40;
    let p = tmp("bad_checksum.pstore");
    std::fs::write(&p, &bad).unwrap();
    let err = PallasStore::open(&p).unwrap_err().to_string();
    assert!(err.contains("checksum"), "{err}");

    // Truncate → short file.
    let p = tmp("bad_short.pstore");
    std::fs::write(&p, &good[..good.len() - 16]).unwrap();
    let err = PallasStore::open(&p).unwrap_err().to_string();
    assert!(err.contains("short") || err.contains("section"), "{err}");

    // Trailing garbage is also a geometry violation.
    let mut bad = good.clone();
    bad.extend_from_slice(&[0u8; 8]);
    let p = tmp("bad_trailing.pstore");
    std::fs::write(&p, &bad).unwrap();
    assert!(PallasStore::open(&p).is_err());

    // Misalign a section offset (values section, header offset slot 2).
    let mut bad = good.clone();
    let slot = 64 + 2 * 8;
    let mut off = u64::from_le_bytes(bad[slot..slot + 8].try_into().unwrap());
    off += 4;
    bad[slot..slot + 8].copy_from_slice(&off.to_le_bytes());
    let p = tmp("bad_align.pstore");
    std::fs::write(&p, &bad).unwrap();
    let err = PallasStore::open(&p).unwrap_err().to_string();
    assert!(err.contains("aligned") || err.contains("section"), "{err}");

    // Unsupported version byte.
    let mut bad = good.clone();
    bad[7] = 9;
    let p = tmp("bad_version.pstore");
    std::fs::write(&p, &bad).unwrap();
    let err = PallasStore::open(&p).unwrap_err().to_string();
    assert!(err.contains("version"), "{err}");

    // Wrong magic → not recognized as a store at all.
    let mut bad = good;
    bad[0] = b'X';
    let p = tmp("bad_magic.pstore");
    std::fs::write(&p, &bad).unwrap();
    assert!(!is_store_file(&p));
    assert!(PallasStore::open(&p).is_err());
}

/// Seeded fuzz: any single-byte flip over a valid store must surface as
/// a *structured error* from `open()` — never a panic, never a silent
/// success. This is exactly the contract the version-2 format buys by
/// extending the checksum over the header: geometry checks catch
/// structural damage, the full-file checksum catches everything else
/// (an unused flag bit, a high byte of `cols`, a payload value).
#[test]
fn fuzzed_single_byte_flips_never_panic_and_always_error() {
    use ranksvm::util::rng::Rng;
    use std::panic::{catch_unwind, AssertUnwindSafe};
    for (ds, tag) in [
        (synthetic::queries(8, 10, 5, 404), "fuzz_grouped"),
        (synthetic::cadata_like(60, 405), "fuzz_global"),
    ] {
        let (_, _, store) = text_and_store(&ds, tag);
        drop(store);
        let good = std::fs::read(tmp(&format!("{tag}.pstore"))).unwrap();
        let victim = tmp(&format!("{tag}_flip.pstore"));
        let mut rng = Rng::new(0xF11B);
        for trial in 0..250usize {
            let pos = rng.below(good.len());
            let bit = 1u8 << rng.below(8);
            let mut bad = good.clone();
            bad[pos] ^= bit;
            std::fs::write(&victim, &bad).unwrap();
            let outcome =
                catch_unwind(AssertUnwindSafe(|| PallasStore::open(&victim).map(|_| ())));
            let Ok(result) = outcome else {
                panic!("{tag} trial {trial}: open() panicked on byte {pos} bit {bit:#04x}")
            };
            let err = match result {
                Err(e) => e,
                Ok(()) => panic!(
                    "{tag} trial {trial}: store with byte {pos} bit {bit:#04x} flipped \
                     opened successfully — corruption went undetected"
                ),
            };
            assert!(!err.to_string().is_empty(), "{tag}: empty error message");
            // The unchecked path may accept a payload flip by contract,
            // but it must never panic either.
            let unchecked = catch_unwind(AssertUnwindSafe(|| {
                PallasStore::open_unchecked(&victim).map(|_| ()).is_ok()
            }));
            assert!(
                unchecked.is_ok(),
                "{tag} trial {trial}: open_unchecked() panicked on byte {pos} bit {bit:#04x}"
            );
        }
    }
}

#[test]
fn open_unchecked_skips_payload_scan_but_not_geometry() {
    let ds = synthetic::cadata_like(120, 5);
    let (_, reference, _) = text_and_store(&ds, "unchecked");
    let p = tmp("unchecked.pstore");
    let store = PallasStore::open_unchecked(&p).unwrap();
    assert_same_data(&reference, &store);
    // Geometry violations are still caught...
    let good = std::fs::read(&p).unwrap();
    let bad_path = tmp("unchecked_short.pstore");
    std::fs::write(&bad_path, &good[..good.len() - 8]).unwrap();
    assert!(PallasStore::open_unchecked(&bad_path).is_err());
    // ...but a payload flip is (by contract) not:
    let mut bad = good;
    let k = bad.len() - 4;
    bad[k] ^= 1;
    let bad_path = tmp("unchecked_flip.pstore");
    std::fs::write(&bad_path, &bad).unwrap();
    assert!(PallasStore::open_unchecked(&bad_path).is_ok());
    assert!(PallasStore::open(&bad_path).is_err());
}

#[test]
fn prefix_views_slice_the_mapping() {
    let ds = synthetic::queries(10, 20, 5, 13);
    let (_, reference, store) = text_and_store(&ds, "prefix");
    for m in [0usize, 1, 73, 200] {
        let pv = store.prefix_view(m);
        let owned = reference.prefix(m);
        assert_same_data(&pv, &owned);
        // A prefix drops the precomputed index (it may no longer apply).
        assert!(pv.group_index().is_none());
    }
    // Training on a prefix view matches training on the owned prefix.
    let pv = store.prefix_view(120);
    let owned = reference.prefix(120);
    let a = train(&owned, &cfg(2)).unwrap();
    let b = train(&pv, &cfg(2)).unwrap();
    assert_eq!(a.model.w, b.model.w);
}

#[test]
fn materialize_store_supports_owned_ops() {
    let ds = synthetic::cadata_like(150, 21);
    let (_, reference, store) = text_and_store(&ds, "materialize");
    let owned = materialize(&store);
    assert_same_data(&owned, &reference);
    let (tr_a, te_a) = owned.split(30, 5);
    let (tr_b, te_b) = reference.split(30, 5);
    assert_eq!(tr_a.y, tr_b.y);
    assert_eq!(te_a.y, te_b.y);
}

/// The tentpole contract of the v3 parallel converter: the emitted
/// `.pstore` is byte-identical for any `--threads` value — including the
/// single-shard serial path — because shard concatenation happens in
/// byte order and every float reduction is serial (phase 2). Whole-file
/// compare at 1/2/8 threads, on a grouped and a global fixture.
#[test]
fn parallel_convert_is_byte_identical_for_any_thread_count() {
    for (ds, tag) in [
        (synthetic::queries(40, 25, 6, 70), "par_grouped"),
        (synthetic::cadata_like(1500, 71), "par_global"),
    ] {
        let text = tmp(&format!("{tag}.libsvm"));
        libsvm::write(&ds, &text).unwrap();
        let mut outputs: Vec<Vec<u8>> = Vec::new();
        for threads in [1usize, 2, 8] {
            let out = tmp(&format!("{tag}.t{threads}.pstore"));
            let opts = ConvertOptions { chunk_bytes: 64 * 1024, n_threads: threads };
            let stats = convert_libsvm(&text, &out, &opts).unwrap();
            if threads == 1 {
                assert_eq!(stats.shards, 1, "{tag}: thread 1 must take the serial path");
            } else {
                assert!(
                    stats.shards > 1,
                    "{tag}: fixture too small to engage sharding ({} shards)",
                    stats.shards
                );
                // Bounded ingest still holds, with per-shard slack.
                assert!(
                    stats.max_buffered_bytes <= opts.chunk_bytes + 64 * stats.shards,
                    "{tag}: buffered {} vs budget {}",
                    stats.max_buffered_bytes,
                    opts.chunk_bytes
                );
            }
            outputs.push(std::fs::read(&out).unwrap());
        }
        assert_eq!(outputs[0], outputs[1], "{tag}: 1 vs 2 threads diverge");
        assert_eq!(outputs[0], outputs[2], "{tag}: 1 vs 8 threads diverge");
        // The parallel artifact opens, verifies, and matches the text.
        let store = PallasStore::open(tmp(&format!("{tag}.t8.pstore"))).unwrap();
        let reference = libsvm::read(&text).unwrap();
        assert_same_data(&reference, &store);
    }
}

/// Parse errors surface with exact global `name:line` context no matter
/// which shard hits them — the stitch phase reconstructs the line
/// number from the preceding shards' line counts.
#[test]
fn parallel_convert_reports_global_line_numbers() {
    // Own subdirectory: the spill-litter check below must not race with
    // other tests' in-flight conversions.
    let dir = std::env::temp_dir().join(format!("ranksvm_store_badline_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    // Big enough to shard at 4 threads; poison one line near the end.
    let ds = synthetic::cadata_like(1200, 73);
    let text = dir.join("par_badline.libsvm");
    libsvm::write(&ds, &text).unwrap();
    let mut contents = std::fs::read_to_string(&text).unwrap();
    let bad_lineno = 1100usize;
    let byte_off: usize = contents
        .split_inclusive('\n')
        .take(bad_lineno - 1)
        .map(str::len)
        .sum();
    contents.insert_str(byte_off, "1 7:notanumber\n");
    std::fs::write(&text, &contents).unwrap();
    for threads in [1usize, 4] {
        let out = dir.join(format!("par_badline.t{threads}.pstore"));
        let opts = ConvertOptions { chunk_bytes: 64 * 1024, n_threads: threads };
        let err = convert_libsvm(&text, &out, &opts).unwrap_err().to_string();
        assert!(
            err.contains(&format!(":{bad_lineno}")),
            "{threads} threads: error lost the line number: {err}"
        );
        assert!(!out.exists(), "{threads} threads: failed convert left an output behind");
    }
    // No spill litter either.
    for leftover in std::fs::read_dir(text.parent().unwrap()).unwrap() {
        let name = leftover.unwrap().file_name().to_string_lossy().to_string();
        assert!(!name.ends_with(".tmp"), "spill litter: {name}");
    }
}

/// COLSTATS acceptance: the cached per-column stats equal a from-scratch
/// recomputation *exactly* (bitwise on the float fields), and expose the
/// quantities the normalization path needs.
#[test]
fn colstats_match_recomputation_exactly() {
    for (ds, tag) in [
        (synthetic::queries(12, 15, 6, 80), "stats_grouped"),
        (synthetic::reuters_like_with(400, 300, 12, 81), "stats_sparse"),
    ] {
        let (_, reference, store) = text_and_store(&ds, tag);
        let stats = store.col_stats().expect("v3 stores cache column stats");
        assert_eq!(stats.len(), reference.dim(), "{tag}");
        let fresh = compute_col_stats(DatasetView::x(&reference));
        assert_eq!(stats.len(), fresh.len(), "{tag}");
        let mut total_nnz = 0u64;
        for (c, (cached, recomputed)) in stats.iter().zip(&fresh).enumerate() {
            assert_eq!(cached.nnz, recomputed.nnz, "{tag} col {c}");
            assert_eq!(cached.sum.to_bits(), recomputed.sum.to_bits(), "{tag} col {c}");
            assert_eq!(cached.sumsq.to_bits(), recomputed.sumsq.to_bits(), "{tag} col {c}");
            assert_eq!(cached.min.to_bits(), recomputed.min.to_bits(), "{tag} col {c}");
            assert_eq!(cached.max.to_bits(), recomputed.max.to_bits(), "{tag} col {c}");
            if cached.nnz > 0 {
                assert!(cached.min <= cached.max, "{tag} col {c}");
                assert!(cached.sumsq >= 0.0, "{tag} col {c}");
            } else {
                assert_eq!((cached.min, cached.max), (0.0, 0.0), "{tag} col {c}");
            }
            total_nnz += cached.nnz;
        }
        assert_eq!(total_nnz as usize, store.nnz(), "{tag}: per-column nnz must sum to nnz");
    }
}

/// Version policy: v1 and v2 files are refused with a structured version
/// error telling the user to re-convert — on both open paths.
#[test]
fn v1_and_v2_stores_are_refused_with_version_error() {
    let ds = synthetic::cadata_like(50, 90);
    text_and_store(&ds, "oldver");
    let good = std::fs::read(tmp("oldver.pstore")).unwrap();
    assert_eq!(good[7], VERSION);
    for old in [1u8, 2] {
        let mut bad = good.clone();
        bad[7] = old;
        let p = tmp(&format!("oldver_v{old}.pstore"));
        std::fs::write(&p, &bad).unwrap();
        let checked = PallasStore::open(&p).unwrap_err().to_string();
        let unchecked = PallasStore::open_unchecked(&p).unwrap_err().to_string();
        for err in [checked, unchecked] {
            assert!(err.contains("version"), "v{old}: {err}");
            assert!(err.contains("convert"), "v{old}: {err}");
        }
    }
}

/// `--normalize l2-col` differential: training a store with cached
/// stats, training text with recomputed stats, and training explicitly
/// pre-normalized text must all produce bit-identical weights.
#[test]
fn normalize_l2_col_matches_pre_normalized_text() {
    let ds = synthetic::queries(12, 15, 6, 91);
    let (_, reference, store) = text_and_store(&ds, "norm");
    // Explicit pre-normalization, using the same fold as the converter.
    let stats = store.col_stats().unwrap();
    let norms: Vec<f64> = stats.iter().map(|s| s.sumsq.sqrt()).collect();
    let mut scaled = materialize(&reference);
    scaled.x.map_values(|c, v| if norms[c] > 0.0 { v / norms[c] } else { v });
    let pre_text = tmp("norm_pre.libsvm");
    libsvm::write(&scaled, &pre_text).unwrap();
    let pre = libsvm::read(&pre_text).unwrap();

    let mut norm_cfg = cfg(2);
    norm_cfg.normalize = Normalize::L2Col;
    let explicit = train(&pre, &cfg(2)).unwrap();
    let from_store = train(&store, &norm_cfg).unwrap();
    let from_text = train(&reference, &norm_cfg).unwrap();
    assert_eq!(explicit.model.w, from_store.model.w, "store-cached stats diverge");
    assert_eq!(from_store.model.w, from_text.model.w, "recomputed stats diverge");
    assert_eq!(explicit.objective.to_bits(), from_store.objective.to_bits());
    // And normalization actually changed the problem (sanity).
    let plain = train(&store, &cfg(2)).unwrap();
    assert_ne!(plain.model.w, from_store.model.w);
}

/// End-to-end through the release binary: gen-data → convert (with a
/// tiny chunk budget, asserting the converter's memory stays bounded on
/// a fixture much larger than the chunk) → train from text and store →
/// identical weights. Skipped when the binary isn't built.
#[test]
fn convert_cli_bounded_memory_and_weight_diff() {
    let Ok(bin) = memprobe::find_cli_bin() else {
        eprintln!("skipping: ranksvm binary not built (cargo build --release)");
        return;
    };
    let run = |args: &[&str]| {
        let out = std::process::Command::new(&bin).args(args).output().expect("spawn ranksvm");
        assert!(
            out.status.success(),
            "ranksvm {args:?} failed: {}",
            String::from_utf8_lossy(&out.stderr)
        );
        String::from_utf8_lossy(&out.stdout).to_string()
    };
    let json_field = |s: &str, key: &str| -> Option<u64> {
        let pat = format!("\"{key}\":");
        let pos = s.find(&pat)? + pat.len();
        let rest = &s[pos..];
        let end = rest.find(['}', ','])?;
        rest[..end].trim().parse().ok()
    };

    // Fixture: ~1.5M non-zeros ⇒ ~18 MB of CSR payload, converted with
    // a 64 KiB chunk budget. An implementation that materialized the
    // matrix (or its triplets) would hold ≥ 18 MB; the streaming
    // converter's transient state is O(m) ≈ 1.2 MB plus the spill
    // buffers.
    let ds = synthetic::reuters_like_with(50_000, 2000, 30, 31);
    let text = tmp("cli_fixture.libsvm");
    libsvm::write(&ds, &text).unwrap();
    drop(ds);
    let pst = tmp("cli_fixture.pstore");
    let stdout = run(&[
        "convert",
        "--data",
        text.to_str().unwrap(),
        "--out",
        pst.to_str().unwrap(),
        "--chunk-kib",
        "64",
    ]);
    let nnz = json_field(&stdout, "nnz").expect("nnz in convert output") as usize;
    assert!(nnz * 12 > 15 << 20, "fixture too small for the RSS assertion: nnz={nnz}");
    let buffered = json_field(&stdout, "max_buffered_bytes").expect("buffer stat") as usize;
    assert!(buffered <= 64 * 1024 + 32, "spill buffers exceeded the chunk budget: {buffered}");
    if let Some(peak_kib) = json_field(&stdout, "peak_rss_kib") {
        // Generous bound: far above the streaming converter's real peak
        // (~6 MB incl. the binary), far below any full materialization
        // of the ≥ 18 MB payload (let alone 36 MB of triplets).
        assert!(
            peak_kib < 16 * 1024,
            "converter peak RSS {peak_kib} KiB — ingest no longer bounded?"
        );
    }

    // Differential: text-trained and store-trained weights match to the
    // digit (the model format prints with full precision).
    let model_text = tmp("cli_model_text.txt");
    let model_store = tmp("cli_model_store.txt");
    for (data, model) in [(&text, &model_text), (&pst, &model_store)] {
        run(&[
            "train",
            "--data",
            data.to_str().unwrap(),
            "--method",
            "tree",
            "--lambda",
            "0.1",
            "--max-iter",
            "12",
            "--out",
            model.to_str().unwrap(),
        ]);
    }
    let a = std::fs::read(&model_text).unwrap();
    let b = std::fs::read(&model_store).unwrap();
    assert_eq!(a, b, "text-path and store-path weights diverge");

    // Parallel conversion through the CLI is byte-identical to serial.
    let pst2 = tmp("cli_fixture.t2.pstore");
    let stdout = run(&[
        "convert",
        "--data",
        text.to_str().unwrap(),
        "--out",
        pst2.to_str().unwrap(),
        "--chunk-kib",
        "64",
        "--threads",
        "2",
    ]);
    assert!(json_field(&stdout, "shards").is_some_and(|s| s > 1), "{stdout}");
    assert_eq!(
        std::fs::read(&pst).unwrap(),
        std::fs::read(&pst2).unwrap(),
        "CLI parallel convert diverged from serial"
    );

    // stats pretty-prints the cached column statistics.
    let stdout = run(&["stats", pst.to_str().unwrap(), "--limit", "4"]);
    assert!(stdout.contains("\"colstats\":true"), "{stdout}");
    assert!(stdout.contains("l2_norm"), "{stdout}");

    // info autodetects and reports the format.
    let stdout = run(&["info", "--data", pst.to_str().unwrap()]);
    assert!(stdout.contains("\"format\":\"pstore\""), "{stdout}");
    // mem-probe runs straight off the store.
    let stdout = run(&[
        "mem-probe",
        "--data",
        pst.to_str().unwrap(),
        "--method",
        "tree",
        "--max-iter",
        "2",
    ]);
    assert!(memprobe::parse_peak(&stdout).is_some(), "{stdout}");
}
