//! Deterministic pseudo-random number generation.
//!
//! The offline crate set ships no `rand`; this module provides a seeded
//! xoshiro256++ generator (Blackman & Vigna, 2019) with a splitmix64
//! seeder. It is the randomness source for the synthetic dataset
//! generators, the randomized property tests, and the benchmark workload
//! generators, so every experiment in the repo is reproducible from a
//! `u64` seed.

/// splitmix64 step — used to expand a single `u64` seed into the four
/// xoshiro256++ state words, per the reference implementation.
#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// xoshiro256++ PRNG. Fast, high-quality, 256-bit state, suitable for
/// everything here except cryptography (which we do not need).
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in [lo, hi).
    #[inline]
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform usize in [0, n). `n` must be > 0.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire-style unbiased bounded sampling.
        let n = n as u64;
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as usize
    }

    /// Uniform i64 in [lo, hi).
    #[inline]
    pub fn int_range(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(hi > lo);
        lo + self.below((hi - lo) as usize) as i64
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.f64();
            if u1 > 1e-300 {
                let u2 = self.f64();
                return (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Bernoulli(p).
    #[inline]
    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher–Yates shuffle of a slice.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (k <= n), in random order.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        // Floyd's algorithm for k << n; shuffle for dense sampling.
        if k * 4 >= n {
            let mut idx: Vec<usize> = (0..n).collect();
            self.shuffle(&mut idx);
            idx.truncate(k);
            idx
        } else {
            let mut chosen = std::collections::HashSet::with_capacity(k);
            let mut out = Vec::with_capacity(k);
            for j in (n - k)..n {
                let t = self.below(j + 1);
                let v = if chosen.contains(&t) { j } else { t };
                chosen.insert(v);
                out.push(v);
            }
            self.shuffle(&mut out);
            out
        }
    }

    /// Power-law (Zipf-like) index in [0, n) with exponent `a`
    /// (a > 0, a != 1), via inverse-CDF of the continuous Pareto envelope.
    /// Used by the reuters-like generator to pick feature ids with a
    /// realistic long-tail document-frequency profile; an approximation of
    /// the discrete Zipf pmf is fully adequate for data generation.
    pub fn zipf(&mut self, n: usize, a: f64) -> usize {
        debug_assert!(n > 0 && a > 0.0 && (a - 1.0).abs() > 1e-9);
        let n_f = n as f64;
        let u = self.f64();
        let x = ((n_f.powf(1.0 - a) - 1.0) * u + 1.0).powf(1.0 / (1.0 - a));
        ((x.floor() as usize).saturating_sub(1)).min(n - 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_bounded_and_covers() {
        let mut r = Rng::new(9);
        let mut seen = [false; 10];
        for _ in 0..10_000 {
            let x = r.below(10);
            assert!(x < 10);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 100_000;
        let mut sum = 0.0;
        let mut sq = 0.0;
        for _ in 0..n {
            let x = r.normal();
            sum += x;
            sq += x * x;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(13);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(17);
        for &(n, k) in &[(100usize, 5usize), (100, 90), (10, 10), (1000, 1)] {
            let s = r.sample_indices(n, k);
            assert_eq!(s.len(), k);
            let set: std::collections::HashSet<_> = s.iter().collect();
            assert_eq!(set.len(), k);
            assert!(s.iter().all(|&i| i < n));
        }
    }

    #[test]
    fn zipf_bounded_and_skewed() {
        let mut r = Rng::new(19);
        let n = 1000;
        let mut counts = vec![0usize; n];
        for _ in 0..20_000 {
            let k = r.zipf(n, 1.1);
            assert!(k < n);
            counts[k] += 1;
        }
        // Long tail: first index must dominate the median index.
        assert!(counts[0] > counts[n / 2] * 5);
    }
}
