//! `O(ms + m log m)` squared-pairwise-hinge oracle — our extension.
//!
//! The paper's PRSVM comparator materializes all `N = O(m²)` preference
//! pairs (Fig. 3's memory blow-up); Chapelle & Keerthi (2010) describe an
//! improved variant "with similar scalability as SVM^rank" but published
//! no implementation. This module supplies one, and removes the `O(rm)`
//! term on top: the [`crate::rbtree::SumTree`] — the order-statistics
//! tree augmented with value sums — turns the same two sweeps as
//! Algorithm 3 into squared-hinge aggregates.
//!
//! For each example `i`, with `A_i = {j : y_j > y_i ∧ 1 + p_i − p_j > 0}`
//! (i on the low-label side) and `B_i = {j : y_j < y_i ∧ 1 + p_j − p_i > 0}`
//! (high side), one tree query returns `(n, Σp_j, Σp_j²)` over each set:
//!
//! - loss  = (1/N) Σ_i [ n_A(1+p_i)² − 2(1+p_i)·S1_A + S2_A ]
//! - ∂R/∂p_i = (2/N) [ n_A(1+p_i) − S1_A − n_B(1−p_i) − S1_B ]
//! - (H·u)_i = (2/N) [ (n_A+n_B)·u_i − Σ_{A_i}u_j − Σ_{B_i}u_j ]
//!
//! The Hessian product re-runs the sweeps with `u` as the auxiliary
//! value (the margin windows depend only on the cached `p`), so each CG
//! iteration of truncated Newton costs `O(ms + m log m)` instead of
//! `O(N)` — PRSVM at TreeRSVM scaling.

use super::{OracleOutput, RankingOracle};
use crate::linalg::ops::argsort_into;
use crate::rbtree::SumTree;

/// Tree-based squared-hinge oracle (PRSVM objective, linearithmic).
pub struct SquaredTreeOracle {
    tree: SumTree,
    pi: Vec<usize>,
    /// Scores cached by the last `eval_full` — fixes the margin windows
    /// for subsequent Hessian products.
    last_p: Vec<f64>,
    last_y: Vec<f64>,
}

/// Per-example aggregates over the two active sets.
#[derive(Clone, Copy, Default)]
struct SideAgg {
    n_a: f64,
    s1_a: f64,
    s2_a: f64,
    n_b: f64,
    s1_b: f64,
}

impl SquaredTreeOracle {
    pub fn new() -> Self {
        SquaredTreeOracle {
            tree: SumTree::new(),
            pi: Vec::new(),
            last_p: Vec::new(),
            last_y: Vec::new(),
        }
    }

    /// Run the two Algorithm-3 sweeps with auxiliary values `val` (p for
    /// loss/gradient, u for Hessian products), collecting aggregates per
    /// example. `p` fixes the margin windows; `y` the tree keys.
    fn sweeps(&mut self, p: &[f64], y: &[f64], val: &[f64], out: &mut Vec<SideAgg>) {
        let m = p.len();
        out.clear();
        out.resize(m, SideAgg::default());
        argsort_into(p, &mut self.pi);

        // Low-side sweep (ascending p): window 1 + p_i − p_j > 0, keys
        // with larger labels form A_i.
        self.tree.clear();
        let mut j = 0usize;
        for i in 0..m {
            let pi_i = self.pi[i];
            while j < m && 1.0 + p[pi_i] - p[self.pi[j]] > 0.0 {
                self.tree.insert(y[self.pi[j]], val[self.pi[j]]);
                j += 1;
            }
            let a = self.tree.agg_larger(y[pi_i]);
            out[pi_i].n_a = a.count as f64;
            out[pi_i].s1_a = a.sum;
            out[pi_i].s2_a = a.sum_sq;
        }

        // High-side sweep (descending p): window 1 + p_j − p_i > 0, keys
        // with smaller labels form B_i.
        self.tree.clear();
        let mut j = m as isize - 1;
        for i in (0..m).rev() {
            let pi_i = self.pi[i];
            while j >= 0 && 1.0 + p[self.pi[j as usize]] - p[pi_i] > 0.0 {
                self.tree.insert(y[self.pi[j as usize]], val[self.pi[j as usize]]);
                j -= 1;
            }
            let b = self.tree.agg_smaller(y[pi_i]);
            out[pi_i].n_b = b.count as f64;
            out[pi_i].s1_b = b.sum;
        }
    }

    /// Loss + gradient coefficients; caches `(p, y)` for Hessian products.
    pub fn eval_full(&mut self, p: &[f64], y: &[f64], n_pairs: f64) -> OracleOutput {
        let m = p.len();
        assert_eq!(m, y.len());
        if n_pairs == 0.0 {
            return OracleOutput { loss: 0.0, coeffs: vec![0.0; m] };
        }
        let mut aggs = Vec::new();
        self.sweeps(p, y, p, &mut aggs);
        self.last_p = p.to_vec();
        self.last_y = y.to_vec();
        let inv_n = 1.0 / n_pairs;
        let mut loss = 0.0;
        let mut coeffs = Vec::with_capacity(m);
        for (i, a) in aggs.iter().enumerate() {
            let one_p = 1.0 + p[i];
            loss += a.n_a * one_p * one_p - 2.0 * one_p * a.s1_a + a.s2_a;
            let grad =
                2.0 * inv_n * (a.n_a * one_p - a.s1_a - a.n_b * (1.0 - p[i]) - a.s1_b);
            coeffs.push(grad);
        }
        OracleOutput { loss: loss * inv_n, coeffs }
    }

    /// Generalized Hessian–vector product in score space at the cached
    /// `p` (see module docs). `O(m log m)`.
    pub fn hessian_apply(&mut self, u: &[f64], n_pairs: f64, out: &mut [f64]) {
        let m = u.len();
        assert_eq!(m, self.last_p.len(), "call eval_full before hessian_apply");
        assert_eq!(m, out.len());
        if n_pairs == 0.0 {
            out.iter_mut().for_each(|x| *x = 0.0);
            return;
        }
        let p = std::mem::take(&mut self.last_p);
        let y = std::mem::take(&mut self.last_y);
        let mut aggs = Vec::new();
        self.sweeps(&p, &y, u, &mut aggs);
        self.last_p = p;
        self.last_y = y;
        let inv_n = 2.0 / n_pairs;
        for (i, a) in aggs.iter().enumerate() {
            out[i] = inv_n * ((a.n_a + a.n_b) * u[i] - a.s1_a - a.s1_b);
        }
    }
}

impl Default for SquaredTreeOracle {
    fn default() -> Self {
        Self::new()
    }
}

impl RankingOracle for SquaredTreeOracle {
    fn eval(&mut self, p: &[f64], y: &[f64], n_pairs: f64) -> OracleOutput {
        self.eval_full(p, y, n_pairs)
    }

    fn name(&self) -> &'static str {
        "squared-tree"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::losses::{count_comparable_pairs, SquaredPairOracle};
    use crate::util::rng::Rng;

    #[test]
    fn matches_pair_materialized_oracle() {
        let mut rng = Rng::new(81);
        for trial in 0..30 {
            let m = 2 + rng.below(120);
            let y: Vec<f64> = match trial % 3 {
                0 => (0..m).map(|_| rng.normal()).collect(),
                1 => (0..m).map(|_| rng.below(4) as f64).collect(),
                _ => (0..m).map(|_| rng.below(2) as f64).collect(),
            };
            let p: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
            let n = count_comparable_pairs(&y) as f64;
            let mut pairs = SquaredPairOracle::new(&y);
            let mut tree = SquaredTreeOracle::new();
            let a = pairs.eval_full(&p, n);
            let b = tree.eval_full(&p, &y, n);
            assert!(
                (a.loss - b.loss).abs() < 1e-9 * (1.0 + a.loss),
                "trial {trial}: loss {} vs {}",
                a.loss,
                b.loss
            );
            for (i, (x, z)) in a.coeffs.iter().zip(&b.coeffs).enumerate() {
                assert!(
                    (x - z).abs() < 1e-9 * (1.0 + x.abs()),
                    "trial {trial} coeff {i}: {x} vs {z}"
                );
            }
        }
    }

    #[test]
    fn hessian_matches_pair_oracle() {
        let mut rng = Rng::new(83);
        for _ in 0..20 {
            let m = 2 + rng.below(80);
            let y: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
            let p: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
            let u: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
            let n = count_comparable_pairs(&y) as f64;
            if n == 0.0 {
                continue;
            }
            let mut pairs = SquaredPairOracle::new(&y);
            let mut tree = SquaredTreeOracle::new();
            pairs.eval_full(&p, n);
            tree.eval_full(&p, &y, n);
            let mut h1 = vec![0.0; m];
            let mut h2 = vec![0.0; m];
            pairs.hessian_apply(&u, n, &mut h1);
            tree.hessian_apply(&u, n, &mut h2);
            for (i, (a, b)) in h1.iter().zip(&h2).enumerate() {
                assert!((a - b).abs() < 1e-9 * (1.0 + a.abs()), "Hu[{i}]: {a} vs {b}");
            }
        }
    }

    #[test]
    fn degenerate_inputs() {
        let mut o = SquaredTreeOracle::new();
        let out = o.eval_full(&[1.0, 2.0], &[3.0, 3.0], 0.0);
        assert_eq!(out.loss, 0.0);
        let out = o.eval_full(&[], &[], 0.0);
        assert!(out.coeffs.is_empty());
    }

    #[test]
    fn no_quadratic_memory() {
        // m = 20_000 with r ≈ m would need ~2·10^8 pairs (1.6 GB) in the
        // materialized oracle; the tree oracle runs in O(m) memory.
        let mut rng = Rng::new(85);
        let m = 20_000;
        let y: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
        let p: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
        let n = count_comparable_pairs(&y) as f64;
        let mut o = SquaredTreeOracle::new();
        let out = o.eval_full(&p, &y, n);
        assert!(out.loss.is_finite() && out.loss > 0.0);
    }
}
