//! Squared pairwise hinge — the PRSVM objective (Chapelle & Keerthi,
//! 2010).
//!
//! `R_emp(w) = (1/N) Σ_{y_i<y_j} max(0, 1 + p_i − p_j)²` is once
//! continuously differentiable, so PRSVM minimizes it with truncated
//! Newton (see [`crate::newton`]) instead of a bundle method. The paper's
//! PRSVM comparator *materializes all N pairs* — `O(ms + m²)` memory —
//! which is exactly what Fig. 3 measures blowing up at 8000 examples; we
//! reproduce that by storing the pair list explicitly.
//!
//! Beyond the loss value/gradient oracle, this module exposes the
//! generalized Hessian–vector product needed by conjugate gradients.

use super::{OracleOutput, RankingOracle};

/// Squared-hinge oracle over an explicitly materialized preference list.
pub struct SquaredPairOracle {
    /// All comparable pairs `(i, j)` with `y_i < y_j`. `O(N)` memory —
    /// deliberately quadratic, reproducing PRSVM's footprint.
    pairs: Vec<(u32, u32)>,
    /// Active set scratch from the last `eval` (pairs violating the
    /// margin at the last evaluated `p`), reused by `hessian_apply`.
    active: Vec<(u32, u32)>,
}

impl SquaredPairOracle {
    /// Materialize the preference pairs for a fixed training label
    /// vector. `O(m²)` time and memory in the worst (r ≈ m) case.
    pub fn new(y: &[f64]) -> Self {
        let m = y.len();
        let mut pairs = Vec::new();
        for i in 0..m {
            for j in 0..m {
                if y[i] < y[j] {
                    pairs.push((i as u32, j as u32));
                }
            }
        }
        SquaredPairOracle { pairs, active: Vec::new() }
    }

    /// Query-grouped construction: pairs only within equal-qid groups
    /// (document-retrieval setting).
    pub fn new_grouped(y: &[f64], qid: &[u64]) -> Self {
        assert_eq!(y.len(), qid.len());
        let m = y.len();
        let mut pairs = Vec::new();
        for i in 0..m {
            for j in 0..m {
                if qid[i] == qid[j] && y[i] < y[j] {
                    pairs.push((i as u32, j as u32));
                }
            }
        }
        SquaredPairOracle { pairs, active: Vec::new() }
    }

    /// Number of materialized preference pairs (= N).
    pub fn n_pairs(&self) -> usize {
        self.pairs.len()
    }

    /// Approximate heap footprint in bytes (Fig.-3 accounting).
    pub fn mem_bytes(&self) -> usize {
        (self.pairs.capacity() + self.active.capacity()) * std::mem::size_of::<(u32, u32)>()
    }

    /// Loss, gradient coefficients, and (side effect) the active pair
    /// set at `p`.
    pub fn eval_full(&mut self, p: &[f64], n_pairs: f64) -> OracleOutput {
        let m = p.len();
        if n_pairs == 0.0 {
            return OracleOutput { loss: 0.0, coeffs: vec![0.0; m] };
        }
        let inv_n = 1.0 / n_pairs;
        let mut loss = 0.0;
        let mut coeffs = vec![0.0; m];
        self.active.clear();
        for &(i, j) in &self.pairs {
            let h = 1.0 + p[i as usize] - p[j as usize];
            if h > 0.0 {
                loss += h * h;
                coeffs[i as usize] += 2.0 * h * inv_n;
                coeffs[j as usize] -= 2.0 * h * inv_n;
                self.active.push((i, j));
            }
        }
        OracleOutput { loss: loss * inv_n, coeffs }
    }

    /// Generalized Hessian–vector product *in score space*: given the
    /// directional scores `u = X·v`, returns `q` with
    /// `q_i = (2/N) Σ_{(i,j) active} (u_i − u_j)` (+ mirrored `−` for the
    /// j side), so that the full product is `Hv = 2λv + Xᵀ·q`. Uses the
    /// active set from the most recent [`Self::eval_full`].
    pub fn hessian_apply(&self, u: &[f64], n_pairs: f64, out: &mut [f64]) {
        assert_eq!(u.len(), out.len());
        out.iter_mut().for_each(|x| *x = 0.0);
        if n_pairs == 0.0 {
            return;
        }
        let inv_n = 2.0 / n_pairs;
        for &(i, j) in &self.active {
            let diff = (u[i as usize] - u[j as usize]) * inv_n;
            out[i as usize] += diff;
            out[j as usize] -= diff;
        }
    }

    /// Number of pairs in the current active set.
    pub fn active_len(&self) -> usize {
        self.active.len()
    }
}

impl RankingOracle for SquaredPairOracle {
    fn eval(&mut self, p: &[f64], _y: &[f64], n_pairs: f64) -> OracleOutput {
        // `y` was consumed at construction (pairs are fixed); the trait
        // signature keeps the call sites uniform.
        self.eval_full(p, n_pairs)
    }

    fn name(&self) -> &'static str {
        "squared"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::losses::count_comparable_pairs;
    use crate::util::rng::Rng;

    fn naive_sq_loss(p: &[f64], y: &[f64]) -> f64 {
        let m = p.len();
        let mut loss = 0.0;
        let mut n = 0u64;
        for i in 0..m {
            for j in 0..m {
                if y[i] < y[j] {
                    n += 1;
                    let h = (1.0 + p[i] - p[j]).max(0.0);
                    loss += h * h;
                }
            }
        }
        if n == 0 {
            0.0
        } else {
            loss / n as f64
        }
    }

    #[test]
    fn loss_matches_naive() {
        let mut rng = Rng::new(301);
        for _ in 0..20 {
            let m = 2 + rng.below(60);
            let y: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
            let p: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
            let n = count_comparable_pairs(&y) as f64;
            let mut o = SquaredPairOracle::new(&y);
            assert_eq!(o.n_pairs() as f64, n);
            let out = o.eval_full(&p, n);
            let direct = naive_sq_loss(&p, &y);
            assert!((out.loss - direct).abs() < 1e-9 * (1.0 + direct));
        }
    }

    #[test]
    fn gradient_matches_finite_differences() {
        let mut rng = Rng::new(303);
        let m = 20;
        let y: Vec<f64> = (0..m).map(|_| rng.below(4) as f64).collect();
        let p: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
        let n = count_comparable_pairs(&y) as f64;
        let mut o = SquaredPairOracle::new(&y);
        let out = o.eval_full(&p, n);
        let eps = 1e-6;
        for k in 0..m {
            let mut pp = p.clone();
            pp[k] += eps;
            let lp = o.eval_full(&pp, n).loss;
            pp[k] -= 2.0 * eps;
            let lm = o.eval_full(&pp, n).loss;
            let fd = (lp - lm) / (2.0 * eps);
            assert!(
                (out.coeffs[k] - fd).abs() < 1e-4 * (1.0 + fd.abs()),
                "coeff {k}: {} vs fd {fd}",
                out.coeffs[k]
            );
        }
    }

    #[test]
    fn hessian_apply_is_symmetric_psd() {
        let mut rng = Rng::new(307);
        let m = 25;
        let y: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
        let p: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
        let n = count_comparable_pairs(&y) as f64;
        let mut o = SquaredPairOracle::new(&y);
        o.eval_full(&p, n); // fix active set
        let u: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
        let v: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
        let mut hu = vec![0.0; m];
        let mut hv = vec![0.0; m];
        o.hessian_apply(&u, n, &mut hu);
        o.hessian_apply(&v, n, &mut hv);
        let uhv = crate::linalg::ops::dot(&u, &hv);
        let vhu = crate::linalg::ops::dot(&v, &hu);
        assert!((uhv - vhu).abs() < 1e-9 * (1.0 + uhv.abs()), "symmetry");
        let uhu = crate::linalg::ops::dot(&u, &hu);
        assert!(uhu >= -1e-12, "PSD violated: {uhu}");
    }

    #[test]
    fn zero_pairs_degenerate() {
        let y = [1.0, 1.0];
        let mut o = SquaredPairOracle::new(&y);
        assert_eq!(o.n_pairs(), 0);
        let out = o.eval_full(&[0.3, -0.3], 0.0);
        assert_eq!(out.loss, 0.0);
    }

    #[test]
    fn memory_grows_quadratically() {
        let make = |m: usize| {
            let y: Vec<f64> = (0..m).map(|i| i as f64).collect();
            SquaredPairOracle::new(&y).n_pairs()
        };
        assert_eq!(make(10), 45);
        assert_eq!(make(100), 4950); // ~100× more pairs for 10× more data
    }
}
