//! Training orchestration: wires a dataset, a compute backend, a loss
//! oracle, and an optimizer into one call — the coordinator face of the
//! library.

use super::config::{BackendKind, Normalize, TrainConfig};
use super::model::RankModel;
use crate::bmrm::{self, BmrmConfig, ScoreOracle};
use crate::compute::{ComputeBackend, NativeBackend, ParallelBackend};
use crate::data::{materialize, Dataset, DatasetView};
use crate::losses::registry::{NewtonKind, OracleCtx};
use crate::losses::{count_comparable_pairs, GroupIndex, RankingOracle, SquaredPairOracle};
use crate::newton::{self, HessianOracle, NewtonConfig};
use crate::obs::{self, trace::TraceSink};
use crate::runtime::WorkerPool;
use crate::util::json::Json;
use anyhow::Result;
use std::sync::Arc;

/// Outcome of a training run, with everything the benches report.
#[derive(Clone, Debug)]
pub struct TrainOutcome {
    pub model: RankModel,
    pub method: &'static str,
    /// Solver family that produced the model (`"bmrm"` or `"newton"`),
    /// from the method's registry spec.
    pub solver: &'static str,
    pub backend: &'static str,
    pub iterations: usize,
    pub converged: bool,
    /// Final objective J(w_b).
    pub objective: f64,
    /// Final optimality gap (BMRM gap or Newton decrement).
    pub gap: f64,
    /// Wall-clock seconds for the whole optimization.
    pub train_secs: f64,
    /// Seconds spent inside loss/subgradient evaluations (Fig. 1).
    pub oracle_secs: f64,
    /// (iteration, objective, gap) trace — the loss curve.
    pub trace: Vec<(usize, f64, f64)>,
    /// Comparable pairs N in the training set.
    pub n_pairs: f64,
    /// Training-set column ℓ2 norms when `--normalize l2-col` was on:
    /// the trained weights live in the normalized feature space, and
    /// these norms are what a [`crate::serve::ScoringModel`] records so
    /// raw inputs score correctly at predict/serve time.
    pub norms: Option<Vec<f64>>,
}

impl TrainOutcome {
    /// Package the trained weights and the recorded normalization as a
    /// self-contained [`crate::serve::ScoringModel`] — the thing
    /// `ranksvm train --out` saves and `predict`/`serve` load.
    pub fn scoring_model(&self) -> crate::serve::ScoringModel {
        crate::serve::ScoringModel::new(self.model.w.clone(), self.norms.clone())
            .expect("norms are per-column of the training set, same length as w")
    }

    /// Average per-iteration oracle cost — the Fig. 1 quantity.
    pub fn avg_oracle_secs(&self) -> f64 {
        if self.iterations == 0 {
            0.0
        } else {
            self.oracle_secs / self.iterations as f64
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("method", self.method.into()),
            ("solver", self.solver.into()),
            ("backend", self.backend.into()),
            ("iterations", self.iterations.into()),
            ("converged", self.converged.into()),
            ("objective", self.objective.into()),
            ("gap", self.gap.into()),
            ("train_secs", self.train_secs.into()),
            ("oracle_secs", self.oracle_secs.into()),
            ("avg_oracle_secs", self.avg_oracle_secs().into()),
            ("n_pairs", self.n_pairs.into()),
        ])
    }
}

/// Adapter: dataset view + backend + score-space loss oracle →
/// [`ScoreOracle`] for the optimizers. Works identically over an owned
/// [`crate::data::Dataset`] or a memory-mapped pallas store.
pub struct DatasetOracle<'a> {
    ds: &'a dyn DatasetView,
    backend: Box<dyn ComputeBackend>,
    inner: Box<dyn RankingOracle>,
    n_pairs: f64,
}

impl<'a> DatasetOracle<'a> {
    pub fn new(
        ds: &'a dyn DatasetView,
        mut backend: Box<dyn ComputeBackend>,
        inner: Box<dyn RankingOracle>,
        n_pairs: f64,
    ) -> Self {
        backend.prepare(ds.x());
        DatasetOracle { ds, backend, inner, n_pairs }
    }

    /// Cumulative phase clocks of the wrapped loss oracle, if it keeps
    /// any (read-only; feeds the `train --trace` phase split).
    pub fn phase_times(&self) -> Option<&crate::util::timer::PhaseTimes> {
        self.inner.phase_times()
    }
}

impl ScoreOracle for DatasetOracle<'_> {
    fn dim(&self) -> usize {
        self.ds.dim()
    }
    fn scores(&mut self, w: &[f64]) -> Vec<f64> {
        self.backend.scores(self.ds.x(), w)
    }
    fn risk_at(&mut self, p: &[f64]) -> (f64, Vec<f64>) {
        let out = self.inner.eval(p, self.ds.y(), self.n_pairs);
        (out.loss, out.coeffs)
    }
    fn grad(&mut self, coeffs: &[f64]) -> Vec<f64> {
        self.backend.grad(self.ds.x(), coeffs)
    }
}

/// Which squared-hinge implementation backs a PRSVM run.
enum SquaredImpl {
    /// Faithful PRSVM: explicit pair materialization (O(m²) memory).
    Pairs(SquaredPairOracle),
    /// Extension: sum-augmented-tree oracle (O(m log m) time, O(m) mem).
    Tree(crate::losses::SquaredTreeOracle),
}

/// PRSVM adapter: like [`DatasetOracle`] but holding the squared-hinge
/// oracle concretely so the truncated Newton solver can request
/// generalized Hessian products.
pub struct SquaredDatasetOracle<'a> {
    ds: &'a dyn DatasetView,
    backend: Box<dyn ComputeBackend>,
    oracle: SquaredImpl,
    n_pairs: f64,
}

impl<'a> SquaredDatasetOracle<'a> {
    /// Faithful pair-materializing PRSVM oracle.
    pub fn new(ds: &'a dyn DatasetView, mut backend: Box<dyn ComputeBackend>) -> Self {
        backend.prepare(ds.x());
        let oracle = match ds.qid() {
            Some(q) => SquaredPairOracle::new_grouped(ds.y(), q),
            None => SquaredPairOracle::new(ds.y()),
        };
        let n_pairs = oracle.n_pairs() as f64;
        SquaredDatasetOracle { ds, backend, oracle: SquaredImpl::Pairs(oracle), n_pairs }
    }

    /// Linearithmic tree-based PRSVM oracle (extension). Query-grouped
    /// data falls back to pair materialization per group.
    pub fn new_tree(ds: &'a dyn DatasetView, mut backend: Box<dyn ComputeBackend>) -> Self {
        if ds.qid().is_some() {
            return Self::new(ds, backend);
        }
        backend.prepare(ds.x());
        let n_pairs = count_comparable_pairs(ds.y()) as f64;
        SquaredDatasetOracle {
            ds,
            backend,
            oracle: SquaredImpl::Tree(crate::losses::SquaredTreeOracle::new()),
            n_pairs,
        }
    }

    /// Materialized-pair memory, for the Fig.-3 accounting (0 for tree).
    pub fn pair_mem_bytes(&self) -> usize {
        match &self.oracle {
            SquaredImpl::Pairs(o) => o.mem_bytes(),
            SquaredImpl::Tree(_) => 0,
        }
    }
}

impl ScoreOracle for SquaredDatasetOracle<'_> {
    fn dim(&self) -> usize {
        self.ds.dim()
    }
    fn scores(&mut self, w: &[f64]) -> Vec<f64> {
        self.backend.scores(self.ds.x(), w)
    }
    fn risk_at(&mut self, p: &[f64]) -> (f64, Vec<f64>) {
        let out = match &mut self.oracle {
            SquaredImpl::Pairs(o) => o.eval_full(p, self.n_pairs),
            SquaredImpl::Tree(o) => o.eval_full(p, self.ds.y(), self.n_pairs),
        };
        (out.loss, out.coeffs)
    }
    fn grad(&mut self, coeffs: &[f64]) -> Vec<f64> {
        self.backend.grad(self.ds.x(), coeffs)
    }
}

impl HessianOracle for SquaredDatasetOracle<'_> {
    fn hess_apply(&mut self, u: &[f64]) -> Vec<f64> {
        let mut out = vec![0.0; u.len()];
        match &mut self.oracle {
            SquaredImpl::Pairs(o) => o.hessian_apply(u, self.n_pairs, &mut out),
            SquaredImpl::Tree(o) => o.hessian_apply(u, self.n_pairs, &mut out),
        }
        out
    }
}

/// Build the configured compute backend on the trainer's persistent
/// work-stealing worker pool. The plain native kind runs the `O(ms)`
/// linear algebra on the sharded [`ParallelBackend`]; every chunk is an
/// individually stealable task, but chunk contents and reduction
/// topology are fixed, so results do not depend on the thread count or
/// the scheduling.
pub fn make_backend(cfg: &TrainConfig, pool: &Arc<WorkerPool>) -> Result<Box<dyn ComputeBackend>> {
    Ok(match cfg.backend {
        BackendKind::Native => Box::new(ParallelBackend::with_pool(Arc::clone(pool))),
        BackendKind::NativeCsc => Box::new(NativeBackend::with_csc()),
        BackendKind::Xla => make_xla_backend(cfg)?,
    })
}

#[cfg(feature = "xla")]
fn make_xla_backend(cfg: &TrainConfig) -> Result<Box<dyn ComputeBackend>> {
    Ok(Box::new(crate::runtime::XlaBackend::load(&cfg.artifacts_dir)?))
}

#[cfg(not(feature = "xla"))]
fn make_xla_backend(_cfg: &TrainConfig) -> Result<Box<dyn ComputeBackend>> {
    anyhow::bail!(
        "this build has no XLA support — enable the `xla` cargo feature \
         and add the `xla` bindings dependency (see rust/Cargo.toml)"
    )
}

/// The Newton solver configuration a [`TrainConfig`] maps to — shared
/// by [`train`] and the CV engine (`coordinator::modelsel`) so a fold
/// training inside a sweep runs the *identical* solver a standalone
/// `train` call would.
pub(crate) fn newton_config(cfg: &TrainConfig) -> NewtonConfig {
    NewtonConfig {
        lambda: cfg.lambda,
        // Paper §5.1: Newton decrement 1e-6 ~ BMRM ε 1e-3.
        decrement_tol: cfg.epsilon * 1e-3,
        max_iter: cfg.max_iter,
        ..Default::default()
    }
}

/// The BMRM configuration a [`TrainConfig`] maps to (same sharing
/// rationale as [`newton_config`]).
pub(crate) fn bmrm_config(cfg: &TrainConfig) -> BmrmConfig {
    BmrmConfig {
        lambda: cfg.lambda,
        epsilon: cfg.epsilon,
        max_iter: cfg.max_iter,
        line_search: cfg.line_search,
        ..Default::default()
    }
}

/// Instantiate the squared-hinge Hessian oracle a [`NewtonKind`] tags —
/// the registry's one documented constructor asymmetry (docs/LOSSES.md),
/// shared by [`train`] and the CV engine.
pub(crate) fn squared_oracle<'a>(
    kind: NewtonKind,
    ds: &'a dyn DatasetView,
    backend: Box<dyn ComputeBackend>,
) -> SquaredDatasetOracle<'a> {
    match kind {
        NewtonKind::MaterializedPairs => SquaredDatasetOracle::new(ds, backend),
        NewtonKind::SumTree => SquaredDatasetOracle::new_tree(ds, backend),
    }
}

/// Per-column ℓ2 norms of a training set: `sqrt(Σ_i x_ij²)` per column.
/// Consumes the source's cached column statistics when present (a v3
/// pallas store — no data scan at all), otherwise recomputes them with
/// the *same* serial row-major fold ([`crate::data::store::compute_col_stats`]),
/// so both origins yield bit-identical norms.
fn l2_col_norms(ds: &dyn DatasetView) -> Vec<f64> {
    match ds.col_stats() {
        Some(stats) => stats.iter().map(|s| s.sumsq.sqrt()).collect(),
        None => crate::data::store::compute_col_stats(ds.x())
            .iter()
            .map(|s| s.sumsq.sqrt())
            .collect(),
    }
}

/// Owned copy of `ds` with every feature column divided by its ℓ2 norm
/// (zero-norm columns untouched), plus the norms themselves — the
/// outcome keeps them so the saved model can score raw inputs. The
/// scale is applied once, value by value (`v / norm`), which makes
/// training on the result bit-identical to training on explicitly
/// pre-normalized input text — `tests/store.rs` pins that differential.
fn normalize_l2_col(ds: &dyn DatasetView) -> (Dataset, Vec<f64>) {
    let norms = l2_col_norms(ds);
    let mut owned = materialize(ds);
    owned.x.map_values(|c, v| if norms[c] > 0.0 { v / norms[c] } else { v });
    (owned, norms)
}

/// The query-group index for a training run: precomputed by the source
/// (pallas store) when available, otherwise built with one scan — built
/// *once* per run and shared by the pair count and the oracle. Exact
/// integers either way, so the two origins are interchangeable
/// bit-for-bit.
fn group_index_for(ds: &dyn DatasetView) -> Option<Arc<GroupIndex>> {
    ds.group_index()
        .or_else(|| ds.qid().map(|q| Arc::new(GroupIndex::build(q, ds.y()))))
}

/// Train a linear ranking SVM on `ds` per the configuration. This is the
/// library's main entry point; `ds` may be an owned in-memory dataset or
/// a memory-mapped pallas store — the run is bit-identical either way.
pub fn train(ds: &dyn DatasetView, cfg: &TrainConfig) -> Result<TrainOutcome> {
    let timer = std::time::Instant::now();
    // Wire the configured cache-target override before any parallel plan
    // is sized (inert for results: chunk counts only shape integer-exact
    // decompositions — docs/DETERMINISM.md).
    crate::runtime::cache::set_chunk_target_kib(cfg.chunk_target_kib);
    // Mapped stores: start paging the file in now (madvise WILLNEED),
    // so the first sweep reads warm pages instead of faulting serially.
    ds.prefetch();
    // Opt-in feature normalization. The scaled copy is owned (an O(nnz)
    // materialization), trading the store's zero-copy path for exact
    // equivalence with pre-normalized input; the norms themselves come
    // from the store's cached column stats when available.
    let (normalized, norms) = match cfg.normalize {
        Normalize::None => (None, None),
        Normalize::L2Col => {
            let (owned, norms) = normalize_l2_col(ds);
            (Some(owned), Some(norms))
        }
    };
    let ds: &dyn DatasetView = match &normalized {
        Some(owned) => owned,
        None => ds,
    };
    // One persistent work-stealing worker pool for the whole run: the
    // sharded oracle, the parallel backend, and the parallel argsort
    // all submit their (finer-than-thread-count) task batches to it, so
    // threads are spawned once here rather than per oracle call and
    // skewed batches rebalance by stealing.
    let pool = Arc::new(WorkerPool::new(cfg.resolved_threads()));
    let backend = make_backend(cfg, &pool)?;
    let backend_name = backend.name();

    // Dispatch by the method's registry spec: Newton-family losses run
    // truncated Newton over their tagged Hessian oracle, everything
    // else builds its score-space oracle through the registry
    // constructor and runs BMRM. Adding a loss means adding a
    // `LossSpec` (docs/LOSSES.md), not editing this function.
    let spec = cfg.method.spec();
    let outcome = if let Some(kind) = spec.newton {
        let mut oracle = squared_oracle(kind, ds, backend);
        let ncfg = newton_config(cfg);
        let res = newton::optimize(&mut oracle, &ncfg, vec![0.0; ds.dim()]);
        // Newton-family runs have no BMRM iterations to trace; a
        // requested trace still gets its start/end envelope
        // (docs/OBSERVABILITY.md).
        if let Some(path) = &cfg.trace_path {
            let mut sink = TraceSink::create(path)?;
            sink.event(&obs::trace::start_event(&obs::trace::StartInfo {
                method: cfg.method.name(),
                m: ds.len(),
                dim: ds.dim(),
                n_pairs: oracle.n_pairs,
                lambda: cfg.lambda,
                epsilon: cfg.epsilon,
                max_iter: cfg.max_iter,
                threads: cfg.resolved_threads(),
                kernel: crate::linalg::simd::active().name(),
            }))?;
            sink.event(&obs::trace::end_event(&obs::trace::EndInfo {
                iterations: res.iterations,
                converged: res.converged,
                objective: res.objective,
                gap: res.trace.last().map(|t| t.2).unwrap_or(f64::INFINITY),
                train_secs: timer.elapsed().as_secs_f64(),
                oracle_secs: res.oracle_secs_total,
            }))?;
            sink.finish()?;
        }
        TrainOutcome {
            model: RankModel::new(res.w),
            method: cfg.method.name(),
            solver: spec.solver.name(),
            backend: backend_name,
            iterations: res.iterations,
            converged: res.converged,
            objective: res.objective,
            gap: res.trace.last().map(|t| t.2).unwrap_or(f64::INFINITY),
            train_secs: timer.elapsed().as_secs_f64(),
            oracle_secs: res.oracle_secs_total,
            trace: res.trace,
            n_pairs: oracle.n_pairs,
            norms,
        }
    } else {
        let index = group_index_for(ds);
        let n_pairs = match &index {
            Some(gi) => gi.total_pairs(),
            None => ds
                .n_pairs_hint()
                .unwrap_or_else(|| count_comparable_pairs(ds.y()) as f64),
        };
        let ctor = spec.bmrm.expect("non-Newton registry losses carry a BMRM oracle constructor");
        let inner = ctor(OracleCtx { ds, index, pool: &pool });
        let mut oracle = DatasetOracle::new(ds, backend, inner, n_pairs);
        let bcfg = bmrm_config(cfg);
        // Structured run trace (`train --trace`): one JSONL event per
        // BMRM iteration, written from the observer *between*
        // iterations. The observer only reads solver state — a traced
        // run trains the byte-identical model (tests/obs.rs).
        let mut sink = match &cfg.trace_path {
            Some(path) => Some(TraceSink::create(path)?),
            None => None,
        };
        if let Some(sink) = sink.as_mut() {
            sink.event(&obs::trace::start_event(&obs::trace::StartInfo {
                method: cfg.method.name(),
                m: ds.len(),
                dim: ds.dim(),
                n_pairs,
                lambda: cfg.lambda,
                epsilon: cfg.epsilon,
                max_iter: cfg.max_iter,
                threads: cfg.resolved_threads(),
                kernel: crate::linalg::simd::active().name(),
            }))?;
        }
        let mut prev_phases: Vec<(String, f64)> = Vec::new();
        let mut prev_tasks = obs::metrics::POOL_TASKS.get();
        let mut prev_stolen = obs::metrics::POOL_STOLEN.get();
        let mut trace_err: Option<anyhow::Error> = None;
        let res = bmrm::optimize_observed(
            &mut oracle,
            &bcfg,
            vec![0.0; ds.dim()],
            &mut |s, o| {
                let Some(sink) = sink.as_mut() else { return };
                let phases = match o.phase_times() {
                    Some(t) => obs::trace::phase_deltas(t, &mut prev_phases),
                    None => Vec::new(),
                };
                let tasks = obs::metrics::POOL_TASKS.get();
                let stolen = obs::metrics::POOL_STOLEN.get();
                let ev = obs::trace::iter_event(&obs::trace::IterInfo {
                    iter: s.iter,
                    objective: s.best_objective,
                    lower_bound: s.lower_bound,
                    gap: s.gap,
                    risk: s.risk,
                    ls_steps: s.ls_steps,
                    oracle_secs: s.oracle_secs,
                    phases,
                    pool_tasks_delta: tasks.saturating_sub(prev_tasks),
                    pool_stolen_delta: stolen.saturating_sub(prev_stolen),
                });
                prev_tasks = tasks;
                prev_stolen = stolen;
                if let Err(e) = sink.event(&ev) {
                    trace_err.get_or_insert(e);
                }
            },
        );
        if let Some(e) = trace_err {
            return Err(e);
        }
        if let Some(sink) = sink.as_mut() {
            sink.event(&obs::trace::end_event(&obs::trace::EndInfo {
                iterations: res.iterations,
                converged: res.converged,
                objective: res.objective,
                gap: res.gap,
                train_secs: timer.elapsed().as_secs_f64(),
                oracle_secs: res.oracle_secs_total,
            }))?;
            sink.finish()?;
        }
        if cfg.verbose {
            for s in &res.trace {
                obs::log::info(
                    &Json::obj(vec![
                        ("iter", s.iter.into()),
                        ("objective", s.best_objective.into()),
                        ("lower_bound", s.lower_bound.into()),
                        ("gap", s.gap.into()),
                        ("risk", s.risk.into()),
                        ("ls_steps", s.ls_steps.into()),
                        ("oracle_secs", s.oracle_secs.into()),
                    ])
                    .to_string(),
                );
            }
        }
        TrainOutcome {
            model: RankModel::new(res.w),
            method: cfg.method.name(),
            solver: spec.solver.name(),
            backend: backend_name,
            iterations: res.iterations,
            converged: res.converged,
            objective: res.objective,
            gap: res.gap,
            train_secs: timer.elapsed().as_secs_f64(),
            oracle_secs: res.oracle_secs_total,
            trace: res.trace.iter().map(|s| (s.iter, s.best_objective, s.gap)).collect(),
            n_pairs,
            norms,
        }
    };
    // Surface the scheduler's balance evidence (how many tasks ran, how
    // many were stolen off a busy worker). Always compiled since the
    // counters moved out of the `pool-stats` feature.
    if cfg.verbose {
        let s = pool.stats();
        obs::log::info(
            &Json::obj(vec![
                ("pool_batches", (s.batches as usize).into()),
                ("pool_tasks", (s.executed as usize).into()),
                ("pool_stolen", (s.stolen as usize).into()),
                ("pool_inline_tasks", (s.inline_tasks as usize).into()),
            ])
            .to_string(),
        );
    }
    Ok(outcome)
}

/// Evaluate a trained model: pairwise ranking error on a dataset
/// (query-grouped if the dataset has qids).
pub fn evaluate(model: &RankModel, ds: &dyn DatasetView) -> f64 {
    let p = model.predict(ds);
    pairwise_error_for(&p, ds)
}

/// [`evaluate`] for a [`crate::serve::ScoringModel`]: `ds` holds *raw*
/// features — the model applies its recorded normalization itself, so
/// an `l2-col` model evaluates correctly without the caller pre-scaling
/// anything.
pub fn evaluate_scoring(model: &crate::serve::ScoringModel, ds: &dyn DatasetView) -> f64 {
    let p = model.scores(ds);
    pairwise_error_for(&p, ds)
}

fn pairwise_error_for(p: &[f64], ds: &dyn DatasetView) -> f64 {
    match ds.qid() {
        Some(q) => crate::metrics::grouped_pairwise_error(p, ds.y(), q),
        None => crate::metrics::pairwise_error(p, ds.y()),
    }
}

#[cfg(test)]
mod tests {
    use super::super::config::Method;
    use super::*;
    use crate::data::synthetic;

    fn cfg(method: Method) -> TrainConfig {
        TrainConfig { method, lambda: 0.1, epsilon: 1e-3, ..Default::default() }
    }

    #[test]
    fn tree_training_learns_ranking() {
        let ds = synthetic::cadata_like(600, 21);
        let (train_ds, test_ds) = ds.split(150, 1);
        let out = train(&train_ds, &cfg(Method::Tree)).unwrap();
        assert!(out.converged, "gap={}", out.gap);
        let err = evaluate(&out.model, &test_ds);
        assert!(err < 0.25, "test error {err}");
        // sanity: better than random
        let rand_err = evaluate(&RankModel::new(vec![0.0; train_ds.dim()]), &test_ds);
        assert!((rand_err - 0.5).abs() < 1e-9); // all-zero scores → all ties → 0.5
    }

    #[test]
    fn all_bmrm_methods_reach_same_objective() {
        // Fig. 4's claim: implementations reach the same solution.
        let ds = synthetic::cadata_like(200, 33);
        let mut objectives = Vec::new();
        let methods =
            [Method::Tree, Method::TreeDedup, Method::TreeFenwick, Method::Pair, Method::RLevel];
        for m in methods {
            let out = train(&ds, &cfg(m)).unwrap();
            assert!(out.converged, "{:?} failed to converge", m);
            objectives.push(out.objective);
        }
        for o in &objectives[1..] {
            assert!(
                (o - objectives[0]).abs() < 2e-3 * (1.0 + objectives[0].abs()),
                "objectives diverge: {objectives:?}"
            );
        }
    }

    #[test]
    fn prsvm_reaches_similar_test_error() {
        let ds = synthetic::cadata_like(400, 44);
        let (tr, te) = ds.split(100, 2);
        let t_out = train(&tr, &cfg(Method::Tree)).unwrap();
        let p_out = train(&tr, &cfg(Method::Prsvm)).unwrap();
        let te_tree = evaluate(&t_out.model, &te);
        let te_prsvm = evaluate(&p_out.model, &te);
        assert!((te_tree - te_prsvm).abs() < 0.05, "tree {te_tree} vs prsvm {te_prsvm}");
    }

    #[test]
    fn query_grouped_training() {
        let ds = synthetic::queries(20, 15, 6, 55);
        let out = train(&ds, &cfg(Method::Tree)).unwrap();
        assert!(out.converged);
        let err = evaluate(&out.model, &ds);
        assert!(err < 0.35, "grouped error {err}");
    }

    #[test]
    fn line_search_converges_not_slower() {
        let ds = synthetic::cadata_like(300, 66);
        let base = train(&ds, &cfg(Method::Tree)).unwrap();
        let mut c = cfg(Method::Tree);
        c.line_search = true;
        let ls = train(&ds, &c).unwrap();
        assert!(ls.converged);
        // Same objective ballpark.
        assert!((ls.objective - base.objective).abs() < 5e-3 * (1.0 + base.objective.abs()));
    }

    #[test]
    fn training_is_bitwise_invariant_to_thread_count() {
        // The sharded oracle's counts are exact integers and the backend's
        // chunk plan/reduction topology are fixed, so the whole BMRM run
        // must produce the same model to the last bit for any n_threads.
        for (ds, tag) in [
            (synthetic::cadata_like(300, 88), "global"),
            (synthetic::queries(12, 18, 5, 89), "grouped"),
        ] {
            let mut reference: Option<TrainOutcome> = None;
            for threads in [1usize, 2, 8] {
                let c = TrainConfig { n_threads: threads, ..cfg(Method::Tree) };
                let out = train(&ds, &c).unwrap();
                match &reference {
                    None => reference = Some(out),
                    Some(base) => {
                        assert_eq!(out.model.w, base.model.w, "{tag}: {threads} threads");
                        assert_eq!(
                            out.objective.to_bits(),
                            base.objective.to_bits(),
                            "{tag}: {threads} threads"
                        );
                        assert_eq!(out.iterations, base.iterations, "{tag}");
                    }
                }
            }
        }
    }

    #[test]
    fn l2_col_normalization_matches_explicit_scaling() {
        let ds = synthetic::cadata_like(200, 12);
        let mut with_norm = cfg(Method::Tree);
        with_norm.normalize = Normalize::L2Col;
        let a = train(&ds, &with_norm).unwrap();
        // Explicitly pre-scale an owned copy with the same fold, then
        // train with normalization off: the runs must agree to the bit.
        let mut sumsq = vec![0.0f64; ds.dim()];
        for i in 0..ds.len() {
            let (idx, val) = ds.x.row(i);
            for (&j, &v) in idx.iter().zip(val) {
                sumsq[j as usize] += v * v;
            }
        }
        let mut scaled = materialize(&ds);
        scaled.x.map_values(|c, v| if sumsq[c] > 0.0 { v / sumsq[c].sqrt() } else { v });
        let b = train(&scaled, &cfg(Method::Tree)).unwrap();
        assert!(a.converged && b.converged);
        assert_eq!(a.model.w, b.model.w);
        assert_eq!(a.objective.to_bits(), b.objective.to_bits());
    }

    #[test]
    fn l2_col_outcome_records_norms_and_scores_raw_inputs() {
        let ds = synthetic::cadata_like(150, 19);
        let mut c = cfg(Method::Tree);
        c.normalize = Normalize::L2Col;
        let out = train(&ds, &c).unwrap();
        let norms = out.norms.as_ref().expect("l2-col training records the column norms");
        assert_eq!(norms.len(), ds.dim());
        // The packaged scoring model, fed RAW features, must reproduce
        // the in-space prediction (weights applied to normalized data)
        // bit for bit — the PR 5 follow-up this field exists for.
        let (normalized, _) = normalize_l2_col(&ds);
        let in_space = out.model.predict(&normalized);
        let raw = out.scoring_model().scores(&ds);
        assert_eq!(in_space.len(), raw.len());
        for (a, b) in in_space.iter().zip(&raw) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        // And the two evaluate paths agree exactly.
        let a = evaluate(&out.model, &normalized);
        let b = evaluate_scoring(&out.scoring_model(), &ds);
        assert_eq!(a.to_bits(), b.to_bits());
        // Plain training records no norms.
        let plain = train(&ds, &cfg(Method::Tree)).unwrap();
        assert!(plain.norms.is_none());
    }

    #[test]
    fn outcome_json_is_well_formed() {
        let ds = synthetic::cadata_like(100, 77);
        let out = train(&ds, &cfg(Method::Tree)).unwrap();
        let s = out.to_json().to_string();
        assert!(s.contains("\"method\":\"tree\""));
        assert!(s.contains("\"solver\":\"bmrm\""));
        assert!(s.contains("\"converged\":true"));
        let out = train(&ds, &cfg(Method::Prsvm)).unwrap();
        assert!(out.to_json().to_string().contains("\"solver\":\"newton\""));
    }

    #[test]
    fn toppush_trains_end_to_end_and_is_thread_invariant() {
        // Grouped fixture with zero-centered labels: every group splits
        // into positives (y > 0) and negatives, the bipartite regime
        // TopPush is for.
        let ds = synthetic::queries(14, 16, 6, 91);
        let mut reference: Option<TrainOutcome> = None;
        for threads in [1usize, 2, 8] {
            let c = TrainConfig { n_threads: threads, ..cfg(Method::TopPush) };
            let out = train(&ds, &c).unwrap();
            assert_eq!(out.method, "toppush");
            assert_eq!(out.solver, "bmrm");
            match &reference {
                None => reference = Some(out),
                Some(base) => {
                    assert_eq!(out.model.w, base.model.w, "{threads} threads");
                    assert_eq!(out.objective.to_bits(), base.objective.to_bits());
                    assert_eq!(out.iterations, base.iterations);
                }
            }
        }
        let out = reference.unwrap();
        assert!(out.converged, "gap={}", out.gap);
        // The learned ranking separates the classes far better than the
        // zero model's 0.5.
        let p = out.model.predict(&ds);
        let yb: Vec<f64> = ds.y.iter().map(|&v| if v > 0.0 { 1.0 } else { 0.0 }).collect();
        let err = crate::metrics::grouped_pairwise_error(&p, &yb, ds.qid().unwrap());
        assert!(err < 0.35, "binarized grouped error {err}");
    }

    #[test]
    fn toppush_trains_on_ungrouped_bipartite_data() {
        // One global ranking (no qid): the generic engine's inline
        // single-group mode.
        let mut ds = synthetic::cadata_like(250, 17);
        let mut sorted = ds.y.clone();
        sorted.sort_unstable_by(|a, b| a.total_cmp(b));
        let med = sorted[sorted.len() / 2];
        for v in &mut ds.y {
            *v = if *v > med { 1.0 } else { 0.0 };
        }
        let out = train(&ds, &cfg(Method::TopPush)).unwrap();
        assert!(out.converged, "gap={}", out.gap);
        let p = out.model.predict(&ds);
        let err = crate::metrics::pairwise_error(&p, &ds.y);
        assert!(err < 0.35, "bipartite error {err} (AUC {})", 1.0 - err);
    }
}
