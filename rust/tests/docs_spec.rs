//! Normative docs ↔ code consistency.
//!
//! docs/STORE_FORMAT.md, docs/LOSSES.md, and docs/OBSERVABILITY.md are
//! normative, so they must not drift from the code. This suite parses
//! their markdown tables (header fields, COLSTATS layout, flag
//! registry, the loss registry table, the metrics registry, the trace
//! event schemas, the bench snapshot envelope) and verifies every
//! claimed offset, size, constant, and registry row against the real
//! encoder and the real registries — by probing, not by trusting a
//! second copy of the numbers.

use ranksvm::data::store::{
    ColStat, Header, CHECKSUM_FIELD, COLSTAT_BYTES, FLAG_HAS_COLSTATS, FLAG_HAS_QID,
    HEADER_LEN, KNOWN_FLAGS, MAGIC, N_SECTIONS, OFFSETS_START, VERSION,
};

/// One parsed `| offset | size | `name` … |` table row.
#[derive(Debug)]
struct Row {
    offset: usize,
    size: usize,
    name: String,
}

fn spec_text() -> String {
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../docs/STORE_FORMAT.md");
    std::fs::read_to_string(path)
        .unwrap_or_else(|e| panic!("read {path}: {e} — the normative spec must exist"))
}

/// Extract the backticked token of a markdown cell ("`rows` — …" → "rows").
fn backticked(cell: &str) -> Option<String> {
    let start = cell.find('`')? + 1;
    let end = start + cell[start..].find('`')?;
    Some(cell[start..end].to_string())
}

/// Collect numeric table rows under the section whose heading contains
/// `heading` (until the next heading).
fn table_rows(doc: &str, heading: &str) -> Vec<Row> {
    let mut in_section = false;
    let mut rows = Vec::new();
    for line in doc.lines() {
        if line.starts_with('#') {
            in_section = line.contains(heading);
            continue;
        }
        if !in_section || !line.starts_with('|') {
            continue;
        }
        let cells: Vec<&str> = line.split('|').map(str::trim).collect();
        // A well-formed row splits into ["", offset, size, field, ""].
        if cells.len() < 5 {
            continue;
        }
        let (Ok(offset), Ok(size)) = (cells[1].parse::<usize>(), cells[2].parse::<usize>())
        else {
            continue; // separator / header rows
        };
        let Some(name) = backticked(cells[3]) else { continue };
        rows.push(Row { offset, size, name });
    }
    rows
}

fn find<'a>(rows: &'a [Row], name: &str) -> &'a Row {
    rows.iter()
        .find(|r| r.name == name)
        .unwrap_or_else(|| panic!("spec table is missing a `{name}` row: {rows:?}"))
}

/// Header with a distinct sentinel in every field, so a probe at a
/// documented offset can only match the field the doc claims is there.
fn sentinel_header() -> Header {
    Header {
        rows: 0x1111_1111_1111_1111,
        cols: 0x2222_2222_2222_2222,
        nnz: 0x3333_3333_3333_3333,
        flags: 0x4444_4444_4444_4444,
        n_groups: 0x5555_5555_5555_5555,
        n_pairs: 0x6666_6666_6666_6666,
        checksum: 0x7777_7777_7777_7777,
        offsets: [
            0x0101_0101_0101_0101,
            0x0202_0202_0202_0202,
            0x0303_0303_0303_0303,
            0x0404_0404_0404_0404,
            0x0505_0505_0505_0505,
            0x0606_0606_0606_0606,
            0x0707_0707_0707_0707,
            0x0808_0808_0808_0808,
            0x0909_0909_0909_0909,
        ],
    }
}

fn u64_at(bytes: &[u8], off: usize) -> u64 {
    u64::from_le_bytes(bytes[off..off + 8].try_into().unwrap())
}

#[test]
fn header_table_offsets_match_the_encoder() {
    let doc = spec_text();
    let rows = table_rows(&doc, "Header");
    let h = sentinel_header();
    let bytes = h.encode();

    let magic = find(&rows, "magic");
    assert_eq!((magic.offset, magic.size), (0, MAGIC.len()));
    assert_eq!(&bytes[magic.offset..magic.offset + magic.size], &MAGIC);

    let version = find(&rows, "version");
    assert_eq!((version.offset, version.size), (7, 1));
    assert_eq!(bytes[version.offset], VERSION);

    // Every u64 count field: the sentinel must sit at the documented
    // offset, proving the doc describes the real encoding.
    for (name, sentinel) in [
        ("rows", h.rows),
        ("cols", h.cols),
        ("nnz", h.nnz),
        ("flags", h.flags),
        ("n_groups", h.n_groups),
        ("n_pairs", h.n_pairs),
        ("checksum", h.checksum),
    ] {
        let row = find(&rows, name);
        assert_eq!(row.size, 8, "{name}");
        assert_eq!(u64_at(&bytes, row.offset), sentinel, "{name} is not at offset {}", row.offset);
    }
    let checksum = find(&rows, "checksum");
    assert_eq!(checksum.offset, CHECKSUM_FIELD.start);
    assert_eq!(checksum.offset + checksum.size, CHECKSUM_FIELD.end);

    let offsets = find(&rows, "section_offsets");
    assert_eq!((offsets.offset, offsets.size), (OFFSETS_START, 8 * N_SECTIONS));
    for (k, &sentinel) in h.offsets.iter().enumerate() {
        assert_eq!(u64_at(&bytes, offsets.offset + 8 * k), sentinel, "section offset {k}");
    }

    let reserved = find(&rows, "reserved");
    assert_eq!(reserved.offset, OFFSETS_START + 8 * N_SECTIONS);
    assert_eq!(reserved.offset + reserved.size, HEADER_LEN);
    assert!(bytes[reserved.offset..HEADER_LEN].iter().all(|&b| b == 0));

    // The documented table covers the whole header, gap-free.
    let mut covered: Vec<(usize, usize)> = rows.iter().map(|r| (r.offset, r.size)).collect();
    covered.sort_unstable();
    let mut cursor = 0usize;
    for (off, size) in covered {
        assert_eq!(off, cursor, "header table has a gap or overlap at byte {cursor}");
        cursor = off + size;
    }
    assert_eq!(cursor, HEADER_LEN, "header table does not cover all {HEADER_LEN} bytes");

    // Prose constants.
    assert!(doc.contains(&format!("{HEADER_LEN}-byte header")), "header size prose");
    assert!(doc.contains(&format!("version {VERSION}")), "version prose");
}

#[test]
fn colstats_table_matches_the_struct_layout() {
    let doc = spec_text();
    let rows = table_rows(&doc, "COLSTATS layout");
    assert_eq!(rows.len(), 5, "COLSTATS records have exactly five fields: {rows:?}");
    for (name, offset) in [
        ("nnz", std::mem::offset_of!(ColStat, nnz)),
        ("sum", std::mem::offset_of!(ColStat, sum)),
        ("sumsq", std::mem::offset_of!(ColStat, sumsq)),
        ("min", std::mem::offset_of!(ColStat, min)),
        ("max", std::mem::offset_of!(ColStat, max)),
    ] {
        let row = find(&rows, name);
        assert_eq!(row.offset, offset, "{name} offset");
        assert_eq!(row.size, 8, "{name} size");
    }
    assert_eq!(COLSTAT_BYTES, std::mem::size_of::<ColStat>());
    assert!(doc.contains("n × 40"), "colstats section length prose");
}

/// All backticked tokens of a markdown cell, in order.
fn all_backticked(cell: &str) -> Vec<String> {
    let mut out = Vec::new();
    let mut rest = cell;
    while let Some(start) = rest.find('`') {
        let tail = &rest[start + 1..];
        let Some(end) = tail.find('`') else { break };
        out.push(tail[..end].to_string());
        rest = &tail[end + 1..];
    }
    out
}

#[test]
fn losses_doc_table_matches_the_registry() {
    use ranksvm::losses::registry::SPECS;
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../docs/LOSSES.md");
    let doc = std::fs::read_to_string(path)
        .unwrap_or_else(|e| panic!("read {path}: {e} — the normative spec must exist"));

    // Parse `| `name` | aliases | solver | substrate | normalization |`
    // rows under the "Registered losses" heading.
    let mut in_section = false;
    let mut rows: Vec<(String, Vec<String>, String, String, String)> = Vec::new();
    for line in doc.lines() {
        if line.starts_with('#') {
            in_section = line.contains("Registered losses");
            continue;
        }
        if !in_section || !line.starts_with('|') {
            continue;
        }
        let cells: Vec<&str> = line.split('|').map(str::trim).collect();
        if cells.len() < 7 {
            continue;
        }
        let Some(name) = backticked(cells[1]) else { continue }; // header/separator rows
        rows.push((
            name,
            all_backticked(cells[2]),
            cells[3].to_string(),
            cells[4].to_string(),
            cells[5].to_string(),
        ));
    }

    assert_eq!(
        rows.len(),
        SPECS.len(),
        "docs/LOSSES.md table must list every registered loss exactly once: {rows:?}"
    );
    // Same order as the registry — the table *is* the registry, rendered.
    for (row, spec) in rows.iter().zip(SPECS) {
        let (name, aliases, solver, substrate, normalization) = row;
        assert_eq!(name, spec.name, "row order must match registry order");
        assert_eq!(aliases, spec.aliases, "aliases of {}", spec.name);
        assert_eq!(solver, spec.solver.name(), "solver of {}", spec.name);
        assert_eq!(substrate, spec.substrate.name(), "substrate of {}", spec.name);
        assert_eq!(normalization, spec.normalization.name(), "normalization of {}", spec.name);
    }
}

#[test]
fn flag_registry_matches_the_constants() {
    let doc = spec_text();
    // Parse `| bit | mask | `NAME` | …` rows of the registry table.
    let mut masks = std::collections::HashMap::new();
    for line in doc.lines() {
        if !line.starts_with('|') || !line.contains("0x") {
            continue;
        }
        let cells: Vec<&str> = line.split('|').map(str::trim).collect();
        if cells.len() < 5 {
            continue;
        }
        let Some(hex) = cells[2].strip_prefix("0x") else { continue };
        let Ok(mask) = u64::from_str_radix(hex, 16) else { continue };
        if let Some(name) = backticked(cells[3]) {
            masks.insert(name, mask);
        }
    }
    assert_eq!(masks.get("HAS_QID"), Some(&FLAG_HAS_QID), "{masks:?}");
    assert_eq!(masks.get("HAS_COLSTATS"), Some(&FLAG_HAS_COLSTATS), "{masks:?}");
    assert_eq!(
        masks.values().fold(0u64, |a, &m| a | m),
        KNOWN_FLAGS,
        "the registry must list exactly the known flag bits"
    );
}

// ------------------------------------------------- docs/OBSERVABILITY.md

fn obs_text() -> String {
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../docs/OBSERVABILITY.md");
    std::fs::read_to_string(path)
        .unwrap_or_else(|e| panic!("read {path}: {e} — the normative spec must exist"))
}

/// Backticked first-cell tokens of the table rows under the heading
/// containing `heading` (header/separator rows have no backticks and
/// drop out).
fn field_rows(doc: &str, heading: &str) -> Vec<String> {
    let mut in_section = false;
    let mut fields = Vec::new();
    for line in doc.lines() {
        if line.starts_with('#') {
            in_section = line.contains(heading);
            continue;
        }
        if !in_section || !line.starts_with('|') {
            continue;
        }
        let cells: Vec<&str> = line.split('|').map(str::trim).collect();
        if cells.len() < 4 {
            continue;
        }
        if let Some(name) = backticked(cells[1]) {
            fields.push(name);
        }
    }
    fields
}

#[test]
fn observability_metrics_table_matches_the_registry() {
    use ranksvm::obs::metrics::REGISTRY;
    let doc = obs_text();
    // Parse `| `name` | type | unit | help |` rows under the
    // "Metrics registry" heading.
    let mut in_section = false;
    let mut rows: Vec<(String, String, String, String)> = Vec::new();
    for line in doc.lines() {
        if line.starts_with('#') {
            in_section = line.contains("Metrics registry");
            continue;
        }
        if !in_section || !line.starts_with('|') {
            continue;
        }
        let cells: Vec<&str> = line.split('|').map(str::trim).collect();
        if cells.len() < 6 {
            continue;
        }
        let Some(name) = backticked(cells[1]) else { continue }; // header/separator rows
        rows.push((name, cells[2].to_string(), cells[3].to_string(), cells[4].to_string()));
    }

    assert_eq!(
        rows.len(),
        REGISTRY.len(),
        "the docs table must list every registered metric exactly once: {rows:?}"
    );
    // Same order as the registry — the table *is* the registry, rendered.
    for ((name, kind, unit, help), def) in rows.iter().zip(REGISTRY) {
        assert_eq!(name, def.name, "row order must match registry order");
        assert_eq!(kind, def.kind.type_name(), "type of {}", def.name);
        assert_eq!(unit, def.unit, "unit of {}", def.name);
        assert_eq!(help, def.help, "help of {}", def.name);
    }
}

#[test]
fn observability_histogram_bounds_match_the_constants() {
    use ranksvm::obs::metrics::{BATCH_SIZE_BUCKETS, LATENCY_BUCKETS_US};
    let doc = obs_text();
    let fmt = |bounds: &[u64]| {
        let strs: Vec<String> = bounds.iter().map(|b| b.to_string()).collect();
        format!("`{}`", strs.join(", "))
    };
    assert!(doc.contains(&fmt(LATENCY_BUCKETS_US)), "latency bucket bounds");
    assert!(doc.contains(&fmt(BATCH_SIZE_BUCKETS)), "batch-size bucket bounds");
}

#[test]
fn observability_trace_tables_match_the_field_lists() {
    use ranksvm::obs::trace::{
        CV_POINT_FIELDS, END_FIELDS, ITER_FIELDS, START_FIELDS, TRACE_SCHEMA_VERSION,
    };
    let doc = obs_text();
    assert_eq!(field_rows(&doc, "`start` event"), START_FIELDS);
    assert_eq!(field_rows(&doc, "`iter` event"), ITER_FIELDS);
    assert_eq!(field_rows(&doc, "`end` event"), END_FIELDS);
    assert_eq!(field_rows(&doc, "`cv_point` event"), CV_POINT_FIELDS);
    assert!(
        doc.contains(&format!("trace schema_version is {TRACE_SCHEMA_VERSION}")),
        "trace schema version prose"
    );
}

#[test]
fn model_selection_docs_pin_the_cv_contract() {
    // README documents the subcommand…
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../README.md");
    let readme = std::fs::read_to_string(path).unwrap_or_else(|e| panic!("read {path}: {e}"));
    assert!(readme.contains("## Model selection"), "README needs a Model selection section");
    assert!(readme.contains("ranksvm cv"), "README must show the cv subcommand");
    assert!(readme.contains("--lambdas"), "README must document the λ grid flag");
    // …and docs/DETERMINISM.md carries the model-selection row of the
    // "Who relies on what" table plus its enforcement pointer.
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../docs/DETERMINISM.md");
    let det = std::fs::read_to_string(path).unwrap_or_else(|e| panic!("read {path}: {e}"));
    assert!(det.contains("model selection"), "DETERMINISM.md needs the model-selection row");
    assert!(det.contains("cv_sweep"), "DETERMINISM.md must name the parallel engine");
    assert!(
        det.contains("tests/modelsel.rs"),
        "DETERMINISM.md must point at the CV determinism battery"
    );
}

#[test]
fn observability_snapshot_table_matches_the_envelope() {
    use ranksvm::obs::snapshot::{SNAPSHOT_FIELDS, SNAPSHOT_SCHEMA, SNAPSHOT_SCHEMA_VERSION};
    let doc = obs_text();
    assert_eq!(field_rows(&doc, "Bench snapshots"), SNAPSHOT_FIELDS);
    assert!(doc.contains(&format!("`\"{SNAPSHOT_SCHEMA}\"`")), "schema name");
    assert!(
        doc.contains(&format!("schema_version {SNAPSHOT_SCHEMA_VERSION}")),
        "envelope schema version prose"
    );
}
