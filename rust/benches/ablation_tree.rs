//! Ablation A — counting-structure variants inside Algorithm 3:
//!
//! 1. plain order-statistics red-black tree (the paper's structure),
//! 2. dedup (`nodesize`) variant — O(log r) ops (paper §4.2 last ¶),
//! 3. Fenwick counter over the rank-compressed label universe (ours).
//!
//! Swept across the number of distinct utility levels r: the paper
//! argues dedup helps when r ≪ m but cannot beat the O(m log m) sort
//! barrier; the Fenwick variant tests how much of the tree's cost is
//! pointer-chasing vs. algorithmic.
//!
//! Also reports the two-copies (CSR+CSC) backend trade-off the paper's
//! Fig-3 discussion mentions (7× slowdown claim for one-copy column
//! access; here: CSC gather vs CSR scatter for the gradient).

mod common;

use common::{fmt_secs, header, record};
use ranksvm::data::synthetic;
use ranksvm::losses::tree::{fenwick_oracle, TreeOracle};
use ranksvm::losses::{count_comparable_pairs, RankingOracle};
use ranksvm::util::json::Json;

fn time_oracle(oracle: &mut dyn RankingOracle, p: &[f64], y: &[f64], n: f64, reps: usize) -> f64 {
    std::hint::black_box(oracle.eval(p, y, n)); // warmup
    let t = std::time::Instant::now();
    for _ in 0..reps {
        std::hint::black_box(oracle.eval(p, y, n));
    }
    t.elapsed().as_secs_f64() / reps as f64
}

fn main() {
    let m = 50_000;
    header(&format!("Ablation A: counting structure vs distinct levels r (m={m})"));
    println!(
        "{:>8} {:>14} {:>14} {:>14}",
        "r", "rb-tree", "rb-dedup", "fenwick"
    );
    for levels in [2usize, 5, 100, 10_000, m] {
        // ordinal() quantizes to exactly `levels`; levels == m ≈ all-distinct.
        let ds = if levels >= m {
            synthetic::cadata_like(m, 300)
        } else {
            synthetic::ordinal(m, levels, 300)
        };
        let p: Vec<f64> =
            ds.y.iter().enumerate().map(|(i, v)| v * 0.3 + (i % 17) as f64 * 0.01).collect();
        let n = count_comparable_pairs(&ds.y) as f64;
        let reps = 3;
        let t_plain = time_oracle(&mut TreeOracle::new(), &p, &ds.y, n, reps);
        let t_dedup = time_oracle(&mut TreeOracle::new_dedup(), &p, &ds.y, n, reps);
        let t_fenwick = time_oracle(&mut fenwick_oracle(&ds.y), &p, &ds.y, n, reps);
        println!(
            "{:>8} {:>14} {:>14} {:>14}",
            levels,
            fmt_secs(t_plain),
            fmt_secs(t_dedup),
            fmt_secs(t_fenwick)
        );
        record(
            "ablation_tree",
            Json::obj(vec![
                ("m", m.into()),
                ("r", levels.into()),
                ("rb_tree_secs", t_plain.into()),
                ("rb_dedup_secs", t_dedup.into()),
                ("fenwick_secs", t_fenwick.into()),
            ]),
        );
    }
    println!("\nExpected: dedup/fenwick flat-to-falling as r shrinks; all three");
    println!("converge at r ≈ m. None can beat the O(m log m) sort (paper §4.2).");

    // --- two-copies backend trade-off --------------------------------
    header("Ablation A2: CSR-scatter vs CSC-gather gradient (two-copies trade-off)");
    use ranksvm::compute::{ComputeBackend, NativeBackend};
    let ds = synthetic::reuters_like_with(40_000, 50_000, 50, 301);
    let coeffs: Vec<f64> = (0..ds.len()).map(|i| ((i * 37) % 101) as f64 / 50.0 - 1.0).collect();
    for (label, mut backend) in [
        ("csr-scatter", NativeBackend::new()),
        ("csr+csc-gather", NativeBackend::with_csc()),
    ] {
        backend.prepare(ds.x.view());
        std::hint::black_box(backend.grad(ds.x.view(), &coeffs));
        let t = std::time::Instant::now();
        for _ in 0..5 {
            std::hint::black_box(backend.grad(ds.x.view(), &coeffs));
        }
        let secs = t.elapsed().as_secs_f64() / 5.0;
        println!("{label:<16} grad: {}", fmt_secs(secs));
        record(
            "ablation_tree",
            Json::obj(vec![("backend", label.into()), ("grad_secs", secs.into())]),
        );
    }
    println!("(the paper kept both copies for a ~7× training-time win on Reuters)");
}
