//! Query-grouped ranking (§2 / end of §4.3).
//!
//! In document-retrieval settings preferences are induced only *within*
//! a query's document set, never across queries: the training data is
//! partitioned into `R` disjoint subsets, the loss/subgradient is
//! computed per subset, and the final value is the average over subsets.
//! With a tree oracle the total complexity is
//! `O(Σ_g (m_g log m_g)) = O(m log(m/R))` plus the `O(ms)` linear algebra
//! (paper, end of §4.3).

use super::{count_comparable_pairs, OracleOutput, RankingOracle};

/// Partition examples into query groups (first-seen qid order) and
/// count each group's comparable pairs. The single source of truth for
/// the grouping convention — shared by [`QueryGrouped`] and the sharded
/// engine ([`super::ShardedTreeOracle`]), whose bit-identity contract
/// depends on both sides agreeing on group order and pair counts.
pub(crate) fn build_groups(qid: &[u64], y: &[f64]) -> (Vec<Vec<usize>>, Vec<f64>) {
    assert_eq!(qid.len(), y.len(), "qid/label count mismatch");
    let mut map = std::collections::HashMap::<u64, usize>::new();
    let mut groups: Vec<Vec<usize>> = Vec::new();
    for (i, &q) in qid.iter().enumerate() {
        let g = *map.entry(q).or_insert_with(|| {
            groups.push(Vec::new());
            groups.len() - 1
        });
        groups[g].push(i);
    }
    let group_pairs = groups
        .iter()
        .map(|g| {
            let yg: Vec<f64> = g.iter().map(|&i| y[i]).collect();
            count_comparable_pairs(&yg) as f64
        })
        .collect();
    (groups, group_pairs)
}

/// Wraps any per-group oracle and averages over query groups.
pub struct QueryGrouped<O: RankingOracle> {
    inner: O,
    /// Example indices per group.
    groups: Vec<Vec<usize>>,
    /// Comparable-pair count per group (fixed by the labels at build).
    group_pairs: Vec<f64>,
    /// Scratch buffers.
    p_buf: Vec<f64>,
    y_buf: Vec<f64>,
}

impl<O: RankingOracle> QueryGrouped<O> {
    /// Build from per-example query ids (`qid[i]` arbitrary integers) and
    /// the fixed label vector.
    pub fn new(inner: O, qid: &[u64], y: &[f64]) -> Self {
        let (groups, group_pairs) = build_groups(qid, y);
        QueryGrouped { inner, groups, group_pairs, p_buf: Vec::new(), y_buf: Vec::new() }
    }

    /// Number of query groups.
    pub fn n_groups(&self) -> usize {
        self.groups.len()
    }

    /// Number of groups with at least one comparable pair — the effective
    /// `R` used for averaging (groups with all-tied labels contribute no
    /// preference information; including them would only rescale).
    pub fn n_effective_groups(&self) -> usize {
        self.group_pairs.iter().filter(|&&n| n > 0.0).count()
    }

    /// Total comparable pairs across groups (for reporting).
    pub fn total_pairs(&self) -> f64 {
        self.group_pairs.iter().sum()
    }
}

impl<O: RankingOracle> RankingOracle for QueryGrouped<O> {
    /// `n_pairs` is ignored — the per-group counts fixed at construction
    /// are authoritative (callers pass `total_pairs()` for uniformity).
    fn eval(&mut self, p: &[f64], y: &[f64], _n_pairs: f64) -> OracleOutput {
        let m = p.len();
        assert_eq!(m, y.len());
        let r_eff = self.n_effective_groups().max(1) as f64;
        let mut loss = 0.0;
        let mut coeffs = vec![0.0; m];
        for (g, idx) in self.groups.iter().enumerate() {
            let ng = self.group_pairs[g];
            if ng == 0.0 {
                continue;
            }
            self.p_buf.clear();
            self.y_buf.clear();
            self.p_buf.extend(idx.iter().map(|&i| p[i]));
            self.y_buf.extend(idx.iter().map(|&i| y[i]));
            let out = self.inner.eval(&self.p_buf, &self.y_buf, ng);
            loss += out.loss / r_eff;
            for (k, &i) in idx.iter().enumerate() {
                coeffs[i] = out.coeffs[k] / r_eff;
            }
        }
        OracleOutput { loss, coeffs }
    }

    fn name(&self) -> &'static str {
        "query-grouped"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::losses::{PairOracle, TreeOracle};
    use crate::util::rng::Rng;

    #[test]
    fn single_group_equals_plain_oracle() {
        let mut rng = Rng::new(401);
        let m = 60;
        let y: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
        let p: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
        let qid = vec![7u64; m];
        let n = count_comparable_pairs(&y) as f64;
        let mut plain = TreeOracle::new();
        let mut grouped = QueryGrouped::new(TreeOracle::new(), &qid, &y);
        let a = plain.eval(&p, &y, n);
        let b = grouped.eval(&p, &y, n);
        assert!((a.loss - b.loss).abs() < 1e-12);
        for (x, z) in a.coeffs.iter().zip(&b.coeffs) {
            assert!((x - z).abs() < 1e-12);
        }
    }

    #[test]
    fn matches_manual_per_group_average() {
        let mut rng = Rng::new(403);
        // 3 groups of different sizes, interleaved qids.
        let qid: Vec<u64> = (0..90).map(|i| (i % 3) as u64).collect();
        let y: Vec<f64> = (0..90).map(|_| rng.below(4) as f64).collect();
        let p: Vec<f64> = (0..90).map(|_| rng.normal()).collect();
        let mut grouped = QueryGrouped::new(PairOracle::new(), &qid, &y);
        let out = grouped.eval(&p, &y, grouped.total_pairs());

        // Manual: evaluate each group separately and average.
        let mut manual_loss = 0.0;
        let mut manual_coeffs = vec![0.0; 90];
        let mut r_eff = 0.0;
        for g in 0..3u64 {
            let idx: Vec<usize> = (0..90).filter(|&i| qid[i] == g).collect();
            let yg: Vec<f64> = idx.iter().map(|&i| y[i]).collect();
            let ng = count_comparable_pairs(&yg) as f64;
            if ng > 0.0 {
                r_eff += 1.0;
            }
        }
        for g in 0..3u64 {
            let idx: Vec<usize> = (0..90).filter(|&i| qid[i] == g).collect();
            let yg: Vec<f64> = idx.iter().map(|&i| y[i]).collect();
            let pg: Vec<f64> = idx.iter().map(|&i| p[i]).collect();
            let ng = count_comparable_pairs(&yg) as f64;
            if ng == 0.0 {
                continue;
            }
            let mut o = PairOracle::new();
            let og = o.eval(&pg, &yg, ng);
            manual_loss += og.loss / r_eff;
            for (k, &i) in idx.iter().enumerate() {
                manual_coeffs[i] = og.coeffs[k] / r_eff;
            }
        }
        assert!((out.loss - manual_loss).abs() < 1e-12);
        for (a, b) in out.coeffs.iter().zip(&manual_coeffs) {
            assert!((a - b).abs() < 1e-12);
        }
    }

    #[test]
    fn no_cross_group_preferences() {
        // Two groups each internally tied: no pairs at all, even though
        // labels differ across groups.
        let qid = [0u64, 0, 1, 1];
        let y = [1.0, 1.0, 2.0, 2.0];
        let p = [9.0, -9.0, 3.0, -3.0];
        let mut grouped = QueryGrouped::new(TreeOracle::new(), &qid, &y);
        assert_eq!(grouped.n_groups(), 2);
        assert_eq!(grouped.n_effective_groups(), 0);
        assert_eq!(grouped.total_pairs(), 0.0);
        let out = grouped.eval(&p, &y, 0.0);
        assert_eq!(out.loss, 0.0);
        assert!(out.coeffs.iter().all(|&c| c == 0.0));
    }

    #[test]
    fn empty_input() {
        let mut grouped = QueryGrouped::new(TreeOracle::new(), &[], &[]);
        let out = grouped.eval(&[], &[], 0.0);
        assert_eq!(out.loss, 0.0);
        assert_eq!(grouped.n_groups(), 0);
    }
}
