//! Query-grouped ranking (§2 / end of §4.3).
//!
//! In document-retrieval settings preferences are induced only *within*
//! a query's document set, never across queries: the training data is
//! partitioned into `R` disjoint subsets, the loss/subgradient is
//! computed per subset, and the final value is the average over subsets.
//! With a tree oracle the total complexity is
//! `O(Σ_g (m_g log m_g)) = O(m log(m/R))` plus the `O(ms)` linear algebra
//! (paper, end of §4.3).

use super::{count_comparable_pairs, OracleOutput, RankingOracle};
use anyhow::{ensure, Result};
use std::sync::Arc;

/// The query-group partition of a training set, in flat CSR-like form:
/// `examples[offsets[g]..offsets[g+1]]` are the example indices of group
/// `g` (groups in first-seen qid order, examples in dataset order), and
/// `pairs[g]` is the group's exact comparable-pair count.
///
/// This is the single source of truth for the grouping convention —
/// shared by [`QueryGrouped`], the sharded engine
/// ([`super::ShardedTreeOracle`]), whose bit-identity contract depends
/// on both sides agreeing on group order and pair counts, and the pallas
/// store (`data::store`), which serializes exactly these three arrays so
/// an opened store skips the per-run group scan entirely.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct GroupIndex {
    /// Group start offsets into `examples`, length `n_groups + 1`.
    offsets: Vec<usize>,
    /// Example indices concatenated by group, length `m`.
    examples: Vec<usize>,
    /// Comparable pairs per group (fixed by the labels at build).
    pairs: Vec<u64>,
}

impl GroupIndex {
    /// Build by scanning per-example query ids (first-seen qid order)
    /// against the fixed label vector.
    pub fn build(qid: &[u64], y: &[f64]) -> Self {
        assert_eq!(qid.len(), y.len(), "qid/label count mismatch");
        let mut map = std::collections::HashMap::<u64, usize>::new();
        let mut groups: Vec<Vec<usize>> = Vec::new();
        for (i, &q) in qid.iter().enumerate() {
            let g = *map.entry(q).or_insert_with(|| {
                groups.push(Vec::new());
                groups.len() - 1
            });
            groups[g].push(i);
        }
        let mut offsets = Vec::with_capacity(groups.len() + 1);
        let mut examples = Vec::with_capacity(qid.len());
        let mut pairs = Vec::with_capacity(groups.len());
        let mut yg = Vec::new();
        offsets.push(0);
        for g in &groups {
            examples.extend_from_slice(g);
            offsets.push(examples.len());
            yg.clear();
            yg.extend(g.iter().map(|&i| y[i]));
            pairs.push(count_comparable_pairs(&yg));
        }
        GroupIndex { offsets, examples, pairs }
    }

    /// Rebuild from serialized parts (the pallas store's group-index
    /// sections), validating structural invariants. Group *contents*
    /// (that `examples` partitions `0..m` consistently with some qid
    /// vector) are the writer's responsibility, guarded by the store
    /// checksum.
    pub fn from_parts(offsets: Vec<usize>, examples: Vec<usize>, pairs: Vec<u64>) -> Result<Self> {
        ensure!(!offsets.is_empty(), "group offsets must contain at least the terminal 0");
        ensure!(offsets[0] == 0, "group offsets must start at 0");
        ensure!(
            offsets.len() == pairs.len() + 1,
            "group offsets/pairs length mismatch: {} vs {}",
            offsets.len(),
            pairs.len()
        );
        for w in offsets.windows(2) {
            ensure!(w[0] <= w[1], "group offsets must be non-decreasing");
        }
        ensure!(
            *offsets.last().unwrap() == examples.len(),
            "group offsets end at {} but {} examples are indexed",
            offsets.last().unwrap(),
            examples.len()
        );
        let m = examples.len();
        let mut seen = vec![false; m];
        for &i in &examples {
            ensure!(i < m, "group example index {i} out of bounds (m = {m})");
            ensure!(!seen[i], "group example index {i} appears twice");
            seen[i] = true;
        }
        Ok(GroupIndex { offsets, examples, pairs })
    }

    /// Number of query groups.
    pub fn n_groups(&self) -> usize {
        self.pairs.len()
    }

    /// Total examples indexed (the dataset's `m`).
    pub fn n_examples(&self) -> usize {
        self.examples.len()
    }

    /// Example indices of group `g`.
    #[inline]
    pub fn group(&self, g: usize) -> &[usize] {
        &self.examples[self.offsets[g]..self.offsets[g + 1]]
    }

    /// Exact comparable-pair count of group `g`.
    #[inline]
    pub fn group_pairs(&self, g: usize) -> u64 {
        self.pairs[g]
    }

    /// Number of groups with at least one comparable pair — the
    /// effective `R` used for averaging (groups with all-tied labels
    /// contribute no preference information; including them would only
    /// rescale).
    pub fn n_effective_groups(&self) -> usize {
        self.pairs.iter().filter(|&&n| n > 0).count()
    }

    /// Total comparable pairs across groups, accumulated in group order
    /// (the order matters for float bit-identity with older per-group
    /// f64 accumulation).
    pub fn total_pairs(&self) -> f64 {
        let mut total = 0.0;
        for &n in &self.pairs {
            total += n as f64;
        }
        total
    }

    /// Serialized views for the store writer: `(offsets, examples,
    /// pairs)` exactly as [`Self::from_parts`] expects them back.
    pub fn as_parts(&self) -> (&[usize], &[usize], &[u64]) {
        (&self.offsets, &self.examples, &self.pairs)
    }
}

/// Wraps any per-group oracle and averages over query groups. The
/// index is shared by `Arc` so a store-carried index is referenced, not
/// copied, per training run.
pub struct QueryGrouped<O: RankingOracle> {
    inner: O,
    index: Arc<GroupIndex>,
    /// Scratch buffers.
    p_buf: Vec<f64>,
    y_buf: Vec<f64>,
}

impl<O: RankingOracle> QueryGrouped<O> {
    /// Build from per-example query ids (`qid[i]` arbitrary integers) and
    /// the fixed label vector.
    pub fn new(inner: O, qid: &[u64], y: &[f64]) -> Self {
        Self::with_index(inner, Arc::new(GroupIndex::build(qid, y)))
    }

    /// Build from a precomputed group index (e.g. the one a pallas store
    /// carries) — no scan, no copy.
    pub fn with_index(inner: O, index: Arc<GroupIndex>) -> Self {
        QueryGrouped { inner, index, p_buf: Vec::new(), y_buf: Vec::new() }
    }

    /// Number of query groups.
    pub fn n_groups(&self) -> usize {
        self.index.n_groups()
    }

    /// Number of groups with at least one comparable pair.
    pub fn n_effective_groups(&self) -> usize {
        self.index.n_effective_groups()
    }

    /// Total comparable pairs across groups (for reporting).
    pub fn total_pairs(&self) -> f64 {
        self.index.total_pairs()
    }
}

impl<O: RankingOracle> RankingOracle for QueryGrouped<O> {
    /// `n_pairs` is ignored — the per-group counts fixed at construction
    /// are authoritative (callers pass `total_pairs()` for uniformity).
    fn eval(&mut self, p: &[f64], y: &[f64], _n_pairs: f64) -> OracleOutput {
        let m = p.len();
        assert_eq!(m, y.len());
        let r_eff = self.index.n_effective_groups().max(1) as f64;
        let mut loss = 0.0;
        let mut coeffs = vec![0.0; m];
        for g in 0..self.index.n_groups() {
            let ng = self.index.group_pairs(g) as f64;
            if ng == 0.0 {
                continue;
            }
            let idx = self.index.group(g);
            self.p_buf.clear();
            self.y_buf.clear();
            self.p_buf.extend(idx.iter().map(|&i| p[i]));
            self.y_buf.extend(idx.iter().map(|&i| y[i]));
            let out = self.inner.eval(&self.p_buf, &self.y_buf, ng);
            loss += out.loss / r_eff;
            for (k, &i) in idx.iter().enumerate() {
                coeffs[i] = out.coeffs[k] / r_eff;
            }
        }
        OracleOutput { loss, coeffs }
    }

    fn name(&self) -> &'static str {
        "query-grouped"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::losses::{PairOracle, TreeOracle};
    use crate::util::rng::Rng;

    #[test]
    fn single_group_equals_plain_oracle() {
        let mut rng = Rng::new(401);
        let m = 60;
        let y: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
        let p: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
        let qid = vec![7u64; m];
        let n = count_comparable_pairs(&y) as f64;
        let mut plain = TreeOracle::new();
        let mut grouped = QueryGrouped::new(TreeOracle::new(), &qid, &y);
        let a = plain.eval(&p, &y, n);
        let b = grouped.eval(&p, &y, n);
        assert!((a.loss - b.loss).abs() < 1e-12);
        for (x, z) in a.coeffs.iter().zip(&b.coeffs) {
            assert!((x - z).abs() < 1e-12);
        }
    }

    #[test]
    fn matches_manual_per_group_average() {
        let mut rng = Rng::new(403);
        // 3 groups of different sizes, interleaved qids.
        let qid: Vec<u64> = (0..90).map(|i| (i % 3) as u64).collect();
        let y: Vec<f64> = (0..90).map(|_| rng.below(4) as f64).collect();
        let p: Vec<f64> = (0..90).map(|_| rng.normal()).collect();
        let mut grouped = QueryGrouped::new(PairOracle::new(), &qid, &y);
        let out = grouped.eval(&p, &y, grouped.total_pairs());

        // Manual: evaluate each group separately and average.
        let mut manual_loss = 0.0;
        let mut manual_coeffs = vec![0.0; 90];
        let mut r_eff = 0.0;
        for g in 0..3u64 {
            let idx: Vec<usize> = (0..90).filter(|&i| qid[i] == g).collect();
            let yg: Vec<f64> = idx.iter().map(|&i| y[i]).collect();
            let ng = count_comparable_pairs(&yg) as f64;
            if ng > 0.0 {
                r_eff += 1.0;
            }
        }
        for g in 0..3u64 {
            let idx: Vec<usize> = (0..90).filter(|&i| qid[i] == g).collect();
            let yg: Vec<f64> = idx.iter().map(|&i| y[i]).collect();
            let pg: Vec<f64> = idx.iter().map(|&i| p[i]).collect();
            let ng = count_comparable_pairs(&yg) as f64;
            if ng == 0.0 {
                continue;
            }
            let mut o = PairOracle::new();
            let og = o.eval(&pg, &yg, ng);
            manual_loss += og.loss / r_eff;
            for (k, &i) in idx.iter().enumerate() {
                manual_coeffs[i] = og.coeffs[k] / r_eff;
            }
        }
        assert!((out.loss - manual_loss).abs() < 1e-12);
        for (a, b) in out.coeffs.iter().zip(&manual_coeffs) {
            assert!((a - b).abs() < 1e-12);
        }
    }

    #[test]
    fn no_cross_group_preferences() {
        // Two groups each internally tied: no pairs at all, even though
        // labels differ across groups.
        let qid = [0u64, 0, 1, 1];
        let y = [1.0, 1.0, 2.0, 2.0];
        let p = [9.0, -9.0, 3.0, -3.0];
        let mut grouped = QueryGrouped::new(TreeOracle::new(), &qid, &y);
        assert_eq!(grouped.n_groups(), 2);
        assert_eq!(grouped.n_effective_groups(), 0);
        assert_eq!(grouped.total_pairs(), 0.0);
        let out = grouped.eval(&p, &y, 0.0);
        assert_eq!(out.loss, 0.0);
        assert!(out.coeffs.iter().all(|&c| c == 0.0));
    }

    #[test]
    fn empty_input() {
        let mut grouped = QueryGrouped::new(TreeOracle::new(), &[], &[]);
        let out = grouped.eval(&[], &[], 0.0);
        assert_eq!(out.loss, 0.0);
        assert_eq!(grouped.n_groups(), 0);
    }

    #[test]
    fn index_roundtrips_through_parts() {
        let qid = [3u64, 1, 3, 3, 1, 9];
        let y = [1.0, 0.0, 2.0, 2.0, 1.0, 5.0];
        let built = GroupIndex::build(&qid, &y);
        assert_eq!(built.n_groups(), 3);
        assert_eq!(built.group(0), &[0, 2, 3]); // qid 3, first seen
        assert_eq!(built.group(1), &[1, 4]); // qid 1
        assert_eq!(built.group(2), &[5]); // qid 9
        assert_eq!(built.group_pairs(2), 0);
        let (o, e, p) = built.as_parts();
        let back = GroupIndex::from_parts(o.to_vec(), e.to_vec(), p.to_vec()).unwrap();
        assert_eq!(back, built);
    }

    #[test]
    fn from_parts_rejects_malformed() {
        // Offsets not starting at 0.
        assert!(GroupIndex::from_parts(vec![1, 2], vec![0, 1], vec![0]).is_err());
        // Decreasing offsets.
        assert!(GroupIndex::from_parts(vec![0, 2, 1], vec![0, 1], vec![0, 0]).is_err());
        // Terminal offset not covering all examples.
        assert!(GroupIndex::from_parts(vec![0, 1], vec![0, 1], vec![1]).is_err());
        // Out-of-bounds example.
        assert!(GroupIndex::from_parts(vec![0, 2], vec![0, 7], vec![1]).is_err());
        // Duplicate example.
        assert!(GroupIndex::from_parts(vec![0, 2], vec![1, 1], vec![1]).is_err());
        // Offsets/pairs mismatch.
        assert!(GroupIndex::from_parts(vec![0, 2], vec![0, 1], vec![1, 2]).is_err());
        // Empty offsets.
        assert!(GroupIndex::from_parts(vec![], vec![], vec![]).is_err());
        // Valid empty index.
        assert!(GroupIndex::from_parts(vec![0], vec![], vec![]).is_ok());
    }
}
