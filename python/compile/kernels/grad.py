"""Pallas kernel: blocked subgradient assembly  a = X^T @ coeffs  (L1).

The second `O(ms)` hot spot (Lemma 2 / Algorithm 3 line 24). The row
blocks stream through VMEM exactly as in ``scores``; the `(n,)` output
block is grid-invariant (index map pins it to block 0), so it stays
VMEM-resident and accumulates across the grid — the standard Pallas
reduction idiom, equivalent to a threadblock-level partial-sum + final
reduction on GPU but with the accumulator held in the scratchpad.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_M = 256


def _grad_kernel(x_ref, c_ref, o_ref):
    """Accumulate o += x_block^T @ c_block over the row-block grid."""
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += x_ref[...].T @ c_ref[...]


@functools.partial(jax.jit, static_argnames=("block_m",))
def grad(x, coeffs, *, block_m=DEFAULT_BLOCK_M):
    """a = X^T @ coeffs with X (m, n) f32, coeffs (m,) f32."""
    m, n = x.shape
    bm = min(block_m, m)
    if m % bm != 0:
        raise ValueError(f"m={m} not divisible by block_m={bm}")
    grid = (m // bm,)
    return pl.pallas_call(
        _grad_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, n), lambda i: (i, 0)),
            pl.BlockSpec((bm,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((n,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=True,
    )(x, coeffs)
