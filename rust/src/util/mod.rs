//! Shared utilities: seeded RNG, JSON emission, timing, CLI parsing,
//! and process memory probes.
//!
//! These replace crates absent from the offline registry (`rand`,
//! `serde_json`, `criterion`, `clap`) — see DESIGN.md §6 toolchain
//! substitutions.

pub mod cli;
pub mod json;
pub mod rng;
pub mod timer;

/// Resolve a worker-thread request: `0` means "all cores" (the host's
/// available parallelism, 1 if that probe fails), anything else is taken
/// literally. The single source of truth for the `--threads`/`n_threads`
/// convention across the trainer, the CLI perf probe, and the benches.
pub fn resolve_threads(requested: usize) -> usize {
    if requested == 0 {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    } else {
        requested
    }
}

/// Peak resident set size (VmHWM) of the current process in KiB, read from
/// /proc/self/status. Used by the Fig-3 memory benchmark. Returns None on
/// non-Linux or if the field is missing.
pub fn peak_rss_kib() -> Option<u64> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    for line in status.lines() {
        if let Some(rest) = line.strip_prefix("VmHWM:") {
            let kb: u64 = rest.trim().trim_end_matches(" kB").trim().parse().ok()?;
            return Some(kb);
        }
    }
    None
}

/// Current resident set size (VmRSS) in KiB.
pub fn current_rss_kib() -> Option<u64> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    for line in status.lines() {
        if let Some(rest) = line.strip_prefix("VmRSS:") {
            let kb: u64 = rest.trim().trim_end_matches(" kB").trim().parse().ok()?;
            return Some(kb);
        }
    }
    None
}

#[cfg(test)]
mod tests {
    #[test]
    fn rss_probes_work_on_linux() {
        let peak = super::peak_rss_kib().expect("VmHWM should parse on Linux");
        let cur = super::current_rss_kib().expect("VmRSS should parse on Linux");
        assert!(peak > 0 && cur > 0);
        assert!(peak >= cur || peak + 1024 > cur); // peak ≈>= current
    }
}
