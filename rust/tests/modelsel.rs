//! CV determinism battery (docs/DETERMINISM.md "model selection").
//!
//! Pins the three contracts the λ-path engine advertises:
//!
//! 1. **Fold splits are byte-stable.** `kfold_indices` is a pure
//!    function of `(m or qid multiset, folds, seed)` — the exact
//!    assignments are recorded here as fixtures, so any RNG or
//!    shuffle-order change shows up as a diff, not as silently moved
//!    rows.
//! 2. **The parallel sweep is the serial sweep.** `cv_sweep` at 1/2/8
//!    threads must reproduce `cv_serial` bit-for-bit — every metric,
//!    every iteration count, every fold model byte-compared.
//! 3. **Warm starts change the cost, not the answer.** Along a 4-point
//!    λ path the warm and cold engines select the same λ, land on
//!    ε-close held-out metrics, and the warm path spends strictly
//!    fewer total solver iterations.
//!
//! Plus the bounded-memory regression: CV of a `.pstore` must not
//! materialize per-fold dataset copies (child-process peak-RSS probe).

use ranksvm::coordinator::{
    cross_validate, cv_serial, cv_sweep, kfold_indices, memprobe, CvConfig, CvReport, Method,
    TrainConfig,
};
use ranksvm::data::store::{convert_libsvm, ConvertOptions};
use ranksvm::data::{libsvm, synthetic, Dataset};
use ranksvm::linalg::CsrMatrix;
use ranksvm::obs::metrics::{CV_BMRM_ITERS, CV_FOLD_TRAININGS, CV_SWEEPS};

fn tmp(name: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("ranksvm_modelsel_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(name)
}

/// A minimal m-row dataset (features are irrelevant to the splitter).
fn rows_only(m: usize, qid: Option<Vec<u64>>) -> Dataset {
    let triplets: Vec<(usize, usize, f64)> = (0..m).map(|i| (i, 0, i as f64)).collect();
    let x = CsrMatrix::from_triplets(m, 1, triplets);
    let y: Vec<f64> = (0..m).map(|i| i as f64).collect();
    Dataset::new(x, y, qid, "fixture".to_string())
}

// ------------------------------------------------- recorded fold splits

#[test]
fn global_kfold_split_matches_recorded_fixture() {
    // Recorded for (m = 10, folds = 3, seed = 7). If this diff ever
    // fires, the split function changed: that silently reassigns every
    // CV result ever produced, so it must be a deliberate,
    // fixture-updating decision — never an accident.
    let ds = rows_only(10, None);
    let folds = kfold_indices(&ds, 3, 7);
    assert_eq!(folds, vec![vec![3, 4, 2, 0], vec![8, 6, 5], vec![9, 7, 1]]);
    // And it is a pure function: same inputs, same bytes, every call.
    assert_eq!(folds, kfold_indices(&ds, 3, 7));
    assert_ne!(folds, kfold_indices(&ds, 3, 8), "seed must matter");
}

#[test]
fn grouped_kfold_split_matches_recorded_fixture() {
    // Recorded for (qid multiset below, folds = 3, seed = 42). Grouped
    // splits move whole queries: fold 0 holds queries {0, 1}, fold 1
    // holds {3, 4}, fold 2 holds {2} — row indices in dataset order.
    let qid = vec![0, 0, 0, 1, 1, 2, 2, 2, 2, 3, 3, 3, 4, 4];
    let ds = rows_only(qid.len(), Some(qid));
    let folds = kfold_indices(&ds, 3, 42);
    assert_eq!(
        folds,
        vec![vec![0, 1, 2, 3, 4], vec![9, 10, 11, 12, 13], vec![5, 6, 7, 8]]
    );
}

// ------------------------------------------- parallel ≡ serial sweeps

/// Every field the report carries, fold models byte-for-byte (`f64`
/// equality on `Vec<f64>` is exact — no tolerance anywhere here).
fn assert_reports_identical(a: &CvReport, b: &CvReport, tag: &str) {
    assert_eq!(a.selected_lambda, b.selected_lambda, "{tag}: selected λ");
    assert_eq!(a.total_iterations, b.total_iterations, "{tag}: iteration totals");
    assert_eq!(a.points.len(), b.points.len(), "{tag}");
    for (pa, pb) in a.points.iter().zip(&b.points) {
        assert_eq!(pa.lambda, pb.lambda, "{tag}");
        assert_eq!(pa.fold_errors, pb.fold_errors, "{tag}: λ={} errors", pa.lambda);
        assert_eq!(pa.fold_aucs, pb.fold_aucs, "{tag}: λ={} AUCs", pa.lambda);
        assert_eq!(pa.fold_precisions, pb.fold_precisions, "{tag}: λ={}", pa.lambda);
        assert_eq!(pa.fold_iterations, pb.fold_iterations, "{tag}: λ={}", pa.lambda);
        assert_eq!(pa.fold_weights, pb.fold_weights, "{tag}: λ={} fold models", pa.lambda);
        assert_eq!(pa.mean_error.to_bits(), pb.mean_error.to_bits(), "{tag}");
        assert_eq!(pa.mean_auc.to_bits(), pb.mean_auc.to_bits(), "{tag}");
        assert_eq!(
            pa.mean_precision_at_k.to_bits(),
            pb.mean_precision_at_k.to_bits(),
            "{tag}"
        );
    }
}

#[test]
fn parallel_sweep_is_bit_identical_to_serial_at_any_thread_count() {
    let grouped = synthetic::queries(9, 8, 4, 3);
    let global = synthetic::cadata_like(150, 11);
    let lambdas = vec![1e-3, 1e-1, 1e-2]; // deliberately unsorted input order
    for (ds, tag) in [(&grouped, "grouped"), (&global, "global")] {
        for warm in [true, false] {
            let base = TrainConfig { method: Method::Tree, ..Default::default() };
            let cfg =
                CvConfig { warm_start: warm, ..CvConfig::new(base, lambdas.clone(), 3, 5) };
            let reference = cv_serial(ds, &cfg).unwrap();
            for threads in [1usize, 2, 8] {
                let tcfg = CvConfig {
                    base: TrainConfig { n_threads: threads, ..cfg.base.clone() },
                    ..cfg.clone()
                };
                let sweep = cv_sweep(ds, &tcfg).unwrap();
                assert_reports_identical(
                    &reference,
                    &sweep,
                    &format!("{tag} warm={warm} threads={threads}"),
                );
            }
        }
    }
}

#[test]
fn cold_sweep_reproduces_the_cross_validate_reference() {
    // `cross_validate` is the serial, cold, error-selected compat entry
    // point; a cold `cv_sweep` must reproduce its points exactly.
    let ds = synthetic::cadata_like(200, 4);
    let base = TrainConfig { method: Method::Tree, ..Default::default() };
    let lambdas = [1e-3, 1e-2, 1e-1];
    let reference = cross_validate(&ds, &base, &lambdas, 3, 11).unwrap();
    let cfg = CvConfig {
        warm_start: false,
        ..CvConfig::new(
            TrainConfig { n_threads: 4, ..base },
            lambdas.to_vec(),
            3,
            11,
        )
    };
    let sweep = cv_sweep(&ds, &cfg).unwrap();
    assert_eq!(reference.len(), sweep.points.len());
    for (pa, pb) in reference.iter().zip(&sweep.points) {
        assert_eq!(pa.lambda, pb.lambda);
        assert_eq!(pa.fold_errors, pb.fold_errors);
        assert_eq!(pa.fold_weights, pb.fold_weights);
        assert_eq!(pa.iterations, pb.iterations);
    }
}

// ----------------------------------------------- warm ≡ cold answers

/// The warm-start differential on a 4-point path: same selected λ,
/// ε-close held-out metrics, strictly fewer total solver iterations.
/// Iteration totals come from the reports (deterministic per run), not
/// from the process-global counters — other tests in this binary touch
/// those concurrently.
fn warm_cold_differential(ds: &Dataset, tag: &str) {
    let lambdas = vec![0.3, 0.1, 0.03, 0.01];
    let base = TrainConfig { method: Method::Tree, ..Default::default() };
    let warm_cfg = CvConfig::new(base, lambdas, 3, 9);
    let cold_cfg = CvConfig { warm_start: false, ..warm_cfg.clone() };
    let warm = cv_serial(ds, &warm_cfg).unwrap();
    let cold = cv_serial(ds, &cold_cfg).unwrap();

    assert_eq!(
        warm.selected_lambda, cold.selected_lambda,
        "{tag}: warm and cold paths must select the same λ"
    );
    for (pw, pc) in warm.points.iter().zip(&cold.points) {
        assert_eq!(pw.lambda, pc.lambda);
        // Both runs are ε-optimal for the same objective, so held-out
        // metrics agree to well within the BMRM tolerance's effect.
        assert!(
            (pw.mean_error - pc.mean_error).abs() < 0.05,
            "{tag}: λ={}: warm error {} vs cold {}",
            pw.lambda,
            pw.mean_error,
            pc.mean_error
        );
        assert!(
            (pw.mean_auc - pc.mean_auc).abs() < 0.05,
            "{tag}: λ={}: warm AUC {} vs cold {}",
            pw.lambda,
            pw.mean_auc,
            pc.mean_auc
        );
    }
    assert!(
        warm.total_iterations < cold.total_iterations,
        "{tag}: warm path must be strictly cheaper: warm {} vs cold {}",
        warm.total_iterations,
        cold.total_iterations
    );
}

#[test]
fn warm_path_matches_cold_with_fewer_iterations_global() {
    warm_cold_differential(&synthetic::cadata_like(300, 8), "global");
}

#[test]
fn warm_path_matches_cold_with_fewer_iterations_grouped() {
    warm_cold_differential(&synthetic::queries(10, 10, 4, 1), "grouped");
}

#[test]
fn cv_counters_are_monotone() {
    // The process-global telemetry counters are shared across the whole
    // test binary, so only monotonicity is assertable here; exact
    // warm-vs-cold accounting lives in the differential above.
    let before = (CV_SWEEPS.get(), CV_FOLD_TRAININGS.get(), CV_BMRM_ITERS.get());
    let ds = synthetic::cadata_like(80, 2);
    let base = TrainConfig { method: Method::Tree, ..Default::default() };
    let cfg = CvConfig::new(base, vec![1e-2, 1e-1], 2, 3);
    let report = cv_serial(&ds, &cfg).unwrap();
    assert!(report.total_iterations > 0);
    assert!(CV_SWEEPS.get() >= before.0 + 1);
    assert!(CV_FOLD_TRAININGS.get() >= before.1 + 4, "2 folds × 2 λ");
    assert!(CV_BMRM_ITERS.get() >= before.2 + report.total_iterations as u64);
}

// ------------------------------------------------ bounded-memory CV

/// Regression for the owned per-fold dataset copies the first CV
/// implementation made: fold views are row-index views into the one
/// mmap'd store, so a CV sweep's peak RSS must stay close to a plain
/// single training's — an engine that gathered k-1 train folds (×
/// concurrent fold chains) would blow well past the payload-sized
/// slack this asserts.
#[test]
fn cv_of_a_store_is_bounded_memory() {
    let Ok(bin) = memprobe::find_cli_bin() else {
        eprintln!("skipping: ranksvm binary not built (cargo build --release)");
        return;
    };
    let ds = synthetic::reuters_like_with(40_000, 4000, 30, 17);
    let text = tmp("cvmem.libsvm");
    libsvm::write(&ds, &text).unwrap();
    let pst = tmp("cvmem.pstore");
    convert_libsvm(&text, &pst, &ConvertOptions::default()).unwrap();
    let payload_kib = std::fs::metadata(&pst).unwrap().len() / 1024;

    let probe = |extra: &[&str]| -> u64 {
        let mut args = vec![
            "mem-probe",
            "--data",
            pst.to_str().unwrap(),
            "--method",
            "tree",
            "--max-iter",
            "5",
            "--no-verify",
        ];
        args.extend_from_slice(extra);
        let out = std::process::Command::new(&bin).args(&args).output().expect("spawn ranksvm");
        assert!(
            out.status.success(),
            "ranksvm {args:?} failed: {}",
            String::from_utf8_lossy(&out.stderr)
        );
        let stdout = String::from_utf8_lossy(&out.stdout).to_string();
        memprobe::parse_peak(&stdout).unwrap_or_else(|| panic!("no peak in: {stdout}"))
    };

    let train_peak = probe(&[]);
    let cv_peak = probe(&["--cv", "--lambdas", "1e-2,1e-1", "--folds", "3"]);
    // O(m + dim) fold state, never O(nnz): half a payload of slack
    // absorbs allocator noise while still catching fold copies (which
    // would cost ≥ (k-1)/k of the payload per concurrent chain).
    assert!(
        cv_peak < train_peak + payload_kib / 2 + 4096,
        "CV peak {cv_peak} KiB vs train peak {train_peak} KiB \
         (payload {payload_kib} KiB) — per-fold dataset copies are back?"
    );
}
