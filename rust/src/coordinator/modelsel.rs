//! Model selection: a parallel, warm-started k-fold sweep over the
//! regularization path.
//!
//! The paper fixes λ per dataset ("observed to lead to good test
//! performance"); a framework user needs the machinery that produces
//! such a choice. This module runs the full k-fold × λ grid as one task
//! set on the shared [`runtime::pool::WorkerPool`](crate::runtime::pool):
//! each *fold chain* (one fold, every λ) is a pool task, and within a
//! chain the λ path is walked in **descending order** with the previous
//! point's cutting-plane bundle warm-starting the next
//! ([`bmrm::optimize_warm`] — see its convergence contract: warm and
//! cold starts reach the same ε-optimum, warm just gets there with
//! fewer oracle calls).
//!
//! # Zero-copy folds
//!
//! Fold construction never copies the dataset. A fold is a list of row
//! indices into the one (possibly memory-mapped) [`DatasetView`]; the
//! fold oracle scores held-in rows by per-row dot products on the
//! borrowed [`CsrView`] and scatters subgradients row-by-row, so CV of
//! a larger-than-RAM `.pstore` stays bounded-memory (the only per-fold
//! allocations are gathered label/qid vectors and the weight/plane
//! dense vectors — all `O(m + dim)`, never `O(nnz)`).
//!
//! The one documented exception: Newton-family losses (`prsvm`,
//! `prsvm-tree`) run through a compute backend that consumes the real
//! feature matrix, so their chains gather one owned train-fold
//! `Dataset` each ("materialized pairs" already dwarf that copy). Their
//! warm start seeds `w₀` from the previous λ's solution instead of a
//! cutting-plane bundle.
//!
//! # Determinism
//!
//! The sweep obeys the bit-identity contract (docs/DETERMINISM.md):
//! fold chains are independent tasks writing disjoint result slots
//! (invariant 2), every float reduction inside a chain is the serial
//! trainer's own, and assembly walks slots in input-λ order — so
//! [`cv_sweep`] at any thread count produces bytes identical to
//! [`cv_serial`], which `tests/modelsel.rs` and the CI cv-matrix leg
//! pin. Query-grouped data is split by whole queries (splitting a query
//! across folds would leak its per-query offset).

use super::config::{Normalize, TrainConfig};
use super::trainer::{bmrm_config, newton_config, squared_oracle};
use crate::bmrm::{self, Bundle, ScoreOracle};
use crate::compute::ParallelBackend;
use crate::data::{Dataset, DatasetRef, DatasetView};
use crate::linalg::{simd, CsrMatrix, CsrView};
use crate::losses::registry::OracleCtx;
use crate::losses::{count_comparable_pairs, GroupIndex, RankingOracle};
use crate::metrics;
use crate::newton;
use crate::obs;
use crate::runtime::{Task, WorkerPool};
use crate::util::rng::Rng;
use anyhow::{ensure, Result};
use std::sync::Arc;

/// Which per-fold metric [`select_by_metric`] optimizes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CvMetric {
    /// Mean pairwise ranking error (eq. 1) — minimized. The default.
    Error,
    /// Mean AUC (grouped: per-query Wilcoxon) — maximized.
    Auc,
    /// Mean precision@k — maximized.
    PrecisionAtK,
}

impl CvMetric {
    /// Parse a CLI spelling.
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "error" => CvMetric::Error,
            "auc" => CvMetric::Auc,
            "precision" | "precision-at-k" | "p@k" => CvMetric::PrecisionAtK,
            other => anyhow::bail!(
                "unknown CV metric {other:?} (expected error | auc | precision)"
            ),
        })
    }

    /// Canonical report name.
    pub fn name(self) -> &'static str {
        match self {
            CvMetric::Error => "error",
            CvMetric::Auc => "auc",
            CvMetric::PrecisionAtK => "precision_at_k",
        }
    }
}

/// Full configuration of a CV sweep.
#[derive(Clone, Debug)]
pub struct CvConfig {
    /// Everything but λ (method, ε, iteration cap, threads, …).
    pub base: TrainConfig,
    /// The λ grid, in the caller's order (the report preserves it).
    pub lambdas: Vec<f64>,
    pub folds: usize,
    /// Fold-split seed ([`kfold_indices`]).
    pub seed: u64,
    /// Warm-start each λ from the previous point on the sorted path.
    /// Off reproduces independent cold trainings (the differential
    /// tests compare both modes).
    pub warm_start: bool,
    /// Selection criterion for [`CvReport::selected_lambda`].
    pub metric: CvMetric,
    /// `k` for the precision@k column.
    pub k: usize,
}

impl CvConfig {
    /// Sweep defaults on top of a base training config.
    pub fn new(base: TrainConfig, lambdas: Vec<f64>, folds: usize, seed: u64) -> Self {
        CvConfig { base, lambdas, folds, seed, warm_start: true, metric: CvMetric::Error, k: 10 }
    }
}

/// One λ row of a CV sweep: per-fold metrics plus their means. Fold
/// vectors are indexed by fold id; `fold_weights` keeps the trained
/// fold models so differential tests can byte-compare them (the CLI
/// report omits them).
#[derive(Clone, Debug)]
pub struct CvPoint {
    pub lambda: f64,
    pub fold_errors: Vec<f64>,
    pub fold_aucs: Vec<f64>,
    pub fold_precisions: Vec<f64>,
    /// Solver iterations each fold spent on this λ (BMRM oracle calls
    /// or Newton steps) — the warm-start savings ledger.
    pub fold_iterations: Vec<usize>,
    pub fold_weights: Vec<Vec<f64>>,
    pub mean_error: f64,
    pub mean_auc: f64,
    pub mean_precision_at_k: f64,
    /// Total solver iterations across folds for this λ.
    pub iterations: usize,
}

/// What a sweep returns: one [`CvPoint`] per λ in input order, the
/// winning λ under the configured metric, and the sweep-wide iteration
/// total (the quantity warm-starting shrinks).
#[derive(Clone, Debug)]
pub struct CvReport {
    pub points: Vec<CvPoint>,
    pub selected_lambda: f64,
    pub total_iterations: usize,
}

/// Deterministic k-fold index split. Grouped data splits by distinct
/// qid so every query stays whole. The assignment is a pure function of
/// `(m or qid multiset, folds, seed)` — byte-stable across platforms
/// and releases, pinned by a recorded fixture in `tests/modelsel.rs`.
pub fn kfold_indices(ds: &dyn DatasetView, folds: usize, seed: u64) -> Vec<Vec<usize>> {
    assert!(folds >= 2, "need at least 2 folds");
    let mut rng = Rng::new(seed);
    match ds.qid() {
        None => {
            let mut idx: Vec<usize> = (0..ds.len()).collect();
            rng.shuffle(&mut idx);
            let mut out = vec![Vec::new(); folds];
            for (i, &e) in idx.iter().enumerate() {
                out[i % folds].push(e);
            }
            out
        }
        Some(qid) => {
            let mut queries: Vec<u64> = {
                let mut q = qid.to_vec();
                q.sort_unstable();
                q.dedup();
                q
            };
            rng.shuffle(&mut queries);
            let mut fold_of: std::collections::HashMap<u64, usize> =
                std::collections::HashMap::new();
            for (i, &q) in queries.iter().enumerate() {
                fold_of.insert(q, i % folds);
            }
            let mut out = vec![Vec::new(); folds];
            for (i, q) in qid.iter().enumerate() {
                out[fold_of[q]].push(i);
            }
            out
        }
    }
}

/// Everything one (fold, λ) cell produces, in sorted-path order.
struct FoldCell {
    error: f64,
    auc: f64,
    precision: f64,
    iterations: usize,
    w: Vec<f64>,
}

/// Zero-copy BMRM fold oracle: scores and gradients touch only the
/// train rows of the shared matrix view, by index; risk delegates to
/// the registry-built score-space oracle over the gathered fold labels.
struct FoldOracle<'a> {
    x: CsrView<'a>,
    rows: &'a [usize],
    inner: Box<dyn RankingOracle>,
    y: &'a [f64],
    n_pairs: f64,
    dim: usize,
}

impl ScoreOracle for FoldOracle<'_> {
    fn dim(&self) -> usize {
        self.dim
    }
    fn scores(&mut self, w: &[f64]) -> Vec<f64> {
        self.rows.iter().map(|&r| self.x.row_dot(r, w)).collect()
    }
    fn risk_at(&mut self, p: &[f64]) -> (f64, Vec<f64>) {
        let out = self.inner.eval(p, self.y, self.n_pairs);
        (out.loss, out.coeffs)
    }
    fn grad(&mut self, coeffs: &[f64]) -> Vec<f64> {
        let kern = simd::active();
        let mut out = vec![0.0; self.dim];
        for (i, &r) in self.rows.iter().enumerate() {
            if coeffs[i] != 0.0 {
                let (idx, val) = self.x.row(r);
                simd::scatter_axpy(kern, idx, val, coeffs[i], &mut out);
            }
        }
        out
    }
}

/// Validated sweep plan: the fold split and the (input slot, λ) path
/// sorted by descending λ (strong regularization first — the classical
/// warm-start direction: each solution is a good bundle/seed for the
/// slightly less constrained next problem).
struct CvPrep {
    fold_idx: Vec<Vec<usize>>,
    path: Vec<(usize, f64)>,
}

fn prep(cfg: &CvConfig) -> Result<()> {
    ensure!(cfg.folds >= 2, "cv needs at least 2 folds, got {}", cfg.folds);
    ensure!(!cfg.lambdas.is_empty(), "cv needs at least one lambda");
    for &l in &cfg.lambdas {
        ensure!(l.is_finite() && l > 0.0, "cv lambdas must be finite and positive, got {l}");
    }
    ensure!(
        matches!(cfg.base.normalize, Normalize::None),
        "cv does not support --normalize: fold views are zero-copy index views, \
         so normalize the input once (`ranksvm convert` a normalized store) instead"
    );
    Ok(())
}

fn plan(ds: &dyn DatasetView, cfg: &CvConfig) -> Result<CvPrep> {
    prep(cfg)?;
    let mut path: Vec<(usize, f64)> = cfg.lambdas.iter().copied().enumerate().collect();
    path.sort_by(|a, b| b.1.total_cmp(&a.1));
    Ok(CvPrep { fold_idx: kfold_indices(ds, cfg.folds, cfg.seed), path })
}

/// Gather an owned train-fold dataset (the Newton-family exception to
/// the zero-copy rule — see the module docs).
fn gather_dataset(
    x: CsrView<'_>,
    y: Vec<f64>,
    qid: Option<Vec<u64>>,
    rows: &[usize],
    dim: usize,
    name: String,
) -> Dataset {
    let mut triplets = Vec::new();
    for (rn, &r) in rows.iter().enumerate() {
        let (idx, val) = x.row(r);
        for (&c, &v) in idx.iter().zip(val) {
            triplets.push((rn, c as usize, v));
        }
    }
    Dataset::new(CsrMatrix::from_triplets(rows.len(), dim, triplets), y, qid, name)
}

/// Train one fold across the whole sorted λ path, warm-starting each
/// point from the previous one. This is the unit of parallelism — both
/// engines call exactly this function, which is what makes
/// [`cv_sweep`] bit-identical to [`cv_serial`].
fn run_fold_chain(
    x: CsrView<'_>,
    y: &[f64],
    qid: Option<&[u64]>,
    cfg: &CvConfig,
    fold_idx: &[Vec<usize>],
    f: usize,
    lambdas_desc: &[f64],
) -> Vec<FoldCell> {
    let test_rows: &[usize] = &fold_idx[f];
    let train_rows: Vec<usize> = (0..fold_idx.len())
        .filter(|&g| g != f)
        .flat_map(|g| fold_idx[g].iter().copied())
        .collect();
    let dim = x.cols();

    // Gathered fold-local labels/groups: the only per-fold copies
    // (`O(m)`), features stay borrowed row-index views.
    let y_tr: Vec<f64> = train_rows.iter().map(|&r| y[r]).collect();
    let qid_tr: Option<Vec<u64>> = qid.map(|q| train_rows.iter().map(|&r| q[r]).collect());
    let y_te: Vec<f64> = test_rows.iter().map(|&r| y[r]).collect();
    let qid_te: Option<Vec<u64>> = qid.map(|q| test_rows.iter().map(|&r| q[r]).collect());

    let measure = |w: &[f64], iterations: usize| -> FoldCell {
        let p: Vec<f64> = test_rows.iter().map(|&r| x.row_dot(r, w)).collect();
        let (error, auc, precision) = match &qid_te {
            Some(q) => (
                metrics::grouped_pairwise_error(&p, &y_te, q),
                metrics::grouped_auc(&p, &y_te, q),
                metrics::grouped_precision_at_k(&p, &y_te, q, cfg.k, 0.0),
            ),
            None => (
                metrics::pairwise_error(&p, &y_te),
                metrics::auc(&p, &y_te),
                metrics::precision_at_k(&p, &y_te, cfg.k, 0.0),
            ),
        };
        FoldCell { error, auc, precision, iterations, w: w.to_vec() }
    };

    let mut cells = Vec::with_capacity(lambdas_desc.len());

    if train_rows.is_empty() {
        // Degenerate split (e.g. fewer queries than folds leaves a fold
        // holding everything): nothing to train on — the zero model
        // scores the held-out rows at every λ.
        let w = vec![0.0; dim];
        for _ in lambdas_desc {
            obs::metrics::CV_FOLD_TRAININGS.inc();
            cells.push(measure(&w, 0));
        }
        return cells;
    }

    let spec = cfg.base.method.spec();
    if let Some(kind) = spec.newton {
        let owned = gather_dataset(x, y_tr, qid_tr, &train_rows, dim, format!("cv{f}train"));
        let chain_pool = Arc::new(WorkerPool::new(1));
        let backend = Box::new(ParallelBackend::with_pool(Arc::clone(&chain_pool)));
        let mut oracle = squared_oracle(kind, &owned, backend);
        let mut w_prev: Option<Vec<f64>> = None;
        for &lambda in lambdas_desc {
            let tcfg = TrainConfig { lambda, ..cfg.base.clone() };
            let ncfg = newton_config(&tcfg);
            let w0 = match (&w_prev, cfg.warm_start) {
                (Some(w), true) => w.clone(),
                _ => vec![0.0; dim],
            };
            let res = newton::optimize(&mut oracle, &ncfg, w0);
            obs::metrics::CV_FOLD_TRAININGS.inc();
            cells.push(measure(&res.w, res.iterations));
            w_prev = Some(res.w);
        }
        return cells;
    }

    // BMRM family: registry ctors consume only labels/group structure
    // (never `ds.x()` — their oracles live in score space), so an empty
    // matrix view over the gathered fold labels is a sound context.
    let zero_indptr = vec![0u64; y_tr.len() + 1];
    let fctx = DatasetRef {
        x: CsrView::new_unchecked(y_tr.len(), dim, &zero_indptr, &[], &[]),
        y: &y_tr,
        qid: qid_tr.as_deref(),
        name: format!("cv{f}train"),
    };
    let index = fctx.qid.map(|q| Arc::new(GroupIndex::build(q, &y_tr)));
    let n_pairs = match &index {
        Some(gi) => gi.total_pairs(),
        None => count_comparable_pairs(&y_tr) as f64,
    };
    // A chain is itself a pool task, and `WorkerPool::run` is
    // non-reentrant — so the oracle gets its own inline (0-worker)
    // pool rather than the sweep's.
    let chain_pool = Arc::new(WorkerPool::new(1));
    let ctor = spec.bmrm.expect("non-Newton registry losses carry a BMRM oracle constructor");
    let inner = ctor(OracleCtx { ds: &fctx, index, pool: &chain_pool });
    let mut oracle =
        FoldOracle { x, rows: &train_rows, inner, y: &y_tr, n_pairs, dim };
    let mut bundle: Option<Bundle> = None;
    for &lambda in lambdas_desc {
        let tcfg = TrainConfig { lambda, ..cfg.base.clone() };
        let bcfg = bmrm_config(&tcfg);
        let warm = if cfg.warm_start { bundle.as_ref() } else { None };
        let (res, grown) = bmrm::optimize_warm(&mut oracle, &bcfg, vec![0.0; dim], warm);
        obs::metrics::CV_FOLD_TRAININGS.inc();
        obs::metrics::CV_BMRM_ITERS.add(res.iterations as u64);
        cells.push(measure(&res.w, res.iterations));
        bundle = Some(grown);
    }
    cells
}

/// Stitch per-fold chains back into input-λ-ordered [`CvPoint`]s and
/// pick the winner. Pure serial assembly, identical for both engines.
fn assemble(cfg: &CvConfig, prep: &CvPrep, mut per_fold: Vec<Vec<FoldCell>>) -> CvReport {
    let folds = cfg.folds;
    let mut points: Vec<Option<CvPoint>> = (0..cfg.lambdas.len()).map(|_| None).collect();
    for (pos, &(slot, lambda)) in prep.path.iter().enumerate() {
        let mut fold_errors = Vec::with_capacity(folds);
        let mut fold_aucs = Vec::with_capacity(folds);
        let mut fold_precisions = Vec::with_capacity(folds);
        let mut fold_iterations = Vec::with_capacity(folds);
        let mut fold_weights = Vec::with_capacity(folds);
        for chain in per_fold.iter_mut() {
            let cell = &mut chain[pos];
            fold_errors.push(cell.error);
            fold_aucs.push(cell.auc);
            fold_precisions.push(cell.precision);
            fold_iterations.push(cell.iterations);
            fold_weights.push(std::mem::take(&mut cell.w));
        }
        let mean = |v: &[f64]| v.iter().sum::<f64>() / folds as f64;
        let iterations = fold_iterations.iter().sum();
        points[slot] = Some(CvPoint {
            lambda,
            mean_error: mean(&fold_errors),
            mean_auc: mean(&fold_aucs),
            mean_precision_at_k: mean(&fold_precisions),
            fold_errors,
            fold_aucs,
            fold_precisions,
            fold_iterations,
            fold_weights,
            iterations,
        });
    }
    let points: Vec<CvPoint> =
        points.into_iter().map(|p| p.expect("every path slot assembled")).collect();
    let selected_lambda = select_by_metric(&points, cfg.metric);
    let total_iterations = points.iter().map(|p| p.iterations).sum();
    CvReport { points, selected_lambda, total_iterations }
}

/// Serial reference engine: fold chains run one after another on the
/// calling thread. The parallel engine is defined to match this
/// bit-for-bit.
pub fn cv_serial(ds: &dyn DatasetView, cfg: &CvConfig) -> Result<CvReport> {
    let prep = plan(ds, cfg)?;
    obs::metrics::CV_SWEEPS.inc();
    ds.prefetch();
    let (x, y, qid) = (ds.x(), ds.y(), ds.qid());
    let lambdas_desc: Vec<f64> = prep.path.iter().map(|&(_, l)| l).collect();
    let per_fold: Vec<Vec<FoldCell>> = (0..cfg.folds)
        .map(|f| run_fold_chain(x, y, qid, cfg, &prep.fold_idx, f, &lambdas_desc))
        .collect();
    Ok(assemble(cfg, &prep, per_fold))
}

/// Parallel sweep engine: one pool task per fold chain, disjoint result
/// slots, input-order assembly — bit-identical to [`cv_serial`] at any
/// `--threads` (docs/DETERMINISM.md; pinned by `tests/modelsel.rs` and
/// the CI cv-matrix leg).
pub fn cv_sweep(ds: &dyn DatasetView, cfg: &CvConfig) -> Result<CvReport> {
    let prep = plan(ds, cfg)?;
    obs::metrics::CV_SWEEPS.inc();
    ds.prefetch();
    let (x, y, qid) = (ds.x(), ds.y(), ds.qid());
    let lambdas_desc: Vec<f64> = prep.path.iter().map(|&(_, l)| l).collect();
    let pool = WorkerPool::new(cfg.base.resolved_threads());
    let mut slots: Vec<Option<Vec<FoldCell>>> = (0..cfg.folds).map(|_| None).collect();
    {
        let fold_idx = &prep.fold_idx;
        let lambdas_desc = &lambdas_desc;
        let cfg_ref = &*cfg;
        let tasks: Vec<Task<'_>> = slots
            .iter_mut()
            .enumerate()
            .map(|(f, slot)| {
                let task: Task<'_> = Box::new(move || {
                    *slot = Some(run_fold_chain(x, y, qid, cfg_ref, fold_idx, f, lambdas_desc));
                });
                task
            })
            .collect();
        pool.run(tasks);
    }
    let per_fold: Vec<Vec<FoldCell>> =
        slots.into_iter().map(|s| s.expect("every fold task ran")).collect();
    Ok(assemble(cfg, &prep, per_fold))
}

/// Compatibility sweep: serial, cold-started, error-selected — one
/// [`CvPoint`] per λ in input order. The differential battery uses this
/// as the reference the parallel warm engine must reproduce point-wise.
pub fn cross_validate(
    ds: &dyn DatasetView,
    base: &TrainConfig,
    lambdas: &[f64],
    folds: usize,
    seed: u64,
) -> Result<Vec<CvPoint>> {
    let cfg = CvConfig {
        warm_start: false,
        ..CvConfig::new(base.clone(), lambdas.to_vec(), folds, seed)
    };
    Ok(cv_serial(ds, &cfg)?.points)
}

/// Pick the λ optimizing `metric`'s mean (error minimized, AUC and
/// precision maximized); ties → larger λ, i.e. the simpler model.
pub fn select_by_metric(points: &[CvPoint], metric: CvMetric) -> f64 {
    assert!(!points.is_empty());
    let value = |p: &CvPoint| match metric {
        CvMetric::Error => p.mean_error,
        CvMetric::Auc => p.mean_auc,
        CvMetric::PrecisionAtK => p.mean_precision_at_k,
    };
    let better = |a: f64, b: f64| match metric {
        CvMetric::Error => a < b - 1e-12,
        CvMetric::Auc | CvMetric::PrecisionAtK => a > b + 1e-12,
    };
    let mut best = &points[0];
    for p in points {
        let (v, bv) = (value(p), value(best));
        if better(v, bv) || ((v - bv).abs() <= 1e-12 && p.lambda > best.lambda) {
            best = p;
        }
    }
    best.lambda
}

/// Pick the λ minimizing mean CV error (ties → larger λ, i.e. the
/// simpler model). Equivalent to [`select_by_metric`] with
/// [`CvMetric::Error`].
pub fn select_lambda(points: &[CvPoint]) -> f64 {
    select_by_metric(points, CvMetric::Error)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Method;
    use crate::data::synthetic;

    #[test]
    fn kfold_partitions_everything_once() {
        let ds = synthetic::cadata_like(103, 3);
        let folds = kfold_indices(&ds, 5, 1);
        let mut all: Vec<usize> = folds.iter().flatten().copied().collect();
        all.sort_unstable();
        assert_eq!(all, (0..103).collect::<Vec<_>>());
        // balanced within 1
        let sizes: Vec<usize> = folds.iter().map(|f| f.len()).collect();
        assert!(sizes.iter().max().unwrap() - sizes.iter().min().unwrap() <= 1);
    }

    #[test]
    fn grouped_kfold_keeps_queries_whole() {
        let ds = synthetic::queries(12, 10, 4, 5);
        let folds = kfold_indices(&ds, 3, 2);
        let qid = ds.qid.as_ref().unwrap();
        for fold in &folds {
            let qs: std::collections::HashSet<u64> = fold.iter().map(|&i| qid[i]).collect();
            // every query in this fold must be fully contained here
            for q in qs {
                let total = qid.iter().filter(|&&x| x == q).count();
                let here = fold.iter().filter(|&&i| qid[i] == q).count();
                assert_eq!(total, here, "query {q} split across folds");
            }
        }
    }

    #[test]
    fn cv_selects_reasonable_lambda() {
        let ds = synthetic::cadata_like(400, 8);
        let base = TrainConfig { method: Method::Tree, ..Default::default() };
        let lambdas = [1e-3, 1e-1, 1e3];
        let points = cross_validate(&ds, &base, &lambdas, 3, 7).unwrap();
        assert_eq!(points.len(), 3);
        let best = select_lambda(&points);
        // Over-regularization hurts (ranking is scale-invariant, so the
        // damage is under-fitting of the direction, not w → 0): the
        // degenerate λ must not win and the winner must actually rank.
        assert!(best < 1e3, "CV picked the degenerate λ: {points:?}");
        let worst = points.iter().find(|p| p.lambda == 1e3).unwrap();
        let chosen = points.iter().find(|p| p.lambda == best).unwrap();
        assert!(
            worst.mean_error > chosen.mean_error + 0.05,
            "λ=1e3 should clearly underperform: {points:?}"
        );
        assert!(chosen.mean_error < 0.25, "winner should rank well: {points:?}");
        // The derived columns came along for every point.
        for p in &points {
            assert_eq!(p.fold_errors.len(), 3);
            assert_eq!(p.fold_aucs.len(), 3);
            assert_eq!(p.fold_weights.len(), 3);
            assert!((p.mean_auc - (1.0 - p.mean_error)).abs() < 1e-12);
        }
    }

    fn point(lambda: f64, mean_error: f64, mean_auc: f64) -> CvPoint {
        CvPoint {
            lambda,
            fold_errors: vec![mean_error],
            fold_aucs: vec![mean_auc],
            fold_precisions: vec![0.5],
            fold_iterations: vec![1],
            fold_weights: vec![vec![0.0]],
            mean_error,
            mean_auc,
            mean_precision_at_k: 0.5,
            iterations: 1,
        }
    }

    #[test]
    fn select_lambda_tie_breaks_to_simpler() {
        let points = vec![point(0.01, 0.2, 0.8), point(1.0, 0.2, 0.8)];
        assert_eq!(select_lambda(&points), 1.0);
    }

    #[test]
    fn select_by_metric_maximizes_auc() {
        let points = vec![point(0.01, 0.3, 0.9), point(1.0, 0.2, 0.7)];
        assert_eq!(select_by_metric(&points, CvMetric::Error), 1.0);
        assert_eq!(select_by_metric(&points, CvMetric::Auc), 0.01);
    }

    #[test]
    fn cv_rejects_bad_grids() {
        let ds = synthetic::cadata_like(30, 3);
        let base = TrainConfig { method: Method::Tree, ..Default::default() };
        let bad = CvConfig::new(base.clone(), vec![], 3, 1);
        assert!(cv_serial(&ds, &bad).is_err());
        let bad = CvConfig::new(base.clone(), vec![0.0], 3, 1);
        assert!(cv_serial(&ds, &bad).is_err());
        let bad = CvConfig::new(base.clone(), vec![0.1], 1, 1);
        assert!(cv_serial(&ds, &bad).is_err());
        let bad = CvConfig {
            base: TrainConfig {
                normalize: Normalize::L2Col,
                method: Method::Tree,
                ..Default::default()
            },
            ..CvConfig::new(base, vec![0.1], 3, 1)
        };
        assert!(cv_serial(&ds, &bad).is_err());
    }
}
