//! Skew balance — the work-stealing scheduler's reason to exist.
//!
//! Fixture: query-grouped data with Zipf(1.1) group sizes (one giant
//! group, a long singleton tail — `synthetic::zipf_queries`). The
//! coarse plan (one task per worker, the PR 1–3 decomposition,
//! reproduced via `with_run_target(…, n_threads)`) serializes each
//! oracle call behind the giant group's owner; the fine default plan
//! (bounded `WorkPlan` group runs, stealable) lets idle workers drain
//! the tail while one worker chews the giant. Both are bit-identical to
//! the serial grouped oracle (asserted here on the first eval); the
//! table shows what the plan costs in wall-clock.
//!
//! The pool's executed/stolen task counters (always on since the
//! telemetry layer — docs/OBSERVABILITY.md) are printed per plan, and
//! on a multi-thread host the bench asserts the fine plan actually
//! steals. The tracked snapshot `BENCH_skew_balance.json` is written
//! through the shared envelope; `RANKSVM_SNAPSHOT_SCHEMA_ONLY=1`
//! emits the placeholder schema and exits.

mod common;

use common::{fmt_secs, full_scale, header, record};
use ranksvm::data::synthetic;
use ranksvm::losses::{QueryGrouped, RankingOracle, ShardedTreeOracle, TreeOracle};
use ranksvm::runtime::WorkerPool;
use ranksvm::util::json::Json;
use ranksvm::util::rng::Rng;
use std::sync::Arc;

fn avg_eval(oracle: &mut dyn RankingOracle, p: &[f64], y: &[f64], reps: usize) -> f64 {
    std::hint::black_box(oracle.eval(p, y, 0.0)); // warmup
    let t = std::time::Instant::now();
    for _ in 0..reps {
        std::hint::black_box(oracle.eval(p, y, 0.0));
    }
    t.elapsed().as_secs_f64() / reps as f64
}

/// Snapshot fixture parameters (key set is part of the schema gate).
/// `kernel` records the resolved compute-kernel dispatch the timings
/// ran on (docs/OBSERVABILITY.md "Kernel dispatch").
fn params(m: usize, groups: usize, threads: usize, reps: usize) -> Json {
    Json::obj(vec![
        ("m", m.into()),
        ("groups", groups.into()),
        ("threads", threads.into()),
        ("reps", reps.into()),
        ("kernel", ranksvm::linalg::simd::active().name().into()),
    ])
}

/// One snapshot metric row (null values in schema-only mode).
#[allow(clippy::too_many_arguments)]
fn metric_row(
    serial_secs: Json,
    coarse_secs: Json,
    fine_secs: Json,
    coarse_runs: Json,
    fine_runs: Json,
    coarse_stolen: Json,
    fine_stolen: Json,
) -> Json {
    Json::obj(vec![
        ("serial_secs", serial_secs),
        ("coarse_secs", coarse_secs),
        ("fine_secs", fine_secs),
        ("coarse_runs", coarse_runs),
        ("fine_runs", fine_runs),
        ("coarse_stolen", coarse_stolen),
        ("fine_stolen", fine_stolen),
    ])
}

fn main() {
    let threads = ranksvm::util::resolve_threads(0);
    let (m, reps) = if full_scale() { (400_000, 5) } else { (60_000, 5) };
    let n_groups = m / 8;
    if common::schema_only() {
        let n = || Json::Null;
        common::write_snapshot(
            "skew_balance",
            true,
            params(m, n_groups, threads, reps),
            vec![metric_row(n(), n(), n(), n(), n(), n(), n())],
        );
        return;
    }
    let ds = synthetic::zipf_queries(m, n_groups, 10, 1.1, 42);
    let qid = ds.qid.as_ref().unwrap();
    let mut sizes = vec![0usize; n_groups];
    for &g in qid.iter() {
        sizes[g as usize] += 1;
    }
    let giant = *sizes.iter().max().unwrap();
    let singletons = sizes.iter().filter(|&&s| s == 1).count();

    header(&format!(
        "Skew balance: Zipf(1.1) group sizes, m = {m}, {n_groups} groups \
         (largest {giant}, {singletons} singletons), {threads} threads"
    ));

    let mut rng = Rng::new(7);
    let p: Vec<f64> = (0..m).map(|_| rng.normal()).collect();

    let pool = Arc::new(WorkerPool::new(threads));
    let mut serial = QueryGrouped::new(TreeOracle::new(), qid, &ds.y);
    let mut coarse =
        ShardedTreeOracle::with_run_target(Arc::clone(&pool), Some(qid), &ds.y, threads);
    let mut fine = ShardedTreeOracle::with_pool(Arc::clone(&pool), Some(qid), &ds.y);
    let coarse_runs = coarse.group_ranges().unwrap().len();
    let fine_runs = fine.group_ranges().unwrap().len();

    // Bit-identity sanity before timing anything.
    let expect = serial.eval(&p, &ds.y, serial.total_pairs());
    let got_coarse = coarse.eval(&p, &ds.y, 0.0);
    let got_fine = fine.eval(&p, &ds.y, 0.0);
    assert_eq!(got_coarse.coeffs, expect.coeffs, "coarse plan diverged");
    assert_eq!(got_fine.coeffs, expect.coeffs, "fine plan diverged");

    let t_serial = avg_eval(&mut serial, &p, &ds.y, reps);

    pool.reset_stats();
    let t_coarse = avg_eval(&mut coarse, &p, &ds.y, reps);
    let coarse_stats = pool.stats();

    pool.reset_stats();
    let t_fine = avg_eval(&mut fine, &p, &ds.y, reps);
    let fine_stats = pool.stats();

    println!(
        "{:>24} {:>12} {:>10} {:>10}",
        "plan", "avg eval", "tasks/call", "vs coarse"
    );
    println!("{:>24} {:>12} {:>10} {:>10}", "serial", fmt_secs(t_serial), "-", "-");
    println!(
        "{:>24} {:>12} {:>10} {:>10}",
        "coarse (1/worker)",
        fmt_secs(t_coarse),
        coarse_runs,
        "1.00×"
    );
    println!(
        "{:>24} {:>12} {:>10} {:>9.2}×",
        "fine (WorkPlan runs)",
        fmt_secs(t_fine),
        fine_runs,
        t_coarse / t_fine.max(1e-12)
    );

    println!(
        "pool stats: coarse executed {} stolen {}  |  fine executed {} stolen {}",
        coarse_stats.executed, coarse_stats.stolen, fine_stats.executed, fine_stats.stolen
    );
    if threads > 1 {
        assert!(
            fine_stats.stolen > 0,
            "fine plan produced no steals on a Zipf fixture — scheduler asleep?"
        );
    }

    let rec = vec![
        ("bench", Json::Str("skew_balance".into())),
        ("m", m.into()),
        ("groups", n_groups.into()),
        ("largest_group", giant.into()),
        ("threads", threads.into()),
        ("serial_secs", t_serial.into()),
        ("coarse_secs", t_coarse.into()),
        ("fine_secs", t_fine.into()),
        ("coarse_runs", coarse_runs.into()),
        ("fine_runs", fine_runs.into()),
        ("fine_stolen", (fine_stats.stolen as usize).into()),
        ("coarse_stolen", (coarse_stats.stolen as usize).into()),
    ];
    record("skew_balance", Json::obj(rec));

    common::write_snapshot(
        "skew_balance",
        false,
        params(m, n_groups, threads, reps),
        vec![metric_row(
            t_serial.into(),
            t_coarse.into(),
            t_fine.into(),
            coarse_runs.into(),
            fine_runs.into(),
            (coarse_stats.stolen as usize).into(),
            (fine_stats.stolen as usize).into(),
        )],
    );
}
