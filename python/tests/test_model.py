"""L2 correctness: the jitted model graphs and the Lemma-1 loss assembly."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile import model
from compile.kernels import ref


def _rng(seed):
    return np.random.default_rng(seed)


def test_scores_fn_tuple_contract():
    r = _rng(0)
    x = jnp.asarray(r.normal(size=(256, 8)).astype(np.float32))
    w = jnp.asarray(r.normal(size=(8,)).astype(np.float32))
    out = model.scores_fn(x, w)
    assert isinstance(out, tuple) and len(out) == 1
    np.testing.assert_allclose(out[0], ref.scores_ref(x, w), rtol=3e-4, atol=1e-4)


def test_grad_fn_tuple_contract():
    r = _rng(1)
    x = jnp.asarray(r.normal(size=(256, 8)).astype(np.float32))
    c = jnp.asarray(r.normal(size=(256,)).astype(np.float32))
    out = model.grad_fn(x, c)
    assert isinstance(out, tuple) and len(out) == 1
    np.testing.assert_allclose(out[0], ref.grad_ref(x, c), rtol=3e-4, atol=1e-3)


def test_pair_count_fn_two_outputs():
    r = _rng(2)
    m = 256
    p = jnp.asarray(r.normal(size=(m,)).astype(np.float32))
    y = jnp.asarray(r.normal(size=(m,)).astype(np.float32))
    v = jnp.ones((m,), jnp.float32)
    c, d = model.pair_count_fn(p, y, v)
    c2, d2 = ref.pair_count_ref(p, y, v)
    np.testing.assert_array_equal(np.asarray(c), np.asarray(c2))
    np.testing.assert_array_equal(np.asarray(d), np.asarray(d2))


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**31 - 1))
def test_lemma1_identity(seed):
    """Loss assembled from (c, d) equals the direct eq.-(4) hinge."""
    r = _rng(seed)
    m = 64
    p = jnp.asarray(r.normal(size=(m,)).astype(np.float32))
    y = jnp.asarray(r.integers(0, 6, size=(m,)).astype(np.float32))
    v = jnp.ones((m,), jnp.float32)
    c, d = model.pair_count_fn(p, y, v)
    n = float(np.sum(np.asarray(y)[:, None] < np.asarray(y)[None, :]))
    if n == 0:
        return
    inv_n = jnp.asarray(np.array([1.0 / n], np.float32))
    (loss,) = model.hinge_from_counts_fn(p, c, d, inv_n)
    direct = ref.hinge_loss_ref(p, y)
    assert float(loss[0]) == pytest.approx(float(direct), rel=1e-4, abs=1e-5)
