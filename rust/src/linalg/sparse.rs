//! Compressed sparse row (CSR) and column (CSC) matrices.
//!
//! The Reuters-like workload is high-dimensional tf-idf-style data with
//! ~50 non-zeros per row; both score computation (`p = X·w`) and
//! subgradient accumulation (`a = Xᵀ·v`) run in `O(nnz)` over CSR. A CSC
//! copy is optional: the paper notes its implementation kept both a
//! row-optimized and a column-optimized copy of the data matrix, trading
//! 2× memory for speed (Fig. 3 discussion); `ablation_tree`/§Perf revisit
//! that trade-off here.
//!
//! The CSR type is split into an owned [`CsrMatrix`] and a borrowed
//! [`CsrView`]: every kernel is implemented once, on the view, and the
//! owned matrix delegates. A view can borrow from the matrix's own
//! vectors *or* from the memory-mapped sections of a pallas store
//! (`data::store`) — the `u64` row-offset width below is exactly the
//! on-disk width, so a store opens with zero copies. Row offsets are
//! interpreted relative to `indptr[0]`, which makes row-range subviews
//! (the growing-prefix benches) O(1) slices rather than copies.

use crate::linalg::simd;
use anyhow::{ensure, Result};

/// Borrowed CSR view (`rows × cols`): the zero-copy substrate shared by
/// the owned [`CsrMatrix`] and the memory-mapped pallas store. `Copy`, so
/// it moves freely into worker-pool tasks.
#[derive(Clone, Copy, Debug)]
pub struct CsrView<'a> {
    rows: usize,
    cols: usize,
    /// Row offsets, length `rows + 1`, non-decreasing; entries are
    /// relative to `indptr[0]` (always 0 for a full matrix, non-zero for
    /// a row-range subview into a larger array).
    indptr: &'a [u64],
    /// Column indices for the viewed rows, ascending within each row.
    indices: &'a [u32],
    /// Values, same length as `indices`.
    values: &'a [f64],
}

impl<'a> CsrView<'a> {
    /// Build a validated view over raw CSR arrays. This is the bounds
    /// gate the pallas store relies on at open time: after it passes,
    /// every kernel below is in-bounds by construction.
    pub fn new(
        rows: usize,
        cols: usize,
        indptr: &'a [u64],
        indices: &'a [u32],
        values: &'a [f64],
    ) -> Result<Self> {
        ensure!(indptr.len() == rows + 1, "indptr length {} != rows+1 {}", indptr.len(), rows + 1);
        ensure!(
            indices.len() == values.len(),
            "indices/values length mismatch: {} vs {}",
            indices.len(),
            values.len()
        );
        for w in indptr.windows(2) {
            ensure!(w[0] <= w[1], "indptr must be non-decreasing");
        }
        let base = indptr[0];
        let nnz = indptr[rows] - base;
        ensure!(
            nnz as usize == indices.len(),
            "indptr spans {} non-zeros but {} are present",
            nnz,
            indices.len()
        );
        for &c in indices {
            ensure!((c as usize) < cols, "column index {c} out of bounds (cols = {cols})");
        }
        Ok(CsrView { rows, cols, indptr, indices, values })
    }

    /// Build without validation — for views derived from an already
    /// validated owned matrix whose invariants hold by construction.
    pub(crate) fn new_unchecked(
        rows: usize,
        cols: usize,
        indptr: &'a [u64],
        indices: &'a [u32],
        values: &'a [f64],
    ) -> Self {
        debug_assert_eq!(indptr.len(), rows + 1);
        debug_assert_eq!(indices.len(), values.len());
        CsrView { rows, cols, indptr, indices, values }
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    #[inline]
    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    /// Average non-zeros per row — the paper's sparsity parameter `s`.
    pub fn avg_nnz_per_row(&self) -> f64 {
        if self.rows == 0 {
            0.0
        } else {
            self.nnz() as f64 / self.rows as f64
        }
    }

    /// Non-zeros of row `i` as `(indices, values)`.
    #[inline]
    pub fn row(&self, i: usize) -> (&'a [u32], &'a [f64]) {
        let base = self.indptr[0];
        let lo = (self.indptr[i] - base) as usize;
        let hi = (self.indptr[i + 1] - base) as usize;
        (&self.indices[lo..hi], &self.values[lo..hi])
    }

    /// `p = X·w` (length `rows`), `O(nnz)`. One row-gather-dot kernel
    /// pass through the [`simd`] dispatch point (bit-identical on either
    /// path; counted once per call in the kernel-dispatch counters).
    pub fn matvec(&self, w: &[f64], out: &mut [f64]) {
        assert_eq!(w.len(), self.cols);
        assert_eq!(out.len(), self.rows);
        let k = simd::active();
        simd::note_pass(k);
        for (i, o) in out.iter_mut().enumerate() {
            let (idx, val) = self.row(i);
            *o = simd::sparse_dot(k, idx, val, w);
        }
    }

    /// `a = Xᵀ·v` (length `cols`), `O(nnz)` scatter. `out` overwritten.
    /// One scatter-axpy kernel pass; the kernel applies each row's adds
    /// in entry order, so the bits match the historical scalar loop.
    pub fn matvec_t(&self, v: &[f64], out: &mut [f64]) {
        assert_eq!(v.len(), self.rows);
        assert_eq!(out.len(), self.cols);
        out.iter_mut().for_each(|x| *x = 0.0);
        let k = simd::active();
        simd::note_pass(k);
        for (i, &vi) in v.iter().enumerate() {
            if vi != 0.0 {
                let (idx, val) = self.row(i);
                simd::scatter_axpy(k, idx, val, vi, out);
            }
        }
    }

    /// Dot product of row `i` with a dense vector (prediction path).
    /// Dispatches per call but does not count a pass: callers that sweep
    /// many rows ([`matvec`], the parallel score plan) count themselves.
    #[inline]
    pub fn row_dot(&self, i: usize, w: &[f64]) -> f64 {
        let (idx, val) = self.row(i);
        simd::sparse_dot(simd::active(), idx, val, w)
    }

    /// Zero-copy row-range subview `[lo, hi)` — the growing-prefix
    /// benches slice a memory-mapped store with this instead of copying.
    pub fn row_range(&self, lo: usize, hi: usize) -> CsrView<'a> {
        assert!(lo <= hi && hi <= self.rows);
        let base = self.indptr[0];
        let a = (self.indptr[lo] - base) as usize;
        let b = (self.indptr[hi] - base) as usize;
        CsrView {
            rows: hi - lo,
            cols: self.cols,
            indptr: &self.indptr[lo..=hi],
            indices: &self.indices[a..b],
            values: &self.values[a..b],
        }
    }

    /// Materialize an owned copy of this view.
    pub fn to_owned_matrix(&self) -> CsrMatrix {
        let base = self.indptr[0];
        CsrMatrix {
            rows: self.rows,
            cols: self.cols,
            indptr: self.indptr.iter().map(|&p| p - base).collect(),
            indices: self.indices.to_vec(),
            values: self.values.to_vec(),
        }
    }

    /// Convert to CSC (column-optimized copy).
    pub fn to_csc(&self) -> CscMatrix {
        let mut colptr = vec![0usize; self.cols + 1];
        for &c in self.indices {
            colptr[c as usize + 1] += 1;
        }
        for j in 0..self.cols {
            colptr[j + 1] += colptr[j];
        }
        let mut next = colptr.clone();
        let mut row_indices = vec![0u32; self.nnz()];
        let mut values = vec![0.0f64; self.nnz()];
        for i in 0..self.rows {
            let (idx, val) = self.row(i);
            for (&j, &v) in idx.iter().zip(val) {
                let slot = next[j as usize];
                row_indices[slot] = i as u32;
                values[slot] = v;
                next[j as usize] += 1;
            }
        }
        CscMatrix { rows: self.rows, cols: self.cols, colptr, row_indices, values }
    }
}

/// Owned CSR sparse matrix (`rows × cols`), f64 values, u32 column
/// indices, u64 row offsets (the pallas-store on-disk width).
#[derive(Clone, Debug, PartialEq)]
pub struct CsrMatrix {
    rows: usize,
    cols: usize,
    /// Row start offsets, length `rows + 1`.
    indptr: Vec<u64>,
    /// Column indices, length nnz, ascending within each row.
    indices: Vec<u32>,
    /// Values, length nnz.
    values: Vec<f64>,
}

impl CsrMatrix {
    /// Build from triplets `(row, col, value)`. Duplicate entries are
    /// summed; zero values are kept (callers may prune beforehand).
    pub fn from_triplets(rows: usize, cols: usize, mut triplets: Vec<(usize, usize, f64)>) -> Self {
        triplets.sort_unstable_by_key(|&(r, c, _)| (r, c));
        let mut indptr = vec![0u64; rows + 1];
        let mut indices = Vec::with_capacity(triplets.len());
        let mut values: Vec<f64> = Vec::with_capacity(triplets.len());
        let mut last: Option<(usize, usize)> = None;
        for (r, c, v) in triplets {
            assert!(r < rows && c < cols, "triplet ({r},{c}) out of bounds");
            if last == Some((r, c)) {
                *values.last_mut().unwrap() += v;
            } else {
                indptr[r + 1] += 1;
                indices.push(c as u32);
                values.push(v);
                last = Some((r, c));
            }
        }
        for i in 0..rows {
            indptr[i + 1] += indptr[i];
        }
        CsrMatrix { rows, cols, indptr, indices, values }
    }

    /// Build directly from CSR arrays (validated; `indptr` in the u64
    /// on-disk width).
    pub fn from_raw(
        rows: usize,
        cols: usize,
        indptr: Vec<u64>,
        indices: Vec<u32>,
        values: Vec<f64>,
    ) -> Self {
        CsrView::new(rows, cols, &indptr, &indices, &values).expect("invalid CSR arrays");
        assert_eq!(indptr.first().copied().unwrap_or(0), 0, "owned indptr must start at 0");
        CsrMatrix { rows, cols, indptr, indices, values }
    }

    /// Dense → CSR (drops exact zeros).
    pub fn from_dense(x: &super::dense::DenseMatrix) -> Self {
        let mut triplets = Vec::new();
        for i in 0..x.rows() {
            for (j, &v) in x.row(i).iter().enumerate() {
                if v != 0.0 {
                    triplets.push((i, j, v));
                }
            }
        }
        CsrMatrix::from_triplets(x.rows(), x.cols(), triplets)
    }

    /// Borrowed zero-copy view — the form every kernel and compute
    /// backend consumes.
    #[inline]
    pub fn view(&self) -> CsrView<'_> {
        CsrView::new_unchecked(self.rows, self.cols, &self.indptr, &self.indices, &self.values)
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    #[inline]
    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    /// Average non-zeros per row — the paper's sparsity parameter `s`.
    pub fn avg_nnz_per_row(&self) -> f64 {
        self.view().avg_nnz_per_row()
    }

    /// Non-zeros of row `i` as `(indices, values)`.
    #[inline]
    pub fn row(&self, i: usize) -> (&[u32], &[f64]) {
        let (lo, hi) = (self.indptr[i] as usize, self.indptr[i + 1] as usize);
        (&self.indices[lo..hi], &self.values[lo..hi])
    }

    /// `p = X·w` (length `rows`), `O(nnz)`.
    pub fn matvec(&self, w: &[f64], out: &mut [f64]) {
        self.view().matvec(w, out)
    }

    /// `a = Xᵀ·v` (length `cols`), `O(nnz)` scatter. `out` overwritten.
    pub fn matvec_t(&self, v: &[f64], out: &mut [f64]) {
        self.view().matvec_t(v, out)
    }

    /// Dot product of row `i` with a dense vector (prediction path).
    pub fn row_dot(&self, i: usize, w: &[f64]) -> f64 {
        self.view().row_dot(i, w)
    }

    /// Extract a row-range submatrix `[lo, hi)` as an owned copy (used by
    /// train/test splits; prefer [`CsrView::row_range`] for zero-copy).
    pub fn row_range(&self, lo: usize, hi: usize) -> CsrMatrix {
        self.view().row_range(lo, hi).to_owned_matrix()
    }

    /// Replace every stored value with `f(col, value)` in place — the
    /// mutation hook behind column-wise transforms such as the
    /// trainer's `--normalize l2-col`. The sparsity structure (stored
    /// positions, row offsets) is untouched even when `f` returns 0.0,
    /// so the result stays bit-comparable entry-for-entry with the
    /// input.
    pub fn map_values(&mut self, mut f: impl FnMut(usize, f64) -> f64) {
        for (k, v) in self.values.iter_mut().enumerate() {
            *v = f(self.indices[k] as usize, *v);
        }
    }

    /// Gather an arbitrary subset of rows into a new matrix.
    pub fn select_rows(&self, rows: &[usize]) -> CsrMatrix {
        let mut triplets = Vec::new();
        for (new_i, &i) in rows.iter().enumerate() {
            let (idx, val) = self.row(i);
            for (&j, &v) in idx.iter().zip(val) {
                triplets.push((new_i, j as usize, v));
            }
        }
        CsrMatrix::from_triplets(rows.len(), self.cols, triplets)
    }

    /// Convert to CSC (column-optimized copy).
    pub fn to_csc(&self) -> CscMatrix {
        self.view().to_csc()
    }

    /// Materialize as dense (tests / XLA tile feeding on small data).
    pub fn to_dense(&self) -> super::dense::DenseMatrix {
        let mut d = super::dense::DenseMatrix::zeros(self.rows, self.cols);
        for i in 0..self.rows {
            let (idx, val) = self.row(i);
            for (&j, &v) in idx.iter().zip(val) {
                d.set(i, j as usize, v);
            }
        }
        d
    }

    /// Approximate heap footprint in bytes (Fig-3 memory accounting).
    pub fn mem_bytes(&self) -> usize {
        self.indptr.len() * std::mem::size_of::<u64>()
            + self.indices.len() * std::mem::size_of::<u32>()
            + self.values.len() * std::mem::size_of::<f64>()
    }
}

/// CSC sparse matrix — column-major twin of [`CsrMatrix`]. Provides the
/// column-oriented `matvec_t` used by the two-copies ablation.
#[derive(Clone, Debug, PartialEq)]
pub struct CscMatrix {
    rows: usize,
    cols: usize,
    colptr: Vec<usize>,
    row_indices: Vec<u32>,
    values: Vec<f64>,
}

impl CscMatrix {
    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    #[inline]
    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    /// Non-zeros of column `j` as `(row indices, values)`.
    #[inline]
    pub fn col(&self, j: usize) -> (&[u32], &[f64]) {
        let (lo, hi) = (self.colptr[j], self.colptr[j + 1]);
        (&self.row_indices[lo..hi], &self.values[lo..hi])
    }

    /// `a = Xᵀ·v` computed column-wise: each `a[j]` is a gather over the
    /// column — no scatter, better locality when `v` is hot in cache.
    /// One gather-dot kernel pass per call (same kernel as the CSR row
    /// dot, with the roles of stored and gathered operand unchanged).
    pub fn matvec_t(&self, v: &[f64], out: &mut [f64]) {
        assert_eq!(v.len(), self.rows);
        assert_eq!(out.len(), self.cols);
        let k = simd::active();
        simd::note_pass(k);
        for j in 0..self.cols {
            let (idx, val) = self.col(j);
            out[j] = simd::sparse_dot(k, idx, val, v);
        }
    }

    pub fn mem_bytes(&self) -> usize {
        self.colptr.len() * std::mem::size_of::<usize>()
            + self.row_indices.len() * std::mem::size_of::<u32>()
            + self.values.len() * std::mem::size_of::<f64>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::dense::DenseMatrix;
    use crate::util::rng::Rng;

    #[test]
    fn map_values_scales_by_column_without_touching_structure() {
        let mut m = CsrMatrix::from_triplets(
            2,
            3,
            vec![(0, 0, 2.0), (0, 2, 4.0), (1, 1, 6.0), (1, 2, 0.5)],
        );
        let before_structure: Vec<_> = (0..2).map(|i| m.row(i).0.to_vec()).collect();
        m.map_values(|c, v| v / (c + 1) as f64);
        assert_eq!(m.row(0), (&[0u32, 2][..], &[2.0, 4.0 / 3.0][..]));
        assert_eq!(m.row(1), (&[1u32, 2][..], &[3.0, 0.5 / 3.0][..]));
        // Zero results stay stored: structure is invariant.
        m.map_values(|_, _| 0.0);
        for (i, idx) in before_structure.iter().enumerate() {
            assert_eq!(m.row(i).0, &idx[..]);
        }
    }

    fn random_csr(rng: &mut Rng, rows: usize, cols: usize, density: f64) -> CsrMatrix {
        let mut t = Vec::new();
        for i in 0..rows {
            for j in 0..cols {
                if rng.bool(density) {
                    t.push((i, j, rng.normal()));
                }
            }
        }
        CsrMatrix::from_triplets(rows, cols, t)
    }

    #[test]
    fn triplets_sum_duplicates() {
        let m = CsrMatrix::from_triplets(2, 2, vec![(0, 1, 2.0), (0, 1, 3.0), (1, 0, 1.0)]);
        assert_eq!(m.nnz(), 2);
        let (idx, val) = m.row(0);
        assert_eq!(idx, &[1]);
        assert_eq!(val, &[5.0]);
    }

    #[test]
    fn matvec_matches_dense() {
        let mut rng = Rng::new(21);
        for _ in 0..20 {
            let rows = 1 + rng.below(40);
            let cols = 1 + rng.below(30);
            let m = random_csr(&mut rng, rows, cols, 0.3);
            let d = m.to_dense();
            let w: Vec<f64> = (0..cols).map(|_| rng.normal()).collect();
            let mut p1 = vec![0.0; rows];
            let mut p2 = vec![0.0; rows];
            m.matvec(&w, &mut p1);
            d.matvec(&w, &mut p2);
            for (a, b) in p1.iter().zip(&p2) {
                assert!((a - b).abs() < 1e-10);
            }
        }
    }

    #[test]
    fn matvec_t_matches_dense_and_csc() {
        let mut rng = Rng::new(23);
        for _ in 0..20 {
            let rows = 1 + rng.below(40);
            let cols = 1 + rng.below(30);
            let m = random_csr(&mut rng, rows, cols, 0.25);
            let d = m.to_dense();
            let csc = m.to_csc();
            let v: Vec<f64> = (0..rows).map(|_| rng.normal()).collect();
            let mut a1 = vec![0.0; cols];
            let mut a2 = vec![0.0; cols];
            let mut a3 = vec![0.0; cols];
            m.matvec_t(&v, &mut a1);
            d.matvec_t(&v, &mut a2);
            csc.matvec_t(&v, &mut a3);
            for i in 0..cols {
                assert!((a1[i] - a2[i]).abs() < 1e-10);
                assert!((a1[i] - a3[i]).abs() < 1e-10);
            }
        }
    }

    #[test]
    fn row_range_and_select() {
        let triplets = vec![(0, 0, 1.0), (1, 1, 2.0), (2, 2, 3.0), (3, 0, 4.0)];
        let m = CsrMatrix::from_triplets(4, 3, triplets);
        let r = m.row_range(1, 3);
        assert_eq!(r.rows(), 2);
        assert_eq!(r.row(0), (&[1u32][..], &[2.0][..]));
        assert_eq!(r.row(1), (&[2u32][..], &[3.0][..]));
        let s = m.select_rows(&[3, 0]);
        assert_eq!(s.row(0), (&[0u32][..], &[4.0][..]));
        assert_eq!(s.row(1), (&[0u32][..], &[1.0][..]));
    }

    #[test]
    fn view_row_range_is_zero_copy_and_consistent() {
        let mut rng = Rng::new(31);
        let m = random_csr(&mut rng, 30, 12, 0.3);
        let view = m.view();
        for (lo, hi) in [(0, 30), (5, 20), (7, 7), (29, 30)] {
            let sub = view.row_range(lo, hi);
            let owned = m.row_range(lo, hi);
            assert_eq!(sub.rows(), owned.rows());
            assert_eq!(sub.nnz(), owned.nnz());
            for i in 0..sub.rows() {
                assert_eq!(sub.row(i), owned.row(i));
            }
            // Round trip through the owned materialization.
            assert_eq!(sub.to_owned_matrix(), owned);
        }
        // Nested subview of a subview (relative indptr base).
        let sub = view.row_range(4, 26).row_range(3, 10);
        let owned = m.row_range(7, 14);
        for i in 0..sub.rows() {
            assert_eq!(sub.row(i), owned.row(i));
        }
    }

    #[test]
    fn view_new_validates() {
        // Valid.
        assert!(CsrView::new(2, 3, &[0, 1, 2], &[0, 2], &[1.0, 2.0]).is_ok());
        // Wrong indptr length.
        assert!(CsrView::new(2, 3, &[0, 1], &[0], &[1.0]).is_err());
        // Decreasing indptr.
        assert!(CsrView::new(2, 3, &[0, 2, 1], &[0, 1], &[1.0, 2.0]).is_err());
        // Column out of bounds.
        assert!(CsrView::new(1, 2, &[0, 1], &[5], &[1.0]).is_err());
        // nnz mismatch.
        assert!(CsrView::new(1, 2, &[0, 2], &[0], &[1.0]).is_err());
        // indices/values length mismatch.
        assert!(CsrView::new(1, 2, &[0, 1], &[0, 1], &[1.0]).is_err());
    }

    #[test]
    fn round_trip_dense() {
        let d = DenseMatrix::from_rows(&[vec![0.0, 1.5], vec![2.5, 0.0]]);
        let m = CsrMatrix::from_dense(&d);
        assert_eq!(m.nnz(), 2);
        assert_eq!(m.to_dense(), d);
    }

    #[test]
    fn empty_matrix() {
        let m = CsrMatrix::from_triplets(0, 5, vec![]);
        assert_eq!(m.nnz(), 0);
        assert_eq!(m.avg_nnz_per_row(), 0.0);
        let mut out = vec![];
        m.matvec(&[0.0; 5], &mut out);
    }

    #[test]
    fn row_dot_matches_matvec() {
        let mut rng = Rng::new(29);
        let m = random_csr(&mut rng, 10, 8, 0.4);
        let w: Vec<f64> = (0..8).map(|_| rng.normal()).collect();
        let mut p = vec![0.0; 10];
        m.matvec(&w, &mut p);
        for i in 0..10 {
            assert!((m.row_dot(i, &w) - p[i]).abs() < 1e-12);
        }
    }

    #[test]
    #[should_panic]
    fn out_of_bounds_triplet_panics() {
        CsrMatrix::from_triplets(2, 2, vec![(2, 0, 1.0)]);
    }
}
