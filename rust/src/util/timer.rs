//! Timing helpers for the bench harness and the trainer's per-phase
//! instrumentation (sort / tree / matvec split recorded in §Perf).

use std::time::{Duration, Instant};

/// A simple stopwatch.
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Self {
        Timer { start: Instant::now() }
    }

    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }
}

/// Accumulates durations per named phase; used to break an oracle call
/// into its sort / tree / linalg components without external profilers.
#[derive(Default, Debug, Clone)]
pub struct PhaseTimes {
    entries: Vec<(String, Duration)>,
}

impl PhaseTimes {
    pub fn new() -> Self {
        Self::default()
    }

    /// Time `f` and accumulate under `name`.
    pub fn time<T>(&mut self, name: &str, f: impl FnOnce() -> T) -> T {
        let t = Instant::now();
        let out = f();
        self.add(name, t.elapsed());
        out
    }

    pub fn add(&mut self, name: &str, d: Duration) {
        if let Some(e) = self.entries.iter_mut().find(|(n, _)| n == name) {
            e.1 += d;
        } else {
            self.entries.push((name.to_string(), d));
        }
    }

    pub fn get(&self, name: &str) -> Duration {
        self.entries
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, d)| *d)
            .unwrap_or_default()
    }

    pub fn entries(&self) -> &[(String, Duration)] {
        &self.entries
    }

    pub fn total(&self) -> Duration {
        self.entries.iter().map(|(_, d)| *d).sum()
    }
}

/// Run `f` repeatedly: `warmup` discarded runs then `reps` timed runs;
/// returns (median, min, mean) seconds. The bench binaries use this in
/// place of criterion (absent from the offline registry).
pub fn bench_runs<T>(warmup: usize, reps: usize, mut f: impl FnMut() -> T) -> BenchStats {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut times = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t = Instant::now();
        std::hint::black_box(f());
        times.push(t.elapsed().as_secs_f64());
    }
    BenchStats::from_times(times)
}

/// Summary statistics of repeated timed runs.
#[derive(Debug, Clone)]
pub struct BenchStats {
    pub times: Vec<f64>,
    pub median: f64,
    pub min: f64,
    pub mean: f64,
}

impl BenchStats {
    pub fn from_times(mut times: Vec<f64>) -> Self {
        assert!(!times.is_empty());
        times.sort_unstable_by(|a, b| a.total_cmp(b));
        let min = times[0];
        let median = times[times.len() / 2];
        let mean = times.iter().sum::<f64>() / times.len() as f64;
        BenchStats { times, median, min, mean }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn phase_times_accumulate() {
        let mut p = PhaseTimes::new();
        p.add("sort", Duration::from_millis(5));
        p.add("sort", Duration::from_millis(7));
        p.add("tree", Duration::from_millis(3));
        assert_eq!(p.get("sort"), Duration::from_millis(12));
        assert_eq!(p.get("tree"), Duration::from_millis(3));
        assert_eq!(p.total(), Duration::from_millis(15));
        assert_eq!(p.get("missing"), Duration::ZERO);
    }

    #[test]
    fn bench_stats_order() {
        let s = BenchStats::from_times(vec![3.0, 1.0, 2.0]);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.median, 2.0);
        assert!((s.mean - 2.0).abs() < 1e-12);
    }

    #[test]
    fn timer_monotone() {
        let t = Timer::start();
        std::thread::sleep(Duration::from_millis(1));
        assert!(t.elapsed_secs() > 0.0);
    }
}
