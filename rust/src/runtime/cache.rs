//! Cache topology probing and cache-aware chunk sizing for the
//! integer-exact parallel work plans.
//!
//! The flat `adaptive_chunks = clamp(4·threads, 4, 64)` plan sizes
//! chunks by *count*, which on large inputs produces chunks far bigger
//! than any cache level: a 60 MB score pass cut into 32 chunks streams
//! ~2 MB per task, evicting the weight vector between rows.
//! [`sized_chunks`] sizes chunks by *bytes* instead — it aims each
//! chunk's working set at a fraction of L2 (probed from sysfs once,
//! overridable) while never dropping below the adaptive count, so small
//! inputs keep their historical plans bit for bit.
//!
//! **Determinism scope** (docs/DETERMINISM.md): cache-aware counts are
//! legal only where the chunk plan is *exact* — integer decompositions
//! and disjoint-write maps such as the score pass and the sharded
//! oracle's counting sweeps. Float reductions keep their fixed plans
//! (`compute::GRAD_CHUNKS`); nothing here may ever size one.
//!
//! Override precedence: [`set_chunk_target_kib`] (wired from
//! `TrainConfig.chunk_target_kib` / `--chunk-target-kib`) beats the
//! `RANKSVM_CHUNK_KIB` environment variable, which beats the sysfs
//! probe, which falls back to a fixed constant off Linux.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

/// Fallback L2 size when the sysfs probe fails (non-Linux, sandboxes):
/// 512 KiB is conservative for every x86_64/aarch64 part of the last
/// decade.
const DEFAULT_L2_BYTES: usize = 512 * 1024;

/// Fallback last-level size under the same conditions.
const DEFAULT_LLC_BYTES: usize = 8 * 1024 * 1024;

/// Upper bound on any chunk plan: with ≤ 64 adaptive chunks below and
/// ≥ 4 KiB targets, 4096 chunks caps scheduler overhead on huge inputs.
const MAX_CHUNKS: usize = 4096;

/// Parse a sysfs cache size string like `"512K"` / `"8M"` / `"32768"`.
fn parse_size(s: &str) -> Option<usize> {
    let t = s.trim();
    let (digits, mult) = match t.as_bytes().last()? {
        b'K' | b'k' => (&t[..t.len() - 1], 1024),
        b'M' | b'm' => (&t[..t.len() - 1], 1024 * 1024),
        b'G' | b'g' => (&t[..t.len() - 1], 1024 * 1024 * 1024),
        _ => (t, 1),
    };
    digits.trim().parse::<usize>().ok().map(|n| n * mult)
}

/// One pass over `/sys/devices/system/cpu/cpu0/cache/index*`: returns
/// `(l2_bytes, llc_bytes)` from the data/unified caches, with fallbacks
/// for whatever the probe cannot see.
fn probe() -> (usize, usize) {
    let mut l2 = None;
    let mut llc: Option<(u32, usize)> = None;
    let base = std::path::Path::new("/sys/devices/system/cpu/cpu0/cache");
    if let Ok(entries) = std::fs::read_dir(base) {
        for e in entries.flatten() {
            let p = e.path();
            let read = |f: &str| std::fs::read_to_string(p.join(f)).unwrap_or_default();
            if read("type").trim() == "Instruction" {
                continue;
            }
            let level: u32 = match read("level").trim().parse() {
                Ok(l) => l,
                Err(_) => continue,
            };
            let size = match parse_size(&read("size")) {
                Some(s) if s > 0 => s,
                _ => continue,
            };
            if level == 2 {
                l2 = Some(size);
            }
            if llc.map(|(ll, _)| level > ll).unwrap_or(true) {
                llc = Some((level, size));
            }
        }
    }
    let l2 = l2.unwrap_or(DEFAULT_L2_BYTES);
    let llc = llc.map(|(_, s)| s).unwrap_or(DEFAULT_LLC_BYTES).max(l2);
    (l2, llc)
}

fn probed() -> &'static (usize, usize) {
    static CACHE: OnceLock<(usize, usize)> = OnceLock::new();
    CACHE.get_or_init(probe)
}

/// L2 data-cache size in bytes (probed once; fallback constant).
pub fn l2_bytes() -> usize {
    probed().0
}

/// Last-level cache size in bytes (probed once; fallback constant).
pub fn llc_bytes() -> usize {
    probed().1
}

/// Config override for the per-chunk byte target, in KiB; 0 = auto.
static CHUNK_TARGET_KIB: AtomicUsize = AtomicUsize::new(0);

/// Set (or with 0, clear) the configured per-chunk byte target. Wired
/// from `TrainConfig.chunk_target_kib` at trainer start; process-global
/// like the observability level, and equally inert: chunk counts only
/// shape integer-exact decompositions, never a float reduction, so this
/// knob cannot change any result bit.
pub fn set_chunk_target_kib(kib: usize) {
    CHUNK_TARGET_KIB.store(kib, Ordering::Relaxed);
}

fn env_target_kib() -> usize {
    static ENV: OnceLock<usize> = OnceLock::new();
    *ENV.get_or_init(|| {
        std::env::var("RANKSVM_CHUNK_KIB")
            .ok()
            .and_then(|v| v.trim().parse::<usize>().ok())
            .unwrap_or(0)
    })
}

/// The per-chunk working-set target in bytes: config override, else
/// `RANKSVM_CHUNK_KIB`, else half of L2 (clamped to `[64 KiB, LLC]` so
/// absurd probe results stay sane).
pub fn chunk_target_bytes() -> usize {
    let cfg = CHUNK_TARGET_KIB.load(Ordering::Relaxed);
    if cfg > 0 {
        return cfg * 1024;
    }
    let env = env_target_kib();
    if env > 0 {
        return env * 1024;
    }
    (l2_bytes() / 2).clamp(64 * 1024, llc_bytes())
}

/// Pure sizing rule, separated for tests: enough chunks that each holds
/// at most `target_bytes` of working set, floored at the adaptive count
/// (small inputs keep their historical plans) and capped at
/// [`MAX_CHUNKS`].
pub fn chunks_for(total_bytes: usize, target_bytes: usize, floor: usize) -> usize {
    let by_cache = total_bytes.div_ceil(target_bytes.max(1));
    by_cache.clamp(floor, MAX_CHUNKS.max(floor))
}

/// Cache-aware chunk count for an integer-exact parallel plan over
/// `total_bytes` of working set. Callers still `.min(n_items)` exactly
/// as they did with `adaptive_chunks`.
pub fn sized_chunks(n_threads: usize, total_bytes: usize) -> usize {
    chunks_for(
        total_bytes,
        chunk_target_bytes(),
        crate::linalg::ops::adaptive_chunks(n_threads),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn size_strings_parse() {
        assert_eq!(parse_size("512K"), Some(512 * 1024));
        assert_eq!(parse_size("8M"), Some(8 * 1024 * 1024));
        assert_eq!(parse_size(" 32768 "), Some(32768));
        assert_eq!(parse_size("1G"), Some(1024 * 1024 * 1024));
        assert_eq!(parse_size("nope"), None);
        assert_eq!(parse_size(""), None);
    }

    #[test]
    fn probe_yields_sane_sizes() {
        // Whether sysfs answered or the fallbacks kicked in: nonzero,
        // ordered, and within physical plausibility.
        let l2 = l2_bytes();
        let llc = llc_bytes();
        assert!(l2 >= 16 * 1024, "l2 {l2}");
        assert!(llc >= l2, "llc {llc} < l2 {l2}");
        assert!(llc <= 16 * 1024 * 1024 * 1024usize, "llc {llc}");
    }

    #[test]
    fn chunks_for_floors_small_and_scales_large() {
        // Small totals: the adaptive floor wins — historical plans are
        // preserved bit for bit.
        assert_eq!(chunks_for(0, 256 * 1024, 8), 8);
        assert_eq!(chunks_for(4_000, 256 * 1024, 32), 32);
        // Large totals: one chunk per target-sized slab.
        assert_eq!(chunks_for(100 * 256 * 1024, 256 * 1024, 8), 100);
        // Cap: absurd totals cannot explode the scheduler.
        assert_eq!(chunks_for(usize::MAX / 2, 1, 4), MAX_CHUNKS);
        // Zero target is treated as 1 byte, not a division by zero.
        assert_eq!(chunks_for(10, 0, 4), 10);
    }

    #[test]
    fn default_target_is_an_l2_fraction() {
        // Without overrides in play the auto target sits in the probed
        // hierarchy. (The config/env overrides are process-global, so
        // they are exercised in `tests/kernels.rs`, not here — lib tests
        // share the process.)
        if std::env::var_os("RANKSVM_CHUNK_KIB").is_some() {
            return; // an external override is in force; nothing to pin
        }
        let t = chunk_target_bytes();
        assert!(t >= 64 * 1024, "target {t}");
        assert!(t <= llc_bytes(), "target {t}");
    }
}
