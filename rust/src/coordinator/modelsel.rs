//! Model selection: k-fold cross-validation over the regularization path.
//!
//! The paper fixes λ per dataset ("observed to lead to good test
//! performance"); a framework user needs the machinery that produces such
//! a choice. Query-grouped data is split by whole queries (splitting a
//! query across folds would leak its per-query offset).

use super::config::TrainConfig;
use super::trainer::{evaluate, train};
use crate::data::Dataset;
use crate::util::rng::Rng;
use anyhow::Result;

/// One (λ, per-fold errors) row of a CV sweep.
#[derive(Clone, Debug)]
pub struct CvPoint {
    pub lambda: f64,
    pub fold_errors: Vec<f64>,
    pub mean_error: f64,
}

/// Deterministic k-fold index split. Grouped data splits by distinct qid.
pub fn kfold_indices(ds: &Dataset, folds: usize, seed: u64) -> Vec<Vec<usize>> {
    assert!(folds >= 2, "need at least 2 folds");
    let mut rng = Rng::new(seed);
    match &ds.qid {
        None => {
            let mut idx: Vec<usize> = (0..ds.len()).collect();
            rng.shuffle(&mut idx);
            let mut out = vec![Vec::new(); folds];
            for (i, &e) in idx.iter().enumerate() {
                out[i % folds].push(e);
            }
            out
        }
        Some(qid) => {
            let mut queries: Vec<u64> = {
                let mut q = qid.clone();
                q.sort_unstable();
                q.dedup();
                q
            };
            rng.shuffle(&mut queries);
            let mut fold_of: std::collections::HashMap<u64, usize> =
                std::collections::HashMap::new();
            for (i, &q) in queries.iter().enumerate() {
                fold_of.insert(q, i % folds);
            }
            let mut out = vec![Vec::new(); folds];
            for (i, q) in qid.iter().enumerate() {
                out[fold_of[q]].push(i);
            }
            out
        }
    }
}

/// Sweep λ over `lambdas` with `folds`-fold CV; returns one [`CvPoint`]
/// per λ, in input order.
pub fn cross_validate(
    ds: &Dataset,
    base: &TrainConfig,
    lambdas: &[f64],
    folds: usize,
    seed: u64,
) -> Result<Vec<CvPoint>> {
    let fold_idx = kfold_indices(ds, folds, seed);
    // Pre-materialize fold datasets once (not per λ).
    let splits: Vec<(Dataset, Dataset)> = (0..folds)
        .map(|f| {
            let test_rows = &fold_idx[f];
            let train_rows: Vec<usize> =
                (0..folds).filter(|&g| g != f).flat_map(|g| fold_idx[g].iter().copied()).collect();
            (
                ds.subset(&train_rows, &format!("cv{f}train")),
                ds.subset(test_rows, &format!("cv{f}test")),
            )
        })
        .collect();
    let mut out = Vec::with_capacity(lambdas.len());
    for &lambda in lambdas {
        let mut fold_errors = Vec::with_capacity(folds);
        for (tr, te) in &splits {
            let cfg = TrainConfig { lambda, ..base.clone() };
            let res = train(tr, &cfg)?;
            fold_errors.push(evaluate(&res.model, te));
        }
        let mean_error = fold_errors.iter().sum::<f64>() / folds as f64;
        out.push(CvPoint { lambda, fold_errors, mean_error });
    }
    Ok(out)
}

/// Pick the λ minimizing mean CV error (ties → larger λ, i.e. the
/// simpler model).
pub fn select_lambda(points: &[CvPoint]) -> f64 {
    assert!(!points.is_empty());
    let mut best = &points[0];
    for p in points {
        if p.mean_error < best.mean_error - 1e-12
            || ((p.mean_error - best.mean_error).abs() <= 1e-12 && p.lambda > best.lambda)
        {
            best = p;
        }
    }
    best.lambda
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Method;
    use crate::data::synthetic;

    #[test]
    fn kfold_partitions_everything_once() {
        let ds = synthetic::cadata_like(103, 3);
        let folds = kfold_indices(&ds, 5, 1);
        let mut all: Vec<usize> = folds.iter().flatten().copied().collect();
        all.sort_unstable();
        assert_eq!(all, (0..103).collect::<Vec<_>>());
        // balanced within 1
        let sizes: Vec<usize> = folds.iter().map(|f| f.len()).collect();
        assert!(sizes.iter().max().unwrap() - sizes.iter().min().unwrap() <= 1);
    }

    #[test]
    fn grouped_kfold_keeps_queries_whole() {
        let ds = synthetic::queries(12, 10, 4, 5);
        let folds = kfold_indices(&ds, 3, 2);
        let qid = ds.qid.as_ref().unwrap();
        for fold in &folds {
            let qs: std::collections::HashSet<u64> = fold.iter().map(|&i| qid[i]).collect();
            // every query in this fold must be fully contained here
            for q in qs {
                let total = qid.iter().filter(|&&x| x == q).count();
                let here = fold.iter().filter(|&&i| qid[i] == q).count();
                assert_eq!(total, here, "query {q} split across folds");
            }
        }
    }

    #[test]
    fn cv_selects_reasonable_lambda() {
        let ds = synthetic::cadata_like(400, 8);
        let base = TrainConfig { method: Method::Tree, ..Default::default() };
        let lambdas = [1e-3, 1e-1, 1e3];
        let points = cross_validate(&ds, &base, &lambdas, 3, 7).unwrap();
        assert_eq!(points.len(), 3);
        let best = select_lambda(&points);
        // Over-regularization hurts (ranking is scale-invariant, so the
        // damage is under-fitting of the direction, not w → 0): the
        // degenerate λ must not win and the winner must actually rank.
        assert!(best < 1e3, "CV picked the degenerate λ: {points:?}");
        let worst = points.iter().find(|p| p.lambda == 1e3).unwrap();
        let chosen = points.iter().find(|p| p.lambda == best).unwrap();
        assert!(
            worst.mean_error > chosen.mean_error + 0.05,
            "λ=1e3 should clearly underperform: {points:?}"
        );
        assert!(chosen.mean_error < 0.25, "winner should rank well: {points:?}");
    }

    #[test]
    fn select_lambda_tie_breaks_to_simpler() {
        let points = vec![
            CvPoint { lambda: 0.01, fold_errors: vec![0.2], mean_error: 0.2 },
            CvPoint { lambda: 1.0, fold_errors: vec![0.2], mean_error: 0.2 },
        ];
        assert_eq!(select_lambda(&points), 1.0);
    }
}
