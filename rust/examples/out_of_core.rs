//! Out-of-core training off a memory-mapped pallas store.
//!
//! Converts a libsvm text file to the binary `.pstore` format once
//! (streaming, bounded memory), then trains straight off the mapping —
//! no parse step, zero-copy, bit-identical to the text path.
//!
//! Run from `rust/`:
//! ```sh
//! cargo run --release --example out_of_core
//! ```

use ranksvm::coordinator::{train, Method, TrainConfig};
use ranksvm::data::store::{convert_libsvm, ConvertOptions, PallasStore};
use ranksvm::data::{libsvm, synthetic, DatasetView};

fn main() -> anyhow::Result<()> {
    let dir = std::env::temp_dir().join("ranksvm_out_of_core");
    std::fs::create_dir_all(&dir)?;
    let text = dir.join("corpus.libsvm");
    let store_path = dir.join("corpus.pstore");

    // A stand-in corpus. In practice this is your real libsvm export.
    let ds = synthetic::queries(200, 25, 12, 42);
    libsvm::write(&ds, &text)?;

    // Convert once: single pass, matrix payload never resident.
    let stats = convert_libsvm(&text, &store_path, &ConvertOptions::default())?;
    println!(
        "converted: m={} nnz={} groups={} -> {} bytes (buffered ≤ {} bytes)",
        stats.rows, stats.nnz, stats.n_groups, stats.out_bytes, stats.max_buffered_bytes
    );

    // Map forever: open is cheap, training reads the kernel page cache.
    let store = PallasStore::open(&store_path)?;
    println!(
        "opened {} ({} groups, {} pairs, mmap={})",
        store.name(),
        store.n_groups(),
        store.n_pairs(),
        store.is_mapped()
    );

    let cfg = TrainConfig { method: Method::Tree, lambda: 0.05, ..Default::default() };
    let out = train(&store, &cfg)?;
    println!(
        "trained {} iterations, objective {:.6}, {:.2}s",
        out.iterations, out.objective, out.train_secs
    );

    // Growing prefixes are O(1) slices of the mapping — the scalability
    // experiment loop, with no per-size data copies.
    for m in [1000, 2000, 4000, store.len()] {
        let prefix = store.prefix_view(m);
        let out = train(&prefix, &cfg)?;
        println!("  m={m:>6}: {} iters, objective {:.6}", out.iterations, out.objective);
    }
    Ok(())
}
