//! The r-level algorithm of Joachims (2006) — what SVM^rank implements.
//!
//! After sorting by predicted score (`O(m log m)`), the frequencies
//! (5)–(6) are computed with one two-pointer merge *per distinct utility
//! level*: for level `ℓ`, the examples labelled `ℓ` are merged against
//! the examples with larger (for `c`) / smaller (for `d`) labels, both
//! streams already in score order. Cost `O(rm)` after the sort, i.e.
//! `O(ms + m log m + rm)` per training iteration — efficient when `r` is
//! a small constant (bipartite, 5-star ratings) and quadratic when
//! `r ≈ m` (the regime Figs. 1–2 probe; the paper's Table-less evaluation
//! hinges on this contrast with the tree oracle).

use super::{assemble_from_counts, OracleOutput, RankingOracle};
use crate::linalg::ops::argsort_into;

/// r-level oracle (SVM^rank stand-in; see DESIGN.md §6).
pub struct RLevelOracle {
    pi: Vec<usize>,
    c: Vec<u64>,
    d: Vec<u64>,
    /// Scratch: indices (in score order) for the current level / others.
    level_buf: Vec<usize>,
    other_buf: Vec<usize>,
}

impl RLevelOracle {
    pub fn new() -> Self {
        RLevelOracle {
            pi: Vec::new(),
            c: Vec::new(),
            d: Vec::new(),
            level_buf: Vec::new(),
            other_buf: Vec::new(),
        }
    }

    /// Distinct sorted utility levels — the paper's `r`.
    pub fn levels(y: &[f64]) -> Vec<f64> {
        let mut l: Vec<f64> = y.to_vec();
        l.sort_unstable_by(|a, b| a.total_cmp(b));
        l.dedup();
        l
    }

    /// Frequency computation with O(r) passes over the score-sorted data.
    pub fn compute_counts(&mut self, p: &[f64], y: &[f64]) -> (&[u64], &[u64]) {
        let m = p.len();
        assert_eq!(m, y.len());
        self.c.clear();
        self.c.resize(m, 0);
        self.d.clear();
        self.d.resize(m, 0);
        argsort_into(p, &mut self.pi);
        let levels = Self::levels(y);

        for &level in &levels {
            // --- c for this level: merge against examples with y > level.
            self.level_buf.clear();
            self.other_buf.clear();
            for &k in &self.pi {
                if y[k] == level {
                    self.level_buf.push(k);
                } else if y[k] > level {
                    self.other_buf.push(k);
                }
            }
            // Two-pointer: both lists ascend in p. For i in level order
            // (the low-label side), count j violating the canonical
            // hinge predicate 1 + p_i − p_j > 0 (eq. 5).
            let mut j = 0usize;
            for &i in &self.level_buf {
                while j < self.other_buf.len() && 1.0 + p[i] - p[self.other_buf[j]] > 0.0 {
                    j += 1;
                }
                self.c[i] = j as u64;
            }

            // --- d for this level: merge against examples with y < level,
            // descending in p. Count j with p[j] > p[i] − 1 (eq. 6).
            self.other_buf.clear();
            for &k in &self.pi {
                if y[k] < level {
                    self.other_buf.push(k);
                }
            }
            // i is now the high-label side: violation ⇔ 1 + p_j − p_i > 0.
            let mut j = self.other_buf.len();
            for &i in self.level_buf.iter().rev() {
                while j > 0 && 1.0 + p[self.other_buf[j - 1]] - p[i] > 0.0 {
                    j -= 1;
                }
                self.d[i] = (self.other_buf.len() - j) as u64;
            }
        }
        (&self.c, &self.d)
    }
}

impl Default for RLevelOracle {
    fn default() -> Self {
        Self::new()
    }
}

impl RankingOracle for RLevelOracle {
    fn eval(&mut self, p: &[f64], y: &[f64], n_pairs: f64) -> OracleOutput {
        self.compute_counts(p, y);
        assemble_from_counts(p, &self.c, &self.d, n_pairs)
    }

    fn name(&self) -> &'static str {
        "rlevel"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::losses::{count_comparable_pairs, PairOracle, RankingOracle, TreeOracle};
    use crate::util::rng::Rng;

    #[test]
    fn agrees_with_tree_and_pair_oracles() {
        let mut rng = Rng::new(202);
        for trial in 0..40 {
            let m = 1 + rng.below(120);
            let y: Vec<f64> = match trial % 4 {
                0 => (0..m).map(|_| rng.below(2) as f64).collect(),   // bipartite
                1 => (0..m).map(|_| 1.0 + rng.below(5) as f64).collect(), // 5-star
                2 => (0..m).map(|_| rng.normal()).collect(),           // r ≈ m
                _ => vec![2.0; m],
            };
            let p: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
            let n = count_comparable_pairs(&y) as f64;
            let mut rl = RLevelOracle::new();
            let mut tr = TreeOracle::new();
            let mut pr = PairOracle::new();
            let o1 = rl.eval(&p, &y, n);
            let o2 = tr.eval(&p, &y, n);
            let o3 = pr.eval(&p, &y, n);
            assert_eq!(o1.coeffs, o2.coeffs, "trial {trial}");
            assert_eq!(o1.coeffs, o3.coeffs, "trial {trial}");
            assert!((o1.loss - o2.loss).abs() < 1e-12);
        }
    }

    #[test]
    fn levels_helper() {
        assert_eq!(RLevelOracle::levels(&[2.0, 1.0, 2.0, 3.0]), vec![1.0, 2.0, 3.0]);
        assert!(RLevelOracle::levels(&[]).is_empty());
    }

    #[test]
    fn bipartite_counts_manual() {
        // y: [0,1], p: [0.5, 0.0] — pair (0,1) violates: 0.5 > 0 − 1.
        let mut rl = RLevelOracle::new();
        let (c, d) = rl.compute_counts(&[0.5, 0.0], &[0.0, 1.0]);
        assert_eq!(c, &[1, 0]);
        assert_eq!(d, &[0, 1]);
    }
}
