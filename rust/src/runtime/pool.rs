//! Persistent scoped worker pool.
//!
//! PR 1 parallelized the subgradient oracle and the `O(ms)` matvecs with
//! `std::thread::scope`, which respawns every worker on every call. The
//! spawn cost is only microseconds, but a BMRM run makes `3 × iterations`
//! parallel calls (scores, oracle, gradient), and the respawn tax scales
//! with the iteration count rather than the data — exactly the overhead
//! the ROADMAP shard-architecture item schedules for removal. This module
//! replaces the per-call scopes with **one pool per trainer**: `N − 1`
//! background threads created once (sized by `TrainConfig.n_threads`) and
//! reused by every parallel region until the pool is dropped.
//!
//! The API is scope-shaped: [`WorkerPool::run`] takes a batch of
//! closures that may borrow caller stack data (`'env`), executes them on
//! the pool plus the calling thread, and returns only once every closure
//! has finished — the same lifetime guarantee `std::thread::scope`
//! provides, with the threads themselves outliving the call. Determinism
//! is unaffected by scheduling: every call site hands the pool closures
//! whose writes target disjoint buffers and performs its floating-point
//! reductions serially afterwards (see `losses/sharded.rs` and
//! `compute::ParallelBackend`), so *which* thread runs a task never
//! influences a result bit.
//!
//! With one worker (`n_threads == 1`) the pool spawns no threads at all
//! and `run` degenerates to an in-place loop, keeping the serial path
//! free of synchronization.

use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

/// A unit of pool work. The `'env` lifetime lets tasks borrow from the
/// submitting stack frame; [`WorkerPool::run`] erases it only for the
/// bounded interval during which it blocks on task completion.
pub type Task<'env> = Box<dyn FnOnce() + Send + 'env>;

type StaticTask = Box<dyn FnOnce() + Send + 'static>;

struct PoolState {
    queue: VecDeque<StaticTask>,
    /// Tasks popped from the queue but not yet finished.
    active: usize,
    /// Tasks of the current batch that panicked (the payload is dropped;
    /// the batch submitter re-raises a summary panic).
    panicked: usize,
    shutdown: bool,
}

struct PoolShared {
    state: Mutex<PoolState>,
    /// Workers wait here for tasks.
    work_cv: Condvar,
    /// The batch submitter waits here for the last task to finish.
    done_cv: Condvar,
    /// Serializes whole batches: concurrent `run` calls from different
    /// threads queue up here instead of interleaving their tasks (and
    /// their panic accounting) in the shared queue.
    batch: Mutex<()>,
}

impl PoolShared {
    /// Execute one task, keeping the completion accounting correct even
    /// when the task panics.
    fn run_task(&self, task: StaticTask) {
        let ok = catch_unwind(AssertUnwindSafe(task)).is_ok();
        let mut st = self.state.lock().unwrap();
        st.active -= 1;
        if !ok {
            st.panicked += 1;
        }
        if st.active == 0 && st.queue.is_empty() {
            self.done_cv.notify_all();
        }
    }
}

/// A persistent pool of `n_threads − 1` background workers plus the
/// calling thread. Create once (per trainer / oracle / backend), submit
/// many batches; threads are joined on drop.
pub struct WorkerPool {
    n_threads: usize,
    shared: Arc<PoolShared>,
    handles: Vec<JoinHandle<()>>,
}

impl WorkerPool {
    /// Build a pool with `n_threads` total workers (the calling thread
    /// participates in every batch, so `n_threads − 1` threads are
    /// spawned; `0` and `1` both mean fully inline execution).
    pub fn new(n_threads: usize) -> Self {
        let n_threads = n_threads.max(1);
        let shared = Arc::new(PoolShared {
            state: Mutex::new(PoolState {
                queue: VecDeque::new(),
                active: 0,
                panicked: 0,
                shutdown: false,
            }),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
            batch: Mutex::new(()),
        });
        let handles = (1..n_threads)
            .map(|i| {
                let shared = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("ranksvm-pool-{i}"))
                    .spawn(move || worker_loop(&shared))
                    .expect("spawn pool worker")
            })
            .collect();
        WorkerPool { n_threads, shared, handles }
    }

    /// Total workers, counting the calling thread.
    pub fn n_threads(&self) -> usize {
        self.n_threads
    }

    /// Execute a batch of tasks, blocking until every task has finished
    /// (or panicked). Tasks may borrow from the caller's stack: the
    /// completion barrier below guarantees no task outlives `'env`.
    ///
    /// Tasks run concurrently on the pool threads and on the calling
    /// thread; submit tasks whose writes are disjoint. If any task
    /// panics, the remaining tasks still run to completion and `run`
    /// then panics (mirroring `std::thread::scope` semantics).
    ///
    /// Reentrant submission (calling `run` from inside a task) is not
    /// supported and may deadlock.
    pub fn run<'env>(&self, tasks: Vec<Task<'env>>) {
        if tasks.is_empty() {
            return;
        }
        // Inline path: single worker, or a single task — nothing to
        // schedule. (Panics propagate directly, same net effect.)
        if self.handles.is_empty() || tasks.len() == 1 {
            for task in tasks {
                task();
            }
            return;
        }
        // SAFETY: the only use of the erased tasks is inside this call:
        // they are either executed below on this thread or drained by
        // worker threads, and `run` does not return until the queue is
        // empty and `active == 0` — i.e. until every task (including
        // panicked ones, via `run_task`'s accounting) has completed.
        // Borrows captured at `'env` therefore strictly outlive every
        // task execution.
        let tasks: Vec<StaticTask> = tasks
            .into_iter()
            .map(|t| unsafe { std::mem::transmute::<Task<'env>, StaticTask>(t) })
            .collect();

        // One batch at a time: a second thread calling `run` blocks here
        // until the current batch fully drains, so batches can never
        // interleave tasks or clobber each other's panic accounting.
        // (A task calling `run` on its own pool would deadlock on this
        // lock — reentrancy is documented as unsupported.) The guard
        // protects no data, so a poisoned lock (possible only through a
        // panicking caller) is safe to recover.
        let batch = self.shared.batch.lock().unwrap_or_else(|e| e.into_inner());

        let mut st = self.shared.state.lock().unwrap();
        debug_assert!(
            st.queue.is_empty() && st.active == 0,
            "WorkerPool::run is not reentrant"
        );
        st.panicked = 0;
        st.queue.extend(tasks);
        drop(st);
        self.shared.work_cv.notify_all();

        // The calling thread participates until the batch drains, then
        // waits for stragglers running on pool threads.
        let mut st = self.shared.state.lock().unwrap();
        loop {
            if let Some(task) = st.queue.pop_front() {
                st.active += 1;
                drop(st);
                self.shared.run_task(task);
                st = self.shared.state.lock().unwrap();
            } else if st.active > 0 {
                st = self.shared.done_cv.wait(st).unwrap();
            } else {
                break;
            }
        }
        let panicked = st.panicked;
        st.panicked = 0;
        drop(st);
        // Release the batch lock *before* re-raising so a panicked batch
        // does not poison it (the pool stays usable afterwards).
        drop(batch);
        if panicked > 0 {
            panic!("{panicked} worker-pool task(s) panicked");
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        self.shared.state.lock().unwrap().shutdown = true;
        self.shared.work_cv.notify_all();
        for handle in self.handles.drain(..) {
            let _ = handle.join();
        }
    }
}

fn worker_loop(shared: &PoolShared) {
    loop {
        let task = {
            let mut st = shared.state.lock().unwrap();
            loop {
                if let Some(task) = st.queue.pop_front() {
                    st.active += 1;
                    break task;
                }
                if st.shutdown {
                    return;
                }
                st = shared.work_cv.wait(st).unwrap();
            }
        };
        shared.run_task(task);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    fn boxed<'env>(f: impl FnOnce() + Send + 'env) -> Task<'env> {
        Box::new(f)
    }

    #[test]
    fn runs_all_tasks_with_borrowed_state() {
        let pool = WorkerPool::new(4);
        let mut out = vec![0usize; 64];
        {
            let mut tasks: Vec<Task> = Vec::new();
            let mut rest: &mut [usize] = &mut out;
            let mut base = 0;
            for _ in 0..8 {
                let (head, tail) = { rest }.split_at_mut(8);
                let lo = base;
                tasks.push(boxed(move || {
                    for (k, slot) in head.iter_mut().enumerate() {
                        *slot = lo + k;
                    }
                }));
                rest = tail;
                base += 8;
            }
            pool.run(tasks);
        }
        assert_eq!(out, (0..64).collect::<Vec<_>>());
    }

    #[test]
    fn pool_is_reusable_across_many_batches() {
        let pool = WorkerPool::new(3);
        let counter = AtomicUsize::new(0);
        for _ in 0..200 {
            let tasks: Vec<Task> = (0..5)
                .map(|_| {
                    boxed(|| {
                        counter.fetch_add(1, Ordering::Relaxed);
                    })
                })
                .collect();
            pool.run(tasks);
        }
        assert_eq!(counter.load(Ordering::Relaxed), 1000);
    }

    #[test]
    fn single_thread_pool_spawns_nothing_and_runs_inline() {
        let pool = WorkerPool::new(1);
        assert_eq!(pool.n_threads(), 1);
        let tid = std::thread::current().id();
        let mut seen = Vec::new();
        {
            let seen_ref = &mut seen;
            pool.run(vec![boxed(move || seen_ref.push(std::thread::current().id()))]);
        }
        assert_eq!(seen, vec![tid]);
    }

    #[test]
    fn zero_means_one() {
        let pool = WorkerPool::new(0);
        assert_eq!(pool.n_threads(), 1);
        pool.run(vec![boxed(|| {})]);
    }

    #[test]
    fn empty_batch_is_a_noop() {
        let pool = WorkerPool::new(4);
        pool.run(Vec::new());
    }

    #[test]
    fn task_panic_propagates_after_batch_completes() {
        let pool = WorkerPool::new(4);
        let finished = AtomicUsize::new(0);
        let result = std::panic::catch_unwind(AssertUnwindSafe(|| {
            let tasks: Vec<Task> = (0..8)
                .map(|i| {
                    let finished = &finished;
                    boxed(move || {
                        if i == 3 {
                            panic!("boom");
                        }
                        finished.fetch_add(1, Ordering::Relaxed);
                    })
                })
                .collect();
            pool.run(tasks);
        }));
        assert!(result.is_err());
        // Every non-panicking task still ran (the barrier held).
        assert_eq!(finished.load(Ordering::Relaxed), 7);
        // The pool stays usable after a panicked batch.
        let counter = AtomicUsize::new(0);
        pool.run(
            (0..4)
                .map(|_| {
                    let counter = &counter;
                    boxed(move || {
                        counter.fetch_add(1, Ordering::Relaxed);
                    })
                })
                .collect(),
        );
        assert_eq!(counter.load(Ordering::Relaxed), 4);
    }

    #[test]
    fn drop_joins_workers() {
        let pool = WorkerPool::new(8);
        let counter = AtomicUsize::new(0);
        pool.run(
            (0..32)
                .map(|_| {
                    let counter = &counter;
                    boxed(move || {
                        counter.fetch_add(1, Ordering::Relaxed);
                    })
                })
                .collect(),
        );
        assert_eq!(counter.load(Ordering::Relaxed), 32);
        drop(pool); // must not hang
    }
}
