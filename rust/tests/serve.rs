//! The serving battery: parity, top-k, hot swap, format fuzz, and the
//! normalize round trip.
//!
//! The contracts pinned here (ISSUE 6):
//!
//! - **Parity** — daemon-scored batches are bit-identical to one-shot
//!   `ranksvm predict` for the same model/data at `--threads 1/2/8`,
//!   in-process and through the real CLI over stdio.
//! - **Top-k** — bounded-heap results equal brute-force
//!   full-sort-then-truncate (ties broken by the documented
//!   `total_cmp` + index order), for every selector.
//! - **Hot swap** — under concurrent score batches and atomic model
//!   republishes, every response is consistent with exactly one model
//!   version, and versions never run backwards.
//! - **Format** — the seeded single-byte-flip fuzz from
//!   `tests/store.rs`, ported to the `.rsm` format; unknown
//!   version/flag bits are refused on checked AND unchecked opens.
//! - **Normalize** — an `--normalize l2-col` model saved and reloaded
//!   scores *raw* inputs bit-identically to scoring explicitly
//!   pre-normalized data; legacy text models still load.

use ranksvm::coordinator::{memprobe, train, Method, Normalize, RankModel, TrainConfig};
use ranksvm::data::{materialize, synthetic, DatasetView, LoadedDataset};
use ranksvm::serve::{
    handle_connection, protocol, scoring, top_k, Engine, Request, ScoringModel, Selector,
};
use std::io::Cursor;
use std::path::PathBuf;

fn tmp(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("ranksvm_serve_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(name)
}

/// Weights with irrational-ish magnitudes so scores exercise the full
/// mantissa; parity failures can't hide in round numbers.
fn weights(dim: usize) -> Vec<f64> {
    (0..dim).map(|j| ((j as f64) + 0.5).sin() * 1.75).collect()
}

fn norms_of(ds: &ranksvm::data::Dataset) -> Vec<f64> {
    ranksvm::data::store::compute_col_stats(ds.x.view())
        .iter()
        .map(|s| s.sumsq.sqrt())
        .collect()
}

fn assert_bits_eq(a: &[f64], b: &[f64], tag: &str) {
    assert_eq!(a.len(), b.len(), "{tag}: length");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{tag}: row {i}: {x} vs {y}");
    }
}

// ---------------------------------------------------------------- parity

#[test]
fn engine_batches_bit_identical_to_predict_at_any_thread_count() {
    let ds = synthetic::zipf_queries(300, 40, 12, 1.2, 71);
    for (tag, norms) in [("plain", None), ("l2col", Some(norms_of(&ds)))] {
        let w = weights(ds.dim());
        let model = ScoringModel::new(w.clone(), norms.clone()).unwrap();
        let path = tmp(&format!("parity_{tag}.rsm"));
        model.save(&path).unwrap();
        // The reference: the shared kernel over the whole dataset —
        // and, for the plain model, the historical RankModel::predict.
        let expect = model.scores(&ds);
        if norms.is_none() {
            assert_bits_eq(&expect, &RankModel::new(w).predict(&ds), tag);
        }
        let all_rows: Vec<usize> = (0..ds.len()).collect();
        let mut reference_lines: Option<Vec<String>> = None;
        for threads in [1usize, 2, 8] {
            let eng =
                Engine::new(&path, Some(LoadedDataset::Owned(materialize(&ds))), threads, true)
                    .unwrap();
            // One request scoring every row…
            let bulk = eng.run_batch(&[Request::Rows(all_rows.clone())]);
            let Ok(ranksvm::serve::Payload::Scores(got)) = &bulk[0].body else {
                panic!("{tag}: bulk rows failed: {:?}", bulk[0].body)
            };
            assert_bits_eq(got, &expect, &format!("{tag} bulk t={threads}"));
            // …and one single-row request per row, as one batch (each
            // task runs on whatever worker steals it — results must
            // not care).
            let singles: Vec<Request> =
                all_rows.iter().map(|&i| Request::Rows(vec![i])).collect();
            let resp = eng.run_batch(&singles);
            let got: Vec<f64> = resp
                .iter()
                .map(|r| match &r.body {
                    Ok(ranksvm::serve::Payload::Scores(s)) => s[0],
                    other => panic!("{tag}: {other:?}"),
                })
                .collect();
            assert_bits_eq(&got, &expect, &format!("{tag} singles t={threads}"));
            // Rendered wire lines are identical across thread counts.
            let lines: Vec<String> = resp.iter().map(protocol::render).collect();
            match &reference_lines {
                None => reference_lines = Some(lines),
                Some(r) => assert_eq!(&lines, r, "{tag}: wire bytes differ at t={threads}"),
            }
        }
        // And the wire text of each score equals predict's `{}` output.
        for (line, s) in reference_lines.unwrap().iter().zip(&expect) {
            assert_eq!(line, &format!("ok v=1 {s}"), "{tag}: formatting parity");
        }
    }
}

#[test]
fn serve_cli_stdio_is_byte_identical_to_predict_cli() {
    use std::process::{Command, Stdio};
    let Ok(bin) = memprobe::find_cli_bin() else {
        eprintln!("skipping: ranksvm binary not built (run `cargo build --release`)");
        return;
    };
    let ds = synthetic::zipf_queries(120, 16, 10, 1.3, 99);
    let data = tmp("cli_parity.libsvm");
    ranksvm::data::libsvm::write(&ds, &data).unwrap();
    for (tag, norms) in [("plain", None), ("l2col", Some(norms_of(&ds)))] {
        let model_path = tmp(&format!("cli_parity_{tag}.rsm"));
        ScoringModel::new(weights(ds.dim()), norms).unwrap().save(&model_path).unwrap();
        let predict = Command::new(&bin)
            .args(["predict", "--model", model_path.to_str().unwrap()])
            .args(["--data", data.to_str().unwrap()])
            .output()
            .expect("spawn predict");
        assert!(predict.status.success(), "{tag}: {}", String::from_utf8_lossy(&predict.stderr));
        let predict_lines: Vec<String> = String::from_utf8(predict.stdout)
            .unwrap()
            .lines()
            .map(str::to_owned)
            .collect();
        assert_eq!(predict_lines.len(), ds.len(), "{tag}");
        let request: String = format!(
            "rows {}\nquit\n",
            (0..ds.len()).map(|i| i.to_string()).collect::<Vec<_>>().join(" ")
        );
        for threads in ["1", "2", "8"] {
            let mut child = Command::new(&bin)
                .args(["serve", "--model", model_path.to_str().unwrap()])
                .args(["--data", data.to_str().unwrap()])
                .args(["--threads", threads])
                .stdin(Stdio::piped())
                .stdout(Stdio::piped())
                .stderr(Stdio::null())
                .spawn()
                .expect("spawn serve");
            {
                use std::io::Write as _;
                child.stdin.as_mut().unwrap().write_all(request.as_bytes()).unwrap();
            }
            let out = child.wait_with_output().expect("serve exits after quit");
            assert!(out.status.success(), "{tag} t={threads}");
            let stdout = String::from_utf8(out.stdout).unwrap();
            let line = stdout.lines().next().unwrap_or_else(|| panic!("{tag}: no response"));
            let tokens: Vec<&str> = line.split(' ').collect();
            assert_eq!(tokens[0], "ok", "{tag}: {line}");
            assert_eq!(tokens[1], "v=1", "{tag}: {line}");
            // Byte-for-byte: every serve score token equals the
            // corresponding predict output line.
            assert_eq!(tokens.len() - 2, predict_lines.len(), "{tag} t={threads}");
            for (i, (tok, pl)) in tokens[2..].iter().zip(&predict_lines).enumerate() {
                assert_eq!(tok, pl, "{tag} t={threads}: row {i} differs");
            }
        }
    }
}

// ----------------------------------------------------------------- top-k

/// Brute-force reference: full sort by `score desc, row asc`, truncate.
fn brute_top_k(rows: &[usize], scores: &[f64], k: usize) -> Vec<(usize, f64)> {
    let mut idx = rows.to_vec();
    idx.sort_unstable_by(|&a, &b| scores[b].total_cmp(&scores[a]).then(a.cmp(&b)));
    idx.truncate(k);
    idx.into_iter().map(|i| (i, scores[i])).collect()
}

#[test]
fn topk_matches_brute_force_for_every_selector() {
    let ds = synthetic::queries(12, 25, 8, 55);
    let model = ScoringModel::new(weights(ds.dim()), None).unwrap();
    let path = tmp("topk.rsm");
    model.save(&path).unwrap();
    let scores = model.scores(&ds);
    let gi = ranksvm::losses::GroupIndex::build(ds.qid.as_ref().unwrap(), &ds.y);
    let eng = Engine::new(&path, Some(LoadedDataset::Owned(materialize(&ds))), 4, true).unwrap();
    let all: Vec<usize> = (0..ds.len()).collect();
    let some: Vec<usize> = (0..ds.len()).step_by(3).collect();
    for k in [1usize, 3, 25, ds.len(), ds.len() + 10] {
        let mut reqs = vec![
            Request::TopK { k, sel: Selector::All },
            Request::TopK { k, sel: Selector::Rows(some.clone()) },
        ];
        for g in 0..gi.n_groups() {
            reqs.push(Request::TopK { k, sel: Selector::Group(g) });
        }
        let resp = eng.run_batch(&reqs);
        let ranked = |r: &ranksvm::serve::Response| match &r.body {
            Ok(ranksvm::serve::Payload::Ranked(v)) => v.clone(),
            other => panic!("topk failed: {other:?}"),
        };
        assert_eq!(ranked(&resp[0]), brute_top_k(&all, &scores, k), "all k={k}");
        assert_eq!(ranked(&resp[1]), brute_top_k(&some, &scores, k), "rows k={k}");
        for g in 0..gi.n_groups() {
            assert_eq!(
                ranked(&resp[2 + g]),
                brute_top_k(gi.group(g), &scores, k),
                "group {g} k={k}"
            );
        }
    }
}

#[test]
fn topk_tie_breaking_is_documented_order() {
    // All-equal scores: top-k must be the k smallest row indices, and
    // NaN (total_cmp's maximum) must sort above everything without
    // panicking — same contract as RankModel::rank.
    let ties: Vec<f64> = vec![2.5; 9];
    assert_eq!(
        top_k(ties.iter().copied().enumerate(), 4),
        vec![(0, 2.5), (1, 2.5), (2, 2.5), (3, 2.5)]
    );
    let mut with_nan = ties.clone();
    with_nan[6] = f64::NAN;
    let got = top_k(with_nan.iter().copied().enumerate(), 3);
    assert_eq!(got[0].0, 6, "NaN ranks first under total_cmp");
    assert_eq!((got[1].0, got[2].0), (0, 1));
}

// ------------------------------------------------- structured errors

#[test]
fn malformed_requests_get_structured_errors_never_panics() {
    let ds = synthetic::queries(5, 8, 6, 3);
    let m = ds.len();
    let path = tmp("errors.rsm");
    ScoringModel::new(weights(ds.dim()), None).unwrap().save(&path).unwrap();
    let eng = Engine::new(&path, Some(LoadedDataset::Owned(ds)), 2, true).unwrap();
    let garbage = format!(
        "score 99:1.0\nscore 7:2.0\nrows {m}\nrows 0 {m}\ntopk 3 group 99\n\
         topk nope all\nscore 0:1\nscore 1:nan\nscore 3:1 1:2\nnonsense line\n\
         rows -4\nscore\ntopk 0 all\nbatch 0\n\u{1F980} crab\nscore 1:1e309\nquit\n"
    );
    let mut out = Vec::new();
    handle_connection(&eng, Cursor::new(garbage.into_bytes()), &mut out).unwrap();
    let text = String::from_utf8(out).unwrap();
    let lines: Vec<&str> = text.lines().collect();
    assert_eq!(lines.len(), 16, "one response per request line: {lines:?}");
    // Every request above is malformed, out of range, or out of dim
    // (the store is dim 6, so 1-based index 7 is already too wide).
    for (i, line) in lines.iter().enumerate() {
        assert!(line.starts_with("err "), "line {i} should be an error: {line}");
        assert!(line.len() > 4, "line {i}: error must carry a message");
    }
    // Sanity: the same engine still serves good requests afterwards.
    let ok = eng.run_batch(&[Request::Rows(vec![0])]);
    assert!(ok[0].body.is_ok());
}

#[test]
fn requests_needing_a_store_fail_cleanly_without_one() {
    let path = tmp("nostore.rsm");
    ScoringModel::new(weights(4), None).unwrap().save(&path).unwrap();
    let eng = Engine::new(&path, None, 1, true).unwrap();
    let resp = eng.run_batch(&[
        Request::Rows(vec![0]),
        Request::TopK { k: 3, sel: Selector::All },
        Request::Score(vec![(1, 2.0)]),
    ]);
    assert!(resp[0].body.as_ref().unwrap_err().contains("--data"), "{:?}", resp[0].body);
    assert!(resp[1].body.as_ref().unwrap_err().contains("--data"), "{:?}", resp[1].body);
    assert!(resp[2].body.is_ok(), "score needs no store");
}

// -------------------------------------------------------------- hot swap

#[test]
fn concurrent_batches_see_exactly_one_version_each() {
    use std::collections::HashMap;
    use std::sync::atomic::{AtomicBool, Ordering};
    use std::sync::Mutex;

    let ds = synthetic::cadata_like(80, 17);
    let dim = ds.dim();
    let rows: Vec<usize> = (0..ds.len()).collect();
    // Two models with everywhere-different scores.
    let model_a = ScoringModel::new(vec![1.0; dim], None).unwrap();
    let model_b = ScoringModel::new(vec![-2.0; dim], None).unwrap();
    let expect_a = model_a.scores(&ds);
    let expect_b = model_b.scores(&ds);
    let live = tmp("hotswap_live.rsm");
    model_a.save(&live).unwrap();
    let eng = Engine::new(&live, Some(LoadedDataset::Owned(ds)), 4, true).unwrap();

    let stop = AtomicBool::new(false);
    let seen: Mutex<HashMap<u64, Vec<f64>>> = Mutex::new(HashMap::new());
    std::thread::scope(|s| {
        // Swapper: republish A/B alternately via the atomic save path.
        s.spawn(|| {
            for i in 0..40u32 {
                let m = if i % 2 == 0 { &model_b } else { &model_a };
                m.save(&live).unwrap();
                std::thread::sleep(std::time::Duration::from_millis(2));
            }
            stop.store(true, Ordering::Relaxed);
        });
        // Scorers: hammer batches, recording (version, scores).
        for _ in 0..3 {
            s.spawn(|| {
                let mut last_version = 0u64;
                while !stop.load(Ordering::Relaxed) {
                    let resp = eng.run_batch(&[Request::Rows(rows.clone())]);
                    let r = &resp[0];
                    let Ok(ranksvm::serve::Payload::Scores(scores)) = &r.body else {
                        panic!("batch failed: {:?}", r.body)
                    };
                    // Versions never run backwards within a scorer.
                    assert!(r.version >= last_version, "{} < {last_version}", r.version);
                    last_version = r.version;
                    // The whole batch matches exactly one model.
                    let is_a = scores[0].to_bits() == expect_a[0].to_bits();
                    let expect = if is_a { &expect_a } else { &expect_b };
                    for (i, (g, e)) in scores.iter().zip(expect).enumerate() {
                        assert_eq!(
                            g.to_bits(),
                            e.to_bits(),
                            "row {i}: torn batch at version {}",
                            r.version
                        );
                    }
                    // And one version always maps to one score vector.
                    let mut seen = seen.lock().unwrap();
                    match seen.get(&r.version) {
                        None => {
                            seen.insert(r.version, scores.clone());
                        }
                        Some(prev) => {
                            for (p, g) in prev.iter().zip(scores) {
                                assert_eq!(
                                    p.to_bits(),
                                    g.to_bits(),
                                    "version {} served two different models",
                                    r.version
                                );
                            }
                        }
                    }
                }
            });
        }
    });
    let (_, _, swaps) = eng.counters();
    assert!(swaps > 0, "the test never actually swapped");
}

#[test]
fn swap_command_round_trips_through_the_daemon() {
    let ds = synthetic::cadata_like(30, 9);
    let dim = ds.dim();
    let live = tmp("swapcmd_live.rsm");
    let staged = tmp("swapcmd_staged.rsm");
    ScoringModel::new(vec![0.5; dim], None).unwrap().save(&live).unwrap();
    ScoringModel::new(vec![3.0; dim], None).unwrap().save(&staged).unwrap();
    let eng = Engine::new(&live, Some(LoadedDataset::Owned(ds)), 2, true).unwrap();
    let input = format!("rows 0 1 2\nswap {}\nrows 0 1 2\nquit\n", staged.display());
    let mut out = Vec::new();
    handle_connection(&eng, Cursor::new(input.into_bytes()), &mut out).unwrap();
    let text = String::from_utf8(out).unwrap();
    let lines: Vec<&str> = text.lines().collect();
    assert_eq!(lines.len(), 3, "{lines:?}");
    let before = lines[0].strip_prefix("ok v=1 ").expect(lines[0]);
    assert_eq!(lines[1], "ok v=2 swapped=true");
    let after = lines[2].strip_prefix("ok v=2 ").expect(lines[2]);
    assert_ne!(before, after, "the staged model must actually change the scores");
    assert!(!staged.exists(), "swap consumes the staged file (rename, not copy)");
}

// ---------------------------------------------------------- format fuzz

/// The store's seeded flip fuzz, ported: any single-byte flip over a
/// valid model must surface as a *structured error* from `open()` —
/// never a panic, never a silent success. The unchecked path may accept
/// a payload flip by contract, but must never panic either.
#[test]
fn fuzzed_single_byte_flips_never_panic_and_always_error() {
    use ranksvm::util::rng::Rng;
    use std::panic::{catch_unwind, AssertUnwindSafe};
    let good_path = tmp("fuzz_good.rsm");
    let w: Vec<f64> = weights(37);
    let norms: Vec<f64> = (0..37).map(|j| (j as f64 * 0.37).cos().abs() + 0.1).collect();
    ScoringModel::new(w, Some(norms)).unwrap().save(&good_path).unwrap();
    let good = std::fs::read(&good_path).unwrap();
    let victim = tmp("fuzz_flip.rsm");
    let mut rng = Rng::new(0xF11B);
    for trial in 0..250usize {
        let pos = rng.below(good.len());
        let bit = 1u8 << rng.below(8);
        let mut bad = good.clone();
        bad[pos] ^= bit;
        std::fs::write(&victim, &bad).unwrap();
        let outcome = catch_unwind(AssertUnwindSafe(|| ScoringModel::open(&victim).map(|_| ())));
        let Ok(result) = outcome else {
            panic!("trial {trial}: open() panicked on byte {pos} bit {bit:#04x}")
        };
        let err = match result {
            Err(e) => e,
            Ok(()) => panic!(
                "trial {trial}: model with byte {pos} bit {bit:#04x} flipped \
                 opened successfully — corruption went undetected"
            ),
        };
        assert!(!err.to_string().is_empty(), "empty error message");
        let unchecked = catch_unwind(AssertUnwindSafe(|| {
            ScoringModel::open_unchecked(&victim).map(|_| ()).is_ok()
        }));
        assert!(
            unchecked.is_ok(),
            "trial {trial}: open_unchecked() panicked on byte {pos} bit {bit:#04x}"
        );
    }
}

#[test]
fn unknown_version_and_flags_refused_on_both_open_paths() {
    use ranksvm::data::store::Checksum;
    let path = tmp("refusal_good.rsm");
    ScoringModel::new(weights(9), None).unwrap().save(&path).unwrap();
    let good = std::fs::read(&path).unwrap();

    // Re-checksum a doctored file so the refusal under test is the
    // structural one, not a checksum mismatch.
    let reseal = |mut bytes: Vec<u8>| -> Vec<u8> {
        let mut sum = Checksum::new();
        sum.update(&bytes[scoring::MODEL_HEADER_LEN..]);
        sum.update(&bytes[..scoring::MODEL_CHECKSUM_FIELD.start]);
        sum.update(&bytes[scoring::MODEL_CHECKSUM_FIELD.end..scoring::MODEL_HEADER_LEN]);
        let digest = sum.finish().to_le_bytes();
        bytes[scoring::MODEL_CHECKSUM_FIELD].copy_from_slice(&digest);
        bytes
    };

    let open_both = |path: &PathBuf, needle: &str| {
        for unchecked in [false, true] {
            let result = if unchecked {
                ScoringModel::open_unchecked(path).map(|_| ())
            } else {
                ScoringModel::open(path).map(|_| ())
            };
            let err = result.unwrap_err().to_string();
            assert!(err.contains(needle), "unchecked={unchecked}: {err}");
        }
    };

    // Future version byte, checksum valid → version refusal, both paths.
    let mut future = good.clone();
    future[7] = scoring::MODEL_VERSION + 1;
    let future_path = tmp("refusal_version.rsm");
    std::fs::write(&future_path, reseal(future)).unwrap();
    open_both(&future_path, "version");

    // Unknown flag bit, checksum valid → flag refusal, both paths.
    let mut flagged = good.clone();
    flagged[16] |= 0x80;
    let flagged_path = tmp("refusal_flag.rsm");
    std::fs::write(&flagged_path, reseal(flagged)).unwrap();
    open_both(&flagged_path, "flag");

    // Control: the reseal helper itself round-trips the good file.
    let resealed_path = tmp("refusal_control.rsm");
    std::fs::write(&resealed_path, reseal(good)).unwrap();
    assert!(ScoringModel::open(&resealed_path).is_ok());
}

// ------------------------------------------------- normalize round trip

#[test]
fn l2col_model_round_trips_and_scores_raw_inputs_bit_identically() {
    let ds = synthetic::cadata_like(250, 41);
    let cfg = TrainConfig {
        method: Method::Tree,
        lambda: 0.1,
        epsilon: 1e-3,
        normalize: Normalize::L2Col,
        ..Default::default()
    };
    let out = train(&ds, &cfg).unwrap();
    assert!(out.converged);
    let path = tmp("roundtrip_l2col.rsm");
    out.scoring_model().save(&path).unwrap();
    let back = ScoringModel::load_auto(&path).unwrap();
    assert_eq!(back.normalize_name(), "l2-col");
    assert_eq!(back.w(), &out.model.w[..]);

    // Reference: pre-normalize explicitly, score with a plain model.
    let norms = norms_of(&ds);
    let mut scaled = materialize(&ds);
    scaled.x.map_values(|c, v| if norms[c] > 0.0 { v / norms[c] } else { v });
    let plain = ScoringModel::new(out.model.w.clone(), None).unwrap();
    assert_bits_eq(&back.scores(&ds), &plain.scores(&scaled), "predict path");

    // Serving path: same raw rows through the engine.
    let eng = Engine::new(&path, Some(LoadedDataset::Owned(materialize(&ds))), 3, true).unwrap();
    let resp = eng.run_batch(&[Request::Rows((0..ds.len()).collect())]);
    let Ok(ranksvm::serve::Payload::Scores(served)) = &resp[0].body else {
        panic!("{:?}", resp[0].body)
    };
    assert_bits_eq(served, &plain.scores(&scaled), "serve path");
}

#[test]
fn legacy_text_models_serve_unnormalized() {
    let ds = synthetic::cadata_like(40, 23);
    let rank = RankModel::new(weights(ds.dim()));
    let path = tmp("legacy_serve.txt");
    rank.save(&path).unwrap();
    // The daemon loads the legacy format and scores raw features as-is.
    let eng = Engine::new(&path, Some(LoadedDataset::Owned(materialize(&ds))), 2, true).unwrap();
    assert_eq!(eng.current().model.normalize_name(), "none");
    let resp = eng.run_batch(&[Request::Rows((0..ds.len()).collect())]);
    let Ok(ranksvm::serve::Payload::Scores(served)) = &resp[0].body else {
        panic!("{:?}", resp[0].body)
    };
    assert_bits_eq(served, &rank.predict(&ds), "legacy parity");
}
