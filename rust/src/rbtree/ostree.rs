//! Order-statistics red-black tree (Definition 1 of the paper).
//!
//! A self-balancing binary search tree over real-valued keys, augmented
//! with subtree sizes so that the number of stored keys strictly smaller
//! (`Count-Smaller`, Algorithm 2) or strictly larger (`Count-Larger`) than
//! a query value is computed in `O(log m)`. Together with `Tree-Insert`
//! (Lemma 3) these are the three operations Algorithm 3 needs.
//!
//! Implementation notes:
//! - **Array-backed nodes** (`Vec<Node>`, `u32` links, index 0 is the NIL
//!   sentinel): no per-node allocation, cache-friendly, and `clear()`
//!   lets the BMRM loop reuse one tree across iterations (§Perf).
//! - **Duplicate keys** are supported two ways, matching §4.2 of the
//!   paper: the default inserts a distinct node per duplicate; the
//!   *dedup* mode (`OsTree::new_dedup`) stores a multiplicity counter
//!   `nodesize` per distinct key, bounding the height by `O(log r)` where
//!   `r` is the number of distinct keys.
//! - Counting is **strict** (`<` / `>`), exactly what eqs. (5)–(6) need:
//!   ties in `y` contribute to neither `c_i` nor `d_i`.

const NIL: u32 = 0;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Color {
    Red,
    Black,
}

#[derive(Clone, Debug)]
struct Node {
    key: f64,
    left: u32,
    right: u32,
    parent: u32,
    color: Color,
    /// Total multiplicity stored in this subtree (`size` of Definition 1,
    /// generalized by the dedup variant's `nodesize` re-definition).
    size: u32,
    /// Multiplicity at this node (1 unless dedup mode merges duplicates).
    nodesize: u32,
}

/// Order-statistics red-black tree over `f64` keys.
#[derive(Clone, Debug)]
pub struct OsTree {
    nodes: Vec<Node>,
    root: u32,
    dedup: bool,
    /// Free list head for reuse after `clear()` — we simply truncate, so
    /// this tracks nothing today, but `clear` keeps capacity.
    len: u64,
}

impl OsTree {
    /// New tree; every insert creates a node (paper's base variant).
    pub fn new() -> Self {
        Self::with_mode(false)
    }

    /// New tree merging duplicate keys into one node with a multiplicity
    /// counter (the `nodesize` variant from §4.2; height `O(log r)`).
    pub fn new_dedup() -> Self {
        Self::with_mode(true)
    }

    fn with_mode(dedup: bool) -> Self {
        let sentinel = Node {
            key: f64::NAN,
            left: NIL,
            right: NIL,
            parent: NIL,
            color: Color::Black,
            size: 0,
            nodesize: 0,
        };
        OsTree { nodes: vec![sentinel], root: NIL, dedup, len: 0 }
    }

    /// Pre-allocate node storage for `cap` inserts.
    pub fn with_capacity(cap: usize) -> Self {
        let mut t = Self::new();
        t.nodes.reserve(cap);
        t
    }

    /// Number of keys stored (counting multiplicity).
    pub fn len(&self) -> u64 {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Number of tree nodes (distinct keys in dedup mode).
    pub fn node_count(&self) -> usize {
        self.nodes.len() - 1
    }

    /// Remove all keys, retaining allocated capacity for reuse.
    pub fn clear(&mut self) {
        self.nodes.truncate(1);
        self.root = NIL;
        self.len = 0;
    }

    #[inline]
    fn n(&self, i: u32) -> &Node {
        &self.nodes[i as usize]
    }

    #[inline]
    fn nm(&mut self, i: u32) -> &mut Node {
        &mut self.nodes[i as usize]
    }

    #[inline]
    fn fix_size(&mut self, x: u32) {
        let l = self.n(self.n(x).left).size;
        let r = self.n(self.n(x).right).size;
        let ns = self.n(x).nodesize;
        self.nm(x).size = l + r + ns;
    }

    /// `Tree-Insert(T, key)` — Lemma 3: `O(log m)` (`O(log r)` in dedup
    /// mode). NaN keys are rejected (would break the search-tree order).
    pub fn insert(&mut self, key: f64) {
        assert!(!key.is_nan(), "NaN keys are not orderable");
        self.len += 1;
        if self.root == NIL {
            let id = self.alloc(key, NIL);
            self.nm(id).color = Color::Black;
            self.root = id;
            return;
        }
        // Descend, bumping subtree sizes on the way (every ancestor of the
        // new/incremented node gains one unit of multiplicity).
        let mut x = self.root;
        loop {
            self.nm(x).size += 1;
            let k = self.n(x).key;
            if self.dedup && key == k {
                self.nm(x).nodesize += 1;
                return;
            }
            if key < k {
                let l = self.n(x).left;
                if l == NIL {
                    let id = self.alloc(key, x);
                    self.nm(x).left = id;
                    self.insert_fixup(id);
                    return;
                }
                x = l;
            } else {
                let r = self.n(x).right;
                if r == NIL {
                    let id = self.alloc(key, x);
                    self.nm(x).right = id;
                    self.insert_fixup(id);
                    return;
                }
                x = r;
            }
        }
    }

    fn alloc(&mut self, key: f64, parent: u32) -> u32 {
        let id = self.nodes.len() as u32;
        self.nodes.push(Node {
            key,
            left: NIL,
            right: NIL,
            parent,
            color: Color::Red,
            size: 1,
            nodesize: 1,
        });
        id
    }

    /// CLRS left rotation with size-augmentation maintenance: the rotated
    /// pair exchange subtree roles, so `y` inherits `x`'s old size and
    /// `x` is recomputed from its new children.
    fn rotate_left(&mut self, x: u32) {
        let y = self.n(x).right;
        debug_assert_ne!(y, NIL);
        let yl = self.n(y).left;
        self.nm(x).right = yl;
        if yl != NIL {
            self.nm(yl).parent = x;
        }
        let xp = self.n(x).parent;
        self.nm(y).parent = xp;
        if xp == NIL {
            self.root = y;
        } else if self.n(xp).left == x {
            self.nm(xp).left = y;
        } else {
            self.nm(xp).right = y;
        }
        self.nm(y).left = x;
        self.nm(x).parent = y;
        // Augmentation: y takes over x's old subtree size; x shrinks.
        self.nm(y).size = self.n(x).size;
        self.fix_size(x);
    }

    fn rotate_right(&mut self, x: u32) {
        let y = self.n(x).left;
        debug_assert_ne!(y, NIL);
        let yr = self.n(y).right;
        self.nm(x).left = yr;
        if yr != NIL {
            self.nm(yr).parent = x;
        }
        let xp = self.n(x).parent;
        self.nm(y).parent = xp;
        if xp == NIL {
            self.root = y;
        } else if self.n(xp).left == x {
            self.nm(xp).left = y;
        } else {
            self.nm(xp).right = y;
        }
        self.nm(y).right = x;
        self.nm(x).parent = y;
        self.nm(y).size = self.n(x).size;
        self.fix_size(x);
    }

    /// CLRS RB-Insert-Fixup: restore red-black invariants after inserting
    /// the red node `z`.
    fn insert_fixup(&mut self, mut z: u32) {
        while self.n(self.n(z).parent).color == Color::Red {
            let p = self.n(z).parent;
            let g = self.n(p).parent;
            if p == self.n(g).left {
                let u = self.n(g).right;
                if self.n(u).color == Color::Red {
                    self.nm(p).color = Color::Black;
                    self.nm(u).color = Color::Black;
                    self.nm(g).color = Color::Red;
                    z = g;
                } else {
                    if z == self.n(p).right {
                        z = p;
                        self.rotate_left(z);
                    }
                    let p = self.n(z).parent;
                    let g = self.n(p).parent;
                    self.nm(p).color = Color::Black;
                    self.nm(g).color = Color::Red;
                    self.rotate_right(g);
                }
            } else {
                let u = self.n(g).left;
                if self.n(u).color == Color::Red {
                    self.nm(p).color = Color::Black;
                    self.nm(u).color = Color::Black;
                    self.nm(g).color = Color::Red;
                    z = g;
                } else {
                    if z == self.n(p).left {
                        z = p;
                        self.rotate_right(z);
                    }
                    let p = self.n(z).parent;
                    let g = self.n(p).parent;
                    self.nm(p).color = Color::Black;
                    self.nm(g).color = Color::Red;
                    self.rotate_left(g);
                }
            }
        }
        let r = self.root;
        self.nm(r).color = Color::Black;
    }

    /// `Count-Smaller(root, k)` — Algorithm 2 / Lemma 4: number of stored
    /// keys strictly smaller than `k`, counting multiplicity. `O(log m)`.
    pub fn count_smaller(&self, k: f64) -> u64 {
        let mut c: u64 = 0;
        let mut x = self.root;
        while x != NIL {
            let node = self.n(x);
            if node.key < k {
                c += (self.n(node.left).size + node.nodesize) as u64;
                x = node.right;
            } else {
                x = node.left;
            }
        }
        c
    }

    /// `Count-Larger(root, k)` — mirror of Algorithm 2: keys strictly
    /// larger than `k`. `O(log m)`.
    pub fn count_larger(&self, k: f64) -> u64 {
        let mut c: u64 = 0;
        let mut x = self.root;
        while x != NIL {
            let node = self.n(x);
            if node.key > k {
                c += (self.n(node.right).size + node.nodesize) as u64;
                x = node.left;
            } else {
                x = node.right;
            }
        }
        c
    }

    /// Height of the tree (root-to-deepest-leaf edge count; -1 for empty).
    /// Exposed for the balance tests and the ablation bench.
    pub fn height(&self) -> i64 {
        fn h(t: &OsTree, x: u32) -> i64 {
            if x == NIL {
                -1
            } else {
                1 + h(t, t.n(x).left).max(h(t, t.n(x).right))
            }
        }
        h(self, self.root)
    }

    /// Validate every invariant of Definition 1 plus the red-black rules;
    /// panics with a description on violation. Test-support API.
    pub fn check_invariants(&self) {
        if self.root == NIL {
            assert_eq!(self.len, 0);
            return;
        }
        assert_eq!(self.n(self.root).color, Color::Black, "root must be black");
        assert_eq!(self.n(self.root).parent, NIL, "root parent must be NIL");
        let (size, _black_height) = self.check_node(self.root, f64::NEG_INFINITY, f64::INFINITY);
        assert_eq!(size as u64, self.len, "root size must equal total multiplicity");
    }

    fn check_node(&self, x: u32, lo: f64, hi: f64) -> (u32, u32) {
        if x == NIL {
            return (0, 1);
        }
        let node = self.n(x);
        assert!(node.key >= lo && node.key <= hi, "BST property violated");
        assert!(node.nodesize >= 1);
        if !self.dedup {
            assert_eq!(node.nodesize, 1, "non-dedup tree must have unit nodesize");
        }
        if node.color == Color::Red {
            assert_eq!(self.n(node.left).color, Color::Black, "red node with red left child");
            assert_eq!(self.n(node.right).color, Color::Black, "red node with red right child");
        }
        if node.left != NIL {
            assert_eq!(self.n(node.left).parent, x, "broken parent link (left)");
        }
        if node.right != NIL {
            assert_eq!(self.n(node.right).parent, x, "broken parent link (right)");
        }
        let (ls, lb) = self.check_node(node.left, lo, node.key);
        let (rs, rb) = self.check_node(node.right, node.key, hi);
        assert_eq!(lb, rb, "black-height mismatch");
        assert_eq!(node.size, ls + rs + node.nodesize, "size augmentation wrong");
        let bh = lb + if node.color == Color::Black { 1 } else { 0 };
        (node.size, bh)
    }
}

impl Default for OsTree {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// Brute-force oracle: counts over a plain vector.
    struct Oracle(Vec<f64>);
    impl Oracle {
        fn count_smaller(&self, k: f64) -> u64 {
            self.0.iter().filter(|&&x| x < k).count() as u64
        }
        fn count_larger(&self, k: f64) -> u64 {
            self.0.iter().filter(|&&x| x > k).count() as u64
        }
    }

    #[test]
    fn empty_tree_counts_zero() {
        let t = OsTree::new();
        assert_eq!(t.count_smaller(0.0), 0);
        assert_eq!(t.count_larger(0.0), 0);
        assert!(t.is_empty());
        t.check_invariants();
    }

    #[test]
    fn single_element() {
        let mut t = OsTree::new();
        t.insert(5.0);
        assert_eq!(t.count_smaller(5.0), 0);
        assert_eq!(t.count_larger(5.0), 0);
        assert_eq!(t.count_smaller(6.0), 1);
        assert_eq!(t.count_larger(4.0), 1);
        t.check_invariants();
    }

    #[test]
    fn strictness_with_duplicates() {
        for dedup in [false, true] {
            let mut t = OsTree::with_mode(dedup);
            for &k in &[1.0, 2.0, 2.0, 2.0, 3.0] {
                t.insert(k);
            }
            assert_eq!(t.len(), 5);
            assert_eq!(t.count_smaller(2.0), 1);
            assert_eq!(t.count_larger(2.0), 1);
            assert_eq!(t.count_smaller(2.5), 4);
            assert_eq!(t.count_larger(1.5), 4);
            t.check_invariants();
            if dedup {
                assert_eq!(t.node_count(), 3);
            } else {
                assert_eq!(t.node_count(), 5);
            }
        }
    }

    #[test]
    fn ascending_descending_insertions_stay_balanced() {
        for dir in 0..2 {
            let mut t = OsTree::new();
            for i in 0..4096 {
                let k = if dir == 0 { i as f64 } else { (4096 - i) as f64 };
                t.insert(k);
            }
            t.check_invariants();
            // RB height bound: 2*log2(n+1) ≈ 24 for n=4096.
            assert!(t.height() <= 26, "height {} too large", t.height());
        }
    }

    #[test]
    fn randomized_against_oracle() {
        let mut rng = Rng::new(1234);
        for trial in 0..30 {
            let dedup = trial % 2 == 0;
            let mut t = OsTree::with_mode(dedup);
            let mut oracle = Oracle(Vec::new());
            let n = 1 + rng.below(400);
            // Small key universe to force many duplicates.
            let universe = 1 + rng.below(50);
            for _ in 0..n {
                let k = rng.below(universe) as f64;
                t.insert(k);
                oracle.0.push(k);
            }
            t.check_invariants();
            for _ in 0..50 {
                let q = rng.range(-2.0, universe as f64 + 2.0);
                assert_eq!(t.count_smaller(q), oracle.count_smaller(q), "smaller({q})");
                assert_eq!(t.count_larger(q), oracle.count_larger(q), "larger({q})");
            }
            // Also query exact stored keys (tie behaviour).
            for &k in oracle.0.iter().take(20) {
                assert_eq!(t.count_smaller(k), oracle.count_smaller(k));
                assert_eq!(t.count_larger(k), oracle.count_larger(k));
            }
        }
    }

    #[test]
    fn invariants_hold_after_every_insert() {
        let mut rng = Rng::new(99);
        let mut t = OsTree::new();
        for _ in 0..600 {
            t.insert(rng.normal());
            t.check_invariants();
        }
    }

    #[test]
    fn clear_reuses_storage() {
        let mut t = OsTree::new();
        for i in 0..100 {
            t.insert(i as f64);
        }
        let cap = t.nodes.capacity();
        t.clear();
        assert!(t.is_empty());
        assert_eq!(t.count_smaller(50.0), 0);
        for i in 0..100 {
            t.insert(i as f64);
        }
        t.check_invariants();
        assert_eq!(t.nodes.capacity(), cap);
        assert_eq!(t.count_smaller(50.0), 50);
    }

    #[test]
    fn dedup_height_bounded_by_distinct_keys() {
        let mut t = OsTree::new_dedup();
        let mut rng = Rng::new(7);
        for _ in 0..10_000 {
            t.insert(rng.below(8) as f64); // r = 8 distinct keys
        }
        t.check_invariants();
        assert_eq!(t.node_count(), 8);
        assert!(t.height() <= 7); // 2*log2(9) ≈ 6.3
        assert_eq!(t.len(), 10_000);
    }

    #[test]
    #[should_panic]
    fn nan_key_rejected() {
        let mut t = OsTree::new();
        t.insert(f64::NAN);
    }

    #[test]
    fn negative_and_extreme_keys() {
        let mut t = OsTree::new();
        for &k in &[f64::MIN, -1e300, -1.0, 0.0, 1.0, 1e300, f64::MAX] {
            t.insert(k);
        }
        t.check_invariants();
        assert_eq!(t.count_smaller(0.0), 3);
        assert_eq!(t.count_larger(0.0), 3);
        assert_eq!(t.count_smaller(f64::INFINITY), 7);
        assert_eq!(t.count_larger(f64::NEG_INFINITY), 7);
    }
}
