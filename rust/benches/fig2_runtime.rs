//! Figure 2 — train-to-convergence runtimes for all RankSVM
//! implementations: TreeRSVM, PairRSVM, SVM^rank (r-level), PRSVM
//! (truncated Newton), on Cadata-like and Reuters-like data.
//!
//! Paper settings reproduced: ε = 1e-3 (Newton decrement 1e-6 for
//! PRSVM), λ = 1e-1 (cadata) / 1e-5 (reuters). Quadratic-cost methods
//! are capped at smaller m by default (the paper let SVM^rank run for
//! 83 h; we do not) — `FULL=1` lifts the caps.

mod common;

use common::{data_from_env, fmt_secs, full_scale, header, prefix_grid, record};
use ranksvm::coordinator::{train, Method, TrainConfig};
use ranksvm::data::{synthetic, Dataset, DatasetView};
use ranksvm::util::json::Json;

fn run(ds: &dyn DatasetView, method: Method, lambda: f64) -> (f64, usize, bool) {
    let cfg = TrainConfig { method, lambda, epsilon: 1e-3, ..Default::default() };
    let out = train(ds, &cfg).expect("training failed");
    (out.train_secs, out.iterations, out.converged)
}

fn panel(
    name: &str,
    make: &dyn Fn(usize) -> Dataset,
    sizes: &[usize],
    lambda: f64,
    caps: &[(Method, usize)],
) {
    header(&format!("Fig 2 ({name}): training runtime to convergence (ε=1e-3, λ={lambda})"));
    let methods = [Method::Tree, Method::Pair, Method::RLevel, Method::Prsvm];
    print!("{:>9}", "m");
    for m in &methods {
        print!(" {:>14}", m.name());
    }
    println!();
    for &m in sizes {
        let ds = make(m);
        print!("{m:>9}");
        for &method in &methods {
            let cap =
                caps.iter().find(|(mm, _)| *mm == method).map(|(_, c)| *c).unwrap_or(usize::MAX);
            if m > cap {
                print!(" {:>14}", "(skipped)");
                continue;
            }
            let (secs, iters, converged) = run(&ds, method, lambda);
            print!(" {:>14}", fmt_secs(secs));
            record(
                "fig2_runtime",
                Json::obj(vec![
                    ("panel", name.into()),
                    ("m", m.into()),
                    ("method", method.name().into()),
                    ("secs", secs.into()),
                    ("iterations", iters.into()),
                    ("converged", converged.into()),
                ]),
            );
        }
        println!();
    }
}

fn main() {
    let full = full_scale();
    let cadata_sizes = vec![1000, 2000, 4000, 8000, 16000];
    let reuters_sizes: Vec<usize> = if full {
        vec![1000, 2000, 4000, 8000, 16000, 32000, 64000, 128000, 256000, 512000]
    } else {
        vec![1000, 2000, 4000, 8000, 16000, 32000]
    };
    // Paper: PRSVM could not go past 8000 (memory). Quadratic-time
    // methods capped by default to keep `cargo bench` in minutes.
    let cadata_caps: Vec<(Method, usize)> = if full {
        vec![(Method::Prsvm, 8000)]
    } else {
        vec![(Method::Prsvm, 4000), (Method::Pair, 16000), (Method::RLevel, 16000)]
    };
    let reuters_caps: Vec<(Method, usize)> = if full {
        vec![(Method::Prsvm, 8000)]
    } else {
        vec![(Method::Prsvm, 2000), (Method::Pair, 8000), (Method::RLevel, 8000)]
    };

    panel("cadata", &|m| synthetic::cadata_like(m, 100), &cadata_sizes, 1e-1, &cadata_caps);
    panel("reuters", &|m| synthetic::reuters_like(m, 200), &reuters_sizes, 1e-5, &reuters_caps);

    // Real-data panel: train-to-convergence on growing zero-copy
    // prefixes of a mapped store (RANKSVM_DATA=foo.pstore).
    if let Some(loaded) = data_from_env() {
        let view = loaded.view();
        header(&format!(
            "Fig 2 ({}): training runtime to convergence, growing prefixes",
            view.name()
        ));
        println!("{:>9} {:>14} {:>7} {:>10}", "m", "tree", "iters", "converged");
        for m in prefix_grid(view.len()) {
            let prefix = view.prefix_view(m);
            let (secs, iters, converged) = run(&prefix, Method::Tree, 1e-4);
            println!("{m:>9} {:>14} {iters:>7} {converged:>10}", fmt_secs(secs));
            record(
                "fig2_runtime",
                Json::obj(vec![
                    ("panel", view.name().into()),
                    ("m", m.into()),
                    ("method", Method::Tree.name().into()),
                    ("secs", secs.into()),
                    ("iterations", iters.into()),
                    ("converged", converged.into()),
                ]),
            );
        }
    }

    println!("\nExpected shape (paper): TreeRSVM orders of magnitude below the");
    println!("quadratic methods at large m; r ≈ m makes rlevel ≈ pair here.");
}
