//! Figure 3 — peak memory usage vs training-set size on Reuters-like
//! data: TreeRSVM, SVM^rank (r-level), PRSVM.
//!
//! Each (method, m) point runs in a fresh child process (`ranksvm
//! mem-probe`) whose VmHWM is reported back — in-process peaks would
//! contaminate each other. The paper's shape: PRSVM blows up
//! quadratically (several GB at 8k), TreeRSVM and SVM^rank settle into
//! linear growth; TreeRSVM carries a constant-factor overhead from the
//! extra index/buffer copies (paper: ~2.5× SVM^rank; here both are the
//! same process so the contrast is tree-vs-prsvm).
//!
//! Requires the CLI binary: `cargo build --release` first (cargo bench
//! builds it automatically as part of the workspace).

mod common;

use common::{full_scale, header, record};
use ranksvm::coordinator::{memprobe, Method};
use ranksvm::util::json::Json;

fn main() {
    header("Fig 3: peak memory (MiB) vs m — reuters-like");
    if memprobe::find_cli_bin().is_err() {
        println!("ranksvm CLI binary not found — run `cargo build --release` first");
        return;
    }
    let full = full_scale();
    let sizes: Vec<usize> = if full {
        vec![1000, 2000, 4000, 8000, 16000, 32000, 64000, 128000]
    } else {
        vec![1000, 2000, 4000, 8000, 16000]
    };
    let methods = [Method::Tree, Method::RLevel, Method::Prsvm];
    let prsvm_cap = if full { 8000 } else { 8000 }; // paper: OOM past 8000

    print!("{:>9}", "m");
    for m in &methods {
        print!(" {:>12}", m.name());
    }
    println!();
    for &m in &sizes {
        print!("{m:>9}");
        for &method in &methods {
            if method == Method::Prsvm && m > prsvm_cap {
                print!(" {:>12}", "(skipped)");
                continue;
            }
            // Few iterations: memory peaks at data + oracle structures,
            // not at convergence.
            match memprobe::spawn_probe("reuters-small", m, method, 1e-5, 5) {
                Ok(kib) => {
                    print!(" {:>12.1}", kib as f64 / 1024.0);
                    record(
                        "fig3_memory",
                        Json::obj(vec![
                            ("m", m.into()),
                            ("method", method.name().into()),
                            ("peak_rss_kib", (kib as usize).into()),
                        ]),
                    );
                }
                Err(e) => print!(" {:>12}", format!("err:{e:.0}")),
            }
        }
        println!();
    }
    println!("\nExpected shape (paper): prsvm column explodes quadratically and");
    println!("stops at 8k; tree/rlevel grow linearly once m dominates constants.");
}
