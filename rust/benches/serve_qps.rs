//! Serving latency/throughput under Zipf traffic — the `ranksvm serve`
//! companion to the training figures.
//!
//! Fixture: a `synthetic::zipf_queries` store (one giant query group, a
//! long tail — the shape that motivated the fine-grained scheduler) and
//! a request trace with Zipf-skewed row popularity: mostly single-row
//! `rows` lookups plus a slice of `topk 10 group` rankings, the two
//! request kinds a live ranker actually serves. Two modes per thread
//! count:
//!
//! - **latency** — one request per batch (the interactive path); we
//!   report p50/p99 per-request wall-clock in microseconds.
//! - **throughput** — batches of `BATCH` requests fanned onto the
//!   worker pool; we report sustained requests/second.
//!
//! Before timing anything, the bench asserts every thread count scores
//! the whole store bit-identically (the serving parity contract).
//!
//! Output: the usual table on stdout + JSONL via `common::record`, and
//! the tracked snapshot `BENCH_serve_qps.json` at the repo root is
//! rewritten through the shared snapshot envelope
//! (`ranksvm::obs::snapshot`, docs/OBSERVABILITY.md): params are the
//! fixture (`m`, `groups`, `dim`, `requests`, `batch`, `topk_share`),
//! metric rows are `{threads, p50_us, p99_us, qps}`, one per thread
//! count. `RANKSVM_SNAPSHOT_SCHEMA_ONLY=1` writes a `placeholder:
//! true` snapshot with null metric values and exits — CI's schema
//! drift gate.
//!
//! Regenerate with `cargo bench --bench serve_qps` (FULL=1 for the
//! paper-scale store).

mod common;

use common::{full_scale, header, record};
use ranksvm::data::{materialize, synthetic, DatasetView, LoadedDataset};
use ranksvm::serve::{Engine, Payload, Request, ScoringModel, Selector};
use ranksvm::util::json::Json;
use ranksvm::util::rng::Rng;

const BATCH: usize = 64;
const TOPK_SHARE: f64 = 0.1;

/// Zipf-skewed request trace: hot rows get hammered, plus a share of
/// per-group top-10 rankings. Deterministic in the seed.
fn trace(n: usize, rows: usize, groups: usize, seed: u64) -> Vec<Request> {
    let mut rng = Rng::new(seed);
    // Rank-skewed row popularity without a float power law: row
    // `u²·rows` for uniform u concentrates mass near row 0.
    let mut skewed = |limit: usize| {
        let u = (rng.below(1 << 20) as f64) / (1 << 20) as f64;
        ((u * u * limit as f64) as usize).min(limit - 1)
    };
    (0..n)
        .map(|_| {
            if (rng.below(1000) as f64) < TOPK_SHARE * 1000.0 {
                Request::TopK { k: 10, sel: Selector::Group(skewed(groups)) }
            } else {
                Request::Rows(vec![skewed(rows)])
            }
        })
        .collect()
}

fn percentile_us(sorted: &[f64], p: f64) -> f64 {
    let i = ((sorted.len() as f64 * p) as usize).min(sorted.len() - 1);
    sorted[i] * 1e6
}

/// Snapshot fixture parameters (key set is part of the schema gate).
fn params(m: usize, groups: usize, dim: usize, requests: usize) -> Json {
    Json::obj(vec![
        ("m", m.into()),
        ("groups", groups.into()),
        ("dim", dim.into()),
        ("requests", requests.into()),
        ("batch", BATCH.into()),
        ("topk_share", TOPK_SHARE.into()),
    ])
}

/// One snapshot metric row (null values in schema-only mode).
fn mode_row(threads: Json, p50_us: Json, p99_us: Json, qps: Json) -> Json {
    Json::obj(vec![
        ("threads", threads),
        ("p50_us", p50_us),
        ("p99_us", p99_us),
        ("qps", qps),
    ])
}

fn main() {
    let max_threads = ranksvm::util::resolve_threads(0);
    let (m, n_groups, dim, n_requests) = if full_scale() {
        (200_000, 4096, 16, 20_000)
    } else {
        (20_000, 512, 16, 4_000)
    };
    if common::schema_only() {
        let null_row = mode_row(Json::Null, Json::Null, Json::Null, Json::Null);
        common::write_snapshot(
            "serve_qps",
            true,
            params(m, n_groups, dim, n_requests),
            vec![null_row],
        );
        return;
    }
    let ds = synthetic::zipf_queries(m, n_groups, dim, 1.1, 42);
    let w: Vec<f64> = (0..ds.dim()).map(|j| ((j as f64) + 0.5).sin() * 1.75).collect();
    let model = ScoringModel::new(w, None).unwrap();
    let model_path = std::env::temp_dir()
        .join(format!("ranksvm_serve_qps_{}.rsm", std::process::id()));
    model.save(&model_path).unwrap();
    let reference = model.scores(&ds);
    let requests = trace(n_requests, m, n_groups, 7);

    let mut thread_grid = vec![1usize, max_threads.div_ceil(2), max_threads];
    thread_grid.dedup();

    header(&format!(
        "Serve QPS: zipf store m = {m}, {n_groups} groups, dim {dim}; \
         {n_requests} requests/mode ({:.0}% topk), batch {BATCH}",
        TOPK_SHARE * 100.0
    ));
    println!(
        "{:>8} {:>12} {:>12} {:>14}",
        "threads", "p50 latency", "p99 latency", "throughput"
    );

    let mut modes = Vec::new();
    for &threads in &thread_grid {
        let eng = Engine::new(
            &model_path,
            Some(LoadedDataset::Owned(materialize(&ds))),
            threads,
            true,
        )
        .unwrap();

        // Parity gate: this thread count serves the exact reference bits.
        let all: Vec<usize> = (0..m).collect();
        let resp = eng.run_batch(&[Request::Rows(all)]);
        let Ok(Payload::Scores(got)) = &resp[0].body else { panic!("parity batch failed") };
        assert_eq!(got.len(), reference.len());
        for (i, (g, r)) in got.iter().zip(&reference).enumerate() {
            assert!(g.to_bits() == r.to_bits(), "parity broke at row {i} with {threads} threads");
        }

        // Latency mode: one request per batch, individually timed.
        let mut lat: Vec<f64> = Vec::with_capacity(requests.len());
        for req in &requests {
            let t = std::time::Instant::now();
            std::hint::black_box(eng.run_batch(std::slice::from_ref(req)));
            lat.push(t.elapsed().as_secs_f64());
        }
        lat.sort_unstable_by(f64::total_cmp);
        let (p50, p99) = (percentile_us(&lat, 0.50), percentile_us(&lat, 0.99));

        // Throughput mode: the same trace in batches of BATCH.
        let t = std::time::Instant::now();
        for chunk in requests.chunks(BATCH) {
            std::hint::black_box(eng.run_batch(chunk));
        }
        let qps = requests.len() as f64 / t.elapsed().as_secs_f64();

        println!("{threads:>8} {p50:>10.1}µs {p99:>10.1}µs {qps:>12.0}/s");
        record(
            "serve_qps",
            Json::obj(vec![
                ("bench", "serve_qps".into()),
                ("m", m.into()),
                ("groups", n_groups.into()),
                ("dim", dim.into()),
                ("requests", requests.len().into()),
                ("batch", BATCH.into()),
                ("threads", threads.into()),
                ("p50_us", p50.into()),
                ("p99_us", p99.into()),
                ("qps", qps.into()),
            ]),
        );
        modes.push(mode_row(threads.into(), p50.into(), p99.into(), qps.into()));
    }
    std::fs::remove_file(&model_path).ok();

    // Rewrite the tracked snapshot through the shared envelope.
    common::write_snapshot("serve_qps", false, params(m, n_groups, dim, requests.len()), modes);
}
