//! Tiny command-line argument parser (the offline registry has no `clap`).
//!
//! Supports `--key value`, `--key=value`, bare flags (`--flag`), and
//! positional arguments. Typed getters with defaults keep call sites
//! short; a malformed value is a proper [`anyhow::Error`] naming the
//! flag and the offending input (propagated to `main`, exit code 2) —
//! never a panic/backtrace in the user's face.

use anyhow::{anyhow, Result};
use std::collections::HashMap;

/// Parsed arguments: flags/options plus positionals, in order.
#[derive(Debug, Default, Clone)]
pub struct Args {
    opts: HashMap<String, String>,
    flags: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from an explicit iterator (testable); `std::env::args().skip(1)`
    /// in production.
    pub fn parse<I: IntoIterator<Item = String>>(items: I) -> Args {
        let mut out = Args::default();
        let mut it = items.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    out.opts.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.opts.insert(rest.to_string(), v);
                } else {
                    out.flags.push(rest.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(|s| s.as_str())
    }

    pub fn str_or(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, name: &str, default: usize) -> Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow!("--{name} expects an integer, got {v:?}")),
        }
    }

    pub fn f64_or(&self, name: &str, default: f64) -> Result<f64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow!("--{name} expects a number, got {v:?}")),
        }
    }

    pub fn u64_or(&self, name: &str, default: u64) -> Result<u64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow!("--{name} expects an integer, got {v:?}")),
        }
    }

    /// Comma-separated usize list, e.g. `--sizes 1000,2000,4000`.
    pub fn usize_list_or(&self, name: &str, default: &[usize]) -> Result<Vec<usize>> {
        match self.get(name) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .filter(|s| !s.is_empty())
                .map(|s| {
                    s.trim().parse().map_err(|_| {
                        anyhow!("--{name} expects comma-separated integers, got {s:?}")
                    })
                })
                .collect(),
        }
    }

    /// Comma-separated f64 list, e.g. `--lambdas 1e-3,1e-2,0.1`.
    pub fn f64_list_or(&self, name: &str, default: &[f64]) -> Result<Vec<f64>> {
        match self.get(name) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .filter(|s| !s.is_empty())
                .map(|s| {
                    s.trim().parse().map_err(|_| {
                        anyhow!("--{name} expects comma-separated numbers, got {s:?}")
                    })
                })
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(v: &[&str]) -> Args {
        Args::parse(v.iter().map(|s| s.to_string()))
    }

    #[test]
    fn parses_options_and_flags() {
        let a = args(&["train", "--m", "1000", "--lambda=0.1", "--verbose", "--out", "x.json"]);
        assert_eq!(a.positional, vec!["train"]);
        assert_eq!(a.usize_or("m", 0).unwrap(), 1000);
        assert!((a.f64_or("lambda", 0.0).unwrap() - 0.1).abs() < 1e-12);
        assert!(a.flag("verbose"));
        assert_eq!(a.str_or("out", ""), "x.json");
    }

    #[test]
    fn defaults_apply() {
        let a = args(&[]);
        assert_eq!(a.usize_or("m", 7).unwrap(), 7);
        assert_eq!(a.str_or("method", "tree"), "tree");
        assert!(!a.flag("verbose"));
    }

    #[test]
    fn trailing_flag_without_value() {
        let a = args(&["--quiet"]);
        assert!(a.flag("quiet"));
    }

    #[test]
    fn usize_list() {
        let a = args(&["--sizes", "1,2,30"]);
        assert_eq!(a.usize_list_or("sizes", &[]).unwrap(), vec![1, 2, 30]);
        assert_eq!(a.usize_list_or("other", &[5]).unwrap(), vec![5]);
    }

    #[test]
    fn f64_list() {
        let a = args(&["--lambdas", "1e-3,0.5, 2"]);
        assert_eq!(a.f64_list_or("lambdas", &[]).unwrap(), vec![1e-3, 0.5, 2.0]);
        assert_eq!(a.f64_list_or("other", &[0.25]).unwrap(), vec![0.25]);
        let bad = args(&["--lambdas", "1,zap"]);
        let err = bad.f64_list_or("lambdas", &[]).unwrap_err().to_string();
        assert!(err.contains("--lambdas") && err.contains("zap"), "{err}");
    }

    #[test]
    fn negative_number_as_value() {
        // "--lambda -0.5" — the "-0.5" does not start with "--", so it binds.
        let a = args(&["--lambda", "-0.5"]);
        assert!((a.f64_or("lambda", 0.0).unwrap() + 0.5).abs() < 1e-12);
    }

    #[test]
    fn malformed_values_are_errors_naming_flag_and_value() {
        let a = args(&["--m", "abc", "--lambda", "xx,", "--sizes", "1,zap"]);
        let err = a.usize_or("m", 0).unwrap_err().to_string();
        assert!(err.contains("--m") && err.contains("abc"), "{err}");
        let err = a.f64_or("lambda", 0.0).unwrap_err().to_string();
        assert!(err.contains("--lambda") && err.contains("xx"), "{err}");
        let err = a.u64_or("m", 0).unwrap_err().to_string();
        assert!(err.contains("--m"), "{err}");
        let err = a.usize_list_or("sizes", &[]).unwrap_err().to_string();
        assert!(err.contains("--sizes") && err.contains("zap"), "{err}");
    }
}
