//! Store-conversion throughput — the pstore v3 parallel parse phase.
//!
//! Writes a Reuters-like libsvm fixture, converts it at 1 / half /
//! all-cores worker threads, and prints MB/s per configuration. The
//! artifacts are byte-compared along the way: the speedup must cost
//! exactly zero output bits (the converter's determinism contract,
//! `docs/DETERMINISM.md`).
//!
//! `FULL=1` runs the paper-scale fixture; `M=<rows>` overrides.
//!
//! The tracked snapshot `BENCH_convert_throughput.json` is written
//! through the shared envelope (`ranksvm::obs::snapshot`,
//! docs/OBSERVABILITY.md): one metric row per thread count;
//! `RANKSVM_SNAPSHOT_SCHEMA_ONLY=1` emits the placeholder schema and
//! exits.

mod common;

use common::full_scale;
use ranksvm::data::store::{convert_libsvm, ConvertOptions};
use ranksvm::data::{libsvm, synthetic};
use ranksvm::util::json::Json;

/// Snapshot fixture parameters (key set is part of the schema gate).
fn params(m: usize, text_bytes: Json) -> Json {
    Json::obj(vec![("m", m.into()), ("text_bytes", text_bytes)])
}

/// One snapshot metric row (null values in schema-only mode).
fn metric_row(threads: Json, shards: Json, secs: Json, mb_per_s: Json) -> Json {
    Json::obj(vec![
        ("threads", threads),
        ("shards", shards),
        ("secs", secs),
        ("mb_per_s", mb_per_s),
    ])
}

fn main() {
    let default_m = if full_scale() { 400_000 } else { 60_000 };
    let m: usize = std::env::var("M")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(default_m);
    if common::schema_only() {
        let n = || Json::Null;
        common::write_snapshot(
            "convert_throughput",
            true,
            params(m, Json::Null),
            vec![metric_row(n(), n(), n(), n())],
        );
        return;
    }
    let dir = std::env::temp_dir().join(format!("ranksvm_convert_bench_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let text = dir.join("bench.libsvm");
    let ds = synthetic::reuters_like(m, 5);
    libsvm::write(&ds, &text).unwrap();
    drop(ds);
    let text_bytes = std::fs::metadata(&text).unwrap().len();
    println!(
        "convert throughput: {m} rows, {:.1} MB of libsvm text",
        text_bytes as f64 / 1e6
    );
    println!("{:>8} {:>7} {:>9} {:>9} {:>10}", "threads", "shards", "secs", "MB/s", "identical");

    let all = ranksvm::util::resolve_threads(0);
    let mut configs = vec![1usize, (all / 2).max(2), all];
    configs.dedup();
    let mut reference: Option<Vec<u8>> = None;
    let mut rows = Vec::new();
    for threads in configs {
        let out = dir.join(format!("bench.t{threads}.pstore"));
        let opts = ConvertOptions { chunk_bytes: 8 << 20, n_threads: threads };
        let t0 = std::time::Instant::now();
        let stats = convert_libsvm(&text, &out, &opts).unwrap();
        let secs = t0.elapsed().as_secs_f64();
        let got = std::fs::read(&out).unwrap();
        let identical = match &reference {
            None => {
                reference = Some(got);
                "(ref)"
            }
            Some(r) => {
                assert_eq!(r, &got, "parallel conversion diverged at {threads} threads");
                "yes"
            }
        };
        println!(
            "{threads:>8} {:>7} {secs:>9.2} {:>9.1} {identical:>10}",
            stats.shards,
            text_bytes as f64 / 1e6 / secs,
        );
        rows.push(metric_row(
            threads.into(),
            stats.shards.into(),
            secs.into(),
            (text_bytes as f64 / 1e6 / secs).into(),
        ));
        std::fs::remove_file(&out).ok();
    }
    std::fs::remove_file(&text).ok();
    std::fs::remove_dir(&dir).ok();

    common::write_snapshot(
        "convert_throughput",
        false,
        params(m, (text_bytes as usize).into()),
        rows,
    );
}
