//! Structured run traces: `train --trace out.jsonl` and the
//! `ranksvm report` renderer (docs/OBSERVABILITY.md "Trace events").
//!
//! A trace is JSONL — one object per line, `event` discriminated:
//! exactly one `start` line, one `iter` line per BMRM iteration, one
//! `end` line. The field lists are normative constants here so the
//! docs table, the emitting trainer, and the schema-pinning tests all
//! reference one definition.
//!
//! Inertness: the sink is written *between* solver iterations from an
//! observer callback that reads — never writes — solver state. Timing
//! fields (`oracle_secs`, `phases`, pool deltas) are nondeterministic
//! wall-clock measurements; every numeric the solver computes
//! (`objective`, `gap`, …) is byte-identical with tracing on or off
//! (pinned by `tests/obs.rs`).

use crate::util::json::Json;
use crate::util::timer::PhaseTimes;
use anyhow::{Context, Result};
use std::io::{BufWriter, Write};

/// Bumped whenever an event gains/loses/renames a field.
/// v2: `start` gained `kernel` (the resolved compute-kernel dispatch,
/// `"scalar"` or `"simd"` — [`crate::linalg::simd`]).
pub const TRACE_SCHEMA_VERSION: i64 = 2;

/// Fields of the `start` event, in emission order.
pub static START_FIELDS: &[&str] = &[
    "event",
    "schema_version",
    "method",
    "m",
    "dim",
    "n_pairs",
    "lambda",
    "epsilon",
    "max_iter",
    "threads",
    "kernel",
];

/// Fields of the per-iteration `iter` event, in emission order.
pub static ITER_FIELDS: &[&str] = &[
    "event",
    "iter",
    "objective",
    "lower_bound",
    "gap",
    "risk",
    "ls_steps",
    "oracle_secs",
    "phases",
    "pool_tasks_delta",
    "pool_stolen_delta",
];

/// Fields of the `end` event, in emission order.
pub static END_FIELDS: &[&str] = &[
    "event",
    "iterations",
    "converged",
    "objective",
    "gap",
    "train_secs",
    "oracle_secs",
];

/// Problem-shape parameters stamped on the `start` event.
pub struct StartInfo<'a> {
    pub method: &'a str,
    pub m: usize,
    pub dim: usize,
    pub n_pairs: f64,
    pub lambda: f64,
    pub epsilon: f64,
    pub max_iter: usize,
    pub threads: usize,
    /// Resolved kernel dispatch for this run (`"scalar"` / `"simd"`).
    pub kernel: &'a str,
}

/// Build the `start` event (keys exactly [`START_FIELDS`]).
pub fn start_event(s: &StartInfo) -> Json {
    Json::Obj(vec![
        ("event".into(), "start".into()),
        ("schema_version".into(), Json::Int(TRACE_SCHEMA_VERSION)),
        ("method".into(), s.method.into()),
        ("m".into(), s.m.into()),
        ("dim".into(), s.dim.into()),
        ("n_pairs".into(), s.n_pairs.into()),
        ("lambda".into(), s.lambda.into()),
        ("epsilon".into(), s.epsilon.into()),
        ("max_iter".into(), s.max_iter.into()),
        ("threads".into(), s.threads.into()),
        ("kernel".into(), s.kernel.into()),
    ])
}

/// Per-iteration measurements for the `iter` event.
pub struct IterInfo {
    pub iter: usize,
    pub objective: f64,
    pub lower_bound: f64,
    pub gap: f64,
    pub risk: f64,
    pub ls_steps: usize,
    pub oracle_secs: f64,
    /// Oracle phase split *for this iteration* (deltas of the oracle's
    /// cumulative [`PhaseTimes`]), seconds. Empty when the loss keeps
    /// no phase clocks.
    pub phases: Vec<(String, f64)>,
    pub pool_tasks_delta: u64,
    pub pool_stolen_delta: u64,
}

/// Build the `iter` event (keys exactly [`ITER_FIELDS`]).
pub fn iter_event(it: &IterInfo) -> Json {
    let phases =
        Json::Obj(it.phases.iter().map(|(k, v)| (k.clone(), Json::Num(*v))).collect());
    Json::Obj(vec![
        ("event".into(), "iter".into()),
        ("iter".into(), it.iter.into()),
        ("objective".into(), it.objective.into()),
        ("lower_bound".into(), it.lower_bound.into()),
        ("gap".into(), it.gap.into()),
        ("risk".into(), it.risk.into()),
        ("ls_steps".into(), it.ls_steps.into()),
        ("oracle_secs".into(), it.oracle_secs.into()),
        ("phases".into(), phases),
        ("pool_tasks_delta".into(), Json::Int(it.pool_tasks_delta as i64)),
        ("pool_stolen_delta".into(), Json::Int(it.pool_stolen_delta as i64)),
    ])
}

/// Final-outcome measurements for the `end` event.
pub struct EndInfo {
    pub iterations: usize,
    pub converged: bool,
    pub objective: f64,
    pub gap: f64,
    pub train_secs: f64,
    pub oracle_secs: f64,
}

/// Build the `end` event (keys exactly [`END_FIELDS`]).
pub fn end_event(e: &EndInfo) -> Json {
    Json::Obj(vec![
        ("event".into(), "end".into()),
        ("iterations".into(), e.iterations.into()),
        ("converged".into(), e.converged.into()),
        ("objective".into(), e.objective.into()),
        ("gap".into(), e.gap.into()),
        ("train_secs".into(), e.train_secs.into()),
        ("oracle_secs".into(), e.oracle_secs.into()),
    ])
}

/// Fields of the standalone `cv_point` event, in emission order. Not
/// part of a training trace: `ranksvm cv --trace` writes one
/// `cv_point` line per λ into its own JSONL file after the sweep
/// completes (the engine itself stays observation-free so the sweep is
/// bit-identical with tracing on or off). `ranksvm report` renders
/// training traces only and rejects these files.
pub static CV_POINT_FIELDS: &[&str] = &[
    "event",
    "schema_version",
    "lambda",
    "mean_error",
    "mean_auc",
    "mean_precision_at_k",
    "iterations",
    "selected",
];

/// Per-λ summary stamped on a `cv_point` event.
pub struct CvPointInfo {
    pub lambda: f64,
    pub mean_error: f64,
    pub mean_auc: f64,
    pub mean_precision_at_k: f64,
    /// Solver iterations summed over folds at this λ.
    pub iterations: usize,
    /// Whether this λ won the sweep's selection metric.
    pub selected: bool,
}

/// Build a `cv_point` event (keys exactly [`CV_POINT_FIELDS`]).
pub fn cv_point_event(p: &CvPointInfo) -> Json {
    Json::Obj(vec![
        ("event".into(), "cv_point".into()),
        ("schema_version".into(), Json::Int(TRACE_SCHEMA_VERSION)),
        ("lambda".into(), p.lambda.into()),
        ("mean_error".into(), p.mean_error.into()),
        ("mean_auc".into(), p.mean_auc.into()),
        ("mean_precision_at_k".into(), p.mean_precision_at_k.into()),
        ("iterations".into(), p.iterations.into()),
        ("selected".into(), p.selected.into()),
    ])
}

/// Compute the per-iteration phase split: current cumulative
/// [`PhaseTimes`] minus the previously seen totals (which are updated
/// in place). Phase order follows the oracle's registration order.
pub fn phase_deltas(times: &PhaseTimes, prev: &mut Vec<(String, f64)>) -> Vec<(String, f64)> {
    let mut out = Vec::new();
    for (name, d) in times.entries() {
        let secs = d.as_secs_f64();
        let before = prev
            .iter_mut()
            .find(|(n, _)| n == name)
            .map(|e| std::mem::replace(&mut e.1, secs))
            .unwrap_or_else(|| {
                prev.push((name.clone(), secs));
                0.0
            });
        out.push((name.clone(), secs - before));
    }
    out
}

/// Append-only JSONL sink for one training run.
pub struct TraceSink {
    out: BufWriter<std::fs::File>,
}

impl TraceSink {
    pub fn create(path: &str) -> Result<Self> {
        let f = std::fs::File::create(path)
            .with_context(|| format!("creating trace file {path}"))?;
        Ok(TraceSink { out: BufWriter::new(f) })
    }

    /// Write one event as a single JSONL line.
    pub fn event(&mut self, ev: &Json) -> Result<()> {
        writeln!(self.out, "{}", ev).context("writing trace event")?;
        Ok(())
    }

    pub fn finish(&mut self) -> Result<()> {
        self.out.flush().context("flushing trace file")?;
        Ok(())
    }
}

/// Render a JSONL trace into the human summary table printed by
/// `ranksvm report`.
pub fn render_report(trace_text: &str) -> Result<String> {
    use std::fmt::Write as _;
    let mut out = String::new();
    let mut n_iters = 0usize;
    for (lineno, line) in trace_text.lines().enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        let ev = Json::parse(line).with_context(|| format!("trace line {}", lineno + 1))?;
        match ev.get("event").and_then(Json::as_str) {
            Some("start") => {
                let _ = writeln!(
                    out,
                    "trace: method={} m={} dim={} n_pairs={} lambda={} epsilon={} threads={}",
                    ev.get("method").and_then(Json::as_str).unwrap_or("?"),
                    fmt_num(&ev, "m"),
                    fmt_num(&ev, "dim"),
                    fmt_num(&ev, "n_pairs"),
                    fmt_num(&ev, "lambda"),
                    fmt_num(&ev, "epsilon"),
                    fmt_num(&ev, "threads"),
                );
                let _ = writeln!(
                    out,
                    "{:>4}  {:>14}  {:>11}  {:>11}  {:>3}  {:>9}  {:>7}",
                    "iter", "objective", "gap", "risk", "ls", "oracle_s", "stolen"
                );
            }
            Some("iter") => {
                n_iters += 1;
                let f = |k: &str| ev.get(k).and_then(Json::as_f64).unwrap_or(f64::NAN);
                let _ = writeln!(
                    out,
                    "{:>4}  {:>14.6e}  {:>11.3e}  {:>11.3e}  {:>3}  {:>9.4}  {:>7}",
                    ev.get("iter").and_then(Json::as_i64).unwrap_or(-1),
                    f("objective"),
                    f("gap"),
                    f("risk"),
                    ev.get("ls_steps").and_then(Json::as_i64).unwrap_or(0),
                    f("oracle_secs"),
                    ev.get("pool_stolen_delta").and_then(Json::as_i64).unwrap_or(0),
                );
            }
            Some("end") => {
                let f = |k: &str| ev.get(k).and_then(Json::as_f64).unwrap_or(f64::NAN);
                let _ = writeln!(
                    out,
                    "done: {} iterations, converged={}, objective={:.6e}, gap={:.3e}",
                    fmt_num(&ev, "iterations"),
                    ev.get("converged").and_then(Json::as_bool).unwrap_or(false),
                    f("objective"),
                    f("gap"),
                );
                let _ = writeln!(
                    out,
                    "time: {:.4}s total, {:.4}s in the oracle",
                    f("train_secs"),
                    f("oracle_secs"),
                );
            }
            other => {
                anyhow::bail!("trace line {}: unknown event {:?}", lineno + 1, other)
            }
        }
    }
    if n_iters == 0 {
        anyhow::bail!("trace has no iter events — is this a --trace output file?");
    }
    Ok(out)
}

fn fmt_num(ev: &Json, key: &str) -> String {
    match ev.get(key) {
        Some(Json::Int(i)) => i.to_string(),
        Some(Json::Num(n)) => format!("{n}"),
        _ => "?".into(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn keys(j: &Json) -> Vec<String> {
        match j {
            Json::Obj(kv) => kv.iter().map(|(k, _)| k.clone()).collect(),
            other => panic!("expected object, got {other}"),
        }
    }

    #[test]
    fn event_builders_match_the_normative_field_lists() {
        let start = start_event(&StartInfo {
            method: "tree",
            m: 10,
            dim: 4,
            n_pairs: 45.0,
            lambda: 0.1,
            epsilon: 0.01,
            max_iter: 5,
            threads: 2,
            kernel: "scalar",
        });
        assert_eq!(keys(&start), START_FIELDS);
        let iter = iter_event(&IterInfo {
            iter: 1,
            objective: 1.0,
            lower_bound: 0.5,
            gap: 0.5,
            risk: 0.9,
            ls_steps: 12,
            oracle_secs: 0.001,
            phases: vec![("sort".into(), 0.0005)],
            pool_tasks_delta: 3,
            pool_stolen_delta: 1,
        });
        assert_eq!(keys(&iter), ITER_FIELDS);
        let end = end_event(&EndInfo {
            iterations: 1,
            converged: true,
            objective: 1.0,
            gap: 0.001,
            train_secs: 0.1,
            oracle_secs: 0.05,
        });
        assert_eq!(keys(&end), END_FIELDS);
        let cv = cv_point_event(&CvPointInfo {
            lambda: 0.1,
            mean_error: 0.2,
            mean_auc: 0.8,
            mean_precision_at_k: 0.5,
            iterations: 17,
            selected: true,
        });
        assert_eq!(keys(&cv), CV_POINT_FIELDS);
    }

    #[test]
    fn phase_deltas_subtract_previous_totals() {
        let mut times = PhaseTimes::default();
        times.add("sort", std::time::Duration::from_millis(10));
        let mut prev = Vec::new();
        let d1 = phase_deltas(&times, &mut prev);
        assert_eq!(d1.len(), 1);
        assert!((d1[0].1 - 0.010).abs() < 1e-9);
        times.add("sort", std::time::Duration::from_millis(5));
        let d2 = phase_deltas(&times, &mut prev);
        assert!((d2[0].1 - 0.005).abs() < 1e-9, "delta {}", d2[0].1);
    }

    #[test]
    fn report_renders_header_rows_and_footer() {
        let start = start_event(&StartInfo {
            method: "tree",
            m: 10,
            dim: 4,
            n_pairs: 45.0,
            lambda: 0.1,
            epsilon: 0.01,
            max_iter: 5,
            threads: 2,
            kernel: "scalar",
        });
        let iter = iter_event(&IterInfo {
            iter: 1,
            objective: 2.5,
            lower_bound: 1.0,
            gap: 1.5,
            risk: 2.0,
            ls_steps: 0,
            oracle_secs: 0.001,
            phases: vec![],
            pool_tasks_delta: 0,
            pool_stolen_delta: 0,
        });
        let end = end_event(&EndInfo {
            iterations: 1,
            converged: true,
            objective: 2.5,
            gap: 0.0,
            train_secs: 0.1,
            oracle_secs: 0.05,
        });
        let text = format!("{start}\n{iter}\n{end}\n");
        let report = render_report(&text).unwrap();
        assert!(report.contains("method=tree"), "{report}");
        assert!(report.contains("converged=true"), "{report}");
        assert!(report.contains("objective"), "{report}");
        // Garbage input errors out instead of panicking.
        assert!(render_report("{\"event\":\"bogus\"}").is_err());
        assert!(render_report("").is_err());
    }
}
