//! Data substrate: dataset container, libsvm I/O, and the synthetic
//! generators standing in for Cadata and Reuters RCV1 (DESIGN.md §6).

pub mod dataset;
pub mod libsvm;
pub mod synthetic;

pub use dataset::Dataset;
