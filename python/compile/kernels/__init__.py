"""L1 Pallas kernels + pure-jnp reference oracles."""

from . import grad, pair_count, ref, scores  # noqa: F401
