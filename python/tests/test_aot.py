"""AOT pipeline: artifacts lower to parseable HLO text + valid manifest."""

import os

from compile import aot


def test_build_writes_artifacts_and_manifest(tmp_path):
    out = str(tmp_path / "artifacts")
    lines = aot.build(out, matvec_shapes=[(64, 8)], paircount_sizes=[32])
    # manifest: header + scores + grad + paircount
    assert len(lines) == 4
    manifest = open(os.path.join(out, "manifest.txt")).read()
    assert "scores 64 8 scores_64x8.hlo.txt" in manifest
    assert "grad 64 8 grad_64x8.hlo.txt" in manifest
    assert "paircount 32 0 paircount_32.hlo.txt" in manifest
    for fname in ["scores_64x8.hlo.txt", "grad_64x8.hlo.txt", "paircount_32.hlo.txt"]:
        text = open(os.path.join(out, fname)).read()
        assert text.startswith("HloModule"), f"{fname} is not HLO text"
        assert "ENTRY" in text


def test_hlo_text_is_plain_ops_no_custom_calls(tmp_path):
    """interpret=True must lower to plain HLO the CPU client can run —
    a Mosaic custom-call here would break the rust runtime."""
    text = aot.lower_scores(64, 8)
    assert "custom-call" not in text.lower()
    text = aot.lower_paircount(32)
    assert "custom-call" not in text.lower()


def test_lowering_is_deterministic():
    a = aot.lower_scores(64, 8)
    b = aot.lower_scores(64, 8)
    assert a == b
