//! Trained ranking model: the weight vector, prediction, and a plain-text
//! on-disk format.

use crate::data::DatasetView;
use anyhow::{bail, Context, Result};
use std::io::{BufRead, Write};
use std::path::Path;

/// A linear ranking function `f(x) = ⟨w, x⟩`.
#[derive(Clone, Debug, PartialEq)]
pub struct RankModel {
    pub w: Vec<f64>,
}

impl RankModel {
    pub fn new(w: Vec<f64>) -> Self {
        RankModel { w }
    }

    pub fn dim(&self) -> usize {
        self.w.len()
    }

    /// Scores for every example of a dataset (owned or memory-mapped).
    /// Feature dimensions may differ (train/test splits of sparse
    /// data): missing trailing features contribute zero either way.
    /// Delegates to the one shared scoring kernel
    /// ([`crate::serve::score_csr`]) so CLI prediction, evaluation,
    /// and the serving daemon are bit-identical by construction.
    pub fn predict(&self, ds: &dyn DatasetView) -> Vec<f64> {
        crate::serve::score_csr(&self.w, None, &ds.x())
    }

    /// Rank a set of examples: indices sorted by descending score (ties
    /// and non-finite scores ordered deterministically via `total_cmp`
    /// then original index — a NaN score cannot panic the ranking).
    pub fn rank(&self, ds: &dyn DatasetView) -> Vec<usize> {
        let p = self.predict(ds);
        let mut idx: Vec<usize> = (0..p.len()).collect();
        idx.sort_unstable_by(|&a, &b| p[b].total_cmp(&p[a]).then(a.cmp(&b)));
        idx
    }

    /// Save as plain text: header line + one weight per line.
    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
        writeln!(f, "ranksvm-model v1 dim={}", self.w.len())?;
        for w in &self.w {
            writeln!(f, "{w:.17e}")?;
        }
        Ok(())
    }

    pub fn load(path: impl AsRef<Path>) -> Result<RankModel> {
        let path = path.as_ref();
        let f = std::fs::File::open(path).with_context(|| format!("open {}", path.display()))?;
        let mut lines = std::io::BufReader::new(f).lines();
        let header = lines.next().context("empty model file")??;
        if !header.starts_with("ranksvm-model v1") {
            bail!("not a ranksvm model file: {header:?}");
        }
        let dim: usize = header
            .split("dim=")
            .nth(1)
            .context("missing dim")?
            .trim()
            .parse()?;
        let mut w = Vec::with_capacity(dim);
        for line in lines {
            let line = line?;
            if line.trim().is_empty() {
                continue;
            }
            w.push(line.trim().parse::<f64>()?);
        }
        if w.len() != dim {
            bail!("model dim mismatch: header {dim}, got {}", w.len());
        }
        Ok(RankModel { w })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;

    #[test]
    fn predict_matches_matvec() {
        let ds = synthetic::cadata_like(30, 5);
        let w: Vec<f64> = (0..ds.dim()).map(|j| j as f64 * 0.1).collect();
        let model = RankModel::new(w.clone());
        let p = model.predict(&ds);
        let mut expect = vec![0.0; ds.len()];
        ds.x.matvec(&w, &mut expect);
        for (a, b) in p.iter().zip(&expect) {
            assert!((a - b).abs() < 1e-12);
        }
    }

    #[test]
    fn save_load_round_trip() {
        let model = RankModel::new(vec![1.5, -2.25e-10, 0.0, 3.7e8]);
        let tmp = std::env::temp_dir().join("ranksvm_model_roundtrip.txt");
        model.save(&tmp).unwrap();
        let back = RankModel::load(&tmp).unwrap();
        assert_eq!(model, back);
        std::fs::remove_file(tmp).ok();
    }

    #[test]
    fn rank_orders_by_score_desc() {
        let ds = synthetic::cadata_like(10, 6);
        let model = RankModel::new(vec![1.0; ds.dim()]);
        let order = model.rank(&ds);
        let p = model.predict(&ds);
        for w in order.windows(2) {
            assert!(p[w[0]] >= p[w[1]]);
        }
    }

    #[test]
    fn load_rejects_garbage() {
        let tmp = std::env::temp_dir().join("ranksvm_model_bad.txt");
        std::fs::write(&tmp, "not a model\n1.0\n").unwrap();
        assert!(RankModel::load(&tmp).is_err());
        std::fs::remove_file(tmp).ok();
    }

    #[test]
    fn predict_handles_dim_mismatch() {
        let ds = synthetic::cadata_like(5, 7);
        let model = RankModel::new(vec![1.0; 2]); // fewer dims than data
        let p = model.predict(&ds);
        assert_eq!(p.len(), 5);
    }
}
