//! Opening and validating a pallas store; the zero-copy [`DatasetView`].

use super::format::{
    cast_slice, Checksum, ColStat, Header, HEADER_LEN, N_SECTIONS, SEC_COLSTATS, SEC_GEX,
    SEC_GOFF, SEC_GPAIRS, SEC_INDICES, SEC_INDPTR, SEC_QID, SEC_VALUES, SEC_Y,
};
use super::mmap::{Advice, Mmap};
use crate::data::DatasetView;
use crate::linalg::CsrView;
use crate::losses::GroupIndex;
use anyhow::{ensure, Context, Result};
use std::path::Path;
use std::sync::Arc;

/// A memory-mapped pallas store: the on-disk training set, readable in
/// place. The CSR arrays, labels, and qids are borrowed straight from
/// the mapping ([`DatasetView`] hands out zero-copy slices); only the
/// group index is decoded into `usize` form at open (O(m), the price of
/// index-width portability — still no parse and no matrix copy).
pub struct PallasStore {
    map: Mmap,
    name: String,
    header: Header,
    /// Resolved `(offset, byte length)` per section.
    sec: [(usize, usize); N_SECTIONS],
    gindex: Option<Arc<GroupIndex>>,
}

impl PallasStore {
    /// Open with full integrity checking: geometry, payload checksum,
    /// CSR structure (bounds, monotone row offsets, strictly ascending
    /// in-row column indices), and group-index consistency. Streams the
    /// whole file once for the checksum — use
    /// [`Self::open_unchecked`] when that single pass is too much (a
    /// dataset larger than RAM on a cold cache).
    pub fn open(path: impl AsRef<Path>) -> Result<Self> {
        Self::open_impl(path.as_ref(), true)
    }

    /// Open without reading the matrix payload: validates the header
    /// geometry (magic, version, section layout — O(1)) and decodes the
    /// group index (O(m); the trainer needs it anyway), but skips the
    /// whole-file checksum and the O(nnz) structural scans — the part
    /// that forces a full read of a dataset larger than RAM. A payload
    /// corruption then surfaces as a panic or garbage numbers
    /// mid-training rather than an error here — reserve this for stores
    /// you just wrote or verify out of band.
    pub fn open_unchecked(path: impl AsRef<Path>) -> Result<Self> {
        Self::open_impl(path.as_ref(), false)
    }

    fn open_impl(path: &Path, verify: bool) -> Result<Self> {
        let name = path.display().to_string();
        let map = Mmap::open(path)?;
        if verify {
            // The verification pass below streams the whole file once;
            // tell the kernel so readahead ramps up immediately.
            map.advise(Advice::Sequential);
        }
        let bytes = map.bytes();
        let header = Header::decode(bytes, bytes.len() as u64)
            .with_context(|| format!("{name}: invalid pallas store"))?;
        let rows = usize::try_from(header.rows).context("row count overflows usize")?;
        let cols = usize::try_from(header.cols).context("column count overflows usize")?;
        let n_groups = usize::try_from(header.n_groups).context("group count overflows")?;
        let mut sec = [(0usize, 0usize); N_SECTIONS];
        for (s, slot) in sec.iter_mut().enumerate() {
            *slot = (header.offsets[s] as usize, header.section_len(s) as usize);
        }
        if verify {
            // Full-file coverage: payload first (the write order), then
            // the header minus the checksum field — so header
            // corruption the geometry checks cannot see (unused flag
            // bits, a grown `cols`) still fails here.
            let mut sum = Checksum::new();
            sum.update(&bytes[HEADER_LEN..]);
            sum.update_header(&bytes[..HEADER_LEN]);
            ensure!(
                sum.finish() == header.checksum,
                "{name}: checksum mismatch — the store is corrupt (expected {:#018x}, \
                 found {:#018x})",
                header.checksum,
                sum.finish()
            );
        }
        let store = PallasStore { map, name, header, sec, gindex: None };
        if verify {
            // Full CSR validation (in-bounds columns, monotone offsets)
            // plus the parser's strictly-ascending in-row invariant, so
            // a verified store is exactly as trustworthy as parsed text.
            let view = CsrView::new(
                rows,
                cols,
                store.indptr(),
                store.indices(),
                store.values(),
            )
            .with_context(|| format!("{}: invalid CSR sections", store.name))?;
            for i in 0..rows {
                let (idx, _) = view.row(i);
                for w in idx.windows(2) {
                    ensure!(
                        w[0] < w[1],
                        "{}: row {i} column indices are not strictly increasing",
                        store.name
                    );
                }
            }
            if !store.header.has_qid() {
                // Global stores: the cached pair count must equal what
                // the text path would recount (grouped stores are
                // cross-checked against gpairs below).
                let recount = crate::losses::count_comparable_pairs(store.y_slice());
                ensure!(
                    store.header.n_pairs == recount,
                    "{}: cached pair count {} disagrees with labels ({recount})",
                    store.name,
                    store.header.n_pairs
                );
            }
            if let Some(stats) = store.col_stats() {
                // Structural sanity only, O(n): the full-file checksum
                // above already authenticates every stats byte, and the
                // bitwise cached-vs-recomputed equality (the definition
                // of the cached values — see docs/STORE_FORMAT.md) is
                // pinned by `tests/store.rs`, so re-deriving them here
                // would add a redundant O(nnz) sweep to every open.
                ensure!(
                    stats.len() == cols,
                    "{}: column-stats section covers {} columns, store has {cols}",
                    store.name,
                    stats.len()
                );
                let mut total = 0u64;
                for (c, s) in stats.iter().enumerate() {
                    total = total.saturating_add(s.nnz);
                    let shape_ok = if s.nnz == 0 {
                        (s.sum, s.sumsq, s.min, s.max) == (0.0, 0.0, 0.0, 0.0)
                    } else {
                        s.min <= s.max && s.sumsq >= 0.0
                    };
                    ensure!(
                        shape_ok,
                        "{}: malformed cached stats for column {c} ({s:?})",
                        store.name
                    );
                }
                ensure!(
                    total == store.header.nnz,
                    "{}: cached column nnz sums to {total}, store has {}",
                    store.name,
                    store.header.nnz
                );
            }
        }
        let gindex = if store.header.has_qid() {
            let offsets: Vec<usize> =
                store.goff().iter().map(|&v| v as usize).collect();
            let examples: Vec<usize> =
                store.gex().iter().map(|&v| v as usize).collect();
            let pairs: Vec<u64> = store.gpairs().to_vec();
            ensure!(
                offsets.len() == n_groups + 1,
                "{}: group offset section length mismatch",
                store.name
            );
            let gi = GroupIndex::from_parts(offsets, examples, pairs)
                .with_context(|| format!("{}: invalid group index", store.name))?;
            ensure!(
                gi.n_examples() == rows,
                "{}: group index covers {} examples, store has {rows}",
                store.name,
                gi.n_examples()
            );
            if verify {
                // The cached objective pair count must equal the
                // per-group sum (exact integers; same order as the
                // writer's accumulation).
                let mut total = 0u64;
                for g in 0..gi.n_groups() {
                    total = total.saturating_add(gi.group_pairs(g));
                }
                ensure!(
                    store.header.n_pairs == total,
                    "{}: cached pair count {} disagrees with the group index ({total})",
                    store.name,
                    store.header.n_pairs
                );
            }
            Some(Arc::new(gi))
        } else {
            None
        };
        let mut store = store;
        store.gindex = gindex;
        Ok(store)
    }

    #[inline]
    fn section(&self, s: usize) -> &[u8] {
        let (off, len) = self.sec[s];
        &self.map.bytes()[off..off + len]
    }

    fn indptr(&self) -> &[u64] {
        cast_slice(self.section(SEC_INDPTR)).expect("validated at open")
    }

    fn indices(&self) -> &[u32] {
        cast_slice(self.section(SEC_INDICES)).expect("validated at open")
    }

    fn values(&self) -> &[f64] {
        cast_slice(self.section(SEC_VALUES)).expect("validated at open")
    }

    fn y_slice(&self) -> &[f64] {
        cast_slice(self.section(SEC_Y)).expect("validated at open")
    }

    fn qid_slice(&self) -> &[u64] {
        cast_slice(self.section(SEC_QID)).expect("validated at open")
    }

    fn goff(&self) -> &[u64] {
        cast_slice(self.section(SEC_GOFF)).expect("validated at open")
    }

    fn gex(&self) -> &[u64] {
        cast_slice(self.section(SEC_GEX)).expect("validated at open")
    }

    fn gpairs(&self) -> &[u64] {
        cast_slice(self.section(SEC_GPAIRS)).expect("validated at open")
    }

    /// Cached per-column statistics (one [`ColStat`] per feature
    /// column), zero-copy from the mapping. `None` only for a store
    /// whose header lacks the colstats flag — every store this build's
    /// converter writes carries them.
    pub fn col_stats(&self) -> Option<&[ColStat]> {
        if self.header.has_colstats() {
            Some(cast_slice(self.section(SEC_COLSTATS)).expect("validated at open"))
        } else {
            None
        }
    }

    /// Hint the kernel that a full sweep over the mapping is imminent
    /// (`madvise(WILLNEED)`): called by the trainer before its first
    /// pass so page-ins overlap setup instead of serializing into the
    /// first matvec. Advice only — a no-op for the read fallback.
    pub fn prefetch(&self) {
        self.map.advise(Advice::WillNeed);
    }

    /// Comparable pairs of the training objective, as precomputed by the
    /// converter (exact integer).
    pub fn n_pairs(&self) -> u64 {
        self.header.n_pairs
    }

    /// Query-group count (0 for a global ranking).
    pub fn n_groups(&self) -> usize {
        self.header.n_groups as usize
    }

    pub fn nnz(&self) -> usize {
        self.header.nnz as usize
    }

    /// Store file size in bytes.
    pub fn file_bytes(&self) -> usize {
        self.map.len()
    }

    /// True when the file is kernel-mapped (false: the read fallback
    /// loaded it into an owned buffer — correct, but not out-of-core).
    pub fn is_mapped(&self) -> bool {
        self.map.is_mapped()
    }
}

impl DatasetView for PallasStore {
    fn x(&self) -> CsrView<'_> {
        // Invariants were established by open-time validation (or
        // explicitly waived via open_unchecked, whose contract is
        // "trusted file"); slice indexing keeps even a corrupt
        // unchecked store memory-safe.
        CsrView::new_unchecked(
            self.header.rows as usize,
            self.header.cols as usize,
            self.indptr(),
            self.indices(),
            self.values(),
        )
    }

    fn y(&self) -> &[f64] {
        self.y_slice()
    }

    fn qid(&self) -> Option<&[u64]> {
        if self.header.has_qid() {
            Some(self.qid_slice())
        } else {
            None
        }
    }

    fn name(&self) -> &str {
        &self.name
    }

    fn group_index(&self) -> Option<Arc<GroupIndex>> {
        self.gindex.clone()
    }

    fn n_pairs_hint(&self) -> Option<f64> {
        Some(self.header.n_pairs as f64)
    }

    fn col_stats(&self) -> Option<&[ColStat]> {
        PallasStore::col_stats(self)
    }

    fn prefetch(&self) {
        PallasStore::prefetch(self)
    }
}

/// From-scratch per-column statistics of a CSR view, with the exact
/// fold conventions of the store's cached COLSTATS section: `nnz` and
/// `min`/`max` over the stored entries (0.0/0.0 for an empty column),
/// `sum`/`sumsq` as the serial left-to-right fold in row-major entry
/// order. The single definition shared by the reader's open-time
/// verification and the trainer's text-path normalization, so cached
/// and recomputed stats can only agree — or fail loudly.
pub fn compute_col_stats(x: crate::linalg::CsrView<'_>) -> Vec<ColStat> {
    let mut stats = vec![
        ColStat { nnz: 0, sum: 0.0, sumsq: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY };
        x.cols()
    ];
    for i in 0..x.rows() {
        let (idx, val) = x.row(i);
        for (&j, &v) in idx.iter().zip(val) {
            let s = &mut stats[j as usize];
            s.nnz += 1;
            s.sum += v;
            s.sumsq += v * v;
            if v < s.min {
                s.min = v;
            }
            if v > s.max {
                s.max = v;
            }
        }
    }
    for s in &mut stats {
        if s.nnz == 0 {
            s.min = 0.0;
            s.max = 0.0;
        }
    }
    stats
}

/// Sniff a file's magic bytes: true iff it starts like a pallas store.
/// (How `--data` autodetects the format without trusting extensions.)
pub fn is_store_file(path: impl AsRef<Path>) -> bool {
    use std::io::Read;
    let Ok(mut f) = std::fs::File::open(path) else {
        return false;
    };
    let mut magic = [0u8; 7];
    f.read_exact(&mut magic).is_ok() && magic == super::format::MAGIC
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn open_rejects_non_store_files() {
        let p = std::env::temp_dir().join(format!("ranksvm_notastore_{}", std::process::id()));
        std::fs::write(&p, b"1 qid:1 1:0.5\n").unwrap();
        let err = PallasStore::open(&p).unwrap_err();
        assert!(err.to_string().contains("pallas store"), "{err}");
        assert!(!is_store_file(&p));
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn open_rejects_truncated_header() {
        let p = std::env::temp_dir().join(format!("ranksvm_shortstore_{}", std::process::id()));
        let mut bytes = Vec::new();
        bytes.extend_from_slice(&super::super::format::MAGIC);
        bytes.push(super::super::format::VERSION);
        bytes.extend_from_slice(&[0u8; 16]); // far short of HEADER_LEN
        std::fs::write(&p, &bytes).unwrap();
        assert!(is_store_file(&p), "magic matches even though the file is truncated");
        let err = PallasStore::open(&p).unwrap_err();
        assert!(err.to_string().contains("short"), "{err}");
        std::fs::remove_file(p).ok();
    }
}
