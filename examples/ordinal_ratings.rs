//! Ordinal-regression scenario (movie-style 1–5 star ratings, §2).
//!
//! With r = 5 utility levels, the r-level algorithm of Joachims (2006)
//! is as fast as the tree — the regime where SVM^rank was already
//! efficient. This example contrasts the oracles across r and shows the
//! dedup tree's O(log r) advantage.
//!
//!     cargo run --release --example ordinal_ratings

use ranksvm::coordinator::{evaluate, train, Method, TrainConfig};
use ranksvm::data::synthetic;
use ranksvm::losses::{count_comparable_pairs, RankingOracle, TreeOracle};

fn main() -> anyhow::Result<()> {
    let m = 6000;
    println!("== training on 5-star ordinal ratings (m={m}) ==");
    let ds = synthetic::ordinal(m, 5, 11);
    let (tr, te) = ds.split(1500, 3);

    for method in [Method::Tree, Method::TreeDedup, Method::RLevel] {
        let cfg = TrainConfig { method, lambda: 0.05, ..Default::default() };
        let out = train(&tr, &cfg)?;
        println!(
            "{:<12} iters={:<3} objective={:.6} oracle_ms/iter={:>7.2} test_err={:.4}",
            out.method,
            out.iterations,
            out.objective,
            1e3 * out.avg_oracle_secs(),
            evaluate(&out.model, &te),
        );
    }

    // Oracle-level contrast across the number of levels r: the r-level
    // algorithm degrades as r grows, the tree does not (the paper's
    // core asymptotic point, §4.1).
    println!("\n== oracle cost vs number of utility levels r (m={m}) ==");
    println!("{:>8} {:>14} {:>14}", "r", "tree (ms)", "rlevel (ms)");
    for levels in [2, 5, 20, 100, 1000] {
        let ds = synthetic::ordinal(m, levels, 19);
        let p: Vec<f64> = ds.y.iter().map(|v| v * 0.3).collect();
        let n = count_comparable_pairs(&ds.y) as f64;
        let mut tree = TreeOracle::new();
        let mut rlevel = ranksvm::losses::RLevelOracle::new();
        let time = |o: &mut dyn RankingOracle| {
            let t = std::time::Instant::now();
            for _ in 0..3 {
                std::hint::black_box(o.eval(&p, &ds.y, n));
            }
            t.elapsed().as_secs_f64() / 3.0 * 1e3
        };
        println!("{:>8} {:>14.3} {:>14.3}", levels, time(&mut tree), time(&mut rlevel));
    }
    println!("\n(the tree column stays flat; the r-level column grows with r)");
    Ok(())
}
