//! The serving engine: an immutable model epoch shared by concurrent
//! score batches, with atomic zero-downtime hot swap.
//!
//! ## Versioning and swap semantics
//!
//! The live model sits behind one pointer swap: `slot:
//! RwLock<Arc<ModelEpoch>>`. A batch snapshots the `Arc` **once**, at
//! its start, and scores every request in the batch against that epoch
//! — so a response is always consistent with exactly one model version
//! (reported as `v=<n>` in the wire protocol), never a torn mix, and a
//! swap takes effect at the next *batch boundary*. When the last
//! in-flight batch holding an old epoch finishes, its `Arc` drop
//! unmaps the old model file.
//!
//! Publishing a new model is [`ScoringModel::save`]'s atomic rename
//! (or [`Engine::swap_from`], which renames a staged file over the
//! live path). The engine stats the model path at each batch boundary
//! and reloads when the file identity (length, mtime, inode) changes;
//! a file that fails to load is remembered and *not* retried every
//! batch — the previous epoch keeps serving until a good file shows
//! up. Because publishes are renames, a changed identity is always a
//! complete file, never a half-written one.
//!
//! ## Execution
//!
//! A batch fans out one [`Task`] per request onto the shared
//! work-stealing [`WorkerPool`] — the same pool that runs training —
//! with each task writing its own disjoint response slot. Scoring a
//! single request is serial (the shared `score_row` kernel), so
//! responses are bit-identical at any `--threads` value; the pool's
//! internal batch lock serializes concurrent `run` calls, which is the
//! request queue: callers line up, each batch drains fully before the
//! next starts.

use super::protocol::{Payload, Request, Response, Selector};
use super::scoring::{score_row, ScoringModel};
use crate::data::{DatasetView, LoadedDataset};
use crate::losses::GroupIndex;
use crate::obs::metrics as obs_metrics;
use crate::runtime::{Task, WorkerPool};
use anyhow::{bail, Context, Result};
use std::cmp::Ordering;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering::Relaxed};
use std::sync::{Arc, Mutex, RwLock};
use std::time::Instant;

/// One immutable generation of the live model. Batches hold an `Arc`
/// to the epoch they scored against; the version number is what
/// responses report.
pub struct ModelEpoch {
    pub version: u64,
    pub model: ScoringModel,
}

/// File identity snapshot used to detect publishes: atomic renames
/// change the inode, direct rewrites change length/mtime.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
struct Fingerprint {
    len: u64,
    mtime: (u64, u32),
    ino: u64,
}

fn fingerprint(path: &Path) -> Option<Fingerprint> {
    let md = std::fs::metadata(path).ok()?;
    let mtime = md
        .modified()
        .ok()
        .and_then(|t| t.duration_since(std::time::UNIX_EPOCH).ok())
        .map(|d| (d.as_secs(), d.subsec_nanos()))
        .unwrap_or((0, 0));
    #[cfg(unix)]
    let ino = {
        use std::os::unix::fs::MetadataExt;
        md.ino()
    };
    #[cfg(not(unix))]
    let ino = 0;
    Some(Fingerprint { len: md.len(), mtime, ino })
}

/// The long-lived serving state: model slot, optional feature store,
/// precomputed group index, and the worker pool batches fan out on.
pub struct Engine {
    model_path: PathBuf,
    verify: bool,
    slot: RwLock<Arc<ModelEpoch>>,
    /// Fingerprint of the last model file we *attempted* to load
    /// (success or not), so a corrupt publish is not retried per batch.
    source: Mutex<Fingerprint>,
    data: Option<LoadedDataset>,
    gindex: Option<Arc<GroupIndex>>,
    pool: WorkerPool,
    batches: AtomicU64,
    requests: AtomicU64,
    swaps: AtomicU64,
    /// Requests answered with an `err` body (structured failures, not
    /// protocol-level drops). Mirrored into `ranksvm_serve_errors_total`.
    errors: AtomicU64,
    started: Instant,
}

impl Engine {
    /// Load the model (either format, via [`ScoringModel::load_auto_with`])
    /// and build the serving state. `data` enables `rows`/`topk`
    /// requests; its query-group index is precomputed here, once.
    pub fn new(
        model_path: impl AsRef<Path>,
        data: Option<LoadedDataset>,
        n_threads: usize,
        verify: bool,
    ) -> Result<Engine> {
        let model_path = model_path.as_ref().to_path_buf();
        let model = ScoringModel::load_auto_with(&model_path, verify)?;
        let source = fingerprint(&model_path).unwrap_or_default();
        let gindex = data.as_ref().and_then(|d| {
            let v = d.view();
            v.group_index()
                .or_else(|| v.qid().map(|q| Arc::new(GroupIndex::build(q, v.y()))))
        });
        obs_metrics::SERVE_MODEL_VERSION.set(1);
        Ok(Engine {
            model_path,
            verify,
            slot: RwLock::new(Arc::new(ModelEpoch { version: 1, model })),
            source: Mutex::new(source),
            data,
            gindex,
            pool: WorkerPool::new(n_threads),
            batches: AtomicU64::new(0),
            requests: AtomicU64::new(0),
            swaps: AtomicU64::new(0),
            errors: AtomicU64::new(0),
            started: Instant::now(),
        })
    }

    /// The epoch new batches would score against right now.
    pub fn current(&self) -> Arc<ModelEpoch> {
        self.slot.read().expect("model slot poisoned").clone()
    }

    pub fn model_path(&self) -> &Path {
        &self.model_path
    }

    pub fn n_threads(&self) -> usize {
        self.pool.n_threads()
    }

    /// Rows in the attached feature store, if one was given.
    pub fn n_rows(&self) -> Option<usize> {
        self.data.as_ref().map(|d| d.view().len())
    }

    /// Query groups in the attached store, if it carries qids.
    pub fn n_groups(&self) -> Option<usize> {
        self.gindex.as_ref().map(|g| g.n_groups())
    }

    /// Cumulative `(batches, requests, swaps)` counters.
    pub fn counters(&self) -> (u64, u64, u64) {
        (self.batches.load(Relaxed), self.requests.load(Relaxed), self.swaps.load(Relaxed))
    }

    /// Requests that produced a structured `err` response.
    pub fn errors_count(&self) -> u64 {
        self.errors.load(Relaxed)
    }

    /// Whole seconds since this engine was constructed.
    pub fn uptime_secs(&self) -> u64 {
        self.started.elapsed().as_secs()
    }

    /// Batch-boundary publish check: reload if the model file identity
    /// changed. Best-effort — on a failed load the old epoch keeps
    /// serving and the bad fingerprint is remembered.
    pub fn maybe_reload(&self) {
        let _ = self.reload_impl(false);
    }

    /// Explicit `reload` command: always re-open the model path and
    /// bump the version. Errors are returned to the caller (the old
    /// epoch keeps serving).
    pub fn force_reload(&self) -> Result<()> {
        self.reload_impl(true)
    }

    fn reload_impl(&self, force: bool) -> Result<()> {
        let mut src = self.source.lock().expect("source lock poisoned");
        let fp = match fingerprint(&self.model_path) {
            Some(fp) => fp,
            None if force => bail!("stat {}: model file is gone", self.model_path.display()),
            None => return Ok(()),
        };
        if !force && fp == *src {
            return Ok(());
        }
        *src = fp;
        let model = ScoringModel::load_auto_with(&self.model_path, self.verify)?;
        let mut slot = self.slot.write().expect("model slot poisoned");
        let version = slot.version + 1;
        *slot = Arc::new(ModelEpoch { version, model });
        drop(slot);
        self.swaps.fetch_add(1, Relaxed);
        obs_metrics::SERVE_SWAPS.inc();
        obs_metrics::SERVE_MODEL_VERSION.set(version);
        Ok(())
    }

    /// Atomic hot swap from a staged file: validate the staged model,
    /// `rename` it over the live path (the atomic publish), then
    /// reload. A staged file that fails validation leaves the live
    /// model untouched.
    pub fn swap_from(&self, staged: impl AsRef<Path>) -> Result<()> {
        let staged = staged.as_ref();
        ScoringModel::load_auto_with(staged, self.verify)
            .with_context(|| format!("staged model {}", staged.display()))?;
        std::fs::rename(staged, &self.model_path).with_context(|| {
            format!("publish {} over {}", staged.display(), self.model_path.display())
        })?;
        self.force_reload()
    }

    /// Score one batch: snapshot the current epoch once, fan one task
    /// per request onto the pool (disjoint response slots), and answer
    /// in request order, every response stamped with that epoch's
    /// version. Blocks until the whole batch is done.
    pub fn run_batch(&self, reqs: &[Request]) -> Vec<Response> {
        if reqs.is_empty() {
            return Vec::new();
        }
        self.maybe_reload();
        let epoch = self.current();
        self.batches.fetch_add(1, Relaxed);
        self.requests.fetch_add(reqs.len() as u64, Relaxed);
        obs_metrics::SERVE_BATCHES.inc();
        obs_metrics::SERVE_REQUESTS.add(reqs.len() as u64);
        obs_metrics::SERVE_BATCH_SIZE.observe(reqs.len() as u64);
        let mut replies: Vec<Option<std::result::Result<Payload, String>>> = Vec::new();
        replies.resize_with(reqs.len(), || None);
        {
            let model = &epoch.model;
            let data = self.data.as_ref();
            let gindex = self.gindex.as_deref();
            let tasks: Vec<Task<'_>> = reqs
                .iter()
                .zip(replies.iter_mut())
                .map(|(req, out)| {
                    Box::new(move || {
                        let t0 = Instant::now();
                        let body = handle_one(model, data, gindex, req);
                        let us = t0.elapsed().as_micros().min(u64::MAX as u128) as u64;
                        obs_metrics::SERVE_REQUEST_LATENCY_US.observe(us);
                        *out = Some(body);
                    }) as Task<'_>
                })
                .collect();
            self.pool.run(tasks);
        }
        let responses: Vec<Response> = replies
            .into_iter()
            .map(|body| Response {
                version: epoch.version,
                body: body.expect("pool runs every task to completion"),
            })
            .collect();
        let n_err = responses.iter().filter(|r| r.body.is_err()).count() as u64;
        if n_err > 0 {
            self.errors.fetch_add(n_err, Relaxed);
            obs_metrics::SERVE_ERRORS.add(n_err);
        }
        responses
    }
}

/// Score one request against one epoch. Every failure is a structured
/// message — nothing here panics on user input.
fn handle_one(
    model: &ScoringModel,
    data: Option<&LoadedDataset>,
    gindex: Option<&GroupIndex>,
    req: &Request,
) -> std::result::Result<Payload, String> {
    match req {
        Request::Invalid(msg) => Err(msg.clone()),
        Request::Score(feats) => match model.score_indexed(feats) {
            Ok(s) => Ok(Payload::Scores(vec![s])),
            Err(e) => Err(e.to_string()),
        },
        Request::Rows(rows) => {
            let Some(data) = data else {
                return Err("no feature store attached (start serve with --data)".into());
            };
            let view = data.view();
            let x = view.x();
            let mut out = Vec::with_capacity(rows.len());
            for &i in rows {
                if i >= x.rows() {
                    return Err(format!("row {i} out of range (store has {} rows)", x.rows()));
                }
                let (idx, val) = x.row(i);
                out.push(score_row(model.w(), model.norms(), idx, val));
            }
            Ok(Payload::Scores(out))
        }
        Request::TopK { k, sel } => {
            let Some(data) = data else {
                return Err("no feature store attached (start serve with --data)".into());
            };
            let view = data.view();
            let x = view.x();
            let (w, norms) = (model.w(), model.norms());
            let score = |i: usize| {
                let (idx, val) = x.row(i);
                score_row(w, norms, idx, val)
            };
            let ranked = match sel {
                Selector::All => top_k((0..x.rows()).map(|i| (i, score(i))), *k),
                Selector::Group(g) => {
                    let Some(gi) = gindex else {
                        return Err("store has no query ids (topk group needs them)".into());
                    };
                    if *g >= gi.n_groups() {
                        return Err(format!(
                            "group {g} out of range (store has {} groups)",
                            gi.n_groups()
                        ));
                    }
                    top_k(gi.group(*g).iter().map(|&i| (i, score(i))), *k)
                }
                Selector::Rows(rows) => {
                    for &i in rows {
                        if i >= x.rows() {
                            return Err(format!(
                                "row {i} out of range (store has {} rows)",
                                x.rows()
                            ));
                        }
                    }
                    top_k(rows.iter().map(|&i| (i, score(i))), *k)
                }
            };
            Ok(Payload::Ranked(ranked))
        }
    }
}

/// Heap entry; the ordering *is* the documented ranking contract:
/// higher score wins, ties go to the smaller row index, and NaN is
/// ordered (not panicking) via `total_cmp` — identical to
/// `RankModel::rank`'s `total_cmp` + index sort.
#[derive(Clone, Copy, Debug)]
struct Entry {
    score: f64,
    row: usize,
}

impl Ord for Entry {
    fn cmp(&self, other: &Self) -> Ordering {
        self.score.total_cmp(&other.score).then_with(|| other.row.cmp(&self.row))
    }
}
impl PartialOrd for Entry {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl PartialEq for Entry {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == Ordering::Equal
    }
}
impl Eq for Entry {}

/// Best `k` of a score stream in `O(n log k)` via a bounded min-heap:
/// keep the k best seen, replace the worst kept only when strictly
/// beaten. Output is best-first and equals a full sort by
/// `score desc, row asc` truncated to k, for any stream order
/// (`tests/serve.rs` pins this against the brute-force reference).
pub fn top_k(items: impl Iterator<Item = (usize, f64)>, k: usize) -> Vec<(usize, f64)> {
    use std::cmp::Reverse;
    use std::collections::BinaryHeap;
    if k == 0 {
        return Vec::new();
    }
    let mut heap: BinaryHeap<Reverse<Entry>> = BinaryHeap::with_capacity(k + 1);
    for (row, score) in items {
        let e = Entry { score, row };
        if heap.len() < k {
            heap.push(Reverse(e));
        } else if e > heap.peek().expect("heap is at capacity").0 {
            heap.pop();
            heap.push(Reverse(e));
        }
    }
    let mut kept: Vec<Entry> = heap.into_iter().map(|Reverse(e)| e).collect();
    kept.sort_unstable_by(|a, b| b.cmp(a));
    kept.into_iter().map(|e| (e.row, e.score)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The reference: full sort by score desc / row asc, truncated.
    fn brute(scores: &[f64], k: usize) -> Vec<(usize, f64)> {
        let mut idx: Vec<usize> = (0..scores.len()).collect();
        idx.sort_unstable_by(|&a, &b| scores[b].total_cmp(&scores[a]).then(a.cmp(&b)));
        idx.truncate(k);
        idx.into_iter().map(|i| (i, scores[i])).collect()
    }

    #[test]
    fn top_k_equals_sort_truncate() {
        let scores = [3.0, -1.0, 3.0, 0.5, f64::NAN, 7.0, 0.5, -2.0, 7.0];
        for k in 0..=scores.len() + 2 {
            let got = top_k(scores.iter().copied().enumerate(), k);
            let want = brute(&scores, k);
            assert_eq!(got.len(), want.len(), "k={k}");
            for (g, w) in got.iter().zip(&want) {
                assert_eq!(g.0, w.0, "k={k}");
                assert_eq!(g.1.to_bits(), w.1.to_bits(), "k={k}");
            }
        }
    }

    #[test]
    fn top_k_is_stream_order_independent() {
        let scores = [1.0, 2.0, 2.0, 2.0, 0.0, 5.0];
        let forward = top_k(scores.iter().copied().enumerate(), 3);
        let backward = top_k(scores.iter().copied().enumerate().rev(), 3);
        assert_eq!(forward, backward);
        assert_eq!(forward, brute(&scores, 3));
    }

    #[test]
    fn entry_ordering_prefers_score_then_low_row() {
        let a = Entry { score: 2.0, row: 5 };
        let b = Entry { score: 2.0, row: 3 };
        let c = Entry { score: 3.0, row: 9 };
        assert!(c > a && c > b);
        assert!(b > a, "tie broken toward the smaller row");
        assert!(Entry { score: f64::NAN, row: 0 } > c, "total_cmp puts +NaN above all");
    }
}
