//! Query-sharded parallel subgradient oracle.
//!
//! The loss of §2 decomposes over disjoint example subsets two ways, and
//! this engine exploits both on a persistent [`WorkerPool`] (shared with
//! the parallel compute backend and the parallel argsort — one pool per
//! trainer, no per-call thread spawns) while keeping per-shard reusable
//! tree buffers alive across BMRM iterations:
//!
//! **Query-grouped data** (the document-retrieval setting): the risk is
//! an average of per-query losses, so whole query groups are dealt to
//! shards (contiguous runs of groups, balanced by example count) and
//! each worker runs its own [`TreeOracle`] over its groups — the same
//! batch-parallel decomposition pursued by WMRB (Liu, 2017). Per-group
//! results are reduced serially *in group order*, so the output is
//! bit-identical to the serial [`super::QueryGrouped`] wrapper for every
//! shard count.
//!
//! **One global ranking**: the frequencies `c_i`/`d_i` of eqs. (5)–(6)
//! are *integer* dominance counts over the margin window
//! `W(i) = {j : 1 + p_i − p_j > 0}` (a prefix of the score-sorted order).
//! The sorted order is split into [`adaptive_chunks`] contiguous chunks
//! (the per-trainer chunk plan, `clamp(4·threads, 4, 64)` — finer than
//! the shard count), and the *queries* (sorted positions `k`) are dealt
//! to shards as equal contiguous ranges. The shard owning query `k`
//! computes `c_k` as
//!
//! - an incremental red-black-tree count over
//!   `[base, w_end(k))`, where `base` is the chunk boundary at or below
//!   the shard's *first* window end (exactly Algorithm 3's sweep,
//!   restricted to the tail the shard actually owns), plus
//! - one binary search per chunk fully below `base` against that chunk's
//!   pre-sorted label array (phase A, also parallel).
//!
//! `d_i` is the mirror image over suffix windows. Because every per-`i`
//! count is an exact integer decomposed by chunk, the assembled
//! `(loss, coeffs)` is **bit-identical to the single-threaded
//! [`TreeOracle`] for any shard count** — no floating-point reduction
//! enters until [`super::assemble_from_counts`], which runs serially on
//! the full count vectors. Each shard owns `m/S` queries and its tree
//! sweep spans at most the growth of the window extents across them plus
//! one chunk (the extents are monotone, so the sweeps telescope to
//! `O(m)` insertions in total), which is what makes the sharded oracle
//! faster in practice on multi-core hosts (see
//! `benches/fig1_iteration_cost.rs`).
//!
//! Degenerate score distributions (e.g. all predictions within one
//! margin of each other, as at `w = 0`) make every window span the whole
//! array; query-balanced ownership then sends *zero* work through the
//! trees — every count is a round of per-chunk binary searches, which is
//! embarrassingly parallel. (The previous window-end ownership collapsed
//! this case onto one shard; see ROADMAP history.)

use super::{assemble_from_counts, GroupIndex, OracleOutput, RankingOracle};
use crate::linalg::ops::{adaptive_chunks, par_argsort_into};
use crate::losses::tree::TreeOracle;
use crate::rbtree::OsTree;
use crate::runtime::pool::{Task, WorkerPool};
use std::sync::Arc;

/// How examples are dealt to shards.
enum Plan {
    /// One global ranking: contiguous chunks of the score-sorted order.
    Global,
    /// Disjoint query groups (first-seen order, as in
    /// [`super::QueryGrouped`]), dealt to shards as contiguous group
    /// runs balanced by example count.
    Grouped {
        /// The flat group partition (shared convention with
        /// [`super::QueryGrouped`] and the pallas store; `Arc`-shared so
        /// a store-carried index is referenced, not copied).
        index: Arc<GroupIndex>,
        /// Effective group count for averaging (groups with pairs).
        r_eff: f64,
        /// Per shard: `[lo, hi)` range of group indices.
        ranges: Vec<(usize, usize)>,
    },
}

/// Per-shard worker state, reused across oracle calls (and hence across
/// BMRM cutting-plane iterations — the trees and buffers are allocated
/// once and only grow).
struct ShardState {
    /// Incremental counter for the partial-chunk sweep (global mode).
    tree: OsTree,
    /// Counts for this shard's owned queries, in sweep order.
    c_out: Vec<u64>,
    d_out: Vec<u64>,
    /// Grouped mode: a full per-shard tree oracle plus gather buffers.
    oracle: TreeOracle,
    p_buf: Vec<f64>,
    y_buf: Vec<f64>,
    /// Grouped mode: concatenated per-group coefficient outputs plus
    /// `(group, offset, len, loss)` records.
    coeff_buf: Vec<f64>,
    meta: Vec<(usize, usize, usize, f64)>,
}

impl ShardState {
    fn new() -> Self {
        ShardState {
            tree: OsTree::new(),
            c_out: Vec::new(),
            d_out: Vec::new(),
            oracle: TreeOracle::new(),
            p_buf: Vec::new(),
            y_buf: Vec::new(),
            coeff_buf: Vec::new(),
            meta: Vec::new(),
        }
    }
}

/// Shared read-only view handed to the global-mode workers.
struct GlobalView<'a> {
    /// Chunk boundaries over sorted positions, length `n_chunks + 1`
    /// (the adaptive chunk plan — finer than the shard count).
    bounds: &'a [usize],
    /// Owned query range `[lo, hi)` per shard (sorted positions `k`),
    /// used by both the forward and the backward sweep.
    owned: &'a [(usize, usize)],
    y_sorted: &'a [f64],
    /// Forward window ends `w(k)` (exclusive), nondecreasing in `k`.
    w_end: &'a [usize],
    /// Backward window starts `v(k)` (inclusive), nondecreasing in `k`.
    v_start: &'a [usize],
    /// Per-chunk sorted label arrays (phase A output; empty when a
    /// single shard runs the pure serial sweep).
    labels: &'a [Vec<f64>],
}

/// The parallel sharded oracle engine. Construct once per training set
/// (like [`super::QueryGrouped`]); evaluate once per BMRM iteration. All
/// parallel phases run on one persistent [`WorkerPool`], shared with the
/// trainer's compute backend when built via [`Self::with_pool`].
pub struct ShardedTreeOracle {
    pool: Arc<WorkerPool>,
    n_shards: usize,
    /// Global-mode chunk count for the binary-search substrate —
    /// [`adaptive_chunks`] of the pool size, fixed at construction
    /// (once per trainer). Finer than the shard count, so each shard's
    /// incremental tree sweep starts at a chunk boundary close to its
    /// first window extent; counts are exact integers, so the chunk
    /// count cannot change a result bit.
    n_chunks: usize,
    plan: Plan,
    shards: Vec<ShardState>,
    /// Per-chunk sorted labels, outside [`ShardState`] so phase-B workers
    /// can read every *other* shard's array.
    sorted_labels: Vec<Vec<f64>>,
    // Per-eval scratch (global mode), reused across calls.
    pi: Vec<usize>,
    sort_scratch: Vec<usize>,
    p_sorted: Vec<f64>,
    y_sorted: Vec<f64>,
    w_end: Vec<usize>,
    v_start: Vec<usize>,
    c: Vec<u64>,
    d: Vec<u64>,
}

impl ShardedTreeOracle {
    /// Build with a private pool of `n_threads` workers. Prefer
    /// [`Self::with_pool`] inside the trainer so the oracle, the compute
    /// backend, and the parallel argsort share one set of threads.
    pub fn new(n_threads: usize, qid: Option<&[u64]>, y: &[f64]) -> Self {
        Self::with_pool(Arc::new(WorkerPool::new(n_threads)), qid, y)
    }

    /// Build on an existing persistent pool (one shard per pool worker)
    /// over a fixed training label vector; `qid` enables query-group
    /// sharding (must align with `y`).
    pub fn with_pool(pool: Arc<WorkerPool>, qid: Option<&[u64]>, y: &[f64]) -> Self {
        let index = qid.map(|q| Arc::new(GroupIndex::build(q, y)));
        Self::from_plan(pool, index)
    }

    /// Build on a persistent pool from a precomputed [`GroupIndex`]
    /// (e.g. the one a pallas store carries) — no per-run group scan,
    /// no copy.
    pub fn with_pool_index(pool: Arc<WorkerPool>, index: Arc<GroupIndex>) -> Self {
        Self::from_plan(pool, Some(index))
    }

    fn from_plan(pool: Arc<WorkerPool>, index: Option<Arc<GroupIndex>>) -> Self {
        let n_shards = pool.n_threads().max(1);
        let n_chunks = adaptive_chunks(n_shards);
        let plan = match index {
            None => Plan::Global,
            Some(index) => {
                let r_eff = index.n_effective_groups().max(1) as f64;
                let ranges = split_groups(&index, n_shards);
                Plan::Grouped { index, r_eff, ranges }
            }
        };
        ShardedTreeOracle {
            pool,
            n_shards,
            n_chunks,
            plan,
            shards: (0..n_shards).map(|_| ShardState::new()).collect(),
            sorted_labels: Vec::new(),
            pi: Vec::new(),
            sort_scratch: Vec::new(),
            p_sorted: Vec::new(),
            y_sorted: Vec::new(),
            w_end: Vec::new(),
            v_start: Vec::new(),
            c: Vec::new(),
            d: Vec::new(),
        }
    }

    /// Number of shard workers.
    pub fn n_shards(&self) -> usize {
        self.n_shards
    }

    /// The persistent pool this oracle evaluates on.
    pub fn pool(&self) -> &Arc<WorkerPool> {
        &self.pool
    }

    /// Query-group count (None for a single global ranking).
    pub fn n_groups(&self) -> Option<usize> {
        match &self.plan {
            Plan::Global => None,
            Plan::Grouped { index, .. } => Some(index.n_groups()),
        }
    }

    /// Per-shard `[lo, hi)` group-index ranges (None in global mode).
    /// Ranges are contiguous and non-overlapping: a query group is never
    /// split across shards.
    pub fn group_ranges(&self) -> Option<&[(usize, usize)]> {
        match &self.plan {
            Plan::Global => None,
            Plan::Grouped { ranges, .. } => Some(ranges),
        }
    }

    /// Total comparable pairs across groups (grouped mode reporting).
    pub fn total_pairs(&self) -> Option<f64> {
        match &self.plan {
            Plan::Global => None,
            Plan::Grouped { index, .. } => Some(index.total_pairs()),
        }
    }

    fn eval_global(&mut self, p: &[f64], y: &[f64], n_pairs: f64) -> OracleOutput {
        let m = p.len();
        assert_eq!(m, y.len());
        if m == 0 {
            return OracleOutput { loss: 0.0, coeffs: Vec::new() };
        }
        let n_shards = self.n_shards.min(m);

        // Shared setup — the same permutation TreeOracle's sort produces
        // (the parallel merge sort is bit-identical to the serial
        // argsort), gathered so the sweeps stream contiguous memory.
        par_argsort_into(p, &mut self.pi, &mut self.sort_scratch, &self.pool);
        self.p_sorted.clear();
        self.p_sorted.extend(self.pi.iter().map(|&k| p[k]));
        self.y_sorted.clear();
        self.y_sorted.extend(self.pi.iter().map(|&k| y[k]));

        // Window extents via two-pointer scans, with the *same* float
        // predicates as the serial sweeps so the counted sets match
        // exactly. Forward: W(k) = [0, w_end[k]) with
        // w_end[k] = first j failing 1 + p_k − p_j > 0 (nondecreasing,
        // and ≥ k+1 since j = k always passes). Backward:
        // V(k) = [v_start[k], m) with v_start[k] = first j passing
        // 1 + p_j − p_k > 0 (nondecreasing, and ≤ k).
        self.w_end.clear();
        self.w_end.reserve(m);
        {
            let ps = &self.p_sorted;
            let mut j = 0usize;
            for k in 0..m {
                let pk = ps[k];
                while j < m && 1.0 + pk - ps[j] > 0.0 {
                    j += 1;
                }
                self.w_end.push(j);
            }
        }
        self.v_start.clear();
        self.v_start.reserve(m);
        {
            let ps = &self.p_sorted;
            let mut j = 0usize;
            for k in 0..m {
                let pk = ps[k];
                // Advance past the js that fail the serial predicate
                // 1 + p_j − p_k > 0 (labels are NaN-free here, so the
                // `<=` form is its exact negation).
                while j < m && 1.0 + ps[j] - pk <= 0.0 {
                    j += 1;
                }
                self.v_start.push(j);
            }
        }

        // Contiguous chunks of the sorted order (binary-search
        // substrate, [`adaptive_chunks`] of the pool size — finer than
        // the shard count so sweep bases land close to the first window
        // extents) and equal contiguous *query* ranges per shard.
        // Query-balanced ownership keeps the per-shard tree sweeps
        // bounded even when every window spans the whole array (the
        // degenerate all-scores-within-one-margin case): window ends
        // that land on chunk boundaries contribute binary searches only,
        // so that case redistributes across all shards instead of
        // collapsing onto the owner of the last chunk.
        let n_chunks = if n_shards == 1 { 1 } else { self.n_chunks.clamp(1, m) };
        let bounds: Vec<usize> = (0..=n_chunks).map(|c| c * m / n_chunks).collect();
        let owned: Vec<(usize, usize)> =
            (0..n_shards).map(|s| (s * m / n_shards, (s + 1) * m / n_shards)).collect();

        // Phase A: per-chunk sorted label arrays (cross-chunk counting
        // substrate). Skipped for a single shard — the lone worker runs
        // the pure serial sweep over one whole-array chunk and never
        // consults them.
        self.sorted_labels.resize_with(n_chunks, Vec::new);
        if n_chunks > 1 {
            let y_sorted = &self.y_sorted;
            let mut tasks: Vec<Task> = Vec::with_capacity(n_chunks);
            for (s, lab) in self.sorted_labels.iter_mut().enumerate() {
                let (lo, hi) = (bounds[s], bounds[s + 1]);
                tasks.push(Box::new(move || {
                    lab.clear();
                    // NaN labels are incomparable (they contribute to no
                    // count, exactly like in the tree sweeps, which skip
                    // inserting them) — drop them here so the numeric
                    // partition_point predicates below stay consistent
                    // with the tree path for any shard count.
                    lab.extend(y_sorted[lo..hi].iter().copied().filter(|x| !x.is_nan()));
                    lab.sort_unstable_by(|a, b| a.total_cmp(b));
                }));
            }
            self.pool.run(tasks);
        }

        // Phase B: each worker counts its owned queries.
        let view = GlobalView {
            bounds: &bounds,
            owned: &owned,
            y_sorted: &self.y_sorted,
            w_end: &self.w_end,
            v_start: &self.v_start,
            labels: &self.sorted_labels,
        };
        if n_shards == 1 {
            global_worker(0, &view, &mut self.shards[0]);
        } else {
            let view = &view;
            let mut tasks: Vec<Task> = Vec::with_capacity(n_shards);
            for (s, state) in self.shards.iter_mut().take(n_shards).enumerate() {
                tasks.push(Box::new(move || global_worker(s, view, state)));
            }
            self.pool.run(tasks);
        }

        // Scatter the per-shard counts back to original example order and
        // assemble — serial and order-fixed, so the float result cannot
        // depend on the shard count.
        self.c.clear();
        self.c.resize(m, 0);
        self.d.clear();
        self.d.resize(m, 0);
        for s in 0..n_shards {
            let st = &self.shards[s];
            let (q_lo, q_hi) = owned[s];
            for (t, k) in (q_lo..q_hi).enumerate() {
                self.c[self.pi[k]] = st.c_out[t];
            }
            // d_out was pushed for descending k.
            for (t, k) in (q_lo..q_hi).rev().enumerate() {
                self.d[self.pi[k]] = st.d_out[t];
            }
        }
        assemble_from_counts(p, &self.c, &self.d, n_pairs)
    }

    fn eval_grouped(&mut self, p: &[f64], y: &[f64]) -> OracleOutput {
        let m = p.len();
        assert_eq!(m, y.len());
        let Plan::Grouped { index, r_eff, ranges } = &self.plan else {
            unreachable!("eval_grouped requires a grouped plan")
        };
        let r_eff = *r_eff;
        let shards = &mut self.shards;

        let gi: &GroupIndex = index;
        if shards.len() == 1 {
            grouped_worker(&mut shards[0], ranges[0], gi, p, y);
        } else {
            let mut tasks: Vec<Task> = Vec::with_capacity(shards.len());
            for (s, state) in shards.iter_mut().enumerate() {
                let range = ranges[s];
                tasks.push(Box::new(move || grouped_worker(state, range, gi, p, y)));
            }
            self.pool.run(tasks);
        }

        // Reduce in group order. Shards hold contiguous ascending group
        // runs, so iterating shards then their records reproduces the
        // serial QueryGrouped accumulation order bit-for-bit.
        let mut loss = 0.0;
        let mut coeffs = vec![0.0; m];
        for state in self.shards.iter() {
            for &(g, off, len, group_loss) in &state.meta {
                loss += group_loss / r_eff;
                let idx = index.group(g);
                debug_assert_eq!(len, idx.len());
                for (k, &i) in idx.iter().enumerate() {
                    coeffs[i] = state.coeff_buf[off + k] / r_eff;
                }
            }
        }
        OracleOutput { loss, coeffs }
    }
}

impl RankingOracle for ShardedTreeOracle {
    /// `n_pairs` normalizes the global mode; in grouped mode the
    /// per-group counts fixed at construction are authoritative (same
    /// contract as [`super::QueryGrouped`]).
    fn eval(&mut self, p: &[f64], y: &[f64], n_pairs: f64) -> OracleOutput {
        if matches!(self.plan, Plan::Global) {
            self.eval_global(p, y, n_pairs)
        } else {
            self.eval_grouped(p, y)
        }
    }

    fn name(&self) -> &'static str {
        "sharded-tree"
    }
}

/// Deal groups to `n_shards` contiguous runs balanced by example count.
/// Deterministic in the inputs; the last shard absorbs the remainder.
fn split_groups(index: &GroupIndex, n_shards: usize) -> Vec<(usize, usize)> {
    let n_groups = index.n_groups();
    let total: usize = index.n_examples();
    let mut ranges = Vec::with_capacity(n_shards);
    let mut lo = 0usize;
    let mut cum = 0usize;
    for s in 0..n_shards {
        let mut hi = lo;
        if s + 1 == n_shards {
            hi = n_groups;
        } else {
            let target = total * (s + 1) / n_shards;
            while hi < n_groups && cum < target {
                cum += index.group(hi).len();
                hi += 1;
            }
        }
        ranges.push((lo, hi));
        lo = hi;
    }
    ranges
}

/// Grouped-mode worker: evaluate this shard's query groups with its own
/// reusable tree oracle, recording per-group losses and coefficients.
fn grouped_worker(
    state: &mut ShardState,
    range: (usize, usize),
    index: &GroupIndex,
    p: &[f64],
    y: &[f64],
) {
    state.meta.clear();
    state.coeff_buf.clear();
    for g in range.0..range.1 {
        let ng = index.group_pairs(g) as f64;
        if ng == 0.0 {
            continue;
        }
        let idx = index.group(g);
        state.p_buf.clear();
        state.p_buf.extend(idx.iter().map(|&i| p[i]));
        state.y_buf.clear();
        state.y_buf.extend(idx.iter().map(|&i| y[i]));
        let out = state.oracle.eval(&state.p_buf, &state.y_buf, ng);
        let off = state.coeff_buf.len();
        state.coeff_buf.extend_from_slice(&out.coeffs);
        state.meta.push((g, off, idx.len(), out.loss));
    }
}

/// Global-mode worker: exact `c`/`d` counts for this shard's contiguous
/// query range. The tree sweep covers `[base, w_end(k))` where `base` is
/// the chunk boundary at or below the range's first window end; chunks
/// fully below `base` are counted with one binary search each against
/// their pre-sorted labels. Counts are exact integers either way, so the
/// split point cannot change a result bit.
fn global_worker(s: usize, v: &GlobalView, state: &mut ShardState) {
    let n_chunks = v.bounds.len() - 1;
    let (q_lo, q_hi) = v.owned[s];

    // NaN labels are incomparable: they are never inserted (a NaN key
    // would sit structure-dependently in the BST and make counts vary
    // with the shard split) and a NaN query counts zero on both the tree
    // and the binary-search path — so counts stay exact and
    // shard-count-invariant even for unvalidated label vectors.

    // Forward sweep: c_k = |{j ∈ W(k) : y_j > y_k}|.
    state.c_out.clear();
    state.tree.clear();
    if q_lo < q_hi {
        // Largest chunk boundary ≤ w_end[q_lo] (w_end ≥ 1, so t0 ≥ 0).
        // A single shard owns everything and sweeps from 0 — the pure
        // serial path, no label arrays needed.
        let t0 = if n_chunks == 1 {
            0
        } else {
            v.bounds.partition_point(|&b| b <= v.w_end[q_lo]) - 1
        };
        let mut j = v.bounds[t0];
        for k in q_lo..q_hi {
            while j < v.w_end[k] {
                let yj = v.y_sorted[j];
                if !yj.is_nan() {
                    state.tree.insert(yj);
                }
                j += 1;
            }
            let yk = v.y_sorted[k];
            let cnt = if yk.is_nan() {
                0
            } else {
                let mut cnt = state.tree.count_larger(yk);
                for lab in &v.labels[..t0] {
                    cnt += (lab.len() - lab.partition_point(|&x| x <= yk)) as u64;
                }
                cnt
            };
            state.c_out.push(cnt);
        }
    }

    // Backward sweep (descending k): d_k = |{j ∈ V(k) : y_j < y_k}|.
    state.d_out.clear();
    state.tree.clear();
    if q_lo < q_hi {
        // Smallest chunk boundary ≥ v_start[q_hi − 1].
        let t1 = if n_chunks == 1 {
            n_chunks
        } else {
            v.bounds.partition_point(|&b| b < v.v_start[q_hi - 1])
        };
        let mut j = v.bounds[t1];
        for k in (q_lo..q_hi).rev() {
            while j > v.v_start[k] {
                j -= 1;
                let yj = v.y_sorted[j];
                if !yj.is_nan() {
                    state.tree.insert(yj);
                }
            }
            let yk = v.y_sorted[k];
            let cnt = if yk.is_nan() {
                0
            } else {
                let mut cnt = state.tree.count_smaller(yk);
                for lab in &v.labels[t1..n_chunks] {
                    cnt += lab.partition_point(|&x| x < yk) as u64;
                }
                cnt
            };
            state.d_out.push(cnt);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::losses::{count_comparable_pairs, PairOracle, QueryGrouped};
    use crate::util::rng::Rng;

    fn random_case(rng: &mut Rng, trial: usize) -> (Vec<f64>, Vec<f64>) {
        let m = 1 + rng.below(250);
        let y: Vec<f64> = match trial % 4 {
            0 => (0..m).map(|_| rng.normal()).collect(), // r ≈ m
            1 => (0..m).map(|_| rng.below(5) as f64).collect(), // heavy ties
            2 => (0..m).map(|_| rng.below(2) as f64).collect(), // bipartite
            _ => vec![3.0; m],                           // fully tied
        };
        // Quantized scores land exactly on margins; mix in ties.
        let p: Vec<f64> = match trial % 3 {
            0 => (0..m).map(|_| rng.normal() * 2.0).collect(),
            1 => (0..m).map(|_| (rng.below(30) as f64) / 7.0 - 2.0).collect(),
            _ => (0..m).map(|_| rng.below(3) as f64).collect(),
        };
        (p, y)
    }

    #[test]
    fn global_mode_bit_identical_to_tree_oracle() {
        let mut rng = Rng::new(9001);
        for trial in 0..60 {
            let (p, y) = random_case(&mut rng, trial);
            let n = count_comparable_pairs(&y) as f64;
            let mut reference = TreeOracle::new();
            let expect = reference.eval(&p, &y, n);
            for threads in [1, 2, 3, 8, 33] {
                let mut sharded = ShardedTreeOracle::new(threads, None, &y);
                let got = sharded.eval(&p, &y, n);
                assert_eq!(got.coeffs, expect.coeffs, "trial {trial}, {threads} shards");
                assert_eq!(
                    got.loss.to_bits(),
                    expect.loss.to_bits(),
                    "trial {trial}, {threads} shards"
                );
            }
        }
    }

    #[test]
    fn global_mode_matches_pair_oracle_counts() {
        let mut rng = Rng::new(9002);
        for trial in 0..40 {
            let (p, y) = random_case(&mut rng, trial);
            let n = count_comparable_pairs(&y) as f64;
            let mut pair = PairOracle::new();
            let expect = pair.eval(&p, &y, n);
            let mut sharded = ShardedTreeOracle::new(4, None, &y);
            let got = sharded.eval(&p, &y, n);
            assert_eq!(got.coeffs, expect.coeffs, "trial {trial}");
            assert!((got.loss - expect.loss).abs() <= 1e-12 * (1.0 + expect.loss));
        }
    }

    #[test]
    fn grouped_mode_bit_identical_to_query_grouped() {
        let mut rng = Rng::new(9003);
        for trial in 0..40 {
            let m = 1 + rng.below(200);
            let n_queries = 1 + rng.below(12);
            let qid: Vec<u64> = (0..m).map(|_| rng.below(n_queries) as u64 * 17).collect();
            let y: Vec<f64> = (0..m).map(|_| rng.below(4) as f64).collect();
            let p: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
            let mut serial = QueryGrouped::new(TreeOracle::new(), &qid, &y);
            let expect = serial.eval(&p, &y, serial.total_pairs());
            for threads in [1, 2, 8, 40] {
                let mut sharded = ShardedTreeOracle::new(threads, Some(&qid), &y);
                let got = sharded.eval(&p, &y, 0.0);
                assert_eq!(got.coeffs, expect.coeffs, "trial {trial}, {threads} shards");
                assert_eq!(
                    got.loss.to_bits(),
                    expect.loss.to_bits(),
                    "trial {trial}, {threads} shards"
                );
            }
        }
    }

    #[test]
    fn shard_plan_respects_query_boundaries() {
        let mut rng = Rng::new(9004);
        let m = 300;
        let qid: Vec<u64> = (0..m).map(|i| (i / 7) as u64).collect();
        let y: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
        for threads in [1, 3, 8] {
            let oracle = ShardedTreeOracle::new(threads, Some(&qid), &y);
            let ranges = oracle.group_ranges().unwrap();
            let n_groups = oracle.n_groups().unwrap();
            assert_eq!(ranges.len(), threads);
            // Contiguous, non-overlapping cover of all groups: groups are
            // assigned whole — no group index appears in two shards.
            let mut expect_lo = 0;
            for &(lo, hi) in ranges {
                assert_eq!(lo, expect_lo);
                assert!(hi >= lo);
                expect_lo = hi;
            }
            assert_eq!(expect_lo, n_groups);
        }
    }

    #[test]
    fn degenerate_inputs() {
        let mut o = ShardedTreeOracle::new(4, None, &[]);
        let out = o.eval(&[], &[], 0.0);
        assert_eq!(out.loss, 0.0);
        assert!(out.coeffs.is_empty());

        // Fewer examples than shards.
        let y = [1.0, 2.0];
        let mut o = ShardedTreeOracle::new(8, None, &y);
        let out = o.eval(&[0.0, 0.5], &y, 1.0);
        let mut reference = TreeOracle::new();
        let expect = reference.eval(&[0.0, 0.5], &y, 1.0);
        assert_eq!(out.coeffs, expect.coeffs);

        // All-tied predictions: every window spans everything — with
        // query-balanced ownership this runs entirely on per-chunk
        // binary searches, spread across every shard.
        let y = [1.0, 2.0, 3.0, 4.0];
        let p = [0.0, 0.0, 0.0, 0.0];
        let n = count_comparable_pairs(&y) as f64;
        let mut o = ShardedTreeOracle::new(3, None, &y);
        let out = o.eval(&p, &y, n);
        assert!((out.loss - 1.0).abs() < 1e-12);
    }

    #[test]
    fn degenerate_window_case_spreads_counts_across_shards() {
        // All scores within one margin: every w_end = m, every
        // v_start = 0. Each shard must produce counts for exactly its
        // own query range (no shard ends up owning everything), and the
        // counts must match the serial oracle bit-for-bit.
        let mut rng = Rng::new(9005);
        let m = 257;
        let y: Vec<f64> = (0..m).map(|_| rng.below(6) as f64).collect();
        let p: Vec<f64> = (0..m).map(|_| rng.normal() * 1e-4).collect();
        let n = count_comparable_pairs(&y) as f64;
        let mut reference = TreeOracle::new();
        let expect = reference.eval(&p, &y, n);
        for threads in [2usize, 4, 8] {
            let mut sharded = ShardedTreeOracle::new(threads, None, &y);
            let got = sharded.eval(&p, &y, n);
            assert_eq!(got.coeffs, expect.coeffs, "{threads} shards");
            // Ownership is balanced by construction: every shard holds
            // its m/S slice of the count outputs.
            for (s, st) in sharded.shards.iter().enumerate() {
                let expect_len = (s + 1) * m / threads - s * m / threads;
                assert_eq!(st.c_out.len(), expect_len, "shard {s} fwd");
                assert_eq!(st.d_out.len(), expect_len, "shard {s} bwd");
            }
        }
    }

    #[test]
    fn nan_labels_are_incomparable_and_shard_count_invariant() {
        // A NaN label must neither panic nor break bit-identity: it is
        // never inserted into a counting tree and counts zero as a
        // query, on the serial and every sharded path alike.
        let mut rng = Rng::new(9006);
        let m = 120;
        let mut y: Vec<f64> = (0..m).map(|_| rng.below(4) as f64).collect();
        y[7] = f64::NAN;
        y[64] = f64::NAN;
        let p: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
        let mut reference = TreeOracle::new();
        let expect = reference.eval(&p, &y, 100.0);
        assert!(expect.loss.is_finite());
        assert_eq!(expect.coeffs[7], 0.0);
        for threads in [1usize, 2, 8] {
            let mut sharded = ShardedTreeOracle::new(threads, None, &y);
            let got = sharded.eval(&p, &y, 100.0);
            assert_eq!(got.coeffs, expect.coeffs, "{threads} shards");
            assert_eq!(got.loss.to_bits(), expect.loss.to_bits(), "{threads} shards");
        }
    }

    #[test]
    fn buffers_reused_across_calls_and_sizes() {
        let mut o = ShardedTreeOracle::new(4, None, &[1.0, 2.0]);
        let a = o.eval(&[0.5, 0.0], &[1.0, 2.0], 1.0);
        assert!(a.loss > 0.0);
        let b = o.eval(&[0.0, 5.0], &[1.0, 2.0], 1.0);
        assert_eq!(b.loss, 0.0);
        // Growing and shrinking sizes across calls.
        let y: Vec<f64> = (0..100).map(|i| (i % 7) as f64).collect();
        let p: Vec<f64> = (0..100).map(|i| ((i * 13) % 29) as f64 * 0.1).collect();
        let n = count_comparable_pairs(&y) as f64;
        let big = o.eval(&p, &y, n);
        let mut reference = TreeOracle::new();
        let expect = reference.eval(&p, &y, n);
        assert_eq!(big.coeffs, expect.coeffs);
        let small = o.eval(&[0.1, 0.0, 2.0], &[1.0, 2.0, 3.0], 3.0);
        let expect_small = reference.eval(&[0.1, 0.0, 2.0], &[1.0, 2.0, 3.0], 3.0);
        assert_eq!(small.coeffs, expect_small.coeffs);
    }

    #[test]
    fn shared_pool_drives_multiple_oracles() {
        // One persistent pool reused by two oracles (the trainer's
        // arrangement: oracle + backend share threads).
        let pool = Arc::new(WorkerPool::new(4));
        let y: Vec<f64> = (0..150).map(|i| (i % 5) as f64).collect();
        let qid: Vec<u64> = (0..150).map(|i| (i / 10) as u64).collect();
        let n = count_comparable_pairs(&y) as f64;
        let mut global = ShardedTreeOracle::with_pool(Arc::clone(&pool), None, &y);
        let mut grouped = ShardedTreeOracle::with_pool(Arc::clone(&pool), Some(&qid), &y);
        let mut reference = TreeOracle::new();
        let mut serial = QueryGrouped::new(TreeOracle::new(), &qid, &y);
        for step in 0..5 {
            let p: Vec<f64> = (0..150).map(|i| ((i * 31 + step * 7) % 23) as f64 * 0.1).collect();
            let expect = reference.eval(&p, &y, n);
            let got = global.eval(&p, &y, n);
            assert_eq!(got.coeffs, expect.coeffs, "step {step}");
            let expect_g = serial.eval(&p, &y, serial.total_pairs());
            let got_g = grouped.eval(&p, &y, 0.0);
            assert_eq!(got_g.coeffs, expect_g.coeffs, "step {step}");
        }
    }

    #[test]
    fn split_groups_balances_and_covers() {
        // 5 groups of sizes 50/10/40/5/95 over 200 examples, via a qid
        // vector with contiguous runs.
        let mut qid = Vec::new();
        for (g, len) in [(0u64, 50usize), (1, 10), (2, 40), (3, 5), (4, 95)] {
            qid.extend(std::iter::repeat(g).take(len));
        }
        let y: Vec<f64> = (0..200).map(|i| (i % 3) as f64).collect();
        let index = GroupIndex::build(&qid, &y);
        for s in 1..=7 {
            let ranges = split_groups(&index, s);
            assert_eq!(ranges.len(), s);
            let mut lo = 0;
            for &(a, b) in &ranges {
                assert_eq!(a, lo);
                lo = b;
            }
            assert_eq!(lo, index.n_groups());
        }
    }

    #[test]
    fn precomputed_index_matches_scan_construction() {
        let mut rng = Rng::new(9007);
        let m = 180;
        let qid: Vec<u64> = (0..m).map(|_| rng.below(9) as u64 * 3).collect();
        let y: Vec<f64> = (0..m).map(|_| rng.below(4) as f64).collect();
        let p: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
        let pool = Arc::new(WorkerPool::new(4));
        let mut scanned = ShardedTreeOracle::with_pool(Arc::clone(&pool), Some(&qid), &y);
        let index = Arc::new(GroupIndex::build(&qid, &y));
        let mut indexed = ShardedTreeOracle::with_pool_index(Arc::clone(&pool), index);
        let a = scanned.eval(&p, &y, 0.0);
        let b = indexed.eval(&p, &y, 0.0);
        assert_eq!(a.coeffs, b.coeffs);
        assert_eq!(a.loss.to_bits(), b.loss.to_bits());
    }
}
