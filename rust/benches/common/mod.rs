//! Shared bench-harness plumbing (criterion substitute — DESIGN.md §6).
//!
//! Each bench binary prints a paper-figure-shaped table to stdout and
//! appends machine-readable JSONL under `bench_results/`. `FULL=1`
//! switches to the paper's full size grids (long-running); the default
//! grids keep `cargo bench` in minutes.

#![allow(dead_code)]

use ranksvm::util::json::Json;
use std::io::Write;

/// True when the full paper-scale grids were requested.
pub fn full_scale() -> bool {
    std::env::var("FULL").map(|v| v == "1").unwrap_or(false)
}

/// Append one JSON record to `bench_results/<name>.jsonl`.
pub fn record(name: &str, json: Json) {
    let dir = std::path::Path::new("bench_results");
    std::fs::create_dir_all(dir).ok();
    let path = dir.join(format!("{name}.jsonl"));
    let mut f = std::fs::OpenOptions::new().create(true).append(true).open(path).unwrap();
    writeln!(f, "{}", json.to_string()).unwrap();
}

/// Pretty separator for figure sections.
pub fn header(title: &str) {
    println!("\n================================================================");
    println!("{title}");
    println!("================================================================");
}

/// True when the bench should emit only its snapshot *schema* — the
/// real envelope and key sets with null metric values — and exit
/// immediately. CI sets `RANKSVM_SNAPSHOT_SCHEMA_ONLY=1` to check the
/// committed `BENCH_*.json` files against what the binaries would
/// write, without paying for a real bench run.
pub fn schema_only() -> bool {
    std::env::var("RANKSVM_SNAPSHOT_SCHEMA_ONLY").map(|v| v == "1").unwrap_or(false)
}

/// Where a bench's tracked snapshot lives: `BENCH_<bench>.json` under
/// `$RANKSVM_SNAPSHOT_DIR` when set (the CI schema gate points this at
/// a temp dir), else at the repo root.
pub fn snapshot_path(bench: &str) -> std::path::PathBuf {
    let dir = std::env::var("RANKSVM_SNAPSHOT_DIR")
        .unwrap_or_else(|_| concat!(env!("CARGO_MANIFEST_DIR"), "/..").to_string());
    std::path::Path::new(&dir).join(format!("BENCH_{bench}.json"))
}

/// Write the bench's snapshot through the shared envelope
/// ([`ranksvm::obs::snapshot::bench_snapshot`], docs/OBSERVABILITY.md).
pub fn write_snapshot(bench: &str, placeholder: bool, params: Json, metrics: Vec<Json>) {
    let snap = ranksvm::obs::snapshot::bench_snapshot(bench, placeholder, params, metrics);
    let path = snapshot_path(bench);
    std::fs::write(&path, format!("{}\n", snap.to_string())).unwrap();
    println!("snapshot written to {}", path.display());
}

/// Real-data hook: when `RANKSVM_DATA` names a dataset file (libsvm
/// text or, ideally, a `.pstore` pallas store — autodetected by magic
/// bytes), the scalability benches add a panel over growing prefixes of
/// it. A store is memory-mapped, so those prefixes are O(1) zero-copy
/// slices — convert once with `ranksvm convert`, bench forever.
pub fn data_from_env() -> Option<ranksvm::data::LoadedDataset> {
    let path = std::env::var("RANKSVM_DATA").ok()?;
    match ranksvm::data::load_auto(&path) {
        Ok(loaded) => Some(loaded),
        Err(e) => {
            eprintln!("RANKSVM_DATA={path}: {e}");
            std::process::exit(2);
        }
    }
}

/// Doubling prefix grid for a real dataset of `m` examples: 1000, 2000,
/// … capped at (and always including) `m` itself.
pub fn prefix_grid(m: usize) -> Vec<usize> {
    let mut sizes = Vec::new();
    let mut s = 1000usize;
    while s < m {
        sizes.push(s);
        s *= 2;
    }
    sizes.push(m);
    sizes
}

/// Format seconds adaptively.
pub fn fmt_secs(s: f64) -> String {
    if s < 1e-3 {
        format!("{:.1}µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{s:.2}s")
    }
}
