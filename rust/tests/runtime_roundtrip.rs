//! Integration: python-AOT artifacts executed from Rust via PJRT must
//! match the native Rust kernels — the full L1/L2 ↔ L3 bridge.
//!
//! Requires the `xla` cargo feature (the PJRT bindings are not in the
//! offline registry — the whole file compiles away without it) and
//! `make artifacts` (skipped with a message otherwise, so `cargo test`
//! stays green on a fresh checkout).
#![cfg(feature = "xla")]

use ranksvm::compute::{ComputeBackend, NativeBackend};
use ranksvm::data::synthetic;
use ranksvm::losses::{count_comparable_pairs, PairOracle, RankingOracle, TreeOracle};
use ranksvm::runtime::{literal_1d, XlaBackend, XlaRuntime};
use ranksvm::util::rng::Rng;

fn artifacts_dir() -> Option<String> {
    let dir = std::env::var("RANKSVM_ARTIFACTS").unwrap_or_else(|_| "artifacts".to_string());
    if std::path::Path::new(&dir).join("manifest.txt").is_file() {
        Some(dir)
    } else {
        eprintln!("skipping: no artifacts at {dir}/ — run `make artifacts`");
        None
    }
}

#[test]
fn xla_backend_matches_native_on_dense_data() {
    let Some(dir) = artifacts_dir() else { return };
    // 700 examples → pads to the 1024-row tile; n = 8 exact match.
    let ds = synthetic::cadata_like(700, 5);
    let mut rng = Rng::new(17);
    let w: Vec<f64> = (0..ds.dim()).map(|_| rng.normal()).collect();
    let coeffs: Vec<f64> = (0..ds.len()).map(|_| rng.normal()).collect();

    let mut native = NativeBackend::new();
    let mut xla = XlaBackend::load(&dir).expect("load artifacts");
    native.prepare(ds.x.view());
    xla.prepare(ds.x.view());

    let p_native = native.scores(ds.x.view(), &w);
    let p_xla = xla.scores(ds.x.view(), &w);
    assert_eq!(p_native.len(), p_xla.len());
    for (i, (a, b)) in p_native.iter().zip(&p_xla).enumerate() {
        assert!(
            (a - b).abs() < 1e-3 * (1.0 + a.abs()),
            "score {i}: native {a} vs xla {b}"
        );
    }

    let g_native = native.grad(ds.x.view(), &coeffs);
    let g_xla = xla.grad(ds.x.view(), &coeffs);
    assert_eq!(g_native.len(), g_xla.len());
    for (i, (a, b)) in g_native.iter().zip(&g_xla).enumerate() {
        // f32 accumulation over 700 rows: tolerance scaled accordingly.
        assert!(
            (a - b).abs() < 5e-3 * (1.0 + a.abs()),
            "grad {i}: native {a} vs xla {b}"
        );
    }
}

#[test]
fn xla_backend_pads_feature_dim() {
    let Some(dir) = artifacts_dir() else { return };
    // 10-feature dense data → pads to the n=64 artifact bucket.
    let ds = synthetic::queries(5, 30, 10, 6); // 150 rows, 10 features
    let mut rng = Rng::new(23);
    let w: Vec<f64> = (0..ds.dim()).map(|_| rng.normal()).collect();
    let mut native = NativeBackend::new();
    let mut xla = XlaBackend::load(&dir).expect("load artifacts");
    native.prepare(ds.x.view());
    xla.prepare(ds.x.view());
    let p1 = native.scores(ds.x.view(), &w);
    let p2 = xla.scores(ds.x.view(), &w);
    for (a, b) in p1.iter().zip(&p2) {
        assert!((a - b).abs() < 1e-3 * (1.0 + a.abs()));
    }
    let c: Vec<f64> = (0..ds.len()).map(|_| rng.normal()).collect();
    let g1 = native.grad(ds.x.view(), &c);
    let g2 = xla.grad(ds.x.view(), &c);
    assert_eq!(g1.len(), 10);
    assert_eq!(g2.len(), 10);
    for (a, b) in g1.iter().zip(&g2) {
        assert!((a - b).abs() < 5e-3 * (1.0 + a.abs()));
    }
}

#[test]
fn paircount_artifact_matches_rust_oracles() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = XlaRuntime::open(&dir).expect("open runtime");
    let entry = rt
        .manifest()
        .best_for("paircount", 0)
        .expect("paircount artifact")
        .clone();
    let tile = entry.m;

    let mut rng = Rng::new(31);
    let m = tile - 37; // force padding
    let p: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
    let y: Vec<f64> = (0..m).map(|_| rng.below(11) as f64).collect();

    // Rust oracles (tree and pair agree; use pair here).
    let mut oracle = PairOracle::new();
    let (c_rs, d_rs) = oracle.compute_counts(&p, &y);
    let (c_rs, d_rs) = (c_rs.to_vec(), d_rs.to_vec());

    // XLA kernel on the padded tile.
    let mut p32 = vec![0.0f32; tile];
    let mut y32 = vec![0.0f32; tile];
    let mut v32 = vec![0.0f32; tile];
    for i in 0..m {
        p32[i] = p[i] as f32;
        y32[i] = y[i] as f32;
        v32[i] = 1.0;
    }
    let (c_xla, d_xla) = rt
        .run2(&entry, &[literal_1d(&p32), literal_1d(&y32), literal_1d(&v32)])
        .expect("paircount execution");
    for i in 0..m {
        assert_eq!(c_xla[i] as u64, c_rs[i], "c[{i}]");
        assert_eq!(d_xla[i] as u64, d_rs[i], "d[{i}]");
    }
    for i in m..tile {
        assert_eq!(c_xla[i], 0.0, "padding row {i} leaked into c");
        assert_eq!(d_xla[i], 0.0, "padding row {i} leaked into d");
    }

    // Also cross-check Lemma 1 through the tree oracle's loss.
    let n_pairs = count_comparable_pairs(&y) as f64;
    let mut tree = TreeOracle::new();
    let out = tree.eval(&p, &y, n_pairs);
    let mut loss_from_xla = 0.0;
    for i in 0..m {
        loss_from_xla += (c_xla[i] as f64 - d_xla[i] as f64) * p[i] + c_xla[i] as f64;
    }
    loss_from_xla /= n_pairs;
    assert!(
        (loss_from_xla - out.loss).abs() < 1e-9 * (1.0 + out.loss),
        "{loss_from_xla} vs {}",
        out.loss
    );
}

#[test]
fn end_to_end_training_with_xla_backend() {
    let Some(dir) = artifacts_dir() else { return };
    use ranksvm::coordinator::{evaluate, train, BackendKind, Method, TrainConfig};
    let ds = synthetic::cadata_like(900, 41);
    let (tr, te) = ds.split(200, 3);
    let cfg_native = TrainConfig { method: Method::Tree, lambda: 0.1, ..Default::default() };
    let cfg_xla = TrainConfig {
        method: Method::Tree,
        backend: BackendKind::Xla,
        lambda: 0.1,
        artifacts_dir: dir,
        ..Default::default()
    };
    let out_native = train(&tr, &cfg_native).expect("native train");
    let out_xla = train(&tr, &cfg_xla).expect("xla train");
    assert!(out_xla.converged);
    // f32 vs f64 arithmetic: same objective to ~1e-3, same test error.
    assert!(
        (out_native.objective - out_xla.objective).abs()
            < 5e-3 * (1.0 + out_native.objective.abs()),
        "objectives: native {} vs xla {}",
        out_native.objective,
        out_xla.objective
    );
    let e1 = evaluate(&out_native.model, &te);
    let e2 = evaluate(&out_xla.model, &te);
    assert!((e1 - e2).abs() < 0.02, "test errors: {e1} vs {e2}");
}
