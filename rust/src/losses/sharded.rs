//! Query-sharded parallel subgradient oracle.
//!
//! The loss of §2 decomposes over disjoint example subsets two ways, and
//! this engine exploits both on a persistent work-stealing
//! [`WorkerPool`] (shared with the parallel compute backend and the
//! parallel argsort — one pool per trainer, no per-call thread spawns)
//! while keeping per-task reusable tree buffers alive across BMRM
//! iterations. In both modes the engine submits **more tasks than
//! workers** — the fine decomposition the stealing scheduler needs to
//! balance skewed inputs — and every task writes a disjoint output slot,
//! with all floating-point reductions running serially afterwards in
//! task order, so *which* worker executes a task never touches a result
//! bit (the three bit-identity invariants this relies on are written
//! down in `docs/DETERMINISM.md`).
//!
//! **Query-grouped data** (the document-retrieval setting): the risk is
//! an average of per-query losses, so query groups are packed by a
//! [`WorkPlan`] into bounded-weight contiguous **group runs** — tiny
//! groups coalesce, a giant group (the norm under Zipf-like group-size
//! skew, the regime WMRB (Liu, 2017) targets with batch decomposition)
//! becomes a run of its own, and no group is ever split. Each run is one
//! stealable task evaluating its groups with its own oracle — the
//! PR 1–3 plan of one coarse task per worker serialized a batch behind
//! the giant group's owner; with run-granularity tasks the other
//! workers steal the remaining runs while one worker chews the giant.
//! Per-group results are reduced serially *in group order*, so the
//! output is bit-identical to the serial [`super::QueryGrouped`] wrapper
//! for every run-plan and thread count. This mode is **generic over the
//! loss**: [`ShardedGroupOracle`] drives any [`GroupOracle`] from the
//! registry (TopPush is the first non-pairwise one) through exactly
//! this plan/reduce machinery, and [`ShardedTreeOracle`]'s grouped mode
//! is just that engine instantiated with per-task [`TreeOracle`]s.
//!
//! **One global ranking**: the frequencies `c_i`/`d_i` of eqs. (5)–(6)
//! are *integer* dominance counts over the margin window
//! `W(i) = {j : 1 + p_i − p_j > 0}` (a prefix of the score-sorted order).
//! The sorted order is split into [`adaptive_chunks`] contiguous chunks
//! (the per-trainer chunk plan, `clamp(4·threads, 4, 64)`), and each
//! chunk is one stealable task counting exactly its own queries: the
//! task owning sorted positions `[lo, hi)` computes `c_k` as
//!
//! - an incremental red-black-tree count over `[base, w_end(k))`, where
//!   `base` is the chunk boundary at or below the chunk's *first* window
//!   end (exactly Algorithm 3's sweep, restricted to the tail the chunk
//!   actually owns), plus
//! - one binary search per chunk fully below `base` against that chunk's
//!   pre-sorted label array (phase A, also one task per chunk).
//!
//! `d_i` is the mirror image over suffix windows. Because every per-`i`
//! count is an exact integer decomposed by chunk, the assembled
//! `(loss, coeffs)` is **bit-identical to the single-threaded
//! [`TreeOracle`] for any chunk plan and any thread count** — no
//! floating-point reduction enters until [`super::assemble_from_counts`],
//! which runs serially on the full count vectors. The window extents are
//! monotone, so the per-chunk tree sweeps telescope to `O(m)` insertions
//! plus at most one chunk length each — `O(m)` in total — which is what
//! makes the sharded oracle faster in practice on multi-core hosts (see
//! `benches/fig1_iteration_cost.rs` and `benches/skew_balance.rs`).
//!
//! Degenerate score distributions (e.g. all predictions within one
//! margin of each other, as at `w = 0`) make every window span the whole
//! array; chunk-granularity ownership then sends *zero* work through the
//! trees — every count is a round of per-chunk binary searches, which is
//! embarrassingly parallel. (The pre-PR-2 window-end ownership collapsed
//! this case onto one shard; see ROADMAP history.)

use super::{assemble_from_counts, GroupIndex, GroupOracle, OracleOutput, RankingOracle};
use crate::linalg::ops::{adaptive_chunks, par_argsort_into, SortScratch};
use crate::losses::tree::TreeOracle;
use crate::rbtree::OsTree;
use crate::runtime::cache;
use crate::runtime::plan::WorkPlan;
use crate::runtime::pool::{Task, WorkerPool};
use std::sync::Arc;

/// How examples are dealt to tasks.
enum Plan {
    /// One global ranking: contiguous chunks of the score-sorted order.
    Global,
    /// Disjoint query groups: delegated to the generic per-group engine
    /// with a per-task [`TreeOracle`] — the tree loss is just the first
    /// registry loss on that engine.
    Grouped(ShardedGroupOracle),
}

/// Per-task worker state for the global chunked counting mode, reused
/// across oracle calls (and hence across BMRM cutting-plane iterations —
/// the trees and buffers are allocated once and only grow).
struct TaskState {
    /// Incremental counter for the partial-chunk sweep.
    tree: OsTree,
    /// Counts for this task's owned queries, in sweep order.
    c_out: Vec<u64>,
    d_out: Vec<u64>,
}

impl TaskState {
    fn new() -> Self {
        TaskState { tree: OsTree::new(), c_out: Vec::new(), d_out: Vec::new() }
    }
}

/// Per-task state of the generic grouped engine: one boxed
/// [`GroupOracle`] plus gather/output buffers, all reused across calls.
struct GroupTaskState {
    oracle: Box<dyn GroupOracle>,
    p_buf: Vec<f64>,
    y_buf: Vec<f64>,
    /// Concatenated per-group coefficient outputs plus
    /// `(group, offset, len, loss)` records for effective groups.
    coeff_buf: Vec<f64>,
    meta: Vec<(usize, usize, usize, f64)>,
}

impl GroupTaskState {
    fn new(factory: fn() -> Box<dyn GroupOracle>) -> Self {
        GroupTaskState {
            oracle: factory(),
            p_buf: Vec::new(),
            y_buf: Vec::new(),
            coeff_buf: Vec::new(),
            meta: Vec::new(),
        }
    }
}

/// The generic per-group parallel engine: evaluates **any**
/// [`GroupOracle`] per query group on the work-stealing pool, with the
/// exact reduction contract the tree loss has always used — group runs
/// packed by a [`WorkPlan`] (no group split), every run one stealable
/// task with its own oracle instance, and a serial *group-order* float
/// reduction dividing by the effective-group count. Which worker runs
/// which task never touches a result bit (docs/DETERMINISM.md); what a
/// new loss must guarantee per group is written down in docs/LOSSES.md.
///
/// Without a [`GroupIndex`] the whole dataset is one group, evaluated
/// inline by the single per-engine oracle — there is no decomposition a
/// scheduler could exploit without a per-loss splitting rule, and an
/// inline call is trivially thread-invariant.
pub struct ShardedGroupOracle {
    pool: Arc<WorkerPool>,
    /// `None`: single implicit group. `Some`: the flat group partition
    /// (shared convention with [`super::QueryGrouped`] and the pallas
    /// store) plus the `[lo, hi)` group ranges of the run plan.
    grouping: Option<(Arc<GroupIndex>, Vec<(usize, usize)>)>,
    states: Vec<GroupTaskState>,
    name: &'static str,
}

impl ShardedGroupOracle {
    /// Build on a persistent pool. `factory` creates one oracle per
    /// task (each task owns private mutable state); `name` is the
    /// engine's [`RankingOracle::name`].
    pub fn new(
        pool: Arc<WorkerPool>,
        index: Option<Arc<GroupIndex>>,
        factory: fn() -> Box<dyn GroupOracle>,
        name: &'static str,
    ) -> Self {
        Self::with_run_target(pool, index, factory, name, None)
    }

    /// [`Self::new`] with an explicit [`WorkPlan`] run-target override
    /// (the same balance-vs-overhead knob as
    /// [`ShardedTreeOracle::with_run_target`]; cannot change a result
    /// bit).
    pub fn with_run_target(
        pool: Arc<WorkerPool>,
        index: Option<Arc<GroupIndex>>,
        factory: fn() -> Box<dyn GroupOracle>,
        name: &'static str,
        target_tasks: Option<usize>,
    ) -> Self {
        let n_workers = pool.n_threads().max(1);
        // Default plan: the adaptive count, raised cache-aware when the
        // index says the corpus is large enough that a run's ~16-byte-
        // per-example working set would overflow the chunk target
        // (small corpora keep their historical plans — the sizing only
        // ever adds runs above the adaptive floor).
        let default_tasks = if n_workers == 1 {
            1
        } else {
            match &index {
                Some(ix) => cache::sized_chunks(n_workers, ix.n_examples() * 16),
                None => adaptive_chunks(n_workers),
            }
        };
        let n_tasks = target_tasks.unwrap_or(default_tasks).max(1);
        let (grouping, n_states) = match index {
            None => (None, 1),
            Some(index) => {
                let runs = WorkPlan::pack(index.n_groups(), n_tasks, |g| index.group(g).len())
                    .runs()
                    .to_vec();
                let n_states = runs.len();
                (Some((index, runs)), n_states)
            }
        };
        ShardedGroupOracle {
            pool,
            grouping,
            states: (0..n_states).map(|_| GroupTaskState::new(factory)).collect(),
            name,
        }
    }

    /// Query-group count (None for the single implicit group).
    pub fn n_groups(&self) -> Option<usize> {
        self.grouping.as_ref().map(|(index, _)| index.n_groups())
    }

    /// Per-task `[lo, hi)` group-index ranges (None for the single
    /// implicit group). Contiguous and non-overlapping: a query group
    /// is never split across tasks.
    pub fn group_ranges(&self) -> Option<&[(usize, usize)]> {
        self.grouping.as_ref().map(|(_, runs)| runs.as_slice())
    }

    /// Total comparable pairs across groups (grouped reporting).
    pub fn total_pairs(&self) -> Option<f64> {
        self.grouping.as_ref().map(|(index, _)| index.total_pairs())
    }

    fn eval_grouped(&mut self, p: &[f64], y: &[f64]) -> OracleOutput {
        let m = p.len();
        assert_eq!(m, y.len());
        let (index, runs) = self.grouping.as_ref().expect("grouped eval requires an index");
        let states = &mut self.states;
        debug_assert_eq!(states.len(), runs.len());

        let gi: &GroupIndex = index;
        if self.pool.n_threads() == 1 || runs.len() <= 1 {
            for (state, &range) in states.iter_mut().zip(runs.iter()) {
                group_run_worker(state, range, gi, p, y);
            }
        } else {
            // One stealable task per group run: a worker stuck on a
            // giant group's run loses its remaining runs to the idle
            // workers instead of serializing the batch.
            let mut tasks: Vec<Task> = Vec::with_capacity(runs.len());
            for (state, &range) in states.iter_mut().zip(runs.iter()) {
                tasks.push(Box::new(move || group_run_worker(state, range, gi, p, y)));
            }
            self.pool.run(tasks);
        }

        // The effective-group count is the total number of per-group
        // records — an exact integer decomposed over disjoint runs, so
        // it cannot depend on the run plan or the scheduling. (For the
        // tree loss this equals `GroupIndex::n_effective_groups()`:
        // effectiveness is pairs > 0.)
        let r_eff = self.states.iter().map(|s| s.meta.len()).sum::<usize>().max(1) as f64;

        // Reduce in run order. Runs hold contiguous ascending group
        // ranges, so iterating runs then their records reproduces the
        // serial QueryGrouped accumulation order bit-for-bit — for any
        // run plan and regardless of which worker ran which task.
        let mut loss = 0.0;
        let mut coeffs = vec![0.0; m];
        for state in self.states.iter() {
            for &(g, off, len, group_loss) in &state.meta {
                loss += group_loss / r_eff;
                let idx = index.group(g);
                debug_assert_eq!(len, idx.len());
                for (k, &i) in idx.iter().enumerate() {
                    coeffs[i] = state.coeff_buf[off + k] / r_eff;
                }
            }
        }
        OracleOutput { loss, coeffs }
    }
}

impl RankingOracle for ShardedGroupOracle {
    /// Grouped data: per-group evaluation on the pool. Ungrouped data:
    /// one inline whole-dataset group (`n_pairs`, rounded to an exact
    /// integer pair count, feeds the oracle's effectiveness test and any
    /// pair-normalized arithmetic).
    fn eval(&mut self, p: &[f64], y: &[f64], n_pairs: f64) -> OracleOutput {
        if self.grouping.is_some() {
            return self.eval_grouped(p, y);
        }
        let state = &mut self.states[0];
        let pairs = if n_pairs > 0.0 { n_pairs as u64 } else { 0 };
        if p.is_empty() || !state.oracle.is_effective(y, pairs) {
            return OracleOutput { loss: 0.0, coeffs: vec![0.0; p.len()] };
        }
        state.oracle.eval_group(p, y, pairs)
    }

    fn name(&self) -> &'static str {
        self.name
    }
}

/// Grouped-engine worker: evaluate one group run with the task's own
/// oracle, recording per-group losses and coefficients for the
/// effective groups.
fn group_run_worker(
    state: &mut GroupTaskState,
    range: (usize, usize),
    index: &GroupIndex,
    p: &[f64],
    y: &[f64],
) {
    state.meta.clear();
    state.coeff_buf.clear();
    for g in range.0..range.1 {
        let pairs = index.group_pairs(g);
        let idx = index.group(g);
        state.p_buf.clear();
        state.p_buf.extend(idx.iter().map(|&i| p[i]));
        state.y_buf.clear();
        state.y_buf.extend(idx.iter().map(|&i| y[i]));
        if !state.oracle.is_effective(&state.y_buf, pairs) {
            continue;
        }
        let out = state.oracle.eval_group(&state.p_buf, &state.y_buf, pairs);
        let off = state.coeff_buf.len();
        state.coeff_buf.extend_from_slice(&out.coeffs);
        state.meta.push((g, off, idx.len(), out.loss));
    }
}

/// Shared read-only view handed to the global-mode workers. Task `t`
/// owns the sorted positions `[bounds[t], bounds[t+1])` — the chunk
/// plan doubles as the ownership plan, so tasks are fine enough to
/// steal and every boundary is shared with the binary-search substrate.
struct GlobalView<'a> {
    /// Chunk boundaries over sorted positions, length `n_tasks + 1`.
    bounds: &'a [usize],
    y_sorted: &'a [f64],
    /// Forward window ends `w(k)` (exclusive), nondecreasing in `k`.
    w_end: &'a [usize],
    /// Backward window starts `v(k)` (inclusive), nondecreasing in `k`.
    v_start: &'a [usize],
    /// Per-chunk sorted label arrays (phase A output; empty when a
    /// single task runs the pure serial sweep).
    labels: &'a [Vec<f64>],
}

/// The parallel sharded oracle engine. Construct once per training set
/// (like [`super::QueryGrouped`]); evaluate once per BMRM iteration. All
/// parallel phases run on one persistent work-stealing [`WorkerPool`],
/// shared with the trainer's compute backend when built via
/// [`Self::with_pool`].
pub struct ShardedTreeOracle {
    pool: Arc<WorkerPool>,
    /// Task granularity: the target task count per parallel phase —
    /// [`adaptive_chunks`] of the pool size by default, fixed at
    /// construction (once per trainer), overridable via
    /// [`Self::with_run_target`]. Global mode uses it as the chunk
    /// count; grouped mode as the [`WorkPlan`] run target. Counts are
    /// exact integers and reductions are task-order serial, so the
    /// granularity cannot change a result bit (pinned by
    /// `tests/scheduler.rs`).
    n_chunks: usize,
    /// True when `n_chunks` is the adaptive default rather than an
    /// explicit [`Self::with_run_target`] override: only then may the
    /// global mode raise the per-eval count cache-aware (an explicit
    /// target — e.g. the skew bench's coarse baseline — is authoritative).
    adaptive_plan: bool,
    plan: Plan,
    states: Vec<TaskState>,
    /// Per-chunk sorted labels, outside [`TaskState`] so phase-B workers
    /// can read every *other* chunk's array.
    sorted_labels: Vec<Vec<f64>>,
    // Per-eval scratch (global mode), reused across calls.
    pi: Vec<usize>,
    sort_scratch: SortScratch,
    p_sorted: Vec<f64>,
    y_sorted: Vec<f64>,
    w_end: Vec<usize>,
    v_start: Vec<usize>,
    c: Vec<u64>,
    d: Vec<u64>,
}

impl ShardedTreeOracle {
    /// Build with a private pool of `n_threads` workers. Prefer
    /// [`Self::with_pool`] inside the trainer so the oracle, the compute
    /// backend, and the parallel argsort share one set of threads.
    pub fn new(n_threads: usize, qid: Option<&[u64]>, y: &[f64]) -> Self {
        Self::with_pool(Arc::new(WorkerPool::new(n_threads)), qid, y)
    }

    /// Build on an existing persistent pool over a fixed training label
    /// vector; `qid` enables query-group task planning (must align with
    /// `y`).
    pub fn with_pool(pool: Arc<WorkerPool>, qid: Option<&[u64]>, y: &[f64]) -> Self {
        let index = qid.map(|q| Arc::new(GroupIndex::build(q, y)));
        Self::from_plan(pool, index, None)
    }

    /// Build on a persistent pool from a precomputed [`GroupIndex`]
    /// (e.g. the one a pallas store carries) — no per-run group scan,
    /// no copy.
    pub fn with_pool_index(pool: Arc<WorkerPool>, index: Arc<GroupIndex>) -> Self {
        Self::from_plan(pool, Some(index), None)
    }

    /// Build with an explicit task-granularity target: the global-mode
    /// chunk count and the grouped-mode [`WorkPlan`] run target.
    /// `target_tasks = n_threads` reproduces the coarse one-task-per-
    /// worker plan of PRs 1–3 (the skew benchmark's baseline); the
    /// default is [`adaptive_chunks`] of the pool size. Any target
    /// produces bit-identical results — the knob trades scheduling
    /// overhead against balance, nothing else.
    pub fn with_run_target(
        pool: Arc<WorkerPool>,
        qid: Option<&[u64]>,
        y: &[f64],
        target_tasks: usize,
    ) -> Self {
        let index = qid.map(|q| Arc::new(GroupIndex::build(q, y)));
        Self::from_plan(pool, index, Some(target_tasks))
    }

    fn from_plan(
        pool: Arc<WorkerPool>,
        index: Option<Arc<GroupIndex>>,
        target_tasks: Option<usize>,
    ) -> Self {
        let n_workers = pool.n_threads().max(1);
        let default_tasks = if n_workers == 1 { 1 } else { adaptive_chunks(n_workers) };
        let n_chunks = target_tasks.unwrap_or(default_tasks).max(1);
        let plan = match index {
            None => Plan::Global,
            Some(index) => Plan::Grouped(ShardedGroupOracle::with_run_target(
                Arc::clone(&pool),
                Some(index),
                || Box::new(TreeOracle::new()),
                "sharded-tree",
                target_tasks,
            )),
        };
        ShardedTreeOracle {
            pool,
            n_chunks,
            adaptive_plan: target_tasks.is_none(),
            plan,
            states: Vec::new(),
            sorted_labels: Vec::new(),
            pi: Vec::new(),
            sort_scratch: SortScratch::default(),
            p_sorted: Vec::new(),
            y_sorted: Vec::new(),
            w_end: Vec::new(),
            v_start: Vec::new(),
            c: Vec::new(),
            d: Vec::new(),
        }
    }

    /// The persistent pool this oracle evaluates on.
    pub fn pool(&self) -> &Arc<WorkerPool> {
        &self.pool
    }

    /// Query-group count (None for a single global ranking).
    pub fn n_groups(&self) -> Option<usize> {
        match &self.plan {
            Plan::Global => None,
            Plan::Grouped(engine) => engine.n_groups(),
        }
    }

    /// Per-task `[lo, hi)` group-index ranges (None in global mode).
    /// Ranges are contiguous and non-overlapping: a query group is never
    /// split across tasks.
    pub fn group_ranges(&self) -> Option<&[(usize, usize)]> {
        match &self.plan {
            Plan::Global => None,
            Plan::Grouped(engine) => engine.group_ranges(),
        }
    }

    /// Total comparable pairs across groups (grouped mode reporting).
    pub fn total_pairs(&self) -> Option<f64> {
        match &self.plan {
            Plan::Global => None,
            Plan::Grouped(engine) => engine.total_pairs(),
        }
    }

    fn eval_global(&mut self, p: &[f64], y: &[f64], n_pairs: f64) -> OracleOutput {
        let m = p.len();
        assert_eq!(m, y.len());
        if m == 0 {
            return OracleOutput { loss: 0.0, coeffs: Vec::new() };
        }

        // Shared setup — the same permutation TreeOracle's sort produces
        // (the parallel merge sort is bit-identical to the serial
        // argsort), gathered so the sweeps stream contiguous memory.
        par_argsort_into(p, &mut self.pi, &mut self.sort_scratch, &self.pool);
        self.p_sorted.clear();
        self.p_sorted.extend(self.pi.iter().map(|&k| p[k]));
        self.y_sorted.clear();
        self.y_sorted.extend(self.pi.iter().map(|&k| y[k]));

        // Window extents via two-pointer scans, with the *same* float
        // predicates as the serial sweeps so the counted sets match
        // exactly. Forward: W(k) = [0, w_end[k]) with
        // w_end[k] = first j failing 1 + p_k − p_j > 0 (nondecreasing,
        // and ≥ k+1 since j = k always passes). Backward:
        // V(k) = [v_start[k], m) with v_start[k] = first j passing
        // 1 + p_j − p_k > 0 (nondecreasing, and ≤ k).
        self.w_end.clear();
        self.w_end.reserve(m);
        {
            let ps = &self.p_sorted;
            let mut j = 0usize;
            for k in 0..m {
                let pk = ps[k];
                while j < m && 1.0 + pk - ps[j] > 0.0 {
                    j += 1;
                }
                self.w_end.push(j);
            }
        }
        self.v_start.clear();
        self.v_start.reserve(m);
        {
            let ps = &self.p_sorted;
            let mut j = 0usize;
            for k in 0..m {
                let pk = ps[k];
                // Advance past the js that fail the serial predicate
                // 1 + p_j − p_k > 0 (labels are NaN-free here, so the
                // `<=` form is its exact negation).
                while j < m && 1.0 + ps[j] - pk <= 0.0 {
                    j += 1;
                }
                self.v_start.push(j);
            }
        }

        // The task plan: contiguous chunks of the sorted order, each one
        // a stealable counting task owning exactly its own queries (and
        // doubling as a binary-search substrate unit for every other
        // task). Chunk-granularity ownership keeps the per-task tree
        // sweeps bounded even when every window spans the whole array
        // (the degenerate all-scores-within-one-margin case): window
        // ends that land on chunk boundaries contribute binary searches
        // only, so that case redistributes across all tasks instead of
        // collapsing onto the owner of the last chunk.
        let n_tasks = if self.pool.n_threads() == 1 {
            1
        } else {
            // Cache-aware refinement of the constructed plan: the sweep
            // streams ~16 bytes per sorted example, so a large m raises
            // the chunk count above the adaptive floor (never below —
            // small inputs keep their historical plans, and an explicit
            // run-target override is honoured verbatim).
            let mut t = self.n_chunks;
            if self.adaptive_plan {
                t = t.max(cache::sized_chunks(self.pool.n_threads(), m * 16));
            }
            t.clamp(1, m)
        };
        let bounds: Vec<usize> = (0..=n_tasks).map(|c| c * m / n_tasks).collect();
        if self.states.len() < n_tasks {
            self.states.resize_with(n_tasks, TaskState::new);
        }

        // Phase A: per-chunk sorted label arrays (cross-chunk counting
        // substrate). Skipped for a single task — the lone worker runs
        // the pure serial sweep over one whole-array chunk and never
        // consults them.
        self.sorted_labels.resize_with(n_tasks, Vec::new);
        if n_tasks > 1 {
            let y_sorted = &self.y_sorted;
            let mut tasks: Vec<Task> = Vec::with_capacity(n_tasks);
            for (s, lab) in self.sorted_labels.iter_mut().enumerate() {
                let (lo, hi) = (bounds[s], bounds[s + 1]);
                tasks.push(Box::new(move || {
                    lab.clear();
                    // NaN labels are incomparable (they contribute to no
                    // count, exactly like in the tree sweeps, which skip
                    // inserting them) — drop them here so the numeric
                    // partition_point predicates below stay consistent
                    // with the tree path for any task count.
                    lab.extend(y_sorted[lo..hi].iter().copied().filter(|x| !x.is_nan()));
                    lab.sort_unstable_by(|a, b| a.total_cmp(b));
                }));
            }
            self.pool.run(tasks);
        }

        // Phase B: one stealable task per chunk counts that chunk's
        // queries.
        let view = GlobalView {
            bounds: &bounds,
            y_sorted: &self.y_sorted,
            w_end: &self.w_end,
            v_start: &self.v_start,
            labels: &self.sorted_labels,
        };
        if n_tasks == 1 {
            global_worker(0, &view, &mut self.states[0]);
        } else {
            let view = &view;
            let mut tasks: Vec<Task> = Vec::with_capacity(n_tasks);
            for (s, state) in self.states.iter_mut().take(n_tasks).enumerate() {
                tasks.push(Box::new(move || global_worker(s, view, state)));
            }
            self.pool.run(tasks);
        }

        // Scatter the per-task counts back to original example order and
        // assemble — serial and order-fixed, so the float result cannot
        // depend on the task count or the scheduling.
        self.c.clear();
        self.c.resize(m, 0);
        self.d.clear();
        self.d.resize(m, 0);
        for t in 0..n_tasks {
            let st = &self.states[t];
            let (q_lo, q_hi) = (bounds[t], bounds[t + 1]);
            for (i, k) in (q_lo..q_hi).enumerate() {
                self.c[self.pi[k]] = st.c_out[i];
            }
            // d_out was pushed for descending k.
            for (i, k) in (q_lo..q_hi).rev().enumerate() {
                self.d[self.pi[k]] = st.d_out[i];
            }
        }
        assemble_from_counts(p, &self.c, &self.d, n_pairs)
    }
}

impl RankingOracle for ShardedTreeOracle {
    /// `n_pairs` normalizes the global mode; in grouped mode the
    /// per-group counts fixed at construction are authoritative (same
    /// contract as [`super::QueryGrouped`]).
    fn eval(&mut self, p: &[f64], y: &[f64], n_pairs: f64) -> OracleOutput {
        if let Plan::Grouped(engine) = &mut self.plan {
            return engine.eval(p, y, n_pairs);
        }
        self.eval_global(p, y, n_pairs)
    }

    fn name(&self) -> &'static str {
        "sharded-tree"
    }
}

/// Global-mode worker: exact `c`/`d` counts for chunk `s`'s query range
/// `[bounds[s], bounds[s+1])`. The tree sweep covers `[base, w_end(k))`
/// where `base` is the chunk boundary at or below the range's first
/// window end; chunks fully below `base` are counted with one binary
/// search each against their pre-sorted labels. Counts are exact
/// integers either way, so the split point cannot change a result bit.
fn global_worker(s: usize, v: &GlobalView, state: &mut TaskState) {
    let n_chunks = v.bounds.len() - 1;
    let (q_lo, q_hi) = (v.bounds[s], v.bounds[s + 1]);

    // NaN labels are incomparable: they are never inserted (a NaN key
    // would sit structure-dependently in the BST and make counts vary
    // with the chunk split) and a NaN query counts zero on both the tree
    // and the binary-search path — so counts stay exact and
    // plan-invariant even for unvalidated label vectors.

    // Forward sweep: c_k = |{j ∈ W(k) : y_j > y_k}|.
    state.c_out.clear();
    state.tree.clear();
    if q_lo < q_hi {
        // Largest chunk boundary ≤ w_end[q_lo] (w_end ≥ 1, so t0 ≥ 0).
        // A single task owns everything and sweeps from 0 — the pure
        // serial path, no label arrays needed.
        let t0 = if n_chunks == 1 {
            0
        } else {
            v.bounds.partition_point(|&b| b <= v.w_end[q_lo]) - 1
        };
        let mut j = v.bounds[t0];
        for k in q_lo..q_hi {
            while j < v.w_end[k] {
                let yj = v.y_sorted[j];
                if !yj.is_nan() {
                    state.tree.insert(yj);
                }
                j += 1;
            }
            let yk = v.y_sorted[k];
            let cnt = if yk.is_nan() {
                0
            } else {
                let mut cnt = state.tree.count_larger(yk);
                for lab in &v.labels[..t0] {
                    cnt += (lab.len() - lab.partition_point(|&x| x <= yk)) as u64;
                }
                cnt
            };
            state.c_out.push(cnt);
        }
    }

    // Backward sweep (descending k): d_k = |{j ∈ V(k) : y_j < y_k}|.
    state.d_out.clear();
    state.tree.clear();
    if q_lo < q_hi {
        // Smallest chunk boundary ≥ v_start[q_hi − 1].
        let t1 = if n_chunks == 1 {
            n_chunks
        } else {
            v.bounds.partition_point(|&b| b < v.v_start[q_hi - 1])
        };
        let mut j = v.bounds[t1];
        for k in (q_lo..q_hi).rev() {
            while j > v.v_start[k] {
                j -= 1;
                let yj = v.y_sorted[j];
                if !yj.is_nan() {
                    state.tree.insert(yj);
                }
            }
            let yk = v.y_sorted[k];
            let cnt = if yk.is_nan() {
                0
            } else {
                let mut cnt = state.tree.count_smaller(yk);
                for lab in &v.labels[t1..n_chunks] {
                    cnt += lab.partition_point(|&x| x < yk) as u64;
                }
                cnt
            };
            state.d_out.push(cnt);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::losses::{count_comparable_pairs, PairOracle, QueryGrouped};
    use crate::util::rng::Rng;

    fn random_case(rng: &mut Rng, trial: usize) -> (Vec<f64>, Vec<f64>) {
        let m = 1 + rng.below(250);
        let y: Vec<f64> = match trial % 4 {
            0 => (0..m).map(|_| rng.normal()).collect(), // r ≈ m
            1 => (0..m).map(|_| rng.below(5) as f64).collect(), // heavy ties
            2 => (0..m).map(|_| rng.below(2) as f64).collect(), // bipartite
            _ => vec![3.0; m],                           // fully tied
        };
        // Quantized scores land exactly on margins; mix in ties.
        let p: Vec<f64> = match trial % 3 {
            0 => (0..m).map(|_| rng.normal() * 2.0).collect(),
            1 => (0..m).map(|_| (rng.below(30) as f64) / 7.0 - 2.0).collect(),
            _ => (0..m).map(|_| rng.below(3) as f64).collect(),
        };
        (p, y)
    }

    #[test]
    fn global_mode_bit_identical_to_tree_oracle() {
        let mut rng = Rng::new(9001);
        for trial in 0..60 {
            let (p, y) = random_case(&mut rng, trial);
            let n = count_comparable_pairs(&y) as f64;
            let mut reference = TreeOracle::new();
            let expect = reference.eval(&p, &y, n);
            for threads in [1, 2, 3, 8, 33] {
                let mut sharded = ShardedTreeOracle::new(threads, None, &y);
                let got = sharded.eval(&p, &y, n);
                assert_eq!(got.coeffs, expect.coeffs, "trial {trial}, {threads} threads");
                assert_eq!(
                    got.loss.to_bits(),
                    expect.loss.to_bits(),
                    "trial {trial}, {threads} threads"
                );
            }
        }
    }

    #[test]
    fn global_mode_matches_pair_oracle_counts() {
        let mut rng = Rng::new(9002);
        for trial in 0..40 {
            let (p, y) = random_case(&mut rng, trial);
            let n = count_comparable_pairs(&y) as f64;
            let mut pair = PairOracle::new();
            let expect = pair.eval(&p, &y, n);
            let mut sharded = ShardedTreeOracle::new(4, None, &y);
            let got = sharded.eval(&p, &y, n);
            assert_eq!(got.coeffs, expect.coeffs, "trial {trial}");
            assert!((got.loss - expect.loss).abs() <= 1e-12 * (1.0 + expect.loss));
        }
    }

    #[test]
    fn grouped_mode_bit_identical_to_query_grouped() {
        let mut rng = Rng::new(9003);
        for trial in 0..40 {
            let m = 1 + rng.below(200);
            let n_queries = 1 + rng.below(12);
            let qid: Vec<u64> = (0..m).map(|_| rng.below(n_queries) as u64 * 17).collect();
            let y: Vec<f64> = (0..m).map(|_| rng.below(4) as f64).collect();
            let p: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
            let mut serial = QueryGrouped::new(TreeOracle::new(), &qid, &y);
            let expect = serial.eval(&p, &y, serial.total_pairs());
            for threads in [1, 2, 8, 40] {
                let mut sharded = ShardedTreeOracle::new(threads, Some(&qid), &y);
                let got = sharded.eval(&p, &y, 0.0);
                assert_eq!(got.coeffs, expect.coeffs, "trial {trial}, {threads} threads");
                assert_eq!(
                    got.loss.to_bits(),
                    expect.loss.to_bits(),
                    "trial {trial}, {threads} shards"
                );
            }
        }
    }

    #[test]
    fn run_plan_respects_query_boundaries() {
        let mut rng = Rng::new(9004);
        let m = 300;
        let qid: Vec<u64> = (0..m).map(|i| (i / 7) as u64).collect();
        let y: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
        for threads in [1, 3, 8] {
            let oracle = ShardedTreeOracle::new(threads, Some(&qid), &y);
            let runs = oracle.group_ranges().unwrap();
            let n_groups = oracle.n_groups().unwrap();
            // Contiguous, non-overlapping cover of all groups: groups
            // are assigned whole — no group index appears in two runs —
            // and a multi-worker pool gets at least one run per worker
            // to steal.
            let mut expect_lo = 0;
            for &(lo, hi) in runs {
                assert_eq!(lo, expect_lo);
                assert!(hi > lo);
                expect_lo = hi;
            }
            assert_eq!(expect_lo, n_groups);
            if threads == 1 {
                assert_eq!(runs.len(), 1, "single worker wants one run");
            } else {
                assert!(runs.len() >= threads, "{} runs for {threads} workers", runs.len());
            }
        }
    }

    #[test]
    fn giant_group_is_a_run_of_its_own() {
        // One group holding half the mass next to many singletons: the
        // plan must isolate it (so the scheduler can steal everything
        // else) without splitting it.
        let mut qid: Vec<u64> = vec![0; 500];
        qid.extend((1..=500).map(|g| g as u64));
        let y: Vec<f64> = (0..qid.len()).map(|i| (i % 3) as f64).collect();
        let oracle = ShardedTreeOracle::new(8, Some(&qid), &y);
        let runs = oracle.group_ranges().unwrap();
        assert_eq!(runs[0], (0, 1), "giant group must sit alone in the first run");
        assert!(runs.len() > 8, "fine-grained plan expected, got {} runs", runs.len());
        assert!(runs.len() <= 2 * adaptive_chunks(8) + 2, "run explosion: {}", runs.len());
    }

    #[test]
    fn degenerate_inputs() {
        let mut o = ShardedTreeOracle::new(4, None, &[]);
        let out = o.eval(&[], &[], 0.0);
        assert_eq!(out.loss, 0.0);
        assert!(out.coeffs.is_empty());

        // Fewer examples than tasks.
        let y = [1.0, 2.0];
        let mut o = ShardedTreeOracle::new(8, None, &y);
        let out = o.eval(&[0.0, 0.5], &y, 1.0);
        let mut reference = TreeOracle::new();
        let expect = reference.eval(&[0.0, 0.5], &y, 1.0);
        assert_eq!(out.coeffs, expect.coeffs);

        // All-tied predictions: every window spans everything — with
        // chunk-granularity ownership this runs entirely on per-chunk
        // binary searches, spread across every task.
        let y = [1.0, 2.0, 3.0, 4.0];
        let p = [0.0, 0.0, 0.0, 0.0];
        let n = count_comparable_pairs(&y) as f64;
        let mut o = ShardedTreeOracle::new(3, None, &y);
        let out = o.eval(&p, &y, n);
        assert!((out.loss - 1.0).abs() < 1e-12);
    }

    #[test]
    fn degenerate_window_case_spreads_counts_across_tasks() {
        // All scores within one margin: every w_end = m, every
        // v_start = 0. Each task must produce counts for exactly its
        // own chunk (no task ends up owning everything), and the counts
        // must match the serial oracle bit-for-bit.
        let mut rng = Rng::new(9005);
        let m = 257;
        let y: Vec<f64> = (0..m).map(|_| rng.below(6) as f64).collect();
        let p: Vec<f64> = (0..m).map(|_| rng.normal() * 1e-4).collect();
        let n = count_comparable_pairs(&y) as f64;
        let mut reference = TreeOracle::new();
        let expect = reference.eval(&p, &y, n);
        for threads in [2usize, 4, 8] {
            let mut sharded = ShardedTreeOracle::new(threads, None, &y);
            let got = sharded.eval(&p, &y, n);
            assert_eq!(got.coeffs, expect.coeffs, "{threads} threads");
            // Ownership is chunk-balanced by construction: every task
            // holds exactly its chunk's slice of the count outputs.
            let n_tasks = adaptive_chunks(threads).clamp(1, m);
            for (t, st) in sharded.states.iter().take(n_tasks).enumerate() {
                let expect_len = (t + 1) * m / n_tasks - t * m / n_tasks;
                assert_eq!(st.c_out.len(), expect_len, "task {t} fwd");
                assert_eq!(st.d_out.len(), expect_len, "task {t} bwd");
            }
        }
    }

    #[test]
    fn nan_labels_are_incomparable_and_plan_invariant() {
        // A NaN label must neither panic nor break bit-identity: it is
        // never inserted into a counting tree and counts zero as a
        // query, on the serial and every sharded path alike.
        let mut rng = Rng::new(9006);
        let m = 120;
        let mut y: Vec<f64> = (0..m).map(|_| rng.below(4) as f64).collect();
        y[7] = f64::NAN;
        y[64] = f64::NAN;
        let p: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
        let mut reference = TreeOracle::new();
        let expect = reference.eval(&p, &y, 100.0);
        assert!(expect.loss.is_finite());
        assert_eq!(expect.coeffs[7], 0.0);
        for threads in [1usize, 2, 8] {
            let mut sharded = ShardedTreeOracle::new(threads, None, &y);
            let got = sharded.eval(&p, &y, 100.0);
            assert_eq!(got.coeffs, expect.coeffs, "{threads} threads");
            assert_eq!(got.loss.to_bits(), expect.loss.to_bits(), "{threads} threads");
        }
    }

    #[test]
    fn buffers_reused_across_calls_and_sizes() {
        let mut o = ShardedTreeOracle::new(4, None, &[1.0, 2.0]);
        let a = o.eval(&[0.5, 0.0], &[1.0, 2.0], 1.0);
        assert!(a.loss > 0.0);
        let b = o.eval(&[0.0, 5.0], &[1.0, 2.0], 1.0);
        assert_eq!(b.loss, 0.0);
        // Growing and shrinking sizes across calls.
        let y: Vec<f64> = (0..100).map(|i| (i % 7) as f64).collect();
        let p: Vec<f64> = (0..100).map(|i| ((i * 13) % 29) as f64 * 0.1).collect();
        let n = count_comparable_pairs(&y) as f64;
        let big = o.eval(&p, &y, n);
        let mut reference = TreeOracle::new();
        let expect = reference.eval(&p, &y, n);
        assert_eq!(big.coeffs, expect.coeffs);
        let small = o.eval(&[0.1, 0.0, 2.0], &[1.0, 2.0, 3.0], 3.0);
        let expect_small = reference.eval(&[0.1, 0.0, 2.0], &[1.0, 2.0, 3.0], 3.0);
        assert_eq!(small.coeffs, expect_small.coeffs);
    }

    #[test]
    fn shared_pool_drives_multiple_oracles() {
        // One persistent pool reused by two oracles (the trainer's
        // arrangement: oracle + backend share threads).
        let pool = Arc::new(WorkerPool::new(4));
        let y: Vec<f64> = (0..150).map(|i| (i % 5) as f64).collect();
        let qid: Vec<u64> = (0..150).map(|i| (i / 10) as u64).collect();
        let n = count_comparable_pairs(&y) as f64;
        let mut global = ShardedTreeOracle::with_pool(Arc::clone(&pool), None, &y);
        let mut grouped = ShardedTreeOracle::with_pool(Arc::clone(&pool), Some(&qid), &y);
        let mut reference = TreeOracle::new();
        let mut serial = QueryGrouped::new(TreeOracle::new(), &qid, &y);
        for step in 0..5 {
            let p: Vec<f64> = (0..150).map(|i| ((i * 31 + step * 7) % 23) as f64 * 0.1).collect();
            let expect = reference.eval(&p, &y, n);
            let got = global.eval(&p, &y, n);
            assert_eq!(got.coeffs, expect.coeffs, "step {step}");
            let expect_g = serial.eval(&p, &y, serial.total_pairs());
            let got_g = grouped.eval(&p, &y, 0.0);
            assert_eq!(got_g.coeffs, expect_g.coeffs, "step {step}");
        }
    }

    #[test]
    fn run_target_cannot_change_a_result_bit() {
        // The task-granularity knob trades balance against scheduling
        // overhead only: coarse (one task per worker, the PR 1–3 plan),
        // default, and absurdly fine plans all match the serial oracle
        // bit-for-bit, in both modes.
        let mut rng = Rng::new(9008);
        let m = 240;
        let qid: Vec<u64> = (0..m).map(|_| rng.below(20) as u64).collect();
        let y: Vec<f64> = (0..m).map(|_| rng.below(5) as f64).collect();
        let p: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
        let n = count_comparable_pairs(&y) as f64;
        let mut reference = TreeOracle::new();
        let expect_global = reference.eval(&p, &y, n);
        let mut serial = QueryGrouped::new(TreeOracle::new(), &qid, &y);
        let expect_grouped = serial.eval(&p, &y, serial.total_pairs());
        let pool = Arc::new(WorkerPool::new(4));
        for target in [1usize, 4, 7, 64, 500] {
            let mut global =
                ShardedTreeOracle::with_run_target(Arc::clone(&pool), None, &y, target);
            let got = global.eval(&p, &y, n);
            assert_eq!(got.coeffs, expect_global.coeffs, "global, target {target}");
            assert_eq!(got.loss.to_bits(), expect_global.loss.to_bits());
            let mut grouped =
                ShardedTreeOracle::with_run_target(Arc::clone(&pool), Some(&qid), &y, target);
            let got = grouped.eval(&p, &y, 0.0);
            assert_eq!(got.coeffs, expect_grouped.coeffs, "grouped, target {target}");
            assert_eq!(got.loss.to_bits(), expect_grouped.loss.to_bits());
        }
    }

    #[test]
    fn generic_engine_with_tree_factory_is_the_grouped_path() {
        // The tree loss on the generic engine is bit-identical to the
        // (delegating) ShardedTreeOracle and the serial wrapper.
        let mut rng = Rng::new(9010);
        let m = 220;
        let qid: Vec<u64> = (0..m).map(|_| rng.below(15) as u64).collect();
        let y: Vec<f64> = (0..m).map(|_| rng.below(4) as f64).collect();
        let p: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
        let mut serial = QueryGrouped::new(TreeOracle::new(), &qid, &y);
        let expect = serial.eval(&p, &y, serial.total_pairs());
        for threads in [1usize, 2, 8] {
            let pool = Arc::new(WorkerPool::new(threads));
            let index = Arc::new(GroupIndex::build(&qid, &y));
            let mut engine = ShardedGroupOracle::new(
                pool,
                Some(index),
                || Box::new(TreeOracle::new()),
                "sharded-tree",
            );
            let got = engine.eval(&p, &y, 0.0);
            assert_eq!(got.coeffs, expect.coeffs, "{threads} threads");
            assert_eq!(got.loss.to_bits(), expect.loss.to_bits(), "{threads} threads");
        }
    }

    #[test]
    fn toppush_grouped_bit_identical_to_serial_for_any_plan() {
        // Binary labels make QueryGrouped's pairs>0 effectiveness
        // coincide with TopPush's both-classes-present rule, so the
        // serial wrapper is an exact reference for the generic engine.
        use crate::losses::TopPushOracle;
        let mut rng = Rng::new(9011);
        for trial in 0..20 {
            let m = 1 + rng.below(240);
            let n_queries = 1 + rng.below(14);
            let qid: Vec<u64> = (0..m).map(|_| rng.below(n_queries) as u64 * 5).collect();
            let y: Vec<f64> = (0..m).map(|_| rng.below(2) as f64).collect();
            let p: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
            let mut serial = QueryGrouped::new(TopPushOracle::new(), &qid, &y);
            let expect = serial.eval(&p, &y, 0.0);
            for threads in [1usize, 2, 8, 40] {
                let pool = Arc::new(WorkerPool::new(threads));
                for target in [None, Some(1), Some(7), Some(500)] {
                    let index = Arc::new(GroupIndex::build(&qid, &y));
                    let mut engine = ShardedGroupOracle::with_run_target(
                        Arc::clone(&pool),
                        Some(index),
                        || Box::new(TopPushOracle::new()),
                        "sharded-toppush",
                        target,
                    );
                    let got = engine.eval(&p, &y, 0.0);
                    assert_eq!(
                        got.coeffs, expect.coeffs,
                        "trial {trial}, {threads} threads, target {target:?}"
                    );
                    assert_eq!(
                        got.loss.to_bits(),
                        expect.loss.to_bits(),
                        "trial {trial}, {threads} threads, target {target:?}"
                    );
                }
            }
        }
    }

    #[test]
    fn generic_engine_single_group_mode_runs_inline() {
        use crate::losses::TopPushOracle;
        let y = [1.0, 0.0, 1.0, 0.0];
        let p = [2.0, 0.5, 1.0, 0.0];
        let pool = Arc::new(WorkerPool::new(4));
        let factory: fn() -> Box<dyn GroupOracle> = || Box::new(TopPushOracle::new());
        let mut engine = ShardedGroupOracle::new(pool, None, factory, "sharded-toppush");
        let mut reference = TopPushOracle::new();
        let expect = reference.eval(&p, &y, 4.0);
        let got = engine.eval(&p, &y, 4.0);
        assert_eq!(got.coeffs, expect.coeffs);
        assert_eq!(got.loss.to_bits(), expect.loss.to_bits());
        assert!(engine.n_groups().is_none());
        assert_eq!(engine.name(), "sharded-toppush");
        // Single-class input is zero-safe through the engine too.
        let out = engine.eval(&[1.0, 2.0], &[3.0, 4.0], 1.0);
        assert_eq!(out.loss, 0.0);
        assert_eq!(out.coeffs, vec![0.0, 0.0]);
    }

    #[test]
    fn precomputed_index_matches_scan_construction() {
        let mut rng = Rng::new(9007);
        let m = 180;
        let qid: Vec<u64> = (0..m).map(|_| rng.below(9) as u64 * 3).collect();
        let y: Vec<f64> = (0..m).map(|_| rng.below(4) as f64).collect();
        let p: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
        let pool = Arc::new(WorkerPool::new(4));
        let mut scanned = ShardedTreeOracle::with_pool(Arc::clone(&pool), Some(&qid), &y);
        let index = Arc::new(GroupIndex::build(&qid, &y));
        let mut indexed = ShardedTreeOracle::with_pool_index(Arc::clone(&pool), index);
        let a = scanned.eval(&p, &y, 0.0);
        let b = indexed.eval(&p, &y, 0.0);
        assert_eq!(a.coeffs, b.coeffs);
        assert_eq!(a.loss.to_bits(), b.loss.to_bits());
    }
}
