//! The telemetry battery: inertness, histogram math, trace schema,
//! and serve exposition formats.
//!
//! The contracts pinned here (ISSUE 8):
//!
//! - **Inertness** — training with `--trace` produces a bit-identical
//!   model, objective, and iteration count to training without it, at
//!   1/2/8 threads, on both a global-order and a grouped fixture. The
//!   observability layer may watch the solver; it may never steer it.
//! - **Histogram math** — `bucket_index` (a `partition_point` over
//!   inclusive upper bounds) agrees with a brute-force linear scan at
//!   every bound, at the bounds' neighbours, and at the extremes, for
//!   the real registered bucket layouts and a small synthetic one.
//! - **Trace schema** — a traced run emits exactly one `start` line,
//!   one `iter` line per BMRM iteration, and one `end` line, each with
//!   exactly the normative key sets (`START_FIELDS` / `ITER_FIELDS` /
//!   `END_FIELDS`, mirrored by docs/OBSERVABILITY.md), and
//!   `ranksvm report` renders the file.
//! - **Serve exposition** — `metrics` answers Prometheus-style text
//!   covering every `REGISTRY` entry and framed by a final `# EOF`
//!   line; `info` carries the extended `errors=`/`uptime_s=` keys.
//!   Formats are pinned, not values: the registry is process-global
//!   and tests in this binary run concurrently.

use ranksvm::coordinator::{train, Method, TrainConfig};
use ranksvm::data::{synthetic, LoadedDataset};
use ranksvm::obs::metrics::{Histogram, BATCH_SIZE_BUCKETS, LATENCY_BUCKETS_US, REGISTRY};
use ranksvm::obs::trace::{END_FIELDS, ITER_FIELDS, START_FIELDS, TRACE_SCHEMA_VERSION};
use ranksvm::serve::{handle_connection, Engine, ScoringModel};
use ranksvm::util::json::Json;
use std::io::Cursor;
use std::path::PathBuf;

fn tmp(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("ranksvm_obs_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(name)
}

fn cfg(threads: usize, trace: Option<PathBuf>) -> TrainConfig {
    TrainConfig {
        method: Method::Tree,
        lambda: 0.1,
        epsilon: 1e-3,
        n_threads: threads,
        trace_path: trace.map(|p| p.display().to_string()),
        ..Default::default()
    }
}

/// Key list of a JSON object, in emission order.
fn keys(j: &Json) -> Vec<&str> {
    match j {
        Json::Obj(kvs) => kvs.iter().map(|(k, _)| k.as_str()).collect(),
        other => panic!("expected object, got {other}"),
    }
}

// ------------------------------------------------------------- inertness

#[test]
fn tracing_is_bitwise_inert_at_any_thread_count() {
    let fixtures = [
        ("global", synthetic::cadata_like(300, 88)),
        ("grouped", synthetic::queries(12, 18, 5, 89)),
    ];
    for (tag, ds) in &fixtures {
        for threads in [1usize, 2, 8] {
            let base = train(ds, &cfg(threads, None)).unwrap();
            let path = tmp(&format!("inert_{tag}_{threads}.jsonl"));
            let traced = train(ds, &cfg(threads, Some(path.clone()))).unwrap();
            assert_eq!(traced.model.w, base.model.w, "{tag}: {threads} threads");
            assert_eq!(
                traced.objective.to_bits(),
                base.objective.to_bits(),
                "{tag}: {threads} threads"
            );
            assert_eq!(traced.iterations, base.iterations, "{tag}: {threads} threads");
            // The trace actually got written — inert, not absent.
            let text = std::fs::read_to_string(&path).unwrap();
            assert!(text.lines().count() >= 3, "{tag}: trace too short");
            std::fs::remove_file(&path).ok();
        }
    }
}

// -------------------------------------------------------- histogram math

/// Reference implementation: with inclusive upper bounds, value `v`
/// lands in the first bucket whose bound is `>= v` — equivalently, past
/// every bound `< v`.
fn brute_force_index(bounds: &[u64], v: u64) -> usize {
    bounds.iter().filter(|&&b| b < v).count()
}

#[test]
fn histogram_bucket_index_matches_brute_force() {
    static SMALL_BOUNDS: &[u64] = &[10, 20, 40, 100];
    static SMALL: Histogram = Histogram::new(SMALL_BOUNDS);
    let layouts: [(&Histogram, &[u64]); 3] = [
        (&SMALL, SMALL_BOUNDS),
        (&ranksvm::obs::metrics::SERVE_REQUEST_LATENCY_US, LATENCY_BUCKETS_US),
        (&ranksvm::obs::metrics::SERVE_BATCH_SIZE, BATCH_SIZE_BUCKETS),
    ];
    for (h, bounds) in layouts {
        assert_eq!(h.bounds(), bounds);
        assert!(bounds.windows(2).all(|w| w[0] < w[1]), "bounds must ascend");
        let mut probes = vec![0u64, u64::MAX];
        for &b in bounds {
            probes.extend([b.saturating_sub(1), b, b + 1]);
        }
        for v in probes {
            assert_eq!(
                h.bucket_index(v),
                brute_force_index(bounds, v),
                "layout {bounds:?}, value {v}"
            );
        }
    }
}

#[test]
fn histogram_counts_and_sum_track_observations() {
    // A dedicated static so concurrent tests can't touch these counts.
    static BOUNDS: &[u64] = &[10, 20, 40, 100];
    static H: Histogram = Histogram::new(BOUNDS);
    let values = [0u64, 1, 9, 10, 11, 20, 39, 40, 41, 100, 101, 5_000];
    let mut expect = vec![0u64; BOUNDS.len() + 1];
    for &v in &values {
        H.observe(v);
        expect[brute_force_index(BOUNDS, v)] += 1;
    }
    assert_eq!(H.bucket_counts(), expect);
    assert_eq!(H.count(), values.len() as u64);
    assert_eq!(H.sum(), values.iter().sum::<u64>());
}

// ----------------------------------------------------------- trace schema

#[test]
fn trace_jsonl_matches_the_normative_schema() {
    let ds = synthetic::queries(12, 18, 5, 89);
    let path = tmp("schema.jsonl");
    let c = TrainConfig { line_search: true, ..cfg(2, Some(path.clone())) };
    let out = train(&ds, &c).unwrap();
    let text = std::fs::read_to_string(&path).unwrap();
    let lines: Vec<Json> = text.lines().map(|l| Json::parse(l).unwrap()).collect();
    assert!(lines.len() >= 3, "start + iters + end");

    let start = &lines[0];
    assert_eq!(start.get("event").and_then(Json::as_str), Some("start"));
    assert_eq!(keys(start), START_FIELDS, "start keys");
    assert_eq!(start.get("schema_version").and_then(Json::as_i64), Some(TRACE_SCHEMA_VERSION));
    assert_eq!(start.get("method").and_then(Json::as_str), Some("tree"));
    assert_eq!(start.get("m").and_then(Json::as_i64), Some(ds.len() as i64));
    assert_eq!(start.get("threads").and_then(Json::as_i64), Some(2));

    let end = lines.last().unwrap();
    assert_eq!(end.get("event").and_then(Json::as_str), Some("end"));
    assert_eq!(keys(end), END_FIELDS, "end keys");
    assert_eq!(end.get("iterations").and_then(Json::as_i64), Some(out.iterations as i64));
    assert_eq!(end.get("converged").and_then(Json::as_bool), Some(out.converged));

    let iters = &lines[1..lines.len() - 1];
    assert_eq!(iters.len(), out.iterations, "one iter event per BMRM iteration");
    let mut probed = 0i64;
    for (i, ev) in iters.iter().enumerate() {
        assert_eq!(ev.get("event").and_then(Json::as_str), Some("iter"));
        assert_eq!(keys(ev), ITER_FIELDS, "iter keys at index {i}");
        assert_eq!(ev.get("iter").and_then(Json::as_i64), Some(i as i64 + 1));
        let gap = ev.get("gap").and_then(Json::as_f64).unwrap();
        assert!(gap.is_finite() && gap >= 0.0, "gap {gap}");
        probed += ev.get("ls_steps").and_then(Json::as_i64).unwrap();
    }
    // Line search was on: later iterations probe cached best points.
    assert!(probed > 0, "line search never probed");

    // The report renderer accepts exactly what the trainer emitted.
    let report = ranksvm::obs::trace::render_report(&text).unwrap();
    assert!(report.ends_with('\n'));
    assert!(report.contains("method=tree"), "{report}");
    assert!(report.contains(&format!("done: {} iterations", out.iterations)), "{report}");
    std::fs::remove_file(&path).ok();
}

// ------------------------------------------------------ serve exposition

#[test]
fn serve_metrics_and_info_formats_are_pinned() {
    let ds = synthetic::queries(6, 5, 8, 7);
    let w: Vec<f64> = (0..8).map(|j| ((j as f64) + 0.5).sin() * 1.75).collect();
    let path = tmp("metrics.rsm");
    ScoringModel::new(w, None).unwrap().save(&path).unwrap();
    let eng = Engine::new(&path, Some(LoadedDataset::Owned(ds)), 2, true).unwrap();

    let mut raw = Vec::new();
    handle_connection(
        &eng,
        Cursor::new(b"score 0:1\ninfo\nmetrics\nquit\n" as &[u8]),
        &mut raw,
    )
    .unwrap();
    let text = String::from_utf8(raw).unwrap();
    let lines: Vec<&str> = text.lines().collect();

    assert!(lines[0].starts_with("ok v=1 "), "{}", lines[0]);
    let info = lines[1];
    for key in [
        " dim=", " normalize=", " rows=", " groups=", " threads=", " batches=", " requests=",
        " swaps=", " errors=", " uptime_s=",
    ] {
        assert!(info.contains(key), "info line missing `{key}`: {info}");
    }

    // Everything between the info line and `quit` is the one multi-line
    // response the protocol ever sends, framed by its `# EOF` line.
    let body = &lines[2..];
    assert_eq!(*body.last().unwrap(), "# EOF", "metrics frame terminator");
    let mtext = body.join("\n");
    for def in REGISTRY {
        assert!(mtext.contains(def.name), "metrics output missing {}", def.name);
        assert!(
            mtext.contains(&format!("# TYPE {} {}", def.name, def.kind.type_name())),
            "missing TYPE line for {}",
            def.name
        );
    }
    assert!(mtext.contains("ranksvm_serve_request_latency_us_bucket{le=\"+Inf\"}"));
    assert!(mtext.contains("ranksvm_serve_batch_size_sum"));
    // `# EOF` appears exactly once — it is the frame terminator, so a
    // second occurrence would desynchronise clients.
    assert_eq!(mtext.matches("# EOF").count(), 1);
}

// ----------------------------------------------------- pool counter mirror

#[test]
fn pool_counters_are_always_on() {
    use ranksvm::obs::metrics::{POOL_BATCHES, POOL_TASKS};
    use ranksvm::runtime::{Task, WorkerPool};
    use std::sync::atomic::{AtomicU64, Ordering};

    let before_tasks = POOL_TASKS.get();
    let before_batches = POOL_BATCHES.get();
    let pool = WorkerPool::new(2);
    let hits = AtomicU64::new(0);
    let tasks: Vec<Task<'_>> = (0..16)
        .map(|_| {
            Box::new(|| {
                hits.fetch_add(1, Ordering::Relaxed);
            }) as Task<'_>
        })
        .collect();
    pool.run(tasks);
    assert_eq!(hits.load(Ordering::Relaxed), 16);
    let stats = pool.stats();
    assert_eq!(stats.executed, 16, "per-pool counter");
    assert_eq!(stats.batches, 1, "per-pool counter");
    // The global mirror is monotonic and shared across concurrently
    // running tests, so assert deltas as lower bounds only.
    assert!(POOL_TASKS.get() >= before_tasks + 16, "global mirror");
    assert!(POOL_BATCHES.get() >= before_batches + 1, "global mirror");
}
