//! Loss / subgradient oracles for pairwise ranking.
//!
//! Every training method in the paper reduces to an *oracle* that, given
//! the predicted scores `p = X·w` and the utility labels `y`, returns the
//! empirical risk and its gradient with respect to `p`:
//!
//! - [`tree::TreeOracle`] — Algorithm 3, `O(m log m)` via the
//!   order-statistics red-black tree (the paper's contribution);
//! - [`pairwise::PairOracle`] — the explicit `O(m²)` pair loop
//!   ("PairRSVM");
//! - [`rlevel::RLevelOracle`] — Joachims (2006), `O(m log m + rm)` with
//!   `r` distinct utility levels (what SVM^rank implements);
//! - [`squared::SquaredPairOracle`] — the squared pairwise hinge of
//!   Chapelle & Keerthi (2010) ("PRSVM"), with explicit pair
//!   materialization (quadratic memory, reproducing Fig. 3);
//! - [`toppush::TopPushOracle`] — TopPush (arXiv:1410.1462), the first
//!   non-pairwise loss: bipartite top-of-ranking hinge, `O(m)` per call;
//! - [`query::QueryGrouped`] — per-query averaging wrapper (§2, §4.3 end);
//! - [`sharded::ShardedTreeOracle`] — the tree oracle sharded across a
//!   persistent [`crate::runtime::WorkerPool`] (by query group, or by
//!   balanced query ranges over the score-sorted order for a single
//!   global ranking), with bit-identical output to the serial path for
//!   any shard count;
//! - [`sharded::ShardedGroupOracle`] — the generic per-group engine:
//!   any [`GroupOracle`] evaluated per query group on the same
//!   work-stealing pool with the same serial group-order reduction.
//!
//! Losses are wired into the trainer through the [`registry`] — a
//! [`registry::LossSpec`] per loss naming its solver family, parallel
//! substrate, and normalization owner (normative contract:
//! docs/LOSSES.md).
//!
//! The gradient w.r.t. `w` is then `a = Xᵀ·coeffs` (row-example
//! convention), computed by a [`crate::compute::ComputeBackend`], so the
//! oracles stay independent of dense/sparse/XLA execution.

pub mod pairwise;
pub mod query;
pub mod registry;
pub mod rlevel;
pub mod sharded;
pub mod squared;
pub mod squared_tree;
pub mod toppush;
pub mod tree;

pub use pairwise::PairOracle;
pub use query::{GroupIndex, QueryGrouped};
pub use rlevel::RLevelOracle;
pub use sharded::{ShardedGroupOracle, ShardedTreeOracle};
pub use squared::SquaredPairOracle;
pub use squared_tree::SquaredTreeOracle;
pub use toppush::TopPushOracle;
pub use tree::TreeOracle;

/// Result of one oracle evaluation.
#[derive(Clone, Debug)]
pub struct OracleOutput {
    /// Empirical risk `R_emp(w)` (already normalized by the pair count N).
    pub loss: f64,
    /// `∂R_emp/∂p` per example; the subgradient w.r.t. `w` is
    /// `Xᵀ·coeffs`. For the hinge losses this is `(c_i − d_i)/N`.
    pub coeffs: Vec<f64>,
}

/// A pairwise ranking loss oracle. Implementations may keep internal
/// buffers (`&mut self`) so repeated calls inside the BMRM loop do not
/// reallocate.
pub trait RankingOracle {
    /// Evaluate loss and per-example gradient coefficients.
    ///
    /// `n_pairs` is the number of comparable pairs `N = |{(i,j): y_i <
    /// y_j}|`, precomputed once per training set with
    /// [`count_comparable_pairs`]. Implementations must return zero loss
    /// and zero coefficients when `n_pairs == 0`.
    fn eval(&mut self, p: &[f64], y: &[f64], n_pairs: f64) -> OracleOutput;

    /// Human-readable name used in logs and bench reports.
    fn name(&self) -> &'static str;

    /// Cumulative per-phase clocks, if this oracle keeps any (the tree
    /// oracle times its sort/sweep phases — the paper's per-phase cost
    /// split). Read-only telemetry for `train --trace`
    /// (docs/OBSERVABILITY.md); `None` for losses without phase clocks.
    fn phase_times(&self) -> Option<&crate::util::timer::PhaseTimes> {
        None
    }
}

impl RankingOracle for Box<dyn RankingOracle> {
    fn eval(&mut self, p: &[f64], y: &[f64], n_pairs: f64) -> OracleOutput {
        (**self).eval(p, y, n_pairs)
    }
    fn name(&self) -> &'static str {
        (**self).name()
    }
    fn phase_times(&self) -> Option<&crate::util::timer::PhaseTimes> {
        (**self).phase_times()
    }
}

/// A *per-query-group* subgradient oracle — the pluggable unit of the
/// generic sharded engine ([`sharded::ShardedGroupOracle`]).
///
/// The contract (normative: docs/LOSSES.md):
///
/// - `eval_group` receives one group's scores/labels (gathered
///   contiguously) and returns the group's risk and coefficients
///   **fully normalized within the group** — the normalizer (comparable
///   pairs, positive count, …) is owned by the loss, never by the
///   engine or the trainer. The engine only averages over effective
///   groups.
/// - `is_effective` decides whether a group contributes at all; an
///   ineffective group must have identically zero loss and
///   coefficients, and is excluded from the effective-group average.
///   The decision must be a pure function of `(y, pairs)` so the
///   effective count cannot depend on scores or scheduling.
/// - One evaluation must be bit-reproducible (same inputs ⇒ same bits):
///   iterate in ascending index order and keep any internal tie-breaks
///   deterministic. That, plus the engine's serial group-order
///   reduction, yields thread-count-invariant training
///   (docs/DETERMINISM.md) — `tests/properties.rs` holds every
///   registered loss to it.
///
/// `Send` because each engine task owns one boxed oracle and tasks
/// migrate between pool workers.
pub trait GroupOracle: Send {
    /// Evaluate one group. `pairs` is the group's comparable-pair count
    /// (from [`GroupIndex`]); pair-normalized losses consume it, others
    /// ignore it.
    fn eval_group(&mut self, p: &[f64], y: &[f64], pairs: u64) -> OracleOutput;

    /// Does a group with these labels/pairs contribute to the risk?
    fn is_effective(&self, y: &[f64], pairs: u64) -> bool;

    /// Loss name for logs and reports.
    fn name(&self) -> &'static str;
}

/// Every tree-family oracle is a [`GroupOracle`]: pair-normalized
/// within the group, effective iff the group has comparable pairs —
/// exactly the per-group arithmetic [`query::QueryGrouped`] and the
/// sharded engine's grouped mode have always performed.
impl<T: crate::rbtree::RankCounter + Send> GroupOracle for tree::GenericTreeOracle<T> {
    fn eval_group(&mut self, p: &[f64], y: &[f64], pairs: u64) -> OracleOutput {
        RankingOracle::eval(self, p, y, pairs as f64)
    }
    fn is_effective(&self, _y: &[f64], pairs: u64) -> bool {
        pairs > 0
    }
    fn name(&self) -> &'static str {
        RankingOracle::name(self)
    }
}

/// Count comparable pairs `N = |{(i,j) : y_i < y_j}|` in `O(m log m)`:
/// total pairs minus tied pairs, via one sort.
pub fn count_comparable_pairs(y: &[f64]) -> u64 {
    let m = y.len() as u64;
    if m < 2 {
        return 0;
    }
    let mut s: Vec<f64> = y.to_vec();
    s.sort_unstable_by(|a, b| a.total_cmp(b));
    let total = m * (m - 1) / 2;
    let mut ties = 0u64;
    let mut run = 1u64;
    for w in s.windows(2) {
        if w[0] == w[1] {
            run += 1;
        } else {
            ties += run * (run - 1) / 2;
            run = 1;
        }
    }
    ties += run * (run - 1) / 2;
    total - ties
}

/// Shared helper: assemble loss from the frequency vectors via Lemma 1,
/// `loss = (1/N) Σ ((c_i − d_i)·p_i + c_i)`, and the gradient
/// coefficients `(c_i − d_i)/N` (Lemma 2).
pub(crate) fn assemble_from_counts(p: &[f64], c: &[u64], d: &[u64], n_pairs: f64) -> OracleOutput {
    debug_assert_eq!(p.len(), c.len());
    debug_assert_eq!(p.len(), d.len());
    if n_pairs == 0.0 {
        return OracleOutput { loss: 0.0, coeffs: vec![0.0; p.len()] };
    }
    let inv_n = 1.0 / n_pairs;
    let mut loss = 0.0;
    let mut coeffs = Vec::with_capacity(p.len());
    for i in 0..p.len() {
        let cd = c[i] as f64 - d[i] as f64;
        loss += cd * p[i] + c[i] as f64;
        coeffs.push(cd * inv_n);
    }
    OracleOutput { loss: loss * inv_n, coeffs }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pair_count_all_distinct() {
        assert_eq!(count_comparable_pairs(&[3.0, 1.0, 2.0]), 3);
        assert_eq!(count_comparable_pairs(&[1.0, 2.0, 3.0, 4.0]), 6);
    }

    #[test]
    fn pair_count_with_ties() {
        assert_eq!(count_comparable_pairs(&[1.0, 1.0, 1.0]), 0);
        assert_eq!(count_comparable_pairs(&[1.0, 1.0, 2.0]), 2);
        // bipartite: 2 positives, 3 negatives → 6 comparable pairs
        assert_eq!(count_comparable_pairs(&[0.0, 1.0, 0.0, 1.0, 0.0]), 6);
    }

    #[test]
    fn pair_count_degenerate() {
        assert_eq!(count_comparable_pairs(&[]), 0);
        assert_eq!(count_comparable_pairs(&[5.0]), 0);
    }

    #[test]
    fn pair_count_matches_naive_randomized() {
        let mut rng = crate::util::rng::Rng::new(41);
        for _ in 0..30 {
            let m = rng.below(60);
            let y: Vec<f64> = (0..m).map(|_| rng.below(6) as f64).collect();
            let mut naive = 0u64;
            for i in 0..m {
                for j in 0..m {
                    if y[i] < y[j] {
                        naive += 1;
                    }
                }
            }
            assert_eq!(count_comparable_pairs(&y), naive);
        }
    }

    #[test]
    fn assemble_zero_pairs() {
        let out = assemble_from_counts(&[1.0, 2.0], &[0, 0], &[0, 0], 0.0);
        assert_eq!(out.loss, 0.0);
        assert_eq!(out.coeffs, vec![0.0, 0.0]);
    }
}
