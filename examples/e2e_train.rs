//! End-to-end driver: the full three-layer system on a realistic
//! workload, proving all layers compose.
//!
//! - L3 (this binary + the ranksvm coordinator): BMRM loop, tree oracle,
//!   metrics, logging;
//! - L2/L1 (AOT JAX/Pallas artifacts via PJRT): the dense score matvec
//!   and gradient assembly, when `artifacts/` is present — the run
//!   reports both backends and checks they agree;
//! - workload: Reuters-like sparse similarity ranking (the paper's §5.1
//!   construction) at m = 20 000, plus a dense Cadata-like run through
//!   the XLA path.
//!
//! Emits a JSONL loss curve to `e2e_loss_curve.jsonl` and a summary to
//! stdout; EXPERIMENTS.md records a reference run.
//!
//!     cargo run --release --example e2e_train

use ranksvm::coordinator::{evaluate, train, BackendKind, Method, TrainConfig};
use ranksvm::data::synthetic;
use ranksvm::util::json::Json;
use std::io::Write;

fn main() -> anyhow::Result<()> {
    let t0 = std::time::Instant::now();

    // ---------- Part 1: sparse Reuters-like workload (native backend) ----
    let m = 20_000;
    println!("== e2e part 1: sparse similarity ranking (reuters-like, m={m}) ==");
    let ds = synthetic::reuters_like(m, 2024);
    println!(
        "built corpus: m={} vocab={} s={:.1} distinct-scores={}",
        ds.len(),
        ds.dim(),
        ds.sparsity(),
        ds.n_levels()
    );
    let (tr, te) = ds.split(4000, 1);
    let cfg = TrainConfig {
        method: Method::Tree,
        lambda: 1e-5, // paper's Reuters value
        epsilon: 1e-3,
        ..Default::default()
    };
    let out = train(&tr, &cfg)?;
    let test_err = evaluate(&out.model, &te);
    println!(
        "tree: {} iters in {:.2}s (oracle {:.1} ms/iter) objective={:.6} gap={:.2e} test_err={:.4}",
        out.iterations,
        out.train_secs,
        1e3 * out.avg_oracle_secs(),
        out.objective,
        out.gap,
        test_err
    );

    // Loss curve to JSONL.
    let curve_path = "e2e_loss_curve.jsonl";
    let mut f = std::fs::File::create(curve_path)?;
    for (iter, objective, gap) in &out.trace {
        writeln!(
            f,
            "{}",
            Json::obj(vec![
                ("iter", (*iter).into()),
                ("objective", (*objective).into()),
                ("gap", (*gap).into()),
            ])
            .to_string()
        )?;
    }
    println!("loss curve ({} points) → {curve_path}", out.trace.len());

    // Loss curve sanity: objective decreases, gap shrinks.
    let first = out.trace.first().unwrap();
    let last = out.trace.last().unwrap();
    assert!(last.1 <= first.1, "objective did not improve");
    assert!(last.2 < 1e-3, "gap did not reach epsilon");

    // ---------- Part 2: dense workload through the XLA (PJRT) path -------
    println!("\n== e2e part 2: dense ranking through AOT JAX/Pallas artifacts ==");
    let artifacts = std::env::var("RANKSVM_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    if std::path::Path::new(&artifacts).join("manifest.txt").is_file() {
        let dense = synthetic::cadata_like(8000, 7);
        let (dtr, dte) = dense.split(2000, 2);
        let native_cfg = TrainConfig { method: Method::Tree, lambda: 0.1, ..Default::default() };
        let xla_cfg = TrainConfig {
            method: Method::Tree,
            backend: BackendKind::Xla,
            lambda: 0.1,
            artifacts_dir: artifacts.clone(),
            ..Default::default()
        };
        let native = train(&dtr, &native_cfg)?;
        let xla = train(&dtr, &xla_cfg)?;
        let native_err = evaluate(&native.model, &dte);
        let xla_err = evaluate(&xla.model, &dte);
        println!(
            "native backend: {} iters {:.2}s objective={:.6} test_err={:.4}",
            native.iterations, native.train_secs, native.objective, native_err
        );
        println!(
            "xla    backend: {} iters {:.2}s objective={:.6} test_err={:.4}",
            xla.iterations, xla.train_secs, xla.objective, xla_err
        );
        assert!(
            (native.objective - xla.objective).abs() < 5e-3 * (1.0 + native.objective.abs()),
            "backends disagree"
        );
        println!("backends agree (|Δobjective| within f32 tolerance) ✓");
    } else {
        println!("artifacts/ missing — run `make artifacts` to exercise the PJRT path");
    }

    // ---------- Part 3: the paper's headline contrast on this testbed ----
    println!("\n== e2e part 3: tree vs pair oracle at m=20k (Fig. 1 spot check) ==");
    let spot = tr.prefix(tr.len().min(20_000));
    for method in [Method::Tree, Method::Pair] {
        let mut c = cfg.clone();
        c.method = method;
        c.max_iter = 5; // per-iteration cost comparison only
        let out = train(&spot, &c)?;
        println!(
            "{:<5} avg oracle cost over {} iters: {:>9.1} ms",
            out.method,
            out.iterations,
            1e3 * out.avg_oracle_secs()
        );
    }

    println!("\ne2e complete in {:.1}s", t0.elapsed().as_secs_f64());
    Ok(())
}
