//! Persistent-pool lockdown: the pooled oracle, the pooled backend, and
//! the parallel argsort must be bitwise identical to the serial paths at
//! 1/2/8 threads — not just for single calls but across repeated
//! evaluations on one long-lived pool, the way a BMRM run uses them.
//! Plus regression tests for the NaN-ordering and libsvm parser fixes.

use ranksvm::compute::{ComputeBackend, NativeBackend, ParallelBackend};
use ranksvm::coordinator::{train, Method, TrainConfig};
use ranksvm::data::{libsvm, synthetic};
use ranksvm::linalg::ops::{argsort, argsort_into, par_argsort_into, SortScratch, PAR_SORT_MIN};
use ranksvm::losses::{count_comparable_pairs, RankingOracle, ShardedTreeOracle, TreeOracle};
use ranksvm::runtime::WorkerPool;
use ranksvm::util::rng::Rng;
use std::sync::Arc;

/// A full BMRM training run on one shared pool must be bit-identical to
/// the single-threaded run — the pool only moves work between threads,
/// never across a floating-point reduction boundary.
#[test]
fn pooled_training_is_bitwise_invariant_to_thread_count() {
    for (ds, tag) in [
        (synthetic::cadata_like(400, 1101), "global"),
        (synthetic::queries(15, 16, 6, 1102), "grouped"),
    ] {
        let mut reference: Option<Vec<f64>> = None;
        for threads in [1usize, 2, 8] {
            let cfg = TrainConfig {
                method: Method::Tree,
                lambda: 0.1,
                epsilon: 1e-3,
                n_threads: threads,
                ..Default::default()
            };
            let out = train(&ds, &cfg).unwrap();
            assert!(out.converged, "{tag}: {threads} threads");
            match &reference {
                None => reference = Some(out.model.w),
                Some(w) => assert_eq!(&out.model.w, w, "{tag}: {threads} threads"),
            }
        }
    }
}

/// The trainer's arrangement in miniature: one pool shared by the
/// sharded oracle and the parallel backend, driven through many
/// score/oracle/grad rounds with evolving weights. Every round must
/// match the serial oracle bit-for-bit — this exercises pool *reuse*
/// (buffer state surviving batches), not just a single dispatch.
#[test]
fn shared_pool_oracle_and_backend_match_serial_across_iterations() {
    let ds = synthetic::cadata_like(600, 1203);
    let n_pairs = count_comparable_pairs(&ds.y) as f64;
    for threads in [1usize, 2, 8] {
        let pool = Arc::new(WorkerPool::new(threads));
        let mut oracle = ShardedTreeOracle::with_pool(Arc::clone(&pool), None, &ds.y);
        let mut backend = ParallelBackend::with_pool(Arc::clone(&pool));
        backend.prepare(ds.x.view());
        let mut serial_oracle = TreeOracle::new();
        let mut serial_backend = NativeBackend::new();
        serial_backend.prepare(ds.x.view());

        let mut w = vec![0.0; ds.dim()];
        for round in 0..6 {
            let p = backend.scores(ds.x.view(), &w);
            let p_ref = serial_backend.scores(ds.x.view(), &w);
            assert_eq!(p, p_ref, "{threads} threads, round {round}: scores");

            let got = oracle.eval(&p, &ds.y, n_pairs);
            let expect = serial_oracle.eval(&p, &ds.y, n_pairs);
            assert_eq!(got.coeffs, expect.coeffs, "{threads} threads, round {round}");
            assert_eq!(
                got.loss.to_bits(),
                expect.loss.to_bits(),
                "{threads} threads, round {round}"
            );

            // Subgradient step (any deterministic update works — the
            // point is that p changes every round).
            let g = backend.grad(ds.x.view(), &got.coeffs);
            for (wi, gi) in w.iter_mut().zip(&g) {
                *wi -= 0.5 * gi;
            }
        }
    }
}

/// par_argsort_into on a long-lived pool, called back to back with
/// changing data and sizes (the oracle's per-iteration pattern), stays
/// bitwise equal to the serial argsort.
#[test]
fn par_argsort_matches_serial_across_repeated_pool_use() {
    let mut rng = Rng::new(1301);
    for threads in [1usize, 2, 8] {
        let pool = WorkerPool::new(threads);
        let mut idx = Vec::new();
        let mut scratch = SortScratch::default();
        for round in 0..10 {
            let m = PAR_SORT_MIN / 2 + rng.below(3 * PAR_SORT_MIN);
            let v: Vec<f64> = match round % 3 {
                0 => (0..m).map(|_| rng.normal()).collect(),
                1 => (0..m).map(|_| rng.below(9) as f64).collect(),
                _ => (0..m).map(|i| (i % 17) as f64 - 8.0).collect(),
            };
            let mut expect = Vec::new();
            argsort_into(&v, &mut expect);
            par_argsort_into(&v, &mut idx, &mut scratch, &pool);
            assert_eq!(idx, expect, "{threads} threads, round {round}, m={m}");
        }
    }
}

/// The pooled tree oracle (parallel argsort, serial sweeps) is a drop-in
/// replacement for the plain serial oracle.
#[test]
fn pooled_tree_oracle_bit_identical_to_serial() {
    let mut rng = Rng::new(1401);
    let m = 3000;
    let y: Vec<f64> = (0..m).map(|_| rng.below(5) as f64).collect();
    let n = count_comparable_pairs(&y) as f64;
    let pool = Arc::new(WorkerPool::new(4));
    let mut serial = TreeOracle::new();
    let mut pooled = TreeOracle::new().with_pool(pool);
    for round in 0..4 {
        let p: Vec<f64> = (0..m).map(|_| rng.normal() * (round + 1) as f64).collect();
        let a = serial.eval(&p, &y, n);
        let b = pooled.eval(&p, &y, n);
        assert_eq!(a.coeffs, b.coeffs, "round {round}");
        assert_eq!(a.loss.to_bits(), b.loss.to_bits(), "round {round}");
    }
}

/// Degenerate all-scores-within-one-margin inputs (every window spans
/// the whole sorted order) must still be exact for every thread count —
/// and they now redistribute across shards instead of collapsing onto
/// one worker, so a large degenerate eval is safe to run wide.
#[test]
fn degenerate_margin_case_exact_at_all_thread_counts() {
    let mut rng = Rng::new(1501);
    let m = 4096;
    let y: Vec<f64> = (0..m).map(|_| rng.below(7) as f64).collect();
    // All scores in [0, 1e-3]: every pair is within the unit margin.
    let p: Vec<f64> = (0..m).map(|_| rng.below(1000) as f64 * 1e-6).collect();
    let n = count_comparable_pairs(&y) as f64;
    let mut reference = TreeOracle::new();
    let expect = reference.eval(&p, &y, n);
    for threads in [1usize, 2, 8] {
        let mut sharded = ShardedTreeOracle::new(threads, None, &y);
        let got = sharded.eval(&p, &y, n);
        assert_eq!(got.coeffs, expect.coeffs, "{threads} threads");
        assert_eq!(got.loss.to_bits(), expect.loss.to_bits(), "{threads} threads");
    }
}

// ---------- work-stealing scheduler regressions (PR 4) ----------

/// A panic inside a *stolen* task must poison exactly the batch it
/// belongs to — `run` re-raises once — and leave the pool fully
/// reusable. The steal is forced structurally: the submitting thread
/// spins on its block's LIFO end until the block's FIFO end (the
/// panicking task) has been taken by another worker.
#[test]
fn stolen_task_panic_poisons_exactly_one_batch() {
    use ranksvm::runtime::Task;
    use std::panic::{catch_unwind, AssertUnwindSafe};
    use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
    let pool = WorkerPool::new(4);
    let survivors = AtomicUsize::new(0);
    let result = catch_unwind(AssertUnwindSafe(|| {
        let taken = AtomicBool::new(false);
        let mut tasks: Vec<Task> = Vec::new();
        // Front of the caller's block: stolen by an idle worker, flags
        // the spinner, then panics.
        tasks.push(Box::new(|| {
            taken.store(true, Ordering::SeqCst);
            panic!("stolen task boom");
        }));
        // Back of the caller's block: runs first on the caller, pinning
        // it until the panicking task has been stolen.
        tasks.push(Box::new(|| {
            let t0 = std::time::Instant::now();
            while !taken.load(Ordering::SeqCst) {
                assert!(t0.elapsed().as_secs() < 10, "steal never happened");
                std::hint::spin_loop();
            }
        }));
        for _ in 0..6 {
            let survivors = &survivors;
            tasks.push(Box::new(move || {
                survivors.fetch_add(1, Ordering::Relaxed);
            }));
        }
        pool.run(tasks);
    }));
    assert!(result.is_err(), "the stolen panic must re-raise from run()");
    // Every other task of the poisoned batch still ran (scope
    // semantics: the barrier holds even through a panic).
    assert_eq!(survivors.load(Ordering::Relaxed), 6);
    // ...and the pool is not poisoned: later batches behave normally.
    for round in 0..3 {
        let counter = AtomicUsize::new(0);
        let tasks: Vec<Task> = (0..12)
            .map(|_| {
                let counter = &counter;
                Box::new(move || {
                    counter.fetch_add(1, Ordering::Relaxed);
                }) as Task
            })
            .collect();
        pool.run(tasks);
        assert_eq!(counter.load(Ordering::Relaxed), 12, "round {round}");
    }
}

/// Empty and singleton batches take the inline fast path: nothing is
/// scheduled, the singleton runs on the submitting thread even when
/// idle workers exist.
#[test]
fn empty_and_singleton_batches_run_inline_on_the_caller() {
    use ranksvm::runtime::Task;
    let pool = WorkerPool::new(8);
    pool.run(Vec::new()); // no-op, must not hang or panic
    let caller = std::thread::current().id();
    for _ in 0..50 {
        let mut ran_on = None;
        {
            let slot = &mut ran_on;
            let task: Task = Box::new(move || *slot = Some(std::thread::current().id()));
            pool.run(vec![task]);
        }
        assert_eq!(ran_on, Some(caller), "singleton escaped the inline path");
    }
}

/// `n_threads == 1` spawns no workers: every task of every batch runs
/// on the calling thread, in submission order.
#[test]
fn single_thread_pool_runs_all_tasks_on_the_caller_in_order() {
    use ranksvm::runtime::Task;
    let pool = WorkerPool::new(1);
    assert_eq!(pool.n_threads(), 1);
    let caller = std::thread::current().id();
    let mut log: Vec<(usize, std::thread::ThreadId)> = Vec::new();
    {
        let log_cell = std::sync::Mutex::new(&mut log);
        let tasks: Vec<Task> = (0..32)
            .map(|i| {
                let log_cell = &log_cell;
                Box::new(move || {
                    log_cell.lock().unwrap().push((i, std::thread::current().id()));
                }) as Task
            })
            .collect();
        pool.run(tasks);
    }
    assert_eq!(log.len(), 32);
    for (k, &(i, tid)) in log.iter().enumerate() {
        assert_eq!(i, k, "inline execution must preserve submission order");
        assert_eq!(tid, caller, "task {i} ran off-thread on a 1-thread pool");
    }
}

/// The sharded oracle under the stealing scheduler: a giant query group
/// next to thousands of singletons (the skew shape the scheduler
/// exists for), repeatedly evaluated on one pool, stays bit-identical
/// to the serial grouped oracle. Overlaps tests/scheduler.rs on
/// purpose — this is the pool-suite-local canary.
#[test]
fn skewed_grouped_eval_on_shared_pool_matches_serial() {
    use ranksvm::losses::QueryGrouped;
    let mut rng = Rng::new(1601);
    let giant = 800usize;
    let singles = 1500usize;
    let m = giant + singles;
    let mut qid = vec![0u64; giant];
    qid.extend((1..=singles).map(|g| g as u64));
    let y: Vec<f64> = (0..m).map(|_| rng.below(4) as f64).collect();
    let mut serial = QueryGrouped::new(TreeOracle::new(), &qid, &y);
    let pool = Arc::new(WorkerPool::new(8));
    let mut sharded = ShardedTreeOracle::with_pool(Arc::clone(&pool), Some(&qid), &y);
    for round in 0..4 {
        let p: Vec<f64> = (0..m).map(|_| rng.normal() * (round + 1) as f64).collect();
        let expect = serial.eval(&p, &y, serial.total_pairs());
        let got = sharded.eval(&p, &y, 0.0);
        assert_eq!(got.coeffs, expect.coeffs, "round {round}");
        assert_eq!(got.loss.to_bits(), expect.loss.to_bits(), "round {round}");
    }
}

// ---------- NaN-ordering regressions (total_cmp satellite) ----------

#[test]
fn nan_scores_no_longer_panic_sorts() {
    // argsort: NaN orders after +inf, deterministically.
    let v = [1.0, f64::NAN, 0.5, f64::INFINITY];
    assert_eq!(argsort(&v), vec![2, 0, 3, 1]);

    // Metrics: a NaN prediction produces a (well-defined) number instead
    // of a mid-training panic.
    let y = [1.0, 2.0, 3.0];
    let p = [0.0, f64::NAN, 1.0];
    let e = ranksvm::metrics::pairwise_error(&p, &y);
    assert!(e.is_finite());
    let _ = ranksvm::metrics::ndcg_at_k(&p, &y, 3);
    let _ = ranksvm::metrics::precision_at_k(&p, &y, 2, 0.5);

    // BenchStats over a NaN timing sample.
    let s = ranksvm::util::timer::BenchStats::from_times(vec![1.0, f64::NAN, 2.0]);
    assert_eq!(s.min, 1.0);
}

#[test]
fn nan_label_no_longer_panics_metrics_or_counts() {
    let y = [1.0, f64::NAN, 2.0];
    let p = [0.1, 0.2, 0.3];
    let _ = ranksvm::metrics::pairwise_error(&p, &y);
    // count_comparable_pairs sorts labels: must not panic either.
    let _ = count_comparable_pairs(&y);
}

#[test]
fn rank_model_with_nan_score_is_deterministic() {
    use ranksvm::coordinator::RankModel;
    let ds = synthetic::cadata_like(8, 9);
    // A NaN weight poisons every score; rank() must still return a
    // deterministic permutation of all examples.
    let model = RankModel::new(vec![f64::NAN; ds.dim()]);
    let order = model.rank(&ds);
    let mut sorted = order.clone();
    sorted.sort_unstable();
    assert_eq!(sorted, (0..ds.len()).collect::<Vec<_>>());
}

// ---------- libsvm parser regressions ----------

#[test]
fn parser_rejects_nan_inf_and_disordered_rows_with_line_numbers() {
    let cases = [
        ("1 1:1.0\nnan 1:1.0\n", "t:2"),
        ("1 1:inf\n", "t:1"),
        ("1 1:1.0 1:2.0\n", "t:1"),
        ("1 1:1.0\n2 5:1.0 3:1.0\n", "t:2"),
    ];
    for (text, frag) in cases {
        let err = libsvm::parse(std::io::Cursor::new(text), "t").unwrap_err();
        assert!(err.to_string().contains(frag), "{text:?} → {err}");
    }
}

#[test]
fn parser_accepts_trailing_qid_and_crlf() {
    let text = "2 1:0.5 2:1.5 qid:3\r\n1 qid:3 1:0.25\r\n";
    let ds = libsvm::parse(std::io::Cursor::new(text), "t").unwrap();
    assert_eq!(ds.len(), 2);
    assert_eq!(ds.qid, Some(vec![3, 3]));
    assert_eq!(ds.y, vec![2.0, 1.0]);
}
