//! Cyclic Jacobi eigendecomposition for symmetric matrices.
//!
//! Used to form `K_bb^{-1/2}` in the Nyström map. Jacobi is slow for
//! large matrices but bullet-proof and accurate for the reduced-set
//! sizes we target (k ≤ a few hundred); no LAPACK exists in the offline
//! crate set (DESIGN.md §6).

use crate::linalg::DenseMatrix;

/// Eigendecomposition of a symmetric matrix: returns `(values, vectors)`
/// with `A = V diag(λ) Vᵀ`, eigenvectors in the *columns* of `V`.
/// Panics on non-square input; symmetry is assumed (upper triangle used).
pub fn eigen_sym(a: &DenseMatrix) -> (Vec<f64>, DenseMatrix) {
    let n = a.rows();
    assert_eq!(n, a.cols(), "eigen_sym needs a square matrix");
    let mut m = a.clone();
    let mut v = DenseMatrix::zeros(n, n);
    for i in 0..n {
        v.set(i, i, 1.0);
    }
    if n <= 1 {
        return ((0..n).map(|i| m.get(i, i)).collect(), v);
    }

    let max_sweeps = 64;
    for _sweep in 0..max_sweeps {
        // Off-diagonal Frobenius mass.
        let mut off = 0.0;
        for i in 0..n {
            for j in (i + 1)..n {
                off += m.get(i, j) * m.get(i, j);
            }
        }
        if off.sqrt() < 1e-14 * (1.0 + frob(&m)) {
            break;
        }
        for p in 0..n {
            for q in (p + 1)..n {
                let apq = m.get(p, q);
                if apq.abs() < 1e-300 {
                    continue;
                }
                let app = m.get(p, p);
                let aqq = m.get(q, q);
                // Rotation angle (Golub & Van Loan 8.4).
                let theta = (aqq - app) / (2.0 * apq);
                let t = theta.signum() / (theta.abs() + (theta * theta + 1.0).sqrt());
                let c = 1.0 / (t * t + 1.0).sqrt();
                let s = t * c;
                // A ← JᵀAJ (rows/cols p and q).
                for k in 0..n {
                    let akp = m.get(k, p);
                    let akq = m.get(k, q);
                    m.set(k, p, c * akp - s * akq);
                    m.set(k, q, s * akp + c * akq);
                }
                for k in 0..n {
                    let apk = m.get(p, k);
                    let aqk = m.get(q, k);
                    m.set(p, k, c * apk - s * aqk);
                    m.set(q, k, s * apk + c * aqk);
                }
                // V ← VJ.
                for k in 0..n {
                    let vkp = v.get(k, p);
                    let vkq = v.get(k, q);
                    v.set(k, p, c * vkp - s * vkq);
                    v.set(k, q, s * vkp + c * vkq);
                }
            }
        }
    }
    ((0..n).map(|i| m.get(i, i)).collect(), v)
}

fn frob(m: &DenseMatrix) -> f64 {
    m.data().iter().map(|v| v * v).sum::<f64>().sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_sym(n: usize, rng: &mut Rng) -> DenseMatrix {
        let mut a = DenseMatrix::zeros(n, n);
        for i in 0..n {
            for j in i..n {
                let v = rng.normal();
                a.set(i, j, v);
                a.set(j, i, v);
            }
        }
        a
    }

    #[test]
    fn reconstructs_matrix() {
        let mut rng = Rng::new(91);
        for &n in &[1usize, 2, 5, 20, 50] {
            let a = random_sym(n, &mut rng);
            let (vals, vecs) = eigen_sym(&a);
            // A ?= V diag(vals) Vᵀ
            for i in 0..n {
                for j in 0..n {
                    let mut s = 0.0;
                    for t in 0..n {
                        s += vecs.get(i, t) * vals[t] * vecs.get(j, t);
                    }
                    assert!(
                        (s - a.get(i, j)).abs() < 1e-8 * (1.0 + a.get(i, j).abs()),
                        "n={n} A[{i}][{j}]: {s} vs {}",
                        a.get(i, j)
                    );
                }
            }
        }
    }

    #[test]
    fn eigenvectors_orthonormal() {
        let mut rng = Rng::new(93);
        let a = random_sym(30, &mut rng);
        let (_, vecs) = eigen_sym(&a);
        for i in 0..30 {
            for j in 0..30 {
                let mut s = 0.0;
                for t in 0..30 {
                    s += vecs.get(t, i) * vecs.get(t, j);
                }
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((s - want).abs() < 1e-9, "VᵀV[{i}][{j}] = {s}");
            }
        }
    }

    #[test]
    fn diagonal_matrix_is_fixed_point() {
        let mut a = DenseMatrix::zeros(3, 3);
        a.set(0, 0, 3.0);
        a.set(1, 1, -1.0);
        a.set(2, 2, 7.0);
        let (mut vals, _) = eigen_sym(&a);
        vals.sort_unstable_by(|x, y| x.total_cmp(y));
        assert_eq!(vals, vec![-1.0, 3.0, 7.0]);
    }

    #[test]
    fn psd_gram_has_nonnegative_spectrum() {
        // Gram matrices (what Nyström feeds in) must get λ ≥ −ε.
        let mut rng = Rng::new(95);
        let k = 25;
        let feats: Vec<Vec<f64>> =
            (0..k).map(|_| (0..10).map(|_| rng.normal()).collect()).collect();
        let mut g = DenseMatrix::zeros(k, k);
        for i in 0..k {
            for j in 0..k {
                g.set(i, j, crate::linalg::ops::dot(&feats[i], &feats[j]));
            }
        }
        let (vals, _) = eigen_sym(&g);
        for v in vals {
            assert!(v > -1e-9, "negative eigenvalue {v} from PSD Gram");
        }
    }
}
