//! Connection front-ends for the serving engine: a line loop that is
//! generic over its transport, plus stdio and TCP drivers.
//!
//! One connection is one [`handle_connection`] call: read a line,
//! classify it ([`protocol::parse`]), answer exactly one line per
//! input line, flush, repeat until `quit` or EOF. `batch <n>` frames
//! the next `n` lines into a single engine batch (one model-version
//! snapshot, responses in input order); every other request line is a
//! batch of one. Control verbs are not allowed inside a batch frame —
//! they become structured errors in their slot, so responses never
//! fall out of alignment with inputs.
//!
//! The TCP driver is thread-per-connection over one shared
//! [`Engine`]: the engine's pool serializes batches internally, so
//! concurrent connections simply interleave at batch granularity —
//! exactly the consistency unit the hot-swap tests pin.

use super::engine::Engine;
use super::protocol::{self, Line, Request};
use crate::obs;
use anyhow::{Context, Result};
use std::io::{BufRead, Write};
use std::sync::Arc;

/// Demote a classified line to a batch-slot request: scoring requests
/// pass through, control verbs become structured errors (a `quit` in
/// the middle of a frame must not silently shift every later slot).
fn as_batch_slot(line: Line) -> Request {
    match line {
        Line::Req(req) => req,
        _ => Request::Invalid("control commands are not allowed inside a batch frame".into()),
    }
}

/// The engine's `info` response: one `ok` line of `key=value` pairs
/// (the key set is normative — docs/OBSERVABILITY.md).
fn info_line(engine: &Engine) -> String {
    let epoch = engine.current();
    let (batches, requests, swaps) = engine.counters();
    let dim_or = |v: Option<usize>| v.map_or_else(|| "-".into(), |n| n.to_string());
    format!(
        "ok v={} dim={} normalize={} rows={} groups={} threads={} batches={} requests={} \
         swaps={} errors={} uptime_s={}",
        epoch.version,
        epoch.model.dim(),
        epoch.model.normalize_name(),
        dim_or(engine.n_rows()),
        dim_or(engine.n_groups()),
        engine.n_threads(),
        batches,
        requests,
        swaps,
        engine.errors_count(),
        engine.uptime_secs()
    )
}

fn flatten(e: anyhow::Error) -> String {
    format!("{e:#}").replace(['\n', '\r'], " ")
}

/// Serve one connection until `quit` or EOF. Errors returned here are
/// transport failures (a vanished socket); protocol-level problems are
/// answered in-band as `err` lines and never tear the connection down.
pub fn handle_connection<R: BufRead, W: Write>(
    engine: &Engine,
    input: R,
    mut out: W,
) -> Result<()> {
    let mut lines = input.lines();
    while let Some(line) = lines.next() {
        let line = line.context("read request line")?;
        match protocol::parse(&line) {
            Line::Quit => break,
            Line::Ping => {
                writeln!(out, "ok v={} pong", engine.current().version)?;
            }
            Line::Info => {
                writeln!(out, "{}", info_line(engine))?;
            }
            Line::Metrics => {
                // The protocol's one multi-line response; the trailing
                // `# EOF` line is the client's frame terminator.
                out.write_all(obs::metrics::render_prometheus().as_bytes())?;
            }
            Line::Reload => match engine.force_reload() {
                Ok(()) => {
                    writeln!(out, "ok v={} reloaded=true", engine.current().version)?;
                }
                Err(e) => writeln!(out, "err {}", flatten(e))?,
            },
            Line::Swap(path) => match engine.swap_from(&path) {
                Ok(()) => {
                    writeln!(out, "ok v={} swapped=true", engine.current().version)?;
                }
                Err(e) => writeln!(out, "err {}", flatten(e))?,
            },
            Line::Batch(n) => {
                let mut reqs = Vec::with_capacity(n);
                while reqs.len() < n {
                    match lines.next() {
                        Some(Ok(l)) => reqs.push(as_batch_slot(protocol::parse(&l))),
                        Some(Err(e)) => return Err(e).context("read batch line"),
                        // EOF inside a frame: answer the missing slots
                        // as errors so the client still gets n lines.
                        None => reqs.push(Request::Invalid("batch frame truncated by EOF".into())),
                    }
                }
                for resp in engine.run_batch(&reqs) {
                    writeln!(out, "{}", protocol::render(&resp))?;
                }
            }
            Line::Req(req) => {
                for resp in engine.run_batch(std::slice::from_ref(&req)) {
                    writeln!(out, "{}", protocol::render(&resp))?;
                }
            }
        }
        out.flush()?;
    }
    out.flush()?;
    Ok(())
}

/// Serve requests from stdin to stdout — the CI smoke test's transport
/// and the default when `--listen` is not given.
pub fn serve_stdio(engine: &Engine) -> Result<()> {
    let stdin = std::io::stdin();
    let stdout = std::io::stdout();
    handle_connection(engine, stdin.lock(), std::io::BufWriter::new(stdout.lock()))
}

/// Bind `addr` and serve each connection on its own thread over the
/// shared engine. Prints one `serve listening <addr>` line once bound
/// (so scripts can wait for readiness), then runs until the process is
/// killed.
pub fn serve_tcp(engine: Arc<Engine>, addr: &str) -> Result<()> {
    let listener =
        std::net::TcpListener::bind(addr).with_context(|| format!("bind {addr}"))?;
    obs::log::data(&format!("serve listening {}", listener.local_addr()?));
    for stream in listener.incoming() {
        let Ok(stream) = stream else { continue };
        let engine = Arc::clone(&engine);
        std::thread::spawn(move || {
            let Ok(reader) = stream.try_clone() else { return };
            let _ = handle_connection(
                &engine,
                std::io::BufReader::new(reader),
                std::io::BufWriter::new(stream),
            );
        });
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{synthetic, LoadedDataset};
    use crate::serve::ScoringModel;
    use std::io::Cursor;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!("ranksvm_daemon_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    fn engine(name: &str) -> Engine {
        let ds = synthetic::queries(6, 5, 8, 7);
        let w: Vec<f64> = (0..8).map(|j| 0.5 - 0.1 * j as f64).collect();
        let path = tmp(&format!("{name}.rsm"));
        ScoringModel::new(w, None).unwrap().save(&path).unwrap();
        Engine::new(&path, Some(LoadedDataset::Owned(ds)), 2, true).unwrap()
    }

    fn drive(engine: &Engine, input: &str) -> Vec<String> {
        let mut out = Vec::new();
        handle_connection(engine, Cursor::new(input.as_bytes()), &mut out).unwrap();
        String::from_utf8(out).unwrap().lines().map(str::to_owned).collect()
    }

    #[test]
    fn one_line_per_request_line() {
        let eng = engine("pairing");
        let out = drive(&eng, "ping\nrows 0 1\nnot-a-verb\nscore 1:2\ninfo\nquit\nrows 2\n");
        // 5 answered lines; quit stops the loop before the last rows.
        assert_eq!(out.len(), 5, "{out:?}");
        assert_eq!(out[0], "ok v=1 pong");
        assert!(out[1].starts_with("ok v=1 "), "{}", out[1]);
        assert!(out[2].starts_with("err "), "{}", out[2]);
        assert!(out[3].starts_with("ok v=1 "), "{}", out[3]);
        assert!(out[4].contains(" dim=8 ") && out[4].contains(" threads=2 "), "{}", out[4]);
    }

    #[test]
    fn batch_frames_stay_aligned() {
        let eng = engine("framing");
        // A control verb and a bad line inside the frame become err
        // slots; the frame still answers exactly 4 lines, in order.
        let out = drive(&eng, "batch 4\nrows 0\nping\nrows nope\nrows 1\n");
        assert_eq!(out.len(), 4, "{out:?}");
        assert!(out[0].starts_with("ok v=1 "), "{}", out[0]);
        assert!(out[1].starts_with("err "), "{}", out[1]);
        assert!(out[2].starts_with("err "), "{}", out[2]);
        assert!(out[3].starts_with("ok v=1 "), "{}", out[3]);
    }

    #[test]
    fn truncated_batch_answers_every_slot() {
        let eng = engine("truncated");
        let out = drive(&eng, "batch 3\nrows 0\n");
        assert_eq!(out.len(), 3, "{out:?}");
        assert!(out[0].starts_with("ok v=1 "), "{}", out[0]);
        assert!(out[1].contains("truncated"), "{}", out[1]);
        assert!(out[2].contains("truncated"), "{}", out[2]);
    }

    #[test]
    fn swap_and_reload_bump_the_version() {
        let eng = engine("swap");
        let staged = tmp("swap_staged.rsm");
        let w2: Vec<f64> = (0..8).map(|j| j as f64).collect();
        ScoringModel::new(w2, None).unwrap().save(&staged).unwrap();
        let input = format!("rows 0\nswap {}\nrows 0\nreload\nrows 0\nquit\n", staged.display());
        let out = drive(&eng, &input);
        assert_eq!(out.len(), 5, "{out:?}");
        assert!(out[0].starts_with("ok v=1 "), "{}", out[0]);
        assert_eq!(out[1], "ok v=2 swapped=true");
        assert!(out[2].starts_with("ok v=2 "), "{}", out[2]);
        assert_eq!(out[3], "ok v=3 reloaded=true");
        assert!(out[4].starts_with("ok v=3 "), "{}", out[4]);
        // The staged file was consumed by the atomic rename.
        assert!(!staged.exists());
        // Scores actually changed with the weights.
        assert_ne!(out[0].split(' ').nth(2), out[2].split(' ').nth(2));
    }

    #[test]
    fn swap_to_garbage_keeps_serving_old_model() {
        let eng = engine("badswap");
        let staged = tmp("badswap_staged.rsm");
        std::fs::write(&staged, b"definitely not a model").unwrap();
        let input = format!("rows 0\nswap {}\nrows 0\n", staged.display());
        let out = drive(&eng, &input);
        assert_eq!(out.len(), 3, "{out:?}");
        let first = out[0].clone();
        assert!(out[1].starts_with("err "), "{}", out[1]);
        assert_eq!(out[2], first, "old model keeps serving byte-identically");
    }
}
