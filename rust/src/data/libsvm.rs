//! LibSVM / SVM-light format I/O.
//!
//! `label [qid:<q>] idx:val idx:val ...` per line, 1-based feature
//! indices, `#` comments. This is the interchange format of Cadata,
//! RCV1, SVM^rank and friends, so real corpora drop in without code
//! changes (the benches default to the synthetic substitutes).

use super::dataset::Dataset;
use crate::linalg::CsrMatrix;
use anyhow::{bail, Context, Result};
use std::io::{BufRead, BufReader, Write};
use std::path::Path;

/// Parse a dataset from a libsvm-format file.
pub fn read(path: impl AsRef<Path>) -> Result<Dataset> {
    let path = path.as_ref();
    let file = std::fs::File::open(path).with_context(|| format!("open {}", path.display()))?;
    let reader = BufReader::new(file);
    parse(reader, &path.display().to_string())
}

/// One parsed libsvm example, reusable across lines (the streaming
/// converter's per-line allocation budget is this struct).
#[derive(Debug, Default, Clone)]
pub(crate) struct Example {
    pub label: f64,
    pub qid: Option<u64>,
    /// `(1-based index, value)` pairs, strictly increasing by index.
    /// Zero values are *kept* here: they still widen the feature space
    /// (`max index` semantics) even though they emit no CSR entry.
    pub feats: Vec<(usize, f64)>,
}

/// Parse one libsvm line into `out`. Returns `false` for blank /
/// comment-only lines (nothing parsed). This is the single validation
/// gate shared by the in-memory [`parse`] and the streaming pallas-store
/// converter (`store::convert_libsvm`) — both paths reject exactly the
/// same inputs with the same `name:line` messages, which is what makes
/// the two load paths bit-identical on everything they accept.
///
/// Hardened beyond the loose libsvm convention:
///
/// - labels and feature values must be finite (a NaN/Inf would otherwise
///   surface much later, mid-training);
/// - feature indices must be strictly increasing within a row (the
///   format's sorted convention) — duplicates or out-of-order indices
///   are rejected instead of silently emitting duplicate CSR triplets;
/// - a `qid:<q>` token is accepted anywhere among the feature tokens
///   (some exporters emit it last), but two conflicting `qid`s on one
///   line are rejected;
/// - CRLF line endings are accepted (`BufRead::lines` strips the full
///   CRLF pair; a regression test pins it).
pub(crate) fn parse_line(line: &str, name: &str, lno: usize, out: &mut Example) -> Result<bool> {
    let line = line.split('#').next().unwrap_or("").trim();
    if line.is_empty() {
        return Ok(false);
    }
    out.feats.clear();
    out.qid = None;
    let mut parts = line.split_ascii_whitespace();
    let label: f64 = parts
        .next()
        .unwrap()
        .parse()
        .with_context(|| format!("{name}:{lno}: bad label"))?;
    if !label.is_finite() {
        bail!("{name}:{lno}: non-finite label {label}");
    }
    out.label = label;
    let mut prev_idx = 0usize;
    for tok in parts {
        let (k, v) = tok
            .split_once(':')
            .with_context(|| format!("{name}:{lno}: expected idx:val, got {tok:?}"))?;
        if k == "qid" {
            let q = v.parse::<u64>().with_context(|| format!("{name}:{lno}: bad qid"))?;
            if let Some(prev) = out.qid {
                if prev != q {
                    bail!("{name}:{lno}: conflicting qids {prev} and {q}");
                }
            }
            out.qid = Some(q);
            continue;
        }
        let idx: usize = k.parse().with_context(|| format!("{name}:{lno}: bad index {k:?}"))?;
        if idx == 0 {
            bail!("{name}:{lno}: libsvm feature indices are 1-based");
        }
        if idx == prev_idx {
            bail!("{name}:{lno}: duplicate feature index {idx}");
        }
        if idx < prev_idx {
            bail!(
                "{name}:{lno}: feature index {idx} after {prev_idx} \
                 (indices must be strictly increasing)"
            );
        }
        prev_idx = idx;
        let val: f64 = v.parse().with_context(|| format!("{name}:{lno}: bad value {v:?}"))?;
        if !val.is_finite() {
            bail!("{name}:{lno}: non-finite value {val} for feature {idx}");
        }
        out.feats.push((idx, val));
    }
    Ok(true)
}

/// Per-dataset accumulator state shared by every libsvm consumer: the
/// feature-space width (`max index`, zero values included), the qid
/// vector with its missing-qid-defaults-to-0 rule, and the label list.
/// Keeping this policy in one place (next to [`parse_line`]) is what
/// makes the in-memory path and the streaming pallas-store converter
/// *structurally* bit-identical rather than coincidentally so.
#[derive(Debug, Default)]
pub(crate) struct RowAccumulator {
    pub y: Vec<f64>,
    pub qids: Vec<u64>,
    pub any_qid: bool,
    pub max_col: usize,
}

impl RowAccumulator {
    /// Fold one parsed example in, yielding each *non-zero* feature (as
    /// its 1-based index plus value) to `emit`.
    pub fn push(
        &mut self,
        ex: &Example,
        mut emit: impl FnMut(usize, f64) -> Result<()>,
    ) -> Result<()> {
        self.y.push(ex.label);
        for &(idx, val) in &ex.feats {
            self.max_col = self.max_col.max(idx);
            if val != 0.0 {
                emit(idx, val)?;
            }
        }
        if let Some(q) = ex.qid {
            self.any_qid = true;
            self.qids.push(q);
        } else {
            self.qids.push(0);
        }
        Ok(())
    }

    /// The qid vector for [`Dataset`]-shaped consumers: `None` when no
    /// line carried a qid.
    pub fn into_qid(self) -> (Vec<f64>, Option<Vec<u64>>, usize) {
        let qid = if self.any_qid { Some(self.qids) } else { None };
        (self.y, qid, self.max_col)
    }
}

/// Parse from any reader (testable). See `parse_line` for the exact
/// validation contract.
pub fn parse<R: BufRead>(reader: R, name: &str) -> Result<Dataset> {
    let mut acc = RowAccumulator::default();
    let mut triplets = Vec::new();
    let mut ex = Example::default();
    for (lineno, line) in reader.lines().enumerate() {
        let line = line?;
        if !parse_line(&line, name, lineno + 1, &mut ex)? {
            continue;
        }
        let row = acc.y.len();
        acc.push(&ex, |idx, val| {
            triplets.push((row, idx - 1, val));
            Ok(())
        })?;
    }
    let (y, qid, max_col) = acc.into_qid();
    let x = CsrMatrix::from_triplets(y.len(), max_col, triplets);
    Ok(Dataset::new(x, y, qid, name))
}

/// Write a dataset (owned or mapped) in libsvm format.
pub fn write(ds: &dyn super::DatasetView, path: impl AsRef<Path>) -> Result<()> {
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    let x = ds.x();
    let y = ds.y();
    let qid = ds.qid();
    for i in 0..ds.len() {
        write!(f, "{}", y[i])?;
        if let Some(q) = qid {
            write!(f, " qid:{}", q[i])?;
        }
        let (idx, val) = x.row(i);
        for (&j, &v) in idx.iter().zip(val) {
            write!(f, " {}:{}", j + 1, v)?;
        }
        writeln!(f)?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_basic_format() {
        let text = "1.5 1:2.0 3:4.0\n-0.5 2:1.0 # comment\n\n2 1:1 2:1 3:1\n";
        let ds = parse(std::io::Cursor::new(text), "test").unwrap();
        assert_eq!(ds.len(), 3);
        assert_eq!(ds.dim(), 3);
        assert_eq!(ds.y, vec![1.5, -0.5, 2.0]);
        assert!(ds.qid.is_none());
        assert_eq!(ds.x.row(0), (&[0u32, 2][..], &[2.0, 4.0][..]));
    }

    #[test]
    fn parses_qid() {
        let text = "3 qid:1 1:0.5\n1 qid:1 2:0.5\n2 qid:2 1:1.0\n";
        let ds = parse(std::io::Cursor::new(text), "test").unwrap();
        assert_eq!(ds.qid, Some(vec![1, 1, 2]));
    }

    #[test]
    fn rejects_zero_index() {
        let r = parse(std::io::Cursor::new("1 0:2.0\n"), "test");
        assert!(r.is_err());
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse(std::io::Cursor::new("abc 1:2\n"), "t").is_err());
        assert!(parse(std::io::Cursor::new("1 nocolon\n"), "t").is_err());
    }

    #[test]
    fn rejects_non_finite_labels_and_values_with_line_numbers() {
        for bad in ["nan", "inf", "-inf", "NaN", "Infinity"] {
            let text = format!("1 1:2.0\n{bad} 1:2.0\n");
            let err = parse(std::io::Cursor::new(text), "t").unwrap_err();
            assert!(err.to_string().contains("t:2"), "{bad}: {err}");
        }
        let err = parse(std::io::Cursor::new("1 1:2.0\n2 1:nan\n"), "t").unwrap_err();
        assert!(err.to_string().contains("t:2"), "{err}");
        let err = parse(std::io::Cursor::new("2 1:1 2:inf\n"), "t").unwrap_err();
        assert!(err.to_string().contains("t:1"), "{err}");
    }

    #[test]
    fn rejects_duplicate_and_decreasing_indices() {
        let err = parse(std::io::Cursor::new("1 1:2.0 1:3.0\n"), "t").unwrap_err();
        assert!(err.to_string().contains("t:1"), "{err}");
        assert!(err.to_string().contains("duplicate"), "{err}");
        let err = parse(std::io::Cursor::new("1 1:2.0\n1 3:1.0 2:1.0\n"), "t").unwrap_err();
        assert!(err.to_string().contains("t:2"), "{err}");
        assert!(err.to_string().contains("strictly increasing"), "{err}");
    }

    #[test]
    fn accepts_qid_after_features_and_rejects_conflicts() {
        let text = "3 1:0.5 qid:1\n1 qid:1 2:0.5\n2 1:1.0 qid:2 2:2.0\n";
        let ds = parse(std::io::Cursor::new(text), "t").unwrap();
        assert_eq!(ds.qid, Some(vec![1, 1, 2]));
        // The same qid twice is tolerated; two different qids are not.
        assert!(parse(std::io::Cursor::new("1 qid:1 1:1 qid:1\n"), "t").is_ok());
        let err = parse(std::io::Cursor::new("1 qid:1 1:1 qid:2\n"), "t").unwrap_err();
        assert!(err.to_string().contains("conflicting"), "{err}");
    }

    #[test]
    fn accepts_crlf_line_endings() {
        let text = "1.5 1:2.0 3:4.0\r\n-0.5 2:1.0 # comment\r\n2 qid:7 1:1\r\n";
        let ds = parse(std::io::Cursor::new(text), "t").unwrap();
        assert_eq!(ds.len(), 3);
        assert_eq!(ds.y, vec![1.5, -0.5, 2.0]);
        assert_eq!(ds.qid, Some(vec![0, 0, 7]));
        assert_eq!(ds.x.row(0), (&[0u32, 2][..], &[2.0, 4.0][..]));
    }

    #[test]
    fn round_trip() {
        let d = crate::data::synthetic::cadata_like(20, 3);
        let tmp = std::env::temp_dir().join("ranksvm_libsvm_roundtrip.txt");
        write(&d, &tmp).unwrap();
        let back = read(&tmp).unwrap();
        assert_eq!(back.len(), d.len());
        for (a, b) in back.y.iter().zip(&d.y) {
            assert!((a - b).abs() < 1e-9);
        }
        // feature values survive (dims may shrink if last col is all-zero)
        for i in 0..d.len() {
            let (ia, va) = d.x.row(i);
            let (ib, vb) = back.x.row(i);
            assert_eq!(ia, ib);
            for (x, z) in va.iter().zip(vb) {
                assert!((x - z).abs() < 1e-9);
            }
        }
        std::fs::remove_file(tmp).ok();
    }

    #[test]
    fn empty_file_gives_empty_dataset() {
        let ds = parse(std::io::Cursor::new("# only comments\n"), "t").unwrap();
        assert!(ds.is_empty());
    }
}
