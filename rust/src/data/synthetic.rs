//! Synthetic dataset generators standing in for the paper's corpora
//! (DESIGN.md §6 documents the substitutions).
//!
//! - [`cadata_like`]: the Cadata regression set — dense, 8 features,
//!   real-valued targets (r ≈ m). We generate features from mixtures of
//!   normals and targets from a noisy nonlinear response, matching the
//!   dimensionality, density and full-range label structure.
//! - [`reuters_like`]: the paper's RCV1 construction — sparse tf-idf-like
//!   documents (Zipf-distributed vocabulary, ~50 nnz/doc), with the
//!   utility score of each document defined as its dot product with a
//!   held-out target document. The score construction is the paper's own
//!   (§5.1); only the documents themselves are synthetic.
//! - [`ordinal`]: discrete 1..r star ratings (the SVM^rank-friendly
//!   regime of Joachims 2006).
//! - [`queries`]: query-grouped retrieval data for the per-subset
//!   setting of §2.

use super::dataset::Dataset;
use crate::linalg::CsrMatrix;
use crate::util::rng::Rng;

/// Dense low-dimensional data with real-valued utilities (Cadata stand-in:
/// m up to ~20k, n = 8). Labels are a noisy nonlinear function of the
/// features so a linear ranker attains a nontrivial but learnable error.
pub fn cadata_like(m: usize, seed: u64) -> Dataset {
    let n = 8;
    let mut rng = Rng::new(seed);
    // Hidden linear preference direction + curvature + noise.
    let w_true: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
    let mut triplets = Vec::with_capacity(m * n);
    let mut y = Vec::with_capacity(m);
    for i in 0..m {
        let mut score = 0.0;
        let mut x_row = [0.0; 8];
        for (j, xr) in x_row.iter_mut().enumerate() {
            // Feature scales vary across columns (income vs. rooms vs. lat).
            let scale = 1.0 + j as f64;
            let v = rng.normal() * scale;
            *xr = v;
            score += w_true[j] * v / scale;
        }
        // Mild nonlinearity + noise keeps r ≈ m (almost surely distinct).
        let label = score + 0.3 * score * score + 0.2 * rng.normal();
        for (j, &v) in x_row.iter().enumerate() {
            triplets.push((i, j, v));
        }
        y.push(label);
    }
    Dataset::new(CsrMatrix::from_triplets(m, n, triplets), y, None, format!("cadata-like(m={m})"))
}

/// Sparse high-dimensional documents with similarity-to-target utilities
/// (Reuters RCV1 stand-in). `vocab` defaults to 50 000 and `nnz_per_doc`
/// to ~50 in [`reuters_like`].
pub fn reuters_like_with(m: usize, vocab: usize, nnz_per_doc: usize, seed: u64) -> Dataset {
    let mut rng = Rng::new(seed);
    // Held-out "target document": moderately dense so that most documents
    // share at least some vocabulary with it (non-degenerate utilities).
    let target_nnz = (nnz_per_doc * 8).min(vocab);
    let mut target = vec![0.0f64; vocab];
    for _ in 0..target_nnz {
        let j = rng.zipf(vocab, 1.2);
        target[j] = rng.range(0.2, 1.0);
    }
    let mut triplets = Vec::with_capacity(m * nnz_per_doc);
    let mut y = Vec::with_capacity(m);
    for i in 0..m {
        // Document length varies ±50% around the mean, Zipf vocabulary,
        // tf-idf-like positive weights.
        let len = (nnz_per_doc / 2).max(1) + rng.below(nnz_per_doc.max(1));
        let mut score = 0.0;
        let mut seen = std::collections::HashSet::with_capacity(len);
        for _ in 0..len {
            let j = rng.zipf(vocab, 1.2);
            if !seen.insert(j) {
                continue; // duplicate term in this doc — skip
            }
            let v = rng.range(0.05, 1.0); // tf-idf weight
            triplets.push((i, j, v));
            score += v * target[j];
        }
        // Utility = similarity to the target document (paper §5.1).
        y.push(score);
    }
    Dataset::new(
        CsrMatrix::from_triplets(m, vocab, triplets),
        y,
        None,
        format!("reuters-like(m={m},v={vocab})"),
    )
}

/// Reuters stand-in with the paper's dimensions (50k vocab, s ≈ 50).
pub fn reuters_like(m: usize, seed: u64) -> Dataset {
    reuters_like_with(m, 50_000, 50, seed)
}

/// Ordinal-ratings data: dense features, labels quantized to `1..=levels`
/// stars — the small-r regime where the r-level algorithm shines.
pub fn ordinal(m: usize, levels: usize, seed: u64) -> Dataset {
    assert!(levels >= 2);
    let base = cadata_like(m, seed);
    // Quantize the real-valued utilities into `levels` buckets by rank so
    // the classes are balanced.
    let mut order: Vec<usize> = (0..m).collect();
    order.sort_unstable_by(|&a, &b| base.y[a].total_cmp(&base.y[b]).then(a.cmp(&b)));
    let mut y = vec![0.0; m];
    for (rank, &i) in order.iter().enumerate() {
        y[i] = 1.0 + ((rank * levels) / m.max(1)) as f64;
    }
    Dataset::new(base.x, y, None, format!("ordinal(m={m},r={levels})"))
}

/// Query-grouped retrieval data: `n_queries` groups of `per_query`
/// documents; utilities are only meaningful within a group.
pub fn queries(n_queries: usize, per_query: usize, n_features: usize, seed: u64) -> Dataset {
    let mut rng = Rng::new(seed);
    let m = n_queries * per_query;
    let mut triplets = Vec::new();
    let mut y = Vec::with_capacity(m);
    let mut qid = Vec::with_capacity(m);
    // Global relevance direction shared across queries (learnable) plus a
    // per-query offset direction (not learnable — must be ignored).
    let w_shared: Vec<f64> = (0..n_features).map(|_| rng.normal()).collect();
    for q in 0..n_queries {
        let offset: Vec<f64> = (0..n_features).map(|_| rng.normal() * 2.0).collect();
        for k in 0..per_query {
            let i = q * per_query + k;
            let mut score = 0.0;
            for j in 0..n_features {
                let v = rng.normal() + offset[j];
                triplets.push((i, j, v));
                score += w_shared[j] * (v - offset[j]);
            }
            y.push(score + 0.1 * rng.normal());
            qid.push(q as u64);
        }
    }
    Dataset::new(
        CsrMatrix::from_triplets(m, n_features, triplets),
        y,
        Some(qid),
        format!("queries({n_queries}x{per_query})"),
    )
}

/// Zipf-skewed query-grouped retrieval data: `n_groups` groups whose
/// sizes follow a power law (size of group `k` ∝ `(k+1)^−a`, so group 0
/// is giant and the tail is mostly singletons), apportioned to exactly
/// `m` total examples with every group keeping at least one. This is
/// the adversarial regime for shard balancing — the group-size
/// distribution real click/retrieval corpora exhibit — used by the
/// work-stealing skew benchmark (`benches/skew_balance.rs`), the
/// scheduler test battery, and the CI thread-matrix fixture. Features
/// and labels follow the [`queries`] construction (shared learnable
/// direction + per-query nuisance offset).
pub fn zipf_queries(m: usize, n_groups: usize, n_features: usize, a: f64, seed: u64) -> Dataset {
    if m == 0 {
        let x = CsrMatrix::from_triplets(0, n_features, Vec::new());
        return Dataset::new(x, Vec::new(), Some(Vec::new()), "zipf-queries(m=0)".into());
    }
    let n_groups = n_groups.clamp(1, m);
    assert!(a > 0.0, "Zipf exponent must be positive");
    // Deterministic apportionment: one example per group up front, the
    // rest by floored power-law shares, the remainder dealt from the
    // head (the head is where rounding took the most).
    let weights: Vec<f64> = (1..=n_groups).map(|k| (k as f64).powf(-a)).collect();
    let total: f64 = weights.iter().sum();
    let spare = m - n_groups;
    let mut sizes: Vec<usize> =
        weights.iter().map(|w| 1 + (spare as f64 * w / total) as usize).collect();
    let mut leftover = m - sizes.iter().sum::<usize>();
    let mut g = 0;
    while leftover > 0 {
        sizes[g % n_groups] += 1;
        leftover -= 1;
        g += 1;
    }
    debug_assert_eq!(sizes.iter().sum::<usize>(), m);

    let mut rng = Rng::new(seed);
    let w_shared: Vec<f64> = (0..n_features).map(|_| rng.normal()).collect();
    let mut triplets = Vec::new();
    let mut y = Vec::with_capacity(m);
    let mut qid = Vec::with_capacity(m);
    let mut i = 0usize;
    for (q, &sz) in sizes.iter().enumerate() {
        let offset: Vec<f64> = (0..n_features).map(|_| rng.normal() * 2.0).collect();
        for _ in 0..sz {
            let mut score = 0.0;
            for j in 0..n_features {
                let v = rng.normal() + offset[j];
                triplets.push((i, j, v));
                score += w_shared[j] * (v - offset[j]);
            }
            y.push(score + 0.1 * rng.normal());
            qid.push(q as u64);
            i += 1;
        }
    }
    Dataset::new(
        CsrMatrix::from_triplets(m, n_features, triplets),
        y,
        Some(qid),
        format!("zipf-queries(m={m},g={n_groups},a={a})"),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cadata_shape_and_levels() {
        let d = cadata_like(500, 1);
        assert_eq!(d.len(), 500);
        assert_eq!(d.dim(), 8);
        assert_eq!(d.sparsity(), 8.0); // dense
        // Real-valued labels: essentially all distinct (r ≈ m).
        assert!(d.n_levels() > 490);
    }

    #[test]
    fn cadata_deterministic() {
        let a = cadata_like(50, 9);
        let b = cadata_like(50, 9);
        assert_eq!(a.y, b.y);
        let c = cadata_like(50, 10);
        assert_ne!(a.y, c.y);
    }

    #[test]
    fn reuters_sparse_and_distinct() {
        let d = reuters_like_with(300, 5000, 30, 2);
        assert_eq!(d.len(), 300);
        assert_eq!(d.dim(), 5000);
        let s = d.sparsity();
        assert!(s > 10.0 && s < 60.0, "sparsity {s}");
        // dot-product scores: overwhelmingly distinct
        assert!(d.n_levels() > 250, "levels {}", d.n_levels());
        // non-degenerate: scores vary
        let mx = d.y.iter().cloned().fold(f64::MIN, f64::max);
        let mn = d.y.iter().cloned().fold(f64::MAX, f64::min);
        assert!(mx > mn);
    }

    #[test]
    fn ordinal_has_exact_levels() {
        let d = ordinal(400, 5, 3);
        assert_eq!(d.n_levels(), 5);
        for &v in &d.y {
            assert!((1.0..=5.0).contains(&v));
            assert_eq!(v.fract(), 0.0);
        }
    }

    #[test]
    fn queries_grouped() {
        let d = queries(10, 20, 6, 4);
        assert_eq!(d.len(), 200);
        let q = d.qid.as_ref().unwrap();
        assert_eq!(q.iter().filter(|&&x| x == 3).count(), 20);
    }

    #[test]
    fn zipf_queries_sizes_are_skewed_and_exact() {
        let d = zipf_queries(3000, 600, 6, 1.1, 5);
        assert_eq!(d.len(), 3000);
        let q = d.qid.as_ref().unwrap();
        let mut sizes = vec![0usize; 600];
        for &g in q {
            sizes[g as usize] += 1;
        }
        assert_eq!(sizes.iter().sum::<usize>(), 3000);
        assert!(sizes.iter().all(|&s| s >= 1), "every group keeps one example");
        // Head dominance: group 0 is much larger than the median group.
        assert!(sizes[0] > 20 * sizes[300], "head {} vs median {}", sizes[0], sizes[300]);
        // Sizes are nonincreasing apart from the round-robin remainder.
        assert!(sizes[0] >= sizes[10] && sizes[10] >= sizes[100]);
        // Deterministic in the seed.
        let e = zipf_queries(3000, 600, 6, 1.1, 5);
        assert_eq!(d.y, e.y);
        assert_ne!(d.y, zipf_queries(3000, 600, 6, 1.1, 6).y);
    }

    #[test]
    fn linear_signal_is_learnable() {
        // Sanity: ranking by a least-squares fit on cadata-like data beats
        // random ordering by a wide margin (the generator has real signal).
        let d = cadata_like(400, 11);
        // crude fit: w = Xᵀy / m (one power-iteration-ish step)
        let mut w = vec![0.0; d.dim()];
        d.x.matvec_t(&d.y, &mut w);
        let mut p = vec![0.0; d.len()];
        d.x.matvec(&w, &mut p);
        let err = crate::metrics::pairwise_error(&p, &d.y);
        assert!(err < 0.35, "ranking error {err} too high — no signal?");
    }
}
