//! Kernelized RankSVM through the reduced-set (Nyström) approximation —
//! the paper's §6 extension realized: a nonlinear ranking problem that
//! defeats any linear ranker, solved by TreeRSVM on RBF Nyström features
//! while keeping the O(ms + m log m) per-iteration cost (s = reduced-set
//! size).
//!
//!     cargo run --release --example kernel_ranking

use ranksvm::coordinator::{train, Method, TrainConfig};
use ranksvm::data::Dataset;
use ranksvm::kernel::{train_kernel, Kernel};
use ranksvm::linalg::CsrMatrix;
use ranksvm::metrics;
use ranksvm::util::rng::Rng;

/// Ring-shaped utility: items closest to radius 2 are best — strictly
/// non-monotone in every linear direction.
fn ring_dataset(m: usize, seed: u64) -> Dataset {
    let mut rng = Rng::new(seed);
    let mut triplets = Vec::new();
    let mut y = Vec::with_capacity(m);
    for i in 0..m {
        let a = rng.normal();
        let b = rng.normal();
        triplets.push((i, 0, a));
        triplets.push((i, 1, b));
        let r = (a * a + b * b).sqrt();
        y.push(-(r - 2.0).abs() + 0.02 * rng.normal());
    }
    Dataset::new(CsrMatrix::from_triplets(m, 2, triplets), y, None, "ring")
}

fn main() -> anyhow::Result<()> {
    let ds = ring_dataset(1200, 2024);
    let (tr, te) = ds.split(400, 5);
    let cfg = TrainConfig { method: Method::Tree, lambda: 1e-3, ..Default::default() };
    // NDCG gains need non-negative labels; ranking metrics are invariant
    // to the shift.
    let y_min = te.y.iter().cloned().fold(f64::INFINITY, f64::min);
    let te_gain: Vec<f64> = te.y.iter().map(|v| v - y_min).collect();

    // Linear RankSVM: doomed on a ring.
    let lin = train(&tr, &cfg)?;
    let lin_pred = lin.model.predict(&te);
    println!(
        "linear  RankSVM: test pairwise error {:.4}  ndcg@10 {:.4}",
        metrics::pairwise_error(&lin_pred, &te.y),
        metrics::ndcg_at_k(&lin_pred, &te_gain, 10),
    );

    // RBF reduced-set RankSVM across reduced-set sizes.
    for k in [10usize, 50, 200] {
        let t = std::time::Instant::now();
        let (km, outcome) = train_kernel(&tr, &cfg, Kernel::Rbf { gamma: 0.5 }, k, 7)?;
        let pred = km.predict(&te);
        println!(
            "rbf k={k:<4} RankSVM: test pairwise error {:.4}  ndcg@10 {:.4}  ({} iters, {:.2}s)",
            metrics::pairwise_error(&pred, &te.y),
            metrics::ndcg_at_k(&pred, &te_gain, 10),
            outcome.iterations,
            t.elapsed().as_secs_f64(),
        );
    }
    println!("\n(linear ≈ 0.5 = random on a ring; RBF reduced-set should reach < 0.1)");
    Ok(())
}
