//! Minimal JSON emission for metric logs and bench reports.
//!
//! The offline crate set ships no `serde`/`serde_json`; benches and the
//! trainer emit machine-readable records through this tiny writer instead.
//! Only what we need: objects, arrays, strings, numbers, bools.

use std::fmt::Write as _;

/// A JSON value builder producing compact single-line output.
#[derive(Clone, Debug)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Int(i64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Convenience: object from key/value pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Convenience: array of f64.
    pub fn nums(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    /// Serialize to a compact string.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Int(i) => {
                let _ = write!(out, "{i}");
            }
            Json::Num(x) => {
                if x.is_finite() {
                    let _ = write!(out, "{x}");
                } else {
                    out.push_str("null"); // JSON has no NaN/Inf
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\r' => out.push_str("\\r"),
                        '\t' => out.push_str("\\t"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(xs) => {
                out.push('[');
                for (i, x) in xs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(kvs) => {
                out.push('{');
                for (i, (k, v)) in kvs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

impl From<&str> for Json {
    fn from(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}
impl From<f64> for Json {
    fn from(x: f64) -> Json {
        Json::Num(x)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Json {
        Json::Int(x as i64)
    }
}
impl From<bool> for Json {
    fn from(x: bool) -> Json {
        Json::Bool(x)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_object() {
        let j = Json::obj(vec![
            ("method", "tree".into()),
            ("m", 1000usize.into()),
            ("loss", 0.25f64.into()),
            ("ok", true.into()),
        ]);
        assert_eq!(j.to_string(), r#"{"method":"tree","m":1000,"loss":0.25,"ok":true}"#);
    }

    #[test]
    fn escapes_strings() {
        let j = Json::Str("a\"b\\c\nd".to_string());
        assert_eq!(j.to_string(), r#""a\"b\\c\nd""#);
    }

    #[test]
    fn nan_becomes_null() {
        assert_eq!(Json::Num(f64::NAN).to_string(), "null");
        assert_eq!(Json::Num(f64::INFINITY).to_string(), "null");
    }

    #[test]
    fn nested_arrays() {
        let j = Json::Arr(vec![Json::nums(&[1.0, 2.5]), Json::Null]);
        assert_eq!(j.to_string(), "[[1,2.5],null]");
    }
}
