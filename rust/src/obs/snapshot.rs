//! Shared `BENCH_*.json` metrics-snapshot schema
//! (docs/OBSERVABILITY.md "Bench snapshots").
//!
//! Every bench binary (`serve_qps`, `skew_balance`,
//! `fig1_iteration_cost`, `convert_throughput`, `modelsel_sweep`) emits
//! its committed snapshot through [`bench_snapshot`], so the perf
//! trajectory accumulates records with one comparable shape:
//!
//! ```json
//! {"schema":"ranksvm-bench-snapshot","schema_version":1,
//!  "bench":"serve_qps","placeholder":false,
//!  "params":{...fixture parameters...},
//!  "metrics":[{...one object per measured mode...}]}
//! ```
//!
//! `placeholder: true` marks a schema-only snapshot (no measurements —
//! all metric values `null`); CI runs each bench with
//! `RANKSVM_SNAPSHOT_SCHEMA_ONLY=1` and fails when the emitted key sets
//! drift from the committed `BENCH_*.json`.

use crate::util::json::Json;

/// Value of the `schema` discriminator field.
pub const SNAPSHOT_SCHEMA: &str = "ranksvm-bench-snapshot";

/// Bumped whenever the envelope (not a bench's own metric keys) changes.
pub const SNAPSHOT_SCHEMA_VERSION: i64 = 1;

/// Envelope field names, in emission order.
pub static SNAPSHOT_FIELDS: &[&str] =
    &["schema", "schema_version", "bench", "placeholder", "params", "metrics"];

/// Wrap a bench's parameters and per-mode metric rows in the shared
/// snapshot envelope. `params` must be an object, `metrics` an array of
/// objects with identical key sets (one row per measured mode).
pub fn bench_snapshot(bench: &str, placeholder: bool, params: Json, metrics: Vec<Json>) -> Json {
    Json::Obj(vec![
        ("schema".into(), SNAPSHOT_SCHEMA.into()),
        ("schema_version".into(), Json::Int(SNAPSHOT_SCHEMA_VERSION)),
        ("bench".into(), bench.into()),
        ("placeholder".into(), placeholder.into()),
        ("params".into(), params),
        ("metrics".into(), Json::Arr(metrics)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn envelope_matches_the_normative_field_list() {
        let snap = bench_snapshot(
            "serve_qps",
            true,
            Json::Obj(vec![("m".into(), 100usize.into())]),
            vec![Json::Obj(vec![("qps".into(), Json::Null)])],
        );
        match &snap {
            Json::Obj(kv) => {
                let keys: Vec<&str> = kv.iter().map(|(k, _)| k.as_str()).collect();
                assert_eq!(keys, SNAPSHOT_FIELDS);
            }
            other => panic!("expected object, got {other}"),
        }
        let text = snap.to_string();
        assert!(text.contains("\"schema\":\"ranksvm-bench-snapshot\""), "{text}");
        assert!(text.contains("\"schema_version\":1"), "{text}");
    }
}
