//! # ranksvm — linearithmic linear RankSVM training
//!
//! A production-grade reproduction of Airola, Pahikkala & Salakoski,
//! *"Training linear ranking SVMs in linearithmic time using red-black
//! trees"* (Pattern Recognition Letters, 2010).
//!
//! The crate implements the full system of the paper:
//!
//! - [`rbtree`] — the order-statistics red-black tree (Definition 1) with
//!   `Tree-Insert` / `Count-Smaller` / `Count-Larger` in `O(log m)`;
//! - [`losses`] — the `O(ms + m log m)` loss/subgradient oracle
//!   (Algorithm 3, "TreeRSVM") plus every baseline the paper evaluates:
//!   the explicit-pairs `O(m²)` oracle ("PairRSVM"), the r-level
//!   algorithm of Joachims (2006) ("SVM^rank"), and the squared pairwise
//!   hinge of Chapelle & Keerthi (2010) ("PRSVM") — and the
//!   query-sharded parallel engine ([`losses::ShardedTreeOracle`]) that
//!   runs Algorithm 3 across a persistent [`runtime::WorkerPool`] with
//!   bit-identical results for any thread count (including a
//!   deterministic parallel argsort, [`linalg::ops::par_argsort_into`]);
//! - [`bmrm`] — bundle-method / cutting-plane optimization (Algorithm 1)
//!   with a dual coordinate-descent inner QP and an optional OCAS-style
//!   line search;
//! - [`newton`] — truncated-Newton optimizer for the PRSVM baseline;
//! - [`data`], [`metrics`], [`linalg`] — dataset substrates
//!   (libsvm I/O, Cadata-like and Reuters-like synthetic generators, and
//!   the memory-mapped [`data::store`] pallas store for out-of-core
//!   training — convert once, mmap forever, bit-identical to the text
//!   path), `O(m log m)` ranking metrics, and dense/CSR/CSC kernels
//!   (owned [`linalg::CsrMatrix`] / borrowed zero-copy
//!   [`linalg::CsrView`]);
//! - [`compute`] + [`runtime`] — a pluggable compute backend: native Rust
//!   kernels (serial, or row-sharded with a fixed reduction topology in
//!   [`compute::ParallelBackend`]), or AOT-compiled XLA executables
//!   (lowered from JAX/Pallas by `python/compile/aot.py`) executed via
//!   PJRT behind the `xla` cargo feature;
//! - [`coordinator`] — training orchestration, config, CLI, and the
//!   memory-probe subprocess used by the Fig.-3 benchmark;
//! - [`serve`] — the online scoring path: a versioned, checksummed
//!   [`serve::ScoringModel`] format that records the `--normalize`
//!   mode and training-set column norms (so raw inputs score
//!   correctly), and the `ranksvm serve` daemon — batched scoring on
//!   the shared worker pool, bounded-heap top-k, and atomic
//!   zero-downtime model hot swap;
//! - [`obs`] — the unified telemetry layer (docs/OBSERVABILITY.md): the
//!   process-wide metrics registry, the leveled log facade every
//!   subcommand shares, structured `train --trace` run traces, and the
//!   bench snapshot schema — all provably inert (training output is
//!   byte-identical with telemetry on or off, pinned by `tests/obs.rs`).
//!
//! Quick start (see `examples/quickstart.rs`):
//!
//! ```no_run
//! use ranksvm::coordinator::{TrainConfig, train};
//! use ranksvm::data::synthetic;
//!
//! let ds = synthetic::cadata_like(4000, 42);
//! let cfg = TrainConfig { lambda: 0.1, ..TrainConfig::default() };
//! let outcome = train(&ds, &cfg).unwrap();
//! println!("trained in {} iterations", outcome.iterations);
//! ```

pub mod bmrm;
pub mod compute;
pub mod coordinator;
pub mod data;
pub mod kernel;
pub mod linalg;
pub mod losses;
pub mod metrics;
pub mod newton;
pub mod obs;
pub mod rbtree;
pub mod runtime;
pub mod serve;
pub mod util;
